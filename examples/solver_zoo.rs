//! Paper Table 2: every DEIS variant x NFE grid on the trained model
//! (rust-native backend for sweep speed; PJRT parity is pinned by tests).
//!
//!     cargo run --release --example solver_zoo -- --dataset gmm2d

use deis::diffusion::Sde;
use deis::exp::{print_table, run_solver, sweep_model, QualityEval};
use deis::solvers::table2_kinds;
use deis::timegrid::GridKind;
use deis::util::cli::Args;

fn main() {
    let args = Args::parse_env();
    let dataset = args.str_or("dataset", "gmm2d");
    let n = args.usize_or("n", 4000);
    let nfes = [5usize, 10, 15, 20, 50];

    let model = sweep_model(&dataset);
    let eval = QualityEval::new(&dataset, 20_000);
    let sde = Sde::vp();

    let header: Vec<String> = nfes.iter().map(|v| format!("NFE {v}")).collect();
    let mut rows = Vec::new();
    for kind in table2_kinds() {
        let mut vals = Vec::new();
        for &nfe in &nfes {
            let (x, spent) =
                run_solver(&*model, &sde, kind, GridKind::Quadratic, 1e-3, nfe, n, 7);
            assert!(spent <= nfe, "{} overspent {spent}/{nfe}", kind.name());
            vals.push(eval.score(&x).swd1000);
        }
        rows.push((kind.name(), vals));
    }
    print_table(
        &format!("Table 2 (SWDx1000, {dataset}, quadratic grid, t0=1e-3)"),
        &header,
        &rows,
    );
}
