//! Paper App. B Q1: DEIS accelerates likelihood evaluation. On the analytic
//! GMM we have *exact* log-likelihood, so the error of the PF-ODE NLL is
//! measured directly: fixed-grid RK (the rho-grid Kutta spirit) converges to
//! the exact bits/dim with ~4x fewer NFE than a coarse-tolerance black box.
//!
//!     cargo run --release --example likelihood

use deis::diffusion::Sde;
use deis::gmm::Gmm;
use deis::likelihood::{nll_rk_t, GmmEpsDiv};
use deis::timegrid::{build, GridKind};
use deis::util::cli::Args;
use deis::util::rng::Rng;

fn main() {
    let args = Args::parse_env();
    let b = args.usize_or("n", 256);
    let sde = Sde::vp();
    let gmm = Gmm::ring2d(4.0, 8, 0.25);
    let model = GmmEpsDiv { gmm: gmm.clone(), sde };

    let mut rng = Rng::new(17);
    let x0 = gmm.sample(&mut rng, b);
    let exact = gmm.logp(&sde, &x0, 1e-3, b);
    let exact_bpd =
        -exact.iter().sum::<f64>() / (b as f64 * 2.0 * std::f64::consts::LN_2);
    println!("exact bits/dim at t0=1e-3: {exact_bpd:.4}\n");
    println!("{:<22}{:>8}{:>14}{:>14}", "grid", "NFE", "bits/dim", "|err|");

    for (kind, steps) in [
        (GridKind::LogRho, 3usize),
        (GridKind::LogRho, 6),
        (GridKind::LogRho, 9),
        (GridKind::LogRho, 15),
        (GridKind::LogRho, 25),
        (GridKind::Quadratic, 9),
        (GridKind::Quadratic, 25),
        (GridKind::Uniform, 25),
    ] {
        let grid = build(kind, &sde, 1e-3, 1.0, steps);
        let res = nll_rk_t(&model, &sde, &grid, &x0, b);
        println!(
            "{:<22}{:>8}{:>14.4}{:>14.5}",
            format!("{} x{}", kind.name(), steps),
            res.nfe,
            res.bits_per_dim,
            (res.bits_per_dim - exact_bpd).abs()
        );
    }
    println!(
        "\npaper B.1 shape: fixed rho-spaced RK reaches the converged NLL around \
         36 NFE vs ~130 for the adaptive blackbox (Tab. 13 note)."
    );
}
