//! Paper Fig. 5 / Table 9: the ingredient ablation ladder —
//!   Euler  ->  +EI (score param; WORSE, the Fig 3a surprise)
//!          ->  +eps param (== DDIM)  ->  +polynomial (tAB3)
//!          ->  +optimized timestamps  — plus RK45/EM baselines.
//!
//!     cargo run --release --example ablation

use deis::diffusion::Sde;
use deis::exp::{print_table, run_solver, sweep_model, QualityEval};
use deis::solvers::SolverKind;
use deis::timegrid::GridKind;
use deis::util::cli::Args;

fn main() {
    let args = Args::parse_env();
    let dataset = args.str_or("dataset", "gmm2d");
    let n = args.usize_or("n", 4000);
    let nfes = [5usize, 10, 20, 30, 50, 100];

    let model = sweep_model(&dataset);
    let eval = QualityEval::new(&dataset, 20_000);
    let sde = Sde::vp();

    // (label, solver, grid) — the ladder uses uniform-t until the last row.
    let ladder: Vec<(&str, SolverKind, GridKind)> = vec![
        ("euler", SolverKind::Euler, GridKind::Uniform),
        ("+EI", SolverKind::EiScore, GridKind::Uniform),
        ("+eps", SolverKind::Tab(0), GridKind::Uniform),
        ("+poly", SolverKind::Tab(3), GridKind::Uniform),
        ("+opt{t_i}", SolverKind::Tab(3), GridKind::Quadratic),
        ("rk45", SolverKind::Rk45, GridKind::Uniform),
        ("em", SolverKind::EulerMaruyama, GridKind::Uniform),
    ];

    let header: Vec<String> = nfes.iter().map(|v| format!("NFE {v}")).collect();
    let mut rows = Vec::new();
    for (label, kind, grid) in ladder {
        let mut vals = Vec::new();
        for &nfe in &nfes {
            if kind == SolverKind::Rk45 {
                // RK45 ignores NFE budgets; report at its natural spend only
                // in the closest column (Tab. 11 has the full tol sweep).
                vals.push(f64::NAN);
                continue;
            }
            let (x, _) = run_solver(&*model, &sde, kind, grid, 1e-3, nfe, n, 7);
            vals.push(eval.score(&x).swd1000);
        }
        rows.push((label.to_string(), vals));
    }
    print_table(
        &format!("Table 9 / Fig 5 ablation (SWDx1000, {dataset})"),
        &header,
        &rows,
    );
    println!("(rk45 rows: see table11_rk45 bench for the tolerance sweep)");
}
