//! End-to-end serving driver (the §5-headline experiment; EXPERIMENTS.md):
//! boots the coordinator over the PJRT-compiled model, fires a mixed
//! request workload from concurrent clients, and reports throughput,
//! latency percentiles, dynamic-batching effectiveness, and sample quality.
//!
//!     cargo run --release --example serve_bench -- --clients 16 --requests 8
//!
//! Flags: --clients N --requests M (per client) --n samples-per-request
//!        --model gmm2d|gmm2d_exact --batching off (disables merging)

use std::sync::Arc;
use std::time::Instant;

use deis::coordinator::{Coordinator, CoordinatorConfig, SampleRequest};
use deis::exp::{default_registry, QualityEval};
use deis::solvers::SolverKind;
use deis::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let clients = args.usize_or("clients", 16);
    let per_client = args.usize_or("requests", 8);
    let n = args.usize_or("n", 128);
    let model = args.str_or("model", "gmm2d");
    let batching = args.str_or("batching", "on") != "off";

    let reg = default_registry(&[model.clone()])?;
    let cfg = CoordinatorConfig {
        workers: args.usize_or("workers", 4),
        max_batch_samples: if batching { 1024 } else { 1 },
        ..Default::default()
    };
    let coord = Arc::new(Coordinator::new(cfg, reg));

    // Mixed solver/NFE workload: what a real sampling service sees.
    let mix = [
        (SolverKind::Tab(3), 10),
        (SolverKind::Tab(0), 20),
        (SolverKind::RhoHeun, 10),
        (SolverKind::Tab(2), 15),
    ];

    println!(
        "serve_bench: {clients} clients x {per_client} reqs x {n} samples, model={model}, \
         batching={batching}"
    );
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let coord = coord.clone();
        let model = model.clone();
        handles.push(std::thread::spawn(move || {
            let mut samples = Vec::new();
            for r in 0..per_client {
                let (solver, nfe) = mix[(c + r) % mix.len()];
                let mut req = SampleRequest::new(&model, solver, nfe, n);
                req.seed = (c * 1000 + r) as u64;
                let res = coord.sample_blocking(req).expect("request failed");
                if samples.len() < 4096 {
                    samples.extend_from_slice(&res.samples);
                }
            }
            samples
        }));
    }
    let mut pool = Vec::new();
    for h in handles {
        pool.extend(h.join().unwrap());
    }
    let wall = t0.elapsed();

    let total_requests = (clients * per_client) as f64;
    let total_samples = total_requests * n as f64;
    let stats = coord.stats();
    println!("\n== throughput ==");
    println!("wall time          {:>10.2} s", wall.as_secs_f64());
    println!("requests/s         {:>10.1}", total_requests / wall.as_secs_f64());
    println!("samples/s          {:>10.0}", total_samples / wall.as_secs_f64());
    println!("\n== latency (per request, end to end) ==");
    println!("p50                {:>10.1} ms", stats.p50_us as f64 / 1e3);
    println!("p99                {:>10.1} ms", stats.p99_us as f64 / 1e3);
    println!("mean               {:>10.1} ms", stats.mean_us / 1e3);
    println!("\n== batching ==");
    println!("solver runs        {:>10}", stats.batches);
    println!("requests merged    {:>10}", stats.merged_requests);
    println!(
        "avg merge factor   {:>10.2}",
        stats.merged_requests as f64 / stats.batches.max(1) as f64
    );
    println!("\n== step-level scheduler ==");
    println!("merged evals       {:>10}", stats.sched_evals);
    println!("eval occupancy     {:>10.2}", stats.eval_occupancy);
    println!("peak occupancy     {:>10}", stats.max_occupancy);
    println!("plan cache hits    {:>10}", stats.plan_cache_hits);
    println!("plan cache misses  {:>10}", stats.plan_cache_misses);

    if model.starts_with("gmm2d") {
        let eval = QualityEval::new("gmm2d", 20_000);
        let q = eval.score(&pool[..pool.len().min(8192)]);
        println!("\n== quality (pooled samples vs exact data) ==");
        println!("SWDx1000           {:>10.2}", q.swd1000);
        println!("MMDx1000           {:>10.2}", q.mmd1000);
    }
    Arc::try_unwrap(coord).ok().map(|c| c.shutdown());
    Ok(())
}
