//! Paper Figs. 3 & 4 on the exact-score oracle (pure discretization error):
//!   3a/3c  Delta_p of Euler vs EI(score) vs DDIM(eps) across N
//!   3b/3d  score-approximation error Delta_s along the true trajectory,
//!          s-parameterization vs eps-parameterization
//!   4a     relative change of eps along the trajectory
//!   4b     polynomial extrapolation error by order r at N=10
//!
//! Prints summary tables and writes CSV series under results/.
//!
//!     cargo run --release --example figures

use deis::diffusion::Sde;
use deis::exp::{print_table, run_solver, sweep_model};
use deis::gmm::Gmm;
use deis::quad::lagrange_basis;
use deis::score::{EpsModel, GmmEps};
use deis::solvers::SolverKind;
use deis::timegrid::{build, GridKind};
use deis::util::bench::CsvSink;
use deis::util::rng::Rng;

/// Ground-truth trajectory via RK4 @ ~1e-3 steps (paper App. H.1): always
/// integrates from T = 1 (where `x_t` lives) down to min(times), recording
/// the state at each requested time (times ascending).
fn ground_truth_traj(
    model: &dyn EpsModel,
    sde: &Sde,
    x_t: &[f64],
    b: usize,
    times: &[f64],
) -> Vec<Vec<f64>> {
    let d = model.dim();
    let n_fine = 1000;
    let grid = build(GridKind::Uniform, sde, times[0], 1.0, n_fine);
    let mut x = x_t.to_vec();
    let mut out: Vec<Vec<f64>> = vec![Vec::new(); times.len()];
    let deriv = |x: &[f64], t: f64, out: &mut Vec<f64>| {
        let eps = model.eval_vec(x, &vec![t; b], b);
        let f = sde.f_scalar(t);
        let w = 0.5 * sde.g2(t) / sde.sigma(t);
        out.clear();
        out.extend(x.iter().zip(&eps).map(|(xv, ev)| f * xv + w * ev));
    };
    let (mut k1, mut k2, mut k3, mut k4) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    let mut xs = vec![0.0; b * d];
    // record at T first
    for (ti, &t_req) in times.iter().enumerate() {
        if (t_req - grid[n_fine]).abs() < 1e-12 {
            out[ti] = x.clone();
        }
    }
    for i in (1..=n_fine).rev() {
        let (t, tp) = (grid[i], grid[i - 1]);
        let h = tp - t;
        deriv(&x, t, &mut k1);
        for j in 0..b * d {
            xs[j] = x[j] + 0.5 * h * k1[j];
        }
        deriv(&xs, t + 0.5 * h, &mut k2);
        for j in 0..b * d {
            xs[j] = x[j] + 0.5 * h * k2[j];
        }
        deriv(&xs, t + 0.5 * h, &mut k3);
        for j in 0..b * d {
            xs[j] = x[j] + h * k3[j];
        }
        deriv(&xs, tp, &mut k4);
        for j in 0..b * d {
            x[j] += h / 6.0 * (k1[j] + 2.0 * k2[j] + 2.0 * k3[j] + k4[j]);
        }
        for (ti, &t_req) in times.iter().enumerate() {
            if (t_req - tp).abs() < 1e-9 || (tp < t_req && t_req < t) {
                if out[ti].is_empty() {
                    out[ti] = x.clone();
                }
            }
        }
    }
    out
}

fn norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

fn main() {
    let sde = Sde::vp();
    let gmm = Gmm::ring2d(4.0, 8, 0.25);
    let model = GmmEps::new(gmm, sde);
    let b = 32;
    let x_t: Vec<f64> = Rng::new(3).normal_vec(b * 2);

    // ---- Fig 3a/3c: Delta_p vs N for Euler / EI(score) / DDIM(eps) -------
    let oracle = sweep_model("gmm2d_oracle");
    let reference =
        run_solver(&*oracle, &sde, SolverKind::Tab(0), GridKind::Uniform, 1e-3, 1000, b, 3).0;
    let mut csv = CsvSink::new("fig3_delta_p.csv", "n,euler,ei_score,ddim");
    let ns = [5usize, 10, 20, 50, 100, 200];
    let mut rows = Vec::new();
    for kind in [SolverKind::Euler, SolverKind::EiScore, SolverKind::Tab(0)] {
        let mut vals = Vec::new();
        for &n in &ns {
            let (x, _) = run_solver(&*oracle, &sde, kind, GridKind::Uniform, 1e-3, n, b, 3);
            vals.push(deis::metrics::mean_abs_diff(&x, &reference));
        }
        rows.push((kind.name(), vals));
    }
    for (i, &n) in ns.iter().enumerate() {
        csv.row(&format!("{n},{:.6},{:.6},{:.6}", rows[0].1[i], rows[1].1[i], rows[2].1[i]));
    }
    print_table(
        "Fig 3a/3c: Delta_p vs N (exact score; EI-score worse than Euler, eps-EI best)",
        &ns.iter().map(|n| format!("N={n}")).collect::<Vec<_>>(),
        &rows,
    );

    // ---- Fig 3b/3d: Delta_s along trajectory, s-param vs eps-param -------
    // The phenomenon needs manifold-like data: the score explodes as t -> 0
    // only when the data distribution is concentrated (paper Sec. 3.1 and
    // Fig. 2 use a "Gaussian concentrated with very small variance"), so
    // this figure runs on a std=0.02 ring — the image-manifold stand-in.
    let anchors = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 10);
    let sharp = GmmEps::new(Gmm::ring2d(4.0, 8, 0.02), sde);
    let sharp_xt: Vec<f64> = Rng::new(5).normal_vec(b * 2);
    let mut csv = CsvSink::new("fig3_delta_s.csv", "interval,ds_score,ds_eps");
    println!("\nFig 3b/3d: frozen-term score error per interval (concentrated data)");
    let (mut tot_score, mut tot_eps) = (0.0, 0.0);
    for i in 1..anchors.len() - 1 {
        let (t_lo, t_hi) = (anchors[i], anchors[i + 1]);
        let taus: Vec<f64> =
            (0..=8).map(|k| t_lo + (t_hi - t_lo) * k as f64 / 8.0).collect();
        let states = ground_truth_traj(&sharp, &sde, &sharp_xt, b, &taus);
        let eps_anchor = sharp.eval_vec(states.last().unwrap(), &vec![t_hi; b], b);
        let sig_a = sde.sigma(t_hi);
        let (mut m_score, mut m_eps): (f64, f64) = (0.0, 0.0);
        for (k, &tau) in taus.iter().enumerate() {
            let eps_tau = sharp.eval_vec(&states[k], &vec![tau; b], b);
            let sig_t = sde.sigma(tau);
            // Eq.(8) freezes s (and its 1/sigma) at the anchor; Eq.(11)
            // freezes eps but integrates 1/sigma(tau) exactly.
            let ds_score: f64 = norm(
                &eps_tau.iter().zip(&eps_anchor).map(|(et, ea)| et / sig_t - ea / sig_a)
                    .collect::<Vec<_>>(),
            ) / (b as f64).sqrt();
            let ds_eps: f64 = norm(
                &eps_tau.iter().zip(&eps_anchor).map(|(et, ea)| (et - ea) / sig_t)
                    .collect::<Vec<_>>(),
            ) / (b as f64).sqrt();
            m_score = m_score.max(ds_score);
            m_eps = m_eps.max(ds_eps);
        }
        csv.row(&format!("{i},{m_score:.6},{m_eps:.6}"));
        tot_score += m_score;
        tot_eps += m_eps;
    }
    println!("  mean-over-intervals max Delta_s: s-param {:.3}  eps-param {:.3}",
        tot_score / 9.0, tot_eps / 9.0);
    println!("  (paper Fig 3b vs 3d: eps-parameterization shrinks the frozen-term error)");

    // ---- Fig 4a: relative change of eps along trajectory ------------------
    let times: Vec<f64> = (0..=40).map(|i| 1e-3 + (1.0 - 1e-3) * i as f64 / 40.0).collect();
    let states = ground_truth_traj(&model, &sde, &x_t, b, &times);
    let mut csv = CsvSink::new("fig4a_eps_change.csv", "t,rel_change");
    let mut prev: Option<Vec<f64>> = None;
    println!("\nFig 4a: relative change of eps along the trajectory (CSV written)");
    for (i, &t) in times.iter().enumerate() {
        let eps = model.eval_vec(&states[i], &vec![t; b], b);
        if let Some(p) = prev {
            let diff: Vec<f64> = eps.iter().zip(&p).map(|(a, b)| a - b).collect();
            csv.row(&format!("{t:.5},{:.6}", norm(&diff) / norm(&p).max(1e-12)));
        }
        prev = Some(eps);
    }

    // ---- Fig 4b: extrapolation error by order at N=10 ---------------------
    // Averaged over every interval of the N=10 grid (the paper plots the
    // whole trajectory): anchor nodes t_{i}..t_{i+r}, probes in [t_{i-1},t_i].
    println!("\nFig 4b: eps extrapolation error by polynomial order (N=10 grid)");
    let mut csv = CsvSink::new("fig4b_extrapolation.csv", "order,mean_err");
    let anchor_states = ground_truth_traj(&model, &sde, &x_t, b, &anchors);
    let anchor_eps: Vec<Vec<f64>> = anchors
        .iter()
        .zip(&anchor_states)
        .map(|(&t, s)| model.eval_vec(s, &vec![t; b], b))
        .collect();
    for order in 0..=3usize {
        let (mut mid_total, mut mid_count) = (0.0, 0usize);
        let (mut last_total, mut last_count) = (0.0, 0usize);
        for i in 1..anchors.len() - order {
            let nds: Vec<f64> = (0..=order).map(|j| anchors[i + j]).collect();
            let probe_ts: Vec<f64> = (1..=5)
                .map(|k| anchors[i - 1] + (anchors[i] - anchors[i - 1]) * k as f64 / 6.0)
                .collect();
            let probe_states = ground_truth_traj(&model, &sde, &x_t, b, &probe_ts);
            for (pi, &tau) in probe_ts.iter().enumerate() {
                let truth = model.eval_vec(&probe_states[pi], &vec![tau; b], b);
                let mut pred = vec![0.0; b * 2];
                for j in 0..=order {
                    let w = lagrange_basis(&nds, j, tau);
                    for (pv, ev) in pred.iter_mut().zip(&anchor_eps[i + j]) {
                        *pv += w * ev;
                    }
                }
                let diff: Vec<f64> = truth.iter().zip(&pred).map(|(a, b)| a - b).collect();
                let e = norm(&diff) / (b as f64).sqrt();
                if i == 1 {
                    // Final interval [t0, t_1]: eps ~ sqrt(tau) here, so
                    // polynomial extrapolation degrades with order — the
                    // same blow-up the paper's Fig 4b curves show at t -> 0.
                    last_total += e;
                    last_count += 1;
                } else {
                    mid_total += e;
                    mid_count += 1;
                }
            }
        }
        let mid = mid_total / mid_count as f64;
        let last = last_total / last_count as f64;
        println!(
            "  order {order}: mean |eps - P_r| = {mid:.5} (t > t_1)   {last:.5} (final interval)"
        );
        csv.row(&format!("{order},{mid:.6}"));
    }
    println!("\nCSV series in results/: fig3_delta_p, fig3_delta_s, fig4a_eps_change, fig4b_extrapolation");
}
