//! Quickstart: load the AOT-compiled eps-model through PJRT, sample the
//! 8-Gaussian ring with tAB3-DEIS at 10 NFE, score it against exact data,
//! and draw an ascii density plot.
//!
//!     make artifacts && cargo run --release --example quickstart

use deis::coordinator::{Coordinator, CoordinatorConfig, SampleRequest};
use deis::exp::{default_registry, QualityEval};
use deis::solvers::SolverKind;
use deis::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let nfe = args.usize_or("nfe", 10);
    let n = args.usize_or("n", 2000);
    let solver = SolverKind::parse(&args.str_or("solver", "tab3")).expect("unknown solver");

    // The serving path end to end: PJRT-compiled trained net behind the
    // dynamic-batching coordinator.
    let reg = default_registry(&["gmm2d".to_string()])?;
    let coord = Coordinator::new(CoordinatorConfig::default(), reg);
    let mut req = SampleRequest::new("gmm2d", solver, nfe, n);
    req.seed = args.u64_or("seed", 0);

    let t = std::time::Instant::now();
    let res = coord.sample_blocking(req)?;
    let ms = t.elapsed().as_secs_f64() * 1e3;

    let eval = QualityEval::new("gmm2d", 20_000);
    let q = eval.score(&res.samples);
    println!(
        "{} samples with {} @ {} NFE in {:.1} ms  |  SWDx1000 {:.2}  MMDx1000 {:.2}  energy {:.3}",
        n, solver.name(), nfe, ms, q.swd1000, q.mmd1000, q.energy
    );

    ascii_density(&res.samples, 56, 28, 5.2);
    coord.shutdown();
    Ok(())
}

/// Terminal density plot over [-lim, lim]^2.
fn ascii_density(samples: &[f64], w: usize, h: usize, lim: f64) {
    let mut grid = vec![0usize; w * h];
    for p in samples.chunks(2) {
        let cx = ((p[0] + lim) / (2.0 * lim) * w as f64) as isize;
        let cy = ((p[1] + lim) / (2.0 * lim) * h as f64) as isize;
        if (0..w as isize).contains(&cx) && (0..h as isize).contains(&cy) {
            grid[cy as usize * w + cx as usize] += 1;
        }
    }
    let max = grid.iter().copied().max().unwrap_or(1).max(1);
    let shades = [' ', '.', ':', '+', '*', '#', '@'];
    for row in (0..h).rev() {
        let line: String = (0..w)
            .map(|c| {
                let v = grid[row * w + c];
                shades[(v * (shades.len() - 1) + max - 1) / max]
            })
            .collect();
        println!("|{line}|");
    }
}
