//! Open-loop production load generator for the DEIS serving stack
//! (EXPERIMENTS.md §Load). Speaks the real wire protocol — JSON lines and
//! `"frame":"bin"` — against either an in-process server it boots itself
//! or an external one (`--addr`), and is fully deterministic from `--seed`:
//! Poisson arrivals at `--rps`, Zipf popularity over `--models`, and a
//! mixed solver/NFE/deadline/framing profile, replayed over `--conns`
//! connections. Reports p50/p99 latency, deadline-hit rate, throughput and
//! the rejected/expired/failed split, then cross-checks every client-side
//! count against the live `{"cmd":"stats"}` wire (global + per_model) and
//! exits nonzero on any mismatch.
//!
//!     cargo run --release --example loadgen -- --rps 300 --duration-s 2
//!     cargo run --release --example loadgen -- --sched-policy edf --quick
//!
//! Flags: --seed 0 --rps 200 --duration-s 1 --conns 8
//!        --models gmm2d_oracle[,..] --zipf-s 1.1
//!        --deadline-share 0.5 --tight-ms 50 --loose-ms 2000
//!        --samples-share 0.5 --bin-share 0.5
//!        --nfes 5,10,20 --n-choices 4,16,64 --solvers tab3,ddim,tab2
//!        --workers 4 --sched-policy oldest|edf   (in-process server)
//!        --addr HOST:PORT    (target an external server instead; skips
//!                             booting one)
//!        --router N          (boot N in-process workers AND a router over
//!                             them, and drive the ROUTER — reconcile then
//!                             audits the aggregated stats fan-in)
//!        --upstream H:P,...  (boot a router over pre-started external
//!                             workers and drive it)
//!        --skip-reconcile    (for shared servers with other traffic)
//!        --quick             (caps duration at 0.25s for CI)

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use deis::coordinator::{Coordinator, CoordinatorConfig, SchedPolicy};
use deis::exp::default_registry;
use deis::router;
use deis::server;
use deis::server::loadgen::{self, LoadProfile};
use deis::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let models = args.list_or("models", "gmm2d_oracle");
    let mut duration_s = args.f64_or("duration-s", 1.0);
    if args.bool("quick") {
        duration_s = duration_s.min(0.25);
    }
    let profile = LoadProfile {
        seed: args.u64_or("seed", 0),
        rps: args.f64_or("rps", 200.0),
        duration: Duration::from_secs_f64(duration_s),
        models: models.clone(),
        zipf_s: args.f64_or("zipf-s", 1.1),
        deadline_share: args.f64_or("deadline-share", 0.5),
        tight_ms: args.u64_or("tight-ms", 50),
        loose_ms: args.u64_or("loose-ms", 2000),
        samples_share: args.f64_or("samples-share", 0.5),
        bin_share: args.f64_or("bin-share", 0.5),
        nfes: args.usize_list_or("nfes", "5,10,20"),
        n_choices: args.usize_list_or("n-choices", "4,16,64"),
        solvers: args.list_or("solvers", "tab3,ddim,tab2"),
    };
    let conns = args.usize_or("conns", 8);

    let boot_worker = |policy: SchedPolicy| -> Result<(std::net::SocketAddr, Arc<Coordinator>)> {
        let reg = default_registry(&models)?;
        let cfg = CoordinatorConfig {
            workers: args.usize_or("workers", 4),
            sched_policy: policy,
            ..Default::default()
        };
        let coord = Arc::new(Coordinator::new(cfg, reg));
        let addr = server::serve(coord.clone(), "127.0.0.1:0")?;
        Ok((addr, coord))
    };

    // Drive an external server (--addr), an external fleet behind a router
    // we boot (--upstream), an in-process sharded fleet behind a router
    // (--router N), or a single in-process server (default).
    let mut own_coords: Vec<Arc<Coordinator>> = Vec::new();
    let router_n = args.usize_or("router", 0);
    let upstreams = args.list_or("upstream", "");
    let addr = if let Some(a) = args.get("addr") {
        a.parse()?
    } else if !upstreams.is_empty() {
        let addr = router::serve(upstreams.clone(), "127.0.0.1:0")?;
        println!("loadgen: router on {addr} over {}", upstreams.join(","));
        addr
    } else if router_n > 0 {
        let policy = SchedPolicy::parse(&args.str_or("sched-policy", "oldest"))?;
        let mut workers = Vec::with_capacity(router_n);
        for _ in 0..router_n {
            let (waddr, coord) = boot_worker(policy)?;
            workers.push(waddr.to_string());
            own_coords.push(coord);
        }
        let addr = router::serve(workers.clone(), "127.0.0.1:0")?;
        println!(
            "loadgen: router on {addr} over {router_n} in-process workers \
             ({}) (policy {policy:?})",
            workers.join(",")
        );
        addr
    } else {
        let policy = SchedPolicy::parse(&args.str_or("sched-policy", "oldest"))?;
        let (addr, coord) = boot_worker(policy)?;
        own_coords.push(coord);
        println!("loadgen: in-process server on {addr} (policy {policy:?})");
        addr
    };

    println!(
        "loadgen: seed {} | {} rps for {:.2}s over {} conns | models {}",
        profile.seed,
        profile.rps,
        profile.duration.as_secs_f64(),
        conns,
        models.join(",")
    );
    let report = loadgen::run(addr, &profile, conns)?;
    print!("{}", loadgen::format_report(&report));

    if args.bool("skip-reconcile") {
        println!("stats reconciliation skipped (--skip-reconcile)");
    } else {
        let stats = loadgen::fetch_stats(addr)?;
        loadgen::reconcile(&report, &stats)?;
        if stats.opt("router").is_some() {
            println!(
                "stats reconciliation: OK (client tallies == aggregated worker wire \
                 + router balance)"
            );
        } else {
            println!("stats reconciliation: OK (client tallies == server wire)");
        }
    }
    // The in-process servers' worker/I/O threads are detached; process
    // exit reaps them (same as `deis serve`). Dropping our handles last
    // keeps the coordinators alive through the final stats call.
    drop(own_coords);
    Ok(())
}
