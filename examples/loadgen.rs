//! Open-loop production load generator for the DEIS serving stack
//! (EXPERIMENTS.md §Load). Speaks the real wire protocol — JSON lines and
//! `"frame":"bin"` — against either an in-process server it boots itself
//! or an external one (`--addr`), and is fully deterministic from `--seed`:
//! Poisson arrivals at `--rps`, Zipf popularity over `--models`, and a
//! mixed solver/NFE/deadline/framing profile, replayed over `--conns`
//! connections. Reports p50/p99 latency, deadline-hit rate, throughput and
//! the rejected/expired/failed split, then cross-checks every client-side
//! count against the live `{"cmd":"stats"}` wire (global + per_model) and
//! exits nonzero on any mismatch.
//!
//!     cargo run --release --example loadgen -- --rps 300 --duration-s 2
//!     cargo run --release --example loadgen -- --sched-policy edf --quick
//!
//! Flags: --seed 0 --rps 200 --duration-s 1 --conns 8
//!        --models gmm2d_oracle[,..] --zipf-s 1.1
//!        --deadline-share 0.5 --tight-ms 50 --loose-ms 2000
//!        --samples-share 0.5 --bin-share 0.5
//!        --nfes 5,10,20 --n-choices 4,16,64 --solvers tab3,ddim,tab2
//!        --workers 4 --sched-policy oldest|edf   (in-process server)
//!        --addr HOST:PORT    (target an external server instead; skips
//!                             booting one)
//!        --skip-reconcile    (for shared servers with other traffic)
//!        --quick             (caps duration at 0.25s for CI)

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use deis::coordinator::{Coordinator, CoordinatorConfig, SchedPolicy};
use deis::exp::default_registry;
use deis::server;
use deis::server::loadgen::{self, LoadProfile};
use deis::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let models = args.list_or("models", "gmm2d_oracle");
    let mut duration_s = args.f64_or("duration-s", 1.0);
    if args.bool("quick") {
        duration_s = duration_s.min(0.25);
    }
    let profile = LoadProfile {
        seed: args.u64_or("seed", 0),
        rps: args.f64_or("rps", 200.0),
        duration: Duration::from_secs_f64(duration_s),
        models: models.clone(),
        zipf_s: args.f64_or("zipf-s", 1.1),
        deadline_share: args.f64_or("deadline-share", 0.5),
        tight_ms: args.u64_or("tight-ms", 50),
        loose_ms: args.u64_or("loose-ms", 2000),
        samples_share: args.f64_or("samples-share", 0.5),
        bin_share: args.f64_or("bin-share", 0.5),
        nfes: args.usize_list_or("nfes", "5,10,20"),
        n_choices: args.usize_list_or("n-choices", "4,16,64"),
        solvers: args.list_or("solvers", "tab3,ddim,tab2"),
    };
    let conns = args.usize_or("conns", 8);

    // Either drive an external server or boot one in-process on port 0.
    let (addr, own_coord) = match args.get("addr") {
        Some(a) => (a.parse()?, None),
        None => {
            let policy = SchedPolicy::parse(&args.str_or("sched-policy", "oldest"))?;
            let reg = default_registry(&models)?;
            let cfg = CoordinatorConfig {
                workers: args.usize_or("workers", 4),
                sched_policy: policy,
                ..Default::default()
            };
            let coord = Arc::new(Coordinator::new(cfg, reg));
            let addr = server::serve(coord.clone(), "127.0.0.1:0")?;
            println!("loadgen: in-process server on {addr} (policy {policy:?})");
            (addr, Some(coord))
        }
    };

    println!(
        "loadgen: seed {} | {} rps for {:.2}s over {} conns | models {}",
        profile.seed,
        profile.rps,
        profile.duration.as_secs_f64(),
        conns,
        models.join(",")
    );
    let report = loadgen::run(addr, &profile, conns)?;
    print!("{}", loadgen::format_report(&report));

    if args.bool("skip-reconcile") {
        println!("stats reconciliation skipped (--skip-reconcile)");
    } else {
        let stats = loadgen::fetch_stats(addr)?;
        loadgen::reconcile(&report, &stats)?;
        println!("stats reconciliation: OK (client tallies == server wire)");
    }
    // The in-process server's worker/I/O threads are detached; process
    // exit reaps them (same as `deis serve`). Dropping our handle last
    // keeps the coordinator alive through the final stats call.
    drop(own_coord);
    Ok(())
}
