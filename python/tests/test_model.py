"""L2 correctness: eps-net, analytic GMM oracle, training loop."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import sde as sde_lib
from compile.datasets import gmm2d_spec, make_sampler, toy1d_spec
from compile.model import (
    NetConfig,
    adam_init,
    adam_update,
    apply_eps,
    gmm_eps,
    gmm_logp,
    init_params,
    train_eps_net,
)


def test_apply_shapes_and_pallas_parity():
    cfg = NetConfig(dim=2, hidden=32, embed=16, n_blocks=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (21, 2))
    t = jax.random.uniform(jax.random.PRNGKey(2), (21,))
    out_ref = apply_eps(params, x, t, cfg, use_pallas=False)
    out_pl = apply_eps(params, x, t, cfg, use_pallas=True)
    assert out_ref.shape == (21, 2)
    np.testing.assert_allclose(np.asarray(out_pl), np.asarray(out_ref), atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(t=st.floats(1e-3, 1.0), seed=st.integers(0, 10_000),
       kind=st.sampled_from(["vp", "ve"]))
def test_gmm_eps_is_neg_sigma_score(t, seed, kind):
    """eps*(x,t) must equal -sigma_t * grad log p_t(x) (autodiff cross-check)."""
    spec = gmm2d_spec()
    sde = sde_lib.VP if kind == "vp" else sde_lib.VE
    x = 4.0 * jax.random.normal(jax.random.PRNGKey(seed), (5, 2))
    tv = jnp.full((5,), t)
    grad = jax.vmap(jax.grad(lambda xx: gmm_logp(spec, sde, xx[None], t)[0]))(x)
    want = -sde.sigma(tv)[:, None] * grad
    got = gmm_eps(spec, sde, x, tv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-3)


def test_gmm_eps_at_large_t_is_whitening():
    """As t -> T (abar ~ 0) the VP marginal ~ N(0, I) so eps(x) ~ x."""
    spec = gmm2d_spec()
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 2))
    got = gmm_eps(spec, sde_lib.VP, x, jnp.ones((64,)))
    corr = jnp.sum(got * x) / jnp.sqrt(jnp.sum(got**2) * jnp.sum(x**2))
    assert float(corr) > 0.95


def test_gmm_logp_normalizes_roughly():
    """Monte-Carlo check: E_{x~p_t}[1] via importance weights ~ 1."""
    spec = toy1d_spec()
    sde = sde_lib.VP
    t = 0.5
    # p_t for toy1d is a single Gaussian: sample from it exactly.
    sq = float(sde.sqrt_abar(t))
    var = (sq * spec.std) ** 2 + float(sde.sigma(t)) ** 2
    xs = jnp.sqrt(var) * jax.random.normal(jax.random.PRNGKey(0), (4096, 1))
    lp = gmm_logp(spec, sde, xs, t)
    want = -0.5 * xs[:, 0] ** 2 / var - 0.5 * jnp.log(2 * jnp.pi * var)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(want), atol=1e-4)


def test_adam_decreases_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adam_init(params)
    f = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(300):
        g = jax.grad(f)(params)
        params, state = adam_update(params, g, state, lr=0.05)
    assert float(f(params)) < 1e-2


def test_training_smoke_loss_decreases():
    cfg = NetConfig(dim=2, hidden=32, embed=16, n_blocks=2)
    params, losses = train_eps_net(
        jax.random.PRNGKey(0), cfg, sde_lib.VP, make_sampler("gmm2d"),
        n_steps=300, batch=128, log_every=299,
    )
    first, last = losses[0][1], losses[-1][1]
    assert last < first * 0.8, (first, last)


def test_init_params_structure():
    cfg = NetConfig(dim=3, hidden=8, embed=4, n_blocks=5)
    p = init_params(jax.random.PRNGKey(0), cfg)
    assert len(p["blocks"]) == 5
    assert p["w_in"].shape == (3, 8) and p["w_out"].shape == (8, 3)
