"""Build-path tests: HLO text export and the exact-divergence artifact."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import sde as sde_lib
from compile.aot import eps_with_div, lower_eps, to_hlo_text
from compile.datasets import gmm2d_spec
from compile.model import NetConfig, apply_eps, gmm_eps, init_params


def test_to_hlo_text_smoke():
    f = lambda x, t: (x * t[:, None],)
    spec = jax.ShapeDtypeStruct((4, 2), jnp.float32)
    tspec = jax.ShapeDtypeStruct((4,), jnp.float32)
    txt = to_hlo_text(jax.jit(f).lower(spec, tspec))
    assert "HloModule" in txt and "f32[4,2]" in txt


def test_lower_eps_net_pallas_and_xla():
    cfg = NetConfig(dim=2, hidden=16, embed=8, n_blocks=1)
    params = init_params(jax.random.PRNGKey(0), cfg)
    for use_pallas in (False, True):
        fn = lambda x, t: apply_eps(params, x, t, cfg, use_pallas=use_pallas)
        txt = lower_eps(fn, 8, 2)
        assert "HloModule" in txt


def test_eps_with_div_matches_jacobian_trace():
    spec = gmm2d_spec()
    eps_fn = lambda x, t: gmm_eps(spec, sde_lib.VP, x, t)
    x = 3.0 * jax.random.normal(jax.random.PRNGKey(1), (6, 2))
    t = jnp.full((6,), 0.4)
    eps, div = eps_with_div(eps_fn, x, t)
    jac = jax.vmap(jax.jacrev(lambda xx, tt: eps_fn(xx[None], tt[None])[0]))(x, t)
    want = jnp.trace(jac, axis1=1, axis2=2)
    np.testing.assert_allclose(np.asarray(div), np.asarray(want), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(eps), np.asarray(eps_fn(x, t)), atol=1e-6)
