"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE signal).

Hypothesis sweeps shapes (batch not divisible by the block, degenerate
batch=1, wide/narrow hidden) so BlockSpec padding and index maps are
exercised, then asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    deis_combine,
    fused_block,
    ref_deis_combine,
    ref_fused_block,
    ref_time_embed,
    time_embed,
)

SETTINGS = dict(max_examples=12, deadline=None)


def rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 300),
    h=st.sampled_from([8, 16, 64, 128]),
    e=st.sampled_from([8, 32, 64]),
    block_b=st.sampled_from([1, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_block_matches_ref(b, h, e, block_b, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    hx, ex = rand(ks[0], b, h), rand(ks[1], b, e)
    w1, b1 = rand(ks[2], h, h), rand(ks[3], h)
    u = rand(ks[4], e, h)
    w2, b2 = rand(ks[5], h, h), rand(ks[6], h)
    got = fused_block(hx, ex, w1, b1, u, w2, b2, block_b=block_b)
    want = ref_fused_block(hx, ex, w1, b1, u, w2, b2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 500),
    dim=st.sampled_from([2, 16, 32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_time_embed_matches_ref(b, dim, seed):
    t = jax.random.uniform(jax.random.PRNGKey(seed), (b,), dtype=jnp.float32)
    got = time_embed(t, dim)
    want = ref_time_embed(t, dim)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-5)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 400),
    d=st.sampled_from([1, 2, 64]),
    r=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_deis_combine_matches_ref(b, d, r, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = rand(ks[0], b, d)
    eps = rand(ks[1], r, b, d)
    coef = rand(ks[2], r + 1)
    got = deis_combine(x, eps, coef)
    want = ref_deis_combine(x, eps, coef)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-5)


def test_deis_combine_zero_coef_is_zero():
    x = jnp.ones((7, 3))
    eps = jnp.ones((2, 7, 3))
    out = deis_combine(x, eps, jnp.zeros((3,)))
    assert float(jnp.abs(out).max()) == 0.0


def test_time_embed_odd_dim_rejected():
    with pytest.raises(AssertionError):
        time_embed(jnp.zeros((4,)), 7)


def test_fused_block_residual_identity():
    """Zero inner weights -> block reduces to h + b2 (residual path intact)."""
    b, h, e = 9, 16, 8
    hx = rand(jax.random.PRNGKey(0), b, h)
    ex = rand(jax.random.PRNGKey(1), b, e)
    z = jnp.zeros
    out = fused_block(hx, ex, z((h, h)), z((h,)), z((e, h)), z((h, h)), 3.0 * jnp.ones((h,)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(hx) + 3.0, atol=1e-6)
