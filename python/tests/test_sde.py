"""Schedule identities shared with the rust side (drift here == drift there)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import sde as sde_lib
from compile.fixtures import (
    quadratic_grid,
    tab_coeffs_vp,
    vp_abar,
    vp_rho,
    vp_t_of_rho,
)

ts = st.floats(1e-4, 1.0)


@settings(max_examples=30, deadline=None)
@given(t=ts)
def test_rho_identity(t):
    """rho * sqrt(abar) == sqrt(1 - abar) — the Prop 3 rescaling identity."""
    sde = sde_lib.VP
    lhs = float(sde.rho(t) * sde.sqrt_abar(t))
    rhs = float(jnp.sqrt(1.0 - sde.abar(t)))
    assert abs(lhs - rhs) < 1e-6


@settings(max_examples=30, deadline=None)
@given(t=ts)
def test_t_of_rho_roundtrip(t):
    assert abs(vp_t_of_rho(vp_rho(np.float64(t))) - t) < 1e-9


def test_abar_boundaries():
    assert float(sde_lib.VP.abar(0.0)) == 1.0
    assert float(sde_lib.VP.abar(1.0)) < 1e-4  # alpha_T ~ 0 (paper Tab 1)


@settings(max_examples=20, deadline=None)
@given(i=st.integers(1, 9))
def test_tab0_coeff_equals_ddim_closed_form(i):
    """Prop 2: the r=0 quadrature coefficient == DDIM's closed form."""
    grid = quadratic_grid(1e-3, 1.0, 10)
    t_s, t_e = grid[i], grid[i - 1]
    a_s, a_e = vp_abar(t_s), vp_abar(t_e)
    want = np.sqrt(1 - a_e) - np.sqrt(a_e / a_s) * np.sqrt(1 - a_s)
    (got,) = tab_coeffs_vp(t_e, t_s, [t_s])
    assert abs(got - want) < 1e-9


def test_ve_schedule_boundaries():
    sde = sde_lib.VE
    assert abs(float(sde.sigma(0.0)) - sde.sigma_min) < 1e-8
    assert abs(float(sde.sigma(1.0)) - sde.sigma_max) < 1e-4
