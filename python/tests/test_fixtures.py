"""Consistency of the float64 reference samplers used for rust parity."""

import numpy as np

from compile.fixtures import (
    gmm2d_means,
    gmm_eps_np,
    quadratic_grid,
    sample_ddim_ve,
    sample_rho_ab_vp,
    sample_rho_heun_vp,
    sample_tab_vp,
    vp_abar,
)

MEANS = gmm2d_means()
STD = 0.25


def eps_fn(x, t, kind="vp"):
    return gmm_eps_np(MEANS, STD, x, t, kind)


def x_init(n=6, seed=0):
    return np.random.default_rng(seed).standard_normal((n, 2))


def test_tab0_equals_rho_ab0():
    """Both r=0 variants are DDIM (Prop 2) — must agree to quadrature tol."""
    grid = quadratic_grid(1e-3, 1.0, 10)
    x = x_init()
    a = sample_tab_vp(eps_fn, x, grid, 0)
    b = sample_rho_ab_vp(eps_fn, x, grid, 0)
    np.testing.assert_allclose(a, b, atol=1e-8)


def test_solvers_converge_to_same_limit():
    """With N=160 steps every solver lands on (nearly) the same x_0."""
    x = x_init()
    grid = quadratic_grid(1e-3, 1.0, 160)
    sols = [
        sample_tab_vp(eps_fn, x, grid, 0),
        sample_tab_vp(eps_fn, x, grid, 3),
        sample_rho_ab_vp(eps_fn, x, grid, 2),
        sample_rho_heun_vp(eps_fn, x, grid),
    ]
    for s in sols[1:]:
        assert np.max(np.abs(s - sols[0])) < 2e-2


def test_high_order_beats_ddim_at_low_nfe():
    """Paper Fig 4c: r=3 closer to the fine-grid limit than r=0 at N=10."""
    x = x_init(32, seed=3)
    ref = sample_tab_vp(eps_fn, x, quadratic_grid(1e-3, 1.0, 640), 0)
    g10 = quadratic_grid(1e-3, 1.0, 10)
    e0 = np.abs(sample_tab_vp(eps_fn, x, g10, 0) - ref).mean()
    e3 = np.abs(sample_tab_vp(eps_fn, x, g10, 3) - ref).mean()
    assert e3 < e0, (e0, e3)


def test_heun_second_order_convergence():
    """Error should shrink ~4x per halving of step size (order 2 in rho)."""
    x = x_init(16, seed=5)
    ref = sample_rho_heun_vp(eps_fn, x, quadratic_grid(1e-3, 1.0, 1024))
    errs = []
    for n in (16, 32, 64):
        got = sample_rho_heun_vp(eps_fn, x, quadratic_grid(1e-3, 1.0, n))
        errs.append(np.abs(got - ref).max())
    rate = np.log2(errs[0] / errs[2]) / 2.0
    assert rate > 1.5, (errs, rate)


def test_ve_ddim_pulls_towards_data():
    """VE DDIM from sigma_max*noise should land near the GMM ring (radius 4)."""
    x = 50.0 * x_init(64, seed=9)
    out = sample_ddim_ve(eps_fn, x, quadratic_grid(1e-5, 1.0, 50))
    radii = np.linalg.norm(out, axis=1)
    assert np.median(np.abs(radii - 4.0)) < 1.0


def test_ddim_samples_near_modes():
    """VP DDIM at N=50 produces points close to one of the 8 modes."""
    rng = np.random.default_rng(11)
    x = rng.standard_normal((128, 2))
    out = sample_tab_vp(eps_fn, x, quadratic_grid(1e-3, 1.0, 50), 0)
    d = np.linalg.norm(out[:, None, :] - MEANS[None], axis=2).min(axis=1)
    assert np.median(d) < 3 * STD, np.median(d)
