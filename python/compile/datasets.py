"""Synthetic datasets standing in for the paper's image corpora.

The paper evaluates on CIFAR10 / CelebA / ImageNet / LSUN with pretrained
score nets. Offline we substitute laptop-scale distributions that keep the
phenomena DEIS exploits (multi-modality, low-dimensional manifold structure,
sharp score near t -> 0) — see DESIGN.md section 1:

  * ``gmm2d``   — ring of 8 isotropic Gaussians (the classic "8 gaussians").
                  Closed-form score under VP/VE => exact-discretization-error
                  studies (paper Figs 3/4) and exact NLL.
  * ``spiral2d``— two-arm spiral with radial noise ("CelebA" stand-in: a
                  curved 1-D manifold in 2-D, no analytic score).
  * ``img8``    — 64-dim synthetic 8x8 "images": random two-bar/gradient
                  patterns ("ImageNet64" stand-in: higher dim, structured).
  * ``toy1d``   — concentrated 1-D Gaussian used for the paper's Fig 2
                  fitting-error demonstration.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class GmmSpec:
    """Isotropic Gaussian mixture: means [M, D], shared std, uniform weights."""

    means: np.ndarray  # [M, D]
    std: float

    @property
    def dim(self) -> int:
        return self.means.shape[1]

    @property
    def n_comp(self) -> int:
        return self.means.shape[0]


def gmm2d_spec(radius: float = 4.0, n_comp: int = 8, std: float = 0.25) -> GmmSpec:
    ang = 2.0 * np.pi * np.arange(n_comp) / n_comp
    means = radius * np.stack([np.cos(ang), np.sin(ang)], axis=1)
    return GmmSpec(means=means.astype(np.float64), std=std)


def toy1d_spec(std: float = 0.05) -> GmmSpec:
    """Paper Fig 2: 1-D Gaussian concentrated with a very small variance."""
    return GmmSpec(means=np.zeros((1, 1)), std=std)


def sample_gmm(key, spec: GmmSpec, n: int) -> jnp.ndarray:
    kc, kn = jax.random.split(key)
    comp = jax.random.randint(kc, (n,), 0, spec.n_comp)
    mu = jnp.asarray(spec.means, dtype=jnp.float32)[comp]
    return mu + spec.std * jax.random.normal(kn, (n, spec.dim), dtype=jnp.float32)


def sample_spiral2d(key, n: int, noise: float = 0.15, turns: float = 2.0) -> jnp.ndarray:
    """Two-arm Archimedean spiral, radius in [0.5, 4], radial Gaussian noise."""
    ku, ka, kn = jax.random.split(key, 3)
    u = jax.random.uniform(ku, (n,))
    arm = jnp.where(jax.random.uniform(ka, (n,)) < 0.5, 0.0, jnp.pi)
    theta = turns * 2.0 * jnp.pi * jnp.sqrt(u) + arm
    r = 0.5 + 3.5 * jnp.sqrt(u)
    pts = jnp.stack([r * jnp.cos(theta), r * jnp.sin(theta)], axis=1)
    return pts + noise * jax.random.normal(kn, (n, 2), dtype=jnp.float32)


def sample_img8(key, n: int, noise: float = 0.1) -> jnp.ndarray:
    """Synthetic 8x8 images: one horizontal + one vertical bright bar on a
    linear gradient background, pixel noise on top. 64-dim, multi-modal
    (8 x 8 bar positions x gradient signs), values roughly in [-1, 1]."""
    krow, kcol, kg, kn = jax.random.split(key, 4)
    row = jax.random.randint(krow, (n,), 0, 8)
    col = jax.random.randint(kcol, (n,), 0, 8)
    gsign = jnp.sign(jax.random.uniform(kg, (n, 1, 1)) - 0.5)
    ramp = jnp.linspace(-0.5, 0.5, 8)
    bg = gsign * ramp[None, :, None] * jnp.ones((1, 1, 8))
    rows = jnp.arange(8)
    img = bg + 1.0 * (rows[None, :, None] == row[:, None, None])
    img = img + 1.0 * (rows[None, None, :] == col[:, None, None])
    img = img + noise * jax.random.normal(kn, (n, 8, 8), dtype=jnp.float32)
    return img.reshape(n, 64)


DATASETS = {
    "gmm2d": dict(dim=2, sampler="gmm", spec=gmm2d_spec()),
    "toy1d": dict(dim=1, sampler="gmm", spec=toy1d_spec()),
    "spiral2d": dict(dim=2, sampler="spiral", spec=None),
    "img8": dict(dim=64, sampler="img8", spec=None),
}


def make_sampler(name: str):
    """Return fn(key, n) -> [n, D] float32 for the named dataset."""
    info = DATASETS[name]
    if info["sampler"] == "gmm":
        spec = info["spec"]
        return lambda key, n: sample_gmm(key, spec, n)
    if info["sampler"] == "spiral":
        return lambda key, n: sample_spiral2d(key, n)
    if info["sampler"] == "img8":
        return lambda key, n: sample_img8(key, n)
    raise ValueError(name)
