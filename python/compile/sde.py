"""Scalar diffusion SDE schedules shared by training, AOT export and tests.

Mirrors rust/src/diffusion/ exactly (same constants); any change here must be
reflected there (parity fixtures in fixtures.py guard against drift).

VPSDE (Ho et al. 2020 / Song et al. 2020b, linear beta):
    beta(t)      = beta0 + t * (beta1 - beta0)
    log abar(t)  = -0.25 t^2 (beta1 - beta0) - 0.5 t beta0
    x_t | x_0 ~ N(sqrt(abar) x_0, (1 - abar) I)
    rho(t)       = sqrt((1 - abar) / abar)      (DEIS time rescaling, Prop 3)

VESDE (Song et al. 2020b, geometric sigma):
    sigma(t) = sigma_min * (sigma_max / sigma_min)^t
    x_t | x_0 ~ N(x_0, sigma(t)^2 I)            (abar == 1)
    rho(t)   = sigma(t)
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

# Default schedule constants (Song et al. 2020b).
VP_BETA0 = 0.1
VP_BETA1 = 20.0
VE_SIGMA_MIN = 0.01
VE_SIGMA_MAX = 50.0
T_MAX = 1.0


@dataclasses.dataclass(frozen=True)
class VpSde:
    """Variance-preserving SDE with linear beta schedule."""

    beta0: float = VP_BETA0
    beta1: float = VP_BETA1

    def beta(self, t):
        return self.beta0 + t * (self.beta1 - self.beta0)

    def log_abar(self, t):
        # d log abar / dt = -beta(t)  =>  log abar = -(beta0 t + t^2 (beta1-beta0)/2)
        return -0.5 * t * t * (self.beta1 - self.beta0) - t * self.beta0

    def abar(self, t):
        return jnp.exp(self.log_abar(t))

    def sqrt_abar(self, t):
        return jnp.exp(0.5 * self.log_abar(t))

    def sigma(self, t):
        """Marginal std of x_t | x_0 (the L_t of the paper, scalar case)."""
        return jnp.sqrt(jnp.maximum(1.0 - self.abar(t), 1e-20))

    def rho(self, t):
        a = self.abar(t)
        return jnp.sqrt(jnp.maximum((1.0 - a) / a, 0.0))

    def f_scalar(self, t):
        """Drift coefficient F_t (scalar; F_t = d log sqrt(abar) / dt)."""
        return -0.5 * self.beta(t)

    def g2(self, t):
        """Squared diffusion coefficient G_t^2 = beta(t)."""
        return self.beta(t)


@dataclasses.dataclass(frozen=True)
class VeSde:
    """Variance-exploding SDE with geometric sigma schedule."""

    sigma_min: float = VE_SIGMA_MIN
    sigma_max: float = VE_SIGMA_MAX

    def sigma(self, t):
        r = self.sigma_max / self.sigma_min
        return self.sigma_min * jnp.power(r, t)

    def abar(self, t):
        return jnp.ones_like(jnp.asarray(t, dtype=jnp.float32))

    def sqrt_abar(self, t):
        return jnp.ones_like(jnp.asarray(t, dtype=jnp.float32))

    def log_abar(self, t):
        return jnp.zeros_like(jnp.asarray(t, dtype=jnp.float32))

    def rho(self, t):
        return self.sigma(t)

    def f_scalar(self, t):
        return jnp.zeros_like(jnp.asarray(t, dtype=jnp.float32))

    def g2(self, t):
        """d sigma^2/dt for the geometric schedule."""
        r = jnp.log(self.sigma_max / self.sigma_min)
        return 2.0 * r * self.sigma(t) ** 2


VP = VpSde()
VE = VeSde()
