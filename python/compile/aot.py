"""AOT export: train the eps-nets and lower everything to HLO text.

This is the ONLY python entry point on the build path (`make artifacts`);
python never runs on the request path. For every (model, batch-size) we emit

    artifacts/eps_<name>_b<B>.hlo.txt        pallas-kernel lowering (L1 path)
    artifacts/eps_<name>_xla_b<B>.hlo.txt    pure-jnp oracle lowering (perf ablation)
    artifacts/epsdiv_<name>_b<B>.hlo.txt     (eps, div_x eps) for NLL (App B.1)
    artifacts/weights_<name>.json            weights for the rust-native backend
    artifacts/checks_<name>.json             (x, t) -> eps parity vectors
    artifacts/meta.json                      schedules, configs, training losses

Interchange format is HLO *text*, NOT `.serialize()`: the image's
xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction ids); the
text parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import sde as sde_lib
from .datasets import DATASETS, gmm2d_spec, make_sampler, toy1d_spec
from .model import NetConfig, apply_eps, gmm_eps, params_to_pylist, train_eps_net

T0_DEFAULT = 1e-3

# Per-model export plan: (dataset, net config, training steps, batch sizes).
MODELS = {
    "toy1d": dict(cfg=NetConfig(dim=1, hidden=64, embed=32, n_blocks=2), steps=1500,
                  batches=(16, 256)),
    "gmm2d": dict(cfg=NetConfig(dim=2, hidden=128, embed=64, n_blocks=3), steps=4000,
                  batches=(16, 64, 256, 1024)),
    "spiral2d": dict(cfg=NetConfig(dim=2, hidden=128, embed=64, n_blocks=3), steps=4000,
                     batches=(16, 256)),
    "img8": dict(cfg=NetConfig(dim=64, hidden=256, embed=64, n_blocks=4), steps=4000,
                 batches=(16, 256)),
}


def to_hlo_text(lowered) -> str:
    """jax lowering -> XLA HLO text (the gotcha-free interchange, see module doc)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default ELIDES big weight tensors as
    # `constant({...})`, which the HLO text parser silently zero-fills — the
    # compiled net then ignores its inputs. (Cost: ~10x larger artifacts.)
    text = comp.as_hlo_text(True)
    assert "{...}" not in text, "HLO printer elided constants"
    return text


def lower_eps(fn, batch: int, dim: int) -> str:
    x = jax.ShapeDtypeStruct((batch, dim), jnp.float32)
    t = jax.ShapeDtypeStruct((batch,), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(x, t))


def eps_with_div(eps_fn, x, t):
    """(eps, sum_d d eps_d / d x_d) — exact divergence via D forward-mode JVPs.

    D <= 64 here, so the exact trace is affordable; this is what the paper's
    likelihood evaluation (App B.1) needs for the augmented probability-flow
    ODE. Returns (eps [B,D], div [B]).
    """
    dim = x.shape[1]
    eps = eps_fn(x, t)

    def one_dir(d):
        v = jnp.zeros_like(x).at[:, d].set(1.0)
        _, jv = jax.jvp(lambda xx: eps_fn(xx, t), (x,), (v,))
        return jv[:, d]

    div = jnp.stack([one_dir(d) for d in range(dim)], axis=0).sum(axis=0)
    return eps, div


def export_model(out: str, name: str, params, cfg: NetConfig, batches, meta: dict):
    """Write the full artifact set for one trained eps-net."""
    written = []
    for use_pallas, tag in ((True, ""), (False, "_xla")):
        fn = lambda x, t: apply_eps(params, x, t, cfg, use_pallas=use_pallas)
        for b in batches:
            path = f"eps_{name}{tag}_b{b}.hlo.txt"
            with open(os.path.join(out, path), "w") as f:
                f.write(lower_eps(fn, b, cfg.dim))
            written.append(path)
    # Divergence artifact (NLL) — xla path only (jvp through interpret-mode
    # pallas is wasteful), smallest + default batch.
    fn_xla = lambda x, t: apply_eps(params, x, t, cfg, use_pallas=False)
    for b in (16, 256):
        path = f"epsdiv_{name}_b{b}.hlo.txt"
        with open(os.path.join(out, path), "w") as f:
            f.write(lower_eps(lambda x, t: eps_with_div(fn_xla, x, t), b, cfg.dim))
        written.append(path)

    with open(os.path.join(out, f"weights_{name}.json"), "w") as f:
        json.dump(
            {"dim": cfg.dim, "hidden": cfg.hidden, "embed": cfg.embed,
             "n_blocks": cfg.n_blocks, "params": params_to_pylist(params)},
            f,
        )

    # Parity check vectors: rust PJRT + rust-native MLP must reproduce these.
    key = jax.random.PRNGKey(1234)
    kx, kt = jax.random.split(key)
    x = 4.0 * jax.random.normal(kx, (16, cfg.dim), dtype=jnp.float32)
    t = jax.random.uniform(kt, (16,), minval=T0_DEFAULT, maxval=1.0)
    eps = fn_xla(x, t)
    eps_pallas = apply_eps(params, x, t, cfg, use_pallas=True)
    np.testing.assert_allclose(np.asarray(eps), np.asarray(eps_pallas), atol=2e-4)
    with open(os.path.join(out, f"checks_{name}.json"), "w") as f:
        json.dump(
            {"x": np.asarray(x, np.float64).tolist(),
             "t": np.asarray(t, np.float64).tolist(),
             "eps": np.asarray(eps, np.float64).tolist()},
            f,
        )
    meta["models"][name] = {
        "dim": cfg.dim, "hidden": cfg.hidden, "embed": cfg.embed,
        "n_blocks": cfg.n_blocks, "batches": list(batches), "files": written,
    }


def export_analytic(out: str, meta: dict):
    """Exact GMM eps as HLO (serving the oracle through the same PJRT path)."""
    spec = gmm2d_spec()
    for sde, tag in ((sde_lib.VP, ""), (sde_lib.VE, "_ve")):
        fn = lambda x, t: gmm_eps(spec, sde, x, t)
        for b in (16, 256, 1024):
            path = f"eps_gmm2d_exact{tag}_b{b}.hlo.txt"
            with open(os.path.join(out, path), "w") as f:
                f.write(lower_eps(fn, b, 2))
    meta["analytic"] = {
        "gmm2d": {"means": spec.means.tolist(), "std": spec.std},
        "toy1d": {"means": toy1d_spec().means.tolist(), "std": toy1d_spec().std},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="tiny training budget (CI smoke only)")
    ap.add_argument("--models", default=",".join(MODELS),
                    help="comma-separated subset of models to build")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    meta = {
        "t0_default": T0_DEFAULT,
        "t_max": sde_lib.T_MAX,
        "vp": {"beta0": sde_lib.VP_BETA0, "beta1": sde_lib.VP_BETA1},
        "ve": {"sigma_min": sde_lib.VE_SIGMA_MIN, "sigma_max": sde_lib.VE_SIGMA_MAX},
        "models": {},
        "losses": {},
    }

    for name in args.models.split(","):
        plan = MODELS[name]
        steps = 100 if args.quick else plan["steps"]
        t_start = time.time()
        key = jax.random.PRNGKey(sum(map(ord, name)))
        params, losses = train_eps_net(
            key, plan["cfg"], sde_lib.VP, make_sampler(name),
            n_steps=steps, t0=T0_DEFAULT,
        )
        print(f"[aot] trained {name}: {steps} steps in {time.time()-t_start:.1f}s, "
              f"final loss {losses[-1][1]:.4f}")
        export_model(args.out, name, params, plan["cfg"], plan["batches"], meta)
        meta["losses"][name] = losses

    export_analytic(args.out, meta)

    with open(os.path.join(args.out, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"[aot] wrote artifacts to {args.out}")


if __name__ == "__main__":
    main()
