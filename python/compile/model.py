"""L2: the eps-model (paper's score network) in JAX, calling the L1 kernels.

Architecture (time-conditioned residual MLP — the laptop-scale stand-in for
the paper's U-Nets, DESIGN.md section 1):

    e   = time_embed(t, E)                      # L1 kernel
    h   = x @ w_in + b_in
    h   = fused_block(h, e, ...)  x n_blocks    # L1 kernel
    eps = h @ w_out + b_out

Both lowering paths share one weight pytree:
  * ``use_pallas=True``  — L1 Pallas kernels (interpret=True), the faithful
    three-layer path; exported to artifacts/eps_<ds>.hlo.txt.
  * ``use_pallas=False`` — the pure-jnp oracle path (XLA fuses it); used for
    training speed and exported as eps_<ds>_xla.hlo.txt for the L1-vs-XLA
    perf ablation.

Also here: the analytic GMM eps (exact score oracle — a GMM diffused by a
scalar SDE stays a GMM), the eps-matching loss Eq.(9), and a manual Adam
(optax is not available offline).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import sde as sde_lib
from .datasets import GmmSpec
from .kernels import (
    deis_combine,
    fused_block,
    ref_deis_combine,
    ref_fused_block,
    ref_time_embed,
    time_embed,
)

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class NetConfig:
    dim: int
    hidden: int = 128
    embed: int = 64
    n_blocks: int = 3


def init_params(key, cfg: NetConfig) -> Params:
    """He-style init; final-layer weights scaled down so eps(x,T) ~ 0 at init."""
    ks = jax.random.split(key, 3 + 4 * cfg.n_blocks)
    d, h, e = cfg.dim, cfg.hidden, cfg.embed

    def dense(k, fan_in, shape, scale=1.0):
        return scale * jax.random.normal(k, shape, dtype=jnp.float32) / jnp.sqrt(fan_in)

    params: Params = {
        "w_in": dense(ks[0], d, (d, h)),
        "b_in": jnp.zeros((h,), jnp.float32),
        "w_out": dense(ks[1], h, (h, d), scale=0.1),
        "b_out": jnp.zeros((d,), jnp.float32),
        "blocks": [],
    }
    for i in range(cfg.n_blocks):
        k1, k2, k3, _ = ks[3 + 4 * i : 7 + 4 * i]
        params["blocks"].append(
            {
                "w1": dense(k1, h, (h, h)),
                "b1": jnp.zeros((h,), jnp.float32),
                "u": dense(k2, e, (e, h)),
                "w2": dense(k3, h, (h, h), scale=0.5),
                "b2": jnp.zeros((h,), jnp.float32),
            }
        )
    return params


def apply_eps(params: Params, x, t, cfg: NetConfig, *, use_pallas: bool = False):
    """Forward pass: x [B,D], t [B] -> eps [B,D]."""
    if use_pallas:
        e = time_embed(t, cfg.embed)
    else:
        e = ref_time_embed(t, cfg.embed)
    h = x @ params["w_in"] + params["b_in"]
    for blk in params["blocks"]:
        if use_pallas:
            h = fused_block(h, e, blk["w1"], blk["b1"], blk["u"], blk["w2"], blk["b2"])
        else:
            h = ref_fused_block(h, e, blk["w1"], blk["b1"], blk["u"], blk["w2"], blk["b2"])
    return h @ params["w_out"] + params["b_out"]


# ---------------------------------------------------------------------------
# Analytic GMM eps oracle (exact score; isolates discretization error).
# ---------------------------------------------------------------------------


def gmm_eps(spec: GmmSpec, sde, x, t):
    """Exact eps*(x, t) = -sigma_t * grad log p_t(x) for GMM data.

    Under a scalar SDE, p_t = sum_m w_m N(sqrt_abar*mu_m, abar*s^2 + sigma^2).
    x [B,D], t [B] (or scalar).
    """
    t = jnp.broadcast_to(jnp.asarray(t, jnp.float32), x.shape[:1])
    sq = sde.sqrt_abar(t)[:, None]  # [B,1]
    sig = sde.sigma(t)[:, None]  # marginal std, [B,1]
    var = (sq * spec.std) ** 2 + sig**2  # [B,1]
    mu = jnp.asarray(spec.means, jnp.float32)  # [M,D]
    diff = x[:, None, :] - sq[:, :, None] * mu[None, :, :]  # [B,M,D]
    logw = -0.5 * jnp.sum(diff**2, axis=-1) / var  # [B,M]
    gamma = jax.nn.softmax(logw, axis=1)  # [B,M]
    score = -jnp.einsum("bm,bmd->bd", gamma, diff) / var  # [B,D]
    return -sig * score


def gmm_logp(spec: GmmSpec, sde, x, t):
    """Exact log p_t(x) for GMM data under a scalar SDE. x [B,D], t scalar/[B]."""
    t = jnp.broadcast_to(jnp.asarray(t, jnp.float32), x.shape[:1])
    sq = sde.sqrt_abar(t)[:, None]
    sig = sde.sigma(t)[:, None]
    var = (sq * spec.std) ** 2 + sig**2
    mu = jnp.asarray(spec.means, jnp.float32)
    d = x.shape[1]
    diff = x[:, None, :] - sq[:, :, None] * mu[None, :, :]
    logn = -0.5 * jnp.sum(diff**2, axis=-1) / var - 0.5 * d * jnp.log(
        2.0 * jnp.pi * var[:, 0]
    )[:, None]
    return jax.nn.logsumexp(logn, axis=1) - jnp.log(spec.n_comp)


# ---------------------------------------------------------------------------
# Training: eps-matching loss Eq.(9) + manual Adam.
# ---------------------------------------------------------------------------


def loss_fn(params, cfg: NetConfig, sde, x0, t, noise):
    xt = sde.sqrt_abar(t)[:, None] * x0 + sde.sigma(t)[:, None] * noise
    pred = apply_eps(params, xt, t, cfg, use_pallas=False)
    return jnp.mean(jnp.sum((pred - noise) ** 2, axis=1))


def adam_init(params):
    zeros = lambda p: jax.tree_util.tree_map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    step = state["step"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    new = jax.tree_util.tree_map(
        lambda p, m, v: p - lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps), params, m, v
    )
    return new, {"m": m, "v": v, "step": step}


def train_eps_net(
    key,
    cfg: NetConfig,
    sde,
    sample_data,
    *,
    n_steps: int = 4000,
    batch: int = 512,
    lr: float = 1e-3,
    t0: float = 1e-3,
    t_max: float = sde_lib.T_MAX,
    log_every: int = 1000,
):
    """Train an eps-net with the denoising loss Eq.(9). Returns (params, losses)."""
    kinit, kloop = jax.random.split(key)
    params = init_params(kinit, cfg)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, k):
        kd, kt, kn = jax.random.split(k, 3)
        x0 = sample_data(kd, batch)
        t = jax.random.uniform(kt, (batch,), minval=t0, maxval=t_max)
        noise = jax.random.normal(kn, (batch, cfg.dim), dtype=jnp.float32)
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, sde, x0, t, noise)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    losses = []
    keys = jax.random.split(kloop, n_steps)
    for i in range(n_steps):
        params, opt, loss = step(params, opt, keys[i])
        if i % log_every == 0 or i == n_steps - 1:
            losses.append((i, float(loss)))
    return params, losses


def params_to_pylist(params: Params):
    """Weight pytree -> JSON-friendly nested structure for the rust-native backend."""
    arr = lambda a: np.asarray(a, dtype=np.float64).tolist()
    return {
        "w_in": arr(params["w_in"]),
        "b_in": arr(params["b_in"]),
        "w_out": arr(params["w_out"]),
        "b_out": arr(params["b_out"]),
        "blocks": [
            {k: arr(v) for k, v in blk.items()} for blk in params["blocks"]
        ],
    }
