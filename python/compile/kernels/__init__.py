"""Pallas kernels (L1) + pure-jnp oracles.

All kernels run under ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls; real-TPU perf is estimated from BlockSpec
footprints in DESIGN.md §Perf.
"""

from .deis_combine import deis_combine
from .fused_block import fused_block
from .ref import ref_deis_combine, ref_fused_block, ref_time_embed
from .time_embed import time_embed

__all__ = [
    "deis_combine",
    "fused_block",
    "time_embed",
    "ref_deis_combine",
    "ref_fused_block",
    "ref_time_embed",
]
