"""L1 Pallas kernel: fused tAB-DEIS update step, Eq.(14) of the paper.

    x_{i-1} = Psi(t_{i-1}, t_i) * x_i + sum_j C_ij * eps_j

One fused weighted multi-accumulate over the state and the r+1 buffered eps
evaluations — a single pass over HBM instead of r+2 scaled-add kernels.
coef[0] = Psi, coef[1..] = C_ij; the coefficients are computed once per
(sde, grid, order) by the rust coordinator (rust/src/quad) and reused across
batches, exactly as the paper notes under Eq.(15).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 256


def _kernel(x_ref, eps_ref, coef_ref, o_ref, *, r: int):
    acc = coef_ref[0] * x_ref[...]
    for j in range(r):  # r is static at trace time — fully unrolled
        acc = acc + coef_ref[1 + j] * eps_ref[j]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def deis_combine(x, eps_stack, coef, *, block_b: int = DEFAULT_BLOCK_B,
                 interpret: bool = True):
    """x [B,D], eps_stack [R,B,D], coef [R+1] -> [B,D]."""
    r, bsz, dim = eps_stack.shape
    assert x.shape == (bsz, dim) and coef.shape == (r + 1,)
    bb = min(block_b, bsz)
    return pl.pallas_call(
        functools.partial(_kernel, r=r),
        grid=(pl.cdiv(bsz, bb),),
        in_specs=[
            pl.BlockSpec((bb, dim), lambda i: (i, 0)),
            pl.BlockSpec((r, bb, dim), lambda i: (0, i, 0)),
            pl.BlockSpec((r + 1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, dim), x.dtype),
        interpret=interpret,
    )(x, eps_stack, coef)
