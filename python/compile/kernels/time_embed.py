"""L1 Pallas kernel: sinusoidal time embedding t [B] -> [B, dim].

Pure VPU elementwise work; tiled over the batch so the (block_b, dim) output
tile is produced in VMEM in one pass. Must match kernels.ref.ref_time_embed
bit-for-bit up to float32 rounding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import TIME_SCALE

DEFAULT_BLOCK_B = 256


def _kernel(t_ref, freq_ref, o_ref, *, half: int):
    t = t_ref[...]  # [bb]
    freqs = freq_ref[...]  # [half]
    ang = TIME_SCALE * t[:, None] * freqs[None, :]
    o_ref[...] = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


@functools.partial(jax.jit, static_argnames=("dim", "block_b", "interpret"))
def time_embed(t, dim: int, *, block_b: int = DEFAULT_BLOCK_B, interpret: bool = True):
    assert dim % 2 == 0, "time_embed dim must be even"
    half = dim // 2
    bsz = t.shape[0]
    bb = min(block_b, bsz)
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    return pl.pallas_call(
        functools.partial(_kernel, half=half),
        grid=(pl.cdiv(bsz, bb),),
        in_specs=[
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((half,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, dim), jnp.float32),
        interpret=interpret,
    )(t.astype(jnp.float32), freqs)
