"""Pure-jnp oracles for the Pallas kernels (the correctness reference).

Every Pallas kernel in this package must match its `ref_*` twin to float32
tolerance; pytest + hypothesis sweep shapes/dtypes (python/tests/). The
oracles are also what the training loop uses (plain XLA fusion is faster on
CPU than interpret-mode Pallas), so the trained weights are shared by both
lowering paths.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Time values live in [0, 1]; scale into the classic transformer range so the
# sinusoidal embedding has non-degenerate frequencies.
TIME_SCALE = 1000.0


def ref_time_embed(t: jnp.ndarray, dim: int) -> jnp.ndarray:
    """Sinusoidal embedding of t [B] -> [B, dim] (dim even)."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = TIME_SCALE * t[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


def ref_fused_block(h, e, w1, b1, u, w2, b2):
    """Residual MLP block with FiLM-style time conditioning.

    o = h + gelu(h @ w1 + b1 + e @ u) @ w2 + b2
    shapes: h [B,H], e [B,E], w1 [H,H], b1 [H], u [E,H], w2 [H,H], b2 [H].
    """
    z = h @ w1 + b1 + e @ u
    return h + jax.nn.gelu(z, approximate=True) @ w2 + b2


def ref_deis_combine(x, eps_stack, coef):
    """Fused DEIS-AB update Eq.(14): coef[0]*x + sum_j coef[1+j]*eps_j.

    x [B,D], eps_stack [R,B,D], coef [R+1].
    """
    out = coef[0] * x
    r = eps_stack.shape[0]
    for j in range(r):
        out = out + coef[1 + j] * eps_stack[j]
    return out
