"""L1 Pallas kernel: fused time-conditioned residual MLP block.

The per-step hot spot of DEIS sampling is the eps-net forward; its inner
loop is this block. Fusing matmul -> bias+FiLM -> GELU -> matmul -> residual
into one kernel keeps the (block_b, H) activation tile resident in VMEM for
the whole chain: one HBM round-trip per tile instead of four kernel-boundary
round-trips (the TPU re-think of the paper's GPU batching; DESIGN.md
section "Hardware adaptation").

Grid: one program per block_b rows of the batch. Weights (H*H etc.) are
broadcast to every program (index_map pins them to block (0, 0)); for the
model sizes here (H <= 256) w1+u+w2+biases fit VMEM comfortably:
  VMEM bytes ~= 4 * (2*H*H + E*H + 2*H + 2*block_b*H + block_b*E).
interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls (real-TPU perf is estimated, not measured — DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 128


def _kernel(h_ref, e_ref, w1_ref, b1_ref, u_ref, w2_ref, b2_ref, o_ref):
    h = h_ref[...]
    z = h @ w1_ref[...] + b1_ref[...] + e_ref[...] @ u_ref[...]
    o_ref[...] = h + jax.nn.gelu(z, approximate=True) @ w2_ref[...] + b2_ref[...]


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def fused_block(h, e, w1, b1, u, w2, b2, *, block_b: int = DEFAULT_BLOCK_B,
                interpret: bool = True):
    """o = h + gelu(h @ w1 + b1 + e @ u) @ w2 + b2, tiled over the batch.

    h [B,H], e [B,E]; B need not divide block_b (pallas pads the tail tile).
    """
    bsz, hdim = h.shape
    edim = e.shape[1]
    bb = min(block_b, bsz)
    grid = (pl.cdiv(bsz, bb),)
    full = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, hdim), lambda i: (i, 0)),
            pl.BlockSpec((bb, edim), lambda i: (i, 0)),
            full((hdim, hdim)),
            full((hdim,)),
            full((edim, hdim)),
            full((hdim, hdim)),
            full((hdim,)),
        ],
        out_specs=pl.BlockSpec((bb, hdim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, hdim), h.dtype),
        interpret=interpret,
    )(h, e, w1, b1, u, w2, b2)
