"""Reference DEIS implementations in float64 numpy -> parity fixtures.

An independent second implementation of the samplers (no jax, no shared
code with the rust side) run on the *analytic* GMM eps oracle. The rust
integration tests (rust/tests/parity.rs) replay the same grids from the same
x_T draws and must match to ~1e-6 — this pins down every coefficient
formula (Psi, C_ij, rho maps) across languages.

Solvers fixtured: DDIM (== tAB0 == rhoAB0, Prop 2), tAB2, rhoAB2, rho-Heun
(VP) and DDIM under VESDE.
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

# ---------------------------------------------------------------------------
# float64 schedule mirror (keep in sync with sde.py and rust/src/diffusion).
# ---------------------------------------------------------------------------

BETA0, BETA1 = 0.1, 20.0
SIG_MIN, SIG_MAX = 0.01, 50.0


def vp_log_abar(t):
    return -0.5 * t * t * (BETA1 - BETA0) - t * BETA0


def vp_abar(t):
    return np.exp(vp_log_abar(t))


def vp_beta(t):
    return BETA0 + t * (BETA1 - BETA0)


def vp_sigma(t):
    return np.sqrt(1.0 - vp_abar(t))


def vp_rho(t):
    a = vp_abar(t)
    return np.sqrt((1.0 - a) / a)


def vp_t_of_rho(rho):
    """Invert rho(t) in closed form (quadratic in t)."""
    log_abar = -np.log1p(rho * rho)
    a = 0.5 * (BETA1 - BETA0)
    b = BETA0
    return (-b + np.sqrt(b * b - 4.0 * a * log_abar)) / (2.0 * a)


def ve_sigma(t):
    return SIG_MIN * (SIG_MAX / SIG_MIN) ** t


# ---------------------------------------------------------------------------
# Analytic GMM eps (float64 mirror of model.gmm_eps).
# ---------------------------------------------------------------------------


def gmm2d_means(radius=4.0, n=8):
    ang = 2.0 * np.pi * np.arange(n) / n
    return radius * np.stack([np.cos(ang), np.sin(ang)], axis=1)


def gmm_eps_np(means, std, x, t, kind="vp"):
    if kind == "vp":
        sq = np.sqrt(vp_abar(t))
        sig = vp_sigma(t)
    else:
        sq = 1.0
        sig = ve_sigma(t)
    var = (sq * std) ** 2 + sig**2
    diff = x[:, None, :] - sq * means[None, :, :]  # [B,M,D]
    logw = -0.5 * np.sum(diff**2, axis=-1) / var
    logw -= logw.max(axis=1, keepdims=True)
    gamma = np.exp(logw)
    gamma /= gamma.sum(axis=1, keepdims=True)
    score = -np.einsum("bm,bmd->bd", gamma, diff) / var
    return -sig * score


# ---------------------------------------------------------------------------
# Grids and quadrature.
# ---------------------------------------------------------------------------


def quadratic_grid(t0, t_max, n):
    """t_i = (sqrt(t0) + i/N (sqrt(T)-sqrt(t0)))^2, i=0..N (Eq. 42, kappa=2)."""
    s = np.sqrt(t0) + (np.arange(n + 1) / n) * (np.sqrt(t_max) - np.sqrt(t0))
    return s**2


_GL_X, _GL_W = np.polynomial.legendre.leggauss(32)


def integrate(f, lo, hi):
    mid, half = 0.5 * (lo + hi), 0.5 * (hi - lo)
    return half * np.sum(_GL_W * f(mid + half * _GL_X))


def lagrange_basis(nodes, j, tau):
    out = np.ones_like(tau)
    for k in range(len(nodes)):
        if k != j:
            out = out * (tau - nodes[k]) / (nodes[j] - nodes[k])
    return out


def tab_coeffs_vp(t_target, t_cur, nodes):
    """C_ij Eq.(15) for VPSDE: signed integral from t_cur down to t_target."""
    sq_t = np.sqrt(vp_abar(t_target))

    def w(tau):
        return 0.5 * sq_t / np.sqrt(vp_abar(tau)) * vp_beta(tau) / vp_sigma(tau)

    return [integrate(lambda tau: w(tau) * lagrange_basis(nodes, j, tau), t_cur, t_target)
            for j in range(len(nodes))]


def rho_ab_coeffs(rho_target, rho_cur, rho_nodes):
    """Exact Lagrange-basis integrals in rho-space (polynomial, 64 GL pts exact)."""
    return [integrate(lambda r: lagrange_basis(rho_nodes, j, r), rho_cur, rho_target)
            for j in range(len(rho_nodes))]


# ---------------------------------------------------------------------------
# Samplers (all take eps(x, t_scalar) -> [B,D]).
# ---------------------------------------------------------------------------


def sample_tab_vp(eps_fn, x_T, grid, order):
    """tAB-DEIS of given order (0 == DDIM by Prop 2). grid[0]=t0, grid[-1]=T."""
    n = len(grid) - 1
    x = x_T.copy()
    buf = []  # [(t_node, eps)] newest first
    for i in range(n, 0, -1):
        t_i, t_prev = grid[i], grid[i - 1]
        buf.insert(0, (t_i, eps_fn(x, t_i)))
        r_eff = min(order, len(buf) - 1)
        nodes = [buf[j][0] for j in range(r_eff + 1)]
        coefs = tab_coeffs_vp(t_prev, t_i, nodes)
        psi = np.sqrt(vp_abar(t_prev) / vp_abar(t_i))
        x = psi * x + sum(c * buf[j][1] for j, c in enumerate(coefs))
        buf = buf[: order + 1]
    return x


def sample_rho_ab_vp(eps_fn, x_T, grid, order):
    """rhoAB-DEIS: AB in the rescaled ODE dy/drho = eps(sqrt(abar) y, t(rho))."""
    n = len(grid) - 1
    rho = vp_rho(grid)
    y = x_T / np.sqrt(vp_abar(grid[n]))
    buf = []
    for i in range(n, 0, -1):
        x_cur = np.sqrt(vp_abar(grid[i])) * y
        buf.insert(0, (rho[i], eps_fn(x_cur, grid[i])))
        r_eff = min(order, len(buf) - 1)
        nodes = [buf[j][0] for j in range(r_eff + 1)]
        coefs = rho_ab_coeffs(rho[i - 1], rho[i], nodes)
        y = y + sum(c * buf[j][1] for j, c in enumerate(coefs))
        buf = buf[: order + 1]
    return np.sqrt(vp_abar(grid[0])) * y


def sample_rho_heun_vp(eps_fn, x_T, grid):
    """rho2Heun: explicit trapezoidal rule in rho-space (Karras et al. special case)."""
    n = len(grid) - 1
    rho = vp_rho(grid)
    y = x_T / np.sqrt(vp_abar(grid[n]))
    for i in range(n, 0, -1):
        h = rho[i - 1] - rho[i]
        k1 = eps_fn(np.sqrt(vp_abar(grid[i])) * y, grid[i])
        y_euler = y + h * k1
        k2 = eps_fn(np.sqrt(vp_abar(grid[i - 1])) * y_euler, grid[i - 1])
        y = y + 0.5 * h * (k1 + k2)
    return np.sqrt(vp_abar(grid[0])) * y


def sample_ddim_ve(eps_fn, x_T, grid):
    """VE DDIM: x_{i-1} = x_i + (sigma_{i-1} - sigma_i) eps."""
    n = len(grid) - 1
    x = x_T.copy()
    for i in range(n, 0, -1):
        x = x + (ve_sigma(grid[i - 1]) - ve_sigma(grid[i])) * eps_fn(x, grid[i], "ve")
    return x


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/fixtures")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    means = gmm2d_means()
    std = 0.25
    eps_vp = lambda x, t, kind="vp": gmm_eps_np(means, std, x, t, kind)

    rng = np.random.default_rng(7)
    x_T = rng.standard_normal((8, 2))
    n, t0, t_max = 10, 1e-3, 1.0
    grid = quadratic_grid(t0, t_max, n)

    fx = {
        "grid": grid.tolist(),
        "x_T": x_T.tolist(),
        "gmm": {"means": means.tolist(), "std": std},
        "solvers": {
            "vp_ddim": sample_tab_vp(eps_vp, x_T, grid, 0).tolist(),
            "vp_tab2": sample_tab_vp(eps_vp, x_T, grid, 2).tolist(),
            "vp_rho_ab2": sample_rho_ab_vp(eps_vp, x_T, grid, 2).tolist(),
            "vp_rho_heun": sample_rho_heun_vp(eps_vp, x_T, grid).tolist(),
            "ve_ddim": sample_ddim_ve(eps_vp, 50.0 * x_T, grid).tolist(),
        },
    }
    # Sanity: Prop 2 closed form == quadrature C_i0 at a random step.
    a_s, a_e = vp_abar(grid[5]), vp_abar(grid[4])
    ddim_c = np.sqrt(1 - a_e) - np.sqrt(a_e / a_s) * np.sqrt(1 - a_s)
    (quad_c,) = tab_coeffs_vp(grid[4], grid[5], [grid[5]])
    assert abs(ddim_c - quad_c) < 1e-9, (ddim_c, quad_c)

    with open(os.path.join(args.out, "solver_parity.json"), "w") as f:
        json.dump(fx, f)
    print(f"[fixtures] wrote {args.out}/solver_parity.json")


if __name__ == "__main__":
    main()
