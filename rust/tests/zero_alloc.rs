//! Zero-allocation discipline of the native inference engine
//! (EXPERIMENTS.md §Perf iteration 3), pinned with a counting global
//! allocator:
//!
//!   1. After warmup, `NativeMlp::eval` performs ZERO heap allocations —
//!      uniform-t fast path and generic path, pooled and single-threaded.
//!   2. A solver trajectory's allocation count is independent of the number
//!      of steps: every per-step buffer (eps history, stage states,
//!      broadcast t) is recycled, so 30 steps allocate exactly as much as
//!      6 (the per-call constant: first-touch buffer sizing).
//!
//! Everything lives in ONE #[test] so no concurrent test pollutes the
//! counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use deis::diffusion::Sde;
use deis::score::{EpsModel, NativeMlp, Precision};
use deis::solvers::{self, SolverKind};
use deis::timegrid::{build, GridKind};
use deis::util::json::Json;
use deis::util::rng::Rng;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Tiny deterministic value stream for synthetic weights ([-0.3, 0.3],
/// small enough that a 30-step solver trajectory through the net cannot
/// overflow to inf).
fn lcg_next(state: &mut u64) -> f64 {
    *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    ((*state >> 33) % 13) as f64 / 20.0 - 0.3
}

fn json_matrix(state: &mut u64, r: usize, c: usize) -> String {
    let rows: Vec<String> = (0..r)
        .map(|_| {
            let vals: Vec<String> = (0..c).map(|_| format!("{:.2}", lcg_next(state))).collect();
            format!("[{}]", vals.join(","))
        })
        .collect();
    format!("[{}]", rows.join(","))
}

fn json_vector(state: &mut u64, n: usize) -> String {
    let vals: Vec<String> = (0..n).map(|_| format!("{:.2}", lcg_next(state))).collect();
    format!("[{}]", vals.join(","))
}

/// Deterministic synthetic weights JSON (values small enough that stacked
/// blocks stay finite).
fn weights_json(dim: usize, hidden: usize, embed: usize, n_blocks: usize) -> String {
    let mut st = 0x9E3779B97F4A7C15u64;
    let blocks: Vec<String> = (0..n_blocks)
        .map(|_| {
            format!(
                r#"{{"w1": {}, "b1": {}, "u": {}, "w2": {}, "b2": {}}}"#,
                json_matrix(&mut st, hidden, hidden),
                json_vector(&mut st, hidden),
                json_matrix(&mut st, embed, hidden),
                json_matrix(&mut st, hidden, hidden),
                json_vector(&mut st, hidden)
            )
        })
        .collect();
    format!(
        r#"{{"dim": {dim}, "hidden": {hidden}, "embed": {embed}, "n_blocks": {n_blocks},
            "params": {{"w_in": {}, "b_in": {}, "w_out": {}, "b_out": {},
                        "blocks": [{}]}}}}"#,
        json_matrix(&mut st, dim, hidden),
        json_vector(&mut st, hidden),
        json_matrix(&mut st, hidden, dim),
        json_vector(&mut st, dim),
        blocks.join(",")
    )
}

#[test]
fn native_engine_is_allocation_free_in_steady_state() {
    // hidden=32, blocks=2 => 2*b*32*32*5 flops: b=512 crosses the pool
    // threshold (2^22), so the pooled path is exercised too.
    let net = NativeMlp::from_json(&Json::parse(&weights_json(4, 32, 8, 2)).unwrap()).unwrap();
    let mut rng = Rng::new(7);

    // ---- 1. eval steady state: zero allocations --------------------------
    let b = 512;
    let x = rng.normal_vec(b * 4);
    let t_uniform = vec![0.5; b];
    let t_generic: Vec<f64> = (0..b).map(|_| rng.uniform_in(0.01, 1.0)).collect();
    let mut out = vec![0.0; b * 4];
    // Warmup. Which pool participant claims which chunk is racy, so warm
    // every participant's thread-local workspace explicitly: fan out more
    // sleep-padded tasks than threads, each running a chunk-sized forward
    // inline (b=256 is below the pool threshold, so no nested fan-out).
    let pool = deis::score::pool::WorkerPool::global();
    {
        let xw = &x[..256 * 4];
        let tw_u = &t_uniform[..256];
        let tw_g = &t_generic[..256];
        pool.run(pool.threads() * 4, &|_| {
            let mut o = vec![0.0; 256 * 4];
            net.eval(xw, tw_u, 256, &mut o);
            net.eval(xw, tw_g, 256, &mut o);
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
    }
    // Belt and braces: repeat full pooled evals until a round is clean.
    let mut warm_rounds = 0;
    loop {
        let before = allocs();
        net.eval(&x, &t_uniform, b, &mut out);
        net.eval(&x, &t_generic, b, &mut out);
        if allocs() == before {
            break;
        }
        warm_rounds += 1;
        assert!(warm_rounds < 50, "eval still allocating after 50 warmup rounds");
    }
    for (label, t) in [("uniform-t", &t_uniform), ("generic-t", &t_generic)] {
        let before = allocs();
        for _ in 0..5 {
            net.eval(&x, t, b, &mut out);
        }
        let n = allocs() - before;
        assert_eq!(n, 0, "{label} eval allocated {n} times in steady state");
    }
    assert!(out.iter().all(|v| v.is_finite()));

    // Small batch (single-threaded path), different shape than the pooled
    // runs — workspaces resize within capacity, still zero allocations.
    let bs = 16;
    let xs = rng.normal_vec(bs * 4);
    let ts = vec![0.25; bs];
    let mut outs = vec![0.0; bs * 4];
    net.eval(&xs, &ts, bs, &mut outs);
    let before = allocs();
    net.eval(&xs, &ts, bs, &mut outs);
    assert_eq!(allocs() - before, 0, "small-batch eval allocated in steady state");

    // ---- 1b. f32 engine: same discipline through the dtype boundary ------
    // The f32 engine adds thread-local narrow/widen buffers (Conv) and its
    // own per-precision scratch; all must reach a zero-allocation steady
    // state exactly like the f64 path.
    let net32 = NativeMlp::from_json_with(
        &Json::parse(&weights_json(4, 32, 8, 2)).unwrap(),
        Precision::F32,
    )
    .unwrap();
    {
        let xw = &x[..256 * 4];
        let tw_u = &t_uniform[..256];
        let tw_g = &t_generic[..256];
        pool.run(pool.threads() * 4, &|_| {
            let mut o = vec![0.0; 256 * 4];
            net32.eval(xw, tw_u, 256, &mut o);
            net32.eval(xw, tw_g, 256, &mut o);
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
    }
    let mut warm_rounds = 0;
    loop {
        let before = allocs();
        net32.eval(&x, &t_uniform, b, &mut out);
        net32.eval(&x, &t_generic, b, &mut out);
        if allocs() == before {
            break;
        }
        warm_rounds += 1;
        assert!(warm_rounds < 50, "f32 eval still allocating after 50 warmup rounds");
    }
    for (label, t) in [("uniform-t", &t_uniform), ("generic-t", &t_generic)] {
        let before = allocs();
        for _ in 0..5 {
            net32.eval(&x, t, b, &mut out);
        }
        let n = allocs() - before;
        assert_eq!(n, 0, "f32 {label} eval allocated {n} times in steady state");
    }
    assert!(out.iter().all(|v| v.is_finite()));

    // ---- 2. solver trajectories: allocations independent of step count ---
    let sde = Sde::vp();
    let b = 8;
    let d = 4;
    let x0 = rng.normal_vec(b * d);
    for kind in [
        SolverKind::Tab(3),
        SolverKind::RhoAb(2),
        SolverKind::Ipndm(3),
        SolverKind::Dpm(3),
        SolverKind::Pndm,
    ] {
        let steps_short = 8;
        let steps_long = 30;
        let short = solvers::build(kind, &sde, &build(GridKind::Quadratic, &sde, 1e-3, 1.0, steps_short));
        let long = solvers::build(kind, &sde, &build(GridKind::Quadratic, &sde, 1e-3, 1.0, steps_long));
        let run = |solver: &dyn solvers::Solver| {
            let mut x = x0.clone();
            let mut srng = Rng::new(3);
            let before = allocs();
            solver.sample(&net, &mut x, b, &mut srng);
            let spent = allocs() - before;
            assert!(x.iter().all(|v| v.is_finite()), "{} diverged", solver.name());
            spent
        };
        // Warm both (sizes the per-shape workspaces for this b*d).
        run(short.as_ref());
        run(long.as_ref());
        let a_short = run(short.as_ref());
        let a_long = run(long.as_ref());
        assert_eq!(
            a_long, a_short,
            "{}: {steps_long}-step trajectory allocated {a_long} vs {a_short} for \
             {steps_short} steps — a per-step allocation survives",
            short.name()
        );
    }
}
