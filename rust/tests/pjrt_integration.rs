//! End-to-end over the real PJRT runtime + AOT artifacts:
//!   jax (training) -> HLO text -> rust PJRT execution == jax numerics,
//!   and the full coordinator serving path on the compiled model.
//!
//! Requires `make artifacts`.

use std::sync::Arc;

use deis::coordinator::{Coordinator, CoordinatorConfig, ModelRegistry, SampleRequest};
use deis::diffusion::Sde;
use deis::gmm::Gmm;
use deis::metrics;
use deis::runtime::Runtime;
use deis::score::{pjrt::PjrtEps, EpsModel, GmmEps, NativeMlp};
use deis::solvers::SolverKind;
use deis::util::json::Json;
use deis::util::rng::Rng;

fn runtime() -> &'static Runtime {
    Runtime::global()
}

fn load_checks(name: &str) -> (Vec<f64>, Vec<f64>, Vec<f64>, usize, usize) {
    let path = format!("artifacts/checks_{name}.json");
    let v = Json::from_file(&path)
        .unwrap_or_else(|e| panic!("{path} missing — run `make artifacts` ({e:#})"));
    let (b, d, x) = v.get("x").unwrap().as_matrix().unwrap();
    let t = v.get("t").unwrap().as_f64_vec().unwrap();
    let (_, _, eps) = v.get("eps").unwrap().as_matrix().unwrap();
    (x, t, eps, b, d)
}

#[test]
#[ignore = "needs the real PJRT backend (cargo feature `pjrt` + vendored xla crate) and artifacts/ from `make artifacts` — run locally with both available"]
fn pjrt_pallas_artifact_matches_jax() {
    // The pallas-kernel lowering executed via rust PJRT == jax's own output.
    let (x, t, want, b, d) = load_checks("gmm2d");
    let model = PjrtEps::load(runtime(), "gmm2d", &[16]).unwrap();
    assert_eq!(model.dim(), d);
    let got = model.eval_vec(&x, &t, b);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 2e-4, "element {i}: pjrt {g} vs jax {w}");
    }
}

#[test]
#[ignore = "needs the real PJRT backend (cargo feature `pjrt` + vendored xla crate) and artifacts/ from `make artifacts` — run locally with both available"]
fn pjrt_xla_variant_matches_jax() {
    let (x, t, want, b, _d) = load_checks("gmm2d");
    let model = PjrtEps::load(runtime(), "gmm2d_xla", &[16]).unwrap();
    let got = model.eval_vec(&x, &t, b);
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() < 2e-4, "pjrt-xla {g} vs jax {w}");
    }
}

#[test]
#[ignore = "needs the real PJRT backend (cargo feature `pjrt` + vendored xla crate) and artifacts/ from `make artifacts` — run locally with both available"]
fn native_mlp_matches_jax() {
    // Independent rust reimplementation of the forward pass == jax.
    for name in ["gmm2d", "toy1d", "spiral2d", "img8"] {
        let (x, t, want, b, d) = load_checks(name);
        let model = NativeMlp::load(&format!("artifacts/weights_{name}.json")).unwrap();
        assert_eq!(model.dim(), d, "{name}");
        let got = model.eval_vec(&x, &t, b);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 5e-4, "{name} element {i}: native {g} vs jax {w}");
        }
    }
}

#[test]
#[ignore = "needs the real PJRT backend (cargo feature `pjrt` + vendored xla crate) and artifacts/ from `make artifacts` — run locally with both available"]
fn pjrt_exact_gmm_artifact_matches_rust_math() {
    // The analytic GMM exported through jax->HLO->PJRT == the rust closed form.
    let model = PjrtEps::load(runtime(), "gmm2d_exact", &[16]).unwrap();
    let oracle = GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp());
    let mut rng = Rng::new(77);
    let x: Vec<f64> = (0..32).map(|_| 4.0 * rng.normal()).collect();
    let t: Vec<f64> = (0..16).map(|_| rng.uniform_in(1e-3, 1.0)).collect();
    let got = model.eval_vec(&x, &t, 16);
    let want = oracle.eval_vec(&x, &t, 16);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 1e-3, "element {i}: pjrt {g} vs rust {w}");
    }
}

#[test]
#[ignore = "needs the real PJRT backend (cargo feature `pjrt` + vendored xla crate) and artifacts/ from `make artifacts` — run locally with both available"]
fn pjrt_batch_padding_and_chunking() {
    // Odd logical batch sizes route through padding; huge ones chunk.
    let model = PjrtEps::load(runtime(), "gmm2d_exact", &[16, 256]).unwrap();
    let oracle = GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp());
    for b in [1, 3, 16, 17, 300] {
        let mut rng = Rng::new(b as u64);
        let x: Vec<f64> = (0..2 * b).map(|_| 3.0 * rng.normal()).collect();
        let t: Vec<f64> = (0..b).map(|_| rng.uniform_in(0.01, 1.0)).collect();
        let got = model.eval_vec(&x, &t, b);
        let want = oracle.eval_vec(&x, &t, b);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3, "b={b}: {g} vs {w}");
        }
    }
}

#[test]
#[ignore = "needs the real PJRT backend (cargo feature `pjrt` + vendored xla crate) and artifacts/ from `make artifacts` — run locally with both available"]
fn coordinator_serves_pjrt_model_end_to_end() {
    let mut reg = ModelRegistry::new();
    reg.insert(
        "gmm2d",
        Arc::new(PjrtEps::load(runtime(), "gmm2d", &[16, 64, 256]).unwrap()),
    );
    let coord = Coordinator::new(CoordinatorConfig::default(), reg);
    let mut req = SampleRequest::new("gmm2d", SolverKind::Tab(3), 10, 512);
    req.seed = 4;
    let res = coord.sample_blocking(req).unwrap();
    assert_eq!(res.samples.len(), 1024);

    // Quality gate: the trained net at NFE=10 should produce samples whose
    // SWD to exact data is far below that of the prior.
    let gmm = Gmm::ring2d(4.0, 8, 0.25);
    let mut rng = Rng::new(123);
    let truth = gmm.sample(&mut rng, 8192);
    let swd = metrics::sliced_wasserstein(&res.samples, &truth, 2, 64, &mut rng);
    let prior: Vec<f64> = Rng::new(5).normal_vec(1024);
    let swd_prior = metrics::sliced_wasserstein(&prior, &truth, 2, 64, &mut rng);
    assert!(
        swd < 0.5 * swd_prior,
        "sampled swd {swd} should beat prior swd {swd_prior}"
    );
    coord.shutdown();
}

#[test]
#[ignore = "needs the real PJRT backend (cargo feature `pjrt` + vendored xla crate) and artifacts/ from `make artifacts` — run locally with both available"]
fn multithreaded_pjrt_access_is_safe() {
    // Hammer the single executor thread from many workers.
    let model = Arc::new(PjrtEps::load(runtime(), "gmm2d_exact", &[16]).unwrap());
    let mut handles = Vec::new();
    for k in 0..8 {
        let m = model.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(k);
            for _ in 0..5 {
                let x: Vec<f64> = (0..32).map(|_| rng.normal()).collect();
                let t: Vec<f64> = (0..16).map(|_| rng.uniform_in(0.1, 1.0)).collect();
                let out = m.eval_vec(&x, &t, 16);
                assert!(out.iter().all(|v| v.is_finite()));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}
