//! f32 inference-mode parity, unit → trajectory → wire (ISSUE 7):
//!
//!   1. The f32 engine tracks the f64 engine on random MLP weights through
//!      the public `EpsModel` boundary (narrow → f32 kernels → widen).
//!   2. End to end through EVERY solver kind: trajectories driven by the
//!      f32 engine land within a documented tolerance of the f64 ones.
//!   3. The dtype wire contract: `"dtype":"f32"` is served and echoed,
//!      unknown dtypes are rejected with a clear error, f32 requests
//!      against a model without an f32 engine are refused, and f32 traffic
//!      shows up under the "<model>@f32" per-model stats key.
//!
//! Tolerance rationale (EXPERIMENTS.md §Kernels): a single f32 op carries
//! ~1.2e-7 relative error; one forward through hidden-width-H matmuls and a
//! handful of layers stays under ~1e-4 relative for O(1)-scale nets. A
//! solver trajectory then feeds eps errors back through 10–20 steps, which
//! amplifies them by roughly the trajectory's Lipschitz factor — for the
//! small-weight synthetic net used here that stays within ~1e-2 absolute.
//! We assert 0.05*(1+|x|) per sample: an order of magnitude of slack, while
//! still far below the inter-sample distances that would indicate a routing
//! or kernel bug. The adaptive-step rk45 solver is the one exception —
//! its accept/reject decisions can flip under an eps perturbation, so its
//! two runs may take DIFFERENT step sequences; it is compared in
//! distribution (per-dimension mean/std) instead of per sample.

mod common;

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use deis::coordinator::{Coordinator, CoordinatorConfig, ModelRegistry};
use deis::diffusion::Sde;
use deis::exp::run_solver;
use deis::score::{EpsModel, NativeMlp, Precision};
use deis::server;
use deis::solvers::SolverKind;
use deis::timegrid::GridKind;
use deis::util::json::Json;
use deis::util::rng::Rng;

/// Every solver kind (mirrors solvers::plan's test list — deterministic
/// and stochastic alike; the stochastic samplers share their seeded noise
/// stream across the two runs, so they compare per sample too).
fn all_kinds() -> Vec<SolverKind> {
    use SolverKind::*;
    vec![
        Euler, EulerScore, EiScore, Tab(0), Tab(3), RhoAb(2), RhoMidpoint, RhoHeun,
        RhoKutta3, RhoRk4, Rk45, Pndm, Ipndm(3), Dpm(1), Dpm(2), Dpm(3), EulerMaruyama,
        StochDdim, ADdim,
    ]
}

fn nets(dim: usize, hidden: usize, embed: usize, n_blocks: usize) -> (NativeMlp, NativeMlp) {
    let root = Json::parse(&common::weights_json(dim, hidden, embed, n_blocks)).unwrap();
    (
        NativeMlp::from_json_with(&root, Precision::F64).unwrap(),
        NativeMlp::from_json_with(&root, Precision::F32).unwrap(),
    )
}

#[test]
fn f32_eval_tracks_f64_on_random_weights() {
    let mut rng = Rng::new(2024);
    for (dim, hidden, embed, n_blocks) in [(2, 16, 8, 2), (3, 24, 6, 1), (1, 5, 3, 3)] {
        let (net64, net32) = nets(dim, hidden, embed, n_blocks);
        assert_eq!(net64.precision(), Precision::F64);
        assert_eq!(net32.precision(), Precision::F32);
        for b in [1, 7, 32] {
            let x = rng.normal_vec(b * dim);
            // Uniform and per-row t exercise both forward paths.
            for uniform in [true, false] {
                let t: Vec<f64> = if uniform {
                    vec![rng.uniform_in(0.01, 1.0); b]
                } else {
                    (0..b).map(|_| rng.uniform_in(0.01, 1.0)).collect()
                };
                let o64 = net64.eval_vec(&x, &t, b);
                let o32 = net32.eval_vec(&x, &t, b);
                for (a, f) in o64.iter().zip(&o32) {
                    let tol = 1e-3 * (1.0 + a.abs());
                    assert!(
                        (a - f).abs() < tol,
                        "eval parity ({dim},{hidden},{embed},{n_blocks}) b={b}: {a} vs {f}"
                    );
                }
            }
        }
    }
}

fn mean_std_per_dim(x: &[f64], d: usize) -> Vec<(f64, f64)> {
    let n = x.len() / d;
    (0..d)
        .map(|j| {
            let col: Vec<f64> = (0..n).map(|i| x[i * d + j]).collect();
            let mean = col.iter().sum::<f64>() / n as f64;
            let var = col.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
            (mean, var.sqrt())
        })
        .collect()
}

#[test]
fn every_solver_kind_agrees_across_precision_end_to_end() {
    let (net64, net32) = nets(2, 16, 8, 2);
    let sde = Sde::vp();
    for kind in all_kinds() {
        let (x64, nfe64) =
            run_solver(&net64, &sde, kind, GridKind::Quadratic, 1e-3, 12, 48, 5);
        let (x32, nfe32) =
            run_solver(&net32, &sde, kind, GridKind::Quadratic, 1e-3, 12, 48, 5);
        assert!(x64.iter().all(|v| v.is_finite()), "{kind:?} f64 diverged");
        assert!(x32.iter().all(|v| v.is_finite()), "{kind:?} f32 diverged");
        if kind == SolverKind::Rk45 {
            // Adaptive stepping: accept/reject flips under eps perturbation
            // ⇒ compare in distribution, not per sample.
            for ((m64, s64), (m32, s32)) in
                mean_std_per_dim(&x64, 2).iter().zip(mean_std_per_dim(&x32, 2))
            {
                assert!((m64 - m32).abs() < 0.05, "rk45 mean drift: {m64} vs {m32}");
                assert!((s64 - s32).abs() < 0.05, "rk45 std drift: {s64} vs {s32}");
            }
        } else {
            assert_eq!(nfe64, nfe32, "{kind:?}: fixed-grid NFE must not depend on dtype");
            for (a, f) in x64.iter().zip(&x32) {
                let tol = 0.05 * (1.0 + a.abs());
                assert!((a - f).abs() < tol, "{kind:?} trajectory parity: {a} vs {f}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Wire contract
// ---------------------------------------------------------------------------

/// Minimal line-protocol client (the in-crate test client is private).
struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: &std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        Client { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn call(&mut self, line: &str) -> Json {
        writeln!(self.writer, "{line}").unwrap();
        self.writer.flush().unwrap();
        let mut reply = String::new();
        self.reader.read_line(&mut reply).unwrap();
        Json::parse(&reply).unwrap()
    }
}

/// Registry shaped like `deis serve --precision f32 --models mlp,gmm-like`:
/// "mlp" has both engines, "nof32" only the f64 one.
fn precision_registry() -> ModelRegistry {
    let root = Json::parse(&common::weights_json(2, 16, 8, 2)).unwrap();
    let mut reg = ModelRegistry::new();
    reg.insert("mlp", Arc::new(NativeMlp::from_json_with(&root, Precision::F64).unwrap()));
    reg.insert(
        "mlp@f32",
        Arc::new(NativeMlp::from_json_with(&root, Precision::F32).unwrap()),
    );
    reg.insert("nof32", Arc::new(NativeMlp::from_json_with(&root, Precision::F64).unwrap()));
    reg
}

#[test]
fn dtype_wire_contract() {
    let coord = Arc::new(Coordinator::new(CoordinatorConfig::default(), precision_registry()));
    let addr = server::serve(coord, "127.0.0.1:0").unwrap();
    let mut client = Client::connect(&addr);

    // Default dtype: served by the f64 engine, echoed as f64.
    let r = client.call(r#"{"model":"mlp","solver":"tab3","nfe":8,"n":4,"seed":1}"#);
    assert!(r.get("ok").unwrap().as_bool().unwrap());
    assert_eq!(r.get("dtype").unwrap().as_str().unwrap(), "f64");

    // Explicit f32: routed to the @f32 sibling, echoed as f32, samples sane.
    let r = client.call(
        r#"{"model":"mlp","solver":"tab3","nfe":8,"n":4,"seed":1,"dtype":"f32","return_samples":true}"#,
    );
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "f32 request failed: {r:?}");
    assert_eq!(r.get("dtype").unwrap().as_str().unwrap(), "f32");
    let samples = r.get("samples").unwrap().as_f64_vec().unwrap();
    assert_eq!(samples.len(), 4 * 2);
    assert!(samples.iter().all(|v| v.is_finite()));

    // The f32 run tracks the f64 run of the same request within tolerance.
    let r64 = client.call(
        r#"{"model":"mlp","solver":"tab3","nfe":8,"n":4,"seed":1,"dtype":"f64","return_samples":true}"#,
    );
    let samples64 = r64.get("samples").unwrap().as_f64_vec().unwrap();
    for (a, f) in samples64.iter().zip(&samples) {
        assert!((a - f).abs() < 0.05 * (1.0 + a.abs()), "wire f32 parity: {a} vs {f}");
    }

    // Unknown dtype: rejected before admission, with a pointed error.
    let r = client.call(r#"{"model":"mlp","solver":"tab3","nfe":8,"n":4,"dtype":"f16"}"#);
    assert!(!r.get("ok").unwrap().as_bool().unwrap());
    let err = r.get("error").unwrap().as_str().unwrap().to_string();
    assert!(err.contains("unknown dtype"), "error was: {err}");

    // f32 against a model with no f32 engine: refused with a hint.
    let r = client.call(r#"{"model":"nof32","solver":"tab3","nfe":8,"n":4,"dtype":"f32"}"#);
    assert!(!r.get("ok").unwrap().as_bool().unwrap());
    let err = r.get("error").unwrap().as_str().unwrap().to_string();
    assert!(err.contains("no f32 engine"), "error was: {err}");

    // Per-model stats key the f32 traffic under the rewritten name.
    let stats = client.call(r#"{"cmd":"stats"}"#);
    let pm32 = stats.get("per_model").unwrap().get("mlp@f32").unwrap();
    assert_eq!(pm32.get("completed").unwrap().as_f64().unwrap(), 1.0);
    let pm64 = stats.get("per_model").unwrap().get("mlp").unwrap();
    assert_eq!(pm64.get("completed").unwrap().as_f64().unwrap(), 2.0);
}
