//! Cross-language parity: rust solvers vs the independent float64 numpy
//! implementations in python/compile/fixtures.py, on the analytic GMM.
//! Pins every coefficient formula (Psi, C_ij, rho maps) across the stack.
//!
//! Requires `make artifacts` (which also writes artifacts/fixtures/).

use deis::diffusion::Sde;
use deis::gmm::Gmm;
use deis::score::GmmEps;
use deis::solvers::{self, SolverKind};
use deis::util::json::Json;
use deis::util::rng::Rng;

fn load_fixture() -> Json {
    let path = "artifacts/fixtures/solver_parity.json";
    Json::from_file(path).unwrap_or_else(|e| {
        panic!("{path} missing — run `make artifacts` first ({e:#})")
    })
}

struct Fixture {
    grid: Vec<f64>,
    x_t: Vec<f64>,
    b: usize,
    gmm: Gmm,
}

fn setup(fx: &Json) -> Fixture {
    let grid = fx.get("grid").unwrap().as_f64_vec().unwrap();
    let (b, _d, x_t) = fx.get("x_T").unwrap().as_matrix().unwrap();
    let gm = fx.get("gmm").unwrap();
    let (_, _, means_flat) = gm.get("means").unwrap().as_matrix().unwrap();
    let means: Vec<Vec<f64>> = means_flat.chunks(2).map(|c| c.to_vec()).collect();
    let gmm = Gmm::new(means, gm.get("std").unwrap().as_f64().unwrap());
    Fixture { grid, x_t, b, gmm }
}

fn check(fx: &Json, solver_key: &str, kind: SolverKind, sde: Sde, scale_xt: f64, atol: f64) {
    let f = setup(fx);
    let model = GmmEps::new(f.gmm.clone(), sde);
    let mut x: Vec<f64> = f.x_t.iter().map(|v| v * scale_xt).collect();
    let solver = solvers::build(kind, &sde, &f.grid);
    solver.sample(&model, &mut x, f.b, &mut Rng::new(0));
    let (_, _, want) = fx.get("solvers").unwrap().get(solver_key).unwrap().as_matrix().unwrap();
    for (i, (got, exp)) in x.iter().zip(&want).enumerate() {
        assert!(
            (got - exp).abs() < atol,
            "{solver_key} element {i}: rust {got} vs python {exp}"
        );
    }
}

#[test]
#[ignore = "needs artifacts/fixtures/solver_parity.json from `make artifacts` (python/JAX, not available in CI) — run locally after building artifacts"]
fn ddim_matches_python() {
    check(&load_fixture(), "vp_ddim", SolverKind::Tab(0), Sde::vp(), 1.0, 1e-6);
}

#[test]
#[ignore = "needs artifacts/fixtures/solver_parity.json from `make artifacts` (python/JAX, not available in CI) — run locally after building artifacts"]
fn tab2_matches_python() {
    check(&load_fixture(), "vp_tab2", SolverKind::Tab(2), Sde::vp(), 1.0, 1e-6);
}

#[test]
#[ignore = "needs artifacts/fixtures/solver_parity.json from `make artifacts` (python/JAX, not available in CI) — run locally after building artifacts"]
fn rho_ab2_matches_python() {
    check(&load_fixture(), "vp_rho_ab2", SolverKind::RhoAb(2), Sde::vp(), 1.0, 1e-6);
}

#[test]
#[ignore = "needs artifacts/fixtures/solver_parity.json from `make artifacts` (python/JAX, not available in CI) — run locally after building artifacts"]
fn rho_heun_matches_python() {
    check(&load_fixture(), "vp_rho_heun", SolverKind::RhoHeun, Sde::vp(), 1.0, 1e-6);
}

#[test]
#[ignore = "needs artifacts/fixtures/solver_parity.json from `make artifacts` (python/JAX, not available in CI) — run locally after building artifacts"]
fn ve_ddim_matches_python() {
    check(&load_fixture(), "ve_ddim", SolverKind::Tab(0), Sde::ve(), 50.0, 1e-6);
}
