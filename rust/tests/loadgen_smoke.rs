//! Loadgen determinism + end-to-end smoke (EXPERIMENTS.md §Load): the
//! open-loop plan must be byte-identical per seed, and a short in-process
//! run's client-side tallies must reconcile EXACTLY with the server's
//! `{"cmd":"stats"}` wire — global and per model — including the new
//! `deadline_hit`/`deadline_missed` counters and binary sample frames.

mod common;

use std::sync::Arc;
use std::time::Duration;

use deis::coordinator::{Coordinator, CoordinatorConfig};
use deis::server::loadgen::{self, LoadProfile};
use deis::server::serve;

/// The smoke profile: three registered models under Zipf popularity, a
/// mixed solver/NFE/framing profile, and only LOOSE deadlines — the
/// stall-free oracles answer in microseconds, so every request completes
/// and the reconciliation is exact-by-construction (no rejected/expired/
/// failed slop to absorb a miscount).
fn smoke_profile(seed: u64) -> LoadProfile {
    LoadProfile {
        seed,
        rps: 400.0,
        duration: Duration::from_millis(400),
        models: vec!["gmm2d".to_string(), "ring6".to_string(), "ring5".to_string()],
        zipf_s: 1.1,
        deadline_share: 0.5,
        tight_ms: 2_000,
        loose_ms: 10_000,
        samples_share: 0.5,
        bin_share: 0.5,
        nfes: vec![4, 6, 8],
        n_choices: vec![2, 4, 8],
        solvers: vec!["tab2".to_string(), "ddim".to_string(), "tab3".to_string()],
    }
}

#[test]
fn same_seed_yields_an_identical_plan_and_different_seeds_differ() {
    let a = loadgen::schedule(&smoke_profile(7));
    let b = loadgen::schedule(&smoke_profile(7));
    assert!(!a.is_empty());
    assert_eq!(a, b, "same seed must reproduce the arrival schedule and mix exactly");
    let c = loadgen::schedule(&smoke_profile(8));
    assert_ne!(a, c, "a different seed must produce a different plan");

    // The plan exercises the full wire surface this smoke claims to cover.
    assert!(a.iter().any(|r| r.bin), "plan must include binary-framed requests");
    assert!(a.iter().any(|r| r.return_samples && !r.bin));
    assert!(a.iter().any(|r| r.deadline_ms.is_some()));
    assert!(a.iter().any(|r| r.deadline_ms.is_none()));
    for model in ["gmm2d", "ring6", "ring5"] {
        assert!(a.iter().any(|r| r.model == model), "no traffic planned for {model}");
    }
}

#[test]
fn client_tallies_reconcile_exactly_with_the_stats_wire() {
    let coord = Arc::new(Coordinator::new(
        CoordinatorConfig { workers: 4, ..Default::default() },
        common::multi_stall_registry(Duration::ZERO),
    ));
    let addr = serve(coord, "127.0.0.1:0").unwrap();

    let profile = smoke_profile(7);
    let plan = loadgen::schedule(&profile);
    let report = loadgen::run_plan(addr, &plan, 6).unwrap();

    // Non-zero completions, and with stall-free oracles + loose deadlines
    // + in-cap load, nothing is shed: every planned request completes.
    assert_eq!(report.global.sent, plan.len() as u64);
    assert!(report.global.completed > 0, "smoke must complete requests");
    assert_eq!(report.global.completed, report.global.sent, "{:?}", report.global);
    assert_eq!(report.global.rejected, 0);
    assert_eq!(report.global.expired, 0);
    assert_eq!(report.global.failed, 0);
    // Deadline accounting: every completed deadline-carrying request is a
    // hit, and the plan mixes deadline and deadline-less traffic.
    let planned_deadlines =
        plan.iter().filter(|r| r.deadline_ms.is_some()).count() as u64;
    assert!(planned_deadlines > 0 && planned_deadlines < plan.len() as u64);
    assert_eq!(report.global.deadline_hit, planned_deadlines);
    assert_eq!(report.global.deadline_missed, 0);
    assert!(report.p50_us > 0, "client latency histogram must record");
    // Every model drew traffic, with the Zipf rank-1 model clearly the
    // most popular. (The full three-way ordering is pinned by the
    // larger-sample unit test in `server/loadgen.rs`; at this short
    // duration the two tail models are too close to assert apart.)
    let sent = |m: &str| report.per_model.get(m).map_or(0, |t| t.sent);
    assert!(sent("gmm2d") > sent("ring6") && sent("gmm2d") > sent("ring5"));
    assert!(sent("ring6") > 0 && sent("ring5") > 0);

    // The headline acceptance check: exact reconciliation of the client
    // tallies against the live stats wire, global and per model.
    let stats = loadgen::fetch_stats(addr).unwrap();
    loadgen::reconcile(&report, &stats).unwrap();
}
