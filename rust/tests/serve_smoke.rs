//! `make serve-smoke`: 64 concurrent clients with a mixed workload — plain
//! submits, JSON-sample submits, binary-frame submits, and counted
//! rejections — against the readiness-driven frontend, then the stats
//! lifecycle balance (`requests == completed + rejected + expired +
//! failed`) globally and per model. Wired into `make ci`.

mod common;

use std::sync::Arc;
use std::time::Duration;

use deis::coordinator::{Coordinator, CoordinatorConfig};
use deis::server::{serve, Client};
use deis::util::json::Json;

#[test]
fn mixed_concurrent_battery_balances_the_books() {
    let coord = Arc::new(Coordinator::new(
        CoordinatorConfig { workers: 4, ..Default::default() },
        // A tiny stall keeps evals overlapping so the burst really is
        // concurrent (merging/co-batching paths engage), without making
        // the smoke slow.
        common::stall_registry(Duration::from_millis(2)),
    ));
    let addr = serve(coord, "127.0.0.1:0").unwrap();

    let mut handles = Vec::new();
    for i in 0..64u64 {
        handles.push(std::thread::spawn(move || {
            let mut cl = Client::connect(addr).unwrap();
            match i % 4 {
                0 => {
                    // Plain submit, no samples on the wire.
                    let req = format!(
                        r#"{{"model":"gmm2d","solver":"tab2","nfe":6,"n":16,"seed":{i}}}"#
                    );
                    let r = cl.call(&Json::parse(&req).unwrap()).unwrap();
                    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
                }
                1 => {
                    // JSON sample array.
                    let req = format!(
                        r#"{{"model":"gmm2d","solver":"ddim","nfe":5,"n":16,"seed":{i},"return_samples":true}}"#
                    );
                    let r = cl.call(&Json::parse(&req).unwrap()).unwrap();
                    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
                    assert_eq!(r.get("samples").unwrap().as_arr().unwrap().len(), 32);
                }
                2 => {
                    // Binary frame.
                    let req = format!(
                        r#"{{"model":"gmm2d","solver":"ddim","nfe":5,"n":16,"seed":{i},"return_samples":true,"frame":"bin"}}"#
                    );
                    let (h, samples) = cl.call_bin(&Json::parse(&req).unwrap()).unwrap();
                    assert!(h.get("ok").unwrap().as_bool().unwrap(), "{h:?}");
                    assert_eq!(h.get("frame").unwrap().as_str().unwrap(), "bin");
                    assert_eq!(samples.len(), 32);
                }
                _ => {
                    // A counted rejection (unknown model reaches the
                    // coordinator, unlike a parse error), then a good call
                    // on the same connection: errors must not poison it.
                    let bad = r#"{"model":"nope","solver":"tab2","nfe":6,"n":4}"#;
                    let r = cl.call(&Json::parse(bad).unwrap()).unwrap();
                    assert!(!r.get("ok").unwrap().as_bool().unwrap());
                    assert!(r.get("error").unwrap().as_str().unwrap().contains("unknown model"));
                    let good = format!(
                        r#"{{"model":"gmm2d","solver":"tab2","nfe":6,"n":16,"seed":{i}}}"#
                    );
                    let r = cl.call(&Json::parse(&good).unwrap()).unwrap();
                    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    let mut cl = Client::connect(addr).unwrap();
    let s = cl.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
    let g = |k: &str| s.get(k).unwrap().as_f64().unwrap();
    // 48 direct successes + 16 post-rejection successes; 16 rejections.
    assert_eq!(g("completed"), 64.0);
    assert_eq!(g("rejected"), 16.0);
    assert_eq!(
        g("requests"),
        g("completed") + g("rejected") + g("expired") + g("failed"),
        "global lifecycle must balance: {s:?}"
    );
    // Per-model books balance too (unknown-model refusals are global-only,
    // so gmm2d sees exactly the 64 served requests).
    let pm = s.get("per_model").unwrap().get("gmm2d").unwrap();
    let p = |k: &str| pm.get(k).unwrap().as_f64().unwrap();
    assert_eq!(p("completed"), 64.0);
    assert_eq!(
        p("requests"),
        p("completed") + p("rejected") + p("expired") + p("failed"),
        "per-model lifecycle must balance: {pm:?}"
    );
}
