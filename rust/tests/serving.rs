//! Serving-layer integration: TCP server + client over the analytic oracle
//! (no artifacts needed), exercising batching, merging and the wire format.

mod common;

use std::sync::Arc;
use std::time::Duration;

use deis::coordinator::{Coordinator, CoordinatorConfig};
use deis::server::{serve, Client};
use deis::util::json::Json;

fn boot_with(workers: usize, stall: Duration, max_inflight: usize) -> std::net::SocketAddr {
    let coord = Arc::new(Coordinator::new(
        CoordinatorConfig {
            workers,
            max_batch_samples: 512,
            max_inflight_requests: max_inflight,
            ..Default::default()
        },
        common::stall_registry(stall),
    ));
    serve(coord, "127.0.0.1:0").unwrap()
}

fn boot(workers: usize, stall: Duration) -> std::net::SocketAddr {
    boot_with(workers, stall, 4096)
}

#[test]
fn many_clients_merge_and_complete() {
    let addr = boot(1, Duration::from_millis(25));

    // Occupy the single worker; everything that arrives during its stalled
    // eval is admitted in one tick.
    let mut warm_client = Client::connect(addr).unwrap();
    let clients: Vec<Client> = (0..12).map(|_| Client::connect(addr).unwrap()).collect();
    let warm = std::thread::spawn(move || {
        warm_client
            .call(&Json::parse(r#"{"model":"gmm2d","solver":"ddim","nfe":2,"n":4}"#).unwrap())
            .unwrap()
    });
    std::thread::sleep(Duration::from_millis(8));

    let mut handles = Vec::new();
    for (i, mut c) in clients.into_iter().enumerate() {
        handles.push(std::thread::spawn(move || {
            let req = format!(
                r#"{{"model":"gmm2d","solver":"tab2","nfe":8,"n":32,"seed":{i}}}"#
            );
            let resp = c.call(&Json::parse(&req).unwrap()).unwrap();
            assert!(resp.get("ok").unwrap().as_bool().unwrap(), "{resp:?}");
            resp.get("merged_with").unwrap().as_f64().unwrap() as usize
        }));
    }
    let merges: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(warm.join().unwrap().get("ok").unwrap().as_bool().unwrap());
    assert_eq!(merges.len(), 12);
    // The queued burst must have been admission-merged into shared runs.
    assert!(merges.iter().any(|&m| m > 1), "no dynamic batching observed: {merges:?}");

    let mut c = Client::connect(addr).unwrap();
    let stats = c.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
    assert_eq!(stats.get("completed").unwrap().as_f64().unwrap() as usize, 13);
    let batches = stats.get("batches").unwrap().as_f64().unwrap() as usize;
    assert!(batches < 13, "expected merging to reduce batch count, got {batches}");
    // Merged trajectory groups drive merged evals: occupancy must show it.
    assert!(
        stats.get("eval_occupancy").unwrap().as_f64().unwrap() > 1.0,
        "stats endpoint must report cross-request eval merging"
    );
}

#[test]
fn mixed_solver_configs_do_not_cross_contaminate() {
    let addr = boot(3, Duration::ZERO);
    let mut a = Client::connect(addr).unwrap();
    let mut b = Client::connect(addr).unwrap();
    // Same seed, different solver => different samples; same seed + same
    // config => identical samples (determinism through the wire).
    let q = |solver: &str| {
        format!(
            r#"{{"model":"gmm2d","solver":"{solver}","nfe":6,"n":8,"seed":3,"return_samples":true}}"#
        )
    };
    let ra = a.call(&Json::parse(&q("ddim")).unwrap()).unwrap();
    let rb = b.call(&Json::parse(&q("rho-heun")).unwrap()).unwrap();
    let ra2 = a.call(&Json::parse(&q("ddim")).unwrap()).unwrap();
    let sa = ra.get("samples").unwrap().as_f64_vec().unwrap();
    let sb = rb.get("samples").unwrap().as_f64_vec().unwrap();
    let sa2 = ra2.get("samples").unwrap().as_f64_vec().unwrap();
    assert_eq!(sa, sa2, "determinism violated");
    assert!(sa.iter().zip(&sb).any(|(x, y)| (x - y).abs() > 1e-9));
}

#[test]
fn deadline_and_overload_are_reported_over_the_wire() {
    let addr = boot(1, Duration::ZERO);
    let mut c = Client::connect(addr).unwrap();
    // A zero deadline expires before the worker can pick the request up.
    let resp = c
        .call(&Json::parse(
            r#"{"model":"gmm2d","solver":"ddim","nfe":5,"n":4,"deadline_ms":0}"#,
        ).unwrap())
        .unwrap();
    assert!(!resp.get("ok").unwrap().as_bool().unwrap());
    assert!(
        resp.get("error").unwrap().as_str().unwrap().contains("deadline"),
        "{resp:?}"
    );
    // A generous deadline samples normally.
    let resp = c
        .call(&Json::parse(
            r#"{"model":"gmm2d","solver":"ddim","nfe":5,"n":4,"deadline_ms":60000}"#,
        ).unwrap())
        .unwrap();
    assert!(resp.get("ok").unwrap().as_bool().unwrap(), "{resp:?}");

    let stats = c.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
    assert_eq!(stats.get("expired").unwrap().as_f64().unwrap() as usize, 1);
}

#[test]
fn deadline_firing_during_checked_out_eval_yields_error_not_late_samples() {
    // Force the race the off-lock advance design must survive: the flight
    // is checked OUT of its scheduler slot (invisible to the expiry sweep)
    // when its deadline fires. An idle worker picks the request up within
    // microseconds, then stalls 120ms inside the trajectory's only eval;
    // the 40ms deadline therefore fires mid-checkout, deterministically.
    // The expired-at-delivery contract demands an error — late samples
    // must be withheld even though the integration finished them.
    let addr = boot(1, Duration::from_millis(120));
    let mut c = Client::connect(addr).unwrap();
    let t0 = std::time::Instant::now();
    let resp = c
        .call(&Json::parse(
            r#"{"model":"gmm2d","solver":"ddim","nfe":1,"n":4,"deadline_ms":40,"return_samples":true}"#,
        ).unwrap())
        .unwrap();
    let elapsed = t0.elapsed();
    assert!(!resp.get("ok").unwrap().as_bool().unwrap(), "{resp:?}");
    assert!(
        resp.get("error").unwrap().as_str().unwrap().contains("deadline"),
        "{resp:?}"
    );
    assert!(resp.get("samples").is_err(), "an expired reply must carry no samples");
    // The reply arriving only after the stalled eval proves the deadline
    // fired while the flight was checked out (a queue-expiry would have
    // answered at ~40ms), i.e. the delivery-time re-check caught it.
    assert!(
        elapsed >= Duration::from_millis(90),
        "reply after {elapsed:?}: deadline did not race the checked-out eval"
    );

    let mut sc = Client::connect(addr).unwrap();
    let stats = sc.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
    assert_eq!(stats.get("expired").unwrap().as_f64().unwrap() as usize, 1);
    assert_eq!(stats.get("completed").unwrap().as_f64().unwrap() as usize, 0);
    assert_eq!(
        stats.get("samples").unwrap().as_f64().unwrap() as usize,
        0,
        "expired-at-delivery parts must contribute no sample rows"
    );
}

#[test]
fn deadline_firing_during_panicking_checked_out_eval_counts_exactly_once() {
    // The deadline/failure interplay: the flight is checked out, its only
    // eval stalls 120ms (overrunning the 40ms deadline) and THEN panics.
    // Two accounting paths now claim the same part — expiry and fault
    // containment — and it must be counted exactly once, as expired (the
    // deadline fired first), with the deadline error text on the wire.
    let coord = Arc::new(Coordinator::new(
        CoordinatorConfig { workers: 1, max_batch_samples: 512, ..Default::default() },
        common::faulty_registry(&[(
            "gmm2d",
            deis::score::FaultPlan::new().stall_on(0, 120).panic_on(0),
        )]),
    ));
    let addr = serve(coord, "127.0.0.1:0").unwrap();
    let mut c = Client::connect(addr).unwrap();
    let t0 = std::time::Instant::now();
    let resp = c
        .call(&Json::parse(
            r#"{"model":"gmm2d","solver":"ddim","nfe":1,"n":4,"deadline_ms":40}"#,
        ).unwrap())
        .unwrap();
    let elapsed = t0.elapsed();
    assert!(!resp.get("ok").unwrap().as_bool().unwrap(), "{resp:?}");
    assert!(
        resp.get("error").unwrap().as_str().unwrap().contains("deadline"),
        "expired-before-panic must surface as a deadline error: {resp:?}"
    );
    // The reply arriving only after the stall proves the deadline fired
    // during the checked-out (and then panicking) eval.
    assert!(
        elapsed >= Duration::from_millis(90),
        "reply after {elapsed:?}: deadline did not race the panicking eval"
    );
    let stats = c.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
    let g = |k: &str| stats.get(k).unwrap().as_f64().unwrap() as u64;
    assert_eq!(g("expired"), 1, "counted as expired (deadline fired first)");
    assert_eq!(g("failed"), 0, "the same part must not ALSO count as failed");
    assert_eq!(g("eval_panics"), 1, "the contained panic is still diagnosed");
    assert_eq!(g("requests"), g("completed") + g("rejected") + g("expired") + g("failed"));
}

#[test]
fn overload_is_reported_over_the_wire() {
    // One in-flight slot and a stalled worker: while the first request is
    // integrating, further submissions must be refused with the documented
    // "overloaded" error instead of queueing without bound.
    let addr = boot_with(1, Duration::from_millis(40), 1);
    let mut busy = Client::connect(addr).unwrap();
    let mut refused = Client::connect(addr).unwrap();

    let first = std::thread::spawn(move || {
        busy.call(&Json::parse(r#"{"model":"gmm2d","solver":"ddim","nfe":3,"n":4}"#).unwrap())
            .unwrap()
    });
    // Let the first request occupy the only slot (worker stalls 40ms/eval).
    std::thread::sleep(Duration::from_millis(15));
    let resp = refused
        .call(&Json::parse(r#"{"model":"gmm2d","solver":"ddim","nfe":3,"n":4}"#).unwrap())
        .unwrap();
    assert!(!resp.get("ok").unwrap().as_bool().unwrap(), "{resp:?}");
    assert!(
        resp.get("error").unwrap().as_str().unwrap().contains("overloaded"),
        "{resp:?}"
    );
    // The occupant completes normally once the stall ends.
    assert!(first.join().unwrap().get("ok").unwrap().as_bool().unwrap());

    let mut c = Client::connect(addr).unwrap();
    let stats = c.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
    assert_eq!(stats.get("rejected").unwrap().as_f64().unwrap() as usize, 1);
    assert_eq!(stats.get("completed").unwrap().as_f64().unwrap() as usize, 1);
}
