//! Serving-layer integration: TCP server + client over the analytic oracle
//! (no artifacts needed), exercising batching, merging and the wire format.

use std::sync::Arc;

use deis::coordinator::{Coordinator, CoordinatorConfig, ModelRegistry};
use deis::diffusion::Sde;
use deis::gmm::Gmm;
use deis::score::GmmEps;
use deis::server::{serve, Client};
use deis::util::json::Json;

fn boot(workers: usize) -> std::net::SocketAddr {
    let mut reg = ModelRegistry::new();
    reg.insert("gmm2d", Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())));
    let coord = Arc::new(Coordinator::new(
        CoordinatorConfig { workers, max_batch_samples: 512 },
        reg,
    ));
    serve(coord, "127.0.0.1:0").unwrap()
}

#[test]
fn many_clients_merge_and_complete() {
    let addr = boot(2);
    let mut handles = Vec::new();
    for i in 0..12 {
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            let req = format!(
                r#"{{"model":"gmm2d","solver":"tab2","nfe":8,"n":32,"seed":{i}}}"#
            );
            let resp = c.call(&Json::parse(&req).unwrap()).unwrap();
            assert!(resp.get("ok").unwrap().as_bool().unwrap(), "{resp:?}");
            resp.get("merged_with").unwrap().as_f64().unwrap() as usize
        }));
    }
    let merges: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(merges.len(), 12);
    // With 2 workers and 12 simultaneous identical requests, at least some
    // runs must have merged more than one request.
    assert!(merges.iter().any(|&m| m > 1), "no dynamic batching observed: {merges:?}");

    let mut c = Client::connect(addr).unwrap();
    let stats = c.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
    assert_eq!(stats.get("completed").unwrap().as_f64().unwrap() as usize, 12);
    let batches = stats.get("batches").unwrap().as_f64().unwrap() as usize;
    assert!(batches < 12, "expected merging to reduce batch count, got {batches}");
}

#[test]
fn mixed_solver_configs_do_not_cross_contaminate() {
    let addr = boot(3);
    let mut a = Client::connect(addr).unwrap();
    let mut b = Client::connect(addr).unwrap();
    // Same seed, different solver => different samples; same seed + same
    // config => identical samples (determinism through the wire).
    let q = |solver: &str| {
        format!(
            r#"{{"model":"gmm2d","solver":"{solver}","nfe":6,"n":8,"seed":3,"return_samples":true}}"#
        )
    };
    let ra = a.call(&Json::parse(&q("ddim")).unwrap()).unwrap();
    let rb = b.call(&Json::parse(&q("rho-heun")).unwrap()).unwrap();
    let ra2 = a.call(&Json::parse(&q("ddim")).unwrap()).unwrap();
    let sa = ra.get("samples").unwrap().as_f64_vec().unwrap();
    let sb = rb.get("samples").unwrap().as_f64_vec().unwrap();
    let sa2 = ra2.get("samples").unwrap().as_f64_vec().unwrap();
    assert_eq!(sa, sa2, "determinism violated");
    assert!(sa.iter().zip(&sb).any(|(x, y)| (x - y).abs() > 1e-9));
}
