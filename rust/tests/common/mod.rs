//! Shared helpers for the serving/scheduler integration tests.
//!
//! Each integration-test target compiles its own copy of this module and
//! uses a different subset of it, so dead-code warnings are suppressed.
#![allow(dead_code)]

use std::sync::Arc;
use std::time::Duration;

use deis::coordinator::ModelRegistry;
use deis::diffusion::Sde;
use deis::gmm::Gmm;
use deis::score::{EpsModel, GmmEps};

/// The standard 8-Gaussian-ring analytic oracle (no artifacts needed).
pub fn oracle() -> GmmEps {
    GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())
}

/// Analytic oracle with an optional per-eval stall. Stalling the (single)
/// worker inside a model call keeps the admission queue open long enough
/// that a burst of concurrent clients is admitted — and therefore merged —
/// in one scheduler tick, making batching assertions deterministic instead
/// of timing-lucky. The math is untouched, so parity against the plain
/// oracle is exact.
pub struct StallOracle {
    inner: GmmEps,
    stall: Duration,
}

impl StallOracle {
    pub fn new(stall: Duration) -> StallOracle {
        StallOracle { inner: oracle(), stall }
    }
}

impl EpsModel for StallOracle {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eval(&self, x: &[f64], t: &[f64], b: usize, out: &mut [f64]) {
        if !self.stall.is_zero() {
            std::thread::sleep(self.stall);
        }
        self.inner.eval(x, t, b, out);
    }
}

/// Registry mapping "gmm2d" to a [`StallOracle`] with the given stall.
pub fn stall_registry(stall: Duration) -> ModelRegistry {
    let mut reg = ModelRegistry::new();
    reg.insert("gmm2d", Arc::new(StallOracle::new(stall)));
    reg
}
