//! Shared helpers for the serving/scheduler integration tests.
//!
//! Each integration-test target compiles its own copy of this module and
//! uses a different subset of it, so dead-code warnings are suppressed.
#![allow(dead_code)]

use std::sync::Arc;
use std::time::Duration;

use deis::coordinator::ModelRegistry;
use deis::diffusion::Sde;
use deis::gmm::Gmm;
use deis::score::{EpsModel, FaultPlan, FaultyEps, GmmEps};
use deis::solvers::SolverKind;

/// The standard 8-Gaussian-ring analytic oracle (no artifacts needed).
pub fn oracle() -> GmmEps {
    GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())
}

/// Distinct analytic mixtures per model name, so multi-model routing tests
/// can prove a request was served by *its* model: the wrong shard would
/// produce visibly (and bit-exactly checkably) different samples.
/// "gmm2d" stays the standard ring so single-model helpers agree.
pub fn gmm_for(name: &str) -> Gmm {
    match name {
        "gmm2d" => Gmm::ring2d(4.0, 8, 0.25),
        "ring6" => Gmm::ring2d(2.5, 6, 0.35),
        "ring5" => Gmm::ring2d(3.25, 5, 0.2),
        "ring7" => Gmm::ring2d(3.75, 7, 0.3),
        other => panic!("no test mixture registered for model '{other}'"),
    }
}

/// Analytic oracle for one of the [`gmm_for`] model names.
pub fn oracle_for(name: &str) -> GmmEps {
    GmmEps::new(gmm_for(name), Sde::vp())
}

/// Analytic oracle with an optional per-eval stall. Stalling the (single)
/// worker inside a model call keeps the admission queue open long enough
/// that a burst of concurrent clients is admitted — and therefore merged —
/// in one scheduler tick, making batching assertions deterministic instead
/// of timing-lucky. The math is untouched, so parity against the plain
/// oracle is exact.
pub struct StallOracle {
    inner: GmmEps,
    stall: Duration,
}

impl StallOracle {
    pub fn new(stall: Duration) -> StallOracle {
        StallOracle { inner: oracle(), stall }
    }
}

impl EpsModel for StallOracle {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eval(&self, x: &[f64], t: &[f64], b: usize, out: &mut [f64]) {
        if !self.stall.is_zero() {
            std::thread::sleep(self.stall);
        }
        self.inner.eval(x, t, b, out);
    }
}

impl StallOracle {
    /// Stalling wrapper around an arbitrary mixture oracle (multi-model
    /// registries need per-model math, not just per-model names).
    pub fn wrapping(inner: GmmEps, stall: Duration) -> StallOracle {
        StallOracle { inner, stall }
    }
}

/// Registry mapping "gmm2d" to a [`StallOracle`] with the given stall.
pub fn stall_registry(stall: Duration) -> ModelRegistry {
    let mut reg = ModelRegistry::new();
    reg.insert("gmm2d", Arc::new(StallOracle::new(stall)));
    reg
}

/// Registry of named analytic oracles wrapped in per-model fault scripts
/// (an empty [`FaultPlan`] = a healthy model). Each entry gets its OWN
/// [`FaultyEps`] eval counter, so one model's faults never shift another
/// model's script — the chaos battery relies on that isolation.
pub fn faulty_registry(entries: &[(&str, FaultPlan)]) -> ModelRegistry {
    let mut reg = ModelRegistry::new();
    for (name, plan) in entries {
        reg.insert(name, Arc::new(FaultyEps::new(oracle_for(name), plan.clone())));
    }
    reg
}

/// Solo reference samples for one of the [`gmm_for`] models, replicating
/// the serving engine's per-request RNG streams exactly (priors from
/// `seed`, stochastic-solver noise from `seed ^ 0xD1F_F051`) — the
/// bit-exact parity oracle for chaos tests: a healthy model served next
/// to misbehaving ones must produce exactly these values.
pub fn solo_samples(name: &str, kind: SolverKind, nfe: usize, n: usize, seed: u64) -> Vec<f64> {
    let sde = Sde::vp();
    let model = oracle_for(name);
    let steps = kind.steps_for_nfe(nfe);
    let grid =
        deis::timegrid::build(deis::timegrid::GridKind::Quadratic, &sde, sde.t0_default(), 1.0, steps);
    let solver = deis::solvers::build(kind, &sde, &grid);
    let mut rng = deis::util::rng::Rng::new(seed);
    let prior = sde.prior_std(1.0);
    let mut x = vec![0.0; n * model.dim()];
    for v in x.iter_mut() {
        *v = prior * rng.normal();
    }
    let mut srng = deis::util::rng::Rng::new(seed ^ 0xD1F_F051);
    solver.sample(&model, &mut x, n, &mut srng);
    x
}

/// Tiny deterministic value stream for synthetic weights ([-0.3, 0.3],
/// small enough that stacked residual blocks and full solver trajectories
/// through the net stay finite).
fn lcg_next(state: &mut u64) -> f64 {
    *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    ((*state >> 33) % 13) as f64 / 20.0 - 0.3
}

fn json_matrix(state: &mut u64, r: usize, c: usize) -> String {
    let rows: Vec<String> = (0..r)
        .map(|_| {
            let vals: Vec<String> = (0..c).map(|_| format!("{:.2}", lcg_next(state))).collect();
            format!("[{}]", vals.join(","))
        })
        .collect();
    format!("[{}]", rows.join(","))
}

fn json_vector(state: &mut u64, n: usize) -> String {
    let vals: Vec<String> = (0..n).map(|_| format!("{:.2}", lcg_next(state))).collect();
    format!("[{}]", vals.join(","))
}

/// Deterministic synthetic eps-net weights JSON in the weights_*.json
/// schema — lets precision/kernel tests load real [`deis::score::NativeMlp`]
/// engines without any artifacts on disk.
pub fn weights_json(dim: usize, hidden: usize, embed: usize, n_blocks: usize) -> String {
    let mut st = 0x9E3779B97F4A7C15u64;
    let blocks: Vec<String> = (0..n_blocks)
        .map(|_| {
            format!(
                r#"{{"w1": {}, "b1": {}, "u": {}, "w2": {}, "b2": {}}}"#,
                json_matrix(&mut st, hidden, hidden),
                json_vector(&mut st, hidden),
                json_matrix(&mut st, embed, hidden),
                json_matrix(&mut st, hidden, hidden),
                json_vector(&mut st, hidden)
            )
        })
        .collect();
    format!(
        r#"{{"dim": {dim}, "hidden": {hidden}, "embed": {embed}, "n_blocks": {n_blocks},
            "params": {{"w_in": {}, "b_in": {}, "w_out": {}, "b_out": {},
                        "blocks": [{}]}}}}"#,
        json_matrix(&mut st, dim, hidden),
        json_vector(&mut st, hidden),
        json_matrix(&mut st, hidden, dim),
        json_vector(&mut st, dim),
        blocks.join(",")
    )
}

/// Registry with three DISTINCT stalling models ("gmm2d", "ring6",
/// "ring5", each its own mixture — see [`gmm_for`]) for shard-routing
/// tests: per-model bit-exact parity against [`oracle_for`] proves every
/// request was served by exactly the model it named.
pub fn multi_stall_registry(stall: Duration) -> ModelRegistry {
    let mut reg = ModelRegistry::new();
    for name in ["gmm2d", "ring6", "ring5"] {
        reg.insert(name, Arc::new(StallOracle::wrapping(oracle_for(name), stall)));
    }
    reg
}
