//! Readiness-driven frontend battery: partial/split reads, pipelining,
//! binary sample frames, slowloris vs idle keep-alive, connection-scale
//! thread bounds, and graceful drain — all against the real TCP event
//! loop over the analytic oracle (no artifacts needed).
//!
//! Synchronization is by observable protocol state (replies received,
//! stats counters), never by sleeping and hoping; the only sleeps are the
//! ones that ARE the scenario (a slowloris trickling bytes, an idle
//! connection outliving the read timeout).

mod common;

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use deis::coordinator::{Coordinator, CoordinatorConfig};
use deis::server::{poll, serve, serve_with, wire, Client, ServeOptions};
use deis::util::json::Json;

fn boot_oracle() -> std::net::SocketAddr {
    let coord = Arc::new(Coordinator::new(
        CoordinatorConfig::default(),
        common::stall_registry(Duration::ZERO),
    ));
    serve(coord, "127.0.0.1:0").unwrap()
}

/// Raw socket + line reader over the same connection, for tests that need
/// byte-level control the [`Client`] wrapper hides.
fn connect_raw(addr: SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let r = BufReader::new(s.try_clone().unwrap());
    (s, r)
}

/// A request line arriving in small fragments across many event-loop
/// wakeups must reassemble into exactly one request (the connection state
/// machine accumulates partial reads; correctness may not depend on how
/// the kernel happens to chunk the stream).
#[test]
fn split_reads_reassemble_into_one_request() {
    let addr = boot_oracle();
    let (mut s, mut r) = connect_raw(addr);
    let line =
        r#"{"model":"gmm2d","solver":"ddim","nfe":4,"n":6,"seed":3,"return_samples":true}"#;
    for chunk in line.as_bytes().chunks(7) {
        s.write_all(chunk).unwrap();
        s.flush().unwrap();
        // Not synchronization — this forces the fragments into separate
        // TCP segments so the server really sees split reads.
        std::thread::sleep(Duration::from_millis(2));
    }
    s.write_all(b"\n").unwrap();
    let mut reply = String::new();
    r.read_line(&mut reply).unwrap();
    let v = Json::parse(&reply).unwrap();
    assert!(v.get("ok").unwrap().as_bool().unwrap(), "{v:?}");
    assert_eq!(v.get("samples").unwrap().as_arr().unwrap().len(), 12);
}

/// Pipelined lines on one connection are answered strictly in order, one
/// request in flight at a time (the distinct `n` values tag each reply to
/// its request; the trailing cmd proves the queue drains past submits).
#[test]
fn pipelined_requests_answer_in_order() {
    let addr = boot_oracle();
    let (mut s, mut r) = connect_raw(addr);
    let mut batch = String::new();
    for n in [2, 4, 6] {
        batch.push_str(&format!(
            "{{\"model\":\"gmm2d\",\"solver\":\"tab1\",\"nfe\":4,\"n\":{n},\"seed\":{n}}}\n"
        ));
    }
    batch.push_str("{\"cmd\":\"models\"}\n");
    s.write_all(batch.as_bytes()).unwrap();
    for n in [2, 4, 6] {
        let mut reply = String::new();
        r.read_line(&mut reply).unwrap();
        let v = Json::parse(&reply).unwrap();
        assert!(v.get("ok").unwrap().as_bool().unwrap(), "{v:?}");
        assert_eq!(v.get("n").unwrap().as_f64().unwrap(), n as f64, "reply out of order");
    }
    let mut reply = String::new();
    r.read_line(&mut reply).unwrap();
    let v = Json::parse(&reply).unwrap();
    assert_eq!(v.get("models").unwrap().as_arr().unwrap().len(), 1);
}

/// The binary frame carries the exact same values as the JSON array —
/// same model, solver and seed on both frames — at under half the wire
/// bytes for the serving shape n=256, d=2.
#[test]
fn bin_frame_matches_json_samples_at_half_the_bytes() {
    let addr = boot_oracle();
    let (mut s, mut r) = connect_raw(addr);
    let base = r#""model":"gmm2d","solver":"tab2","nfe":6,"n":256,"seed":11,"return_samples":true"#;

    s.write_all(format!("{{{base}}}\n").as_bytes()).unwrap();
    let mut json_line = String::new();
    r.read_line(&mut json_line).unwrap();
    let v = Json::parse(&json_line).unwrap();
    assert!(v.get("ok").unwrap().as_bool().unwrap(), "{v:?}");
    let json_samples: Vec<f64> = v
        .get("samples")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap())
        .collect();
    assert_eq!(json_samples.len(), 512);

    s.write_all(format!("{{{base},\"frame\":\"bin\"}}\n").as_bytes()).unwrap();
    let mut header_line = String::new();
    r.read_line(&mut header_line).unwrap();
    let h = Json::parse(&header_line).unwrap();
    assert!(h.get("ok").unwrap().as_bool().unwrap(), "{h:?}");
    assert_eq!(h.get("frame").unwrap().as_str().unwrap(), "bin");
    assert_eq!(h.get("rows").unwrap().as_f64().unwrap(), 256.0);
    assert_eq!(h.get("dim").unwrap().as_f64().unwrap(), 2.0);
    let nbytes = h.get("bin_bytes").unwrap().as_u64().unwrap() as usize;
    assert_eq!(nbytes, 512 * 8);
    let mut payload = vec![0u8; nbytes];
    r.read_exact(&mut payload).unwrap();
    let bin_samples = wire::samples_from_le_bytes(&payload).unwrap();
    assert_eq!(json_samples, bin_samples, "frames must carry identical sample values");

    // Honest wire accounting: full JSON reply line vs header line + raw
    // payload. Shortest-round-trip f64 text averages ~21 bytes per value
    // against 8 raw, so the realistic win is ~2.5x (see EXPERIMENTS.md
    // §Serving for why 4x is unreachable without quantization).
    let json_bytes = json_line.len();
    let bin_total = header_line.len() + nbytes;
    assert!(
        json_bytes as f64 >= 2.0 * bin_total as f64,
        "bin frame should at least halve the reply: json={json_bytes}B bin={bin_total}B"
    );

    // The Client helper decodes the same frame, and `frame:"bin"` without
    // return_samples degrades to the plain JSON reply (nothing to frame).
    let mut cl = Client::connect(addr).unwrap();
    let (h2, samples2) = cl
        .call_bin(&Json::parse(&format!("{{{base},\"frame\":\"bin\"}}")).unwrap())
        .unwrap();
    assert!(h2.get("ok").unwrap().as_bool().unwrap());
    assert_eq!(samples2, bin_samples);
    let (h3, empty) = cl
        .call_bin(
            &Json::parse(r#"{"model":"gmm2d","solver":"tab2","nfe":6,"n":8,"frame":"bin"}"#)
                .unwrap(),
        )
        .unwrap();
    assert!(h3.get("ok").unwrap().as_bool().unwrap(), "{h3:?}");
    assert!(h3.opt("bin_bytes").is_none(), "no payload without return_samples");
    assert!(h3.opt("frame").is_none());
    assert!(empty.is_empty());
}

/// Slowloris vs idle: a connection stalled MID-line past `read_timeout`
/// is silently dropped by the sweep, while an idle connection *between*
/// requests outlives the same timeout untouched.
#[test]
fn slowloris_is_dropped_but_idle_keepalive_survives() {
    let coord = Arc::new(Coordinator::new(
        CoordinatorConfig::default(),
        common::stall_registry(Duration::ZERO),
    ));
    let addr = serve_with(
        coord,
        "127.0.0.1:0",
        ServeOptions { read_timeout: Duration::from_millis(150), ..Default::default() },
    )
    .unwrap();

    // Half a request, then silence: the sweep must close the connection.
    let (mut s, mut r) = connect_raw(addr);
    s.write_all(b"{\"model\":\"gm").unwrap();
    let mut line = String::new();
    let n = r.read_line(&mut line).expect("server should close, not leave us hanging");
    assert_eq!(n, 0, "mid-line stall must be dropped silently, got: {line:?}");

    // Idle between requests: the same timeout must NOT fire.
    let mut cl = Client::connect(addr).unwrap();
    let req = Json::parse(r#"{"model":"gmm2d","solver":"ddim","nfe":3,"n":2}"#).unwrap();
    assert!(cl.call(&req).unwrap().get("ok").unwrap().as_bool().unwrap());
    std::thread::sleep(Duration::from_millis(400)); // the scenario under test
    let v = cl.call(&req).unwrap();
    assert!(
        v.get("ok").unwrap().as_bool().unwrap(),
        "idle connection was dropped by the slowloris sweep: {v:?}"
    );
}

#[cfg(target_os = "linux")]
fn thread_count() -> i64 {
    std::fs::read_to_string("/proc/self/status")
        .unwrap()
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .map(|v| v.trim().parse().unwrap())
        .expect("Threads: line in /proc/self/status")
}

/// The headline scale claim: ~1024 concurrent mostly-idle connections are
/// held by the fixed I/O-thread pool — the process thread count stays
/// flat while the connections are open, and the server stays responsive
/// through the crowd (thread-per-connection would add ~1024 here).
#[cfg(target_os = "linux")]
#[test]
fn thousand_idle_connections_hold_with_bounded_threads() {
    const CONNS: usize = 1024;
    // Both ends of every connection live in this process: ~2 fds each.
    let limit = poll::raise_nofile_limit(4096);
    if limit < (2 * CONNS + 256) as u64 {
        eprintln!("skipping {CONNS}-connection test: fd limit {limit} is too low");
        return;
    }
    let coord = Arc::new(Coordinator::new(
        CoordinatorConfig { workers: 1, ..Default::default() },
        common::stall_registry(Duration::ZERO),
    ));
    let addr = serve_with(
        coord,
        "127.0.0.1:0",
        ServeOptions { max_conns: CONNS + 16, ..Default::default() },
    )
    .unwrap();
    let before = thread_count();
    let mut socks: Vec<TcpStream> = Vec::with_capacity(CONNS);
    for _ in 0..CONNS {
        socks.push(TcpStream::connect(addr).unwrap());
    }
    // Liveness through the crowd: a fresh connection round-trips...
    let mut cl = Client::connect(addr).unwrap();
    let models = cl.call(&Json::parse(r#"{"cmd":"models"}"#).unwrap()).unwrap();
    assert!(models.get("ok").unwrap().as_bool().unwrap());
    // ...and so does a sample of the held connections themselves.
    for s in socks.iter_mut().step_by(128) {
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        s.write_all(b"{\"cmd\":\"health\"}\n").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(Json::parse(&line).unwrap().get("ok").unwrap().as_bool().unwrap());
    }
    let after = thread_count();
    // Slack covers concurrently-running tests in this binary, not conns.
    assert!(
        after - before < 64,
        "{CONNS} connections must not grow the thread pool: {before} -> {after} threads"
    );
}

/// Graceful drain answers the in-flight request: a request admitted
/// before `begin_drain` still gets its reply (written out through the
/// event loop), while new submissions are refused and introspection keeps
/// working. Synchronized on the stats counter, not on sleeps.
#[test]
fn drain_answers_the_in_flight_request() {
    let coord = Arc::new(Coordinator::new(
        CoordinatorConfig { workers: 1, ..Default::default() },
        common::stall_registry(Duration::from_millis(300)),
    ));
    let addr = serve(coord.clone(), "127.0.0.1:0").unwrap();

    // Submit on A without reading the reply yet.
    let (mut a, mut a_reader) = connect_raw(addr);
    a.write_all(b"{\"model\":\"gmm2d\",\"solver\":\"tab2\",\"nfe\":4,\"n\":8,\"seed\":1}\n")
        .unwrap();

    // Wait until the coordinator has really admitted it, then drain.
    let mut b = Client::connect(addr).unwrap();
    let stats_cmd = Json::parse(r#"{"cmd":"stats"}"#).unwrap();
    loop {
        let s = b.call(&stats_cmd).unwrap();
        if s.get("requests").unwrap().as_f64().unwrap() >= 1.0 {
            break;
        }
        std::thread::yield_now();
    }
    coord.begin_drain();

    // New submissions are refused; introspection still works.
    let refused = b
        .call(&Json::parse(r#"{"model":"gmm2d","solver":"tab2","nfe":4,"n":8}"#).unwrap())
        .unwrap();
    assert!(!refused.get("ok").unwrap().as_bool().unwrap());
    assert!(refused.get("error").unwrap().as_str().unwrap().contains("shutting down"));
    let h = b.call(&Json::parse(r#"{"cmd":"health"}"#).unwrap()).unwrap();
    assert!(h.get("draining").unwrap().as_bool().unwrap());

    // The in-flight request drains to a real reply, not a hang or an error.
    let mut reply = String::new();
    a_reader.read_line(&mut reply).unwrap();
    let v = Json::parse(&reply).unwrap();
    assert!(v.get("ok").unwrap().as_bool().unwrap(), "in-flight request lost in drain: {v:?}");
}

/// The client refuses to allocate a binary payload larger than its hard
/// cap — a hostile (or corrupted) header cannot become an allocation bomb.
#[test]
fn client_rejects_oversized_binary_frames() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let fake = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        // 2^40 bytes claimed: far past MAX_BIN_REPLY_BYTES.
        s.write_all(b"{\"bin_bytes\":1099511627776,\"ok\":true}\n").unwrap();
    });
    let mut cl = Client::connect(addr).unwrap();
    let err = cl
        .call_bin(&Json::parse(r#"{"cmd":"stats"}"#).unwrap())
        .expect_err("a 1TB frame claim must be refused before allocation");
    assert!(err.to_string().contains("binary frame too large"), "{err:#}");
    fake.join().unwrap();
}
