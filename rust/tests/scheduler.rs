//! Step-level scheduler integration: concurrent TCP clients whose
//! ε-evaluations get merged across requests, observable through the stats
//! endpoint, plus the bit-exactness guarantee — batched-scheduled sampling
//! must equal solo sampling per (seed, config).

mod common;

use std::sync::Arc;
use std::time::Duration;

use deis::coordinator::{Coordinator, CoordinatorConfig, SampleRequest, SchedPolicy, StatsSnapshot};
use deis::server::{serve, Client};
use deis::solvers::{self, SolverKind};
use deis::timegrid;
use deis::util::json::Json;
use deis::util::rng::Rng;

/// Reference: the exact samples request `req` must produce, computed
/// without the coordinator (same prior stream, same solver, solo batch).
/// Model-aware: resolves the mixture by the request's model name, so
/// multi-model parity checks also prove the request was routed to the
/// shard of exactly the model it named.
fn solo_samples(req: &SampleRequest) -> Vec<f64> {
    let model = common::oracle_for(&req.model);
    let steps = req.solver.steps_for_nfe(req.nfe);
    let grid = timegrid::build(req.grid, &req.sde, req.t0, 1.0, steps);
    let solver = solvers::build(req.solver, &req.sde, &grid);
    let d = model.dim();
    let mut rng = Rng::new(req.seed);
    let prior = req.sde.prior_std(1.0);
    let mut x = vec![0.0; req.n_samples * d];
    for v in x.iter_mut() {
        *v = prior * rng.normal();
    }
    let mut srng = Rng::new(req.seed ^ 0xD1F_F051);
    solver.sample(&model, &mut x, req.n_samples, &mut srng);
    x
}

#[test]
fn concurrent_clients_with_mixed_nfes_merge_evals_over_tcp() {
    // One worker + a 40ms eval stall: every client that submits during the
    // stall is admitted in the same scheduler tick. All trajectories start
    // at t_N = T regardless of NFE, so even the different-NFE flights merge
    // their first eval, and the same-config pairs stay merged throughout.
    let coord = Arc::new(Coordinator::new(
        CoordinatorConfig { workers: 1, max_batch_samples: 4096, ..Default::default() },
        common::stall_registry(Duration::from_millis(40)),
    ));
    let addr = serve(coord, "127.0.0.1:0").unwrap();

    // Pre-connect so client threads only need to write one line during the
    // stall window.
    let mut warm_client = Client::connect(addr).unwrap();
    let clients: Vec<Client> = (0..6).map(|_| Client::connect(addr).unwrap()).collect();

    // Occupy the worker: its first eval stalls 40ms with the queue open.
    let warm = std::thread::spawn(move || {
        warm_client
            .call(&Json::parse(r#"{"model":"gmm2d","solver":"ddim","nfe":2,"n":4}"#).unwrap())
            .unwrap()
    });
    // Give the warm request time to reach the worker.
    std::thread::sleep(Duration::from_millis(10));

    let nfes = [6usize, 6, 8, 8, 10, 12];
    let mut handles = Vec::new();
    for (i, mut c) in clients.into_iter().enumerate() {
        let nfe = nfes[i];
        handles.push(std::thread::spawn(move || {
            let req = format!(
                r#"{{"model":"gmm2d","solver":"tab2","nfe":{nfe},"n":8,"seed":{i}}}"#
            );
            c.call(&Json::parse(&req).unwrap()).unwrap()
        }));
    }
    let responses: Vec<Json> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(warm.join().unwrap().get("ok").unwrap().as_bool().unwrap());
    for r in &responses {
        assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
    }
    // The same-NFE pairs admission-merge; their evals then co-batch.
    let max_co = responses
        .iter()
        .map(|r| r.get("co_batched").unwrap().as_f64().unwrap() as usize)
        .max()
        .unwrap();
    assert!(max_co > 1, "no cross-request eval batching observed");

    // The stats endpoint must prove evals were merged: occupancy > 1.
    let mut c = Client::connect(addr).unwrap();
    let stats = c.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
    assert_eq!(stats.get("completed").unwrap().as_f64().unwrap() as usize, 7);
    let sched_evals = stats.get("sched_evals").unwrap().as_f64().unwrap();
    assert!(sched_evals > 0.0, "scheduler dispatched no merged evals");
    let occupancy = stats.get("eval_occupancy").unwrap().as_f64().unwrap();
    assert!(
        occupancy > 1.0,
        "stats endpoint must show cross-request merging (eval_occupancy {occupancy})"
    );
    assert!(stats.get("max_occupancy").unwrap().as_f64().unwrap() >= 2.0);
    // The shared plan cache is observable over the wire. (Hits are not
    // asserted here: concurrent submissions of one config may race into
    // two builds, which the cache counts as two misses by design.)
    assert!(
        stats.get("plan_cache_misses").unwrap().as_f64().unwrap() >= 1.0,
        "plan cache misses must be reported in server stats"
    );
    assert!(stats.get("plan_cache_hits").is_ok(), "plan_cache_hits key must exist");
}

/// The concurrency battery for the off-lock scheduler: many concurrent TCP
/// clients with mixed solver kinds — including the adaptive rk45 and the
/// stochastic samplers — against 4 scheduler workers and a stall-model that
/// keeps many flights checked out simultaneously. Asserts the three
/// serving invariants the off-lock refactor must preserve:
///
///   1. every request gets exactly one response (every call returns, and
///      the lifecycle counters balance: requests == completed + rejected
///      + expired);
///   2. refusals stay refusals — over-cap NFE is rejected, a zero deadline
///      expires — and neither perturbs the live traffic;
///   3. bit-exact parity: each completed request's samples equal its solo
///      `sample()` run per (seed, config), proving checked-out advance
///      changed no math. Coupling-sensitive kinds (rk45, em, addim) get
///      unique (solver, nfe) keys so nothing admission-merges with them —
///      the regime where scheduled == solo holds exactly (see the scheduler
///      module doc); the deterministic kinds share keys freely and must be
///      bit-exact merged or not.
#[test]
fn stress_battery_exactly_one_response_stats_balance_and_parity() {
    let coord = Arc::new(Coordinator::new(
        CoordinatorConfig {
            workers: 4,
            max_batch_samples: 4096,
            max_inflight_requests: 4096,
            ..Default::default()
        },
        common::stall_registry(Duration::from_millis(10)),
    ));
    let addr = serve(coord.clone(), "127.0.0.1:0").unwrap();

    // (wire solver name, nfe, seed) — 24 completing requests.
    let mut cfgs: Vec<(&str, usize, u64)> = Vec::new();
    for s in 0..8 {
        cfgs.push(("tab2", 8, s)); // one shared batch key: admission-merge fodder
    }
    for s in 0..4 {
        cfgs.push(("tab3", 10, 40 + s));
    }
    for s in 0..4 {
        cfgs.push(("dpm2", 10, 80 + s));
    }
    for (i, nfe) in [10usize, 12, 14, 16].into_iter().enumerate() {
        cfgs.push(("rk45", nfe, 100 + i as u64)); // unique keys: never merged
    }
    for (i, nfe) in [9usize, 11].into_iter().enumerate() {
        cfgs.push(("em", nfe, 120 + i as u64)); // stochastic, unique keys
    }
    for (i, nfe) in [13usize, 15].into_iter().enumerate() {
        cfgs.push(("addim", nfe, 140 + i as u64)); // stochastic, unique keys
    }
    let expected: Vec<Vec<f64>> = cfgs
        .iter()
        .map(|&(name, nfe, seed)| {
            let mut r = SampleRequest::new("gmm2d", SolverKind::parse(name).unwrap(), nfe, 6);
            r.seed = seed;
            solo_samples(&r)
        })
        .collect();

    // Pre-connect every client, then fire all requests concurrently.
    let clients: Vec<Client> = (0..cfgs.len()).map(|_| Client::connect(addr).unwrap()).collect();
    let mut handles = Vec::new();
    for ((name, nfe, seed), mut c) in cfgs.iter().copied().zip(clients) {
        handles.push(std::thread::spawn(move || {
            let req = format!(
                r#"{{"model":"gmm2d","solver":"{name}","nfe":{nfe},"n":6,"seed":{seed},"return_samples":true}}"#
            );
            c.call(&Json::parse(&req).unwrap()).unwrap()
        }));
    }
    // Refusal traffic alongside: three zero-deadline requests (expire in
    // the queue) and two over-cap NFE requests (rejected at submit).
    let over_cap = deis::coordinator::MAX_REQUEST_NFE + 1;
    let mut refusals = Vec::new();
    for i in 0..5 {
        let line = if i < 3 {
            r#"{"model":"gmm2d","solver":"euler","nfe":4,"n":2,"deadline_ms":0}"#.to_string()
        } else {
            format!(r#"{{"model":"gmm2d","solver":"tab1","nfe":{over_cap},"n":2}}"#)
        };
        let mut c = Client::connect(addr).unwrap();
        refusals.push(std::thread::spawn(move || c.call(&Json::parse(&line).unwrap()).unwrap()));
    }

    // Exactly one response per request: every call returns one reply.
    let responses: Vec<Json> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (r, ((name, nfe, seed), want)) in responses.iter().zip(cfgs.iter().zip(&expected)) {
        assert!(r.get("ok").unwrap().as_bool().unwrap(), "{name} nfe {nfe} seed {seed}: {r:?}");
        assert_eq!(r.get("n").unwrap().as_f64().unwrap() as usize, 6);
        let got = r.get("samples").unwrap().as_f64_vec().unwrap();
        // JSON floats use shortest-roundtrip formatting, so equality here
        // is bit-exactness through the full TCP path.
        assert_eq!(&got, want, "scheduled vs solo mismatch for {name} nfe {nfe} seed {seed}");
        assert!(r.get("co_batched").unwrap().as_f64().unwrap() >= 1.0);
    }
    for (i, h) in refusals.into_iter().enumerate() {
        let r = h.join().unwrap();
        assert!(!r.get("ok").unwrap().as_bool().unwrap(), "refusal {i} must be an error");
        let err = r.get("error").unwrap().as_str().unwrap().to_string();
        if i < 3 {
            assert!(err.contains("deadline"), "refusal {i}: {err}");
        } else {
            assert!(err.contains("out of range"), "refusal {i}: {err}");
        }
    }

    // Lifecycle balance: nothing double-answered, nothing dropped.
    let s = coord.stats();
    assert_eq!(s.requests, 29);
    assert_eq!(s.completed, 24);
    assert_eq!(s.expired, 3);
    assert_eq!(s.rejected, 2);
    assert_eq!(
        s.requests,
        s.completed + s.rejected + s.expired,
        "lifecycle counters must balance"
    );
    assert_eq!(s.samples, 24 * 6, "only completed requests contribute sample rows");
    assert!(s.sched_evals > 0);
    assert!(s.p50_us > 0, "bucketed latency histogram must report percentiles");
}

/// Multi-model extension of the stress battery: the per-model sharding
/// refactor must keep every serving invariant while routing ≥3 registered
/// models' traffic to ≥3 independent shards over one TCP front end.
///
///   1. exactly one response per request, and the lifecycle counters
///      balance globally AND per model (`requests == completed + rejected
///      + expired` in every `per_model` entry);
///   2. bit-exact solo parity per model — each model is a DIFFERENT
///      mixture (`common::gmm_for`), so a response that matched the wrong
///      shard's model could not possibly pass;
///   3. shard eval accounting: every model runs merged evals on its own
///      shard, and the per-model eval counters sum exactly to the global
///      ones — eval traffic is fully attributed, never cross-shard.
#[test]
fn stress_battery_multi_model_shard_routing_balance_and_parity() {
    let coord = Arc::new(Coordinator::new(
        CoordinatorConfig {
            workers: 4,
            max_batch_samples: 4096,
            max_inflight_requests: 4096,
            ..Default::default()
        },
        common::multi_stall_registry(Duration::from_millis(10)),
    ));
    let addr = serve(coord.clone(), "127.0.0.1:0").unwrap();
    let models = ["gmm2d", "ring6", "ring5"];

    // Per model: 3x tab2 under one shared batch key (admission-merge
    // fodder), tab3 + dpm2 (deterministic, mergeable), and rk45/em/addim
    // with unique (solver, nfe) keys so the coupling-sensitive kinds never
    // admission-merge — the regime where scheduled == solo is exact.
    let mut cfgs: Vec<(&str, &str, usize, u64)> = Vec::new();
    for (mi, m) in models.into_iter().enumerate() {
        let base = 1000 * (mi as u64 + 1);
        for s in 0..3 {
            cfgs.push((m, "tab2", 8, base + s));
        }
        cfgs.push((m, "tab3", 10, base + 40));
        cfgs.push((m, "dpm2", 10, base + 50));
        cfgs.push((m, "rk45", 10 + 2 * mi, base + 60));
        cfgs.push((m, "em", 9 + 2 * mi, base + 70));
        cfgs.push((m, "addim", 13 + 2 * mi, base + 80));
    }
    let expected: Vec<Vec<f64>> = cfgs
        .iter()
        .map(|&(model, name, nfe, seed)| {
            let mut r = SampleRequest::new(model, SolverKind::parse(name).unwrap(), nfe, 6);
            r.seed = seed;
            solo_samples(&r)
        })
        .collect();

    // Pre-connect every client, then fire all requests concurrently.
    let clients: Vec<Client> = (0..cfgs.len()).map(|_| Client::connect(addr).unwrap()).collect();
    let mut handles = Vec::new();
    for ((model, name, nfe, seed), mut c) in cfgs.iter().copied().zip(clients) {
        handles.push(std::thread::spawn(move || {
            let req = format!(
                r#"{{"model":"{model}","solver":"{name}","nfe":{nfe},"n":6,"seed":{seed},"return_samples":true}}"#
            );
            c.call(&Json::parse(&req).unwrap()).unwrap()
        }));
    }
    // Refusal traffic alongside: one zero-deadline request per model
    // (expires on its own shard) and one unknown model name (rejected at
    // routing, before any shard exists for it).
    let mut refusals = Vec::new();
    for m in models {
        let line = format!(
            r#"{{"model":"{m}","solver":"euler","nfe":4,"n":2,"deadline_ms":0}}"#
        );
        let mut c = Client::connect(addr).unwrap();
        refusals.push(("deadline", std::thread::spawn(move || c.call(&Json::parse(&line).unwrap()).unwrap())));
    }
    {
        let line = r#"{"model":"not_registered","solver":"ddim","nfe":4,"n":2}"#.to_string();
        let mut c = Client::connect(addr).unwrap();
        refusals.push(("unknown model", std::thread::spawn(move || c.call(&Json::parse(&line).unwrap()).unwrap())));
    }

    // Exactly one response per request, bit-exact per (model, seed, config).
    let responses: Vec<Json> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (r, ((model, name, nfe, seed), want)) in responses.iter().zip(cfgs.iter().zip(&expected)) {
        assert!(
            r.get("ok").unwrap().as_bool().unwrap(),
            "{model}/{name} nfe {nfe} seed {seed}: {r:?}"
        );
        let got = r.get("samples").unwrap().as_f64_vec().unwrap();
        // JSON floats use shortest-roundtrip formatting, so equality here
        // is bit-exactness through the full TCP path — and because every
        // model is a different mixture, a cross-shard routing mistake
        // cannot produce these samples.
        assert_eq!(&got, want, "scheduled vs solo mismatch for {model}/{name} seed {seed}");
    }
    for (needle, h) in refusals {
        let r = h.join().unwrap();
        assert!(!r.get("ok").unwrap().as_bool().unwrap(), "refusal ({needle}) must error");
        let err = r.get("error").unwrap().as_str().unwrap().to_string();
        assert!(err.contains(needle), "expected '{needle}' in: {err}");
    }

    // Lifecycle balance, globally and per model, over the wire.
    let mut c = Client::connect(addr).unwrap();
    let stats = c.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
    let g = |k: &str| stats.get(k).unwrap().as_f64().unwrap() as u64;
    assert_eq!(g("requests"), 28);
    assert_eq!(g("completed"), 24);
    assert_eq!(g("expired"), 3);
    assert_eq!(g("rejected"), 1, "the unknown-model refusal counts as rejected");
    assert_eq!(g("requests"), g("completed") + g("rejected") + g("expired"));
    assert_eq!(g("samples"), 24 * 6);
    let per_model = stats.get("per_model").unwrap();
    let mut sum_sched_evals = 0.0;
    let mut sum_model_evals = 0.0;
    for m in models {
        let pm = per_model.get(m).unwrap_or_else(|_| panic!("missing per_model entry for {m}"));
        let p = |k: &str| pm.get(k).unwrap().as_f64().unwrap() as u64;
        assert_eq!(p("requests"), 9, "{m}: 8 sampling + 1 zero-deadline");
        assert_eq!(p("completed"), 8, "{m}");
        assert_eq!(p("expired"), 1, "{m}");
        assert_eq!(p("rejected"), 0, "{m}");
        assert_eq!(p("requests"), p("completed") + p("rejected") + p("expired"), "{m}");
        assert_eq!(p("samples"), 8 * 6, "{m}");
        assert!(p("sched_evals") > 0, "{m}: shard must run its own merged evals");
        sum_sched_evals += pm.get("sched_evals").unwrap().as_f64().unwrap();
        sum_model_evals += pm.get("model_evals").unwrap().as_f64().unwrap();
    }
    // Eval attribution is exact: shard counters partition the global ones.
    assert_eq!(sum_sched_evals as u64, g("sched_evals"));
    assert_eq!(sum_model_evals as u64, g("model_evals"));
    // The unknown model never got a shard (no fourth per_model entry).
    assert!(per_model.get("not_registered").is_err());
}

/// Work stealing: a single-model hot spot on a many-shard coordinator must
/// keep ALL workers busy. Three idle shards are warmed first, so worker
/// affinity parks three of the four workers on idle home shards; the test
/// then drives four independent flights at the fourth ("hot") model, whose
/// ε-model is a rendezvous barrier that only releases when all four evals
/// are in flight SIMULTANEOUSLY. Without stealing, only the hot shard's
/// affinity worker would ever arrive, the rendezvous would time out and
/// flag failure — so completion with a clean flag is deterministic proof
/// that every worker stole into the hot shard.
#[test]
fn single_model_hotspot_keeps_all_workers_busy_via_stealing() {
    use deis::coordinator::ModelRegistry;
    use deis::score::EpsModel;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Condvar, Mutex};

    const W: usize = 4;

    struct Rendezvous {
        want: usize,
        /// (arrived-this-phase, phase)
        state: Mutex<(usize, u64)>,
        cv: Condvar,
        failed: AtomicBool,
    }

    impl Rendezvous {
        fn wait(&self) {
            if self.failed.load(Ordering::SeqCst) {
                return; // already failed: let the test drain and report
            }
            let mut g = self.state.lock().unwrap();
            g.0 += 1;
            if g.0 >= self.want {
                g.0 = 0;
                g.1 = g.1.wrapping_add(1);
                self.cv.notify_all();
                return;
            }
            let phase = g.1;
            loop {
                let (ng, to) = self.cv.wait_timeout(g, Duration::from_secs(5)).unwrap();
                g = ng;
                if g.1 != phase {
                    return; // the phase completed: all `want` arrived
                }
                if to.timed_out() {
                    self.failed.store(true, Ordering::SeqCst);
                    g.0 = 0;
                    g.1 = g.1.wrapping_add(1);
                    self.cv.notify_all();
                    return;
                }
            }
        }
    }

    struct RendezvousEps {
        inner: deis::score::GmmEps,
        rv: Arc<Rendezvous>,
    }

    impl EpsModel for RendezvousEps {
        fn dim(&self) -> usize {
            self.inner.dim()
        }

        fn eval(&self, x: &[f64], t: &[f64], b: usize, out: &mut [f64]) {
            self.rv.wait();
            self.inner.eval(x, t, b, out);
        }
    }

    let rv = Arc::new(Rendezvous {
        want: W,
        state: Mutex::new((0, 0)),
        cv: Condvar::new(),
        failed: AtomicBool::new(false),
    });
    let mut reg = ModelRegistry::new();
    for name in ["idle0", "idle1", "idle2"] {
        reg.insert(name, Arc::new(common::oracle()));
    }
    reg.insert("hot", Arc::new(RendezvousEps { inner: common::oracle(), rv: rv.clone() }));
    // max_batch_samples = 1: no admission merging and one flight per
    // dispatched eval, so the four hot requests are four independent
    // flights whose evals must be executed by four distinct workers at
    // once for the rendezvous to release.
    let coord = Coordinator::new(
        CoordinatorConfig { workers: W, max_batch_samples: 1, ..Default::default() },
        reg,
    );
    // Warm the idle shards FIRST: shard order is creation order, so the
    // hot shard is created last and exactly one worker has it as home.
    for name in ["idle0", "idle1", "idle2"] {
        coord.sample_blocking(SampleRequest::new(name, SolverKind::Tab(0), 5, 2)).unwrap();
    }
    let rxs: Vec<_> = (0..W)
        .map(|i| {
            let mut q = SampleRequest::new("hot", SolverKind::Tab(1), 8, 1);
            q.seed = i as u64;
            coord.submit(q)
        })
        .collect();
    for rx in rxs {
        assert!(rx.recv().unwrap().is_ok(), "hot-model request failed");
    }
    assert!(
        !rv.failed.load(Ordering::SeqCst),
        "rendezvous timed out: the idle-shard workers never stole into the hot shard"
    );
    let s = coord.stats();
    assert_eq!(s.completed, 3 + W as u64);
    coord.shutdown();
}

/// One contended run for the EDF-vs-oldest policy comparison: 4 workers on
/// a 10ms-stall model, 6 long loose-deadline flights submitted first, then
/// 6 short tight-deadline flights. Every request gets its own t0, so
/// batch keys AND time buckets are distinct — no admission merging and
/// (past the shared t_N = 1.0 first eval) no co-batching: 12 independent
/// flights compete for 4 workers, and the anchor policy alone decides who
/// runs first. Timing is sleep-dominated with hard lower bounds: a loose
/// flight needs 50 evals x 10ms >= 500ms, so under oldest-first no worker
/// can reach a tight flight before its 400ms deadline fires — while under
/// EDF the tights (~50ms each, two waves) finish with ~270ms to spare.
/// Returns the outcome per tight request plus the final stats snapshot.
fn run_contended(policy: SchedPolicy) -> (Vec<anyhow::Result<()>>, StatsSnapshot) {
    let coord = Coordinator::new(
        CoordinatorConfig {
            workers: 4,
            max_batch_samples: 4096,
            sched_policy: policy,
            ..Default::default()
        },
        common::stall_registry(Duration::from_millis(10)),
    );
    let mk = |nfe: usize, t0: f64, deadline_ms: u64, seed: u64| {
        let mut r = SampleRequest::new("gmm2d", SolverKind::parse("ddim").unwrap(), nfe, 2);
        r.t0 = t0;
        r.deadline_ms = Some(deadline_ms);
        r.seed = seed;
        r
    };
    // Loose first (older), tight second: oldest-first must serve the loose
    // flights to completion before the tights, EDF must not.
    let loose_rxs: Vec<_> = (0..6)
        .map(|i| coord.submit(mk(50, 1e-3 + i as f64 * 2e-5, 10_000, 100 + i as u64)))
        .collect();
    let tight_rxs: Vec<_> = (0..6)
        .map(|i| coord.submit(mk(5, 2e-3 + i as f64 * 2e-5, 400, 200 + i as u64)))
        .collect();
    for rx in loose_rxs {
        assert!(
            rx.recv().unwrap().is_ok(),
            "loose flights (10s deadline) must complete under either policy"
        );
    }
    let tight: Vec<anyhow::Result<()>> =
        tight_rxs.into_iter().map(|rx| rx.recv().unwrap().map(|_| ())).collect();
    let s = coord.stats();
    coord.shutdown();
    (tight, s)
}

/// Per-run invariants that must hold under BOTH policies: the 4-term
/// lifecycle balance (`requests == completed + rejected + expired +
/// failed`) globally and per model, `deadline_missed == expired` (every
/// request in this scenario carries a deadline), and `deadline_hit ==
/// completed` for the same reason.
fn assert_contended_balance(s: &StatsSnapshot, policy: &str) {
    assert_eq!(s.requests, 12, "{policy}");
    assert_eq!(s.rejected, 0, "{policy}");
    assert_eq!(s.failed, 0, "{policy}");
    assert_eq!(
        s.requests,
        s.completed + s.rejected + s.expired + s.failed,
        "{policy}: global lifecycle must balance"
    );
    assert_eq!(s.deadline_missed, s.expired, "{policy}");
    assert_eq!(s.deadline_hit, s.completed, "{policy}");
    assert_eq!(s.per_model.len(), 1, "{policy}: single-model run");
    let (name, m) = &s.per_model[0];
    assert_eq!(name, "gmm2d", "{policy}");
    assert_eq!(m.requests, 12, "{policy}");
    assert_eq!(
        m.requests,
        m.completed + m.rejected + m.expired + m.failed,
        "{policy}: per-model lifecycle must balance"
    );
    assert_eq!(m.completed, s.completed, "{policy}");
    assert_eq!(m.expired, s.expired, "{policy}");
    assert_eq!(m.deadline_hit, s.deadline_hit, "{policy}");
    assert_eq!(m.deadline_missed, s.deadline_missed, "{policy}");
}

/// The policy-outcome battery: identical offered load under oldest-first
/// and under EDF. Oldest-first starves the tight-deadline flights behind
/// older loose ones (all 6 expire); EDF anchors the tights first (all 6
/// hit), strictly reducing the expired count at the same load. The EDF
/// age guard is set far above the loose deadlines so it cannot mask the
/// deadline ordering under test (the guard's own semantics have dedicated
/// unit tests in `coordinator/scheduler.rs`).
#[test]
fn edf_strictly_reduces_expired_count_vs_oldest_first_under_contention() {
    let (tight_oldest, s_oldest) = run_contended(SchedPolicy::Oldest);
    let (tight_edf, s_edf) =
        run_contended(SchedPolicy::Edf { age_guard: Duration::from_secs(2) });

    assert_contended_balance(&s_oldest, "oldest");
    assert_contended_balance(&s_edf, "edf");

    // Oldest-first: every tight flight expires waiting behind the loose
    // backlog, and the error says so.
    for (i, r) in tight_oldest.iter().enumerate() {
        let err = r.as_ref().expect_err(&format!(
            "oldest: tight flight {i} cannot beat a 400ms deadline behind \
             >=500ms of older loose work"
        ));
        assert!(err.to_string().contains("deadline"), "tight {i}: {err:#}");
    }
    assert_eq!(s_oldest.completed, 6, "oldest: only the loose flights finish");
    assert_eq!(s_oldest.expired, 6);

    // EDF: the tights are anchored ahead of the older loose flights and
    // all hit their deadlines; nothing expires.
    for (i, r) in tight_edf.iter().enumerate() {
        assert!(r.is_ok(), "edf: tight flight {i} must hit its deadline: {r:?}");
    }
    assert_eq!(s_edf.completed, 12, "edf: every flight completes");
    assert_eq!(s_edf.expired, 0);

    // The acceptance criterion proper: strictly fewer expired parts under
    // EDF at identical offered load.
    assert!(
        s_edf.expired < s_oldest.expired,
        "EDF must strictly reduce the expired count ({} vs {})",
        s_edf.expired,
        s_oldest.expired
    );
}

#[test]
fn scheduled_sampling_is_bit_identical_to_solo_per_seed() {
    // Mixed burst: same-key requests (admission merge), cross-solver
    // same-grid requests (step-level co-batching), multi-stage solvers,
    // the adaptive rk45, the s-param EI baseline, and the stochastic
    // samplers (whose cursors own an Rng seeded from the request). Every
    // solver is scheduled — there is no blocking path — and every request
    // must still produce exactly the samples its (seed, config) produces
    // solo, bit-for-bit.
    let coord = Coordinator::new(
        CoordinatorConfig { workers: 2, max_batch_samples: 4096, ..Default::default() },
        common::stall_registry(Duration::from_millis(10)),
    );
    let mk = |solver: SolverKind, nfe: usize, n: usize, seed: u64| {
        let mut r = SampleRequest::new("gmm2d", solver, nfe, n);
        r.seed = seed;
        r
    };
    let reqs = vec![
        mk(SolverKind::Tab(3), 10, 16, 1),
        mk(SolverKind::Tab(3), 10, 8, 2), // same key as above: admission merge
        mk(SolverKind::Tab(0), 10, 8, 3), // same grid, different solver: co-batch
        mk(SolverKind::RhoAb(2), 10, 8, 4),
        mk(SolverKind::Dpm(2), 10, 8, 5),
        mk(SolverKind::Ipndm(3), 10, 8, 6),
        mk(SolverKind::Pndm, 15, 8, 7),
        mk(SolverKind::Euler, 10, 8, 8),
        mk(SolverKind::RhoHeun, 10, 8, 9),      // fixed-stage ρRK cursor
        mk(SolverKind::EiScore, 10, 8, 10),     // s-param EI cursor
        mk(SolverKind::Rk45, 10, 8, 11),        // adaptive cursor
        mk(SolverKind::EulerMaruyama, 10, 8, 12), // stochastic cursor
        mk(SolverKind::ADdim, 10, 8, 13),       // stochastic cursor
    ];
    let expected: Vec<Vec<f64>> = reqs.iter().map(solo_samples).collect();
    let rxs: Vec<_> = reqs.iter().map(|r| coord.submit(r.clone())).collect();
    for ((req, rx), want) in reqs.iter().zip(rxs).zip(&expected) {
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(
            &got.samples, want,
            "scheduled vs solo samples differ for {:?} seed {}",
            req.solver, req.seed
        );
        assert!(got.co_batched >= 1, "every solver reports co_batched now");
    }
    let s = coord.stats();
    assert_eq!(s.completed, 13);
    assert!(
        s.plan_cache_misses > 0 && s.plan_cache_hits > 0,
        "the tab3 pair shares one plan (hit); distinct configs build (misses): \
         hits {} misses {}",
        s.plan_cache_hits,
        s.plan_cache_misses
    );
    coord.shutdown();
}
