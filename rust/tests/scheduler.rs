//! Step-level scheduler integration: concurrent TCP clients whose
//! ε-evaluations get merged across requests, observable through the stats
//! endpoint, plus the bit-exactness guarantee — batched-scheduled sampling
//! must equal solo sampling per (seed, config).

mod common;

use std::sync::Arc;
use std::time::Duration;

use deis::coordinator::{Coordinator, CoordinatorConfig, SampleRequest};
use deis::server::{serve, Client};
use deis::solvers::{self, SolverKind};
use deis::timegrid;
use deis::util::json::Json;
use deis::util::rng::Rng;

/// Reference: the exact samples request `req` must produce, computed
/// without the coordinator (same prior stream, same solver, solo batch).
fn solo_samples(req: &SampleRequest) -> Vec<f64> {
    let model = common::oracle();
    let steps = req.solver.steps_for_nfe(req.nfe);
    let grid = timegrid::build(req.grid, &req.sde, req.t0, 1.0, steps);
    let solver = solvers::build(req.solver, &req.sde, &grid);
    let d = model.dim();
    let mut rng = Rng::new(req.seed);
    let prior = req.sde.prior_std(1.0);
    let mut x = vec![0.0; req.n_samples * d];
    for v in x.iter_mut() {
        *v = prior * rng.normal();
    }
    let mut srng = Rng::new(req.seed ^ 0xD1F_F051);
    solver.sample(&model, &mut x, req.n_samples, &mut srng);
    x
}

#[test]
fn concurrent_clients_with_mixed_nfes_merge_evals_over_tcp() {
    // One worker + a 40ms eval stall: every client that submits during the
    // stall is admitted in the same scheduler tick. All trajectories start
    // at t_N = T regardless of NFE, so even the different-NFE flights merge
    // their first eval, and the same-config pairs stay merged throughout.
    let coord = Arc::new(Coordinator::new(
        CoordinatorConfig { workers: 1, max_batch_samples: 4096, ..Default::default() },
        common::stall_registry(Duration::from_millis(40)),
    ));
    let addr = serve(coord, "127.0.0.1:0").unwrap();

    // Pre-connect so client threads only need to write one line during the
    // stall window.
    let mut warm_client = Client::connect(addr).unwrap();
    let clients: Vec<Client> = (0..6).map(|_| Client::connect(addr).unwrap()).collect();

    // Occupy the worker: its first eval stalls 40ms with the queue open.
    let warm = std::thread::spawn(move || {
        warm_client
            .call(&Json::parse(r#"{"model":"gmm2d","solver":"ddim","nfe":2,"n":4}"#).unwrap())
            .unwrap()
    });
    // Give the warm request time to reach the worker.
    std::thread::sleep(Duration::from_millis(10));

    let nfes = [6usize, 6, 8, 8, 10, 12];
    let mut handles = Vec::new();
    for (i, mut c) in clients.into_iter().enumerate() {
        let nfe = nfes[i];
        handles.push(std::thread::spawn(move || {
            let req = format!(
                r#"{{"model":"gmm2d","solver":"tab2","nfe":{nfe},"n":8,"seed":{i}}}"#
            );
            c.call(&Json::parse(&req).unwrap()).unwrap()
        }));
    }
    let responses: Vec<Json> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(warm.join().unwrap().get("ok").unwrap().as_bool().unwrap());
    for r in &responses {
        assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
    }
    // The same-NFE pairs admission-merge; their evals then co-batch.
    let max_co = responses
        .iter()
        .map(|r| r.get("co_batched").unwrap().as_f64().unwrap() as usize)
        .max()
        .unwrap();
    assert!(max_co > 1, "no cross-request eval batching observed");

    // The stats endpoint must prove evals were merged: occupancy > 1.
    let mut c = Client::connect(addr).unwrap();
    let stats = c.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
    assert_eq!(stats.get("completed").unwrap().as_f64().unwrap() as usize, 7);
    let sched_evals = stats.get("sched_evals").unwrap().as_f64().unwrap();
    assert!(sched_evals > 0.0, "scheduler dispatched no merged evals");
    let occupancy = stats.get("eval_occupancy").unwrap().as_f64().unwrap();
    assert!(
        occupancy > 1.0,
        "stats endpoint must show cross-request merging (eval_occupancy {occupancy})"
    );
    assert!(stats.get("max_occupancy").unwrap().as_f64().unwrap() >= 2.0);
    // The shared plan cache is observable over the wire. (Hits are not
    // asserted here: concurrent submissions of one config may race into
    // two builds, which the cache counts as two misses by design.)
    assert!(
        stats.get("plan_cache_misses").unwrap().as_f64().unwrap() >= 1.0,
        "plan cache misses must be reported in server stats"
    );
    assert!(stats.get("plan_cache_hits").is_ok(), "plan_cache_hits key must exist");
}

#[test]
fn scheduled_sampling_is_bit_identical_to_solo_per_seed() {
    // Mixed burst: same-key requests (admission merge), cross-solver
    // same-grid requests (step-level co-batching), multi-stage solvers,
    // the adaptive rk45, the s-param EI baseline, and the stochastic
    // samplers (whose cursors own an Rng seeded from the request). Every
    // solver is scheduled — there is no blocking path — and every request
    // must still produce exactly the samples its (seed, config) produces
    // solo, bit-for-bit.
    let coord = Coordinator::new(
        CoordinatorConfig { workers: 2, max_batch_samples: 4096, ..Default::default() },
        common::stall_registry(Duration::from_millis(10)),
    );
    let mk = |solver: SolverKind, nfe: usize, n: usize, seed: u64| {
        let mut r = SampleRequest::new("gmm2d", solver, nfe, n);
        r.seed = seed;
        r
    };
    let reqs = vec![
        mk(SolverKind::Tab(3), 10, 16, 1),
        mk(SolverKind::Tab(3), 10, 8, 2), // same key as above: admission merge
        mk(SolverKind::Tab(0), 10, 8, 3), // same grid, different solver: co-batch
        mk(SolverKind::RhoAb(2), 10, 8, 4),
        mk(SolverKind::Dpm(2), 10, 8, 5),
        mk(SolverKind::Ipndm(3), 10, 8, 6),
        mk(SolverKind::Pndm, 15, 8, 7),
        mk(SolverKind::Euler, 10, 8, 8),
        mk(SolverKind::RhoHeun, 10, 8, 9),      // fixed-stage ρRK cursor
        mk(SolverKind::EiScore, 10, 8, 10),     // s-param EI cursor
        mk(SolverKind::Rk45, 10, 8, 11),        // adaptive cursor
        mk(SolverKind::EulerMaruyama, 10, 8, 12), // stochastic cursor
        mk(SolverKind::ADdim, 10, 8, 13),       // stochastic cursor
    ];
    let expected: Vec<Vec<f64>> = reqs.iter().map(solo_samples).collect();
    let rxs: Vec<_> = reqs.iter().map(|r| coord.submit(r.clone())).collect();
    for ((req, rx), want) in reqs.iter().zip(rxs).zip(&expected) {
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(
            &got.samples, want,
            "scheduled vs solo samples differ for {:?} seed {}",
            req.solver, req.seed
        );
        assert!(got.co_batched >= 1, "every solver reports co_batched now");
    }
    let s = coord.stats();
    assert_eq!(s.completed, 13);
    assert!(
        s.plan_cache_misses > 0 && s.plan_cache_hits > 0,
        "the tab3 pair shares one plan (hit); distinct configs build (misses): \
         hits {} misses {}",
        s.plan_cache_hits,
        s.plan_cache_misses
    );
    coord.shutdown();
}
