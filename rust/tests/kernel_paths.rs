//! Kernel-path numeric contract, pinned end to end (ISSUE 7 acceptance):
//!
//!   1. `KernelPath::Tiled` is BIT-IDENTICAL to `KernelPath::Reference` —
//!      the pre-refactor scalar kernel kept verbatim as the baseline — for
//!      every kernel variant, at the kernel level AND through a full native
//!      forward AND a full multi-step solver trajectory.
//!   2. `KernelPath::Fma` (where the CPU has AVX2+FMA) tracks the scalar
//!      paths within a few ulps; fused multiply-adds skip intermediate
//!      roundings, so it is its own numeric class and bit-equality is not
//!      claimed for it.
//!
//! Everything lives in ONE #[test]: the engine-level comparisons steer the
//! auto-dispatched path with the process-global `force_kernel_path`, which
//! must not race with other tests in the same binary.

mod common;

use deis::diffusion::Sde;
use deis::score::{EpsModel, NativeMlp};
use deis::solvers::{self, SolverKind};
use deis::tensor::{
    fma_supported, force_kernel_path, Kernel, KernelPath, Mat,
};
use deis::timegrid::{build, GridKind};
use deis::util::json::Json;
use deis::util::rng::Rng;

/// Every kernel variant the engine's forward pass can issue.
const KERNELS: [Kernel; 5] = [
    Kernel::overwrite(),
    Kernel::overwrite_gelu(),
    Kernel::accumulate(),
    Kernel::accumulate_gelu(),
    Kernel::gelu_residual(),
];

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: element {i} differs: {x} vs {y}"
        );
    }
}

/// Run one full solver trajectory on the CURRENT auto-dispatched path.
fn trajectory(net: &NativeMlp, kind: SolverKind, steps: usize, n: usize) -> Vec<f64> {
    let sde = Sde::vp();
    let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, steps);
    let solver = solvers::build(kind, &sde, &grid);
    let d = net.dim();
    let mut rng = Rng::new(41);
    let prior = sde.prior_std(1.0);
    let mut x = vec![0.0; n * d];
    for v in x.iter_mut() {
        *v = prior * rng.normal();
    }
    let mut srng = Rng::new(41 ^ 0xD1F_F051);
    solver.sample(net, &mut x, n, &mut srng);
    assert!(x.iter().all(|v| v.is_finite()), "{} diverged", solver.name());
    x
}

#[test]
fn tiled_path_is_bit_identical_to_the_reference_scalar_kernel() {
    // ---- 1. kernel level: explicit paths, every variant, ragged shapes ----
    // Shapes straddle the MR=4 / NR=8 tile boundaries in both directions.
    let mut rng = Rng::new(7);
    for (b, k, n) in [(1, 1, 1), (4, 8, 8), (5, 7, 9), (13, 5, 17), (64, 32, 24)] {
        let x = rng.normal_vec(b * k);
        let w = Mat::from_rows(k, n, rng.normal_vec(k * n));
        let bias = rng.normal_vec(n);
        let base = rng.normal_vec(b * n);
        for kern in KERNELS {
            let mut o_ref = base.clone();
            kern.run_with(KernelPath::Reference, &x, k, &w, &bias, &mut o_ref);
            let mut o_tiled = base.clone();
            kern.run_with(KernelPath::Tiled, &x, k, &w, &bias, &mut o_tiled);
            assert_bits_eq(&o_ref, &o_tiled, &format!("{kern:?} @ ({b},{k},{n})"));
            if fma_supported() {
                let mut o_fma = base.clone();
                kern.run_with(KernelPath::Fma, &x, k, &w, &bias, &mut o_fma);
                for (a, f) in o_ref.iter().zip(&o_fma) {
                    let tol = 1e-11 * (1.0 + a.abs());
                    assert!((a - f).abs() < tol, "{kern:?}: {a} vs {f} (fma)");
                }
            }
        }
    }

    // ---- 2. engine level: full forward under the forced global path ------
    // hidden=24 and b=21 are deliberately NOT multiples of the tile sizes.
    let net = NativeMlp::from_json(&Json::parse(&common::weights_json(3, 24, 8, 2)).unwrap())
        .unwrap();
    let b = 21;
    let x = rng.normal_vec(b * 3);
    let t_uniform = vec![0.35; b];
    let t_generic: Vec<f64> = (0..b).map(|_| rng.uniform_in(0.01, 1.0)).collect();
    for (label, t) in [("uniform-t", &t_uniform), ("generic-t", &t_generic)] {
        let mut eval_on = |path: KernelPath| {
            force_kernel_path(Some(path));
            let mut out = vec![0.0; b * 3];
            net.eval(&x, t, b, &mut out);
            out
        };
        let o_ref = eval_on(KernelPath::Reference);
        let o_tiled = eval_on(KernelPath::Tiled);
        assert_bits_eq(&o_ref, &o_tiled, &format!("forward ({label})"));
        if fma_supported() {
            let o_fma = eval_on(KernelPath::Fma);
            for (a, f) in o_ref.iter().zip(&o_fma) {
                let tol = 1e-10 * (1.0 + a.abs());
                assert!((a - f).abs() < tol, "forward ({label}) fma: {a} vs {f}");
            }
        }
    }

    // ---- 3. trajectory level: multi-step solver runs stay bit-identical --
    // Error through a trajectory would amplify any kernel difference; bit
    // equality here is the strongest full-stack statement of the contract.
    for kind in [SolverKind::Tab(3), SolverKind::RhoHeun] {
        force_kernel_path(Some(KernelPath::Reference));
        let x_ref = trajectory(&net, kind, 10, 16);
        force_kernel_path(Some(KernelPath::Tiled));
        let x_tiled = trajectory(&net, kind, 10, 16);
        assert_bits_eq(&x_ref, &x_tiled, &format!("{kind:?} trajectory"));
        if fma_supported() {
            force_kernel_path(Some(KernelPath::Fma));
            let x_fma = trajectory(&net, kind, 10, 16);
            for (a, f) in x_ref.iter().zip(&x_fma) {
                // Per-eval FMA deltas are ~1e-13; 10 solver steps through a
                // mild (small-weight) net amplify them only modestly.
                let tol = 1e-8 * (1.0 + a.abs());
                assert!((a - f).abs() < tol, "{kind:?} fma trajectory: {a} vs {f}");
            }
        }
    }

    force_kernel_path(None);
}
