//! Router-tier integration battery: rendezvous placement, bit-exact proxy
//! parity (JSON and binary frames), stats/health fan-in, worker death
//! mid-flight, drain behind the router, error-text parity with the worker
//! frontend, and the `--spawn-workers` end-to-end path.
//!
//! Workers are real in-process servers over the analytic oracles (no
//! artifacts); the router is the real `deis::router` event loop. The one
//! synthetic piece is the kill test's stub worker — a raw listener whose
//! accepted connection we sever on cue, the only way to make "worker dies
//! with a request in flight" deterministic.

mod common;

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use deis::coordinator::{Coordinator, CoordinatorConfig, ModelRegistry};
use deis::router::{self, hash, RouterOptions};
use deis::server::{serve, Client};
use deis::util::json::Json;

/// One in-process worker over the three-mixture registry (gmm2d / ring6 /
/// ring5, each a DIFFERENT analytic mixture — wrong-shard routing shows up
/// as bit-level sample divergence, not just a wrong counter).
fn boot_worker(stall: Duration) -> (SocketAddr, Arc<Coordinator>) {
    let coord = Arc::new(Coordinator::new(
        CoordinatorConfig { workers: 2, ..Default::default() },
        common::multi_stall_registry(stall),
    ));
    let addr = serve(coord.clone(), "127.0.0.1:0").unwrap();
    (addr, coord)
}

fn boot_fleet(n: usize, stall: Duration) -> (Vec<String>, Vec<Arc<Coordinator>>) {
    let mut names = Vec::new();
    let mut coords = Vec::new();
    for _ in 0..n {
        let (addr, coord) = boot_worker(stall);
        names.push(addr.to_string());
        coords.push(coord);
    }
    (names, coords)
}

fn submit(model: &str, seed: u64, bin: bool) -> Json {
    let frame = if bin { r#","frame":"bin""# } else { "" };
    Json::parse(&format!(
        r#"{{"model":"{model}","solver":"tab3","nfe":8,"n":6,"seed":{seed},"return_samples":true{frame}}}"#
    ))
    .unwrap()
}

#[test]
fn proxied_replies_are_bit_exact_with_direct_ones() {
    let (names, _coords) = boot_fleet(2, Duration::ZERO);
    let raddr = router::serve(names.clone(), "127.0.0.1:0").unwrap();
    let mut via_router = Client::connect(raddr).unwrap();

    for (seed, model) in [(1u64, "gmm2d"), (2, "ring6"), (3, "ring5")] {
        // JSON framing: proxied samples == direct samples == the solo
        // engine replay, bitwise. Timing fields differ by construction, so
        // parity is asserted on the payload and the semantic fields.
        let owner = hash::pick(&names, hash::routing_key(model)).unwrap();
        let mut direct = Client::connect(names[owner].parse().unwrap()).unwrap();
        let p = via_router.call(&submit(model, seed, false)).unwrap();
        let d = direct.call(&submit(model, seed, false)).unwrap();
        assert!(p.get("ok").unwrap().as_bool().unwrap(), "{p:?}");
        let ps = p.get("samples").unwrap().as_f64_vec().unwrap();
        let ds = d.get("samples").unwrap().as_f64_vec().unwrap();
        assert_eq!(ps, ds, "proxied vs direct samples diverged for {model}");
        let solo =
            common::solo_samples(model, deis::solvers::SolverKind::Tab(3), 8, 6, seed);
        assert_eq!(ps, solo, "proxied samples are not the solo engine's for {model}");
        for key in ["ok", "n", "dim", "nfe", "model"] {
            assert_eq!(
                p.opt(key).map(|v| v.to_string()),
                d.opt(key).map(|v| v.to_string()),
                "field '{key}' diverged for {model}"
            );
        }

        // Binary framing: the raw payload must survive the passthrough.
        let (ph, pbin) = via_router.call_bin(&submit(model, seed, true)).unwrap();
        let (_, dbin) = direct.call_bin(&submit(model, seed, true)).unwrap();
        assert!(ph.get("ok").unwrap().as_bool().unwrap(), "{ph:?}");
        assert_eq!(pbin, dbin, "bin payload diverged for {model}");
        assert_eq!(pbin, solo, "bin payload is not the solo engine's for {model}");
    }
}

#[test]
fn rendezvous_concentrates_each_model_on_its_owner() {
    let (names, _coords) = boot_fleet(2, Duration::ZERO);
    let raddr = router::serve(names.clone(), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(raddr).unwrap();

    let models = ["gmm2d", "ring6", "ring5"];
    for (i, model) in models.iter().enumerate() {
        for s in 0..4u64 {
            let r = client.call(&submit(model, 100 + i as u64 * 10 + s, false)).unwrap();
            assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
        }
    }
    let stats = client.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
    let per_worker = stats.get("router").unwrap().get("per_worker").unwrap();

    // Every request for a model must have landed on its rendezvous owner:
    // the routed counts per worker are exactly 4 * (models owned).
    let mut expect = vec![0u64; names.len()];
    for model in models {
        expect[hash::pick(&names, hash::routing_key(model)).unwrap()] += 4;
    }
    for (widx, name) in names.iter().enumerate() {
        let w = per_worker.get(name).unwrap();
        assert_eq!(
            w.get("routed").unwrap().as_u64().unwrap(),
            expect[widx],
            "worker {name} routed count off"
        );
        assert_eq!(w.get("forwarded").unwrap().as_u64().unwrap(), expect[widx]);
        assert_eq!(w.get("upstream_errors").unwrap().as_u64().unwrap(), 0);
    }
    // And the placement is non-trivial with these three models only if
    // both workers own something — if not, the test still proved owner
    // concentration, which is the property under test.
}

#[test]
fn stats_fan_in_sums_exactly_and_models_union() {
    let (names, _coords) = boot_fleet(2, Duration::ZERO);
    let raddr = router::serve(names.clone(), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(raddr).unwrap();

    for (i, model) in ["gmm2d", "ring6", "ring5"].iter().enumerate() {
        for s in 0..(i as u64 + 2) {
            let r = client.call(&submit(model, 500 + s, false)).unwrap();
            assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
        }
    }
    let merged = client.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();

    // Ground truth: each worker's own stats wire, summed by hand.
    let mut sum_requests = 0u64;
    let mut sum_completed = 0u64;
    let mut pm_requests: std::collections::BTreeMap<String, u64> = Default::default();
    for name in &names {
        let mut direct = Client::connect(name.parse().unwrap()).unwrap();
        let s = direct.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
        sum_requests += s.get("requests").unwrap().as_f64().unwrap() as u64;
        sum_completed += s.get("completed").unwrap().as_f64().unwrap() as u64;
        if let Json::Obj(pm) = s.get("per_model").unwrap() {
            for (model, entry) in pm {
                *pm_requests.entry(model.clone()).or_insert(0) +=
                    entry.get("requests").unwrap().as_f64().unwrap() as u64;
            }
        }
    }
    assert_eq!(merged.get("requests").unwrap().as_f64().unwrap() as u64, sum_requests);
    assert_eq!(merged.get("completed").unwrap().as_f64().unwrap() as u64, sum_completed);
    assert_eq!(sum_requests, 2 + 3 + 4, "the workers saw every routed request");
    for (model, expected) in &pm_requests {
        let entry = merged.get("per_model").unwrap().get(model).unwrap();
        assert_eq!(
            entry.get("requests").unwrap().as_f64().unwrap() as u64,
            *expected,
            "per_model '{model}' mismatch"
        );
    }
    let r = merged.get("router").unwrap();
    assert_eq!(r.get("requests").unwrap().as_u64().unwrap(), 9);
    assert_eq!(r.get("forwarded").unwrap().as_u64().unwrap(), 9);
    assert_eq!(r.get("upstream_errors").unwrap().as_u64().unwrap(), 0);
    assert_eq!(r.get("in_flight").unwrap().as_u64().unwrap(), 0);

    // models: sorted union across the fleet (both carry all three here).
    let models = client.call(&Json::parse(r#"{"cmd":"models"}"#).unwrap()).unwrap();
    let list: Vec<String> = match models.get("models").unwrap() {
        Json::Arr(l) => l.iter().map(|m| m.as_str().unwrap().to_string()).collect(),
        other => panic!("not an array: {other:?}"),
    };
    assert_eq!(list, vec!["gmm2d", "ring5", "ring6"]);

    // health: reachable fleet, nothing draining, all models healthy.
    let health = client.call(&Json::parse(r#"{"cmd":"health"}"#).unwrap()).unwrap();
    assert!(health.get("ok").unwrap().as_bool().unwrap());
    assert!(!health.get("draining").unwrap().as_bool().unwrap());
    assert!(health.get("models").unwrap().get("ring6").unwrap().as_bool().unwrap());
}

/// The acceptance-criteria kill test: one of two workers dies with a
/// request in flight. The client must get an error reply (never a hang),
/// the model must re-home to the surviving worker, and every router
/// counter must balance afterwards.
#[test]
fn worker_death_mid_flight_errors_rebalances_and_balances_counters() {
    // Survivor: a real worker carrying synthetic models m0..m15 (the
    // standard ring each — the math is irrelevant here, the NAMES give the
    // rendezvous enough keys that at least one must hash to the victim).
    let mut reg = ModelRegistry::new();
    let model_names: Vec<String> = (0..16).map(|i| format!("m{i}")).collect();
    for name in &model_names {
        reg.insert(name, Arc::new(common::oracle()));
    }
    let coord = Arc::new(Coordinator::new(
        CoordinatorConfig { workers: 2, ..Default::default() },
        reg,
    ));
    let survivor = serve(coord.clone(), "127.0.0.1:0").unwrap();

    // Victim: a stub listener. It accepts, swallows the request, and its
    // connection is severed on cue — a deterministic mid-flight death.
    let stub = TcpListener::bind("127.0.0.1:0").unwrap();
    let stub_addr = stub.local_addr().unwrap();

    let names = vec![survivor.to_string(), stub_addr.to_string()];
    let victim_model = model_names
        .iter()
        .find(|m| hash::pick(&names, hash::routing_key(m)) == Some(1))
        .expect("16 keys over 2 workers: at least one must hash to the victim")
        .clone();

    // Cooldown far beyond the test: the victim must STAY re-homed.
    let opts = RouterOptions { cooldown: Duration::from_secs(60), ..Default::default() };
    let raddr = router::serve_with(names.clone(), "127.0.0.1:0", opts).unwrap();

    // In-flight request toward the victim, from its own client thread.
    let vm = victim_model.clone();
    let stuck = std::thread::spawn(move || {
        let mut c = Client::connect(raddr).unwrap();
        c.call(&submit(&vm, 7, false)).unwrap()
    });

    // Sever the connection only after the request line has arrived, so the
    // death is genuinely mid-flight, then drop the listener too (no
    // reconnect target).
    let (mut conn, _) = stub.accept().unwrap();
    let mut first = [0u8; 1];
    conn.read_exact(&mut first).unwrap();
    drop(conn);
    drop(stub);

    let reply = stuck.join().expect("client must get a reply, not a hang");
    assert!(!reply.get("ok").unwrap().as_bool().unwrap(), "{reply:?}");
    let err = reply.get("error").unwrap().as_str().unwrap().to_string();
    assert!(err.contains("upstream unavailable"), "unexpected error: {err}");
    assert!(err.contains(&victim_model), "error must name the model: {err}");

    // The victim's model re-homes to the survivor and completes there.
    let mut client = Client::connect(raddr).unwrap();
    let rehomed = client.call(&submit(&victim_model, 8, false)).unwrap();
    assert!(rehomed.get("ok").unwrap().as_bool().unwrap(), "{rehomed:?}");
    let solo =
        common::solo_samples("gmm2d", deis::solvers::SolverKind::Tab(3), 8, 6, 8);
    assert_eq!(
        rehomed.get("samples").unwrap().as_f64_vec().unwrap(),
        solo,
        "re-homed request must be served by the survivor's real engine"
    );

    // Counters balance: 2 requests = 1 forwarded + 1 upstream error.
    let stats = client.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
    let r = stats.get("router").unwrap();
    assert_eq!(r.get("requests").unwrap().as_u64().unwrap(), 2);
    assert_eq!(r.get("forwarded").unwrap().as_u64().unwrap(), 1);
    assert_eq!(r.get("upstream_errors").unwrap().as_u64().unwrap(), 1);
    assert_eq!(r.get("in_flight").unwrap().as_u64().unwrap(), 0);
    assert_eq!(r.get("workers_up").unwrap().as_u64().unwrap(), 1);
    assert_eq!(
        r.get("per_model_errors").unwrap().get(&victim_model).unwrap().as_u64().unwrap(),
        1
    );
    let pw = r.get("per_worker").unwrap();
    assert_eq!(
        pw.get(&names[1]).unwrap().get("upstream_errors").unwrap().as_u64().unwrap(),
        1
    );
    assert!(!pw.get(&names[1]).unwrap().get("up").unwrap().as_bool().unwrap());
    assert_eq!(
        pw.get(&names[0]).unwrap().get("forwarded").unwrap().as_u64().unwrap(),
        1
    );
}

#[test]
fn drain_behind_the_router_answers_the_proxied_tail() {
    // One stalling worker: the in-flight request is parked in an eval when
    // the drain flag flips.
    let (names, coords) = boot_fleet(1, Duration::from_millis(60));
    let raddr = router::serve(names, "127.0.0.1:0").unwrap();

    let parked = std::thread::spawn(move || {
        let mut c = Client::connect(raddr).unwrap();
        c.call(&submit("gmm2d", 11, false)).unwrap()
    });
    std::thread::sleep(Duration::from_millis(20));
    coords[0].begin_drain();

    // The parked request completes through the router...
    let reply = parked.join().expect("drained tail must still be answered");
    assert!(reply.get("ok").unwrap().as_bool().unwrap(), "{reply:?}");

    // ...new work is refused (an answered refusal, relayed verbatim)...
    let mut c = Client::connect(raddr).unwrap();
    let refused = c.call(&submit("gmm2d", 12, false)).unwrap();
    assert!(!refused.get("ok").unwrap().as_bool().unwrap(), "{refused:?}");

    // ...and the merged health wire reports the drain.
    let health = c.call(&Json::parse(r#"{"cmd":"health"}"#).unwrap()).unwrap();
    assert!(health.get("draining").unwrap().as_bool().unwrap());
}

/// Raw-socket helper: one line out, one line back.
fn raw_call(addr: SocketAddr, line: &str) -> String {
    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer.write_all(line.as_bytes()).unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    reply
}

#[test]
fn local_error_texts_match_the_worker_frontend_byte_for_byte() {
    let (names, _coords) = boot_fleet(1, Duration::ZERO);
    let waddr: SocketAddr = names[0].parse().unwrap();
    let raddr = router::serve(names.clone(), "127.0.0.1:0").unwrap();

    // Lines the router answers itself must be indistinguishable from the
    // worker's own replies: same parser, same error formatting.
    for line in ["not json\n", "{\"cmd\":\"nope\"}\n", "{\"cmd\":7}\n", "[1,2]\n"] {
        assert_eq!(
            raw_call(raddr, line),
            raw_call(waddr, line),
            "reply diverged for line {line:?}"
        );
    }
    // A submit with no model is the WORKER's error (routed under ""):
    // still byte-identical end to end.
    let no_model = "{\"solver\":\"tab3\",\"nfe\":2,\"n\":4}\n";
    assert_eq!(raw_call(raddr, no_model), raw_call(waddr, no_model));

    // Blank lines get no reply from a worker; the router must skip them
    // too (relaying one would desync the reply FIFO). The next reply on
    // the connection belongs to the submit AFTER the blanks.
    let stream = TcpStream::connect(raddr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer.write_all(b"\n   \n").unwrap();
    writer
        .write_all(format!("{}\n", submit("gmm2d", 21, false)).as_bytes())
        .unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    let v = Json::parse(&reply).unwrap();
    assert!(v.get("ok").unwrap().as_bool().unwrap(), "blank lines desynced: {reply}");
}

/// `deis router --spawn-workers 2` end to end: banner, submit, aggregated
/// stats. The whole process group is killed on exit (workers are children
/// of the router process).
#[test]
fn spawn_workers_end_to_end() {
    use std::os::unix::process::CommandExt;
    use std::process::{Child, Command, Stdio};

    extern "C" {
        fn kill(pid: i32, sig: i32) -> i32;
    }
    /// Kills the router's whole process group (router + spawned workers),
    /// even when an assertion unwinds first.
    struct Fleet(Child);
    impl Drop for Fleet {
        fn drop(&mut self) {
            unsafe { kill(-(self.0.id() as i32), 9) };
            let _ = self.0.wait();
        }
    }

    let mut cmd = Command::new(env!("CARGO_BIN_EXE_deis"));
    cmd.args([
        "router",
        "--spawn-workers",
        "2",
        "--addr",
        "127.0.0.1:0",
        "--models",
        "gmm2d_oracle",
    ]);
    cmd.stdout(Stdio::piped()).stderr(Stdio::null());
    cmd.process_group(0);
    let mut child = cmd.spawn().unwrap();
    let stdout = child.stdout.take().unwrap();
    let fleet = Fleet(child);
    let mut reader = BufReader::new(stdout);
    let mut banner = String::new();
    reader.read_line(&mut banner).unwrap();
    let addr: SocketAddr = banner
        .trim()
        .strip_prefix("deis router on ")
        .unwrap_or_else(|| panic!("bad banner: {banner:?}"))
        .split_whitespace()
        .next()
        .unwrap()
        .parse()
        .unwrap();

    let mut client = Client::connect(addr).unwrap();
    let r = client
        .call(&Json::parse(r#"{"model":"gmm2d_oracle","solver":"tab3","nfe":6,"n":4}"#).unwrap())
        .unwrap();
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");

    let stats = client.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
    let router = stats.get("router").unwrap();
    assert_eq!(router.get("workers").unwrap().as_u64().unwrap(), 2);
    assert_eq!(router.get("requests").unwrap().as_u64().unwrap(), 1);
    assert_eq!(router.get("forwarded").unwrap().as_u64().unwrap(), 1);
    assert_eq!(stats.get("requests").unwrap().as_f64().unwrap() as u64, 1);
    drop(fleet);
}
