//! Chaos battery: a 4-worker multi-model TCP run where three of the four
//! registered models misbehave on script — one panics its first two evals
//! (opening its circuit breaker), one returns NaNs once, one stalls past a
//! request deadline — while a healthy sibling keeps serving.
//!
//! What the battery pins down:
//!   * every client gets exactly one reply (no request hangs or vanishes),
//!   * the 4-term lifecycle balance `requests == completed + rejected +
//!     expired + failed` holds globally AND per model,
//!   * the breaker opens at its threshold, refuses with an "unhealthy"
//!     error without dispatching an eval, and recovers after its cooldown,
//!   * the healthy sibling's samples stay BIT-EXACT against an in-process
//!     solo reference — fault containment means untouched traffic is not
//!     perturbed at all, not merely "still completes".
//!
//! Determinism: each model's `FaultyEps` has its own eval counter and each
//! fault phase drives its model with serialized blocking calls, so the
//! scripted eval indices are hit exactly; the concurrent burst at the end
//! runs entirely off-script.

mod common;

use std::sync::Arc;
use std::time::Duration;

use deis::coordinator::{Coordinator, CoordinatorConfig};
use deis::score::FaultPlan;
use deis::server::{serve, Client};
use deis::solvers::SolverKind;
use deis::util::json::Json;

fn call(c: &mut Client, line: &str) -> Json {
    c.call(&Json::parse(line).unwrap()).unwrap()
}

fn err_text(resp: &Json) -> String {
    assert!(!resp.get("ok").unwrap().as_bool().unwrap(), "expected an error: {resp:?}");
    resp.get("error").unwrap().as_str().unwrap().to_string()
}

#[test]
fn chaos_battery_contains_faults_and_balances_the_lifecycle() {
    // "gmm2d" healthy; "ring6" panics evals #0 and #1; "ring5" NaNs eval
    // #0; "ring7" stalls eval #0 for 150 ms (vs a 40 ms deadline).
    let reg = common::faulty_registry(&[
        ("gmm2d", FaultPlan::new()),
        ("ring6", FaultPlan::new().panic_on(0).panic_on(1)),
        ("ring5", FaultPlan::new().nan_on(0)),
        ("ring7", FaultPlan::new().stall_on(0, 150)),
    ]);
    let coord = Arc::new(Coordinator::new(
        CoordinatorConfig {
            workers: 4,
            max_batch_samples: 512,
            breaker_threshold: 2,
            breaker_cooldown_ms: 200,
            ..Default::default()
        },
        reg,
    ));
    let addr = serve(coord, "127.0.0.1:0").unwrap();
    let mut c = Client::connect(addr).unwrap();

    // Phase 1 — healthy baseline: bit-exact against the solo reference.
    let want = common::solo_samples("gmm2d", SolverKind::Tab(2), 6, 8, 11);
    let resp = call(
        &mut c,
        r#"{"model":"gmm2d","solver":"tab2","nfe":6,"n":8,"seed":11,"return_samples":true}"#,
    );
    assert!(resp.get("ok").unwrap().as_bool().unwrap(), "{resp:?}");
    assert_eq!(
        resp.get("samples").unwrap().as_f64_vec().unwrap(),
        want,
        "healthy model must be bit-exact before any chaos"
    );

    // Phase 2 — panic model: two scripted eval panics are contained as
    // per-request errors and trip the threshold-2 breaker.
    for _ in 0..2 {
        let e = err_text(&call(&mut c, r#"{"model":"ring6","solver":"ddim","nfe":3,"n":4}"#));
        assert!(e.contains("panicked"), "{e}");
    }
    let h = call(&mut c, r#"{"cmd":"health"}"#);
    assert!(
        !h.get("models").unwrap().get("ring6").unwrap().as_bool().unwrap(),
        "breaker must be open after 2 consecutive eval panics: {h:?}"
    );
    // Open circuit: refused at submit — no eval is dispatched, so the
    // FaultyEps counter is NOT advanced and the recovery below still runs
    // the clean eval #2.
    let e = err_text(&call(&mut c, r#"{"model":"ring6","solver":"ddim","nfe":3,"n":4}"#));
    assert!(e.contains("unhealthy"), "{e}");
    // Half-open after the cooldown: a clean eval closes the breaker.
    std::thread::sleep(Duration::from_millis(260));
    let resp = call(&mut c, r#"{"model":"ring6","solver":"ddim","nfe":3,"n":4}"#);
    assert!(resp.get("ok").unwrap().as_bool().unwrap(), "breaker must recover: {resp:?}");
    let h = call(&mut c, r#"{"cmd":"health"}"#);
    assert!(h.get("models").unwrap().get("ring6").unwrap().as_bool().unwrap(), "{h:?}");

    // Phase 3 — NaN model: a non-finite eval fails the request with a
    // clear error; the next request is served normally.
    let e = err_text(&call(&mut c, r#"{"model":"ring5","solver":"ddim","nfe":3,"n":4}"#));
    assert!(e.contains("non-finite"), "{e}");
    let resp = call(&mut c, r#"{"model":"ring5","solver":"ddim","nfe":3,"n":4}"#);
    assert!(resp.get("ok").unwrap().as_bool().unwrap(), "{resp:?}");

    // Phase 4 — stall model: the 150 ms stalled eval overruns the 40 ms
    // deadline; the reply is a deadline error, never late samples.
    let e = err_text(&call(
        &mut c,
        r#"{"model":"ring7","solver":"ddim","nfe":1,"n":4,"deadline_ms":40}"#,
    ));
    assert!(e.contains("deadline"), "{e}");

    // Phase 5 — concurrent off-script burst across all four models on all
    // four workers: every client must get a successful reply, and the
    // healthy model must STILL be bit-exact (fault containment leaves no
    // residue in sibling shards).
    let mut handles = Vec::new();
    for (i, model) in ["gmm2d", "ring6", "ring5", "ring7"].iter().cycle().take(8).enumerate() {
        let model = model.to_string();
        handles.push(std::thread::spawn(move || {
            let mut cl = Client::connect(addr).unwrap();
            let line = format!(
                r#"{{"model":"{model}","solver":"tab2","nfe":6,"n":4,"seed":{}}}"#,
                100 + i
            );
            let resp = cl.call(&Json::parse(&line).unwrap()).unwrap();
            assert!(resp.get("ok").unwrap().as_bool().unwrap(), "{model}: {resp:?}");
        }));
    }
    for handle in handles {
        handle.join().unwrap();
    }
    let resp = call(
        &mut c,
        r#"{"model":"gmm2d","solver":"tab2","nfe":6,"n":8,"seed":11,"return_samples":true}"#,
    );
    assert_eq!(
        resp.get("samples").unwrap().as_f64_vec().unwrap(),
        want,
        "healthy model must stay bit-exact after the chaos"
    );

    // Final accounting: the 4-term lifecycle balance, globally and per
    // model, with every fault attributed exactly once.
    let stats = call(&mut c, r#"{"cmd":"stats"}"#);
    let g = |j: &Json, k: &str| j.get(k).unwrap().as_f64().unwrap() as u64;
    let balance = |j: &Json, who: &str| {
        assert_eq!(
            g(j, "requests"),
            g(j, "completed") + g(j, "rejected") + g(j, "expired") + g(j, "failed"),
            "{who}: lifecycle out of balance: {j:?}"
        );
    };
    balance(&stats, "global");
    // gmm2d: baseline + parity + 2 burst, all completed.
    let pm = stats.get("per_model").unwrap();
    let m = pm.get("gmm2d").unwrap();
    balance(m, "gmm2d");
    assert_eq!(g(m, "completed"), 4);
    assert_eq!(g(m, "failed") + g(m, "expired") + g(m, "rejected"), 0);
    // ring6: 2 contained panics, 1 unhealthy refusal, recovery + 2 burst.
    let m = pm.get("ring6").unwrap();
    balance(m, "ring6");
    assert_eq!(g(m, "failed"), 2);
    assert_eq!(g(m, "eval_panics"), 2);
    assert_eq!(g(m, "rejected"), 1);
    assert_eq!(g(m, "unhealthy"), 1, "the breaker refusal is diagnosed as unhealthy");
    assert_eq!(g(m, "completed"), 3);
    // ring5: 1 non-finite failure, then clean service.
    let m = pm.get("ring5").unwrap();
    balance(m, "ring5");
    assert_eq!(g(m, "failed"), 1);
    assert_eq!(g(m, "eval_panics"), 0, "a NaN eval is a failure, not a panic");
    assert_eq!(g(m, "completed"), 3);
    // ring7: 1 deadline expiry (counted once — not also failed).
    let m = pm.get("ring7").unwrap();
    balance(m, "ring7");
    assert_eq!(g(m, "expired"), 1);
    assert_eq!(g(m, "failed"), 0);
    assert_eq!(g(m, "completed"), 2);
    // Global rollups agree with the per-model sums.
    assert_eq!(g(&stats, "failed"), 3);
    assert_eq!(g(&stats, "eval_panics"), 2);
    assert_eq!(g(&stats, "unhealthy"), 1);
    assert_eq!(g(&stats, "expired"), 1);
}
