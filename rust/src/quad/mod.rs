//! Numerical machinery for the DEIS coefficients:
//!   * Gauss–Legendre quadrature (for the C_ij integrals of Eq. (15) — the
//!     paper: "1-dimensional integrations ... easy to evaluate numerically")
//!   * Lagrange basis polynomials (the P_r(t) extrapolation of Eq. (13))
//!
//! Coefficients are computed once per (sde, grid, order) and reused across
//! batches; this module is off the hot path.

/// 32-point Gauss–Legendre nodes/weights on [-1, 1] (computed at first use by
/// Newton iteration on P_32 — avoids a 64-constant table and is exact to
/// f64 precision).
pub fn gauss_legendre(n: usize) -> (Vec<f64>, Vec<f64>) {
    let mut nodes = vec![0.0; n];
    let mut weights = vec![0.0; n];
    let m = n.div_ceil(2);
    for i in 0..m {
        // Initial guess (Abramowitz & Stegun 25.4.30ish).
        let mut x = (std::f64::consts::PI * (i as f64 + 0.75) / (n as f64 + 0.5)).cos();
        for _ in 0..100 {
            let (p, dp) = legendre_and_deriv(n, x);
            let dx = p / dp;
            x -= dx;
            if dx.abs() < 1e-15 {
                break;
            }
        }
        let (_, dp) = legendre_and_deriv(n, x);
        let w = 2.0 / ((1.0 - x * x) * dp * dp);
        nodes[i] = -x;
        nodes[n - 1 - i] = x;
        weights[i] = w;
        weights[n - 1 - i] = w;
    }
    (nodes, weights)
}

/// (P_n(x), P_n'(x)) by the three-term recurrence.
fn legendre_and_deriv(n: usize, x: f64) -> (f64, f64) {
    let (mut p0, mut p1) = (1.0, x);
    for k in 2..=n {
        let kf = k as f64;
        let p2 = ((2.0 * kf - 1.0) * x * p1 - (kf - 1.0) * p0) / kf;
        p0 = p1;
        p1 = p2;
    }
    let dp = n as f64 * (x * p1 - p0) / (x * x - 1.0);
    (p1, dp)
}

/// Precomputed quadrature rule on [-1, 1], mappable to any interval.
#[derive(Clone, Debug)]
pub struct Quadrature {
    nodes: Vec<f64>,
    weights: Vec<f64>,
}

impl Quadrature {
    pub fn gauss(n: usize) -> Quadrature {
        let (nodes, weights) = gauss_legendre(n);
        Quadrature { nodes, weights }
    }

    /// Signed integral of f over [lo, hi] (hi < lo gives the negative).
    pub fn integrate<F: Fn(f64) -> f64>(&self, f: F, lo: f64, hi: f64) -> f64 {
        let mid = 0.5 * (lo + hi);
        let half = 0.5 * (hi - lo);
        let mut acc = 0.0;
        for (x, w) in self.nodes.iter().zip(&self.weights) {
            acc += w * f(mid + half * x);
        }
        half * acc
    }

    /// Panelled integration: split [lo, hi] into `panels` equal pieces (for
    /// integrands with fast-varying weight near t -> 0).
    pub fn integrate_panels<F: Fn(f64) -> f64>(&self, f: F, lo: f64, hi: f64, panels: usize) -> f64 {
        let mut acc = 0.0;
        let h = (hi - lo) / panels as f64;
        for p in 0..panels {
            let a = lo + p as f64 * h;
            acc += self.integrate(&f, a, a + h);
        }
        acc
    }
}

/// Evaluate the j-th Lagrange basis over `nodes` at `x` (Eq. (13) factor).
pub fn lagrange_basis(nodes: &[f64], j: usize, x: f64) -> f64 {
    let mut out = 1.0;
    for (k, &nk) in nodes.iter().enumerate() {
        if k != j {
            out *= (x - nk) / (nodes[j] - nk);
        }
    }
    out
}

/// Exact ∫_{lo}^{hi} ℓ_j(x) dx via the monomial expansion of the basis
/// polynomial (degree ≤ 3 here, so this is well-conditioned). Used for the
/// ρAB coefficients where the integrand is exactly polynomial.
pub fn lagrange_basis_integral(nodes: &[f64], j: usize, lo: f64, hi: f64) -> f64 {
    // Build the coefficients of ℓ_j as a polynomial (lowest degree first).
    let mut coef = vec![1.0];
    let mut denom = 1.0;
    for (k, &nk) in nodes.iter().enumerate() {
        if k == j {
            continue;
        }
        denom *= nodes[j] - nk;
        // multiply coef by (x - nk)
        let mut next = vec![0.0; coef.len() + 1];
        for (d, &c) in coef.iter().enumerate() {
            next[d + 1] += c;
            next[d] -= c * nk;
        }
        coef = next;
    }
    let mut acc = 0.0;
    for (d, &c) in coef.iter().enumerate() {
        // Integer exponent: powi is cheaper than powf and exactly
        // representable (powf goes through exp/ln and can be off by an ulp
        // even for integral powers).
        let p = (d + 1) as i32;
        acc += c / p as f64 * (hi.powi(p) - lo.powi(p));
    }
    acc / denom
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;

    #[test]
    fn gauss_exact_for_high_degree_polys() {
        // n-point GL is exact for degree <= 2n-1.
        let q = Quadrature::gauss(8);
        // f = x^15 on [0, 1]: integral = 1/16.
        let got = q.integrate(|x| x.powi(15), 0.0, 1.0);
        assert!((got - 1.0 / 16.0).abs() < 1e-14, "{got}");
    }

    #[test]
    fn gauss_weights_sum_to_two() {
        for n in [4, 8, 16, 32] {
            let (_, w) = gauss_legendre(n);
            let s: f64 = w.iter().sum();
            assert!((s - 2.0).abs() < 1e-13, "n={n} sum={s}");
        }
    }

    #[test]
    fn integrate_signed_direction() {
        let q = Quadrature::gauss(8);
        let a = q.integrate(|x| x * x, 0.0, 1.0);
        let b = q.integrate(|x| x * x, 1.0, 0.0);
        assert!((a + b).abs() < 1e-15);
        assert!((a - 1.0 / 3.0).abs() < 1e-14);
    }

    #[test]
    fn panels_match_single_for_smooth() {
        let q = Quadrature::gauss(16);
        let f = |x: f64| (5.0 * x).sin() * (-x).exp();
        let one = q.integrate(f, 0.0, 2.0);
        let four = q.integrate_panels(f, 0.0, 2.0, 4);
        assert!((one - four).abs() < 1e-12);
    }

    #[test]
    fn lagrange_partition_of_unity() {
        run_prop("lagrange unity", 3, 50, |rng| {
            let n = 1 + rng.below(4);
            let mut nodes: Vec<f64> = (0..=n).map(|i| i as f64 + 0.3 * rng.uniform()).collect();
            nodes.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
            let x = rng.uniform_in(-1.0, (nodes.len() + 1) as f64);
            let s: f64 = (0..nodes.len()).map(|j| lagrange_basis(&nodes, j, x)).sum();
            assert!((s - 1.0).abs() < 1e-9, "sum {s}");
        });
    }

    #[test]
    fn lagrange_interpolates_nodes() {
        let nodes = [0.1, 0.5, 0.9, 1.4];
        for j in 0..4 {
            for (k, &nk) in nodes.iter().enumerate() {
                let v = lagrange_basis(&nodes, j, nk);
                let want = if j == k { 1.0 } else { 0.0 };
                assert!((v - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn basis_integral_matches_quadrature() {
        run_prop("basis integral", 11, 50, |rng| {
            let n = 1 + rng.below(4);
            let nodes: Vec<f64> =
                (0..n).map(|i| i as f64 * 0.7 + rng.uniform_in(0.01, 0.3)).collect();
            let j = rng.below(n);
            let (lo, hi) = (rng.uniform_in(-1.0, 0.5), rng.uniform_in(0.5, 2.0));
            let exact = lagrange_basis_integral(&nodes, j, lo, hi);
            let q = Quadrature::gauss(16).integrate(|x| lagrange_basis(&nodes, j, x), lo, hi);
            assert!((exact - q).abs() < 1e-10, "{exact} vs {q}");
        });
    }
}
