//! Time discretizations {t_i}, i = 0..N, t_0 = t0 (end), t_N = T (start).
//!
//! The paper finds t0 and the grid shape dominate quality at low NFE
//! (Ingredient 4, App. H.3); every scheme it sweeps is here:
//!   * `Uniform`        — linear in t
//!   * `Quadratic`      — DDIM's suggestion (== PowerT κ=2)
//!   * `PowerT(κ)`      — Eq. (42): power function in t
//!   * `PowerRho(κ)`    — Eq. (43): power function in ρ (κ=7 ≡ EDM/Karras)
//!   * `LogRho`         — Eq. (44): uniform in log ρ (DPM-Solver's choice)

use crate::diffusion::Sde;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GridKind {
    Uniform,
    Quadratic,
    PowerT(f64),
    PowerRho(f64),
    LogRho,
}

impl GridKind {
    pub fn name(&self) -> String {
        match self {
            GridKind::Uniform => "uniform-t".into(),
            GridKind::Quadratic => "quadratic-t".into(),
            GridKind::PowerT(k) => format!("t-power{k}"),
            GridKind::PowerRho(k) => format!("rho-power{k}"),
            GridKind::LogRho => "log-rho".into(),
        }
    }

    /// Stable identity for hashable cache keys (`solvers::cache::PlanKey`):
    /// (variant discriminant, parameter bits). `GridKind` itself cannot be
    /// `Eq`/`Hash` because of the f64 parameters.
    pub fn key_bits(&self) -> (u8, u64) {
        match self {
            GridKind::Uniform => (0, 0),
            GridKind::Quadratic => (1, 0),
            GridKind::PowerT(k) => (2, k.to_bits()),
            GridKind::PowerRho(k) => (3, k.to_bits()),
            GridKind::LogRho => (4, 0),
        }
    }

    pub fn parse(s: &str) -> Option<GridKind> {
        match s {
            "uniform" | "uniform-t" => Some(GridKind::Uniform),
            "quadratic" | "quadratic-t" => Some(GridKind::Quadratic),
            "log-rho" | "logrho" => Some(GridKind::LogRho),
            _ => {
                if let Some(k) = s.strip_prefix("t-power") {
                    k.parse().ok().map(GridKind::PowerT)
                } else if let Some(k) = s.strip_prefix("rho-power") {
                    k.parse().ok().map(GridKind::PowerRho)
                } else {
                    None
                }
            }
        }
    }
}

/// Build the grid: returns t_0..t_N ascending with t_0 = t0, t_N = t_max.
pub fn build(kind: GridKind, sde: &Sde, t0: f64, t_max: f64, n: usize) -> Vec<f64> {
    assert!(n >= 1 && t0 > 0.0 && t0 < t_max, "bad grid spec n={n} t0={t0}");
    let frac = |i: usize| i as f64 / n as f64;
    let mut grid: Vec<f64> = match kind {
        GridKind::Uniform => (0..=n).map(|i| t0 + frac(i) * (t_max - t0)).collect(),
        GridKind::Quadratic => power_t(2.0, t0, t_max, n),
        GridKind::PowerT(k) => power_t(k, t0, t_max, n),
        GridKind::PowerRho(k) => {
            let (r0, r1) = (sde.rho(t0), sde.rho(t_max));
            (0..=n)
                .map(|i| {
                    let r = ((1.0 - frac(i)) * r0.powf(1.0 / k) + frac(i) * r1.powf(1.0 / k))
                        .powf(k);
                    sde.t_of_rho(r)
                })
                .collect()
        }
        GridKind::LogRho => {
            let (l0, l1) = (sde.rho(t0).ln(), sde.rho(t_max).ln());
            (0..=n)
                .map(|i| sde.t_of_rho(((1.0 - frac(i)) * l0 + frac(i) * l1).exp()))
                .collect()
        }
    };
    // Pin the endpoints exactly (inversion round-off otherwise leaks in).
    grid[0] = t0;
    grid[n] = t_max;
    grid
}

fn power_t(k: f64, t0: f64, t_max: f64, n: usize) -> Vec<f64> {
    (0..=n)
        .map(|i| {
            let f = i as f64 / n as f64;
            ((1.0 - f) * t0.powf(1.0 / k) + f * t_max.powf(1.0 / k)).powf(k)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_valid(g: &[f64], t0: f64, t_max: f64) {
        assert_eq!(g[0], t0);
        assert_eq!(*g.last().unwrap(), t_max);
        for w in g.windows(2) {
            assert!(w[1] > w[0], "grid not strictly increasing: {w:?}");
        }
    }

    #[test]
    fn all_kinds_produce_valid_grids() {
        let kinds = [
            GridKind::Uniform,
            GridKind::Quadratic,
            GridKind::PowerT(3.0),
            GridKind::PowerRho(7.0),
            GridKind::LogRho,
        ];
        for sde in [Sde::vp(), Sde::ve()] {
            let t0 = sde.t0_default();
            for kind in kinds {
                for n in [1, 2, 5, 10, 50] {
                    let g = build(kind, &sde, t0, 1.0, n);
                    assert_eq!(g.len(), n + 1);
                    check_valid(&g, t0, 1.0);
                }
            }
        }
    }

    #[test]
    fn quadratic_refines_near_zero() {
        let g = build(GridKind::Quadratic, &Sde::vp(), 1e-3, 1.0, 10);
        let first = g[1] - g[0];
        let last = g[10] - g[9];
        assert!(first < last / 3.0, "first {first} last {last}");
    }

    #[test]
    fn quadratic_equals_power2() {
        let a = build(GridKind::Quadratic, &Sde::vp(), 1e-3, 1.0, 7);
        let b = build(GridKind::PowerT(2.0), &Sde::vp(), 1e-3, 1.0, 7);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn parse_roundtrip() {
        for s in ["uniform", "quadratic", "t-power3", "rho-power7", "log-rho"] {
            assert!(GridKind::parse(s).is_some(), "{s}");
        }
        assert!(GridKind::parse("nope").is_none());
    }

    #[test]
    fn key_bits_distinguish_kinds_and_params() {
        let kinds = [
            GridKind::Uniform,
            GridKind::Quadratic,
            GridKind::PowerT(2.0),
            GridKind::PowerT(3.0),
            GridKind::PowerRho(7.0),
            GridKind::LogRho,
        ];
        for (i, a) in kinds.iter().enumerate() {
            for (j, b) in kinds.iter().enumerate() {
                if i == j {
                    assert_eq!(a.key_bits(), b.key_bits());
                } else {
                    assert_ne!(a.key_bits(), b.key_bits(), "{a:?} vs {b:?}");
                }
            }
        }
    }

    #[test]
    fn log_rho_uniform_in_log_rho() {
        let sde = Sde::vp();
        let g = build(GridKind::LogRho, &sde, 1e-3, 1.0, 8);
        let logs: Vec<f64> = g.iter().map(|&t| sde.rho(t).ln()).collect();
        let d0 = logs[1] - logs[0];
        for w in logs.windows(2) {
            assert!(((w[1] - w[0]) / d0 - 1.0).abs() < 1e-6);
        }
    }
}
