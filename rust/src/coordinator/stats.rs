//! Serving counters + latency aggregation (lock-free on the hot path).
//!
//! Counter glossary (see also the wire-protocol doc in `server`):
//!   * `requests` / `completed` / `rejected` / `expired` — request lifecycle.
//!     `rejected` counts refusals at submit (backpressure overload and
//!     out-of-range nfe); `expired` counts per-request deadlines that fired
//!     before completion.
//!   * `batches` / `merged_requests` — admission-time merging: one batch is
//!     one trajectory group (requests stacked into a shared state matrix).
//!   * `model_evals` — ε-model calls actually dispatched. Every solver is
//!     scheduled (cursorization is universal), so one merged call can serve
//!     many trajectory groups at once.
//!   * `sched_evals` / `sched_eval_requests` — the step-level scheduler's
//!     merged dispatches and how many client requests each one served.
//!     Their ratio (`eval_occupancy` in the snapshot) is the headline
//!     cross-request batching win: occupancy k means each network call was
//!     amortized over k requests. `max_occupancy` is the observed peak.
//!   * `plan_cache_hits` / `plan_cache_misses` — shared solver-plan cache
//!     (`solvers::cache`): a hit means admission reused a previously built
//!     (grid, coefficients) plan; a miss means the submitting thread built
//!     one. In the steady state of a serving workload hits dominate and no
//!     coefficient work happens anywhere near the coordinator mutex.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Default)]
pub struct Stats {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub expired: AtomicU64,
    pub samples: AtomicU64,
    pub batches: AtomicU64,
    pub merged_requests: AtomicU64,
    pub model_evals: AtomicU64,
    pub sched_evals: AtomicU64,
    pub sched_eval_requests: AtomicU64,
    pub max_occupancy: AtomicU64,
    pub plan_cache_hits: AtomicU64,
    pub plan_cache_misses: AtomicU64,
    latencies_us: Mutex<Vec<u64>>, // end-to-end per request
}

#[derive(Clone, Debug, Default)]
pub struct StatsSnapshot {
    pub requests: u64,
    pub completed: u64,
    pub rejected: u64,
    pub expired: u64,
    pub samples: u64,
    pub batches: u64,
    pub merged_requests: u64,
    pub model_evals: u64,
    pub sched_evals: u64,
    pub sched_eval_requests: u64,
    /// Mean requests served per scheduled ε-eval (0 if none ran yet).
    pub eval_occupancy: f64,
    pub max_occupancy: u64,
    pub plan_cache_hits: u64,
    pub plan_cache_misses: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub mean_us: f64,
}

impl Stats {
    pub fn record_latency(&self, us: u64) {
        self.latencies_us.lock().unwrap().push(us);
    }

    /// Record one scheduler-merged ε-eval that served `requests` client
    /// requests in a single model call.
    pub fn record_sched_eval(&self, requests: u64) {
        self.sched_evals.fetch_add(1, Ordering::Relaxed);
        self.sched_eval_requests.fetch_add(requests, Ordering::Relaxed);
        self.max_occupancy.fetch_max(requests, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        let mut lat = self.latencies_us.lock().unwrap().clone();
        lat.sort_unstable();
        let pct = |p: f64| -> u64 {
            if lat.is_empty() {
                0
            } else {
                lat[((lat.len() - 1) as f64 * p).ceil() as usize]
            }
        };
        let sched_evals = self.sched_evals.load(Ordering::Relaxed);
        let sched_eval_requests = self.sched_eval_requests.load(Ordering::Relaxed);
        StatsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            samples: self.samples.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            merged_requests: self.merged_requests.load(Ordering::Relaxed),
            model_evals: self.model_evals.load(Ordering::Relaxed),
            sched_evals,
            sched_eval_requests,
            eval_occupancy: if sched_evals == 0 {
                0.0
            } else {
                sched_eval_requests as f64 / sched_evals as f64
            },
            max_occupancy: self.max_occupancy.load(Ordering::Relaxed),
            plan_cache_hits: self.plan_cache_hits.load(Ordering::Relaxed),
            plan_cache_misses: self.plan_cache_misses.load(Ordering::Relaxed),
            p50_us: pct(0.5),
            p99_us: pct(0.99),
            mean_us: if lat.is_empty() {
                0.0
            } else {
                lat.iter().sum::<u64>() as f64 / lat.len() as f64
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_percentiles() {
        let s = Stats::default();
        for v in [10, 20, 30, 40, 1000] {
            s.record_latency(v);
        }
        s.requests.store(5, Ordering::Relaxed);
        let snap = s.snapshot();
        assert_eq!(snap.p50_us, 30);
        assert_eq!(snap.p99_us, 1000);
        assert_eq!(snap.requests, 5);
        assert!((snap.mean_us - 220.0).abs() < 1e-9);
    }

    #[test]
    fn occupancy_aggregates() {
        let s = Stats::default();
        assert_eq!(s.snapshot().eval_occupancy, 0.0);
        s.record_sched_eval(1);
        s.record_sched_eval(3);
        s.record_sched_eval(2);
        let snap = s.snapshot();
        assert_eq!(snap.sched_evals, 3);
        assert_eq!(snap.sched_eval_requests, 6);
        assert!((snap.eval_occupancy - 2.0).abs() < 1e-12);
        assert_eq!(snap.max_occupancy, 3);
    }
}
