//! Serving counters + latency aggregation (lock-free on the hot path).
//!
//! Counter glossary (see also the wire-protocol doc in `server`):
//!   * `requests` / `completed` / `rejected` / `expired` / `failed` —
//!     request lifecycle. `rejected` counts refusals at submit (backpressure
//!     overload — global or per-model — plus out-of-range nfe, unknown model
//!     names, invalid sampling configurations, circuit-breaker refusals and
//!     drain-time refusals); `expired` counts per-request deadlines that
//!     fired before completion; `failed` counts requests that were admitted
//!     but could not be completed (a panicking or non-finite ε-eval, a
//!     panicking cursor, or work abandoned by a forced shutdown). The
//!     lifecycle therefore balances: every submitted request lands in
//!     exactly one of `completed`/`rejected`/`expired`/`failed`.
//!   * `deadline_hit` / `deadline_missed` — the deadline-carrying subset
//!     of the lifecycle: a `deadline_hit` is a completed request that was
//!     submitted with `deadline_ms` and delivered in time; a
//!     `deadline_missed` is counted at every site that counts `expired`
//!     (queue expiry, the slotted sweep, delivery re-check, and a failing
//!     flight whose deadline had already fired), so `deadline_missed ==
//!     expired` always and `deadline_hit / (deadline_hit +
//!     deadline_missed)` is the deadline-hit rate. A deadline-carrying
//!     request that is `rejected` or `failed` before its deadline fires
//!     counts in neither.
//!   * `eval_panics` — ε-eval dispatches that panicked (one per panicking
//!     merged call, not per affected request; the affected requests land in
//!     `failed`/`expired`). `unhealthy` — submits refused because the
//!     model's circuit breaker was open (these are also included in
//!     `rejected`, keeping the four-term balance above intact).
//!   * `batches` / `merged_requests` — admission-time merging: one batch is
//!     one trajectory group (requests stacked into a shared state matrix).
//!   * `model_evals` — ε-model calls actually dispatched. Every solver is
//!     scheduled (cursorization is universal), so one merged call can serve
//!     many trajectory groups at once.
//!   * `sched_evals` / `sched_eval_requests` — the step-level scheduler's
//!     merged dispatches and how many client requests each one served.
//!     Their ratio (`eval_occupancy` in the snapshot) is the headline
//!     cross-request batching win: occupancy k means each network call was
//!     amortized over k requests. `max_occupancy` is the observed peak.
//!   * `plan_cache_hits` / `plan_cache_misses` — shared solver-plan cache
//!     (`solvers::cache`): a hit means admission reused a previously built
//!     (grid, coefficients) plan; a miss means the submitting thread built
//!     one. In the steady state of a serving workload hits dominate and no
//!     coefficient work happens anywhere near a shard mutex.
//!
//! The coordinator is sharded by model (one scheduler shard per registered
//! model, see `coordinator/scheduler.rs`), and each shard additionally
//! records its own [`ModelStats`] — the same lifecycle/merging/occupancy
//! counters, scoped to one model. [`StatsSnapshot::per_model`] carries the
//! per-shard snapshots (sorted by model name); the global counters above
//! remain authoritative for the aggregate, and refusals that cannot be
//! attributed to a shard (global-overload rejections, out-of-range nfe,
//! unknown model names) appear only in the global `rejected`.
//!
//! Latency aggregation is a [`LatencyHistogram`]: a fixed array of log-
//! bucketed `AtomicU64` counters, so `record_latency` is three relaxed
//! atomic adds — no mutex, no allocation, no sorting on the delivery path.
//! The old implementation pushed every latency into a `Mutex<Vec<u64>>`,
//! which made request completion serialize on one lock (and `snapshot`
//! clone + sort an unbounded vector). The histogram trades that for a
//! bounded quantile quantization error documented on
//! [`LatencyHistogram::REL_ERROR`]; the mean stays exact and the wire
//! schema (`p50_us`/`p99_us`/`mean_us`) is unchanged.

use std::sync::atomic::{AtomicU64, Ordering};

/// Sub-bucket resolution of the latency histogram: values below
/// `2^LAT_SUB_BITS` are counted exactly; each power-of-two range
/// `[2^m, 2^(m+1))` above that is split into `2^LAT_SUB_BITS` equal
/// sub-buckets.
pub const LAT_SUB_BITS: u32 = 5;
const SUBS: usize = 1 << LAT_SUB_BITS;
/// Bucket count covering the full u64 range: the exact block plus one
/// `SUBS`-wide block per leading-bit position `LAT_SUB_BITS..=63`.
const NUM_BUCKETS: usize = (64 - LAT_SUB_BITS as usize) * SUBS + SUBS;

/// Bucket holding `v`: identity below `SUBS`; otherwise the top
/// `LAT_SUB_BITS + 1` significant bits pick (power-of-two block, sub-bucket).
fn bucket_index(v: u64) -> usize {
    if v < SUBS as u64 {
        v as usize
    } else {
        let m = 63 - v.leading_zeros(); // m >= LAT_SUB_BITS
        let sub = (v >> (m - LAT_SUB_BITS)) as usize - SUBS;
        ((m - LAT_SUB_BITS) as usize + 1) * SUBS + sub
    }
}

/// Midpoint of bucket `idx`'s value range — the representative reported for
/// quantiles. For buckets of width 1 (all values below `2 * SUBS`) this is
/// the value itself.
fn bucket_mid(idx: usize) -> u64 {
    if idx < SUBS {
        return idx as u64;
    }
    let shift = (idx / SUBS - 1) as u32;
    let low = (SUBS as u64 + (idx % SUBS) as u64) << shift;
    low + (1u64 << shift) / 2
}

/// Lock-free log-bucketed histogram for end-to-end request latencies.
///
/// `record` performs three `fetch_add(Relaxed)`s and nothing else — safe to
/// call from any number of delivery threads concurrently. `quantile` walks
/// the fixed bucket array (the cold introspection path).
///
/// Error bound: the reported quantile is the midpoint of the bucket that
/// contains the exact order statistic, so it differs from the exact value
/// by at most one bucket width — a relative error of at most
/// [`Self::REL_ERROR`] (`2^-LAT_SUB_BITS` ≈ 3.1%), and exactly 0 for values
/// below `2^(LAT_SUB_BITS + 1)` = 64 (bucket width 1). The mean is exact:
/// sum and count are tracked directly.
pub struct LatencyHistogram {
    buckets: Box<[AtomicU64; NUM_BUCKETS]>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: Box::new(std::array::from_fn(|_| AtomicU64::new(0))),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    /// Worst-case relative quantization error of `quantile`
    /// (one bucket width, `2^-LAT_SUB_BITS`).
    pub const REL_ERROR: f64 = 1.0 / SUBS as f64;

    /// Record one value. Lock-free; callable concurrently from any thread.
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact mean of all recorded values (0 if none).
    pub fn mean(&self) -> f64 {
        let n = self.count.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Quantile estimate following the same rank rule the sorted-Vec
    /// implementation used (`sorted[ceil((len-1) * p)]`), quantized to the
    /// containing bucket's midpoint (see [`Self::REL_ERROR`]). Returns 0
    /// when nothing has been recorded.
    pub fn quantile(&self, p: f64) -> u64 {
        // One coherent pass over the bucket array; the rank is derived from
        // the same loads so a concurrent `record` cannot push the target
        // rank past the scanned mass.
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((total - 1) as f64 * p).ceil() as u64; // 0-based
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                return bucket_mid(i);
            }
        }
        bucket_mid(NUM_BUCKETS - 1)
    }
}

/// Per-model (per-shard) serving counters: the shard-attributable subset of
/// [`Stats`], recorded by exactly one scheduler shard each — so recording
/// never contends across models. `rejected` here counts only refusals made
/// *after* shard resolution (per-model overload, invalid configurations);
/// global-overload/unknown-model/over-cap-nfe refusals have no shard and
/// live only in the global counters.
#[derive(Default)]
pub struct ModelStats {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub expired: AtomicU64,
    pub failed: AtomicU64,
    pub deadline_hit: AtomicU64,
    pub deadline_missed: AtomicU64,
    pub eval_panics: AtomicU64,
    pub unhealthy: AtomicU64,
    pub samples: AtomicU64,
    pub batches: AtomicU64,
    pub merged_requests: AtomicU64,
    pub model_evals: AtomicU64,
    pub sched_evals: AtomicU64,
    pub sched_eval_requests: AtomicU64,
    pub max_occupancy: AtomicU64,
}

/// Point-in-time copy of one model's [`ModelStats`], carried in
/// [`StatsSnapshot::per_model`] and serialized additively under the
/// `per_model` key of the `{"cmd":"stats"}` wire reply.
#[derive(Clone, Debug, Default)]
pub struct ModelStatsSnapshot {
    pub requests: u64,
    pub completed: u64,
    pub rejected: u64,
    pub expired: u64,
    pub failed: u64,
    pub deadline_hit: u64,
    pub deadline_missed: u64,
    pub eval_panics: u64,
    pub unhealthy: u64,
    pub samples: u64,
    pub batches: u64,
    pub merged_requests: u64,
    pub model_evals: u64,
    pub sched_evals: u64,
    pub sched_eval_requests: u64,
    /// Mean requests served per scheduled ε-eval of this model's shard.
    pub eval_occupancy: f64,
    pub max_occupancy: u64,
}

impl ModelStats {
    /// Record one scheduler-merged ε-eval of this shard that served
    /// `requests` client requests in a single model call.
    pub fn record_sched_eval(&self, requests: u64) {
        self.sched_evals.fetch_add(1, Ordering::Relaxed);
        self.sched_eval_requests.fetch_add(requests, Ordering::Relaxed);
        self.max_occupancy.fetch_max(requests, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> ModelStatsSnapshot {
        let sched_evals = self.sched_evals.load(Ordering::Relaxed);
        let sched_eval_requests = self.sched_eval_requests.load(Ordering::Relaxed);
        ModelStatsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            deadline_hit: self.deadline_hit.load(Ordering::Relaxed),
            deadline_missed: self.deadline_missed.load(Ordering::Relaxed),
            eval_panics: self.eval_panics.load(Ordering::Relaxed),
            unhealthy: self.unhealthy.load(Ordering::Relaxed),
            samples: self.samples.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            merged_requests: self.merged_requests.load(Ordering::Relaxed),
            model_evals: self.model_evals.load(Ordering::Relaxed),
            sched_evals,
            sched_eval_requests,
            eval_occupancy: if sched_evals == 0 {
                0.0
            } else {
                sched_eval_requests as f64 / sched_evals as f64
            },
            max_occupancy: self.max_occupancy.load(Ordering::Relaxed),
        }
    }
}

#[derive(Default)]
pub struct Stats {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub expired: AtomicU64,
    pub failed: AtomicU64,
    pub deadline_hit: AtomicU64,
    pub deadline_missed: AtomicU64,
    pub eval_panics: AtomicU64,
    pub unhealthy: AtomicU64,
    pub samples: AtomicU64,
    pub batches: AtomicU64,
    pub merged_requests: AtomicU64,
    pub model_evals: AtomicU64,
    pub sched_evals: AtomicU64,
    pub sched_eval_requests: AtomicU64,
    pub max_occupancy: AtomicU64,
    pub plan_cache_hits: AtomicU64,
    pub plan_cache_misses: AtomicU64,
    /// End-to-end per-request latency, log-bucketed and lock-free.
    latency_us: LatencyHistogram,
}

#[derive(Clone, Debug, Default)]
pub struct StatsSnapshot {
    pub requests: u64,
    pub completed: u64,
    pub rejected: u64,
    pub expired: u64,
    pub failed: u64,
    pub deadline_hit: u64,
    pub deadline_missed: u64,
    pub eval_panics: u64,
    pub unhealthy: u64,
    pub samples: u64,
    pub batches: u64,
    pub merged_requests: u64,
    pub model_evals: u64,
    pub sched_evals: u64,
    pub sched_eval_requests: u64,
    /// Mean requests served per scheduled ε-eval (0 if none ran yet).
    pub eval_occupancy: f64,
    pub max_occupancy: u64,
    pub plan_cache_hits: u64,
    pub plan_cache_misses: u64,
    /// Bucketed-histogram percentiles: within [`LatencyHistogram::REL_ERROR`]
    /// relative error of the exact order statistics.
    pub p50_us: u64,
    pub p99_us: u64,
    /// Exact mean latency (sum/count, not bucketed).
    pub mean_us: f64,
    /// Per-model shard counters, sorted by model name. Filled by
    /// `Coordinator::stats` (the shard map owns the per-model recorders);
    /// empty on a bare `Stats::snapshot()`.
    pub per_model: Vec<(String, ModelStatsSnapshot)>,
}

impl Stats {
    /// Record one delivered request's end-to-end latency. Lock-free (three
    /// relaxed atomic adds) — the delivery hot path never serializes here.
    pub fn record_latency(&self, us: u64) {
        self.latency_us.record(us);
    }

    /// Record one scheduler-merged ε-eval that served `requests` client
    /// requests in a single model call.
    pub fn record_sched_eval(&self, requests: u64) {
        self.sched_evals.fetch_add(1, Ordering::Relaxed);
        self.sched_eval_requests.fetch_add(requests, Ordering::Relaxed);
        self.max_occupancy.fetch_max(requests, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        let sched_evals = self.sched_evals.load(Ordering::Relaxed);
        let sched_eval_requests = self.sched_eval_requests.load(Ordering::Relaxed);
        StatsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            deadline_hit: self.deadline_hit.load(Ordering::Relaxed),
            deadline_missed: self.deadline_missed.load(Ordering::Relaxed),
            eval_panics: self.eval_panics.load(Ordering::Relaxed),
            unhealthy: self.unhealthy.load(Ordering::Relaxed),
            samples: self.samples.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            merged_requests: self.merged_requests.load(Ordering::Relaxed),
            model_evals: self.model_evals.load(Ordering::Relaxed),
            sched_evals,
            sched_eval_requests,
            eval_occupancy: if sched_evals == 0 {
                0.0
            } else {
                sched_eval_requests as f64 / sched_evals as f64
            },
            max_occupancy: self.max_occupancy.load(Ordering::Relaxed),
            plan_cache_hits: self.plan_cache_hits.load(Ordering::Relaxed),
            plan_cache_misses: self.plan_cache_misses.load(Ordering::Relaxed),
            p50_us: self.latency_us.quantile(0.5),
            p99_us: self.latency_us.quantile(0.99),
            mean_us: self.latency_us.mean(),
            per_model: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;
    use crate::util::rng::Rng;

    #[test]
    fn snapshot_percentiles() {
        // All five values sit in width-1 buckets except 1000, whose bucket
        // [992, 1008) happens to have midpoint exactly 1000 — so the
        // bucketed histogram reproduces the old sorted-Vec answers here.
        let s = Stats::default();
        for v in [10, 20, 30, 40, 1000] {
            s.record_latency(v);
        }
        s.requests.store(5, Ordering::Relaxed);
        let snap = s.snapshot();
        assert_eq!(snap.p50_us, 30);
        assert_eq!(snap.p99_us, 1000);
        assert_eq!(snap.requests, 5);
        assert!((snap.mean_us - 220.0).abs() < 1e-9);
    }

    #[test]
    fn occupancy_aggregates() {
        let s = Stats::default();
        assert_eq!(s.snapshot().eval_occupancy, 0.0);
        s.record_sched_eval(1);
        s.record_sched_eval(3);
        s.record_sched_eval(2);
        let snap = s.snapshot();
        assert_eq!(snap.sched_evals, 3);
        assert_eq!(snap.sched_eval_requests, 6);
        assert!((snap.eval_occupancy - 2.0).abs() < 1e-12);
        assert_eq!(snap.max_occupancy, 3);
    }

    #[test]
    fn per_model_stats_snapshot_and_occupancy() {
        let m = ModelStats::default();
        assert_eq!(m.snapshot().eval_occupancy, 0.0);
        m.record_sched_eval(2);
        m.record_sched_eval(4);
        m.requests.store(6, Ordering::Relaxed);
        let snap = m.snapshot();
        assert_eq!(snap.requests, 6);
        assert_eq!(snap.sched_evals, 2);
        assert_eq!(snap.sched_eval_requests, 6);
        assert!((snap.eval_occupancy - 3.0).abs() < 1e-12);
        assert_eq!(snap.max_occupancy, 4);
        // A bare global snapshot carries no per-model rows; the shard map
        // fills them in `Coordinator::stats`.
        assert!(Stats::default().snapshot().per_model.is_empty());
    }

    #[test]
    fn failure_counters_land_in_snapshots() {
        let s = Stats::default();
        s.failed.fetch_add(3, Ordering::Relaxed);
        s.eval_panics.fetch_add(2, Ordering::Relaxed);
        s.unhealthy.fetch_add(1, Ordering::Relaxed);
        let snap = s.snapshot();
        assert_eq!(snap.failed, 3);
        assert_eq!(snap.eval_panics, 2);
        assert_eq!(snap.unhealthy, 1);

        let m = ModelStats::default();
        m.failed.fetch_add(5, Ordering::Relaxed);
        m.eval_panics.fetch_add(4, Ordering::Relaxed);
        m.unhealthy.fetch_add(6, Ordering::Relaxed);
        let snap = m.snapshot();
        assert_eq!(snap.failed, 5);
        assert_eq!(snap.eval_panics, 4);
        assert_eq!(snap.unhealthy, 6);
    }

    #[test]
    fn bucket_math_edges() {
        // Exact region: identity both ways.
        for v in [0u64, 1, 31, 32, 63] {
            assert_eq!(bucket_mid(bucket_index(v)), v, "width-1 bucket for {v}");
        }
        // Largest value maps to the last bucket, in bounds.
        assert_eq!(bucket_index(u64::MAX), NUM_BUCKETS - 1);
        // Buckets are monotone: a larger value never lands in an earlier
        // bucket, and the midpoint stays within one relative bucket width.
        let mut prev = 0usize;
        for shift in 0..64u32 {
            let v = 1u64 << shift;
            let idx = bucket_index(v);
            assert!(idx >= prev, "bucket order broken at 2^{shift}");
            prev = idx;
            let mid = bucket_mid(idx);
            let err = (mid as f64 - v as f64).abs();
            assert!(
                err <= LatencyHistogram::REL_ERROR * v as f64 + 0.5,
                "2^{shift}: mid {mid} too far from {v}"
            );
        }
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.quantile(0.99), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.count(), 0);
    }

    /// The documented accuracy contract: on random latency sets spanning
    /// the exact region, mid-range log buckets and huge values, the
    /// bucketed p50/p99 are within one bucket's relative error of the exact
    /// sorted-Vec quantiles, and the mean is exact.
    #[test]
    fn prop_bucketed_quantiles_match_exact_within_one_bucket() {
        run_prop("latency histogram accuracy", 31, 60, |rng: &mut Rng| {
            let s = Stats::default();
            let n = 1 + rng.below(300);
            let mut vals: Vec<u64> = Vec::with_capacity(n);
            for _ in 0..n {
                let v = match rng.below(3) {
                    0 => rng.below(64) as u64,        // exact buckets
                    1 => rng.below(5_000_000) as u64, // serving-shaped µs
                    // Any log scale up to 2^40 — large enough to span the
                    // bucket blocks, small enough that the u64 sum (and its
                    // f64 image) stays exact over 300 values.
                    _ => rng.next_u64() >> (24 + rng.below(40) as u32),
                };
                vals.push(v);
                s.record_latency(v);
            }
            let mut sorted = vals.clone();
            sorted.sort_unstable();
            let exact = |p: f64| sorted[((sorted.len() - 1) as f64 * p).ceil() as usize];
            let snap = s.snapshot();
            for (p, got) in [(0.5, snap.p50_us), (0.99, snap.p99_us)] {
                let want = exact(p);
                // got is the midpoint of the bucket containing `want`; the
                // +1 absorbs the integer half-width of width-1/2 buckets.
                let tol = LatencyHistogram::REL_ERROR * want as f64 + 1.0;
                assert!(
                    (got as f64 - want as f64).abs() <= tol,
                    "p{p}: bucketed {got} vs exact {want} (n {n}, tol {tol})"
                );
            }
            let exact_mean =
                vals.iter().map(|&v| v as f64).sum::<f64>() / vals.len() as f64;
            // Same sum, same count: the histogram mean must agree to float
            // roundoff of the summation order, not to bucket resolution.
            assert!(
                (snap.mean_us - exact_mean).abs() <= 1e-9 * exact_mean.max(1.0),
                "mean {} vs exact {exact_mean}",
                snap.mean_us
            );
        });
    }

    /// Concurrent recorders: no count is lost and the totals balance —
    /// the lock-freedom claim, exercised rather than asserted.
    #[test]
    fn concurrent_records_all_land() {
        let s = std::sync::Arc::new(Stats::default());
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    s.record_latency(t * 1000 + i);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.latency_us.count(), 4000);
        let p50 = s.snapshot().p50_us;
        // All values lie in [0, 4000): the median must too.
        assert!(p50 < 4100, "p50 {p50} out of recorded range");
    }
}
