//! Serving counters + latency aggregation (lock-free on the hot path).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Default)]
pub struct Stats {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub samples: AtomicU64,
    pub batches: AtomicU64,
    pub merged_requests: AtomicU64,
    pub model_evals: AtomicU64,
    latencies_us: Mutex<Vec<u64>>, // end-to-end per request
}

#[derive(Clone, Debug, Default)]
pub struct StatsSnapshot {
    pub requests: u64,
    pub completed: u64,
    pub samples: u64,
    pub batches: u64,
    pub merged_requests: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub mean_us: f64,
}

impl Stats {
    pub fn record_latency(&self, us: u64) {
        self.latencies_us.lock().unwrap().push(us);
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        let mut lat = self.latencies_us.lock().unwrap().clone();
        lat.sort_unstable();
        let pct = |p: f64| -> u64 {
            if lat.is_empty() {
                0
            } else {
                lat[((lat.len() - 1) as f64 * p).ceil() as usize]
            }
        };
        StatsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            samples: self.samples.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            merged_requests: self.merged_requests.load(Ordering::Relaxed),
            p50_us: pct(0.5),
            p99_us: pct(0.99),
            mean_us: if lat.is_empty() {
                0.0
            } else {
                lat.iter().sum::<u64>() as f64 / lat.len() as f64
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_percentiles() {
        let s = Stats::default();
        for v in [10, 20, 30, 40, 1000] {
            s.record_latency(v);
        }
        s.requests.store(5, Ordering::Relaxed);
        let snap = s.snapshot();
        assert_eq!(snap.p50_us, 30);
        assert_eq!(snap.p99_us, 1000);
        assert_eq!(snap.requests, 5);
        assert!((snap.mean_us - 220.0).abs() < 1e-9);
    }
}
