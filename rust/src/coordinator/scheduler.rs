//! Step-level cross-request batching scheduler, sharded by model.
//!
//! Step-level co-batching only ever merges ε-evals that share `(model, t)`
//! — cross-model merges are impossible by construction — so scheduler state
//! is partitioned the same way: one [`Shard`] per registered model, each
//! owning its *own* mutex, admission [`Batcher`], flight slots, ready
//! index and deadline sweep. Traffic for model A never takes model B's
//! lock: `Coordinator::submit` routes to the shard through the
//! [`ShardMap`] (a shared read-lock in the steady state; an exclusive lock
//! only on the first sighting of a model, which creates its shard from the
//! registry), and workers *scan* shard load through per-shard atomics
//! without locking, so an idle shard costs nothing and a busy fleet of k
//! models scales its scheduler bookkeeping across k independent mutexes.
//!
//! Within one shard the two-layer merge design is unchanged from the
//! single-state scheduler:
//!
//! * **Admission merge**: requests arriving with an identical batch key
//!   (model, sde, solver, grid, t0, NFE) are stacked into one trajectory
//!   group with per-request prior RNG streams. The [`Batcher`] indexes the
//!   queue by key (per-key FIFO lanes + a nonempty-key list), so popping a
//!   group is O(group), not O(queue).
//! * **Step-level scheduler**: solvers are resumable [`StepCursor`]
//!   machines that *yield* their pending ε-evals; the shard buckets pending
//!   evals from all of its in-flight trajectory groups by `t` (the model is
//!   fixed per shard) and dispatches one merged network call per bucket.
//!   Every cursor eval broadcasts one scalar t, so a merged bucket is
//!   uniform-t and takes the native engine's shared-embedding fast path.
//!   Groups admitted in the same tick with the same grid stay in lockstep
//!   and merge on *every* step, including across different solvers.
//!
//! Scheduling policy per shard ([`SchedPolicy`]): pick the bucket
//! containing the highest-priority trajectory group, cap it at
//! `max_batch_samples`, run the eval, scatter the eps slices back through
//! each cursor and advance it. Under the default `oldest` policy the
//! priority is the group's earliest enqueue time (FIFO fairness keeps
//! lockstep groups together — bit-compatible with the pre-policy
//! scheduler). Under `edf` the priority is the group's earliest part
//! deadline, clamped at `oldest + age_guard` so deadline-less (or
//! far-deadline) groups are never starved past the age guard by a stream
//! of tight-deadline arrivals.
//!
//! # Workers, affinity and stealing
//!
//! Workers are not bound to shards. Each worker has an affinity index —
//! shard `widx % shards` is tried first, which spreads a balanced
//! multi-model fleet across the cores with no cross-shard lock traffic —
//! and a worker that finds its own shard idle **steals** work from the
//! busiest other shard (simple length heuristic over the per-shard `load`
//! atomics: queued requests + slotted flights). A single-model hot spot
//! therefore still uses every core; a balanced fleet runs shard-parallel.
//! Because the scan reads only atomics, a worker never takes the lock of a
//! shard it does not take work from.
//!
//! Admission-merged groups for the *same* shard build concurrently: a
//! worker pops ONE key-merged group under the shard lock, and if more work
//! remains it wakes peers before starting its own off-lock `build_flight`
//! — so a burst of distinct keys on one model fans its prior draws and
//! cursor instantiations across all idle workers instead of serializing on
//! one worker's build loop.
//!
//! # Off-lock execution
//!
//! Each shard mutex guards *routing state only*. Everything whose cost
//! scales with rows·dim runs without it:
//!
//! * **Admission** pops one key-merged group from the shard queue under the
//!   lock, then releases it to draw priors and instantiate the cursor
//!   (`build_flight`), re-locking only to slot the finished flight. The
//!   (grid, coefficients) plan arrived prebuilt on the queue tag via the
//!   shared [`PlanCache`](crate::solvers::cache::PlanCache), resolved in
//!   `Coordinator::submit` on the submitting thread.
//! * **Evals** check member flights *out of their slots* in [`pick_group`]
//!   (they are removed from the flights table entirely, not merely flagged
//!   busy), so the worker owns them: input gather, the merged model call,
//!   the eps scatter, and `cursor.advance()` — the solver's O(rows·dim)
//!   linear combines, and for stochastic cursors the noise draws — all run
//!   lock-free in [`run_group`]. A short re-lock then re-slots each flight
//!   (or routes it to [`complete_flight`] when its trajectory is done).
//!
//! A checked-out flight is invisible to the expiry sweep; the deadline
//! contract holds anyway because it is enforced *at delivery*: a part whose
//! deadline fires while its flight is checked out is caught either by the
//! sweep after the flight re-slots, or by `complete_flight`'s re-check
//! before sending — it always receives an error, never late samples.
//!
//! Backpressure is fully atomic: a request reserves one slot in the global
//! `Shared::inflight_parts` counter (and one in its shard's `inflight`
//! counter, the per-model cap) at submit and releases it when its response
//! is sent — queued, slotted, checked-out and mid-admission parts are all
//! covered by the one reservation, so the overload bound cannot be dodged
//! by catching the scheduler mid-eval, and admission control never takes
//! any lock.
//!
//! # Ready index (per shard)
//!
//! * `buckets`: `pending_t bits -> Vec<slot>` — member gathering is
//!   O(bucket), and a bucket is exactly one merged dispatch candidate.
//!   (The model key the single-state index carried is gone: a shard serves
//!   one model by construction.)
//! * `ready`: a min-heap of `(priority, generation, slot)` — anchor
//!   selection (the shard's highest-priority ready flight under its
//!   [`SchedPolicy`]) is O(log flights) amortized. Entries are lazily
//!   invalidated: each slot carries a generation bumped on every
//!   (re)occupancy, and stale entries are discarded when they surface at
//!   the top.
//! * `free_slots`: vacant slot indices, so admission is a pop instead of a
//!   linear scan for a `None`.
//!
//! The index invariant (checked by the unit tests below): every slotted
//! flight — all of which have a pending eval by construction — appears in
//! exactly the bucket of its `pending_t` and has exactly one live heap
//! entry; buckets and the free list never point at anything else. Flights
//! checked out by a worker are *absent* from slots and index alike; they
//! re-enter through [`ShardState::insert_flight`] which restores the
//! invariant.
//!
//! # Sleep/wake
//!
//! Idle workers park on one global [`WakeRail`] (generation counter +
//! condvar): any publication of work — a queue push, a re-slotted flight, a
//! freshly created shard — bumps the generation, and a worker only sleeps
//! if the generation has not moved since before its scan, so work can never
//! be published into a gap and lost. The rail's fast path (no sleepers) is
//! two atomic ops; no shard lock is ever held while sleeping.
//!
//! # Fault containment
//!
//! Model code is untrusted: an ε-eval (or a solver advance fed by one) may
//! panic, stall, or emit non-finite values, and none of those may take the
//! service down. The off-lock execution region — gather, the merged model
//! call, scatter, `cursor.advance()` — runs under `catch_unwind`: a panic
//! fails every member flight's parts with an error (honouring the deadline
//! contract: an already-expired part counts `expired`, the rest count
//! `failed`), releases their backpressure reservations, re-slots nothing,
//! and bumps `eval_panics`. Non-finite eval output fails exactly the
//! flights whose slices are poisoned; clean siblings in the same merged
//! call proceed untouched. Each shard carries a consecutive-failure
//! [`Breaker`]: after `threshold` consecutive failing evals the shard is
//! marked unhealthy and `Coordinator::submit` refuses its traffic
//! immediately (counted `rejected` + `unhealthy`) until a cooldown passes;
//! the first clean eval after the half-open probe closes it again. Shard
//! mutexes recover from poisoning (`util::sync`) — the state they guard is
//! routing bookkeeping mutated only by short panic-free critical sections —
//! and worker threads run under [`supervised_worker_loop`], which catches
//! any panic that escapes the contained regions and restarts the loop, so
//! a scheduler bug cannot silently eat a worker. The chaos battery
//! (`rust/tests/chaos.rs`) drives all of this with scripted faults and
//! asserts the lifecycle balance `requests == completed + rejected +
//! expired + failed`, globally and per model.
//!
//! # Determinism
//!
//! Unchanged by sharding, because routing moved while the math stayed in
//! the cursors: for deterministic solvers a request's samples depend only
//! on its (seed, n, config) — per-request prior RNG streams, and per-row
//! model math independent of batch composition — so scheduled,
//! admission-merged and solo runs are bit-identical
//! (`rust/tests/scheduler.rs` pins this per model in the multi-model stress
//! battery). Stochastic flights draw noise only inside `advance`, from a
//! cursor-owned stream seeded by the flight's HEAD request, so step-level
//! co-batching with strangers never perturbs the noise. Two caveats, both
//! inherited from the original blocking path: same-config stochastic
//! requests admission-merged in one tick share the head's noise stream, and
//! batch-coupled estimators (A-DDIM's Γ, rk45's RMS error norm) span the
//! merged rows. Which shard, which worker, and which lock regime advanced a
//! flight is unobservable in the output.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock};
use std::time::{Duration, Instant};

use super::batcher::{Batcher, Pending};
use super::request::{SampleRequest, SampleResult};
use super::stats::{ModelStats, ModelStatsSnapshot};
use super::{ModelRegistry, Responder, Shared};
use crate::score::EpsModel;
use crate::solvers::{Solver as _, SolverPlan, StepCursor};
use crate::util::rng::Rng;
use crate::util::sync::{lock_recover, read_recover, wait_recover, write_recover};

/// Queue tag carried through admission: response channel, enqueue time,
/// absolute deadline (if the request set one), and the shared solver plan
/// resolved at submit (so admission does no grid/coefficient work).
pub(crate) type Tag = (Responder, Instant, Option<Instant>, Arc<SolverPlan>);

/// Default EDF starvation guard: a flight is anchored no later than it
/// would be if a deadline fired this long after its earliest enqueue.
pub const DEFAULT_EDF_AGE_GUARD: Duration = Duration::from_millis(250);

/// Anchor-selection policy for the per-shard ready heap (`--sched-policy`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedPolicy {
    /// FIFO fairness: anchor the longest-waiting ready flight. The default,
    /// bit-compatible with the pre-policy scheduler.
    Oldest,
    /// Earliest-deadline-first: anchor the ready flight whose tightest part
    /// deadline fires soonest. Deadline-less (or far-deadline) flights rank
    /// as if a deadline fired `age_guard` after their earliest enqueue, so
    /// a stream of tight-deadline arrivals can delay them by at most the
    /// guard relative to FIFO — never starve them.
    Edf {
        /// Starvation bound for deadline-less parts.
        age_guard: Duration,
    },
}

impl SchedPolicy {
    /// EDF with the default starvation guard.
    pub fn edf() -> SchedPolicy {
        SchedPolicy::Edf { age_guard: DEFAULT_EDF_AGE_GUARD }
    }

    /// Parse a `--sched-policy` value (`oldest` | `edf`).
    pub fn parse(s: &str) -> anyhow::Result<SchedPolicy> {
        match s {
            "oldest" => Ok(SchedPolicy::Oldest),
            "edf" => Ok(SchedPolicy::edf()),
            other => anyhow::bail!("unknown sched policy '{other}' (expected oldest|edf)"),
        }
    }
}

impl Default for SchedPolicy {
    fn default() -> SchedPolicy {
        SchedPolicy::Oldest
    }
}

/// One client request inside a trajectory group.
struct FlightPart {
    n: usize,
    /// First row of this request inside the flight's stacked state matrix.
    /// Fixed at admission: expiring another part must not shift the rows a
    /// surviving request receives.
    row0: usize,
    responder: Responder,
    enqueued: Instant,
    deadline: Option<Instant>,
}

/// An in-flight trajectory group: requests admitted together under one
/// batch key, integrating as one cursor over a stacked state matrix.
///
/// A `Flight` lives in exactly one of two places: a [`ShardState`] slot
/// (pending its next eval, visible to the ready index and the expiry sweep)
/// or checked out by a worker mid-eval (owned, lock-free). The cursor owns
/// every piece of trajectory state, so a checked-out flight needs nothing
/// from the shared state to advance. The model is not stored here: a
/// flight belongs to exactly one shard, which owns the model handle.
struct Flight {
    cursor: Box<dyn StepCursor>,
    parts: Vec<FlightPart>,
    nfe: usize,
    dim: usize,
    /// Total sample rows (sum of part n's).
    rows: usize,
    /// Peak number of requests co-batched with this flight's evals.
    co_batched_peak: usize,
    /// First eval dispatch (queue_us / solve_us split point).
    started: Option<Instant>,
    /// Earliest enqueue time over parts — the FIFO fairness key.
    oldest: Instant,
}

impl Flight {
    /// Ready-heap ordering key under `policy` (smaller anchors first).
    /// `Oldest` reproduces the pre-policy heap key exactly. `Edf` ranks by
    /// the tightest part deadline, clamped at `oldest + age_guard`: the
    /// clamp is both the deadline-less ranking AND the starvation guard —
    /// once a flight has aged past the guard its key is in the past, where
    /// no future deadline can outrank it.
    fn priority(&self, policy: SchedPolicy) -> Instant {
        match policy {
            SchedPolicy::Oldest => self.oldest,
            SchedPolicy::Edf { age_guard } => {
                let guard = self.oldest + age_guard;
                self.parts
                    .iter()
                    .filter_map(|p| p.deadline)
                    .min()
                    .map_or(guard, |d| d.min(guard))
            }
        }
    }
}

/// Circuit-breaker configuration, shared by every shard of a coordinator.
#[derive(Clone, Copy, Debug)]
pub struct BreakerConfig {
    /// Consecutive failing evals that open the breaker. 0 disables it.
    pub threshold: u32,
    /// How long an open breaker refuses traffic before half-opening.
    pub cooldown: Duration,
}

impl Default for BreakerConfig {
    fn default() -> BreakerConfig {
        BreakerConfig { threshold: 5, cooldown: Duration::from_millis(1000) }
    }
}

/// Per-shard consecutive-failure circuit breaker (lock-free).
///
/// Closed → open: `threshold` consecutive failing evals (a panic, or any
/// member flight failed by non-finite output / a panicking advance) set an
/// open-until timestamp; while it is in the future, `Coordinator::submit`
/// refuses the model's traffic immediately instead of queueing work a
/// broken model will burn. Open → half-open: once the cooldown elapses,
/// `is_open` reads false and traffic is admitted again — but the
/// consecutive counter still sits at the threshold, so one more failure
/// re-opens instantly, while the first clean eval (`on_success`) closes
/// the breaker fully.
pub(crate) struct Breaker {
    cfg: BreakerConfig,
    /// Time base for `open_until_ms` (monotonic, per shard).
    epoch: Instant,
    consecutive: AtomicU32,
    /// 0 = not open; otherwise open until `epoch + this many ms`.
    open_until_ms: AtomicU64,
}

impl Breaker {
    pub(crate) fn new(cfg: BreakerConfig) -> Breaker {
        Breaker {
            cfg,
            epoch: Instant::now(),
            consecutive: AtomicU32::new(0),
            open_until_ms: AtomicU64::new(0),
        }
    }

    pub(crate) fn is_open(&self) -> bool {
        let until = self.open_until_ms.load(Ordering::SeqCst);
        until != 0 && (self.epoch.elapsed().as_millis() as u64) < until
    }

    /// Record one failing eval; opens the breaker at the threshold.
    pub(crate) fn on_failure(&self) {
        let n = self.consecutive.fetch_add(1, Ordering::SeqCst).saturating_add(1);
        if self.cfg.threshold > 0 && n >= self.cfg.threshold {
            let until = self.epoch.elapsed().as_millis() as u64
                + (self.cfg.cooldown.as_millis() as u64).max(1);
            self.open_until_ms.store(until, Ordering::SeqCst);
        }
    }

    /// Record one clean eval: closes the breaker and resets the streak.
    pub(crate) fn on_success(&self) {
        self.consecutive.store(0, Ordering::SeqCst);
        self.open_until_ms.store(0, Ordering::SeqCst);
    }

    /// The configured consecutive-failure threshold (for refusal text).
    pub(crate) fn threshold(&self) -> u32 {
        self.cfg.threshold
    }

    #[cfg(test)]
    pub(crate) fn consecutive(&self) -> u32 {
        self.consecutive.load(Ordering::SeqCst)
    }
}

/// One model's scheduler shard: admission queue, flight slots and ready
/// index under the shard's own mutex, plus the lock-free load/backpressure
/// atomics and the per-model stats recorder. Created lazily from the
/// registry on a model's first request; lives for the coordinator's
/// lifetime.
pub(crate) struct Shard {
    pub(crate) name: Arc<str>,
    pub(crate) model: Arc<dyn EpsModel>,
    pub(crate) dim: usize,
    /// Consecutive-failure circuit breaker; consulted lock-free at submit.
    pub(crate) breaker: Breaker,
    state: Mutex<ShardState>,
    /// Approximate pending work (queued requests + slotted flights),
    /// readable WITHOUT the shard lock. Workers scanning for work — their
    /// own shard or a steal target — consult only this, so idle shards see
    /// zero lock traffic from foreign-model activity.
    load: AtomicUsize,
    /// Per-model backpressure reservation (see `Coordinator::submit`):
    /// requests routed to this shard and not yet answered.
    pub(crate) inflight: AtomicUsize,
    pub(crate) stats: ModelStats,
    /// Times this shard's mutex was acquired — the shard-isolation proof
    /// hook: tests drive traffic at model A and assert model B's count
    /// stays frozen.
    #[cfg(test)]
    pub(crate) lock_acquisitions: AtomicU64,
}

impl Shard {
    fn new(
        name: &str,
        model: Arc<dyn EpsModel>,
        max_batch_samples: usize,
        breaker: BreakerConfig,
        policy: SchedPolicy,
    ) -> Shard {
        let dim = model.dim();
        Shard {
            name: Arc::from(name),
            model,
            dim,
            breaker: Breaker::new(breaker),
            state: Mutex::new(ShardState::new(max_batch_samples, policy)),
            load: AtomicUsize::new(0),
            inflight: AtomicUsize::new(0),
            stats: ModelStats::default(),
            #[cfg(test)]
            lock_acquisitions: AtomicU64::new(0),
        }
    }

    /// The only way to the shard's state: counts acquisitions under test so
    /// shard isolation is assertable, not just claimed. Recovers from a
    /// poisoned mutex (see `util::sync`) — critical sections here are short
    /// and panic-free, so a poison mark means a fault elsewhere unwound
    /// through a guard, not that the routing state is torn.
    pub(crate) fn lock(&self) -> MutexGuard<'_, ShardState> {
        #[cfg(test)]
        self.lock_acquisitions.fetch_add(1, Ordering::Relaxed);
        lock_recover(&self.state)
    }

    /// Publish the lock-free load estimate; call before releasing the shard
    /// lock whenever the queue or the slot table changed.
    pub(crate) fn publish_load(&self, st: &ShardState) {
        self.load.store(st.queue.len() + st.slotted, Ordering::Release);
    }

    fn load_hint(&self) -> usize {
        self.load.load(Ordering::Acquire)
    }
}

/// Lock-free-in-the-steady-state router from model name to [`Shard`].
///
/// Shards are created on first use (exclusive lock, once per model name
/// ever); every later request takes only the shared read lock, which never
/// contends with other readers — submit threads and worker rescans route
/// concurrently. Unknown model names create nothing and resolve to `None`.
pub(crate) struct ShardMap {
    inner: RwLock<ShardMapInner>,
    /// Bumped after every shard creation; workers cache the ordered shard
    /// list and refresh it only when this moves.
    version: AtomicU64,
    max_batch_samples: usize,
    breaker: BreakerConfig,
    policy: SchedPolicy,
}

#[derive(Default)]
struct ShardMapInner {
    by_name: HashMap<String, Arc<Shard>>,
    /// Creation order — the worker-affinity ordering.
    ordered: Vec<Arc<Shard>>,
}

impl ShardMap {
    pub(crate) fn new(
        max_batch_samples: usize,
        breaker: BreakerConfig,
        policy: SchedPolicy,
    ) -> ShardMap {
        ShardMap {
            inner: RwLock::new(ShardMapInner::default()),
            version: AtomicU64::new(0),
            max_batch_samples,
            breaker,
            policy,
        }
    }

    /// Resolve `name` to its shard, creating it from the registry on first
    /// sighting. Returns `None` for names the registry does not know (the
    /// unknown-model refusal path — no shard is created for typos).
    pub(crate) fn get_or_create(
        &self,
        name: &str,
        registry: &ModelRegistry,
    ) -> Option<Arc<Shard>> {
        if let Some(s) = read_recover(&self.inner).by_name.get(name) {
            return Some(s.clone());
        }
        let model = registry.get(name)?;
        let mut w = write_recover(&self.inner);
        if let Some(s) = w.by_name.get(name) {
            return Some(s.clone()); // racing creator won; use its shard
        }
        let shard =
            Arc::new(Shard::new(name, model, self.max_batch_samples, self.breaker, self.policy));
        w.by_name.insert(name.to_string(), shard.clone());
        w.ordered.push(shard.clone());
        drop(w);
        self.version.fetch_add(1, Ordering::SeqCst);
        Some(shard)
    }

    /// Refresh `out` with the ordered shard list iff it changed since
    /// `seen` — the worker fast path re-reads nothing in the steady state.
    pub(crate) fn refresh(&self, seen: &mut u64, out: &mut Vec<Arc<Shard>>) {
        let v = self.version.load(Ordering::SeqCst);
        if v != *seen {
            out.clear();
            out.extend(read_recover(&self.inner).ordered.iter().cloned());
            *seen = v;
        }
    }

    /// Every shard created so far, in creation order (drain + health walks).
    pub(crate) fn all(&self) -> Vec<Arc<Shard>> {
        read_recover(&self.inner).ordered.to_vec()
    }

    /// Per-model stats snapshots, sorted by model name.
    pub(crate) fn per_model_snapshots(&self) -> Vec<(String, ModelStatsSnapshot)> {
        let inner = read_recover(&self.inner);
        let mut v: Vec<(String, ModelStatsSnapshot)> = inner
            .ordered
            .iter()
            .map(|s| (s.name.to_string(), s.stats.snapshot()))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Shards created so far (lazy-creation observability).
    #[cfg(test)]
    pub(crate) fn count(&self) -> usize {
        read_recover(&self.inner).ordered.len()
    }

    #[cfg(test)]
    pub(crate) fn get(&self, name: &str) -> Option<Arc<Shard>> {
        read_recover(&self.inner).by_name.get(name).cloned()
    }
}

/// Global sleep/wake rail for scheduler workers. Publications of work bump
/// `gen`; a worker snapshots `gen` before scanning for work and goes to
/// sleep only if it has not moved since — so a publication can never fall
/// into the scan-to-sleep gap. The no-sleeper fast path of [`Self::wake`]
/// is one atomic add + one atomic load.
pub(crate) struct WakeRail {
    gen: AtomicU64,
    waiters: AtomicUsize,
    mx: Mutex<()>,
    cv: Condvar,
}

impl WakeRail {
    pub(crate) fn new() -> WakeRail {
        WakeRail {
            gen: AtomicU64::new(0),
            waiters: AtomicUsize::new(0),
            mx: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    pub(crate) fn generation(&self) -> u64 {
        self.gen.load(Ordering::SeqCst)
    }

    /// Publish work: bump the generation, wake sleepers if any. SeqCst
    /// pairs with [`Self::sleep`]: either the waker sees `waiters > 0` and
    /// notifies under the mutex, or the sleeper's in-mutex generation check
    /// sees the bump and never waits.
    pub(crate) fn wake(&self) {
        self.gen.fetch_add(1, Ordering::SeqCst);
        if self.waiters.load(Ordering::SeqCst) > 0 {
            let _g = lock_recover(&self.mx);
            self.cv.notify_all();
        }
    }

    /// Workers currently parked in [`Self::sleep`]. A worker counts from
    /// just before its in-mutex generation check until just after it
    /// resumes — so `waiters == workers` proves no worker is mid-scan
    /// (test quiescence hook).
    #[cfg(test)]
    pub(crate) fn waiters(&self) -> usize {
        self.waiters.load(Ordering::SeqCst)
    }

    /// Park until the generation moves past `seen` (or shutdown). Spurious
    /// wakeups re-check and re-park.
    pub(crate) fn sleep(&self, seen: u64, shutdown: &std::sync::atomic::AtomicBool) {
        self.waiters.fetch_add(1, Ordering::SeqCst);
        let mut g = lock_recover(&self.mx);
        while self.gen.load(Ordering::SeqCst) == seen && !shutdown.load(Ordering::SeqCst) {
            g = wait_recover(&self.cv, g);
        }
        drop(g);
        self.waiters.fetch_sub(1, Ordering::SeqCst);
    }
}

impl Default for WakeRail {
    fn default() -> Self {
        WakeRail::new()
    }
}

/// One shard's scheduler state under its mutex: the admission queue, the
/// flight slots, and the ready index over them. All bookkeeping here is
/// O(1)/O(log n)/O(bucket) per operation — nothing under the mutex scales
/// with rows·dim or with the total flight count.
pub(crate) struct ShardState {
    /// Admission queue: key-merged by the [`Batcher`] (per-key lanes, so a
    /// pop is O(group)).
    pub(crate) queue: Batcher<Tag>,
    flights: Vec<Option<Flight>>,
    /// Per-slot occupancy generation, bumped on every insert; heap entries
    /// carry the generation they were pushed under, so entries for departed
    /// flights are recognizably stale.
    slot_gen: Vec<u64>,
    /// Vacant slot indices (every `None` in `flights` is here exactly once).
    free_slots: Vec<usize>,
    /// Ready index: `pending_t bits -> slots` pending that eval. The model
    /// is implied by the shard.
    buckets: HashMap<u64, Vec<usize>>,
    /// Min-heap (via `Reverse`) of `(priority, generation, slot)` over
    /// ready flights, keyed by [`Flight::priority`] under `policy`; stale
    /// entries are skipped/discarded lazily at the top.
    ready: BinaryHeap<Reverse<(Instant, u64, usize)>>,
    /// Anchor-selection policy; fixed at shard creation.
    policy: SchedPolicy,
    /// Occupied slots — with `queue.len()`, the shard's published load.
    slotted: usize,
    /// Slotted-or-checked-out parts that carry a deadline. When zero — the
    /// common case — the per-tick expiry sweep exits immediately instead of
    /// walking every slot.
    deadline_parts: usize,
}

impl ShardState {
    pub(crate) fn new(max_batch_samples: usize, policy: SchedPolicy) -> ShardState {
        ShardState {
            queue: Batcher::new(max_batch_samples),
            flights: Vec::new(),
            slot_gen: Vec::new(),
            free_slots: Vec::new(),
            buckets: HashMap::new(),
            ready: BinaryHeap::new(),
            policy,
            slotted: 0,
            deadline_parts: 0,
        }
    }

    /// Slot a pending flight and index it. The one entry point back into
    /// the shard state, used by admission and by workers re-slotting
    /// checked-out flights.
    fn insert_flight(&mut self, f: Flight) {
        let t_bits = f.cursor.pending_t().expect("only pending flights are slotted").to_bits();
        let slot = match self.free_slots.pop() {
            Some(s) => s,
            None => {
                self.flights.push(None);
                self.slot_gen.push(0);
                self.flights.len() - 1
            }
        };
        debug_assert!(self.flights[slot].is_none(), "insert into an occupied slot");
        self.slot_gen[slot] = self.slot_gen[slot].wrapping_add(1);
        self.buckets.entry(t_bits).or_default().push(slot);
        self.ready.push(Reverse((f.priority(self.policy), self.slot_gen[slot], slot)));
        self.flights[slot] = Some(f);
        self.slotted += 1;
    }

    /// Unslot a flight (worker checkout or abort): clears the slot, removes
    /// the bucket entry, reclaims the slot. The flight's heap entry is left
    /// to be discarded lazily (the slot's generation no longer matches once
    /// the slot is reused, and a vacant slot fails the occupancy check).
    fn remove_flight(&mut self, slot: usize) -> Flight {
        let f = self.flights[slot].take().expect("removing an empty slot");
        let t_bits = f.cursor.pending_t().expect("slotted flights are always pending").to_bits();
        if let Some(b) = self.buckets.get_mut(&t_bits) {
            if let Some(pos) = b.iter().position(|&s| s == slot) {
                b.swap_remove(pos);
            }
            if b.is_empty() {
                self.buckets.remove(&t_bits);
            }
        }
        self.free_slots.push(slot);
        self.slotted -= 1;
        f
    }

    /// A heap entry is live iff its slot is occupied by the same occupancy
    /// (generation) it was pushed under.
    fn heap_entry_live(&self, gen: u64, slot: usize) -> bool {
        self.flights[slot].is_some() && self.slot_gen[slot] == gen
    }

    /// Ready-index invariant, used by the unit tests after every mutation:
    /// the index covers exactly the slotted flights (all of which have a
    /// pending t), with one live heap entry each; the free list covers
    /// exactly the vacant slots.
    #[cfg(test)]
    fn assert_ready_invariants(&self) {
        let mut occupied = 0;
        for (slot, f) in self.flights.iter().enumerate() {
            match f {
                Some(f) => {
                    occupied += 1;
                    let t = f.cursor.pending_t().expect("slotted flight must be pending");
                    let b = self
                        .buckets
                        .get(&t.to_bits())
                        .unwrap_or_else(|| panic!("slot {slot} missing from its bucket"));
                    assert_eq!(
                        b.iter().filter(|&&s| s == slot).count(),
                        1,
                        "slot {slot} must appear in its bucket exactly once"
                    );
                    assert_eq!(
                        self.ready
                            .iter()
                            .filter(|Reverse((o, g, s))| *s == slot
                                && *g == self.slot_gen[slot]
                                && *o == f.priority(self.policy))
                            .count(),
                        1,
                        "slot {slot} must have exactly one live heap entry \
                         keyed by the policy priority"
                    );
                    assert!(!self.free_slots.contains(&slot), "occupied slot {slot} on free list");
                }
                None => assert_eq!(
                    self.free_slots.iter().filter(|&&s| s == slot).count(),
                    1,
                    "vacant slot {slot} must be on the free list exactly once"
                ),
            }
        }
        assert_eq!(occupied, self.slotted, "slotted counter out of sync");
        for (t_bits, slots) in &self.buckets {
            assert!(!slots.is_empty(), "empty bucket retained for t bits {t_bits}");
            for &s in slots {
                let f = self.flights[s].as_ref().expect("bucket points at a vacant slot");
                assert_eq!(
                    f.cursor.pending_t().unwrap().to_bits(),
                    *t_bits,
                    "bucket t mismatch at slot {s}"
                );
            }
        }
    }
}

/// A merged ε-eval: the member flights, checked out of their slots and
/// owned by the worker until it re-slots or completes them.
struct GroupJob {
    flights: Vec<Flight>,
    t: f64,
    rows: usize,
}

/// Work a scheduler tick hands to the off-lock half of the loop.
enum Work {
    /// A key-merged admission group to build into a flight.
    Admit(Vec<Pending<Tag>>),
    /// A merged eval over checked-out flights.
    Eval(GroupJob),
}

/// Worker supervisor: runs [`worker_loop`] under `catch_unwind` and
/// restarts it if a panic escapes the fault-contained execution regions
/// (i.e. a bug in the scheduler itself rather than in model code), so a
/// worker thread is never silently lost. A clean return — shutdown — ends
/// the thread. Restarts are counted on `Shared::worker_panics`.
pub(crate) fn supervised_worker_loop(sh: Arc<Shared>, widx: usize) {
    loop {
        let sh2 = sh.clone();
        let run = catch_unwind(AssertUnwindSafe(move || worker_loop(sh2, widx)));
        match run {
            Ok(()) => return,
            Err(_) => {
                sh.worker_panics.fetch_add(1, Ordering::SeqCst);
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
            }
        }
    }
}

/// Scheduler worker: scan shards for work (own shard first, then steal
/// from the busiest), take one work item under that shard's lock, execute
/// it off-lock. Workers never lock a shard they do not take work from —
/// the scan reads the per-shard load atomics only.
pub(crate) fn worker_loop(sh: Arc<Shared>, widx: usize) {
    // Worker-owned buffers reused across evals (gathered states, merged
    // eps output, broadcast t) — no steady-state allocation on the loop.
    let mut xbuf: Vec<f64> = Vec::new();
    let mut outbuf: Vec<f64> = Vec::new();
    let mut tb: Vec<f64> = Vec::new();
    // Cached shard list (refreshed only when the map version moves) and a
    // reusable scan order buffer.
    let mut shards: Vec<Arc<Shard>> = Vec::new();
    let mut seen_version = 0u64;
    let mut scan: Vec<usize> = Vec::new();
    loop {
        if sh.shutdown.load(Ordering::SeqCst) {
            return;
        }
        // Deterministic supervisor hook: tests arm a countdown of worker
        // panics outside the contained eval region to prove the supervisor
        // restarts the loop.
        #[cfg(test)]
        if sh
            .test_worker_bomb
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
            .is_ok()
        {
            panic!("injected worker panic (test bomb)");
        }
        // Snapshot the wake generation BEFORE scanning: anything published
        // after this point bumps it and cancels the sleep below.
        let gen = sh.wake.generation();
        sh.shards.refresh(&mut seen_version, &mut shards);
        match find_work(&sh, &shards, widx, &mut scan) {
            Some((shard, work)) => {
                execute(&sh, &shard, work, &mut xbuf, &mut outbuf, &mut tb);
                // New flights or re-slotted cursors may be schedulable, and
                // a waiting worker may now find work.
                sh.wake.wake();
            }
            None => sh.wake.sleep(gen, &sh.shutdown),
        }
    }
}

/// Pick a shard with work and take one work item from it. Own (affinity)
/// shard first; otherwise the busiest shard by published load, then the
/// next-busiest, until a take succeeds or every shard reads idle.
fn find_work(
    sh: &Shared,
    shards: &[Arc<Shard>],
    widx: usize,
    scan: &mut Vec<usize>,
) -> Option<(Arc<Shard>, Work)> {
    if shards.is_empty() {
        return None;
    }
    let home = widx % shards.len();
    if shards[home].load_hint() > 0 {
        if let Some(w) = try_take(sh, &shards[home]) {
            return Some((shards[home].clone(), w));
        }
    }
    // Steal scan: order every other shard by observed load, descending.
    scan.clear();
    scan.extend((0..shards.len()).filter(|&i| i != home));
    scan.sort_by_key(|&i| Reverse(shards[i].load_hint()));
    for &i in scan.iter() {
        if shards[i].load_hint() == 0 {
            break; // sorted: everything after is idle too
        }
        if let Some(w) = try_take(sh, &shards[i]) {
            return Some((shards[i].clone(), w));
        }
    }
    None
}

/// One scheduler tick on `shard`: sweep deadlines, then prefer admission
/// (queued groups become schedulable flights before new evals dispatch, so
/// a burst admitted during one stalled eval still merges), then a merged
/// eval. Returns None if the shard turned out idle (the load hint raced).
fn try_take(sh: &Shared, shard: &Shard) -> Option<Work> {
    let mut st = shard.lock();
    expire_deadlines(sh, shard, &mut st);
    if let Some((_key, group)) = st.queue.pop_batch() {
        shard.publish_load(&st);
        return Some(Work::Admit(group));
    }
    let budget = st.queue.max_batch_samples;
    if let Some(job) = pick_group(&mut st, budget) {
        shard.publish_load(&st);
        return Some(Work::Eval(job));
    }
    shard.publish_load(&st);
    None
}

/// Execute one work item off-lock.
fn execute(
    sh: &Shared,
    shard: &Shard,
    work: Work,
    xbuf: &mut Vec<f64>,
    outbuf: &mut Vec<f64>,
    tb: &mut Vec<f64>,
) {
    match work {
        Work::Admit(group) => {
            // Parallel group builds: if the shard still has work (more
            // queued groups, or ready flights), wake peers NOW so a burst
            // of distinct keys fans its flight builds across workers
            // instead of serializing behind this one.
            if shard.load_hint() > 0 {
                sh.wake.wake();
            }
            // Priors + cursor instantiation (O(rows·dim)) run here,
            // off-lock; the re-lock only slots the result.
            let flight = build_flight(sh, shard, group);
            if let Some(f) = flight {
                let mut st = shard.lock();
                st.deadline_parts += f.parts.iter().filter(|p| p.deadline.is_some()).count();
                st.insert_flight(f);
                shard.publish_load(&st);
            }
        }
        Work::Eval(job) => {
            let finished = run_group(sh, shard, job, xbuf, outbuf, tb);
            for flight in finished {
                complete_flight(sh, shard, flight);
            }
        }
    }
}

/// Release one request's backpressure reservations (global + shard) —
/// called exactly once per request, at the moment its response is sent.
fn release_inflight(sh: &Shared, shard: &Shard) {
    shard.inflight.fetch_sub(1, Ordering::SeqCst);
    sh.inflight_parts.fetch_sub(1, Ordering::SeqCst);
}

/// Per-request prior draws, deterministic in each request's seed, stacked
/// into one state matrix in part order.
fn draw_priors(group: &[Pending<Tag>], spec: &SampleRequest, d: usize, rows: usize) -> Vec<f64> {
    let mut x = vec![0.0; rows * d];
    let prior = spec.sde.prior_std(1.0);
    let mut offset = 0;
    for p in group {
        let mut rng = Rng::new(p.req.seed);
        for v in x[offset * d..(offset + p.req.n_samples) * d].iter_mut() {
            *v = prior * rng.normal();
        }
        offset += p.req.n_samples;
    }
    x
}

/// Build one admission group into a flight — off-lock. The heavy per-config
/// work (grid + coefficients) arrived prebuilt on the queue tag; what
/// remains is the prior draw and cursor instantiation, which scale with
/// rows·dim and therefore must not run under the shard mutex. Returns
/// `None` when every member expired in the queue — refusals are answered
/// directly from here. (Unknown models never reach admission: submit
/// refuses them at shard resolution.)
fn build_flight(sh: &Shared, shard: &Shard, group: Vec<Pending<Tag>>) -> Option<Flight> {
    // Deadline check at admission: a request that expired while queued
    // gets an error instead of occupying a solver run.
    let now = Instant::now();
    let mut live: Vec<Pending<Tag>> = Vec::with_capacity(group.len());
    for p in group {
        if p.tag.2.is_some_and(|d| d <= now) {
            sh.stats.expired.fetch_add(1, Ordering::Relaxed);
            sh.stats.deadline_missed.fetch_add(1, Ordering::Relaxed);
            shard.stats.expired.fetch_add(1, Ordering::Relaxed);
            shard.stats.deadline_missed.fetch_add(1, Ordering::Relaxed);
            p.tag.0.send(Err(anyhow::anyhow!("deadline exceeded while queued")));
            release_inflight(sh, shard);
        } else {
            live.push(p);
        }
    }
    if live.is_empty() {
        return None;
    }
    let spec = live[0].req.clone();
    let d = shard.dim;
    // All group members share a batch key, hence the same plan config;
    // the head's Arc is the group's plan.
    let plan = live[0].tag.3.clone();
    let rows: usize = live.iter().map(|p| p.req.n_samples).sum();
    let x = draw_priors(&live, &spec, d, rows);
    let mut oldest = live[0].tag.1;
    let mut row0 = 0;
    let parts: Vec<FlightPart> = live
        .into_iter()
        .map(|p| {
            oldest = oldest.min(p.tag.1);
            let part = FlightPart {
                n: p.req.n_samples,
                row0,
                responder: p.tag.0,
                enqueued: p.tag.1,
                deadline: p.tag.2,
            };
            row0 += p.req.n_samples;
            part
        })
        .collect();
    sh.stats.batches.fetch_add(1, Ordering::Relaxed);
    sh.stats.merged_requests.fetch_add(parts.len() as u64, Ordering::Relaxed);
    shard.stats.batches.fetch_add(1, Ordering::Relaxed);
    shard.stats.merged_requests.fetch_add(parts.len() as u64, Ordering::Relaxed);
    // Stochastic solvers clone this stream into their cursor; it is
    // deterministic in the head request's seed, which `tests/scheduler.rs`
    // mirrors for its solo references.
    let mut srng = Rng::new(spec.seed ^ 0xD1F_F051);
    // Cursor construction is solver code operating on request-shaped input;
    // contain it like an eval. On panic every member gets a per-part error
    // and its reservations back — the group was never slotted, so there is
    // no index state to repair.
    let cursor = match catch_unwind(AssertUnwindSafe(|| plan.solver.cursor(&x, rows, &mut srng)))
    {
        Ok(c) => c,
        Err(_) => {
            for part in parts {
                sh.stats.failed.fetch_add(1, Ordering::Relaxed);
                shard.stats.failed.fetch_add(1, Ordering::Relaxed);
                part.responder.send(Err(anyhow::anyhow!(
                    "solver cursor construction panicked (fault contained)"
                )));
                release_inflight(sh, shard);
            }
            return None;
        }
    };
    Some(Flight {
        cursor,
        parts,
        nfe: spec.nfe,
        dim: d,
        rows,
        co_batched_peak: 0,
        started: None,
        oldest,
    })
}

/// Drop expired waiting requests; abort flights nobody is waiting on.
/// Exits immediately when no slotted-or-checked-out part of this shard
/// carries a deadline (the common serving case), so the per-tick cost of
/// the sweep is zero unless deadlines are actually in play. Checked-out
/// flights are invisible here by construction — their parts are caught
/// after re-slotting, or at delivery by `complete_flight`.
fn expire_deadlines(sh: &Shared, shard: &Shard, st: &mut ShardState) {
    if st.deadline_parts == 0 {
        return;
    }
    let now = Instant::now();
    for slot in 0..st.flights.len() {
        let (removed, abort) = match st.flights[slot].as_mut() {
            None => continue,
            Some(f) => {
                let before = f.parts.len();
                f.parts.retain(|part| {
                    if part.deadline.is_some_and(|d| d <= now) {
                        sh.stats.expired.fetch_add(1, Ordering::Relaxed);
                        sh.stats.deadline_missed.fetch_add(1, Ordering::Relaxed);
                        shard.stats.expired.fetch_add(1, Ordering::Relaxed);
                        shard.stats.deadline_missed.fetch_add(1, Ordering::Relaxed);
                        part.responder.send(Err(anyhow::anyhow!(
                            "deadline exceeded before sampling completed"
                        )));
                        release_inflight(sh, shard);
                        false
                    } else {
                        true
                    }
                });
                (before - f.parts.len(), f.parts.is_empty())
            }
        };
        // Only deadline-carrying parts can be retained away.
        st.deadline_parts -= removed;
        if abort {
            // No live requester left: abort the trajectory, reclaiming
            // its remaining eval budget.
            drop(st.remove_flight(slot));
        } else if removed > 0 && matches!(st.policy, SchedPolicy::Edf { .. }) {
            // Under EDF the flight's priority depends on its surviving
            // parts' deadlines; re-slot it so the heap key (and the index
            // invariant) track the new tightest deadline. The generation
            // bump lazily stales the old entry.
            let f = st.remove_flight(slot);
            st.insert_flight(f);
        }
    }
}

/// Choose the next merged eval: the `t` bucket containing the shard's
/// highest-priority ready flight (under its [`SchedPolicy`]), filled in
/// priority order up to the sample budget — and **check the members out of
/// their slots**, transferring ownership to the calling worker so
/// gather/eval/scatter/advance all run without the shard mutex.
///
/// Anchor selection peeks the ready heap (discarding stale entries at the
/// top) instead of scanning the slots; member gathering reads only the
/// anchor's bucket. Cost: O(log flights + bucket), independent of the total
/// flight count.
fn pick_group(st: &mut ShardState, budget: usize) -> Option<GroupJob> {
    // Anchor: the highest-priority live ready flight. Peek, don't pop — in
    // the rare tie case where an equal-priority bucket mate wins the sort
    // below and the budget excludes the anchor, its entry must survive for
    // the next tick.
    let a = loop {
        let &Reverse((_, gen, slot)) = st.ready.peek()?;
        if st.heap_entry_live(gen, slot) {
            break slot;
        }
        st.ready.pop();
    };
    let t = st.flights[a].as_ref().unwrap().cursor.pending_t().unwrap();
    // Every ready flight pending the same t — the anchor's bucket — in
    // priority order. The anchor is the bucket's (possibly tied) minimum.
    let mut members: Vec<(Instant, usize)> = st.buckets[&t.to_bits()]
        .iter()
        .map(|&s| (st.flights[s].as_ref().unwrap().priority(st.policy), s))
        .collect();
    members.sort_unstable();
    let started = Instant::now();
    let mut flights: Vec<Flight> = Vec::with_capacity(members.len());
    let mut rows = 0;
    for (_, slot) in members {
        let f_rows = st.flights[slot].as_ref().unwrap().rows;
        // The first member always dispatches, even oversized; later members
        // must fit the remaining budget.
        if !flights.is_empty() && rows + f_rows > budget {
            continue;
        }
        let mut f = st.remove_flight(slot);
        if f.started.is_none() {
            f.started = Some(started);
        }
        rows += f.rows;
        flights.push(f);
        if rows >= budget {
            break;
        }
    }
    Some(GroupJob { flights, t, rows })
}

/// Execute one merged ε-eval over checked-out flights: gather inputs, run
/// the shard's model, scatter the eps slices back and advance every cursor
/// — all without the shard mutex (the worker owns the flights). A short
/// re-lock then re-slots still-pending flights; finished ones are returned
/// for delivery (also off-lock).
fn run_group(
    sh: &Shared,
    shard: &Shard,
    mut job: GroupJob,
    xbuf: &mut Vec<f64>,
    outbuf: &mut Vec<f64>,
    tb: &mut Vec<f64>,
) -> Vec<Flight> {
    let d = shard.dim;
    // Gather + merged model call under `catch_unwind`: model code is
    // untrusted, and a panicking eval must become per-part errors for every
    // member flight — counters released, nothing re-slotted — instead of a
    // dead worker with stranded clients.
    let evaled = catch_unwind(AssertUnwindSafe(|| {
        xbuf.clear();
        xbuf.reserve(job.rows * d);
        for f in job.flights.iter_mut() {
            let (x_in, _) = f.cursor.io();
            xbuf.extend_from_slice(x_in);
        }
        tb.clear();
        tb.resize(job.rows, job.t);
        outbuf.clear();
        outbuf.resize(job.rows * d, 0.0);
        shard.model.eval(&xbuf[..job.rows * d], &tb[..], job.rows, &mut outbuf[..]);
    }));
    sh.stats.model_evals.fetch_add(1, Ordering::Relaxed);
    shard.stats.model_evals.fetch_add(1, Ordering::Relaxed);
    let group_reqs: usize = job.flights.iter().map(|f| f.parts.len()).sum();
    sh.stats.record_sched_eval(group_reqs as u64);
    shard.stats.record_sched_eval(group_reqs as u64);
    if evaled.is_err() {
        sh.stats.eval_panics.fetch_add(1, Ordering::Relaxed);
        shard.stats.eval_panics.fetch_add(1, Ordering::Relaxed);
        shard.breaker.on_failure();
        let msg = "model eval panicked (fault contained)";
        fail_flights(sh, shard, job.flights.drain(..).map(|f| (f, msg)).collect());
        return Vec::new();
    }

    // Scatter + advance, with per-flight containment: the O(rows·dim)
    // linear combines (and stochastic noise draws) run here, lock-free. A
    // flight whose eps slice is non-finite — or whose advance panics — is
    // failed alone; clean siblings in the same merged call proceed.
    let mut ok: Vec<Flight> = Vec::with_capacity(job.flights.len());
    let mut failed: Vec<(Flight, &'static str)> = Vec::new();
    let mut offset = 0;
    for mut f in job.flights {
        let rows = f.rows;
        let eps = &outbuf[offset * d..(offset + rows) * d];
        offset += rows;
        if eps.iter().any(|v| !v.is_finite()) {
            failed.push((f, "model returned non-finite eps"));
            continue;
        }
        let advanced = catch_unwind(AssertUnwindSafe(|| {
            {
                let (_x, out) = f.cursor.io();
                out.copy_from_slice(eps);
            }
            f.cursor.advance();
        }));
        match advanced {
            Ok(()) => {
                f.co_batched_peak = f.co_batched_peak.max(group_reqs);
                ok.push(f);
            }
            Err(_) => failed.push((f, "solver advance panicked (fault contained)")),
        }
    }
    if failed.is_empty() {
        shard.breaker.on_success();
    } else {
        shard.breaker.on_failure();
    }

    // Short re-lock: route each surviving flight back to a slot or out to
    // delivery. Failed flights are NOT touched here — `fail_flights` owns
    // their deadline-part unwinding and part delivery.
    let mut finished: Vec<Flight> = Vec::new();
    {
        let mut st = shard.lock();
        for f in ok {
            if f.cursor.pending_t().is_some() {
                st.insert_flight(f);
            } else {
                st.deadline_parts -= f.parts.iter().filter(|p| p.deadline.is_some()).count();
                finished.push(f);
            }
        }
        shard.publish_load(&st);
    }
    if !failed.is_empty() {
        fail_flights(sh, shard, failed);
    }
    finished
}

/// Fail checked-out flights: unwind their deadline-part accounting (they
/// were invisible to the sweep but still counted), then answer every part
/// with an error — delivery runs off-lock. The deadline contract stays
/// exactly-once: a part whose deadline already fired counts (and reads) as
/// `expired`; every other part counts as `failed`. Each part's backpressure
/// reservation is released exactly once, here.
fn fail_flights(sh: &Shared, shard: &Shard, failed: Vec<(Flight, &str)>) {
    {
        let mut st = shard.lock();
        let dropped: usize = failed
            .iter()
            .map(|(f, _)| f.parts.iter().filter(|p| p.deadline.is_some()).count())
            .sum();
        st.deadline_parts -= dropped;
        shard.publish_load(&st);
    }
    let now = Instant::now();
    for (flight, msg) in failed {
        for part in flight.parts {
            if part.deadline.is_some_and(|dl| dl <= now) {
                sh.stats.expired.fetch_add(1, Ordering::Relaxed);
                sh.stats.deadline_missed.fetch_add(1, Ordering::Relaxed);
                shard.stats.expired.fetch_add(1, Ordering::Relaxed);
                shard.stats.deadline_missed.fetch_add(1, Ordering::Relaxed);
                part.responder.send(Err(anyhow::anyhow!(
                    "deadline exceeded before sampling completed"
                )));
            } else {
                sh.stats.failed.fetch_add(1, Ordering::Relaxed);
                shard.stats.failed.fetch_add(1, Ordering::Relaxed);
                part.responder.send(Err(anyhow::anyhow!("{msg}")));
            }
            release_inflight(sh, shard);
        }
    }
}

/// Shutdown sweep: answer everything still parked on `shard` — queued
/// admission groups and slotted flights — with a `failed` error carrying
/// `msg`. Called by the drain path AFTER the workers stop and the drain
/// wait elapses, so nothing here races a checkout: whatever the sweep
/// sees is all that is left. Each part's backpressure reservation is
/// released exactly once, keeping the lifecycle balance intact through a
/// shutdown with work still in the pipe.
pub(crate) fn abort_shard(sh: &Shared, shard: &Shard, msg: &str) {
    // Queued requests first: pop admission groups until the queue is dry.
    loop {
        let group = {
            let mut st = shard.lock();
            let g = st.queue.pop_batch();
            shard.publish_load(&st);
            g
        };
        let Some((_key, pending)) = group else { break };
        for p in pending {
            let (responder, _enq, _deadline, _plan) = p.tag;
            sh.stats.failed.fetch_add(1, Ordering::Relaxed);
            shard.stats.failed.fetch_add(1, Ordering::Relaxed);
            responder.send(Err(anyhow::anyhow!("{msg}")));
            release_inflight(sh, shard);
        }
    }
    // Then slotted flights: unslot them all and route through the shared
    // failure path (which owns the expired-vs-failed split and the
    // reservation release).
    let stranded: Vec<(Flight, &str)> = {
        let mut st = shard.lock();
        let mut v = Vec::new();
        for slot in 0..st.flights.len() {
            if st.flights[slot].is_some() {
                // The parts stay counted in `deadline_parts` (slotted or
                // checked out both count); fail_flights unwinds them.
                v.push((st.remove_flight(slot), msg));
            }
        }
        shard.publish_load(&st);
        v
    };
    if !stranded.is_empty() {
        fail_flights(sh, shard, stranded);
    }
}

/// Deliver a finished flight: slice the stacked samples back into
/// per-request results. The deadline contract holds through delivery: a
/// part whose deadline fired while the flight was checked out in its final
/// evals (where `expire_deadlines` cannot see it) gets an error, not late
/// samples.
fn complete_flight(sh: &Shared, shard: &Shard, mut flight: Flight) {
    let samples = flight.cursor.take_samples();
    let d = flight.dim;
    let solve_end = Instant::now();
    let started = flight.started.unwrap_or(solve_end);
    let merged = flight.parts.len();
    for part in flight.parts {
        if part.deadline.is_some_and(|dl| dl <= solve_end) {
            sh.stats.expired.fetch_add(1, Ordering::Relaxed);
            sh.stats.deadline_missed.fetch_add(1, Ordering::Relaxed);
            shard.stats.expired.fetch_add(1, Ordering::Relaxed);
            shard.stats.deadline_missed.fetch_add(1, Ordering::Relaxed);
            part.responder.send(Err(anyhow::anyhow!(
                "deadline exceeded before sampling completed"
            )));
            release_inflight(sh, shard);
            continue;
        }
        // Slice by the admission-time row offset, not cumulatively: parts
        // expired mid-flight leave holes, and surviving requests must still
        // get exactly their own rows.
        let res = SampleResult {
            samples: samples[part.row0 * d..(part.row0 + part.n) * d].to_vec(),
            dim: d,
            nfe: flight.nfe,
            merged_with: merged,
            co_batched: flight.co_batched_peak,
            queue_us: started.duration_since(part.enqueued).as_micros() as u64,
            solve_us: solve_end.duration_since(started).as_micros() as u64,
        };
        // Count rows per DELIVERED part (not per finished flight): parts
        // expired at delivery or mid-flight contribute no samples, keeping
        // `samples` consistent with `completed`.
        sh.stats.samples.fetch_add(part.n as u64, Ordering::Relaxed);
        sh.stats.completed.fetch_add(1, Ordering::Relaxed);
        sh.stats.record_latency(part.enqueued.elapsed().as_micros() as u64);
        shard.stats.samples.fetch_add(part.n as u64, Ordering::Relaxed);
        shard.stats.completed.fetch_add(1, Ordering::Relaxed);
        // A delivered deadline-carrying part beat its deadline: with the
        // miss counts at every expiry site, hit/(hit+missed) is the
        // deadline-hit rate, global and per model.
        if part.deadline.is_some() {
            sh.stats.deadline_hit.fetch_add(1, Ordering::Relaxed);
            shard.stats.deadline_hit.fetch_add(1, Ordering::Relaxed);
        }
        part.responder.send(Ok(res));
        release_inflight(sh, shard);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diffusion::Sde;
    use crate::gmm::Gmm;
    use crate::score::GmmEps;
    use crate::solvers::SolverKind;
    use crate::timegrid::GridKind;
    use std::sync::mpsc::{sync_channel, Receiver};
    use std::time::Duration;

    type Rx = Receiver<anyhow::Result<SampleResult>>;

    fn test_shard() -> Shard {
        let model: Arc<dyn EpsModel> =
            Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp()));
        Shard::new("gmm2d", model, 1024, BreakerConfig::default(), SchedPolicy::Oldest)
    }

    /// A slottable flight over the analytic oracle with `n` rows, one part.
    /// Every fresh cursor's FIRST pending t is t_N = T = 1.0 regardless of
    /// NFE, so fresh flights share one bucket; `pre_advance` steps the
    /// cursor (zero eps — only bookkeeping is under test) so a flight can
    /// be placed in a different-t bucket.
    fn test_flight(
        seed: u64,
        nfe: usize,
        n: usize,
        deadline: Option<Instant>,
        pre_advance: usize,
    ) -> (Flight, Rx) {
        let plan =
            SolverPlan::build(&Sde::vp(), SolverKind::Tab(1), GridKind::Quadratic, 1e-3, nfe);
        let d = 2;
        let mut rng = Rng::new(seed);
        let x = rng.normal_vec(n * d);
        let mut srng = Rng::new(seed ^ 0xD1F_F051);
        let mut cursor = plan.solver.cursor(&x, n, &mut srng);
        for _ in 0..pre_advance {
            {
                let (_x, out) = cursor.io();
                for v in out.iter_mut() {
                    *v = 0.0;
                }
            }
            cursor.advance();
        }
        let (tx, rx) = sync_channel(1);
        let now = Instant::now();
        let flight = Flight {
            cursor,
            parts: vec![FlightPart {
                n,
                row0: 0,
                responder: Responder::channel(tx),
                enqueued: now,
                deadline,
            }],
            nfe,
            dim: d,
            rows: n,
            co_batched_peak: 0,
            started: None,
            oldest: now,
        };
        (flight, rx)
    }

    fn slot_in(st: &mut ShardState, f: Flight) {
        st.deadline_parts += f.parts.iter().filter(|p| p.deadline.is_some()).count();
        st.insert_flight(f);
    }

    #[test]
    fn ready_index_invariants_hold_across_mutations() {
        let mut st = ShardState::new(1024, SchedPolicy::Oldest);
        let mut rxs = Vec::new();
        // Insert: two fresh flights (shared t_N = 1.0 bucket) plus one
        // pre-advanced flight, which pends a later grid node and is the
        // only way a flight lands in a separate bucket within one shard.
        for (seed, nfe, n, pre) in [(1u64, 6usize, 2usize, 0usize), (2, 6, 3, 0), (3, 9, 2, 1)] {
            let (f, rx) = test_flight(seed, nfe, n, None, pre);
            slot_in(&mut st, f);
            rxs.push(rx);
            st.assert_ready_invariants();
        }
        assert_eq!(st.slotted, 3);
        assert_eq!(st.buckets.len(), 2, "fresh pair + pre-advanced = two t buckets");

        // Checkout: the whole oldest bucket leaves slots and index alike.
        let job = pick_group(&mut st, 1024).expect("ready flights must be pickable");
        st.assert_ready_invariants();
        assert_eq!(job.flights.len(), 2, "same-t flights must group");
        assert_eq!(job.rows, 5);
        assert_eq!(st.slotted, 1, "checked-out flights leave the slot table");

        // Advance off-index (zero eps is numerically fine here — only the
        // index bookkeeping is under test), then re-slot.
        let mut flights = job.flights;
        for f in flights.iter_mut() {
            {
                let (_x, out) = f.cursor.io();
                for v in out.iter_mut() {
                    *v = 0.0;
                }
            }
            f.cursor.advance();
        }
        for f in flights {
            assert!(f.cursor.pending_t().is_some(), "nfe 6 has more than one step");
            st.insert_flight(f);
            st.assert_ready_invariants();
        }

        // The re-slotted pair advanced to a NEW t: three flights, all
        // indexed. (Whether the new t collides with the pre-advanced
        // flight's bucket depends on the grids; the invariant check above
        // is what matters.)
        assert_eq!(st.slotted, 3);

        // Abort: removal leaves no dangling bucket or free-list entry.
        let occupied: Vec<usize> =
            (0..st.flights.len()).filter(|&s| st.flights[s].is_some()).collect();
        let victim = occupied[0];
        drop(st.remove_flight(victim));
        st.assert_ready_invariants();

        // Freed slots are reused before the table grows.
        let len_before = st.flights.len();
        let (f, rx) = test_flight(9, 6, 1, None, 0);
        slot_in(&mut st, f);
        rxs.push(rx);
        st.assert_ready_invariants();
        assert_eq!(st.flights.len(), len_before, "admission must reuse the freed slot");
    }

    #[test]
    fn pick_group_is_fifo_and_respects_budget() {
        let mut st = ShardState::new(1024, SchedPolicy::Oldest);
        let mut rxs = Vec::new();
        // Three bucket-mates with rows 1, 2, 3, inserted oldest-first.
        for (seed, n) in [(1u64, 1usize), (2, 2), (3, 3)] {
            let (f, rx) = test_flight(seed, 6, n, None, 0);
            slot_in(&mut st, f);
            rxs.push(rx);
        }
        // Budget 3: flights 1 and 2 fit (rows 1+2), flight 3 must wait.
        let job = pick_group(&mut st, 3).unwrap();
        assert_eq!(
            job.flights.iter().map(|f| f.rows).collect::<Vec<_>>(),
            vec![1, 2],
            "FIFO selection under the sample budget"
        );
        st.assert_ready_invariants();
        // The leftover flight is the next anchor, oversized or not.
        let job2 = pick_group(&mut st, 1).unwrap();
        assert_eq!(job2.flights.len(), 1);
        assert_eq!(job2.flights[0].rows, 3, "anchor dispatches even over budget");
        st.assert_ready_invariants();
        assert!(pick_group(&mut st, 1024).is_none(), "no ready flights left");
    }

    #[test]
    fn edf_anchors_tightest_deadline_ahead_of_an_older_flight() {
        let far = Instant::now() + Duration::from_secs(5);
        let soon = Instant::now() + Duration::from_millis(50);
        // EDF: the YOUNGER flight with the tighter deadline (rows 3)
        // anchors ahead of the older loose-deadline flight (rows 2). The
        // flights pend different t's (pre_advance), so the anchor's bucket
        // is exactly one flight and `rows` identifies the winner.
        let mut st = ShardState::new(1024, SchedPolicy::edf());
        let (loose, _rx1) = test_flight(1, 9, 2, Some(far), 1);
        let (tight, _rx2) = test_flight(2, 6, 3, Some(soon), 0);
        slot_in(&mut st, loose);
        slot_in(&mut st, tight);
        st.assert_ready_invariants();
        let job = pick_group(&mut st, 1024).unwrap();
        assert_eq!(job.rows, 3, "EDF must anchor the tightest deadline, not the oldest");
        st.assert_ready_invariants();

        // The identical shape under the default policy anchors the older
        // flight — deadlines must not influence `oldest` (bit-compat).
        let mut st = ShardState::new(1024, SchedPolicy::Oldest);
        let (loose, _rx3) = test_flight(1, 9, 2, Some(far), 1);
        let (tight, _rx4) = test_flight(2, 6, 3, Some(soon), 0);
        slot_in(&mut st, loose);
        slot_in(&mut st, tight);
        let job = pick_group(&mut st, 1024).unwrap();
        assert_eq!(job.rows, 2, "oldest-first must ignore deadlines");
        st.assert_ready_invariants();
    }

    #[test]
    fn edf_age_guard_keeps_deadline_less_flights_from_starving() {
        let guard = Duration::from_millis(10);
        // A deadline-less flight aged past the guard outranks a fresh
        // tight-deadline arrival: its clamp (oldest + guard) is already in
        // the past, where no future deadline can reach.
        let mut st = ShardState::new(1024, SchedPolicy::Edf { age_guard: guard });
        let (mut aged, _rx1) = test_flight(1, 9, 2, None, 1);
        aged.oldest = Instant::now() - guard - Duration::from_millis(50);
        let (tight, _rx2) =
            test_flight(2, 6, 3, Some(Instant::now() + Duration::from_millis(5)), 0);
        slot_in(&mut st, aged);
        slot_in(&mut st, tight);
        st.assert_ready_invariants();
        let job = pick_group(&mut st, 1024).unwrap();
        assert_eq!(job.rows, 2, "a flight aged past the guard must not be starved");
        st.assert_ready_invariants();

        // A FRESH deadline-less flight yields to the tight deadline —
        // that reordering is what EDF buys, bounded by the guard above.
        let mut st = ShardState::new(1024, SchedPolicy::Edf { age_guard: guard });
        let (fresh, _rx3) = test_flight(1, 9, 2, None, 1);
        let (tight, _rx4) =
            test_flight(2, 6, 3, Some(Instant::now() + Duration::from_millis(5)), 0);
        slot_in(&mut st, fresh);
        slot_in(&mut st, tight);
        let job = pick_group(&mut st, 1024).unwrap();
        assert_eq!(job.rows, 3, "a fresh deadline-less flight must yield to a tight deadline");
        st.assert_ready_invariants();
    }

    #[test]
    fn edf_rekeys_a_flight_when_its_tightest_deadline_part_expires() {
        let sh = bare_shared();
        let shard = test_shard();
        let mut st = ShardState::new(1024, SchedPolicy::edf());
        // Two-part flight: the tight part is already expired, the loose one
        // lives on. After the sweep the flight's priority is governed by
        // the surviving deadline — the invariant check fails if the heap
        // key were left at the expired part's deadline.
        let (mut f, _rx0) = test_flight(1, 6, 4, None, 0);
        let (tx1, rx1) = sync_channel(1);
        let (tx2, _rx2) = sync_channel(1);
        let now = Instant::now();
        f.parts = vec![
            FlightPart {
                n: 2,
                row0: 0,
                responder: Responder::channel(tx1),
                enqueued: now,
                deadline: Some(now - Duration::from_millis(1)),
            },
            FlightPart {
                n: 2,
                row0: 2,
                responder: Responder::channel(tx2),
                enqueued: now,
                deadline: Some(now + Duration::from_secs(5)),
            },
        ];
        sh.inflight_parts.fetch_add(2, Ordering::SeqCst);
        shard.inflight.fetch_add(2, Ordering::SeqCst);
        slot_in(&mut st, f);
        expire_deadlines(&sh, &shard, &mut st);
        st.assert_ready_invariants();
        assert_eq!(st.slotted, 1, "the flight survives on its live part");
        assert_eq!(st.deadline_parts, 1);
        assert!(rx1.try_recv().unwrap().is_err(), "expired part must get an error");
        assert_eq!(shard.stats.snapshot().deadline_missed, 1);
        assert_eq!(sh.stats.snapshot().deadline_missed, 1);
        assert_eq!(sh.inflight_parts.load(Ordering::SeqCst), 1);
    }

    fn bare_shared() -> Shared {
        Shared {
            shards: ShardMap::new(64, BreakerConfig::default(), SchedPolicy::Oldest),
            wake: WakeRail::new(),
            shutdown: std::sync::atomic::AtomicBool::new(false),
            draining: std::sync::atomic::AtomicBool::new(false),
            registry: ModelRegistry::new(),
            stats: super::super::Stats::default(),
            max_inflight: 1024,
            max_inflight_per_model: 1024,
            inflight_parts: AtomicUsize::new(0),
            worker_panics: AtomicU64::new(0),
            plan_cache: crate::solvers::PlanCache::new(),
            #[cfg(test)]
            test_worker_bomb: AtomicUsize::new(0),
        }
    }

    #[test]
    fn expiry_sweep_skips_when_no_deadlines_and_aborts_empty_flights() {
        let sh = bare_shared();
        let shard = test_shard();
        let mut st = shard.lock();
        let (f, _rx_live) = test_flight(1, 6, 2, None, 0);
        slot_in(&mut st, f);
        sh.inflight_parts.fetch_add(1, Ordering::SeqCst);
        shard.inflight.fetch_add(1, Ordering::SeqCst);
        // No deadline parts anywhere: the sweep must be a no-op (and in
        // particular must not walk or disturb the index).
        expire_deadlines(&sh, &shard, &mut st);
        st.assert_ready_invariants();
        assert_eq!(shard.stats.snapshot().expired, 0);
        assert_eq!(sh.stats.snapshot().expired, 0);

        // A flight whose only part is already expired: swept, answered,
        // aborted, slot reclaimed — and its backpressure reservation
        // released on both the global and the shard counters.
        let (f, rx) =
            test_flight(2, 6, 2, Some(Instant::now() - Duration::from_millis(1)), 0);
        slot_in(&mut st, f);
        sh.inflight_parts.fetch_add(1, Ordering::SeqCst);
        shard.inflight.fetch_add(1, Ordering::SeqCst);
        expire_deadlines(&sh, &shard, &mut st);
        st.assert_ready_invariants();
        assert_eq!(shard.stats.snapshot().expired, 1);
        assert_eq!(sh.stats.snapshot().expired, 1, "sweep must count globally too");
        assert_eq!(shard.stats.snapshot().deadline_missed, 1, "expiry is a deadline miss");
        assert_eq!(sh.stats.snapshot().deadline_missed, 1);
        assert_eq!(st.deadline_parts, 0);
        assert_eq!(st.slotted, 1, "only the live flight remains");
        assert_eq!(sh.inflight_parts.load(Ordering::SeqCst), 1);
        assert_eq!(shard.inflight.load(Ordering::SeqCst), 1);
        let err = rx.try_recv().expect("expired part must be answered synchronously");
        assert!(err.is_err(), "expired part must receive an error");
    }

    #[test]
    fn wake_rail_never_loses_a_publication() {
        // The scan-to-sleep race: a publication that lands between a
        // worker's scan and its sleep must cancel the sleep. Simulated
        // directly: snapshot the generation, publish, then "sleep" — which
        // must return immediately.
        let rail = WakeRail::new();
        let shutdown = std::sync::atomic::AtomicBool::new(false);
        let gen = rail.generation();
        rail.wake();
        let t0 = Instant::now();
        rail.sleep(gen, &shutdown); // must not block
        assert!(t0.elapsed() < Duration::from_secs(1), "sleep missed the wake");

        // A real sleeper is woken by a later publication.
        let rail = Arc::new(WakeRail::new());
        let shutdown = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let sleeper = {
            let rail = rail.clone();
            let shutdown = shutdown.clone();
            std::thread::spawn(move || {
                let gen = rail.generation();
                rail.sleep(gen, &shutdown);
            })
        };
        std::thread::sleep(Duration::from_millis(20));
        rail.wake();
        sleeper.join().unwrap();
    }

    #[test]
    fn breaker_opens_at_threshold_and_half_opens_after_cooldown() {
        let b = Breaker::new(BreakerConfig { threshold: 2, cooldown: Duration::from_millis(40) });
        assert!(!b.is_open());
        b.on_failure();
        assert!(!b.is_open(), "one failure is below threshold");
        b.on_failure();
        assert!(b.is_open(), "threshold consecutive failures must open");
        std::thread::sleep(Duration::from_millis(50));
        assert!(!b.is_open(), "cooldown elapsed: half-open admits traffic");
        // Half-open keeps the streak: one more failure re-opens instantly.
        b.on_failure();
        assert!(b.is_open(), "failure in half-open must re-open");
        std::thread::sleep(Duration::from_millis(50));
        b.on_success();
        assert!(!b.is_open());
        assert_eq!(b.consecutive(), 0, "success must reset the streak");
        b.on_failure();
        assert!(!b.is_open(), "closed breaker needs a fresh streak to open");

        // threshold 0 disables the breaker entirely.
        let off = Breaker::new(BreakerConfig { threshold: 0, cooldown: Duration::from_millis(1) });
        for _ in 0..10 {
            off.on_failure();
        }
        assert!(!off.is_open());
    }

    #[test]
    fn panicking_eval_fails_parts_releases_counters_and_reslots_nothing() {
        let sh = bare_shared();
        let model: Arc<dyn EpsModel> = Arc::new(crate::score::FaultyEps::new(
            GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp()),
            crate::score::FaultPlan::new().panic_on(0),
        ));
        let shard = Shard::new(
            "faulty",
            model,
            1024,
            BreakerConfig { threshold: 2, cooldown: Duration::from_millis(50) },
            SchedPolicy::Oldest,
        );
        let (f, rx) = test_flight(1, 6, 2, None, 0);
        sh.inflight_parts.fetch_add(1, Ordering::SeqCst);
        shard.inflight.fetch_add(1, Ordering::SeqCst);
        let job;
        {
            let mut st = shard.lock();
            slot_in(&mut st, f);
            job = pick_group(&mut st, 1024).unwrap();
            st.assert_ready_invariants();
        }
        let (mut xbuf, mut outbuf, mut tb) = (Vec::new(), Vec::new(), Vec::new());
        let finished = run_group(&sh, &shard, job, &mut xbuf, &mut outbuf, &mut tb);
        assert!(finished.is_empty(), "a panicked eval must finish nothing");
        let err = rx.try_recv().expect("failed part must be answered synchronously");
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("panicked"));
        assert_eq!(sh.inflight_parts.load(Ordering::SeqCst), 0, "reservation leaked");
        assert_eq!(shard.inflight.load(Ordering::SeqCst), 0);
        assert_eq!(shard.stats.snapshot().failed, 1);
        assert_eq!(shard.stats.snapshot().eval_panics, 1);
        assert_eq!(sh.stats.snapshot().failed, 1);
        assert_eq!(sh.stats.snapshot().eval_panics, 1);
        assert_eq!(shard.breaker.consecutive(), 1);
        {
            let st = shard.lock();
            assert_eq!(st.slotted, 0, "failed flights must not re-slot");
            assert_eq!(st.deadline_parts, 0);
        }

        // Two consecutive panicking evals (fresh shard, plan scripting both)
        // must open the breaker at threshold 2.
        let model2: Arc<dyn EpsModel> = Arc::new(crate::score::FaultyEps::new(
            GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp()),
            crate::score::FaultPlan::new().panic_on(0).panic_on(1),
        ));
        let shard2 = Shard::new(
            "faulty2",
            model2,
            1024,
            BreakerConfig { threshold: 2, cooldown: Duration::from_millis(50) },
            SchedPolicy::Oldest,
        );
        for seed in [3u64, 4] {
            let (f, _rx) = test_flight(seed, 6, 2, None, 0);
            sh.inflight_parts.fetch_add(1, Ordering::SeqCst);
            shard2.inflight.fetch_add(1, Ordering::SeqCst);
            let job;
            {
                let mut st = shard2.lock();
                slot_in(&mut st, f);
                job = pick_group(&mut st, 1024).unwrap();
            }
            let finished = run_group(&sh, &shard2, job, &mut xbuf, &mut outbuf, &mut tb);
            assert!(finished.is_empty());
        }
        assert!(shard2.breaker.is_open(), "two consecutive panics must open the breaker");
    }

    #[test]
    fn non_finite_eval_fails_the_flight_with_a_clear_error() {
        let sh = bare_shared();
        let model: Arc<dyn EpsModel> = Arc::new(crate::score::FaultyEps::new(
            GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp()),
            crate::score::FaultPlan::new().nan_on(0),
        ));
        let shard = Shard::new("nan", model, 1024, BreakerConfig::default(), SchedPolicy::Oldest);
        let (f, rx) = test_flight(1, 6, 2, None, 0);
        sh.inflight_parts.fetch_add(1, Ordering::SeqCst);
        shard.inflight.fetch_add(1, Ordering::SeqCst);
        let job;
        {
            let mut st = shard.lock();
            slot_in(&mut st, f);
            job = pick_group(&mut st, 1024).unwrap();
        }
        let (mut xbuf, mut outbuf, mut tb) = (Vec::new(), Vec::new(), Vec::new());
        let finished = run_group(&sh, &shard, job, &mut xbuf, &mut outbuf, &mut tb);
        assert!(finished.is_empty(), "a NaN eval must not complete the flight");
        let err = rx.try_recv().unwrap();
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("non-finite"));
        assert_eq!(shard.stats.snapshot().failed, 1);
        assert_eq!(shard.stats.snapshot().eval_panics, 0, "NaN is not a panic");
        assert_eq!(sh.inflight_parts.load(Ordering::SeqCst), 0);
        assert_eq!(shard.breaker.consecutive(), 1, "NaN output counts toward the breaker");

        // The next (clean) eval closes the streak.
        let (f2, rx2) = test_flight(2, 1, 2, None, 0);
        sh.inflight_parts.fetch_add(1, Ordering::SeqCst);
        shard.inflight.fetch_add(1, Ordering::SeqCst);
        let job2;
        {
            let mut st = shard.lock();
            slot_in(&mut st, f2);
            job2 = pick_group(&mut st, 1024).unwrap();
        }
        let finished = run_group(&sh, &shard, job2, &mut xbuf, &mut outbuf, &mut tb);
        assert_eq!(finished.len(), 1, "nfe-1 flight completes in one eval");
        for fl in finished {
            complete_flight(&sh, &shard, fl);
        }
        assert!(rx2.try_recv().unwrap().is_ok());
        assert_eq!(shard.breaker.consecutive(), 0, "clean eval must reset the streak");
    }

    #[test]
    fn shard_map_creates_lazily_and_only_for_registered_models() {
        let mut reg = ModelRegistry::new();
        reg.insert("a", Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())));
        reg.insert("b", Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())));
        let map = ShardMap::new(64, BreakerConfig::default(), SchedPolicy::Oldest);
        assert_eq!(map.count(), 0, "no shards before traffic");
        let a1 = map.get_or_create("a", &reg).expect("registered model must resolve");
        assert_eq!(map.count(), 1);
        let a2 = map.get_or_create("a", &reg).unwrap();
        assert!(Arc::ptr_eq(&a1, &a2), "repeat lookups must reuse the shard");
        assert_eq!(map.count(), 1, "repeat lookups must not create shards");
        assert!(map.get_or_create("nope", &reg).is_none(), "unknown model resolves to None");
        assert_eq!(map.count(), 1, "unknown models must not leak shards");
        let _b = map.get_or_create("b", &reg).unwrap();
        assert_eq!(map.count(), 2);
        // Worker snapshot refresh: version-gated, creation-ordered.
        let mut seen = 0u64;
        let mut shards = Vec::new();
        map.refresh(&mut seen, &mut shards);
        assert_eq!(shards.len(), 2);
        assert_eq!(&*shards[0].name, "a");
        assert_eq!(&*shards[1].name, "b");
        let before = seen;
        map.refresh(&mut seen, &mut shards);
        assert_eq!(seen, before, "no version change, no re-snapshot");
        // Per-model snapshots come out name-sorted.
        let pm = map.per_model_snapshots();
        assert_eq!(pm.len(), 2);
        assert!(pm[0].0 < pm[1].0);
    }
}
