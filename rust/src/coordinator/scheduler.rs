//! Step-level cross-request batching scheduler.
//!
//! The old coordinator merged requests only at admission: requests that
//! arrived in the same tick with an identical batch key were stacked into
//! one solver run, and every trajectory otherwise paid for its ε-evaluations
//! alone. This module keeps that admission-time merge (it is what makes
//! bursts of identical requests cheap) and adds the step-level layer the
//! paper's cost model actually calls for: solvers are resumable
//! [`StepCursor`] machines that *yield* their pending ε-evals, and the
//! scheduler collects pending evals across **all** in-flight trajectory
//! groups, buckets them by `(model, t)`, and dispatches one merged network
//! call per bucket.
//!
//! Why `(model, t)`: every cursor eval broadcasts one scalar t, so a merged
//! bucket is uniform-t and takes the native engine's shared-embedding fast
//! path (one time-embedding fold per call, `score/native.rs`). Because grid
//! nodes are a pure function of (grid kind, NFE, t0, sde), trajectory groups
//! admitted in the same tick with the same grid stay in lockstep and merge
//! on *every* step — including across different solvers (e.g. ddim and tab3
//! at the same NFE share all their nodes), which admission-keyed merging
//! could never do. All trajectories also share their very first node
//! t_N = T, so even different-NFE groups admitted together merge their first
//! eval.
//!
//! Scheduling policy: pick the bucket containing the longest-waiting
//! trajectory group (FIFO fairness keeps lockstep groups together), cap it
//! at `max_batch_samples`, run the eval outside the lock, then scatter the
//! eps slices back through each cursor and advance it. Solvers without a
//! cursor (adaptive RK45, stochastic samplers, ρRK, s-param EI) fall back to
//! a whole-trajectory blocking run, preserving the old behavior exactly.
//!
//! Determinism: a request's samples depend only on its (seed, n, config) —
//! per-request prior RNG streams, and per-row model math independent of
//! batch composition — so scheduled, admission-merged and solo runs are
//! bit-identical (`rust/tests/scheduler.rs` pins this).
//!
//! Known tradeoff: the post-eval scatter + `advance()` (the solver's linear
//! combination, O(rows·dim)) runs under the coordinator mutex. That is 2–3
//! orders of magnitude cheaper than the network eval it follows
//! (O(rows·dim·hidden²)), but it does serialize across workers; if profiles
//! ever show contention here, the fix is to take the member flights out of
//! their slots (they are already marked busy), advance outside the lock,
//! and reinsert — tracked in ROADMAP.md.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use super::batcher::{Batcher, Pending};
use super::request::{SampleRequest, SampleResult};
use super::{Responder, Shared};
use crate::score::EpsModel;
use crate::solvers::{self, Solver, StepCursor};
use crate::timegrid;
use crate::util::rng::Rng;

/// Queue tag carried through admission: response channel, enqueue time,
/// absolute deadline (if the request set one).
pub(super) type Tag = (Responder, Instant, Option<Instant>);

/// One client request inside a trajectory group.
struct FlightPart {
    n: usize,
    /// First row of this request inside the flight's stacked state matrix.
    /// Fixed at admission: expiring another part must not shift the rows a
    /// surviving request receives.
    row0: usize,
    responder: Responder,
    enqueued: Instant,
    deadline: Option<Instant>,
}

/// An in-flight trajectory group: requests admitted together under one
/// batch key, integrating as one cursor over a stacked state matrix.
struct Flight {
    model_name: String,
    model: Arc<dyn EpsModel>,
    cursor: Box<dyn StepCursor>,
    parts: Vec<FlightPart>,
    nfe: usize,
    dim: usize,
    /// Total sample rows (sum of part n's).
    rows: usize,
    /// Peak number of requests co-batched with this flight's evals.
    co_batched_peak: usize,
    /// True while a worker holds this flight's rows in a merged eval.
    busy: bool,
    /// First eval dispatch (queue_us / solve_us split point).
    started: Option<Instant>,
    /// Earliest enqueue time over parts — the FIFO fairness key.
    oldest: Instant,
}

/// Scheduler state under the coordinator mutex.
pub(super) struct SchedState {
    /// Admission queue: key-merged by the [`Batcher`] exactly as before.
    pub(super) queue: Batcher<Tag>,
    flights: Vec<Option<Flight>>,
}

impl SchedState {
    pub(super) fn new(max_batch_samples: usize) -> SchedState {
        SchedState { queue: Batcher::new(max_batch_samples), flights: Vec::new() }
    }

    /// Requests not yet responded to (backpressure accounting).
    pub(super) fn inflight_requests(&self) -> usize {
        self.queue.len()
            + self
                .flights
                .iter()
                .flatten()
                .map(|f| f.parts.len())
                .sum::<usize>()
    }
}

/// A blocking whole-trajectory job (solver without cursor support).
struct LegacyJob {
    spec: SampleRequest,
    model: Arc<dyn EpsModel>,
    solver: Box<dyn Solver>,
    x: Vec<f64>,
    rows: usize,
    dim: usize,
    parts: Vec<FlightPart>,
}

/// A merged ε-eval covering every flight in `idx` at scalar time `t`.
struct GroupJob {
    idx: Vec<usize>,
    model: Arc<dyn EpsModel>,
    t: f64,
    rows: usize,
    dim: usize,
}

enum Work {
    Legacy(LegacyJob),
    Group(GroupJob),
}

/// Scheduler worker: admit -> pick merged eval (or legacy run) -> execute.
pub(super) fn worker_loop(sh: Arc<Shared>) {
    // Worker-owned buffers reused across evals (gathered states, merged
    // eps output, broadcast t) — no steady-state allocation on the loop.
    let mut xbuf: Vec<f64> = Vec::new();
    let mut outbuf: Vec<f64> = Vec::new();
    let mut tb: Vec<f64> = Vec::new();
    loop {
        let work = {
            let mut st = sh.state.lock().unwrap();
            loop {
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                expire_deadlines(&mut st, &sh);
                if let Some(job) = admit(&mut st, &sh) {
                    break Work::Legacy(job);
                }
                if let Some(job) = pick_group(&mut st, &sh, &mut xbuf) {
                    break Work::Group(job);
                }
                st = sh.cv.wait(st).unwrap();
            }
        };
        match work {
            Work::Legacy(job) => run_legacy(&sh, job),
            Work::Group(job) => run_group(&sh, job, &xbuf, &mut outbuf, &mut tb),
        }
        // Completed or unblocked flights may be schedulable again, and a
        // waiting worker may now find work.
        sh.cv.notify_all();
    }
}

/// Per-request prior draws, deterministic in each request's seed, stacked
/// into one state matrix in part order.
fn draw_priors(group: &[Pending<Tag>], spec: &SampleRequest, d: usize, rows: usize) -> Vec<f64> {
    let mut x = vec![0.0; rows * d];
    let prior = spec.sde.prior_std(1.0);
    let mut offset = 0;
    for p in group {
        let mut rng = Rng::new(p.req.seed);
        for v in x[offset * d..(offset + p.req.n_samples) * d].iter_mut() {
            *v = prior * rng.normal();
        }
        offset += p.req.n_samples;
    }
    x
}

/// Drain the admission queue into flights. Returns the first key group
/// whose solver has no cursor — the caller runs it as a blocking job (the
/// rest of the queue is handled on subsequent passes).
fn admit(st: &mut SchedState, sh: &Shared) -> Option<LegacyJob> {
    while let Some((_key, group)) = st.queue.pop_batch() {
        // Deadline check at admission: a request that expired while queued
        // gets an error instead of occupying a solver run.
        let now = Instant::now();
        let mut live: Vec<Pending<Tag>> = Vec::with_capacity(group.len());
        for p in group {
            if p.tag.2.is_some_and(|d| d <= now) {
                sh.stats.expired.fetch_add(1, Ordering::Relaxed);
                let _ = p
                    .tag
                    .0
                    .send(Err(anyhow::anyhow!("deadline exceeded while queued")));
            } else {
                live.push(p);
            }
        }
        if live.is_empty() {
            continue;
        }
        let spec = live[0].req.clone();
        let model = match sh.registry.get(&spec.model) {
            Some(m) => m,
            None => {
                for p in live {
                    let _ = p
                        .tag
                        .0
                        .send(Err(anyhow::anyhow!("unknown model '{}'", spec.model)));
                }
                continue;
            }
        };
        let d = model.dim();
        // Grid/solver constructors assert on malformed configs (t0 out of
        // range, too few steps for PNDM, ...). A panic here would poison the
        // coordinator mutex and brick the service for every client, so turn
        // construction panics into per-request errors instead.
        let built = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let steps = spec.solver.steps_for_nfe(spec.nfe);
            let grid = timegrid::build(spec.grid, &spec.sde, spec.t0, 1.0, steps);
            solvers::build(spec.solver, &spec.sde, &grid)
        }));
        let solver = match built {
            Ok(s) => s,
            Err(_) => {
                for p in live {
                    let _ = p.tag.0.send(Err(anyhow::anyhow!(
                        "invalid sampling configuration for solver '{}' (nfe {}, t0 {}): \
                         grid/solver constraints violated",
                        spec.solver.name(),
                        spec.nfe,
                        spec.t0
                    )));
                }
                continue;
            }
        };
        let rows: usize = live.iter().map(|p| p.req.n_samples).sum();
        let x = draw_priors(&live, &spec, d, rows);
        let mut oldest = live[0].tag.1;
        let mut row0 = 0;
        let parts: Vec<FlightPart> = live
            .into_iter()
            .map(|p| {
                oldest = oldest.min(p.tag.1);
                let part = FlightPart {
                    n: p.req.n_samples,
                    row0,
                    responder: p.tag.0,
                    enqueued: p.tag.1,
                    deadline: p.tag.2,
                };
                row0 += p.req.n_samples;
                part
            })
            .collect();
        sh.stats.batches.fetch_add(1, Ordering::Relaxed);
        sh.stats.merged_requests.fetch_add(parts.len() as u64, Ordering::Relaxed);
        match solver.cursor(&x, rows) {
            Some(cursor) => {
                let flight = Flight {
                    model_name: spec.model.clone(),
                    model,
                    cursor,
                    parts,
                    nfe: spec.nfe,
                    dim: d,
                    rows,
                    co_batched_peak: 0,
                    busy: false,
                    started: None,
                    oldest,
                };
                match st.flights.iter_mut().find(|s| s.is_none()) {
                    Some(slot) => *slot = Some(flight),
                    None => st.flights.push(Some(flight)),
                }
            }
            None => {
                // Keep the parts visible to backpressure while they execute
                // outside `state`; run_legacy decrements after responding.
                sh.legacy_inflight.fetch_add(parts.len(), Ordering::Relaxed);
                return Some(LegacyJob { spec, model, solver, x, rows, dim: d, parts });
            }
        }
    }
    None
}

/// Drop expired waiting requests; abort flights nobody is waiting on.
/// In-place (`retain`): the common no-deadline sweep allocates nothing —
/// this runs on every scheduler tick under the coordinator mutex.
fn expire_deadlines(st: &mut SchedState, sh: &Shared) {
    let now = Instant::now();
    for slot in st.flights.iter_mut() {
        if let Some(f) = slot {
            if f.busy {
                continue;
            }
            f.parts.retain(|part| {
                if part.deadline.is_some_and(|d| d <= now) {
                    sh.stats.expired.fetch_add(1, Ordering::Relaxed);
                    let _ = part.responder.send(Err(anyhow::anyhow!(
                        "deadline exceeded before sampling completed"
                    )));
                    false
                } else {
                    true
                }
            });
            if f.parts.is_empty() {
                // No live requester left: abort the trajectory, reclaiming
                // its remaining eval budget.
                *slot = None;
            }
        }
    }
}

/// Choose the next merged eval: the `(model, t)` bucket containing the
/// longest-waiting ready flight, filled in FIFO order up to the sample
/// budget. Marks members busy and gathers their input rows into `xbuf`.
fn pick_group(st: &mut SchedState, sh: &Shared, xbuf: &mut Vec<f64>) -> Option<GroupJob> {
    let mut anchor: Option<usize> = None;
    for (i, f) in st.flights.iter().enumerate() {
        if let Some(f) = f {
            if !f.busy && f.cursor.pending_t().is_some() {
                let better = match anchor {
                    Some(a) => f.oldest < st.flights[a].as_ref().unwrap().oldest,
                    None => true,
                };
                if better {
                    anchor = Some(i);
                }
            }
        }
    }
    let a = anchor?;
    let (name, t, model, dim) = {
        let f = st.flights[a].as_ref().unwrap();
        (f.model_name.clone(), f.cursor.pending_t().unwrap(), f.model.clone(), f.dim)
    };
    // Every ready flight pending the same (model, t), oldest first.
    let mut members: Vec<(usize, Instant)> = st
        .flights
        .iter()
        .enumerate()
        .filter_map(|(i, f)| f.as_ref().map(|f| (i, f)))
        .filter(|(_, f)| {
            !f.busy
                && f.model_name == name
                && f.cursor.pending_t().map(f64::to_bits) == Some(t.to_bits())
        })
        .map(|(i, f)| (i, f.oldest))
        .collect();
    members.sort_by_key(|&(_, oldest)| oldest);
    let budget = sh.max_batch_samples;
    let mut idx = Vec::with_capacity(members.len());
    let mut rows = 0;
    for (i, _) in members {
        let f_rows = st.flights[i].as_ref().unwrap().rows;
        // The anchor always dispatches, even oversized; later members must
        // fit the remaining budget.
        if !idx.is_empty() && rows + f_rows > budget {
            continue;
        }
        idx.push(i);
        rows += f_rows;
        if rows >= budget {
            break;
        }
    }
    let started = Instant::now();
    xbuf.clear();
    xbuf.reserve(rows * dim);
    for &i in &idx {
        let f = st.flights[i].as_mut().unwrap();
        f.busy = true;
        if f.started.is_none() {
            f.started = Some(started);
        }
        let (x_in, _) = f.cursor.io();
        xbuf.extend_from_slice(x_in);
    }
    Some(GroupJob { idx, model, t, rows, dim })
}

/// Execute one merged ε-eval and scatter the results back through the
/// member cursors.
fn run_group(sh: &Shared, job: GroupJob, xbuf: &[f64], outbuf: &mut Vec<f64>, tb: &mut Vec<f64>) {
    let d = job.dim;
    tb.clear();
    tb.resize(job.rows, job.t);
    outbuf.clear();
    outbuf.resize(job.rows * d, 0.0);
    job.model.eval(&xbuf[..job.rows * d], tb, job.rows, outbuf);
    sh.stats.model_evals.fetch_add(1, Ordering::Relaxed);

    let mut finished: Vec<Flight> = Vec::new();
    {
        let mut st = sh.state.lock().unwrap();
        let group_reqs: usize =
            job.idx.iter().map(|&i| st.flights[i].as_ref().unwrap().parts.len()).sum();
        sh.stats.record_sched_eval(group_reqs as u64);
        let mut offset = 0;
        for &i in &job.idx {
            let f = st.flights[i].as_mut().unwrap();
            let rows = f.rows;
            {
                let (_x, out) = f.cursor.io();
                out.copy_from_slice(&outbuf[offset * d..(offset + rows) * d]);
            }
            f.cursor.advance();
            f.busy = false;
            f.co_batched_peak = f.co_batched_peak.max(group_reqs);
            offset += rows;
            if f.cursor.pending_t().is_none() {
                finished.push(st.flights[i].take().unwrap());
            }
        }
    }
    for flight in finished {
        complete_flight(sh, flight);
    }
}

/// Deliver a finished flight: slice the stacked samples back into
/// per-request results.
fn complete_flight(sh: &Shared, mut flight: Flight) {
    let samples = flight.cursor.take_samples();
    let d = flight.dim;
    let solve_end = Instant::now();
    let started = flight.started.unwrap_or(solve_end);
    let merged = flight.parts.len();
    sh.stats.samples.fetch_add(flight.rows as u64, Ordering::Relaxed);
    for part in flight.parts {
        // Slice by the admission-time row offset, not cumulatively: parts
        // expired mid-flight leave holes, and surviving requests must still
        // get exactly their own rows.
        let res = SampleResult {
            samples: samples[part.row0 * d..(part.row0 + part.n) * d].to_vec(),
            dim: d,
            nfe: flight.nfe,
            merged_with: merged,
            co_batched: flight.co_batched_peak,
            queue_us: started.duration_since(part.enqueued).as_micros() as u64,
            solve_us: solve_end.duration_since(started).as_micros() as u64,
        };
        sh.stats.completed.fetch_add(1, Ordering::Relaxed);
        sh.stats.record_latency(part.enqueued.elapsed().as_micros() as u64);
        let _ = part.responder.send(Ok(res));
    }
}

/// Whole-trajectory blocking run for solvers without cursor support —
/// the pre-scheduler sampling behavior, kept bit-identical, plus the
/// deadline contract: the run cannot be interrupted mid-integration, but
/// a part whose deadline has fired by delivery time gets an error rather
/// than late samples (and an all-expired job skips the solve entirely).
fn run_legacy(sh: &Shared, job: LegacyJob) {
    let LegacyJob { spec, model, solver, mut x, rows, dim, parts } = job;
    let n_parts = parts.len();
    let expire = |part: &FlightPart| {
        sh.stats.expired.fetch_add(1, Ordering::Relaxed);
        let _ = part
            .responder
            .send(Err(anyhow::anyhow!("deadline exceeded before sampling completed")));
    };
    let expired_by =
        |part: &FlightPart, now: Instant| part.deadline.is_some_and(|d| d <= now);
    let now = Instant::now();
    if parts.iter().all(|p| expired_by(p, now)) {
        for part in &parts {
            expire(part);
        }
        sh.legacy_inflight.fetch_sub(n_parts, Ordering::Relaxed);
        return;
    }
    let t_solve = now;
    // One rng stream for stochastic solvers across the merged batch,
    // deterministic in the head request's seed.
    let mut srng = Rng::new(spec.seed ^ 0xD1F_F051);
    solver.sample(model.as_ref(), &mut x, rows, &mut srng);
    let solve_us = t_solve.elapsed().as_micros() as u64;
    sh.stats.samples.fetch_add(rows as u64, Ordering::Relaxed);
    sh.stats.model_evals.fetch_add(solver.nfe() as u64, Ordering::Relaxed);
    let merged = parts.len();
    let delivery = Instant::now();
    for part in parts {
        if expired_by(&part, delivery) {
            expire(&part);
            continue;
        }
        let res = SampleResult {
            samples: x[part.row0 * dim..(part.row0 + part.n) * dim].to_vec(),
            dim,
            nfe: spec.nfe,
            merged_with: merged,
            co_batched: 1,
            queue_us: t_solve.duration_since(part.enqueued).as_micros() as u64,
            solve_us,
        };
        sh.stats.completed.fetch_add(1, Ordering::Relaxed);
        sh.stats.record_latency(part.enqueued.elapsed().as_micros() as u64);
        let _ = part.responder.send(Ok(res));
    }
    sh.legacy_inflight.fetch_sub(n_parts, Ordering::Relaxed);
}
