//! Step-level cross-request batching scheduler.
//!
//! The old coordinator merged requests only at admission: requests that
//! arrived in the same tick with an identical batch key were stacked into
//! one solver run, and every trajectory otherwise paid for its ε-evaluations
//! alone. This module keeps that admission-time merge (it is what makes
//! bursts of identical requests cheap) and adds the step-level layer the
//! paper's cost model actually calls for: solvers are resumable
//! [`StepCursor`] machines that *yield* their pending ε-evals, and the
//! scheduler collects pending evals across **all** in-flight trajectory
//! groups, buckets them by `(model, t)`, and dispatches one merged network
//! call per bucket.
//!
//! Why `(model, t)`: every cursor eval broadcasts one scalar t, so a merged
//! bucket is uniform-t and takes the native engine's shared-embedding fast
//! path (one time-embedding fold per call, `score/native.rs`). Because grid
//! nodes are a pure function of (grid kind, NFE, t0, sde), trajectory groups
//! admitted in the same tick with the same grid stay in lockstep and merge
//! on *every* step — including across different solvers (e.g. ddim and tab3
//! at the same NFE share all their nodes), which admission-keyed merging
//! could never do. All trajectories also share their very first node
//! t_N = T, so even different-NFE groups admitted together merge their first
//! eval.
//!
//! Scheduling policy: pick the bucket containing the longest-waiting
//! trajectory group (FIFO fairness keeps lockstep groups together), cap it
//! at `max_batch_samples`, run the eval, then scatter the eps slices back
//! through each cursor and advance it. Cursorization is universal —
//! adaptive RK45, the ρRK stage schemes, s-param EI and the stochastic
//! samplers are all resumable — so there is no blocking whole-trajectory
//! path left: every request is co-batchable.
//!
//! # Off-lock execution
//!
//! The coordinator mutex guards *routing state only*. Everything whose cost
//! scales with rows·dim runs without it:
//!
//! * **Admission** pops one key-merged group from the queue under the lock,
//!   then releases it to draw priors and instantiate the cursor
//!   (`build_flight`), re-locking only to slot the finished flight. The
//!   (grid, coefficients) plan arrived prebuilt on the queue tag via the
//!   shared [`PlanCache`](crate::solvers::cache::PlanCache), resolved in
//!   `Coordinator::submit` on the submitting thread.
//! * **Evals** check member flights *out of their slots* in [`pick_group`]
//!   (they are removed from the flights table entirely, not merely flagged
//!   busy), so the worker owns them: input gather, the merged model call,
//!   the eps scatter, and `cursor.advance()` — the solver's O(rows·dim)
//!   linear combines, and for stochastic cursors the noise draws — all run
//!   lock-free in [`run_group`]. A short re-lock then re-slots each flight
//!   (or routes it to [`complete_flight`] when its trajectory is done).
//!
//! A checked-out flight is invisible to the expiry sweep; the deadline
//! contract holds anyway because it is enforced *at delivery*: a part whose
//! deadline fires while its flight is checked out is caught either by the
//! sweep after the flight re-slots, or by `complete_flight`'s re-check
//! before sending — it always receives an error, never late samples.
//! In-flight accounting (backpressure) counts checked-out and mid-admission
//! parts through `SchedState::{active_parts, admitting_parts}`, so the
//! overload bound cannot be dodged by catching the scheduler mid-eval.
//!
//! # Ready index
//!
//! [`pick_group`] used to scan every flight slot twice per tick (once for
//! the anchor, once for members) — fine at hundreds of flights, O(flights)
//! pain at tens of thousands. The scheduler now maintains a **ready index**
//! updated at insert/checkout/abort:
//!
//! * `buckets`: `(model, pending_t bits) -> Vec<slot>` — member gathering is
//!   O(bucket), and a bucket is exactly one merged dispatch candidate.
//! * `ready`: a min-heap of `(oldest, generation, slot)` — anchor selection
//!   (the globally longest-waiting ready flight) is O(log flights)
//!   amortized. Entries are lazily invalidated: each slot carries a
//!   generation bumped on every (re)occupancy, and stale entries are
//!   discarded when they surface at the top. A slotted flight has exactly
//!   one live entry (one push per insert), so the heap holds at most one
//!   entry per insert event — bounded by live flights plus not-yet-surfaced
//!   stale entries, which each pick drains from the top.
//! * `free_slots`: vacant slot indices, so admission is a pop instead of a
//!   linear scan for a `None`.
//!
//! The index invariant (checked by the unit tests below): every slotted
//! flight — all of which have a pending eval by construction — appears in
//! exactly the bucket of its `(model, pending_t)` and has exactly one live
//! heap entry; buckets and the free list never point at anything else.
//! Flights checked out by a worker are *absent* from slots and index alike;
//! they re-enter through [`SchedState::insert_flight`] which restores the
//! invariant.
//!
//! # Determinism
//!
//! For deterministic solvers a request's samples depend only on its
//! (seed, n, config) — per-request prior RNG streams, and per-row model math
//! independent of batch composition — so scheduled, admission-merged and
//! solo runs are bit-identical (`rust/tests/scheduler.rs` pins this, now
//! under a ≥4-worker stress battery). Stochastic flights draw noise only
//! inside `advance`, from a cursor-owned stream seeded by the flight's HEAD
//! request, so step-level co-batching with strangers never perturbs the
//! noise — scheduled == solo holds for any stochastic request that is not
//! admission-merged. Two caveats, both inherited from the old blocking path
//! (which also ran the solver over the stacked rows): same-config stochastic
//! requests admission-merged in one tick share the head's noise stream, and
//! batch-coupled estimators span the merged rows — A-DDIM's Γ estimate and
//! rk45's RMS error norm (hence its accept/reject sequence) are computed
//! over the whole flight. A merged non-head request of those solvers can
//! therefore differ from its solo run; fully deterministic per-row solvers
//! (everything else) are bit-identical merged or not. Off-lock execution
//! changes none of this: a flight's math is self-contained in its cursor
//! (see the cursor-invariants note in `solvers/plan.rs`), so which worker
//! advances it, and under which lock regime, is unobservable in the output.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use super::batcher::{Batcher, Pending};
use super::request::{SampleRequest, SampleResult};
use super::{Responder, Shared};
use crate::score::EpsModel;
use crate::solvers::{Solver as _, SolverPlan, StepCursor};
use crate::util::rng::Rng;

/// Queue tag carried through admission: response channel, enqueue time,
/// absolute deadline (if the request set one), and the shared solver plan
/// resolved at submit (so admission does no grid/coefficient work).
pub(super) type Tag = (Responder, Instant, Option<Instant>, Arc<SolverPlan>);

/// One client request inside a trajectory group.
struct FlightPart {
    n: usize,
    /// First row of this request inside the flight's stacked state matrix.
    /// Fixed at admission: expiring another part must not shift the rows a
    /// surviving request receives.
    row0: usize,
    responder: Responder,
    enqueued: Instant,
    deadline: Option<Instant>,
}

/// An in-flight trajectory group: requests admitted together under one
/// batch key, integrating as one cursor over a stacked state matrix.
///
/// A `Flight` lives in exactly one of two places: a `SchedState` slot
/// (pending its next eval, visible to the ready index and the expiry sweep)
/// or checked out by a worker mid-eval (owned, lock-free). The cursor owns
/// every piece of trajectory state, so a checked-out flight needs nothing
/// from the shared state to advance.
struct Flight {
    model_name: Arc<str>,
    model: Arc<dyn EpsModel>,
    cursor: Box<dyn StepCursor>,
    parts: Vec<FlightPart>,
    nfe: usize,
    dim: usize,
    /// Total sample rows (sum of part n's).
    rows: usize,
    /// Peak number of requests co-batched with this flight's evals.
    co_batched_peak: usize,
    /// First eval dispatch (queue_us / solve_us split point).
    started: Option<Instant>,
    /// Earliest enqueue time over parts — the FIFO fairness key.
    oldest: Instant,
}

/// Scheduler state under the coordinator mutex: the admission queue, the
/// flight slots, and the ready index over them. All bookkeeping here is
/// O(1)/O(log n)/O(bucket) per operation — nothing under the mutex scales
/// with rows·dim or with the total flight count.
pub(super) struct SchedState {
    /// Admission queue: key-merged by the [`Batcher`] exactly as before.
    pub(super) queue: Batcher<Tag>,
    flights: Vec<Option<Flight>>,
    /// Per-slot occupancy generation, bumped on every insert; heap entries
    /// carry the generation they were pushed under, so entries for departed
    /// flights are recognizably stale.
    slot_gen: Vec<u64>,
    /// Vacant slot indices (every `None` in `flights` is here exactly once).
    free_slots: Vec<usize>,
    /// Ready index: `(model, pending_t bits) -> slots` pending that eval.
    buckets: HashMap<(Arc<str>, u64), Vec<usize>>,
    /// Min-heap (via `Reverse`) of `(oldest, generation, slot)` over ready
    /// flights; stale entries are skipped/discarded lazily at the top.
    ready: BinaryHeap<Reverse<(Instant, u64, usize)>>,
    /// FlightParts admitted into a slot or checked out by a worker — i.e.
    /// every request past the queue that has not yet been routed to
    /// delivery. Part of the backpressure bound.
    active_parts: usize,
    /// Requests popped from the queue whose flight is being built off-lock
    /// (between `pop_batch` and `insert_flight`). Part of the backpressure
    /// bound so overload cannot slip through mid-admission.
    admitting_parts: usize,
    /// Parts among `active_parts` that carry a deadline. When zero — the
    /// common case — the per-tick expiry sweep exits immediately instead of
    /// walking every slot.
    deadline_parts: usize,
}

impl SchedState {
    pub(super) fn new(max_batch_samples: usize) -> SchedState {
        SchedState {
            queue: Batcher::new(max_batch_samples),
            flights: Vec::new(),
            slot_gen: Vec::new(),
            free_slots: Vec::new(),
            buckets: HashMap::new(),
            ready: BinaryHeap::new(),
            active_parts: 0,
            admitting_parts: 0,
            deadline_parts: 0,
        }
    }

    /// Requests not yet responded to (backpressure accounting): queued,
    /// slotted, checked out mid-eval, or mid-admission. Counter-based —
    /// O(1), no flight scan.
    pub(super) fn inflight_requests(&self) -> usize {
        self.queue.len() + self.active_parts + self.admitting_parts
    }

    /// Slot a pending flight and index it. The one entry point back into
    /// the shared state, used by admission and by workers re-slotting
    /// checked-out flights.
    fn insert_flight(&mut self, f: Flight) {
        let t_bits = f.cursor.pending_t().expect("only pending flights are slotted").to_bits();
        let slot = match self.free_slots.pop() {
            Some(s) => s,
            None => {
                self.flights.push(None);
                self.slot_gen.push(0);
                self.flights.len() - 1
            }
        };
        debug_assert!(self.flights[slot].is_none(), "insert into an occupied slot");
        self.slot_gen[slot] = self.slot_gen[slot].wrapping_add(1);
        self.buckets.entry((f.model_name.clone(), t_bits)).or_default().push(slot);
        self.ready.push(Reverse((f.oldest, self.slot_gen[slot], slot)));
        self.flights[slot] = Some(f);
    }

    /// Unslot a flight (worker checkout or abort): clears the slot, removes
    /// the bucket entry, reclaims the slot. The flight's heap entry is left
    /// to be discarded lazily (the slot's generation no longer matches once
    /// the slot is reused, and a vacant slot fails the occupancy check).
    fn remove_flight(&mut self, slot: usize) -> Flight {
        let f = self.flights[slot].take().expect("removing an empty slot");
        let t_bits = f.cursor.pending_t().expect("slotted flights are always pending").to_bits();
        let key = (f.model_name.clone(), t_bits);
        if let Some(b) = self.buckets.get_mut(&key) {
            if let Some(pos) = b.iter().position(|&s| s == slot) {
                b.swap_remove(pos);
            }
            if b.is_empty() {
                self.buckets.remove(&key);
            }
        }
        self.free_slots.push(slot);
        f
    }

    /// A heap entry is live iff its slot is occupied by the same occupancy
    /// (generation) it was pushed under.
    fn heap_entry_live(&self, gen: u64, slot: usize) -> bool {
        self.flights[slot].is_some() && self.slot_gen[slot] == gen
    }

    /// Ready-index invariant, used by the unit tests after every mutation:
    /// the index covers exactly the slotted flights (all of which have a
    /// pending t), with one live heap entry each; the free list covers
    /// exactly the vacant slots.
    #[cfg(test)]
    fn assert_ready_invariants(&self) {
        for (slot, f) in self.flights.iter().enumerate() {
            match f {
                Some(f) => {
                    let t = f.cursor.pending_t().expect("slotted flight must be pending");
                    let b = self
                        .buckets
                        .get(&(f.model_name.clone(), t.to_bits()))
                        .unwrap_or_else(|| panic!("slot {slot} missing from its bucket"));
                    assert_eq!(
                        b.iter().filter(|&&s| s == slot).count(),
                        1,
                        "slot {slot} must appear in its bucket exactly once"
                    );
                    assert_eq!(
                        self.ready
                            .iter()
                            .filter(|Reverse((o, g, s))| *s == slot
                                && *g == self.slot_gen[slot]
                                && *o == f.oldest)
                            .count(),
                        1,
                        "slot {slot} must have exactly one live heap entry"
                    );
                    assert!(!self.free_slots.contains(&slot), "occupied slot {slot} on free list");
                }
                None => assert_eq!(
                    self.free_slots.iter().filter(|&&s| s == slot).count(),
                    1,
                    "vacant slot {slot} must be on the free list exactly once"
                ),
            }
        }
        for ((name, t_bits), slots) in &self.buckets {
            assert!(!slots.is_empty(), "empty bucket retained for {name}");
            for &s in slots {
                let f = self.flights[s].as_ref().expect("bucket points at a vacant slot");
                assert_eq!(&f.model_name, name, "bucket model mismatch at slot {s}");
                assert_eq!(
                    f.cursor.pending_t().unwrap().to_bits(),
                    *t_bits,
                    "bucket t mismatch at slot {s}"
                );
            }
        }
    }
}

/// A merged ε-eval: the member flights, checked out of their slots and
/// owned by the worker until it re-slots or completes them.
struct GroupJob {
    flights: Vec<Flight>,
    model: Arc<dyn EpsModel>,
    t: f64,
    rows: usize,
    dim: usize,
}

/// Work a scheduler tick hands to the off-lock half of the loop.
enum Work {
    /// A key-merged admission group to build into a flight.
    Admit(Vec<Pending<Tag>>),
    /// A merged eval over checked-out flights.
    Eval(GroupJob),
}

/// Scheduler worker: pick work under the mutex, execute it off-lock.
pub(super) fn worker_loop(sh: Arc<Shared>) {
    // Worker-owned buffers reused across evals (gathered states, merged
    // eps output, broadcast t) — no steady-state allocation on the loop.
    let mut xbuf: Vec<f64> = Vec::new();
    let mut outbuf: Vec<f64> = Vec::new();
    let mut tb: Vec<f64> = Vec::new();
    loop {
        let work = {
            let mut st = sh.state.lock().unwrap();
            loop {
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                expire_deadlines(&mut st, &sh);
                // Admission first: queued groups become schedulable flights
                // before new evals dispatch, so a burst admitted during one
                // stalled eval still merges (and other workers can pick the
                // new flights' evals while this one admits the next group).
                if let Some((_key, group)) = st.queue.pop_batch() {
                    st.admitting_parts += group.len();
                    break Work::Admit(group);
                }
                if let Some(job) = pick_group(&mut st, sh.max_batch_samples) {
                    break Work::Eval(job);
                }
                st = sh.cv.wait(st).unwrap();
            }
        };
        match work {
            Work::Admit(group) => {
                let n_group = group.len();
                // Priors + cursor instantiation (O(rows·dim)) run here,
                // off-lock; the re-lock only slots the result.
                let flight = build_flight(&sh, group);
                {
                    let mut st = sh.state.lock().unwrap();
                    st.admitting_parts -= n_group;
                    if let Some(f) = flight {
                        st.active_parts += f.parts.len();
                        st.deadline_parts +=
                            f.parts.iter().filter(|p| p.deadline.is_some()).count();
                        st.insert_flight(f);
                    }
                }
            }
            Work::Eval(job) => {
                let finished = run_group(&sh, job, &mut xbuf, &mut outbuf, &mut tb);
                for flight in finished {
                    complete_flight(&sh, flight);
                }
            }
        }
        // New flights or re-slotted cursors may be schedulable, and a
        // waiting worker may now find work.
        sh.cv.notify_all();
    }
}

/// Per-request prior draws, deterministic in each request's seed, stacked
/// into one state matrix in part order.
fn draw_priors(group: &[Pending<Tag>], spec: &SampleRequest, d: usize, rows: usize) -> Vec<f64> {
    let mut x = vec![0.0; rows * d];
    let prior = spec.sde.prior_std(1.0);
    let mut offset = 0;
    for p in group {
        let mut rng = Rng::new(p.req.seed);
        for v in x[offset * d..(offset + p.req.n_samples) * d].iter_mut() {
            *v = prior * rng.normal();
        }
        offset += p.req.n_samples;
    }
    x
}

/// Build one admission group into a flight — off-lock. The heavy per-config
/// work (grid + coefficients) arrived prebuilt on the queue tag; what
/// remains is the prior draw and cursor instantiation, which scale with
/// rows·dim and therefore must not run under the coordinator mutex.
/// Returns `None` when every member was refused (expired in the queue, or
/// the model name is unknown) — refusals are answered directly from here.
fn build_flight(sh: &Shared, group: Vec<Pending<Tag>>) -> Option<Flight> {
    // Deadline check at admission: a request that expired while queued
    // gets an error instead of occupying a solver run.
    let now = Instant::now();
    let mut live: Vec<Pending<Tag>> = Vec::with_capacity(group.len());
    for p in group {
        if p.tag.2.is_some_and(|d| d <= now) {
            sh.stats.expired.fetch_add(1, Ordering::Relaxed);
            let _ = p
                .tag
                .0
                .send(Err(anyhow::anyhow!("deadline exceeded while queued")));
        } else {
            live.push(p);
        }
    }
    if live.is_empty() {
        return None;
    }
    let spec = live[0].req.clone();
    let model = match sh.registry.get(&spec.model) {
        Some(m) => m,
        None => {
            for p in live {
                let _ = p
                    .tag
                    .0
                    .send(Err(anyhow::anyhow!("unknown model '{}'", spec.model)));
            }
            return None;
        }
    };
    let d = model.dim();
    // All group members share a batch key, hence the same plan config;
    // the head's Arc is the group's plan.
    let plan = live[0].tag.3.clone();
    let rows: usize = live.iter().map(|p| p.req.n_samples).sum();
    let x = draw_priors(&live, &spec, d, rows);
    let mut oldest = live[0].tag.1;
    let mut row0 = 0;
    let parts: Vec<FlightPart> = live
        .into_iter()
        .map(|p| {
            oldest = oldest.min(p.tag.1);
            let part = FlightPart {
                n: p.req.n_samples,
                row0,
                responder: p.tag.0,
                enqueued: p.tag.1,
                deadline: p.tag.2,
            };
            row0 += p.req.n_samples;
            part
        })
        .collect();
    sh.stats.batches.fetch_add(1, Ordering::Relaxed);
    sh.stats.merged_requests.fetch_add(parts.len() as u64, Ordering::Relaxed);
    // Stochastic solvers clone this stream into their cursor; it is
    // deterministic in the head request's seed, which `tests/scheduler.rs`
    // mirrors for its solo references.
    let mut srng = Rng::new(spec.seed ^ 0xD1F_F051);
    let cursor = plan.solver.cursor(&x, rows, &mut srng);
    Some(Flight {
        model_name: Arc::from(spec.model.as_str()),
        model,
        cursor,
        parts,
        nfe: spec.nfe,
        dim: d,
        rows,
        co_batched_peak: 0,
        started: None,
        oldest,
    })
}

/// Drop expired waiting requests; abort flights nobody is waiting on.
/// Exits immediately when no slotted-or-checked-out part carries a deadline
/// (the common serving case), so the per-tick cost of the sweep is zero
/// unless deadlines are actually in play. Checked-out flights are invisible
/// here by construction — their parts are caught after re-slotting, or at
/// delivery by `complete_flight`.
fn expire_deadlines(st: &mut SchedState, sh: &Shared) {
    if st.deadline_parts == 0 {
        return;
    }
    let now = Instant::now();
    for slot in 0..st.flights.len() {
        let (removed, abort) = match st.flights[slot].as_mut() {
            None => continue,
            Some(f) => {
                let before = f.parts.len();
                f.parts.retain(|part| {
                    if part.deadline.is_some_and(|d| d <= now) {
                        sh.stats.expired.fetch_add(1, Ordering::Relaxed);
                        let _ = part.responder.send(Err(anyhow::anyhow!(
                            "deadline exceeded before sampling completed"
                        )));
                        false
                    } else {
                        true
                    }
                });
                (before - f.parts.len(), f.parts.is_empty())
            }
        };
        // Only deadline-carrying parts can be retained away.
        st.active_parts -= removed;
        st.deadline_parts -= removed;
        if abort {
            // No live requester left: abort the trajectory, reclaiming
            // its remaining eval budget.
            drop(st.remove_flight(slot));
        }
    }
}

/// Choose the next merged eval: the `(model, t)` bucket containing the
/// longest-waiting ready flight, filled in FIFO order up to the sample
/// budget — and **check the members out of their slots**, transferring
/// ownership to the calling worker so gather/eval/scatter/advance all run
/// without the coordinator mutex.
///
/// Anchor selection peeks the ready heap (discarding stale entries at the
/// top) instead of scanning the slots; member gathering reads only the
/// anchor's bucket. Cost: O(log flights + bucket), independent of the total
/// flight count.
fn pick_group(st: &mut SchedState, budget: usize) -> Option<GroupJob> {
    // Anchor: the oldest live ready flight. Peek, don't pop — in the rare
    // tie case where an equally-old bucket mate wins the sort below and the
    // budget excludes the anchor, its entry must survive for the next tick.
    let a = loop {
        let &Reverse((_, gen, slot)) = st.ready.peek()?;
        if st.heap_entry_live(gen, slot) {
            break slot;
        }
        st.ready.pop();
    };
    let (key, t, model, dim) = {
        let f = st.flights[a].as_ref().unwrap();
        let t = f.cursor.pending_t().unwrap();
        ((f.model_name.clone(), t.to_bits()), t, f.model.clone(), f.dim)
    };
    // Every ready flight pending the same (model, t) — the anchor's bucket —
    // oldest first. The anchor is the bucket's (possibly tied) minimum.
    let mut members: Vec<(Instant, usize)> = st.buckets[&key]
        .iter()
        .map(|&s| (st.flights[s].as_ref().unwrap().oldest, s))
        .collect();
    members.sort_unstable();
    let started = Instant::now();
    let mut flights: Vec<Flight> = Vec::with_capacity(members.len());
    let mut rows = 0;
    for (_, slot) in members {
        let f_rows = st.flights[slot].as_ref().unwrap().rows;
        // The first member always dispatches, even oversized; later members
        // must fit the remaining budget.
        if !flights.is_empty() && rows + f_rows > budget {
            continue;
        }
        let mut f = st.remove_flight(slot);
        if f.started.is_none() {
            f.started = Some(started);
        }
        rows += f.rows;
        flights.push(f);
        if rows >= budget {
            break;
        }
    }
    Some(GroupJob { flights, model, t, rows, dim })
}

/// Execute one merged ε-eval over checked-out flights: gather inputs, run
/// the model, scatter the eps slices back and advance every cursor — all
/// without the coordinator mutex (the worker owns the flights). A short
/// re-lock then re-slots still-pending flights; finished ones are returned
/// for delivery (also off-lock).
fn run_group(
    sh: &Shared,
    mut job: GroupJob,
    xbuf: &mut Vec<f64>,
    outbuf: &mut Vec<f64>,
    tb: &mut Vec<f64>,
) -> Vec<Flight> {
    let d = job.dim;
    xbuf.clear();
    xbuf.reserve(job.rows * d);
    for f in job.flights.iter_mut() {
        let (x_in, _) = f.cursor.io();
        xbuf.extend_from_slice(x_in);
    }
    tb.clear();
    tb.resize(job.rows, job.t);
    outbuf.clear();
    outbuf.resize(job.rows * d, 0.0);
    job.model.eval(&xbuf[..job.rows * d], &tb[..], job.rows, &mut outbuf[..]);
    sh.stats.model_evals.fetch_add(1, Ordering::Relaxed);
    let group_reqs: usize = job.flights.iter().map(|f| f.parts.len()).sum();
    sh.stats.record_sched_eval(group_reqs as u64);

    // Scatter + advance: the O(rows·dim) linear combines (and stochastic
    // noise draws) run here, lock-free.
    let mut offset = 0;
    for f in job.flights.iter_mut() {
        let rows = f.rows;
        {
            let (_x, out) = f.cursor.io();
            out.copy_from_slice(&outbuf[offset * d..(offset + rows) * d]);
        }
        f.cursor.advance();
        f.co_batched_peak = f.co_batched_peak.max(group_reqs);
        offset += rows;
    }

    // Short re-lock: route each flight back to a slot or out to delivery.
    let mut finished: Vec<Flight> = Vec::new();
    {
        let mut st = sh.state.lock().unwrap();
        for f in job.flights {
            if f.cursor.pending_t().is_some() {
                st.insert_flight(f);
            } else {
                st.active_parts -= f.parts.len();
                st.deadline_parts -= f.parts.iter().filter(|p| p.deadline.is_some()).count();
                finished.push(f);
            }
        }
    }
    finished
}

/// Deliver a finished flight: slice the stacked samples back into
/// per-request results. The deadline contract holds through delivery: a
/// part whose deadline fired while the flight was checked out in its final
/// evals (where `expire_deadlines` cannot see it) gets an error, not late
/// samples.
fn complete_flight(sh: &Shared, mut flight: Flight) {
    let samples = flight.cursor.take_samples();
    let d = flight.dim;
    let solve_end = Instant::now();
    let started = flight.started.unwrap_or(solve_end);
    let merged = flight.parts.len();
    for part in flight.parts {
        if part.deadline.is_some_and(|dl| dl <= solve_end) {
            sh.stats.expired.fetch_add(1, Ordering::Relaxed);
            let _ = part.responder.send(Err(anyhow::anyhow!(
                "deadline exceeded before sampling completed"
            )));
            continue;
        }
        // Slice by the admission-time row offset, not cumulatively: parts
        // expired mid-flight leave holes, and surviving requests must still
        // get exactly their own rows.
        let res = SampleResult {
            samples: samples[part.row0 * d..(part.row0 + part.n) * d].to_vec(),
            dim: d,
            nfe: flight.nfe,
            merged_with: merged,
            co_batched: flight.co_batched_peak,
            queue_us: started.duration_since(part.enqueued).as_micros() as u64,
            solve_us: solve_end.duration_since(started).as_micros() as u64,
        };
        // Count rows per DELIVERED part (not per finished flight): parts
        // expired at delivery or mid-flight contribute no samples, keeping
        // `samples` consistent with `completed`.
        sh.stats.samples.fetch_add(part.n as u64, Ordering::Relaxed);
        sh.stats.completed.fetch_add(1, Ordering::Relaxed);
        sh.stats.record_latency(part.enqueued.elapsed().as_micros() as u64);
        let _ = part.responder.send(Ok(res));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ModelRegistry;
    use crate::coordinator::Stats;
    use crate::diffusion::Sde;
    use crate::gmm::Gmm;
    use crate::score::GmmEps;
    use crate::solvers::SolverKind;
    use crate::timegrid::GridKind;
    use std::sync::mpsc::{sync_channel, Receiver};
    use std::sync::{atomic::AtomicBool, Condvar, Mutex};
    use std::time::Duration;

    type Rx = Receiver<anyhow::Result<SampleResult>>;

    /// A slottable flight over the analytic oracle with `n` rows, one part.
    /// `name` controls the index bucket: every cursor's FIRST pending t is
    /// t_N = T = 1.0 regardless of NFE, so same-name flights always start in
    /// one bucket — use a different name to force a separate bucket.
    fn test_flight(
        name: &str,
        seed: u64,
        nfe: usize,
        n: usize,
        deadline: Option<Instant>,
    ) -> (Flight, Rx) {
        let model: Arc<dyn EpsModel> =
            Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp()));
        let plan = SolverPlan::build(&Sde::vp(), SolverKind::Tab(1), GridKind::Quadratic, 1e-3, nfe);
        let d = model.dim();
        let mut rng = Rng::new(seed);
        let x = rng.normal_vec(n * d);
        let mut srng = Rng::new(seed ^ 0xD1F_F051);
        let cursor = plan.solver.cursor(&x, n, &mut srng);
        let (tx, rx) = sync_channel(1);
        let now = Instant::now();
        let flight = Flight {
            model_name: Arc::from(name),
            model,
            cursor,
            parts: vec![FlightPart { n, row0: 0, responder: tx, enqueued: now, deadline }],
            nfe,
            dim: d,
            rows: n,
            co_batched_peak: 0,
            started: None,
            oldest: now,
        };
        (flight, rx)
    }

    fn slot_in(st: &mut SchedState, f: Flight) {
        st.active_parts += f.parts.len();
        st.deadline_parts += f.parts.iter().filter(|p| p.deadline.is_some()).count();
        st.insert_flight(f);
    }

    #[test]
    fn ready_index_invariants_hold_across_mutations() {
        let mut st = SchedState::new(1024);
        let mut rxs = Vec::new();
        // Insert: two same-model flights (shared bucket — every fresh cursor
        // pends t_N = 1.0) plus one under a different model name, which is
        // the only way a fresh flight lands in a separate bucket.
        for (name, seed, nfe, n) in
            [("gmm2d", 1u64, 6usize, 2usize), ("gmm2d", 2, 6, 3), ("other", 3, 9, 2)]
        {
            let (f, rx) = test_flight(name, seed, nfe, n, None);
            slot_in(&mut st, f);
            rxs.push(rx);
            st.assert_ready_invariants();
        }
        assert_eq!(st.inflight_requests(), 3);

        // Checkout: the whole oldest bucket leaves slots and index alike.
        let job = pick_group(&mut st, 1024).expect("ready flights must be pickable");
        st.assert_ready_invariants();
        assert_eq!(job.flights.len(), 2, "same-(model,t) flights must group");
        assert_eq!(job.rows, 5);
        assert_eq!(st.inflight_requests(), 3, "checked-out parts still count as inflight");

        // Advance off-index (zero eps is numerically fine here — only the
        // index bookkeeping is under test), then re-slot.
        let mut flights = job.flights;
        for f in flights.iter_mut() {
            {
                let (_x, out) = f.cursor.io();
                for v in out.iter_mut() {
                    *v = 0.0;
                }
            }
            f.cursor.advance();
        }
        for f in flights {
            assert!(f.cursor.pending_t().is_some(), "nfe 6 has more than one step");
            st.insert_flight(f);
            st.assert_ready_invariants();
        }

        // The re-slotted pair advanced to a NEW t: three flights, all
        // indexed, two buckets again.
        assert_eq!(st.buckets.len(), 2);

        // Abort: removal leaves no dangling bucket or free-list entry.
        let occupied: Vec<usize> =
            (0..st.flights.len()).filter(|&s| st.flights[s].is_some()).collect();
        let victim = occupied[0];
        let parts = st.flights[victim].as_ref().unwrap().parts.len();
        st.active_parts -= parts;
        drop(st.remove_flight(victim));
        st.assert_ready_invariants();

        // Freed slots are reused before the table grows.
        let len_before = st.flights.len();
        let (f, rx) = test_flight("gmm2d", 9, 6, 1, None);
        slot_in(&mut st, f);
        rxs.push(rx);
        st.assert_ready_invariants();
        assert_eq!(st.flights.len(), len_before, "admission must reuse the freed slot");
    }

    #[test]
    fn pick_group_is_fifo_and_respects_budget() {
        let mut st = SchedState::new(1024);
        let mut rxs = Vec::new();
        // Three bucket-mates with rows 1, 2, 3, inserted oldest-first.
        for (seed, n) in [(1u64, 1usize), (2, 2), (3, 3)] {
            let (f, rx) = test_flight("gmm2d", seed, 6, n, None);
            slot_in(&mut st, f);
            rxs.push(rx);
        }
        // Budget 3: flights 1 and 2 fit (rows 1+2), flight 3 must wait.
        let job = pick_group(&mut st, 3).unwrap();
        assert_eq!(
            job.flights.iter().map(|f| f.rows).collect::<Vec<_>>(),
            vec![1, 2],
            "FIFO selection under the sample budget"
        );
        st.assert_ready_invariants();
        // The leftover flight is the next anchor, oversized or not.
        let job2 = pick_group(&mut st, 1).unwrap();
        assert_eq!(job2.flights.len(), 1);
        assert_eq!(job2.flights[0].rows, 3, "anchor dispatches even over budget");
        st.assert_ready_invariants();
        assert!(pick_group(&mut st, 1024).is_none(), "no ready flights left");
    }

    fn bare_shared() -> Shared {
        Shared {
            state: Mutex::new(SchedState::new(64)),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            registry: ModelRegistry::new(),
            stats: Stats::default(),
            max_batch_samples: 64,
            max_inflight: 1024,
            plan_cache: crate::solvers::PlanCache::new(),
        }
    }

    #[test]
    fn expiry_sweep_skips_when_no_deadlines_and_aborts_empty_flights() {
        let sh = bare_shared();
        let mut st = sh.state.lock().unwrap();
        let (f, _rx_live) = test_flight("gmm2d", 1, 6, 2, None);
        slot_in(&mut st, f);
        // No deadline parts anywhere: the sweep must be a no-op (and in
        // particular must not walk or disturb the index).
        expire_deadlines(&mut st, &sh);
        st.assert_ready_invariants();
        assert_eq!(sh.stats.snapshot().expired, 0);

        // A flight whose only part is already expired: swept, answered,
        // aborted, slot reclaimed.
        let (f, rx) =
            test_flight("gmm2d", 2, 6, 2, Some(Instant::now() - Duration::from_millis(1)));
        slot_in(&mut st, f);
        expire_deadlines(&mut st, &sh);
        st.assert_ready_invariants();
        assert_eq!(sh.stats.snapshot().expired, 1);
        assert_eq!(st.deadline_parts, 0);
        assert_eq!(st.inflight_requests(), 1, "only the live flight remains");
        let err = rx.try_recv().expect("expired part must be answered synchronously");
        assert!(err.is_err(), "expired part must receive an error");
    }
}
