//! Step-level cross-request batching scheduler.
//!
//! The old coordinator merged requests only at admission: requests that
//! arrived in the same tick with an identical batch key were stacked into
//! one solver run, and every trajectory otherwise paid for its ε-evaluations
//! alone. This module keeps that admission-time merge (it is what makes
//! bursts of identical requests cheap) and adds the step-level layer the
//! paper's cost model actually calls for: solvers are resumable
//! [`StepCursor`] machines that *yield* their pending ε-evals, and the
//! scheduler collects pending evals across **all** in-flight trajectory
//! groups, buckets them by `(model, t)`, and dispatches one merged network
//! call per bucket.
//!
//! Why `(model, t)`: every cursor eval broadcasts one scalar t, so a merged
//! bucket is uniform-t and takes the native engine's shared-embedding fast
//! path (one time-embedding fold per call, `score/native.rs`). Because grid
//! nodes are a pure function of (grid kind, NFE, t0, sde), trajectory groups
//! admitted in the same tick with the same grid stay in lockstep and merge
//! on *every* step — including across different solvers (e.g. ddim and tab3
//! at the same NFE share all their nodes), which admission-keyed merging
//! could never do. All trajectories also share their very first node
//! t_N = T, so even different-NFE groups admitted together merge their first
//! eval.
//!
//! Scheduling policy: pick the bucket containing the longest-waiting
//! trajectory group (FIFO fairness keeps lockstep groups together), cap it
//! at `max_batch_samples`, run the eval outside the lock, then scatter the
//! eps slices back through each cursor and advance it. Cursorization is
//! universal — adaptive RK45, the ρRK stage schemes, s-param EI and the
//! stochastic samplers are all resumable — so there is no blocking
//! whole-trajectory path left: every request is co-batchable.
//!
//! Admission is deliberately thin: the (grid, coefficients) plan a flight
//! needs is resolved in `Coordinator::submit` through the shared
//! [`PlanCache`](crate::solvers::cache::PlanCache) and rides the queue tag,
//! so under the coordinator mutex admission only draws priors and
//! instantiates a cursor. No quadrature, no grid construction, no panic
//! risk under the lock.
//!
//! Determinism: for deterministic solvers a request's samples depend only
//! on its (seed, n, config) — per-request prior RNG streams, and per-row
//! model math independent of batch composition — so scheduled,
//! admission-merged and solo runs are bit-identical
//! (`rust/tests/scheduler.rs` pins this). Stochastic flights draw noise
//! only inside `advance`, from a cursor-owned stream seeded by the flight's
//! HEAD request, so step-level co-batching with strangers never perturbs
//! the noise — scheduled == solo holds for any stochastic request that is
//! not admission-merged. Two caveats, both inherited from the old blocking
//! path (which also ran the solver over the stacked rows): same-config
//! stochastic requests admission-merged in one tick share the head's noise
//! stream, and batch-coupled estimators span the merged rows — A-DDIM's Γ
//! estimate and rk45's RMS error norm (hence its accept/reject sequence)
//! are computed over the whole flight. A merged non-head request of those
//! solvers can therefore differ from its solo run; fully deterministic
//! per-row solvers (everything else) are bit-identical merged or not.
//!
//! Known tradeoff: the post-eval scatter + `advance()` (the solver's linear
//! combination, O(rows·dim)) runs under the coordinator mutex. That is 2–3
//! orders of magnitude cheaper than the network eval it follows
//! (O(rows·dim·hidden²)), but it does serialize across workers; if profiles
//! ever show contention here, the fix is to take the member flights out of
//! their slots (they are already marked busy), advance outside the lock,
//! and reinsert — tracked in ROADMAP.md.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use super::batcher::{Batcher, Pending};
use super::request::{SampleRequest, SampleResult};
use super::{Responder, Shared};
use crate::score::EpsModel;
use crate::solvers::{Solver as _, SolverPlan, StepCursor};
use crate::util::rng::Rng;

/// Queue tag carried through admission: response channel, enqueue time,
/// absolute deadline (if the request set one), and the shared solver plan
/// resolved at submit (so admission does no grid/coefficient work).
pub(super) type Tag = (Responder, Instant, Option<Instant>, Arc<SolverPlan>);

/// One client request inside a trajectory group.
struct FlightPart {
    n: usize,
    /// First row of this request inside the flight's stacked state matrix.
    /// Fixed at admission: expiring another part must not shift the rows a
    /// surviving request receives.
    row0: usize,
    responder: Responder,
    enqueued: Instant,
    deadline: Option<Instant>,
}

/// An in-flight trajectory group: requests admitted together under one
/// batch key, integrating as one cursor over a stacked state matrix.
struct Flight {
    model_name: String,
    model: Arc<dyn EpsModel>,
    cursor: Box<dyn StepCursor>,
    parts: Vec<FlightPart>,
    nfe: usize,
    dim: usize,
    /// Total sample rows (sum of part n's).
    rows: usize,
    /// Peak number of requests co-batched with this flight's evals.
    co_batched_peak: usize,
    /// True while a worker holds this flight's rows in a merged eval.
    busy: bool,
    /// First eval dispatch (queue_us / solve_us split point).
    started: Option<Instant>,
    /// Earliest enqueue time over parts — the FIFO fairness key.
    oldest: Instant,
}

/// Scheduler state under the coordinator mutex.
pub(super) struct SchedState {
    /// Admission queue: key-merged by the [`Batcher`] exactly as before.
    pub(super) queue: Batcher<Tag>,
    flights: Vec<Option<Flight>>,
}

impl SchedState {
    pub(super) fn new(max_batch_samples: usize) -> SchedState {
        SchedState { queue: Batcher::new(max_batch_samples), flights: Vec::new() }
    }

    /// Requests not yet responded to (backpressure accounting).
    pub(super) fn inflight_requests(&self) -> usize {
        self.queue.len()
            + self
                .flights
                .iter()
                .flatten()
                .map(|f| f.parts.len())
                .sum::<usize>()
    }
}

/// A merged ε-eval covering every flight in `idx` at scalar time `t`.
struct GroupJob {
    idx: Vec<usize>,
    model: Arc<dyn EpsModel>,
    t: f64,
    rows: usize,
    dim: usize,
}

/// Scheduler worker: admit -> pick merged eval -> execute.
pub(super) fn worker_loop(sh: Arc<Shared>) {
    // Worker-owned buffers reused across evals (gathered states, merged
    // eps output, broadcast t) — no steady-state allocation on the loop.
    let mut xbuf: Vec<f64> = Vec::new();
    let mut outbuf: Vec<f64> = Vec::new();
    let mut tb: Vec<f64> = Vec::new();
    loop {
        let job = {
            let mut st = sh.state.lock().unwrap();
            loop {
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                expire_deadlines(&mut st, &sh);
                admit(&mut st, &sh);
                if let Some(job) = pick_group(&mut st, &sh, &mut xbuf) {
                    break job;
                }
                st = sh.cv.wait(st).unwrap();
            }
        };
        run_group(&sh, job, &xbuf, &mut outbuf, &mut tb);
        // Completed or unblocked flights may be schedulable again, and a
        // waiting worker may now find work.
        sh.cv.notify_all();
    }
}

/// Per-request prior draws, deterministic in each request's seed, stacked
/// into one state matrix in part order.
fn draw_priors(group: &[Pending<Tag>], spec: &SampleRequest, d: usize, rows: usize) -> Vec<f64> {
    let mut x = vec![0.0; rows * d];
    let prior = spec.sde.prior_std(1.0);
    let mut offset = 0;
    for p in group {
        let mut rng = Rng::new(p.req.seed);
        for v in x[offset * d..(offset + p.req.n_samples) * d].iter_mut() {
            *v = prior * rng.normal();
        }
        offset += p.req.n_samples;
    }
    x
}

/// Drain the admission queue into flights. The heavy per-config work (grid
/// + coefficients) arrived prebuilt on the queue tag, so each group costs
/// one prior draw and one cursor instantiation — cheap enough for the
/// coordinator mutex.
fn admit(st: &mut SchedState, sh: &Shared) {
    while let Some((_key, group)) = st.queue.pop_batch() {
        // Deadline check at admission: a request that expired while queued
        // gets an error instead of occupying a solver run.
        let now = Instant::now();
        let mut live: Vec<Pending<Tag>> = Vec::with_capacity(group.len());
        for p in group {
            if p.tag.2.is_some_and(|d| d <= now) {
                sh.stats.expired.fetch_add(1, Ordering::Relaxed);
                let _ = p
                    .tag
                    .0
                    .send(Err(anyhow::anyhow!("deadline exceeded while queued")));
            } else {
                live.push(p);
            }
        }
        if live.is_empty() {
            continue;
        }
        let spec = live[0].req.clone();
        let model = match sh.registry.get(&spec.model) {
            Some(m) => m,
            None => {
                for p in live {
                    let _ = p
                        .tag
                        .0
                        .send(Err(anyhow::anyhow!("unknown model '{}'", spec.model)));
                }
                continue;
            }
        };
        let d = model.dim();
        // All group members share a batch key, hence the same plan config;
        // the head's Arc is the group's plan.
        let plan = live[0].tag.3.clone();
        let rows: usize = live.iter().map(|p| p.req.n_samples).sum();
        let x = draw_priors(&live, &spec, d, rows);
        let mut oldest = live[0].tag.1;
        let mut row0 = 0;
        let parts: Vec<FlightPart> = live
            .into_iter()
            .map(|p| {
                oldest = oldest.min(p.tag.1);
                let part = FlightPart {
                    n: p.req.n_samples,
                    row0,
                    responder: p.tag.0,
                    enqueued: p.tag.1,
                    deadline: p.tag.2,
                };
                row0 += p.req.n_samples;
                part
            })
            .collect();
        sh.stats.batches.fetch_add(1, Ordering::Relaxed);
        sh.stats.merged_requests.fetch_add(parts.len() as u64, Ordering::Relaxed);
        // Stochastic solvers clone this stream into their cursor; it is
        // deterministic in the head request's seed, which `tests/scheduler.rs`
        // mirrors for its solo references.
        let mut srng = Rng::new(spec.seed ^ 0xD1F_F051);
        let cursor = plan.solver.cursor(&x, rows, &mut srng);
        let flight = Flight {
            model_name: spec.model.clone(),
            model,
            cursor,
            parts,
            nfe: spec.nfe,
            dim: d,
            rows,
            co_batched_peak: 0,
            busy: false,
            started: None,
            oldest,
        };
        match st.flights.iter_mut().find(|s| s.is_none()) {
            Some(slot) => *slot = Some(flight),
            None => st.flights.push(Some(flight)),
        }
    }
}

/// Drop expired waiting requests; abort flights nobody is waiting on.
/// In-place (`retain`): the common no-deadline sweep allocates nothing —
/// this runs on every scheduler tick under the coordinator mutex.
fn expire_deadlines(st: &mut SchedState, sh: &Shared) {
    let now = Instant::now();
    for slot in st.flights.iter_mut() {
        if let Some(f) = slot {
            if f.busy {
                continue;
            }
            f.parts.retain(|part| {
                if part.deadline.is_some_and(|d| d <= now) {
                    sh.stats.expired.fetch_add(1, Ordering::Relaxed);
                    let _ = part.responder.send(Err(anyhow::anyhow!(
                        "deadline exceeded before sampling completed"
                    )));
                    false
                } else {
                    true
                }
            });
            if f.parts.is_empty() {
                // No live requester left: abort the trajectory, reclaiming
                // its remaining eval budget.
                *slot = None;
            }
        }
    }
}

/// Choose the next merged eval: the `(model, t)` bucket containing the
/// longest-waiting ready flight, filled in FIFO order up to the sample
/// budget. Marks members busy and gathers their input rows into `xbuf`.
fn pick_group(st: &mut SchedState, sh: &Shared, xbuf: &mut Vec<f64>) -> Option<GroupJob> {
    let mut anchor: Option<usize> = None;
    for (i, f) in st.flights.iter().enumerate() {
        if let Some(f) = f {
            if !f.busy && f.cursor.pending_t().is_some() {
                let better = match anchor {
                    Some(a) => f.oldest < st.flights[a].as_ref().unwrap().oldest,
                    None => true,
                };
                if better {
                    anchor = Some(i);
                }
            }
        }
    }
    let a = anchor?;
    let (name, t, model, dim) = {
        let f = st.flights[a].as_ref().unwrap();
        (f.model_name.clone(), f.cursor.pending_t().unwrap(), f.model.clone(), f.dim)
    };
    // Every ready flight pending the same (model, t), oldest first.
    let mut members: Vec<(usize, Instant)> = st
        .flights
        .iter()
        .enumerate()
        .filter_map(|(i, f)| f.as_ref().map(|f| (i, f)))
        .filter(|(_, f)| {
            !f.busy
                && f.model_name == name
                && f.cursor.pending_t().map(f64::to_bits) == Some(t.to_bits())
        })
        .map(|(i, f)| (i, f.oldest))
        .collect();
    members.sort_by_key(|&(_, oldest)| oldest);
    let budget = sh.max_batch_samples;
    let mut idx = Vec::with_capacity(members.len());
    let mut rows = 0;
    for (i, _) in members {
        let f_rows = st.flights[i].as_ref().unwrap().rows;
        // The anchor always dispatches, even oversized; later members must
        // fit the remaining budget.
        if !idx.is_empty() && rows + f_rows > budget {
            continue;
        }
        idx.push(i);
        rows += f_rows;
        if rows >= budget {
            break;
        }
    }
    let started = Instant::now();
    xbuf.clear();
    xbuf.reserve(rows * dim);
    for &i in &idx {
        let f = st.flights[i].as_mut().unwrap();
        f.busy = true;
        if f.started.is_none() {
            f.started = Some(started);
        }
        let (x_in, _) = f.cursor.io();
        xbuf.extend_from_slice(x_in);
    }
    Some(GroupJob { idx, model, t, rows, dim })
}

/// Execute one merged ε-eval and scatter the results back through the
/// member cursors.
fn run_group(sh: &Shared, job: GroupJob, xbuf: &[f64], outbuf: &mut Vec<f64>, tb: &mut Vec<f64>) {
    let d = job.dim;
    tb.clear();
    tb.resize(job.rows, job.t);
    outbuf.clear();
    outbuf.resize(job.rows * d, 0.0);
    job.model.eval(&xbuf[..job.rows * d], tb, job.rows, outbuf);
    sh.stats.model_evals.fetch_add(1, Ordering::Relaxed);

    let mut finished: Vec<Flight> = Vec::new();
    {
        let mut st = sh.state.lock().unwrap();
        let group_reqs: usize =
            job.idx.iter().map(|&i| st.flights[i].as_ref().unwrap().parts.len()).sum();
        sh.stats.record_sched_eval(group_reqs as u64);
        let mut offset = 0;
        for &i in &job.idx {
            let f = st.flights[i].as_mut().unwrap();
            let rows = f.rows;
            {
                let (_x, out) = f.cursor.io();
                out.copy_from_slice(&outbuf[offset * d..(offset + rows) * d]);
            }
            f.cursor.advance();
            f.busy = false;
            f.co_batched_peak = f.co_batched_peak.max(group_reqs);
            offset += rows;
            if f.cursor.pending_t().is_none() {
                finished.push(st.flights[i].take().unwrap());
            }
        }
    }
    for flight in finished {
        complete_flight(sh, flight);
    }
}

/// Deliver a finished flight: slice the stacked samples back into
/// per-request results. The deadline contract holds through delivery: a
/// part whose deadline fired while the flight was busy in its final evals
/// (where `expire_deadlines` cannot touch it) gets an error, not late
/// samples.
fn complete_flight(sh: &Shared, mut flight: Flight) {
    let samples = flight.cursor.take_samples();
    let d = flight.dim;
    let solve_end = Instant::now();
    let started = flight.started.unwrap_or(solve_end);
    let merged = flight.parts.len();
    sh.stats.samples.fetch_add(flight.rows as u64, Ordering::Relaxed);
    for part in flight.parts {
        if part.deadline.is_some_and(|dl| dl <= solve_end) {
            sh.stats.expired.fetch_add(1, Ordering::Relaxed);
            let _ = part.responder.send(Err(anyhow::anyhow!(
                "deadline exceeded before sampling completed"
            )));
            continue;
        }
        // Slice by the admission-time row offset, not cumulatively: parts
        // expired mid-flight leave holes, and surviving requests must still
        // get exactly their own rows.
        let res = SampleResult {
            samples: samples[part.row0 * d..(part.row0 + part.n) * d].to_vec(),
            dim: d,
            nfe: flight.nfe,
            merged_with: merged,
            co_batched: flight.co_batched_peak,
            queue_us: started.duration_since(part.enqueued).as_micros() as u64,
            solve_us: solve_end.duration_since(started).as_micros() as u64,
        };
        sh.stats.completed.fetch_add(1, Ordering::Relaxed);
        sh.stats.record_latency(part.enqueued.elapsed().as_micros() as u64);
        let _ = part.responder.send(Ok(res));
    }
}

