//! Request/response types and the batch-compatibility key.

use crate::diffusion::Sde;
use crate::score::Precision;
use crate::solvers::SolverKind;
use crate::timegrid::GridKind;

/// A sampling request as submitted by a client.
#[derive(Clone, Debug)]
pub struct SampleRequest {
    /// Model name in the registry ("gmm2d", "gmm2d_exact", "img8", ...).
    pub model: String,
    pub sde: Sde,
    pub solver: SolverKind,
    pub grid: GridKind,
    /// Sampling end time (t0 > 0; see App. H.1).
    pub t0: f64,
    /// NFE budget; the solver's step count is derived from it.
    pub nfe: usize,
    pub n_samples: usize,
    pub seed: u64,
    /// Optional per-request deadline, relative to submission. A request
    /// still queued (or still integrating) when it expires receives an
    /// error instead of samples, and its trajectory is aborted if no other
    /// request shares it. The contract is enforced *at delivery*: even if
    /// the deadline fires while the request's flight is checked out by a
    /// worker for an off-lock eval (where the expiry sweep cannot see it),
    /// the reply is still an error, never late samples. Not part of the
    /// batch key.
    pub deadline_ms: Option<u64>,
    /// Inference precision. F64 (default) runs the model as registered;
    /// F32 routes to the model's "<name>@f32" registry sibling at submit
    /// time (see `Coordinator::submit`), so the batch key needs no extra
    /// field — the rewritten model name carries the dtype.
    pub dtype: Precision,
}

impl SampleRequest {
    pub fn new(model: &str, solver: SolverKind, nfe: usize, n_samples: usize) -> Self {
        let sde = Sde::vp();
        SampleRequest {
            model: model.to_string(),
            sde,
            solver,
            grid: GridKind::Quadratic,
            t0: sde.t0_default(),
            nfe,
            n_samples,
            seed: 0,
            deadline_ms: None,
            dtype: Precision::default(),
        }
    }

    /// Two requests may share one solver run iff their keys match: same
    /// model, dynamics, solver config and grid — then their states can be
    /// stacked into one batch and stepped together.
    pub fn batch_key(&self) -> BatchKey {
        BatchKey {
            model: self.model.clone(),
            sde: self.sde.key_bits(),
            solver: self.solver,
            grid: self.grid.key_bits(),
            t0_bits: self.t0.to_bits(),
            nfe: self.nfe,
        }
    }
}

/// Batch-compatibility key. The f64-parameterized parts enter as bit
/// patterns ([`crate::diffusion::Sde::key_bits`],
/// [`crate::timegrid::GridKind::key_bits`]) so key construction under the
/// coordinator mutex costs one String clone (the model name), not Debug
/// formatting.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct BatchKey {
    pub model: String,
    pub sde: (u8, u64, u64),
    pub solver: SolverKind,
    pub grid: (u8, u64),
    pub t0_bits: u64,
    pub nfe: usize,
}

/// Result delivered to the requester.
#[derive(Clone, Debug)]
pub struct SampleResult {
    /// Row-major [n_samples * dim].
    pub samples: Vec<f64>,
    pub dim: usize,
    /// NFE actually spent by the merged run (per trajectory).
    pub nfe: usize,
    /// How many requests shared the solver run (admission-time merge).
    pub merged_with: usize,
    /// Peak number of requests whose ε-evaluations were co-batched with
    /// this one by the step-level scheduler. Every solver is scheduled, so
    /// this is always >= merged_with (>= 1).
    pub co_batched: usize,
    /// Submission to the flight's first eval checkout.
    pub queue_us: u64,
    /// First eval checkout to delivery (the integration itself).
    pub solve_us: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_key_groups_compatible_requests() {
        let a = SampleRequest::new("gmm2d", SolverKind::Tab(3), 10, 100);
        let mut b = a.clone();
        b.n_samples = 7; // size may differ
        b.seed = 99; // seed may differ
        assert_eq!(a.batch_key(), b.batch_key());

        let mut c = a.clone();
        c.nfe = 20;
        assert_ne!(a.batch_key(), c.batch_key());
        let mut d = a.clone();
        d.solver = SolverKind::Tab(2);
        assert_ne!(a.batch_key(), d.batch_key());
        let mut e = a.clone();
        e.grid = GridKind::LogRho;
        assert_ne!(a.batch_key(), e.batch_key());
        let mut f = a.clone();
        f.t0 = 1e-4;
        assert_ne!(a.batch_key(), f.batch_key());
    }
}
