//! Dynamic batcher: groups queued requests with equal [`BatchKey`] into one
//! solver run, bounded by a sample budget. FIFO across keys (the head of the
//! queue picks the key), FIFO within a key — property-tested invariants:
//! every submitted request is dispatched exactly once, merged requests
//! always share a key, and no merged batch exceeds the budget unless a
//! single oversized request forces it.

use std::collections::VecDeque;

use super::request::{BatchKey, SampleRequest};

pub struct Pending<T> {
    pub req: SampleRequest,
    pub tag: T,
    pub enqueued: std::time::Instant,
}

pub struct Batcher<T> {
    queue: VecDeque<Pending<T>>,
    pub max_batch_samples: usize,
}

impl<T> Batcher<T> {
    pub fn new(max_batch_samples: usize) -> Self {
        Batcher { queue: VecDeque::new(), max_batch_samples: max_batch_samples.max(1) }
    }

    pub fn push(&mut self, req: SampleRequest, tag: T) {
        self.queue.push_back(Pending { req, tag, enqueued: std::time::Instant::now() });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Pop the next merged batch: the oldest request plus every later
    /// request with the same key, until the sample budget fills.
    /// Returns (key, requests) or None if idle.
    pub fn pop_batch(&mut self) -> Option<(BatchKey, Vec<Pending<T>>)> {
        let head = self.queue.pop_front()?;
        let key = head.req.batch_key();
        let mut total = head.req.n_samples;
        let mut group = vec![head];
        let mut rest = VecDeque::with_capacity(self.queue.len());
        while let Some(p) = self.queue.pop_front() {
            if total < self.max_batch_samples
                && p.req.batch_key() == key
                && total + p.req.n_samples <= self.max_batch_samples
            {
                total += p.req.n_samples;
                group.push(p);
            } else {
                rest.push_back(p);
            }
        }
        self.queue = rest;
        Some((key, group))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::SolverKind;
    use crate::util::{prop::run_prop, rng::Rng};

    fn req(model: &str, solver: SolverKind, nfe: usize, n: usize) -> SampleRequest {
        SampleRequest::new(model, solver, nfe, n)
    }

    #[test]
    fn merges_same_key_fifo() {
        let mut b: Batcher<usize> = Batcher::new(1000);
        b.push(req("m", SolverKind::Tab(3), 10, 10), 0);
        b.push(req("m", SolverKind::Tab(2), 10, 10), 1);
        b.push(req("m", SolverKind::Tab(3), 10, 20), 2);
        let (_, g) = b.pop_batch().unwrap();
        assert_eq!(g.iter().map(|p| p.tag).collect::<Vec<_>>(), vec![0, 2]);
        let (_, g2) = b.pop_batch().unwrap();
        assert_eq!(g2[0].tag, 1);
        assert!(b.pop_batch().is_none());
    }

    #[test]
    fn respects_sample_budget() {
        let mut b: Batcher<usize> = Batcher::new(25);
        for i in 0..5 {
            b.push(req("m", SolverKind::Tab(3), 10, 10), i);
        }
        let (_, g) = b.pop_batch().unwrap();
        assert_eq!(g.len(), 2, "10+10 fits, +10 would exceed 25");
        // skipped requests retain order
        let (_, g2) = b.pop_batch().unwrap();
        assert_eq!(g2.iter().map(|p| p.tag).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn oversized_single_request_still_dispatches() {
        let mut b: Batcher<usize> = Batcher::new(16);
        b.push(req("m", SolverKind::Tab(3), 10, 1000), 0);
        let (_, g) = b.pop_batch().unwrap();
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].req.n_samples, 1000);
    }

    #[test]
    fn prop_every_request_dispatched_once_with_matching_key() {
        run_prop("batcher bijection", 29, 40, |rng: &mut Rng| {
            let mut b: Batcher<usize> = Batcher::new(1 + rng.below(100));
            let n = 1 + rng.below(40);
            for i in 0..n {
                let model = ["a", "b"][rng.below(2)];
                let solver = [SolverKind::Tab(3), SolverKind::RhoHeun][rng.below(2)];
                let nfe = [10, 20][rng.below(2)];
                b.push(req(model, solver, nfe, 1 + rng.below(30)), i);
            }
            let mut seen = vec![false; n];
            while let Some((key, group)) = b.pop_batch() {
                let budget_ok = group.iter().map(|p| p.req.n_samples).sum::<usize>()
                    <= b.max_batch_samples
                    || group.len() == 1;
                assert!(budget_ok, "budget violated by a merged batch");
                for p in group {
                    assert_eq!(p.req.batch_key(), key, "mixed keys in one batch");
                    assert!(!seen[p.tag], "request {} dispatched twice", p.tag);
                    seen[p.tag] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "some requests never dispatched");
        });
    }
}
