//! Dynamic batcher: groups queued requests with equal [`BatchKey`] into one
//! solver run, bounded by a sample budget. FIFO across keys (the oldest
//! queued request picks the key), FIFO within a key — property-tested
//! invariants: every submitted request is dispatched exactly once, merged
//! requests always share a key, and no merged batch exceeds the budget
//! unless a single oversized request forces it.
//!
//! # Complexity
//!
//! The queue is indexed by key: every pending request lives in its key's
//! FIFO *lane* (`lanes`), and `key_fifo` orders the nonempty lanes by when
//! they last became nonempty — so the front lane's head is always the
//! globally oldest pending request. `pop_batch` therefore costs O(front
//! lane) per pop: it packs the front lane up to the sample budget and never
//! looks at any other lane. The previous implementation popped and
//! re-pushed the *entire* queue to find same-key requests — O(queue) per
//! pop, recomputing every request's `batch_key()` along the way — which
//! made a deep mixed-key queue quadratic to drain. The grouping semantics
//! are unchanged: a lane holds *all* arrivals of its key regardless of how
//! other keys interleave, and a budget-capped lane is re-filed into
//! `key_fifo` by its new head's arrival order — leftovers dispatch exactly
//! where the linear scan would have left them in the queue, so a capped
//! key can never starve an older key's requests.
//!
//! The lane index is exposed read-only ([`Batcher::pending_keys`],
//! [`Batcher::pending_for`]) so tests can pin the no-scan claim
//! structurally instead of by timing.

use std::collections::{HashMap, VecDeque};

use super::request::{BatchKey, SampleRequest};

pub struct Pending<T> {
    pub req: SampleRequest,
    pub tag: T,
    pub enqueued: std::time::Instant,
    /// Global arrival sequence number — the cross-lane FIFO order key.
    seq: u64,
}

pub struct Batcher<T> {
    /// Per-key FIFO lanes; a queued request lives in exactly one lane.
    lanes: HashMap<BatchKey, VecDeque<Pending<T>>>,
    /// Nonempty lanes, sorted ascending by their head request's arrival
    /// `seq` — so the front lane's head is always the globally oldest
    /// request. Maintained for free on push (a newly nonempty lane's head
    /// is the newest request of all, so it belongs at the back) and by a
    /// re-file on budget-capped pops (see `pop_batch`).
    key_fifo: VecDeque<BatchKey>,
    /// Total queued requests across all lanes.
    len: usize,
    /// Next arrival sequence number.
    next_seq: u64,
    pub max_batch_samples: usize,
}

impl<T> Batcher<T> {
    pub fn new(max_batch_samples: usize) -> Self {
        Batcher {
            lanes: HashMap::new(),
            key_fifo: VecDeque::new(),
            len: 0,
            next_seq: 0,
            max_batch_samples: max_batch_samples.max(1),
        }
    }

    pub fn push(&mut self, req: SampleRequest, tag: T) {
        let key = req.batch_key();
        let seq = self.next_seq;
        self.next_seq += 1;
        let lane = self.lanes.entry(key.clone()).or_default();
        if lane.is_empty() {
            // This lane's head carries the largest seq of any queued
            // request, so appending keeps `key_fifo` sorted by head seq.
            self.key_fifo.push_back(key);
        }
        lane.push_back(Pending { req, tag, enqueued: std::time::Instant::now(), seq });
        self.len += 1;
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of distinct keys with queued requests (the admission lanes).
    pub fn pending_keys(&self) -> usize {
        self.lanes.len()
    }

    /// Queued requests under `key` — an O(1) lane lookup, which is the
    /// whole point: same-key lookups never scan the other lanes.
    pub fn pending_for(&self, key: &BatchKey) -> usize {
        self.lanes.get(key).map_or(0, |lane| lane.len())
    }

    /// Pop the next merged batch: the oldest queued request plus every
    /// other request in its lane that fits the remaining sample budget, in
    /// FIFO order. Returns (key, requests) or None if idle. O(front lane),
    /// not O(queue): only the front lane is touched.
    ///
    /// Budget packing is first-fit within the lane: the head is always
    /// taken, and the scan continues PAST a request that does not fit to
    /// pack smaller later same-key ones (a single big request must not
    /// strand the rest of the budget — a steady small/large mix would
    /// otherwise dispatch the small requests one batch late forever).
    /// Skipped requests keep their relative order, and because the head is
    /// unconditional, a skipped request heads the lane on the next pop —
    /// it is at worst one batch late, never starved.
    pub fn pop_batch(&mut self) -> Option<(BatchKey, Vec<Pending<T>>)> {
        let key = self.key_fifo.pop_front()?;
        let lane = self.lanes.get_mut(&key).expect("key_fifo entry must have a lane");
        let head = lane.pop_front().expect("key_fifo lanes are nonempty by invariant");
        let mut total = head.req.n_samples;
        let mut group = vec![head];
        let mut rest: VecDeque<Pending<T>> = VecDeque::new();
        let mut drain = std::mem::take(lane).into_iter();
        for p in drain.by_ref() {
            if total >= self.max_batch_samples {
                // Nothing further can fit (n_samples >= 1): stop sorting.
                rest.push_back(p);
                break;
            }
            if total + p.req.n_samples <= self.max_batch_samples {
                total += p.req.n_samples;
                group.push(p);
            } else {
                rest.push_back(p);
            }
        }
        rest.extend(drain);
        *lane = rest;
        self.len -= group.len();
        let leftover_head_seq = lane.front().map(|p| p.seq);
        match leftover_head_seq {
            None => {
                self.lanes.remove(&key);
            }
            Some(hs) => {
                // Budget-capped: re-file the key by its NEW head's arrival
                // order, keeping `key_fifo` sorted by head seq — so a
                // leftover enqueued after another key's head does NOT cut
                // in front of it (exactly the old linear scan's ordering,
                // which left leftovers in their original queue positions;
                // pinning this at the front instead would let a sustained
                // same-key stream starve every other key). O(distinct
                // keys) worst case, and only on the capped path.
                let pos = self.key_fifo.partition_point(|k| {
                    self.lanes[k]
                        .front()
                        .expect("key_fifo lanes are nonempty by invariant")
                        .seq
                        < hs
                });
                self.key_fifo.insert(pos, key.clone());
            }
        }
        Some((key, group))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::SolverKind;
    use crate::util::{prop::run_prop, rng::Rng};

    fn req(model: &str, solver: SolverKind, nfe: usize, n: usize) -> SampleRequest {
        SampleRequest::new(model, solver, nfe, n)
    }

    #[test]
    fn merges_same_key_fifo() {
        let mut b: Batcher<usize> = Batcher::new(1000);
        b.push(req("m", SolverKind::Tab(3), 10, 10), 0);
        b.push(req("m", SolverKind::Tab(2), 10, 10), 1);
        b.push(req("m", SolverKind::Tab(3), 10, 20), 2);
        let (_, g) = b.pop_batch().unwrap();
        assert_eq!(g.iter().map(|p| p.tag).collect::<Vec<_>>(), vec![0, 2]);
        let (_, g2) = b.pop_batch().unwrap();
        assert_eq!(g2[0].tag, 1);
        assert!(b.pop_batch().is_none());
    }

    #[test]
    fn respects_sample_budget() {
        let mut b: Batcher<usize> = Batcher::new(25);
        for i in 0..5 {
            b.push(req("m", SolverKind::Tab(3), 10, 10), i);
        }
        let (_, g) = b.pop_batch().unwrap();
        assert_eq!(g.len(), 2, "10+10 fits, +10 would exceed 25");
        // skipped requests retain order
        let (_, g2) = b.pop_batch().unwrap();
        assert_eq!(g2.iter().map(|p| p.tag).collect::<Vec<_>>(), vec![2, 3]);
    }

    /// The budget-drain regression: a big request that does not fit must
    /// not stop the pack — smaller later same-key requests fill the rest
    /// of the budget, the skipped big request keeps its place, and it
    /// heads the very next batch (one pop late at worst, never starved).
    #[test]
    fn fill_after_big_request() {
        let mut b: Batcher<usize> = Batcher::new(20);
        for (i, n) in [8, 15, 5, 15, 7].into_iter().enumerate() {
            b.push(req("m", SolverKind::Tab(3), 10, n), i);
        }
        let (_, g) = b.pop_batch().unwrap();
        assert_eq!(
            g.iter().map(|p| p.tag).collect::<Vec<_>>(),
            vec![0, 2, 4],
            "8+5+7 packs the budget past the non-fitting 15s"
        );
        let (_, g) = b.pop_batch().unwrap();
        assert_eq!(g.iter().map(|p| p.tag).collect::<Vec<_>>(), vec![1]);
        let (_, g) = b.pop_batch().unwrap();
        assert_eq!(g.iter().map(|p| p.tag).collect::<Vec<_>>(), vec![3]);
        assert!(b.pop_batch().is_none());
    }

    #[test]
    fn oversized_single_request_still_dispatches() {
        let mut b: Batcher<usize> = Batcher::new(16);
        b.push(req("m", SolverKind::Tab(3), 10, 1000), 0);
        let (_, g) = b.pop_batch().unwrap();
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].req.n_samples, 1000);
    }

    /// FIFO across interleaved keys: three keys arriving interleaved must
    /// dispatch in oldest-head order, each batch containing every arrival
    /// of its key (including ones enqueued after other keys), and the lane
    /// index must track the structure exactly — the structural form of the
    /// "no O(queue) scan" claim.
    #[test]
    fn interleaved_keys_dispatch_fifo_with_indexed_lanes() {
        let mut b: Batcher<usize> = Batcher::new(1000);
        let ka = req("m", SolverKind::Tab(3), 10, 1);
        let kb = req("m", SolverKind::Tab(2), 10, 1);
        let kc = req("m", SolverKind::Tab(1), 10, 1);
        // Arrival order: a b a c b a  — lanes a:[0,2,5] b:[1,4] c:[3].
        for (r, tag) in
            [(&ka, 0usize), (&kb, 1), (&ka, 2), (&kc, 3), (&kb, 4), (&ka, 5)]
        {
            b.push(r.clone(), tag);
        }
        assert_eq!(b.len(), 6);
        assert_eq!(b.pending_keys(), 3);
        assert_eq!(b.pending_for(&ka.batch_key()), 3);
        assert_eq!(b.pending_for(&kb.batch_key()), 2);
        assert_eq!(b.pending_for(&kc.batch_key()), 1);

        let (key, g) = b.pop_batch().unwrap();
        assert_eq!(key, ka.batch_key(), "oldest request picks the key");
        assert_eq!(g.iter().map(|p| p.tag).collect::<Vec<_>>(), vec![0, 2, 5]);
        // Popping lane a must not have disturbed lanes b and c.
        assert_eq!(b.pending_keys(), 2);
        assert_eq!(b.pending_for(&ka.batch_key()), 0);
        assert_eq!(b.pending_for(&kb.batch_key()), 2);

        let (key, g) = b.pop_batch().unwrap();
        assert_eq!(key, kb.batch_key());
        assert_eq!(g.iter().map(|p| p.tag).collect::<Vec<_>>(), vec![1, 4]);
        let (key, g) = b.pop_batch().unwrap();
        assert_eq!(key, kc.batch_key());
        assert_eq!(g.iter().map(|p| p.tag).collect::<Vec<_>>(), vec![3]);
        assert!(b.pop_batch().is_none());
        assert_eq!(b.pending_keys(), 0);
        assert_eq!(b.len(), 0);
    }

    /// When a capped lane's leftovers really are the oldest requests, they
    /// keep the front of the queue and dispatch before any younger key,
    /// across repeated pops, with the max-batch cap honored every time.
    #[test]
    fn budget_capped_lane_stays_at_the_front() {
        let mut b: Batcher<usize> = Batcher::new(20);
        for i in 0..5 {
            b.push(req("m", SolverKind::Tab(3), 10, 10), i);
        }
        b.push(req("m", SolverKind::Tab(1), 10, 10), 99); // younger key
        let (_, g) = b.pop_batch().unwrap();
        assert_eq!(g.iter().map(|p| p.tag).collect::<Vec<_>>(), vec![0, 1]);
        let (_, g) = b.pop_batch().unwrap();
        assert_eq!(
            g.iter().map(|p| p.tag).collect::<Vec<_>>(),
            vec![2, 3],
            "capped leftovers must dispatch before the younger key"
        );
        let (_, g) = b.pop_batch().unwrap();
        assert_eq!(g.iter().map(|p| p.tag).collect::<Vec<_>>(), vec![4]);
        let (_, g) = b.pop_batch().unwrap();
        assert_eq!(g.iter().map(|p| p.tag).collect::<Vec<_>>(), vec![99]);
        assert!(b.pop_batch().is_none());
    }

    /// The starvation regression: leftovers of a budget-capped lane that
    /// arrived AFTER another key's head must not cut in front of it. The
    /// capped key is re-filed by its new head's arrival order, so the
    /// dispatch order matches what the old in-place linear scan produced.
    #[test]
    fn budget_capped_leftovers_do_not_starve_older_keys() {
        let mut b: Batcher<usize> = Batcher::new(20);
        // Arrivals: A1(15) B1(10) A2(15) — A2 cannot join A1's batch.
        b.push(req("m", SolverKind::Tab(3), 10, 15), 0); // A1
        b.push(req("m", SolverKind::Tab(2), 10, 10), 1); // B1
        b.push(req("m", SolverKind::Tab(3), 10, 15), 2); // A2
        let (_, g) = b.pop_batch().unwrap();
        assert_eq!(g.iter().map(|p| p.tag).collect::<Vec<_>>(), vec![0]);
        let (_, g) = b.pop_batch().unwrap();
        assert_eq!(
            g.iter().map(|p| p.tag).collect::<Vec<_>>(),
            vec![1],
            "B1 is older than A's leftover and must dispatch first"
        );
        let (_, g) = b.pop_batch().unwrap();
        assert_eq!(g.iter().map(|p| p.tag).collect::<Vec<_>>(), vec![2]);
        assert!(b.pop_batch().is_none());
    }

    #[test]
    fn prop_every_request_dispatched_once_with_matching_key() {
        run_prop("batcher bijection", 29, 40, |rng: &mut Rng| {
            let mut b: Batcher<usize> = Batcher::new(1 + rng.below(100));
            let n = 1 + rng.below(40);
            for i in 0..n {
                let model = ["a", "b"][rng.below(2)];
                let solver = [SolverKind::Tab(3), SolverKind::RhoHeun][rng.below(2)];
                let nfe = [10, 20][rng.below(2)];
                b.push(req(model, solver, nfe, 1 + rng.below(30)), i);
            }
            let mut seen = vec![false; n];
            while let Some((key, group)) = b.pop_batch() {
                let budget_ok = group.iter().map(|p| p.req.n_samples).sum::<usize>()
                    <= b.max_batch_samples
                    || group.len() == 1;
                assert!(budget_ok, "budget violated by a merged batch");
                for p in group {
                    assert_eq!(p.req.batch_key(), key, "mixed keys in one batch");
                    assert!(!seen[p.tag], "request {} dispatched twice", p.tag);
                    seen[p.tag] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "some requests never dispatched");
            assert_eq!(b.pending_keys(), 0, "drained batcher must hold no lanes");
        });
    }
}
