//! L3 coordinator: the serving engine, sharded by model.
//!
//! Architecture (continuous-batching-shaped, scaled to a sampling service
//! that serves many named models at once):
//!
//! ```text
//!   submit() ── atomic admission (global + per-model caps, no lock)
//!       │            ShardMap: model name ──> shard   (shared-read router)
//!       ├────────────────┬──────────────────┐
//!   shard "imgnet"   shard "gmm2d"     shard "ffhq"      (one per model,
//!   ┌ own mutex ┐    ┌ own mutex ┐    ┌ own mutex ┐       created lazily)
//!   │ Batcher   │    │ Batcher   │    │ Batcher   │  admission, key-merged
//!   │ flights   │    │ flights   │    │ flights   │  trajectory groups
//!   │ ready idx │    │ ready idx │    │ ready idx │  (t)-buckets + heap
//!   └───────────┘    └───────────┘    └───────────┘
//!         ╰───────────── worker pool ─────────────╯
//!      affinity shard first, steal from the busiest;
//!      gather / merged ε-eval / scatter / advance run OFF-lock
//! ```
//!
//! Step-level co-batching can only merge ε-evals that share `(model, t)` —
//! a cross-model merge is impossible by construction — so scheduler state
//! is partitioned by model: each registered model gets its own [`Shard`]
//! (mutex + admission queue + flight slots + ready index + deadline sweep),
//! created on first use. Requests for model A never touch model B's lock:
//! routing is a shared read-lock map lookup in [`Coordinator::submit`],
//! admission control is a pair of atomic counters (global and per-shard
//! caps), and workers *scan* for work through per-shard load atomics,
//! locking only the shard they take work from. A fleet serving k models
//! runs its scheduler bookkeeping on k independent mutexes; a single-model
//! hot spot still uses every worker through load-based stealing (see
//! `scheduler.rs`).
//!
//! Two merging layers per shard. At **admission**, requests that share
//! (model, sde, solver, grid, t0, NFE) are stacked into one state matrix —
//! DEIS's batch-reusable coefficients make the extra rows nearly free; the
//! [`Batcher`](batcher::Batcher) indexes its queue per key, so popping a
//! merged group is O(group), not O(queue). At the **step level**, every
//! in-flight trajectory group yields its pending ε-evaluation through the
//! resumable [`StepCursor`] API, and evals that land on the same `t` are
//! dispatched as one merged network call. Cursorization is universal, so
//! **all** traffic is co-batchable. Python is never involved; the model
//! registry maps names to [`EpsModel`] backends (PJRT / native / analytic).
//!
//! The per-config (grid, coefficient) plans behind the cursors come from a
//! shared [`PlanCache`](crate::solvers::PlanCache): `submit` resolves the
//! plan on the submitting thread (a map lookup in the steady state) and
//! attaches it to the queued request, so admission under a shard mutex
//! does no grid or quadrature work at all.
//!
//! Each shard mutex guards routing state only. Workers check member
//! flights *out of their slots*, so input gather, the model call, the eps
//! scatter and `cursor.advance()` — every O(rows·dim) cost, including
//! stochastic noise draws — run lock-free; a short re-lock re-slots the
//! flights. Under the lock the scheduler consults a ready index
//! ((t)-buckets + an oldest-first heap + a free-slot list) instead of
//! scanning flight slots, and admission's prior draw + cursor instantiation
//! also run off-lock between two short critical sections — with the wake
//! rail fanning a burst of distinct keys across idle workers so group
//! builds for the *same* shard proceed concurrently. See `scheduler.rs`
//! for the design and its invariants.
//!
//! Observability is sharded too: global [`Stats`] stay authoritative for
//! the aggregate, and every shard records the same lifecycle/occupancy
//! counters into its own [`ModelStats`], surfaced as
//! [`StatsSnapshot::per_model`] and the additive `per_model` key of the
//! `{"cmd":"stats"}` wire reply.
//!
//! [`StepCursor`]: crate::solvers::StepCursor
//! [`Shard`]: scheduler::Shard
//!
//! Offline-registry note: built on std::thread + channels (no tokio).

pub mod batcher;
pub mod request;
mod scheduler;
pub mod stats;

pub use request::{BatchKey, SampleRequest, SampleResult};
pub use scheduler::{SchedPolicy, DEFAULT_EDF_AGE_GUARD};
// The router reuses the per-model breaker shape for per-upstream health.
pub(crate) use scheduler::{Breaker, BreakerConfig};
pub use stats::{ModelStats, ModelStatsSnapshot, Stats, StatsSnapshot};

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::score::{EpsModel, Precision};
use crate::solvers::PlanCache;

use self::scheduler::{ShardMap, WakeRail};

/// Registry-name suffix for a model's f32 engine. An `"dtype":"f32"`
/// request is rewritten to `<model>@f32` at submit time, so shard routing,
/// batch keys and per-model stats all key on the precision-qualified name
/// with zero scheduler changes — and f32 and f64 traffic can never be
/// co-batched by construction.
pub const F32_SUFFIX: &str = "@f32";

/// Model registry: name -> eps backend.
#[derive(Default)]
pub struct ModelRegistry {
    models: HashMap<String, Arc<dyn EpsModel>>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, model: Arc<dyn EpsModel>) {
        self.models.insert(name.to_string(), model);
    }

    pub fn get(&self, name: &str) -> Option<Arc<dyn EpsModel>> {
        self.models.get(name).cloned()
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.sort();
        v
    }
}

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub workers: usize,
    /// Max merged samples per solver run / merged ε-eval (PJRT artifact cap
    /// is 1024; larger batches chunk inside the backend anyway).
    pub max_batch_samples: usize,
    /// Global backpressure bound: submissions beyond this many unanswered
    /// requests (across all models) are rejected immediately with an
    /// "overloaded" error instead of growing the queues without limit.
    pub max_inflight_requests: usize,
    /// Per-model backpressure bound: one model's traffic beyond this many
    /// unanswered requests is rejected even when the global bound has room,
    /// so a single hot model cannot starve every other shard out of the
    /// global budget.
    pub max_inflight_per_model: usize,
    /// Consecutive failing ε-evals (panic / non-finite output / panicking
    /// advance) that open a model's circuit breaker; while open, submit
    /// refuses that model's traffic immediately instead of queueing work a
    /// broken model will burn. 0 disables the breaker.
    pub breaker_threshold: u32,
    /// How long an open breaker refuses traffic before half-opening
    /// (admitting again with the failure streak retained, so one more
    /// failure re-opens instantly while one clean eval closes it).
    pub breaker_cooldown_ms: u64,
    /// Anchor-selection policy for every shard's ready heap. The default
    /// (`Oldest`) is bit-compatible with the pre-policy scheduler; `Edf`
    /// anchors the tightest part deadline first with an age-based
    /// starvation guard for deadline-less parts (`--sched-policy`).
    pub sched_policy: SchedPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 2,
            max_batch_samples: 1024,
            max_inflight_requests: 4096,
            max_inflight_per_model: 4096,
            breaker_threshold: 5,
            breaker_cooldown_ms: 1000,
            sched_policy: SchedPolicy::Oldest,
        }
    }
}

/// Liveness/degradation snapshot for the `{"cmd":"health"}` wire reply:
/// the drain flag, worker restarts so far, and per-model circuit state.
#[derive(Clone, Debug)]
pub struct HealthSnapshot {
    /// True once a graceful shutdown began: new submissions are refused.
    pub draining: bool,
    /// Worker threads restarted by the supervisor after a scheduler panic.
    pub worker_panics: u64,
    /// `(model, healthy)` for every shard created so far, sorted by name;
    /// healthy = circuit closed (the model's traffic is being admitted).
    pub models: Vec<(String, bool)>,
}

/// How a finished (or refused) request's result reaches its requester.
/// `Channel` is the blocking in-process path ([`Coordinator::submit`]);
/// `Hook` carries an arbitrary completion callback — the readiness-driven
/// server front end uses it to route results back to the owning I/O thread
/// without parking a thread per request. Delivery is exactly-once for
/// hooks (the callback is taken out of its slot before it runs); a channel
/// send to a dropped receiver is ignored, as before.
pub(crate) enum Responder {
    Channel(SyncSender<anyhow::Result<SampleResult>>),
    Hook(Mutex<Option<Box<dyn FnOnce(anyhow::Result<SampleResult>) + Send>>>),
}

impl Responder {
    pub(crate) fn channel(tx: SyncSender<anyhow::Result<SampleResult>>) -> Responder {
        Responder::Channel(tx)
    }

    pub(crate) fn hook(
        f: impl FnOnce(anyhow::Result<SampleResult>) + Send + 'static,
    ) -> Responder {
        Responder::Hook(Mutex::new(Some(Box::new(f))))
    }

    pub(crate) fn send(&self, r: anyhow::Result<SampleResult>) {
        match self {
            Responder::Channel(tx) => {
                let _ = tx.send(r);
            }
            Responder::Hook(slot) => {
                // Take under the lock, run after dropping it: the callback
                // may be arbitrarily heavy (it serializes the reply).
                let f = crate::util::sync::lock_recover(slot).take();
                if let Some(f) = f {
                    f(r);
                }
            }
        }
    }
}

/// Upper bound on a request's NFE budget. NFE comes straight off the wire
/// and sizes both the grid allocation and the coefficient quadrature behind
/// a plan build, so it must be bounded before any plan work happens. Far
/// above any sensible serving config (the paper's regime is NFE <= 50).
pub const MAX_REQUEST_NFE: usize = 8192;

pub(crate) struct Shared {
    /// Per-model scheduler shards, created lazily from the registry.
    pub(crate) shards: ShardMap,
    /// Global worker sleep/wake rail (generation-counted, lost-wakeup-free).
    pub(crate) wake: WakeRail,
    pub(crate) shutdown: AtomicBool,
    /// Graceful-shutdown gate: set first, before workers stop, so submit
    /// refuses new work while the in-flight tail drains.
    pub(crate) draining: AtomicBool,
    /// Worker threads restarted by [`scheduler::supervised_worker_loop`]
    /// after a panic escaped the fault-contained regions.
    pub(crate) worker_panics: AtomicU64,
    /// Deterministic supervisor hook: a countdown of worker-loop panics to
    /// inject at the top of the tick (see `worker_loop`).
    #[cfg(test)]
    pub(crate) test_worker_bomb: AtomicUsize,
    pub(crate) registry: ModelRegistry,
    pub(crate) stats: Stats,
    pub(crate) max_inflight: usize,
    pub(crate) max_inflight_per_model: usize,
    /// Requests admitted past submit and not yet answered — the global
    /// backpressure reservation. One fetch_add at submit, one fetch_sub
    /// when the response is sent; queued, slotted, checked-out and
    /// mid-admission parts are all covered by the single reservation, so
    /// admission control is O(1) and takes no lock anywhere.
    pub(crate) inflight_parts: AtomicUsize,
    /// Shared (grid, coefficients) plans, resolved at submit time so no
    /// shard mutex ever sees grid or quadrature work.
    pub(crate) plan_cache: PlanCache,
}

pub struct Coordinator {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig, registry: ModelRegistry) -> Coordinator {
        let breaker = scheduler::BreakerConfig {
            threshold: cfg.breaker_threshold,
            cooldown: Duration::from_millis(cfg.breaker_cooldown_ms),
        };
        let shared = Arc::new(Shared {
            shards: ShardMap::new(cfg.max_batch_samples.max(1), breaker, cfg.sched_policy),
            wake: WakeRail::new(),
            shutdown: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            worker_panics: AtomicU64::new(0),
            #[cfg(test)]
            test_worker_bomb: AtomicUsize::new(0),
            registry,
            stats: Stats::default(),
            max_inflight: cfg.max_inflight_requests.max(1),
            max_inflight_per_model: cfg.max_inflight_per_model.max(1),
            inflight_parts: AtomicUsize::new(0),
            plan_cache: PlanCache::new(),
        });
        let workers = (0..cfg.workers.max(1))
            .map(|widx| {
                let sh = shared.clone();
                std::thread::spawn(move || scheduler::supervised_worker_loop(sh, widx))
            })
            .collect();
        Coordinator { shared, workers }
    }

    /// Non-blocking submit; the receiver yields the result. Overload
    /// (global or per-model), unknown model names, invalid configurations
    /// and pre-expired deadlines are reported through the receiver as
    /// errors — every refusal counts into `rejected` (or `expired`), so the
    /// lifecycle counters always balance.
    ///
    /// The hot path takes no coordinator-wide lock at all: admission
    /// control is two atomic reservations, shard routing is a shared read
    /// lock (exclusive only on a model's first sighting), and plan
    /// resolution happens HERE, on the submitting thread — a shared
    /// [`PlanCache`] lookup in the steady state, a (concurrency-friendly)
    /// build on the first sighting of a config. Only the owning shard's
    /// mutex is taken at the end, for the queue push.
    pub fn submit(&self, req: SampleRequest) -> Receiver<anyhow::Result<SampleResult>> {
        let (tx, rx) = sync_channel(1);
        self.submit_with(req, Responder::channel(tx));
        rx
    }

    /// Submit with an explicit [`Responder`] — the non-channel entry the
    /// event-loop front end uses: refusals are delivered synchronously on
    /// the calling thread, completions from wherever the scheduler finishes
    /// the flight. Same admission path, same counters, same error texts as
    /// [`Coordinator::submit`] (which is now a thin wrapper over this).
    pub(crate) fn submit_with(&self, mut req: SampleRequest, responder: Responder) {
        let sh = &*self.shared;
        // Precision routing: an f32 request runs on the model's registered
        // f32 sibling ("<name>@f32", see [`F32_SUFFIX`]), so everything
        // downstream — shards, batch keys, stats — keys on the rewritten
        // name and needs no dtype awareness.
        if req.dtype == Precision::F32 && !req.model.ends_with(F32_SUFFIX) {
            req.model.push_str(F32_SUFFIX);
        }
        sh.stats.requests.fetch_add(1, Ordering::Relaxed);
        // Drain gate: a coordinator shutting down finishes what it has and
        // refuses everything new — checked before any reservation so the
        // drain wait (inflight_parts -> 0) cannot be pushed back forever.
        if sh.draining.load(Ordering::SeqCst) {
            sh.stats.rejected.fetch_add(1, Ordering::Relaxed);
            responder.send(Err(anyhow::anyhow!(
                "coordinator shutting down: not accepting new requests"
            )));
            return;
        }
        let deadline = req.deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
        // Cheap request sanity BEFORE any plan work: nfe comes off the wire
        // and sizes the grid allocation + coefficient quadrature.
        if req.nfe > MAX_REQUEST_NFE {
            sh.stats.rejected.fetch_add(1, Ordering::Relaxed);
            responder.send(Err(anyhow::anyhow!(
                "nfe {} out of range (max {MAX_REQUEST_NFE})",
                req.nfe
            )));
            return;
        }
        // Global admission: reserve one in-flight slot atomically. An
        // overloaded coordinator must shed BEFORE paying for routing or
        // plan resolution (a plan build is the most expensive thing a
        // request can trigger). The reservation is released when the
        // response is sent — wherever that happens.
        let cur = sh.inflight_parts.fetch_add(1, Ordering::SeqCst);
        if cur >= sh.max_inflight {
            sh.inflight_parts.fetch_sub(1, Ordering::SeqCst);
            sh.stats.rejected.fetch_add(1, Ordering::Relaxed);
            responder.send(Err(anyhow::anyhow!(
                "coordinator overloaded: {cur} requests in flight (max {}); retry later",
                sh.max_inflight
            )));
            return;
        }
        // Route to the model's shard (created lazily from the registry on
        // first sighting). Unknown models are refused here — no shard, no
        // queue occupancy, no plan work — with the same error text the
        // admission path used to produce.
        let shard = match sh.shards.get_or_create(&req.model, &sh.registry) {
            Some(s) => s,
            None => {
                sh.inflight_parts.fetch_sub(1, Ordering::SeqCst);
                sh.stats.rejected.fetch_add(1, Ordering::Relaxed);
                // An f32 request for a model whose base name IS registered
                // deserves a precise diagnosis, not "unknown model".
                let msg = match req.model.strip_suffix(F32_SUFFIX) {
                    Some(base) if sh.registry.get(base).is_some() => anyhow::anyhow!(
                        "model '{base}' has no f32 engine registered \
                         (serve with --precision f32)"
                    ),
                    _ => anyhow::anyhow!("unknown model '{}'", req.model),
                };
                responder.send(Err(msg));
                return;
            }
        };
        shard.stats.requests.fetch_add(1, Ordering::Relaxed);
        // Circuit breaker: a model whose evals keep failing is refused
        // up front — fail fast beats queueing work a broken backend will
        // burn, and the healthy shards keep their full worker share. The
        // refusal counts as `rejected` (the balance term) AND `unhealthy`
        // (the diagnosis), globally and per model.
        if shard.breaker.is_open() {
            sh.inflight_parts.fetch_sub(1, Ordering::SeqCst);
            sh.stats.rejected.fetch_add(1, Ordering::Relaxed);
            sh.stats.unhealthy.fetch_add(1, Ordering::Relaxed);
            shard.stats.rejected.fetch_add(1, Ordering::Relaxed);
            shard.stats.unhealthy.fetch_add(1, Ordering::Relaxed);
            responder.send(Err(anyhow::anyhow!(
                "model '{}' unhealthy (circuit open after {} consecutive eval \
                 failures; retry after cooldown)",
                req.model,
                shard.breaker.threshold()
            )));
            return;
        }
        // Per-model admission: same reservation discipline against the
        // shard's own counter, so one hot model sheds before it can occupy
        // the whole global budget.
        let scur = shard.inflight.fetch_add(1, Ordering::SeqCst);
        if scur >= sh.max_inflight_per_model {
            shard.inflight.fetch_sub(1, Ordering::SeqCst);
            sh.inflight_parts.fetch_sub(1, Ordering::SeqCst);
            sh.stats.rejected.fetch_add(1, Ordering::Relaxed);
            shard.stats.rejected.fetch_add(1, Ordering::Relaxed);
            responder.send(Err(anyhow::anyhow!(
                "model '{}' overloaded: {scur} requests in flight (max {}); retry later",
                req.model,
                sh.max_inflight_per_model
            )));
            return;
        }
        // Grid/solver constructors assert on malformed configs (t0 out of
        // range, too few steps for PNDM, ...); turn panics into per-request
        // errors. No lock is held, so nothing can be poisoned.
        let built = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            sh.plan_cache.get_or_build(&req.sde, req.solver, req.grid, req.t0, req.nfe)
        }));
        let plan = match built {
            Ok((plan, hit)) => {
                let ctr = if hit {
                    &sh.stats.plan_cache_hits
                } else {
                    &sh.stats.plan_cache_misses
                };
                ctr.fetch_add(1, Ordering::Relaxed);
                plan
            }
            Err(_) => {
                shard.inflight.fetch_sub(1, Ordering::SeqCst);
                sh.inflight_parts.fetch_sub(1, Ordering::SeqCst);
                sh.stats.rejected.fetch_add(1, Ordering::Relaxed);
                shard.stats.rejected.fetch_add(1, Ordering::Relaxed);
                responder.send(Err(anyhow::anyhow!(
                    "invalid sampling configuration for solver '{}' (nfe {}, t0 {}): \
                     grid/solver constraints violated",
                    req.solver.name(),
                    req.nfe,
                    req.t0
                )));
                return;
            }
        };
        {
            let mut st = shard.lock();
            st.queue.push(req, (responder, Instant::now(), deadline, plan));
            shard.publish_load(&st);
        }
        sh.wake.wake();
    }

    /// Submit and wait.
    pub fn sample_blocking(&self, req: SampleRequest) -> anyhow::Result<SampleResult> {
        self.submit(req).recv().expect("coordinator dropped response channel")
    }

    /// Aggregate counters plus the per-model (per-shard) breakdown, sorted
    /// by model name.
    pub fn stats(&self) -> StatsSnapshot {
        let mut snap = self.shared.stats.snapshot();
        snap.per_model = self.shared.shards.per_model_snapshots();
        snap
    }

    pub fn models(&self) -> Vec<String> {
        self.shared.registry.names()
    }

    #[cfg(test)]
    pub(crate) fn shard_count(&self) -> usize {
        self.shared.shards.count()
    }

    /// Block until every worker is parked on the wake rail (no tick is
    /// mid-scan with a stale load hint) — the deterministic quiescence
    /// point for shard-isolation assertions.
    #[cfg(test)]
    pub(crate) fn quiesce_workers(&self) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while self.shared.wake.waiters() < self.workers.len() {
            assert!(Instant::now() < deadline, "workers failed to quiesce within 10s");
            std::thread::yield_now();
        }
    }

    /// Times a shard's mutex has been acquired (0 for absent shards) — the
    /// shard-isolation assertion hook.
    #[cfg(test)]
    pub(crate) fn shard_lock_count(&self, name: &str) -> u64 {
        self.shared
            .shards
            .get(name)
            .map_or(0, |s| s.lock_acquisitions.load(Ordering::Relaxed))
    }

    /// Stop admitting new work without stopping the engine: every submit
    /// from here on is refused with a "shutting down" error (counted
    /// `rejected`) while already-admitted work keeps running. The server
    /// front end flips this before its listener closes so in-flight
    /// connections drain cleanly.
    pub fn begin_drain(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
    }

    /// Liveness/degradation snapshot: drain flag, worker restarts, and
    /// per-model circuit state (healthy = closed), sorted by model name.
    pub fn health(&self) -> HealthSnapshot {
        let mut models: Vec<(String, bool)> = self
            .shared
            .shards
            .all()
            .iter()
            .map(|s| (s.name.to_string(), !s.breaker.is_open()))
            .collect();
        models.sort_by(|a, b| a.0.cmp(&b.0));
        HealthSnapshot {
            draining: self.shared.draining.load(Ordering::SeqCst),
            worker_panics: self.shared.worker_panics.load(Ordering::SeqCst),
            models,
        }
    }

    /// Graceful drain-then-stop with the default 5 s drain window.
    pub fn shutdown(self) {
        self.shutdown_with_timeout(Duration::from_secs(5));
    }

    /// Graceful shutdown: stop admitting (submit refuses with a "shutting
    /// down" error), wait up to `timeout` for the in-flight tail to be
    /// answered, stop and join the workers, then answer whatever work is
    /// still stranded (queued or slotted past the window) as `failed` —
    /// every admitted request gets exactly one reply, and the lifecycle
    /// balance `requests == completed + rejected + expired + failed` holds
    /// through the shutdown itself.
    pub fn shutdown_with_timeout(self, timeout: Duration) {
        let sh = &*self.shared;
        sh.draining.store(true, Ordering::SeqCst);
        let deadline = Instant::now() + timeout;
        while sh.inflight_parts.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            sh.wake.wake();
            std::thread::sleep(Duration::from_millis(1));
        }
        // Workers stop BEFORE the stranded sweep so the sweep cannot race
        // a checkout: after the join, whatever the shards hold is all that
        // is left.
        sh.shutdown.store(true, Ordering::SeqCst);
        sh.wake.wake();
        for w in self.workers {
            let _ = w.join();
        }
        for shard in sh.shards.all() {
            scheduler::abort_shard(sh, &shard, "coordinator shutting down");
        }
    }

    /// Arm `n` injected worker-loop panics (outside the contained eval
    /// regions) — the deterministic supervisor-restart hook.
    #[cfg(test)]
    pub(crate) fn arm_worker_bomb(&self, n: usize) {
        self.shared.test_worker_bomb.store(n, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diffusion::Sde;
    use crate::gmm::Gmm;
    use crate::score::GmmEps;
    use crate::solvers::SolverKind;
    use crate::util::prop::assert_close;

    fn registry() -> ModelRegistry {
        let mut r = ModelRegistry::new();
        r.insert("gmm2d", Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())));
        r
    }

    #[test]
    fn end_to_end_single_request() {
        let c = Coordinator::new(CoordinatorConfig::default(), registry());
        let res = c
            .sample_blocking(SampleRequest::new("gmm2d", SolverKind::Tab(3), 10, 32))
            .unwrap();
        assert_eq!(res.samples.len(), 64);
        assert_eq!(res.dim, 2);
        assert!(res.samples.iter().all(|v| v.is_finite()));
        c.shutdown();
    }

    #[test]
    fn unknown_model_is_an_error() {
        let c = Coordinator::new(CoordinatorConfig::default(), registry());
        let err = c.sample_blocking(SampleRequest::new("nope", SolverKind::Tab(0), 5, 4));
        assert!(err.is_err());
        assert!(
            err.unwrap_err().to_string().contains("unknown model"),
            "unknown-model error text must be preserved"
        );
        let s = c.stats();
        assert_eq!(s.rejected, 1, "unknown-model refusals count as rejected");
        assert_eq!(s.requests, s.completed + s.rejected + s.expired);
        c.shutdown();
    }

    #[test]
    fn shards_are_created_lazily_per_model() {
        let mut r = registry();
        r.insert("gmm2d_b", Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())));
        let c = Coordinator::new(CoordinatorConfig::default(), r);
        assert_eq!(c.shard_count(), 0, "no shards before any traffic");
        c.sample_blocking(SampleRequest::new("gmm2d", SolverKind::Tab(0), 5, 4)).unwrap();
        assert_eq!(c.shard_count(), 1, "first request creates its model's shard");
        c.sample_blocking(SampleRequest::new("gmm2d", SolverKind::Tab(0), 5, 4)).unwrap();
        assert_eq!(c.shard_count(), 1, "repeat traffic reuses the shard");
        c.sample_blocking(SampleRequest::new("gmm2d_b", SolverKind::Tab(0), 5, 4)).unwrap();
        assert_eq!(c.shard_count(), 2);
        // Unknown models create nothing (and still error — see above).
        let _ = c.sample_blocking(SampleRequest::new("nope", SolverKind::Tab(0), 5, 4));
        assert_eq!(c.shard_count(), 2);
        // The per-model breakdown mirrors the shards, sorted by name.
        let s = c.stats();
        let names: Vec<&str> = s.per_model.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["gmm2d", "gmm2d_b"]);
        assert_eq!(s.per_model[0].1.completed, 2);
        assert_eq!(s.per_model[1].1.completed, 1);
        c.shutdown();
    }

    /// The sharding contract itself: traffic at model A must never take
    /// model B's shard lock. Proven by the lock-acquisition counter — B's
    /// count freezes once B's own traffic drains, no matter how much A
    /// traffic follows.
    #[test]
    fn foreign_model_traffic_never_takes_an_idle_shards_lock() {
        let mut r = registry();
        r.insert("cold", Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())));
        let c = Coordinator::new(
            CoordinatorConfig { workers: 4, ..Default::default() },
            r,
        );
        c.sample_blocking(SampleRequest::new("cold", SolverKind::Tab(1), 6, 4)).unwrap();
        c.sample_blocking(SampleRequest::new("gmm2d", SolverKind::Tab(1), 6, 4)).unwrap();
        // Quiesce: once every worker is parked on the wake rail, no tick
        // can still hold a stale load hint for the cold shard — and cold's
        // load stays 0 from here on, so its lock count must freeze.
        c.quiesce_workers();
        let frozen = c.shard_lock_count("cold");
        assert!(frozen > 0, "cold's own traffic must have locked its shard");
        for i in 0..24 {
            let mut q = SampleRequest::new("gmm2d", SolverKind::Tab(2), 8, 4);
            q.seed = i;
            c.sample_blocking(q).unwrap();
        }
        assert_eq!(
            c.shard_lock_count("cold"),
            frozen,
            "gmm2d traffic took the idle cold shard's lock"
        );
        c.shutdown();
    }

    #[test]
    fn determinism_per_seed_even_when_merged() {
        // The same (seed, n) request must yield identical samples whether it
        // runs alone or merged with strangers — per-request RNG streams.
        let c = Coordinator::new(
            CoordinatorConfig { workers: 1, max_batch_samples: 4096, ..Default::default() },
            registry(),
        );
        let mk = |seed: u64| {
            let mut r = SampleRequest::new("gmm2d", SolverKind::Tab(2), 10, 16);
            r.seed = seed;
            r
        };
        let solo = c.sample_blocking(mk(7)).unwrap();

        // Saturate the queue so the three submissions merge.
        let rx1 = c.submit(mk(1));
        let rx2 = c.submit(mk(7));
        let rx3 = c.submit(mk(3));
        let merged = rx2.recv().unwrap().unwrap();
        let _ = (rx1.recv(), rx3.recv());
        assert_close(&solo.samples, &merged.samples, 1e-12, "seed determinism under merge");
        c.shutdown();
    }

    #[test]
    fn stats_accumulate() {
        let c = Coordinator::new(CoordinatorConfig::default(), registry());
        for _ in 0..3 {
            c.sample_blocking(SampleRequest::new("gmm2d", SolverKind::Tab(0), 5, 8)).unwrap();
        }
        let s = c.stats();
        assert_eq!(s.requests, 3);
        assert_eq!(s.completed, 3);
        assert_eq!(s.samples, 24);
        assert!(s.p50_us > 0);
        // Per-model mirror of a single-model workload.
        assert_eq!(s.per_model.len(), 1);
        let (name, m) = &s.per_model[0];
        assert_eq!(name, "gmm2d");
        assert_eq!(m.requests, 3);
        assert_eq!(m.completed, 3);
        assert_eq!(m.samples, 24);
        c.shutdown();
    }

    #[test]
    fn plan_cache_hits_on_repeat_config_and_does_not_alias() {
        let c = Coordinator::new(CoordinatorConfig::default(), registry());
        let mk = |nfe: usize, seed: u64| {
            let mut r = SampleRequest::new("gmm2d", SolverKind::Tab(2), nfe, 4);
            r.seed = seed;
            r
        };
        let a = c.sample_blocking(mk(10, 1)).unwrap();
        let s = c.stats();
        assert_eq!(s.plan_cache_misses, 1, "first config must build");
        assert_eq!(s.plan_cache_hits, 0);
        // Same config, different seed: admission key and plan key both match
        // — second submission must reuse the cached plan.
        let _ = c.sample_blocking(mk(10, 2)).unwrap();
        let s = c.stats();
        assert_eq!(s.plan_cache_misses, 1);
        assert_eq!(s.plan_cache_hits, 1, "repeat config must hit the plan cache");
        // Distinct config (different NFE): its own plan, not an alias.
        let b = c.sample_blocking(mk(12, 1)).unwrap();
        let s = c.stats();
        assert_eq!(s.plan_cache_misses, 2, "distinct config must build its own plan");
        assert_eq!(s.plan_cache_hits, 1);
        assert_eq!(a.nfe, 10);
        assert_eq!(b.nfe, 12);
        c.shutdown();
    }

    #[test]
    fn invalid_config_is_an_error_not_a_crash() {
        let c = Coordinator::new(CoordinatorConfig::default(), registry());
        // PNDM requires >= 4 grid steps; nfe 10 maps to 1 step. The plan
        // build panics, which submit must convert into a per-request error
        // — and the coordinator must stay serviceable afterwards.
        let bad = SampleRequest::new("gmm2d", SolverKind::Pndm, 10, 4);
        let err = c.sample_blocking(bad);
        assert!(err.is_err(), "invalid config must be reported as an error");
        // Oversized NFE is rejected before any plan work happens.
        let huge = SampleRequest::new("gmm2d", SolverKind::Tab(0), MAX_REQUEST_NFE + 1, 4);
        let err = c.sample_blocking(huge);
        assert!(err.is_err(), "over-cap nfe must be rejected");
        assert!(err.unwrap_err().to_string().contains("out of range"));
        let ok = c.sample_blocking(SampleRequest::new("gmm2d", SolverKind::Tab(0), 5, 4));
        assert!(ok.is_ok(), "coordinator must survive an invalid config");
        // Both refusals are accounted: the lifecycle balances.
        let s = c.stats();
        assert_eq!(s.rejected, 2, "invalid-config and over-cap refusals count as rejected");
        assert_eq!(s.requests, s.completed + s.rejected + s.expired);
        c.shutdown();
    }

    #[test]
    fn concurrent_mixed_load() {
        let c = Arc::new(Coordinator::new(
            CoordinatorConfig { workers: 4, max_batch_samples: 256, ..Default::default() },
            registry(),
        ));
        let mut handles = Vec::new();
        for i in 0..16 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let solver = [SolverKind::Tab(3), SolverKind::RhoHeun, SolverKind::Tab(0)]
                    [i % 3];
                let mut req = SampleRequest::new("gmm2d", solver, 10, 8 + i);
                req.seed = i as u64;
                let res = c.sample_blocking(req).unwrap();
                assert_eq!(res.samples.len(), (8 + i) * 2);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = c.stats();
        assert_eq!(stats.completed, 16);
        if let Ok(c) = Arc::try_unwrap(c) {
            c.shutdown();
        }
    }

    #[test]
    fn backpressure_rejects_over_limit() {
        // Two in-flight slots: the burst beyond them must be rejected, and
        // the rejection must be immediate (error through the receiver).
        let c = Coordinator::new(
            CoordinatorConfig {
                workers: 1,
                max_batch_samples: 1,
                max_inflight_requests: 2,
                ..Default::default()
            },
            registry(),
        );
        let reqs: Vec<_> = (0..24)
            .map(|i| {
                let mut r = SampleRequest::new("gmm2d", SolverKind::Tab(1), 20, 64);
                r.seed = i;
                c.submit(r)
            })
            .collect();
        let results: Vec<_> = reqs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        let rejected = results.iter().filter(|r| r.is_err()).count();
        assert!(rejected > 0, "no submission was rejected under a 2-request cap");
        assert!(results.iter().any(|r| r.is_ok()), "everything was rejected");
        let s = c.stats();
        assert_eq!(s.rejected as usize, rejected);
        assert_eq!(s.completed + s.rejected, 24);
        c.shutdown();
    }

    #[test]
    fn per_model_cap_rejects_only_the_hot_model() {
        // A hot model capped at 2 in-flight requests sheds its burst with a
        // model-naming overload error while a cold model (and the global
        // budget) stays wide open.
        let mut r = ModelRegistry::new();
        r.insert(
            "hot",
            Arc::new(SlowEps(
                GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp()),
                std::time::Duration::from_millis(20),
            )),
        );
        r.insert("cold", Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())));
        let c = Coordinator::new(
            CoordinatorConfig {
                workers: 2,
                max_batch_samples: 1,
                max_inflight_requests: 4096,
                max_inflight_per_model: 2,
                ..Default::default()
            },
            r,
        );
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                let mut q = SampleRequest::new("hot", SolverKind::Tab(1), 6, 2);
                q.seed = i;
                c.submit(q)
            })
            .collect();
        // The cold model admits freely while hot is capped out.
        let cold = c.sample_blocking(SampleRequest::new("cold", SolverKind::Tab(0), 5, 4));
        assert!(cold.is_ok(), "per-model cap on 'hot' must not shed 'cold' traffic");
        let results: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        let rejected = results.iter().filter(|r| r.is_err()).count();
        assert!(rejected > 0, "8 instant submissions over a 2-slot cap must shed");
        assert!(results.iter().any(|r| r.is_ok()));
        for r in results.iter().filter(|r| r.is_err()) {
            let msg = r.as_ref().unwrap_err().to_string();
            assert!(msg.contains("model 'hot' overloaded"), "{msg}");
        }
        let s = c.stats();
        assert_eq!(s.requests, s.completed + s.rejected + s.expired);
        let hot = &s.per_model.iter().find(|(n, _)| n == "hot").unwrap().1;
        let cold_m = &s.per_model.iter().find(|(n, _)| n == "cold").unwrap().1;
        assert_eq!(hot.rejected as usize, rejected, "per-model rejections attributed to hot");
        assert_eq!(cold_m.rejected, 0);
        assert_eq!(cold_m.completed, 1);
        assert_eq!(hot.requests, hot.completed + hot.rejected + hot.expired);
        c.shutdown();
    }

    #[test]
    fn zero_deadline_expires_instead_of_sampling() {
        let c = Coordinator::new(CoordinatorConfig::default(), registry());
        let mut req = SampleRequest::new("gmm2d", SolverKind::Tab(2), 10, 8);
        req.deadline_ms = Some(0); // already expired on arrival
        let res = c.sample_blocking(req);
        assert!(res.is_err(), "expired request must not return samples");
        // Generous deadlines behave normally.
        let mut req = SampleRequest::new("gmm2d", SolverKind::Tab(2), 10, 8);
        req.deadline_ms = Some(60_000);
        assert!(c.sample_blocking(req).is_ok());
        let s = c.stats();
        assert_eq!(s.expired, 1);
        assert_eq!(s.completed, 1);
        c.shutdown();
    }

    /// Wrapper that stalls every ε-eval — lets a test deterministically
    /// queue a burst of requests while the (single) worker is mid-eval, so
    /// the burst is admitted in one tick.
    struct SlowEps<M>(M, std::time::Duration);

    impl<M: crate::score::EpsModel> crate::score::EpsModel for SlowEps<M> {
        fn dim(&self) -> usize {
            self.0.dim()
        }

        fn eval(&self, x: &[f64], t: &[f64], b: usize, out: &mut [f64]) {
            std::thread::sleep(self.1);
            self.0.eval(x, t, b, out);
        }
    }

    fn slow_registry(stall: std::time::Duration) -> ModelRegistry {
        let mut r = ModelRegistry::new();
        r.insert(
            "slow",
            Arc::new(SlowEps(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp()), stall)),
        );
        r
    }

    /// A deadline that fires mid-flight (between evals, while the sibling
    /// requests keep integrating) must error exactly that part and leave a
    /// row hole: the surviving merged request still gets bit-exactly its
    /// own rows, proving delivery slices by admission-time `row0` and the
    /// expiry sweep never touches sibling state.
    #[test]
    fn deadline_mid_flight_errors_part_while_sibling_stays_bit_exact() {
        let stall = std::time::Duration::from_millis(40);
        let c = Coordinator::new(
            CoordinatorConfig { workers: 1, max_batch_samples: 4096, ..Default::default() },
            slow_registry(stall),
        );
        // Solo reference for the surviving request, same prior + noise
        // streams the coordinator uses (see tests/scheduler.rs).
        let solo = {
            let model = GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp());
            let kind = SolverKind::Tab(2);
            let sde = Sde::vp();
            let steps = kind.steps_for_nfe(6);
            let grid = crate::timegrid::build(
                crate::timegrid::GridKind::Quadratic,
                &sde,
                sde.t0_default(),
                1.0,
                steps,
            );
            let solver = crate::solvers::build(kind, &sde, &grid);
            let mut rng = crate::util::rng::Rng::new(5);
            let prior = sde.prior_std(1.0);
            let mut x = vec![0.0; 8 * 2];
            for v in x.iter_mut() {
                *v = prior * rng.normal();
            }
            let mut srng = crate::util::rng::Rng::new(5 ^ 0xD1F_F051);
            solver.sample(&model, &mut x, 8, &mut srng);
            x
        };
        // Occupy the single worker so A and B queue during the stall and
        // admission-merge into ONE flight (same batch key).
        let warm = c.submit(SampleRequest::new("slow", SolverKind::Tab(0), 2, 4));
        std::thread::sleep(std::time::Duration::from_millis(10));
        let mut a = SampleRequest::new("slow", SolverKind::Tab(2), 6, 8);
        a.seed = 4;
        // Fires after the flight is admitted (~2 stalls in) but long before
        // its 6 evals finish (~6 stalls): mid-flight by a wide margin.
        a.deadline_ms = Some(150);
        let mut b = SampleRequest::new("slow", SolverKind::Tab(2), 6, 8);
        b.seed = 5;
        let rx_a = c.submit(a);
        let rx_b = c.submit(b);
        let ra = rx_a.recv().unwrap();
        assert!(ra.is_err(), "mid-flight expired part must get an error, not late samples");
        assert!(ra.unwrap_err().to_string().contains("deadline"));
        let rb = rx_b.recv().unwrap().unwrap();
        assert_eq!(
            rb.samples, solo,
            "sibling of an expired part must still receive exactly its own rows"
        );
        assert!(warm.recv().unwrap().is_ok());
        let s = c.stats();
        assert_eq!(s.expired, 1);
        assert_eq!(s.completed, 2, "warm + sibling complete; expired part does not");
        assert_eq!(s.samples, 4 + 8, "only delivered parts contribute sample rows");
        c.shutdown();
    }

    #[test]
    fn scheduler_reports_occupancy_for_merged_evals() {
        // Identical requests admitted in one tick form one trajectory group;
        // every one of its evals serves all 4 requests in a single model
        // call, which must be visible through the occupancy counters.
        let c = Coordinator::new(
            CoordinatorConfig { workers: 1, max_batch_samples: 4096, ..Default::default() },
            slow_registry(std::time::Duration::from_millis(25)),
        );
        // Stall the single worker inside the warm request's first eval; the
        // burst queues during the stall and is admitted together. (If the
        // worker is slow to wake, warm + burst admit in one tick instead —
        // also fine: the burst still forms a single group.)
        let warm = c.submit(SampleRequest::new("slow", SolverKind::Tab(0), 2, 4));
        std::thread::sleep(std::time::Duration::from_millis(8));
        let rxs: Vec<_> = (0..4)
            .map(|i| {
                let mut r = SampleRequest::new("slow", SolverKind::Tab(2), 4, 8);
                r.seed = i;
                c.submit(r)
            })
            .collect();
        let _ = warm.recv().unwrap().unwrap();
        for rx in rxs {
            let res = rx.recv().unwrap().unwrap();
            assert_eq!(res.merged_with, 4, "burst should tick-merge into one group");
            assert!(res.co_batched >= res.merged_with);
        }
        let s = c.stats();
        assert!(s.sched_evals > 0, "scheduled solver ran no merged evals");
        assert!(
            s.max_occupancy >= 4,
            "4 merged requests should co-batch (max occupancy {})",
            s.max_occupancy
        );
        c.shutdown();
    }

    #[test]
    fn cross_solver_same_grid_requests_share_evals() {
        // ddim and tab3 at the same (grid kind, nfe, t0) visit identical t
        // nodes: admitted in the same tick, the scheduler must co-batch
        // their evals even though their batch keys differ — the merge the
        // old admission-keyed batcher could never do.
        let c = Coordinator::new(
            CoordinatorConfig { workers: 1, max_batch_samples: 4096, ..Default::default() },
            slow_registry(std::time::Duration::from_millis(25)),
        );
        // Same stall-window guard as above: a and b must be admitted in one
        // tick so their grids stay in lockstep from t_N on.
        let warm = c.submit(SampleRequest::new("slow", SolverKind::Tab(0), 2, 4));
        std::thread::sleep(std::time::Duration::from_millis(8));
        let rx_a = c.submit(SampleRequest::new("slow", SolverKind::Tab(0), 4, 8));
        let rx_b = c.submit(SampleRequest::new("slow", SolverKind::Tab(3), 4, 8));
        let _ = warm.recv().unwrap().unwrap();
        let a = rx_a.recv().unwrap().unwrap();
        let b = rx_b.recv().unwrap().unwrap();
        assert_eq!(a.merged_with, 1, "different keys must not admission-merge");
        assert_eq!(b.merged_with, 1);
        assert!(
            a.co_batched >= 2 && b.co_batched >= 2,
            "cross-solver evals did not co-batch (a {}, b {})",
            a.co_batched,
            b.co_batched
        );
        c.shutdown();
    }

    /// The full breaker arc at the coordinator surface: consecutive eval
    /// panics open the circuit, open-circuit traffic is refused at submit
    /// (no eval dispatched, counted rejected AND unhealthy), and after the
    /// cooldown a clean eval closes it again — with the 4-term lifecycle
    /// balance holding globally and per model throughout.
    #[test]
    fn breaker_opens_then_refuses_then_recovers_after_cooldown() {
        use crate::score::{FaultPlan, FaultyEps};
        let mut r = ModelRegistry::new();
        r.insert(
            "flaky",
            Arc::new(FaultyEps::new(
                GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp()),
                FaultPlan::new().panic_on(0).panic_on(1),
            )),
        );
        let c = Coordinator::new(
            CoordinatorConfig {
                workers: 1,
                breaker_threshold: 2,
                breaker_cooldown_ms: 60,
                ..Default::default()
            },
            r,
        );
        // Two serialized failing requests trip the threshold-2 breaker.
        for seed in 0..2u64 {
            let mut q = SampleRequest::new("flaky", SolverKind::Tab(0), 5, 4);
            q.seed = seed;
            let err = c.sample_blocking(q).unwrap_err().to_string();
            assert!(err.contains("panicked"), "{err}");
        }
        let health = c.health();
        assert_eq!(health.models, vec![("flaky".to_string(), false)]);
        // Open circuit: refused at submit, no eval dispatched.
        let refused = c
            .sample_blocking(SampleRequest::new("flaky", SolverKind::Tab(0), 5, 4))
            .unwrap_err()
            .to_string();
        assert!(refused.contains("unhealthy"), "{refused}");
        // Half-open after the cooldown: the (now off-script) model evals
        // cleanly, the request completes, the breaker closes.
        std::thread::sleep(std::time::Duration::from_millis(90));
        let ok = c.sample_blocking(SampleRequest::new("flaky", SolverKind::Tab(0), 5, 4));
        assert!(ok.is_ok(), "half-open breaker must admit after cooldown");
        assert_eq!(c.health().models, vec![("flaky".to_string(), true)]);
        let s = c.stats();
        assert_eq!(s.requests, 4);
        assert_eq!(s.failed, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.unhealthy, 1, "the refusal is diagnosed, not just rejected");
        assert_eq!(s.completed, 1);
        assert_eq!(s.requests, s.completed + s.rejected + s.expired + s.failed);
        let (_, m) = s.per_model.iter().find(|(n, _)| n == "flaky").unwrap();
        assert_eq!(m.unhealthy, 1);
        assert_eq!(m.requests, m.completed + m.rejected + m.expired + m.failed);
        c.shutdown();
    }

    /// A worker thread lost to a scheduler panic (injected OUTSIDE the
    /// fault-contained eval region) must be restarted by the supervisor —
    /// with one worker configured, a lost thread would hang the next
    /// request forever.
    #[test]
    fn worker_supervisor_restarts_a_panicked_worker() {
        let c = Coordinator::new(
            CoordinatorConfig { workers: 1, ..Default::default() },
            registry(),
        );
        c.sample_blocking(SampleRequest::new("gmm2d", SolverKind::Tab(0), 5, 4)).unwrap();
        c.arm_worker_bomb(1);
        let ok = c.sample_blocking(SampleRequest::new("gmm2d", SolverKind::Tab(0), 6, 4));
        assert!(ok.is_ok(), "request after a worker panic must still complete");
        assert!(c.health().worker_panics >= 1, "supervisor must count the restart");
        c.shutdown();
    }

    /// Graceful degradation at shutdown: begin_drain refuses new work
    /// immediately, and a drain window too short for the queued tail still
    /// leaves no request unanswered — stranded work gets a "shutting down"
    /// error instead of a hung receiver.
    #[test]
    fn drain_refuses_new_work_and_answers_every_stranded_request() {
        let c = Coordinator::new(
            CoordinatorConfig { workers: 1, max_batch_samples: 1, ..Default::default() },
            slow_registry(std::time::Duration::from_millis(60)),
        );
        // Batch cap 1: no admission merge, so the tail really queues
        // behind the in-flight request while the worker stalls mid-eval.
        let rxs: Vec<_> = (0..3)
            .map(|i| {
                let mut q = SampleRequest::new("slow", SolverKind::Tab(0), 2, 4);
                q.seed = i;
                c.submit(q)
            })
            .collect();
        std::thread::sleep(std::time::Duration::from_millis(10));
        c.begin_drain();
        let refused = c.sample_blocking(SampleRequest::new("slow", SolverKind::Tab(0), 2, 4));
        assert!(
            refused.unwrap_err().to_string().contains("shutting down"),
            "draining coordinator must refuse new submissions"
        );
        c.shutdown_with_timeout(Duration::from_millis(1));
        // Every admitted request was answered exactly once: samples if it
        // beat the drain window, a shutdown error otherwise.
        let replies: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        assert!(replies.iter().any(|r| r.is_err()), "1 ms cannot drain ~360 ms of stalls");
        for r in replies.iter().filter(|r| r.is_err()) {
            let msg = r.as_ref().unwrap_err().to_string();
            assert!(msg.contains("shutting down"), "{msg}");
        }
    }
}
