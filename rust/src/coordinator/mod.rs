//! L3 coordinator: the serving engine.
//!
//! Architecture (vLLM-router-shaped, scaled to a sampling service):
//!
//! ```text
//!   submit() ──> bounded queue ──> Batcher (group by BatchKey)
//!                                     │ merged batch
//!                              worker thread pool
//!                                     │ one solver run per batch
//!                          per-request slices ──> response channels
//! ```
//!
//! Requests that share (model, sde, solver, grid, t0, NFE) are stacked into
//! one state matrix and integrated together — one ε-model call per solver
//! step serves every merged request, which is exactly where DEIS's
//! batch-reusable coefficients pay off. Python is never involved; the model
//! registry maps names to [`EpsModel`] backends (PJRT / native / analytic).
//!
//! Offline-registry note: built on std::thread + channels (no tokio).

pub mod batcher;
pub mod request;
pub mod stats;

pub use request::{BatchKey, SampleRequest, SampleResult};
pub use stats::{Stats, StatsSnapshot};

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::score::EpsModel;
use crate::solvers;
use crate::timegrid;
use crate::util::rng::Rng;

use batcher::Batcher;

/// Model registry: name -> eps backend.
#[derive(Default)]
pub struct ModelRegistry {
    models: HashMap<String, Arc<dyn EpsModel>>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, model: Arc<dyn EpsModel>) {
        self.models.insert(name.to_string(), model);
    }

    pub fn get(&self, name: &str) -> Option<Arc<dyn EpsModel>> {
        self.models.get(name).cloned()
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.sort();
        v
    }
}

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub workers: usize,
    /// Max merged samples per solver run (PJRT artifact cap is 1024; larger
    /// batches chunk inside the backend anyway).
    pub max_batch_samples: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { workers: 2, max_batch_samples: 1024 }
    }
}

type Responder = SyncSender<anyhow::Result<SampleResult>>;

struct Shared {
    batcher: Mutex<Batcher<(Responder, Instant)>>,
    cv: Condvar,
    shutdown: AtomicBool,
    registry: ModelRegistry,
    stats: Stats,
}

pub struct Coordinator {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig, registry: ModelRegistry) -> Coordinator {
        let shared = Arc::new(Shared {
            batcher: Mutex::new(Batcher::new(cfg.max_batch_samples)),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            registry,
            stats: Stats::default(),
        });
        let workers = (0..cfg.workers.max(1))
            .map(|_| {
                let sh = shared.clone();
                std::thread::spawn(move || worker_loop(sh))
            })
            .collect();
        Coordinator { shared, workers }
    }

    /// Non-blocking submit; the receiver yields the result.
    pub fn submit(&self, req: SampleRequest) -> Receiver<anyhow::Result<SampleResult>> {
        let (tx, rx) = sync_channel(1);
        self.shared.stats.requests.fetch_add(1, Ordering::Relaxed);
        {
            let mut b = self.shared.batcher.lock().unwrap();
            b.push(req, (tx, Instant::now()));
        }
        self.shared.cv.notify_one();
        rx
    }

    /// Submit and wait.
    pub fn sample_blocking(&self, req: SampleRequest) -> anyhow::Result<SampleResult> {
        self.submit(req).recv().expect("coordinator dropped response channel")
    }

    pub fn stats(&self) -> StatsSnapshot {
        self.shared.stats.snapshot()
    }

    pub fn models(&self) -> Vec<String> {
        self.shared.registry.names()
    }

    pub fn shutdown(self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    // Merged-batch state buffer, owned by this worker and reused across
    // batches (sized to the largest merged batch seen; part of the
    // zero-hot-loop-allocation discipline of EXPERIMENTS.md §Perf).
    let mut xbuf: Vec<f64> = Vec::new();
    loop {
        let popped = {
            let mut guard = sh.batcher.lock().unwrap();
            loop {
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(batch) = guard.pop_batch() {
                    break Some(batch);
                }
                guard = sh.cv.wait(guard).unwrap();
            }
        };
        let Some((_key, group)) = popped else { return };
        run_batch(&sh, group, &mut xbuf);
    }
}

fn run_batch(
    sh: &Shared,
    group: Vec<batcher::Pending<(Responder, Instant)>>,
    xbuf: &mut Vec<f64>,
) {
    let spec = group[0].req.clone();
    let merged = group.len();
    sh.stats.batches.fetch_add(1, Ordering::Relaxed);
    sh.stats.merged_requests.fetch_add(merged as u64, Ordering::Relaxed);

    let model = match sh.registry.get(&spec.model) {
        Some(m) => m,
        None => {
            for p in group {
                let _ = p.tag.0.send(Err(anyhow::anyhow!("unknown model '{}'", spec.model)));
            }
            return;
        }
    };
    let d = model.dim();
    let total: usize = group.iter().map(|p| p.req.n_samples).sum();

    // Build grid + solver once for the merged run.
    let steps = spec.solver.steps_for_nfe(spec.nfe);
    let grid = timegrid::build(spec.grid, &spec.sde, spec.t0, 1.0, steps);
    let solver = solvers::build(spec.solver, &spec.sde, &grid);

    // Per-request prior draws, deterministic in each request's seed, into
    // the worker's recycled state buffer.
    xbuf.clear();
    xbuf.resize(total * d, 0.0);
    let x = &mut xbuf[..total * d];
    let prior = spec.sde.prior_std(1.0);
    let mut offset = 0;
    for p in &group {
        let mut rng = Rng::new(p.req.seed);
        for v in x[offset * d..(offset + p.req.n_samples) * d].iter_mut() {
            *v = prior * rng.normal();
        }
        offset += p.req.n_samples;
    }

    let t_solve = Instant::now();
    // One rng stream for stochastic solvers across the merged batch,
    // deterministic in the head request's seed.
    let mut srng = Rng::new(spec.seed ^ 0xD1F_F051);
    solver.sample(model.as_ref(), x, total, &mut srng);
    let solve_us = t_solve.elapsed().as_micros() as u64;
    sh.stats.samples.fetch_add(total as u64, Ordering::Relaxed);
    sh.stats.model_evals.fetch_add(solver.nfe() as u64, Ordering::Relaxed);

    let mut offset = 0;
    for p in group {
        let n = p.req.n_samples;
        let res = SampleResult {
            samples: x[offset * d..(offset + n) * d].to_vec(),
            dim: d,
            nfe: spec.nfe,
            merged_with: merged,
            queue_us: t_solve.duration_since(p.enqueued).as_micros() as u64,
            solve_us,
        };
        offset += n;
        sh.stats.completed.fetch_add(1, Ordering::Relaxed);
        sh.stats.record_latency(p.tag.1.elapsed().as_micros() as u64);
        let _ = p.tag.0.send(Ok(res));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diffusion::Sde;
    use crate::gmm::Gmm;
    use crate::score::GmmEps;
    use crate::solvers::SolverKind;
    use crate::util::prop::assert_close;

    fn registry() -> ModelRegistry {
        let mut r = ModelRegistry::new();
        r.insert("gmm2d", Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())));
        r
    }

    #[test]
    fn end_to_end_single_request() {
        let c = Coordinator::new(CoordinatorConfig::default(), registry());
        let res = c
            .sample_blocking(SampleRequest::new("gmm2d", SolverKind::Tab(3), 10, 32))
            .unwrap();
        assert_eq!(res.samples.len(), 64);
        assert_eq!(res.dim, 2);
        assert!(res.samples.iter().all(|v| v.is_finite()));
        c.shutdown();
    }

    #[test]
    fn unknown_model_is_an_error() {
        let c = Coordinator::new(CoordinatorConfig::default(), registry());
        let err = c.sample_blocking(SampleRequest::new("nope", SolverKind::Tab(0), 5, 4));
        assert!(err.is_err());
        c.shutdown();
    }

    #[test]
    fn determinism_per_seed_even_when_merged() {
        // The same (seed, n) request must yield identical samples whether it
        // runs alone or merged with strangers — per-request RNG streams.
        let c = Coordinator::new(
            CoordinatorConfig { workers: 1, max_batch_samples: 4096 },
            registry(),
        );
        let mk = |seed: u64| {
            let mut r = SampleRequest::new("gmm2d", SolverKind::Tab(2), 10, 16);
            r.seed = seed;
            r
        };
        let solo = c.sample_blocking(mk(7)).unwrap();

        // Saturate the queue so the three submissions merge.
        let rx1 = c.submit(mk(1));
        let rx2 = c.submit(mk(7));
        let rx3 = c.submit(mk(3));
        let merged = rx2.recv().unwrap().unwrap();
        let _ = (rx1.recv(), rx3.recv());
        assert_close(&solo.samples, &merged.samples, 1e-12, "seed determinism under merge");
        c.shutdown();
    }

    #[test]
    fn stats_accumulate() {
        let c = Coordinator::new(CoordinatorConfig::default(), registry());
        for _ in 0..3 {
            c.sample_blocking(SampleRequest::new("gmm2d", SolverKind::Tab(0), 5, 8)).unwrap();
        }
        let s = c.stats();
        assert_eq!(s.requests, 3);
        assert_eq!(s.completed, 3);
        assert_eq!(s.samples, 24);
        assert!(s.p50_us > 0);
        c.shutdown();
    }

    #[test]
    fn concurrent_mixed_load() {
        let c = Arc::new(Coordinator::new(
            CoordinatorConfig { workers: 4, max_batch_samples: 256 },
            registry(),
        ));
        let mut handles = Vec::new();
        for i in 0..16 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let solver = [SolverKind::Tab(3), SolverKind::RhoHeun, SolverKind::Tab(0)]
                    [i % 3];
                let mut req = SampleRequest::new("gmm2d", solver, 10, 8 + i);
                req.seed = i as u64;
                let res = c.sample_blocking(req).unwrap();
                assert_eq!(res.samples.len(), (8 + i) * 2);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = c.stats();
        assert_eq!(stats.completed, 16);
        Arc::try_unwrap(c).ok().map(|c| c.shutdown());
    }
}
