//! L3 coordinator: the serving engine.
//!
//! Architecture (continuous-batching-shaped, scaled to a sampling service):
//!
//! ```text
//!   submit() ──> bounded queue ──> admission (group by BatchKey)
//!                                     │ trajectory groups (StepCursor each)
//!                             step-level scheduler
//!                      (bucket pending evals by (model, t))
//!                                     │ one merged ε-eval per bucket
//!                              worker thread pool
//!                                     │ scatter eps, advance cursors
//!                          per-request slices ──> response channels
//! ```
//!
//! Two merging layers. At **admission**, requests that share (model, sde,
//! solver, grid, t0, NFE) are stacked into one state matrix — DEIS's
//! batch-reusable coefficients make the extra rows nearly free. At the
//! **step level** (`scheduler` module), every in-flight trajectory group
//! yields its pending ε-evaluation through the resumable [`StepCursor`]
//! API, and evals that land on the same `(model, t)` are dispatched as one
//! merged network call — amortizing the dominant per-step cost across
//! requests that admission-time keying could never merge. Cursorization is
//! universal (there is no blocking whole-trajectory path), so **all**
//! traffic is co-batchable. Python is never involved; the model registry
//! maps names to [`EpsModel`] backends (PJRT / native / analytic).
//!
//! The per-config (grid, coefficient) plans behind the cursors come from a
//! shared [`PlanCache`](crate::solvers::PlanCache): `submit` resolves the
//! plan on the submitting thread (a map lookup in the steady state) and
//! attaches it to the queued request, so admission under the coordinator
//! mutex does no grid or quadrature work at all.
//!
//! The coordinator mutex itself guards routing state only. Workers check
//! member flights *out of their slots*, so input gather, the model call,
//! the eps scatter and `cursor.advance()` — every O(rows·dim) cost,
//! including stochastic noise draws — run lock-free; a short re-lock
//! re-slots the flights. Under the lock the scheduler consults a ready
//! index ((model, t) buckets + an oldest-first heap + a free-slot list)
//! instead of scanning flight slots, and admission's prior draw + cursor
//! instantiation also run off-lock between two short critical sections.
//! See `scheduler.rs` for the design and its invariants.
//!
//! [`StepCursor`]: crate::solvers::StepCursor
//!
//! Offline-registry note: built on std::thread + channels (no tokio).

pub mod batcher;
pub mod request;
mod scheduler;
pub mod stats;

pub use request::{BatchKey, SampleRequest, SampleResult};
pub use stats::{Stats, StatsSnapshot};

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::score::EpsModel;
use crate::solvers::PlanCache;

/// Model registry: name -> eps backend.
#[derive(Default)]
pub struct ModelRegistry {
    models: HashMap<String, Arc<dyn EpsModel>>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, model: Arc<dyn EpsModel>) {
        self.models.insert(name.to_string(), model);
    }

    pub fn get(&self, name: &str) -> Option<Arc<dyn EpsModel>> {
        self.models.get(name).cloned()
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.sort();
        v
    }
}

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub workers: usize,
    /// Max merged samples per solver run / merged ε-eval (PJRT artifact cap
    /// is 1024; larger batches chunk inside the backend anyway).
    pub max_batch_samples: usize,
    /// Backpressure bound: submissions beyond this many unanswered requests
    /// are rejected immediately with an "overloaded" error instead of
    /// growing the queue without limit.
    pub max_inflight_requests: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { workers: 2, max_batch_samples: 1024, max_inflight_requests: 4096 }
    }
}

pub(crate) type Responder = SyncSender<anyhow::Result<SampleResult>>;

/// Upper bound on a request's NFE budget. NFE comes straight off the wire
/// and sizes both the grid allocation and the coefficient quadrature behind
/// a plan build, so it must be bounded before any plan work happens. Far
/// above any sensible serving config (the paper's regime is NFE <= 50).
pub const MAX_REQUEST_NFE: usize = 8192;

pub(crate) struct Shared {
    pub(crate) state: Mutex<scheduler::SchedState>,
    pub(crate) cv: Condvar,
    pub(crate) shutdown: AtomicBool,
    pub(crate) registry: ModelRegistry,
    pub(crate) stats: Stats,
    pub(crate) max_batch_samples: usize,
    pub(crate) max_inflight: usize,
    /// Shared (grid, coefficients) plans, resolved at submit time so the
    /// coordinator mutex never sees grid or quadrature work.
    pub(crate) plan_cache: PlanCache,
}

pub struct Coordinator {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig, registry: ModelRegistry) -> Coordinator {
        let shared = Arc::new(Shared {
            state: Mutex::new(scheduler::SchedState::new(cfg.max_batch_samples)),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            registry,
            stats: Stats::default(),
            max_batch_samples: cfg.max_batch_samples.max(1),
            max_inflight: cfg.max_inflight_requests.max(1),
            plan_cache: PlanCache::new(),
        });
        let workers = (0..cfg.workers.max(1))
            .map(|_| {
                let sh = shared.clone();
                std::thread::spawn(move || scheduler::worker_loop(sh))
            })
            .collect();
        Coordinator { shared, workers }
    }

    /// Non-blocking submit; the receiver yields the result. Overload,
    /// invalid configurations and pre-expired deadlines are reported through
    /// the receiver as errors.
    ///
    /// Plan resolution happens HERE, on the submitting thread: a shared
    /// [`PlanCache`] lookup in the steady state, a (concurrency-friendly)
    /// build on the first sighting of a config. The coordinator mutex is
    /// only taken afterwards, for the queue push — the heavy polynomial-
    /// integral work of solver construction never runs under it.
    pub fn submit(&self, req: SampleRequest) -> Receiver<anyhow::Result<SampleResult>> {
        let (tx, rx) = sync_channel(1);
        self.shared.stats.requests.fetch_add(1, Ordering::Relaxed);
        let deadline = req.deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
        let reject_overloaded = |inflight: usize| {
            self.shared.stats.rejected.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(Err(anyhow::anyhow!(
                "coordinator overloaded: {inflight} requests in flight (max {}); retry later",
                self.shared.max_inflight
            )));
        };
        // Cheap request sanity BEFORE any plan work: nfe comes off the wire
        // and sizes the grid allocation + coefficient quadrature. Counted
        // as `rejected` so stats account for every refused request.
        if req.nfe > MAX_REQUEST_NFE {
            self.shared.stats.rejected.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(Err(anyhow::anyhow!(
                "nfe {} out of range (max {MAX_REQUEST_NFE})",
                req.nfe
            )));
            return rx;
        }
        // Early shed: an overloaded coordinator must reject without paying
        // for plan resolution (a plan build is the most expensive thing a
        // request can trigger). The bound is re-checked at the queue push.
        {
            let st = self.shared.state.lock().unwrap();
            let inflight = st.inflight_requests();
            if inflight >= self.shared.max_inflight {
                drop(st);
                reject_overloaded(inflight);
                return rx;
            }
        }
        // Grid/solver constructors assert on malformed configs (t0 out of
        // range, too few steps for PNDM, ...); turn panics into per-request
        // errors. No lock is held, so nothing can be poisoned.
        let built = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.shared
                .plan_cache
                .get_or_build(&req.sde, req.solver, req.grid, req.t0, req.nfe)
        }));
        let plan = match built {
            Ok((plan, hit)) => {
                let ctr = if hit {
                    &self.shared.stats.plan_cache_hits
                } else {
                    &self.shared.stats.plan_cache_misses
                };
                ctr.fetch_add(1, Ordering::Relaxed);
                plan
            }
            Err(_) => {
                let _ = tx.send(Err(anyhow::anyhow!(
                    "invalid sampling configuration for solver '{}' (nfe {}, t0 {}): \
                     grid/solver constraints violated",
                    req.solver.name(),
                    req.nfe,
                    req.t0
                )));
                return rx;
            }
        };
        {
            let mut st = self.shared.state.lock().unwrap();
            let inflight = st.inflight_requests();
            if inflight >= self.shared.max_inflight {
                drop(st);
                reject_overloaded(inflight);
                return rx;
            }
            st.queue.push(req, (tx, Instant::now(), deadline, plan));
        }
        self.shared.cv.notify_one();
        rx
    }

    /// Submit and wait.
    pub fn sample_blocking(&self, req: SampleRequest) -> anyhow::Result<SampleResult> {
        self.submit(req).recv().expect("coordinator dropped response channel")
    }

    pub fn stats(&self) -> StatsSnapshot {
        self.shared.stats.snapshot()
    }

    pub fn models(&self) -> Vec<String> {
        self.shared.registry.names()
    }

    pub fn shutdown(self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diffusion::Sde;
    use crate::gmm::Gmm;
    use crate::score::GmmEps;
    use crate::solvers::SolverKind;
    use crate::util::prop::assert_close;

    fn registry() -> ModelRegistry {
        let mut r = ModelRegistry::new();
        r.insert("gmm2d", Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())));
        r
    }

    #[test]
    fn end_to_end_single_request() {
        let c = Coordinator::new(CoordinatorConfig::default(), registry());
        let res = c
            .sample_blocking(SampleRequest::new("gmm2d", SolverKind::Tab(3), 10, 32))
            .unwrap();
        assert_eq!(res.samples.len(), 64);
        assert_eq!(res.dim, 2);
        assert!(res.samples.iter().all(|v| v.is_finite()));
        c.shutdown();
    }

    #[test]
    fn unknown_model_is_an_error() {
        let c = Coordinator::new(CoordinatorConfig::default(), registry());
        let err = c.sample_blocking(SampleRequest::new("nope", SolverKind::Tab(0), 5, 4));
        assert!(err.is_err());
        c.shutdown();
    }

    #[test]
    fn determinism_per_seed_even_when_merged() {
        // The same (seed, n) request must yield identical samples whether it
        // runs alone or merged with strangers — per-request RNG streams.
        let c = Coordinator::new(
            CoordinatorConfig { workers: 1, max_batch_samples: 4096, ..Default::default() },
            registry(),
        );
        let mk = |seed: u64| {
            let mut r = SampleRequest::new("gmm2d", SolverKind::Tab(2), 10, 16);
            r.seed = seed;
            r
        };
        let solo = c.sample_blocking(mk(7)).unwrap();

        // Saturate the queue so the three submissions merge.
        let rx1 = c.submit(mk(1));
        let rx2 = c.submit(mk(7));
        let rx3 = c.submit(mk(3));
        let merged = rx2.recv().unwrap().unwrap();
        let _ = (rx1.recv(), rx3.recv());
        assert_close(&solo.samples, &merged.samples, 1e-12, "seed determinism under merge");
        c.shutdown();
    }

    #[test]
    fn stats_accumulate() {
        let c = Coordinator::new(CoordinatorConfig::default(), registry());
        for _ in 0..3 {
            c.sample_blocking(SampleRequest::new("gmm2d", SolverKind::Tab(0), 5, 8)).unwrap();
        }
        let s = c.stats();
        assert_eq!(s.requests, 3);
        assert_eq!(s.completed, 3);
        assert_eq!(s.samples, 24);
        assert!(s.p50_us > 0);
        c.shutdown();
    }

    #[test]
    fn plan_cache_hits_on_repeat_config_and_does_not_alias() {
        let c = Coordinator::new(CoordinatorConfig::default(), registry());
        let mk = |nfe: usize, seed: u64| {
            let mut r = SampleRequest::new("gmm2d", SolverKind::Tab(2), nfe, 4);
            r.seed = seed;
            r
        };
        let a = c.sample_blocking(mk(10, 1)).unwrap();
        let s = c.stats();
        assert_eq!(s.plan_cache_misses, 1, "first config must build");
        assert_eq!(s.plan_cache_hits, 0);
        // Same config, different seed: admission key and plan key both match
        // — second submission must reuse the cached plan.
        let _ = c.sample_blocking(mk(10, 2)).unwrap();
        let s = c.stats();
        assert_eq!(s.plan_cache_misses, 1);
        assert_eq!(s.plan_cache_hits, 1, "repeat config must hit the plan cache");
        // Distinct config (different NFE): its own plan, not an alias.
        let b = c.sample_blocking(mk(12, 1)).unwrap();
        let s = c.stats();
        assert_eq!(s.plan_cache_misses, 2, "distinct config must build its own plan");
        assert_eq!(s.plan_cache_hits, 1);
        assert_eq!(a.nfe, 10);
        assert_eq!(b.nfe, 12);
        c.shutdown();
    }

    #[test]
    fn invalid_config_is_an_error_not_a_crash() {
        let c = Coordinator::new(CoordinatorConfig::default(), registry());
        // PNDM requires >= 4 grid steps; nfe 10 maps to 1 step. The plan
        // build panics, which submit must convert into a per-request error
        // — and the coordinator must stay serviceable afterwards.
        let bad = SampleRequest::new("gmm2d", SolverKind::Pndm, 10, 4);
        let err = c.sample_blocking(bad);
        assert!(err.is_err(), "invalid config must be reported as an error");
        // Oversized NFE is rejected before any plan work happens.
        let huge = SampleRequest::new("gmm2d", SolverKind::Tab(0), MAX_REQUEST_NFE + 1, 4);
        let err = c.sample_blocking(huge);
        assert!(err.is_err(), "over-cap nfe must be rejected");
        assert!(err.unwrap_err().to_string().contains("out of range"));
        let ok = c.sample_blocking(SampleRequest::new("gmm2d", SolverKind::Tab(0), 5, 4));
        assert!(ok.is_ok(), "coordinator must survive an invalid config");
        c.shutdown();
    }

    #[test]
    fn concurrent_mixed_load() {
        let c = Arc::new(Coordinator::new(
            CoordinatorConfig { workers: 4, max_batch_samples: 256, ..Default::default() },
            registry(),
        ));
        let mut handles = Vec::new();
        for i in 0..16 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let solver = [SolverKind::Tab(3), SolverKind::RhoHeun, SolverKind::Tab(0)]
                    [i % 3];
                let mut req = SampleRequest::new("gmm2d", solver, 10, 8 + i);
                req.seed = i as u64;
                let res = c.sample_blocking(req).unwrap();
                assert_eq!(res.samples.len(), (8 + i) * 2);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = c.stats();
        assert_eq!(stats.completed, 16);
        if let Ok(c) = Arc::try_unwrap(c) {
            c.shutdown();
        }
    }

    #[test]
    fn backpressure_rejects_over_limit() {
        // Two in-flight slots: the burst beyond them must be rejected, and
        // the rejection must be immediate (error through the receiver).
        let c = Coordinator::new(
            CoordinatorConfig { workers: 1, max_batch_samples: 1, max_inflight_requests: 2 },
            registry(),
        );
        let reqs: Vec<_> = (0..24)
            .map(|i| {
                let mut r = SampleRequest::new("gmm2d", SolverKind::Tab(1), 20, 64);
                r.seed = i;
                c.submit(r)
            })
            .collect();
        let results: Vec<_> = reqs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        let rejected = results.iter().filter(|r| r.is_err()).count();
        assert!(rejected > 0, "no submission was rejected under a 2-request cap");
        assert!(results.iter().any(|r| r.is_ok()), "everything was rejected");
        let s = c.stats();
        assert_eq!(s.rejected as usize, rejected);
        assert_eq!(s.completed + s.rejected, 24);
        c.shutdown();
    }

    #[test]
    fn zero_deadline_expires_instead_of_sampling() {
        let c = Coordinator::new(CoordinatorConfig::default(), registry());
        let mut req = SampleRequest::new("gmm2d", SolverKind::Tab(2), 10, 8);
        req.deadline_ms = Some(0); // already expired on arrival
        let res = c.sample_blocking(req);
        assert!(res.is_err(), "expired request must not return samples");
        // Generous deadlines behave normally.
        let mut req = SampleRequest::new("gmm2d", SolverKind::Tab(2), 10, 8);
        req.deadline_ms = Some(60_000);
        assert!(c.sample_blocking(req).is_ok());
        let s = c.stats();
        assert_eq!(s.expired, 1);
        assert_eq!(s.completed, 1);
        c.shutdown();
    }

    /// Wrapper that stalls every ε-eval — lets a test deterministically
    /// queue a burst of requests while the (single) worker is mid-eval, so
    /// the burst is admitted in one tick.
    struct SlowEps<M>(M, std::time::Duration);

    impl<M: crate::score::EpsModel> crate::score::EpsModel for SlowEps<M> {
        fn dim(&self) -> usize {
            self.0.dim()
        }

        fn eval(&self, x: &[f64], t: &[f64], b: usize, out: &mut [f64]) {
            std::thread::sleep(self.1);
            self.0.eval(x, t, b, out);
        }
    }

    fn slow_registry(stall: std::time::Duration) -> ModelRegistry {
        let mut r = ModelRegistry::new();
        r.insert(
            "slow",
            Arc::new(SlowEps(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp()), stall)),
        );
        r
    }

    /// A deadline that fires mid-flight (between evals, while the sibling
    /// requests keep integrating) must error exactly that part and leave a
    /// row hole: the surviving merged request still gets bit-exactly its
    /// own rows, proving delivery slices by admission-time `row0` and the
    /// expiry sweep never touches sibling state.
    #[test]
    fn deadline_mid_flight_errors_part_while_sibling_stays_bit_exact() {
        let stall = std::time::Duration::from_millis(40);
        let c = Coordinator::new(
            CoordinatorConfig { workers: 1, max_batch_samples: 4096, ..Default::default() },
            slow_registry(stall),
        );
        // Solo reference for the surviving request, same prior + noise
        // streams the coordinator uses (see tests/scheduler.rs).
        let solo = {
            let model = GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp());
            let kind = SolverKind::Tab(2);
            let sde = Sde::vp();
            let steps = kind.steps_for_nfe(6);
            let grid = crate::timegrid::build(
                crate::timegrid::GridKind::Quadratic,
                &sde,
                sde.t0_default(),
                1.0,
                steps,
            );
            let solver = crate::solvers::build(kind, &sde, &grid);
            let mut rng = crate::util::rng::Rng::new(5);
            let prior = sde.prior_std(1.0);
            let mut x = vec![0.0; 8 * 2];
            for v in x.iter_mut() {
                *v = prior * rng.normal();
            }
            let mut srng = crate::util::rng::Rng::new(5 ^ 0xD1F_F051);
            solver.sample(&model, &mut x, 8, &mut srng);
            x
        };
        // Occupy the single worker so A and B queue during the stall and
        // admission-merge into ONE flight (same batch key).
        let warm = c.submit(SampleRequest::new("slow", SolverKind::Tab(0), 2, 4));
        std::thread::sleep(std::time::Duration::from_millis(10));
        let mut a = SampleRequest::new("slow", SolverKind::Tab(2), 6, 8);
        a.seed = 4;
        // Fires after the flight is admitted (~2 stalls in) but long before
        // its 6 evals finish (~6 stalls): mid-flight by a wide margin.
        a.deadline_ms = Some(150);
        let mut b = SampleRequest::new("slow", SolverKind::Tab(2), 6, 8);
        b.seed = 5;
        let rx_a = c.submit(a);
        let rx_b = c.submit(b);
        let ra = rx_a.recv().unwrap();
        assert!(ra.is_err(), "mid-flight expired part must get an error, not late samples");
        assert!(ra.unwrap_err().to_string().contains("deadline"));
        let rb = rx_b.recv().unwrap().unwrap();
        assert_eq!(
            rb.samples, solo,
            "sibling of an expired part must still receive exactly its own rows"
        );
        assert!(warm.recv().unwrap().is_ok());
        let s = c.stats();
        assert_eq!(s.expired, 1);
        assert_eq!(s.completed, 2, "warm + sibling complete; expired part does not");
        assert_eq!(s.samples, 4 + 8, "only delivered parts contribute sample rows");
        c.shutdown();
    }

    #[test]
    fn scheduler_reports_occupancy_for_merged_evals() {
        // Identical requests admitted in one tick form one trajectory group;
        // every one of its evals serves all 4 requests in a single model
        // call, which must be visible through the occupancy counters.
        let c = Coordinator::new(
            CoordinatorConfig { workers: 1, max_batch_samples: 4096, ..Default::default() },
            slow_registry(std::time::Duration::from_millis(25)),
        );
        // Stall the single worker inside the warm request's first eval; the
        // burst queues during the stall and is admitted together. (If the
        // worker is slow to wake, warm + burst admit in one tick instead —
        // also fine: the burst still forms a single group.)
        let warm = c.submit(SampleRequest::new("slow", SolverKind::Tab(0), 2, 4));
        std::thread::sleep(std::time::Duration::from_millis(8));
        let rxs: Vec<_> = (0..4)
            .map(|i| {
                let mut r = SampleRequest::new("slow", SolverKind::Tab(2), 4, 8);
                r.seed = i;
                c.submit(r)
            })
            .collect();
        let _ = warm.recv().unwrap().unwrap();
        for rx in rxs {
            let res = rx.recv().unwrap().unwrap();
            assert_eq!(res.merged_with, 4, "burst should tick-merge into one group");
            assert!(res.co_batched >= res.merged_with);
        }
        let s = c.stats();
        assert!(s.sched_evals > 0, "scheduled solver ran no merged evals");
        assert!(
            s.max_occupancy >= 4,
            "4 merged requests should co-batch (max occupancy {})",
            s.max_occupancy
        );
        c.shutdown();
    }

    #[test]
    fn cross_solver_same_grid_requests_share_evals() {
        // ddim and tab3 at the same (grid kind, nfe, t0) visit identical t
        // nodes: admitted in the same tick, the scheduler must co-batch
        // their evals even though their batch keys differ — the merge the
        // old admission-keyed batcher could never do.
        let c = Coordinator::new(
            CoordinatorConfig { workers: 1, max_batch_samples: 4096, ..Default::default() },
            slow_registry(std::time::Duration::from_millis(25)),
        );
        // Same stall-window guard as above: a and b must be admitted in one
        // tick so their grids stay in lockstep from t_N on.
        let warm = c.submit(SampleRequest::new("slow", SolverKind::Tab(0), 2, 4));
        std::thread::sleep(std::time::Duration::from_millis(8));
        let rx_a = c.submit(SampleRequest::new("slow", SolverKind::Tab(0), 4, 8));
        let rx_b = c.submit(SampleRequest::new("slow", SolverKind::Tab(3), 4, 8));
        let _ = warm.recv().unwrap().unwrap();
        let a = rx_a.recv().unwrap().unwrap();
        let b = rx_b.recv().unwrap().unwrap();
        assert_eq!(a.merged_with, 1, "different keys must not admission-merge");
        assert_eq!(b.merged_with, 1);
        assert!(
            a.co_batched >= 2 && b.co_batched >= 2,
            "cross-solver evals did not co-batch (a {}, b {})",
            a.co_batched,
            b.co_batched
        );
        c.shutdown();
    }
}
