//! Tiny property-testing harness (proptest is not in the offline registry).
//!
//! `run_prop` generates `cases` random inputs through a user generator and
//! asserts the property; on failure it reports the seed so the case replays
//! deterministically. No shrinking — generators here produce small values to
//! begin with. Used for coordinator/solver/quadrature invariants.

use crate::util::rng::Rng;

/// Run `prop(rng)` for `cases` independent seeds derived from `seed`.
/// The closure should panic (assert!) on violation.
pub fn run_prop<F: FnMut(&mut Rng)>(name: &str, seed: u64, cases: usize, mut prop: F) {
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(err) = result {
            eprintln!("property '{name}' FAILED at case {case} (replay seed {case_seed:#x})");
            std::panic::resume_unwind(err);
        }
    }
}

/// Assert two slices are element-wise close.
#[track_caller]
pub fn assert_close(a: &[f64], b: &[f64], atol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= atol + 1e-12 * y.abs().max(x.abs()),
            "{what}: element {i}: {x} vs {y} (atol {atol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_prop_executes_all_cases() {
        let mut n = 0;
        run_prop("count", 1, 25, |_| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic]
    fn run_prop_propagates_failure() {
        run_prop("fail", 1, 10, |rng| assert!(rng.uniform() < -1.0));
    }

    #[test]
    fn assert_close_tolerates_atol() {
        assert_close(&[1.0, 2.0], &[1.0 + 1e-9, 2.0], 1e-8, "ok");
    }
}
