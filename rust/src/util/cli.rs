//! Flag parsing for binaries/examples (clap is not in the offline registry).
//!
//! Supports `--key value`, `--key=value`, bare `--flag`, and positionals.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list.
    pub fn list_or(&self, key: &str, default: &str) -> Vec<String> {
        self.str_or(key, default).split(',').map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty()).collect()
    }

    /// Comma-separated list of usize values; entries that fail to parse are
    /// dropped (consistent with the lenient scalar accessors above).
    pub fn usize_list_or(&self, key: &str, default: &str) -> Vec<usize> {
        self.list_or(key, default)
            .iter()
            .filter_map(|s| s.parse().ok())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_all_forms() {
        // NB: a bare flag must be followed by another --flag (or end of argv)
        // to parse as boolean; `--verbose pos1` would consume the positional.
        let a = args("--nfe 10 --solver=tab3 pos1 --verbose --seeds 1,2,3");
        assert_eq!(a.usize_or("nfe", 0), 10);
        assert_eq!(a.str_or("solver", ""), "tab3");
        assert!(a.bool("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
        assert_eq!(a.list_or("seeds", ""), vec!["1", "2", "3"]);
    }

    #[test]
    fn usize_lists_parse_and_drop_junk() {
        let a = args("--nfes 5,10,20 --bad 3,x,7");
        assert_eq!(a.usize_list_or("nfes", ""), vec![5, 10, 20]);
        assert_eq!(a.usize_list_or("bad", ""), vec![3, 7]);
        assert_eq!(a.usize_list_or("missing", "8,16"), vec![8, 16]);
    }

    #[test]
    fn defaults_kick_in() {
        let a = args("");
        assert_eq!(a.f64_or("t0", 1e-3), 1e-3);
        assert!(!a.bool("missing"));
    }
}
