//! Micro-benchmark harness (criterion is not in the offline registry).
//!
//! Warmup + timed iterations + percentile summary, plus a tiny CSV sink so
//! `cargo bench` runs append machine-readable rows under results/.

use std::io::Write as _;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn mean_us(&self) -> f64 {
        self.mean.as_secs_f64() * 1e6
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<42} {:>9.1}us mean  {:>9.1}us p50  {:>9.1}us p99  ({} iters)",
            self.name,
            self.mean.as_secs_f64() * 1e6,
            self.p50.as_secs_f64() * 1e6,
            self.p99.as_secs_f64() * 1e6,
            self.iters
        )
    }
}

/// Time `f` with `warmup` untimed runs then `iters` timed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    summarize(name, samples)
}

/// Time `f` repeatedly until `budget` elapses (at least 3 iterations).
pub fn bench_for<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchStats {
    f(); // warmup
    let start = Instant::now();
    let mut samples = Vec::new();
    while start.elapsed() < budget || samples.len() < 3 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
        if samples.len() > 100_000 {
            break;
        }
    }
    summarize(name, samples)
}

fn summarize(name: &str, mut samples: Vec<Duration>) -> BenchStats {
    samples.sort();
    let n = samples.len();
    let total: Duration = samples.iter().sum();
    let pct = |p: f64| samples[(((n - 1) as f64) * p) as usize];
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean: total / n as u32,
        p50: pct(0.50),
        p95: pct(0.95),
        p99: pct(0.99),
        min: samples[0],
        max: samples[n - 1],
    }
}

/// Prevent the optimizer from deleting a computation's result.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// True when the invocation asked for a smoke run: `--quick` anywhere in
/// argv (`cargo bench --bench perf_hotpath -- --quick`) or the
/// `DEIS_BENCH_QUICK` env var. CI uses this to verify every bench executes
/// end-to-end (and still emits its JSON/CSV rows) without paying full
/// measurement budgets.
pub fn quick_requested() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var_os("DEIS_BENCH_QUICK").is_some()
}

/// Per-bench time budget honoring `--quick`.
pub fn budget_or_quick(full: Duration) -> Duration {
    if quick_requested() {
        // Enough for >= 3 iterations of every hot-path bench; the numbers
        // are smoke-quality only and should not be written into tables.
        Duration::from_millis(40)
    } else {
        full
    }
}

/// Append rows to results/<file>.csv, creating the header on first write.
pub struct CsvSink {
    path: std::path::PathBuf,
    wrote_header: bool,
}

impl CsvSink {
    pub fn new(file: &str, header: &str) -> Self {
        let dir = std::path::Path::new("results");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(file);
        let exists = path.exists();
        let mut sink = CsvSink { path, wrote_header: exists };
        if !exists {
            sink.row(header);
            sink.wrote_header = true;
        }
        sink
    }

    pub fn row(&mut self, line: &str) {
        if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&self.path)
        {
            let _ = writeln!(f, "{line}");
        }
    }
}

/// Machine-readable bench summary: collects [`BenchStats`] and writes one
/// JSON object `{name: {mean_us, p50_us, p99_us}}` so successive PRs can
/// diff the perf trajectory (`BENCH_hotpath.json` at the repo root).
pub struct JsonSink {
    path: std::path::PathBuf,
    entries: Vec<(String, f64, f64, f64)>,
}

impl JsonSink {
    pub fn new(path: &str) -> Self {
        JsonSink { path: std::path::PathBuf::from(path), entries: Vec::new() }
    }

    pub fn add(&mut self, s: &BenchStats) {
        self.entries.push((
            s.name.clone(),
            s.mean_us(),
            s.p50.as_secs_f64() * 1e6,
            s.p99.as_secs_f64() * 1e6,
        ));
    }

    /// Write the collected entries (overwrites; call once at the end).
    pub fn flush(&self) -> std::io::Result<()> {
        let mut out = String::from("{\n");
        for (i, (name, mean, p50, p99)) in self.entries.iter().enumerate() {
            // Bench names are plain ASCII (no quotes/backslashes); escape
            // the two JSON-significant characters anyway for safety.
            let esc = name.replace('\\', "\\\\").replace('"', "\\\"");
            out.push_str(&format!(
                "  \"{esc}\": {{\"mean_us\": {mean:.2}, \"p50_us\": {p50:.2}, \"p99_us\": {p99:.2}}}{}\n",
                if i + 1 < self.entries.len() { "," } else { "" }
            ));
        }
        out.push_str("}\n");
        std::fs::write(&self.path, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_percentiles() {
        let s = bench("noop", 2, 50, || {
            black_box(1 + 1);
        });
        assert_eq!(s.iters, 50);
        assert!(s.min <= s.p50 && s.p50 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn bench_for_respects_min_iters() {
        let s = bench_for("fast", Duration::from_micros(1), || {
            black_box(0);
        });
        assert!(s.iters >= 3);
    }

    #[test]
    fn json_sink_emits_parseable_object() {
        let dir = std::env::temp_dir().join("deis_bench_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("sink.json");
        let mut sink = JsonSink::new(&path.to_string_lossy());
        for name in ["a bench", "b bench"] {
            sink.add(&bench(name, 1, 5, || {
                black_box(1 + 1);
            }));
        }
        sink.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        let a = parsed.get("a bench").unwrap();
        assert!(a.get("mean_us").unwrap().as_f64().unwrap() >= 0.0);
        assert!(a.get("p99_us").unwrap().as_f64().unwrap() >= 0.0);
        let _ = std::fs::remove_file(&path);
    }
}
