//! Micro-benchmark harness (criterion is not in the offline registry).
//!
//! Warmup + timed iterations + percentile summary, plus a tiny CSV sink so
//! `cargo bench` runs append machine-readable rows under results/.

use std::io::Write as _;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn mean_us(&self) -> f64 {
        self.mean.as_secs_f64() * 1e6
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<42} {:>9.1}us mean  {:>9.1}us p50  {:>9.1}us p99  ({} iters)",
            self.name,
            self.mean.as_secs_f64() * 1e6,
            self.p50.as_secs_f64() * 1e6,
            self.p99.as_secs_f64() * 1e6,
            self.iters
        )
    }
}

/// Time `f` with `warmup` untimed runs then `iters` timed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    summarize(name, samples)
}

/// Time `f` repeatedly until `budget` elapses (at least 3 iterations).
pub fn bench_for<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchStats {
    f(); // warmup
    let start = Instant::now();
    let mut samples = Vec::new();
    while start.elapsed() < budget || samples.len() < 3 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
        if samples.len() > 100_000 {
            break;
        }
    }
    summarize(name, samples)
}

fn summarize(name: &str, mut samples: Vec<Duration>) -> BenchStats {
    samples.sort();
    let n = samples.len();
    let total: Duration = samples.iter().sum();
    let pct = |p: f64| samples[(((n - 1) as f64) * p) as usize];
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean: total / n as u32,
        p50: pct(0.50),
        p95: pct(0.95),
        p99: pct(0.99),
        min: samples[0],
        max: samples[n - 1],
    }
}

/// Prevent the optimizer from deleting a computation's result.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Append rows to results/<file>.csv, creating the header on first write.
pub struct CsvSink {
    path: std::path::PathBuf,
    wrote_header: bool,
}

impl CsvSink {
    pub fn new(file: &str, header: &str) -> Self {
        let dir = std::path::Path::new("results");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(file);
        let exists = path.exists();
        let mut sink = CsvSink { path, wrote_header: exists };
        if !exists {
            sink.row(header);
            sink.wrote_header = true;
        }
        sink
    }

    pub fn row(&mut self, line: &str) {
        if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&self.path)
        {
            let _ = writeln!(f, "{line}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_percentiles() {
        let s = bench("noop", 2, 50, || {
            black_box(1 + 1);
        });
        assert_eq!(s.iters, 50);
        assert!(s.min <= s.p50 && s.p50 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn bench_for_respects_min_iters() {
        let s = bench_for("fast", Duration::from_micros(1), || {
            black_box(0);
        });
        assert!(s.iters >= 3);
    }
}
