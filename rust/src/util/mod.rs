//! In-repo substitutes for crates absent from the offline registry
//! (rand, serde, clap, criterion, proptest) — see DESIGN.md §1.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod sync;
