//! Minimal JSON parser/writer (serde is not in the offline registry).
//!
//! Covers the full JSON grammar we exchange with the build path: objects,
//! arrays, numbers, strings with escapes, bool, null. Used to read
//! artifacts/meta.json, weights_*.json, checks_*.json, the parity fixtures,
//! and for the line-JSON wire protocol of `server`.
//!
//! Numbers: unsigned integer tokens are kept exact as [`Json::Int`] (u64),
//! everything else is f64 ([`Json::Num`]). The split exists because RNG
//! seeds ride this format: a u64 seed ≥ 2^53 routed through f64 silently
//! collapses onto a neighbouring even value, so `{"seed": …}` would sample
//! a different trajectory than the client asked for. `as_f64` accepts both
//! variants; `as_u64` is the lossless accessor for seed-shaped fields.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    /// Unsigned integer token, kept exact (f64 loses integers above 2^53).
    Int(u64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn from_file(path: &str) -> Result<Json> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Json::parse(&text).with_context(|| format!("parsing {path}"))
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            Json::Int(u) => Ok(*u as f64),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        if let Json::Int(u) = self {
            return Ok(*u as usize);
        }
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    /// Lossless u64 accessor. Accepts exact integer tokens of any u64
    /// magnitude; accepts float-typed values only when they are non-negative
    /// integers small enough (≤ 2^53) that no precision was lost on the way
    /// in. Rejects negatives and non-integral values.
    pub fn as_u64(&self) -> Result<u64> {
        match self {
            Json::Int(u) => Ok(*u),
            Json::Num(x) => {
                if *x < 0.0 || x.fract() != 0.0 {
                    bail!("not a non-negative integer: {x}");
                }
                if *x > 9_007_199_254_740_992.0 {
                    // 2^53: above this an f64 no longer identifies a unique
                    // integer, so the original value is unrecoverable.
                    bail!("integer too large to round-trip through f64: {x}");
                }
                Ok(*x as u64)
            }
            _ => bail!("not a number"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&std::collections::BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    /// Flat numeric vector.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// 2-D numeric array -> (rows, cols, row-major data).
    pub fn as_matrix(&self) -> Result<(usize, usize, Vec<f64>)> {
        let rows = self.as_arr()?;
        let r = rows.len();
        if r == 0 {
            return Ok((0, 0, vec![]));
        }
        let c = rows[0].as_arr()?.len();
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            let row = row.as_arr()?;
            if row.len() != c {
                bail!("ragged matrix");
            }
            for v in row {
                data.push(v.as_f64()?);
            }
        }
        Ok((r, c, data))
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_f64(out, *x),
            Json::Int(u) => {
                let _ = write!(out, "{u}");
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn uint(u: u64) -> Json {
        Json::Int(u)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
}

/// Serialize one f64 exactly as the tree writer does (integers below 1e15
/// as plain digits, everything else shortest-roundtrip `{:e}`, non-finite
/// as `null`). Shared with the direct reply writer in `server/wire.rs` so
/// a response built without a [`Json`] tree is byte-identical to one built
/// with it — the binary-frame parity tests lean on that.
pub fn write_f64(out: &mut String, x: f64) {
    if x.is_finite() {
        if x.fract() == 0.0 && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
        } else {
            let _ = write!(out, "{x:e}");
        }
    } else {
        out.push_str("null"); // JSON has no inf/nan
    }
}

/// Escape + quote `s` as a JSON string (the tree writer's string form,
/// exported for the direct reply writer).
pub fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'N' => self.lit("NaN", Json::Num(f64::NAN)), // python json.dumps emits these
            b'I' => self.lit("Infinity", Json::Num(f64::INFINITY)),
            b'-' if self.b[self.i..].starts_with(b"-Infinity") => {
                self.lit("-Infinity", Json::Num(f64::NEG_INFINITY))
            }
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs: decode if followed by low surrogate.
                            let ch = if (0xD800..0xDC00).contains(&code)
                                && self.b[self.i..].starts_with(b"\\u")
                            {
                                let hex2 = std::str::from_utf8(&self.b[self.i + 2..self.i + 6])?;
                                let low = u32::from_str_radix(hex2, 16)?;
                                self.i += 6;
                                0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                            } else {
                                code
                            };
                            s.push(char::from_u32(ch).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence.
                    let len = utf8_len(c);
                    let start = self.i - 1;
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        // Pure-digit tokens stay exact as u64 (seeds above 2^53 must not be
        // squeezed through f64); anything signed/fractional/exponential — or
        // too large even for u64 — takes the float path.
        if s.bytes().all(|c| c.is_ascii_digit()) {
            if let Ok(u) = s.parse::<u64>() {
                return Ok(Json::Int(u));
            }
        }
        Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number '{s}'"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// One number token as the [`Scanner`] sees it, mirroring the tree
/// parser's integer/float split (pure-digit tokens stay exact as u64).
/// The conversion methods reproduce [`Json::as_usize`]/[`Json::as_u64`]/
/// [`Json::as_f64`] — same rules, same error texts — so a value parsed
/// through the scanner is indistinguishable from one parsed through the
/// tree.
#[derive(Clone, Copy, Debug)]
pub enum NumTok {
    Int(u64),
    Float(f64),
}

impl NumTok {
    pub fn as_f64(self) -> f64 {
        match self {
            NumTok::Int(u) => u as f64,
            NumTok::Float(x) => x,
        }
    }

    pub fn as_usize(self) -> Result<usize> {
        match self {
            NumTok::Int(u) => Ok(u as usize),
            NumTok::Float(x) => {
                if x < 0.0 || x.fract() != 0.0 {
                    bail!("not a non-negative integer: {x}");
                }
                Ok(x as usize)
            }
        }
    }

    pub fn as_u64(self) -> Result<u64> {
        match self {
            NumTok::Int(u) => Ok(u),
            NumTok::Float(x) => {
                if x < 0.0 || x.fract() != 0.0 {
                    bail!("not a non-negative integer: {x}");
                }
                if x > 9_007_199_254_740_992.0 {
                    bail!("integer too large to round-trip through f64: {x}");
                }
                Ok(x as u64)
            }
        }
    }
}

/// Pull-based zero-copy scanner over one flat JSON object: string values
/// come back as slices borrowed from the input, and nothing allocates.
/// Built for the wire hot path (`server/wire.rs` parses a submit line
/// straight into a `SampleRequest` with no [`Json`] tree); the tree parser
/// above remains the reference for everything else.
///
/// The scanner is deliberately *incomplete*: any construct it cannot
/// handle borrowed — escape sequences in a wanted string, a non-number
/// where a number is expected, structural surprises — is an `Err`, and the
/// caller falls back to the tree parser. That split keeps the fast path
/// honest: it may only ever succeed with exactly the value the tree parser
/// would have produced, never fail where the tree parser would succeed
/// *silently differently*. (`skip_value` does tolerate escapes and nesting
/// — skipping needs no borrow.)
pub struct Scanner<'a> {
    b: &'a [u8],
    s: &'a str,
    i: usize,
    /// Inside the object: whether a key/value pair has been consumed
    /// (controls the `,` separator), and whether `}` has been seen.
    first: bool,
    closed: bool,
}

impl<'a> Scanner<'a> {
    pub fn new(s: &'a str) -> Scanner<'a> {
        Scanner { b: s.as_bytes(), s, i: 0, first: true, closed: false }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    /// Enter the top-level object. Must be called first.
    pub fn begin_object(&mut self) -> Result<()> {
        self.skip_ws();
        self.eat(b'{')
    }

    /// Next key, borrowed, with its `:` consumed — the cursor rests on the
    /// value. `None` once the object closes. Escaped keys are an `Err`
    /// (fall back to the tree parser).
    pub fn next_key(&mut self) -> Result<Option<&'a str>> {
        if self.closed {
            return Ok(None);
        }
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            self.closed = true;
            return Ok(None);
        }
        if self.first {
            self.first = false;
        } else {
            self.eat(b',')?;
            self.skip_ws();
        }
        let key = self.raw_string()?;
        self.skip_ws();
        self.eat(b':')?;
        Ok(Some(key))
    }

    /// After the object closes: only trailing whitespace may remain (the
    /// tree parser's "trailing data" rule).
    pub fn end(&mut self) -> Result<()> {
        if !self.closed {
            bail!("object not closed");
        }
        self.skip_ws();
        if self.i != self.b.len() {
            bail!("trailing data at byte {}", self.i);
        }
        Ok(())
    }

    /// Borrowed string body. Errs on any backslash: an escaped string
    /// cannot be returned as a slice of the input.
    fn raw_string(&mut self) -> Result<&'a str> {
        self.eat(b'"')?;
        let start = self.i;
        loop {
            match self.peek()? {
                b'"' => {
                    let out = &self.s[start..self.i];
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => bail!("escape in string (no zero-copy)"),
                _ => self.i += 1,
            }
        }
    }

    pub fn value_str(&mut self) -> Result<&'a str> {
        self.skip_ws();
        self.raw_string()
    }

    pub fn value_bool(&mut self) -> Result<bool> {
        self.skip_ws();
        if self.b[self.i..].starts_with(b"true") {
            self.i += 4;
            Ok(true)
        } else if self.b[self.i..].starts_with(b"false") {
            self.i += 5;
            Ok(false)
        } else {
            bail!("expected bool at byte {}", self.i)
        }
    }

    /// Number token, split exactly like the tree parser: pure digits stay
    /// u64, everything else (sign/fraction/exponent) is f64. Non-number
    /// values are an `Err` (fall back).
    pub fn value_num(&mut self) -> Result<NumTok> {
        self.skip_ws();
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        if self.i == start {
            bail!("expected number at byte {}", start);
        }
        let s = &self.s[start..self.i];
        if s.bytes().all(|c| c.is_ascii_digit()) {
            if let Ok(u) = s.parse::<u64>() {
                return Ok(NumTok::Int(u));
            }
        }
        Ok(NumTok::Float(s.parse::<f64>().with_context(|| format!("bad number '{s}'"))?))
    }

    /// Skip any value (nested containers, escaped strings, literals) —
    /// the unknown-key path. Skipping validates the same grammar the tree
    /// parser accepts (separators, bracket matching, literals): the fast
    /// path may never bless a line the tree parser would reject. Anything
    /// past the recursion bound errs into the tree-parser fallback instead.
    pub fn skip_value(&mut self) -> Result<()> {
        self.skip_value_rec(0)
    }

    fn skip_value_rec(&mut self, depth: u32) -> Result<()> {
        if depth > 64 {
            bail!("nesting too deep (no zero-copy)");
        }
        self.skip_ws();
        match self.peek()? {
            b'{' => {
                self.i += 1;
                self.skip_ws();
                if self.peek()? == b'}' {
                    self.i += 1;
                    return Ok(());
                }
                loop {
                    self.skip_ws();
                    self.skip_string()?;
                    self.skip_ws();
                    self.eat(b':')?;
                    self.skip_value_rec(depth + 1)?;
                    self.skip_ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b'}' => {
                            self.i += 1;
                            return Ok(());
                        }
                        _ => bail!("expected ',' or '}}' at byte {}", self.i),
                    }
                }
            }
            b'[' => {
                self.i += 1;
                self.skip_ws();
                if self.peek()? == b']' {
                    self.i += 1;
                    return Ok(());
                }
                loop {
                    self.skip_value_rec(depth + 1)?;
                    self.skip_ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b']' => {
                            self.i += 1;
                            return Ok(());
                        }
                        _ => bail!("expected ',' or ']' at byte {}", self.i),
                    }
                }
            }
            b'"' => self.skip_string(),
            b't' => self.skip_lit("true"),
            b'f' => self.skip_lit("false"),
            b'n' => self.skip_lit("null"),
            b'N' => self.skip_lit("NaN"),
            b'I' => self.skip_lit("Infinity"),
            b'-' if self.b[self.i..].starts_with(b"-Infinity") => self.skip_lit("-Infinity"),
            _ => self.value_num().map(|_| ()),
        }
    }

    fn skip_lit(&mut self, word: &str) -> Result<()> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(())
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn skip_string(&mut self) -> Result<()> {
        self.eat(b'"')?;
        loop {
            match self.peek()? {
                b'"' => {
                    self.i += 1;
                    return Ok(());
                }
                b'\\' => {
                    self.i += 1;
                    self.peek()?; // escaped byte must exist
                    self.i += 1;
                }
                _ => self.i += 1,
            }
        }
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e-2], "b": {"c": true, "d": null}, "s": "x\ny"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64_vec().unwrap(), vec![1.0, 2.5, -0.03]);
        assert!(v.get("b").unwrap().get("c").unwrap().as_bool().unwrap());
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "x\ny");
        // writer output reparses to the same value
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn matrix_accessor() {
        let v = Json::parse("[[1,2,3],[4,5,6]]").unwrap();
        let (r, c, d) = v.as_matrix().unwrap();
        assert_eq!((r, c), (2, 3));
        assert_eq!(d, vec![1., 2., 3., 4., 5., 6.]);
        assert!(Json::parse("[[1,2],[3]]").unwrap().as_matrix().is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn u64_seeds_above_2_53_round_trip_exactly() {
        // 2^60 + 1: adjacent f64s differ by 256 here, so any float detour
        // would destroy the low bits. The exact-integer path must not.
        let seed: u64 = (1u64 << 60) + 1;
        let src = format!("{{\"seed\": {seed}}}");
        let v = Json::parse(&src).unwrap();
        assert_eq!(v.get("seed").unwrap().as_u64().unwrap(), seed);
        // Writer emits it exactly and it reparses to the same value.
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(again.get("seed").unwrap().as_u64().unwrap(), seed);
        // u64::MAX survives too (would overflow i64 in the float writer).
        let v = Json::parse(&format!("{}", u64::MAX)).unwrap();
        assert_eq!(v.as_u64().unwrap(), u64::MAX);
        assert_eq!(v.to_string(), format!("{}", u64::MAX));
    }

    #[test]
    fn as_u64_rejects_lossy_and_negative_values() {
        assert!(Json::parse("-3").unwrap().as_u64().is_err());
        assert!(Json::parse("1.5").unwrap().as_u64().is_err());
        assert!(Json::parse("\"7\"").unwrap().as_u64().is_err());
        // A float-typed integral value within exact range is accepted…
        assert_eq!(Json::Num(42.0).as_u64().unwrap(), 42);
        // …but one beyond 2^53 is refused rather than silently rounded.
        assert!(Json::Num(1e300).as_u64().is_err());
        // Int tokens still satisfy the generic numeric accessors.
        assert_eq!(Json::parse("7").unwrap().as_usize().unwrap(), 7);
        assert_eq!(Json::parse("7").unwrap().as_f64().unwrap(), 7.0);
    }

    #[test]
    fn float_precision_survives() {
        let x = 0.123456789012345678;
        let v = Json::parse(&Json::Num(x).to_string()).unwrap();
        assert!((v.as_f64().unwrap() - x).abs() < 1e-15);
    }

    #[test]
    fn scanner_borrows_slices_from_the_input() {
        let src = r#"{"model":"gmm2d","nfe":10,"seed":1152921504606846977,"t0":1e-3}"#;
        let mut sc = Scanner::new(src);
        sc.begin_object().unwrap();
        let range = src.as_bytes().as_ptr_range();
        while let Some(key) = sc.next_key().unwrap() {
            assert!(range.contains(&key.as_ptr()), "key must borrow from the input");
            match key {
                "model" => {
                    let v = sc.value_str().unwrap();
                    assert_eq!(v, "gmm2d");
                    assert!(range.contains(&v.as_ptr()), "value must borrow from the input");
                }
                "nfe" => assert_eq!(sc.value_num().unwrap().as_usize().unwrap(), 10),
                "seed" => {
                    // Above 2^53: the integer split must keep it exact.
                    assert_eq!(sc.value_num().unwrap().as_u64().unwrap(), (1u64 << 60) + 1);
                }
                "t0" => assert_eq!(sc.value_num().unwrap().as_f64(), 1e-3),
                other => panic!("unexpected key {other}"),
            }
        }
        sc.end().unwrap();
    }

    #[test]
    fn scanner_skips_unknown_values_and_rejects_trailing_data() {
        let src = r#"{"x":{"deep":[1,"a\"b",{}]},"y":[true,null,-1.5e3],"z":"k"}"#;
        let mut sc = Scanner::new(src);
        sc.begin_object().unwrap();
        let mut z = "";
        while let Some(key) = sc.next_key().unwrap() {
            if key == "z" {
                z = sc.value_str().unwrap();
            } else {
                sc.skip_value().unwrap();
            }
        }
        assert_eq!(z, "k");
        sc.end().unwrap();

        let mut sc = Scanner::new(r#"{"a":1} extra"#);
        sc.begin_object().unwrap();
        while let Some(_k) = sc.next_key().unwrap() {
            sc.skip_value().unwrap();
        }
        assert!(sc.end().is_err(), "trailing bytes must be rejected");
    }

    #[test]
    fn scanner_refuses_what_it_cannot_borrow() {
        // Escaped wanted-string: must err so callers fall back to the tree.
        let mut sc = Scanner::new(r#"{"model":"a\nb"}"#);
        sc.begin_object().unwrap();
        assert_eq!(sc.next_key().unwrap(), Some("model"));
        assert!(sc.value_str().is_err());
        // Wrong-typed number: err, never a silent coercion.
        let mut sc = Scanner::new(r#"{"nfe":"ten"}"#);
        sc.begin_object().unwrap();
        sc.next_key().unwrap();
        assert!(sc.value_num().is_err());
        // NumTok conversions mirror the tree accessors' rules.
        assert!(NumTok::Float(1.5).as_usize().is_err());
        assert!(NumTok::Float(-1.0).as_u64().is_err());
        assert!(NumTok::Float(1e300).as_u64().is_err());
        assert_eq!(NumTok::Float(42.0).as_u64().unwrap(), 42);
    }

    #[test]
    fn write_f64_matches_the_tree_writer() {
        for x in [0.0, 1.0, -3.5, 1e-3, 0.123456789012345678, 1e300, f64::NAN, 2.0f64.powi(53)] {
            let mut direct = String::new();
            write_f64(&mut direct, x);
            assert_eq!(direct, Json::Num(x).to_string(), "mismatch for {x}");
        }
    }
}
