//! Poison-tolerant lock acquisition.
//!
//! `std`'s mutexes poison on panic, and every `lock().unwrap()` downstream
//! of a single panicking thread then turns one contained fault into a
//! process-wide cascade. The coordinator's locks guard routing bookkeeping
//! whose invariants are maintained by short, panic-free critical sections
//! (all heavy work — ε-evals, solver advances, coefficient math — runs off
//! the locks, and the fault-containment layer catches panics before they
//! unwind through a guard). Recovering the guard is therefore sound: the
//! protected state cannot have been left half-mutated by the panic that
//! poisoned it, and the chaos battery (`rust/tests/chaos.rs`) verifies the
//! bookkeeping still balances after injected faults.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// `mutex.lock()` that recovers the guard from a poisoned mutex instead of
/// panicking.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// `rwlock.read()` with poison recovery.
pub fn read_recover<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// `rwlock.write()` with poison recovery.
pub fn write_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

/// `condvar.wait(guard)` with poison recovery.
pub fn wait_recover<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::AssertUnwindSafe;
    use std::sync::{Arc, RwLock};

    #[test]
    fn poisoned_mutex_recovers() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = m.clone();
        // Poison it: panic while holding the guard.
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*lock_recover(&m), 7);
        // Recovery is repeatable and writable.
        *lock_recover(&m) = 8;
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn poisoned_rwlock_recovers() {
        let l = Arc::new(RwLock::new(1usize));
        let l2 = l.clone();
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison");
        })
        .join();
        assert!(l.read().is_err(), "rwlock should be poisoned");
        assert_eq!(*read_recover(&l), 1);
        *write_recover(&l) = 2;
        assert_eq!(*read_recover(&l), 2);
    }

    #[test]
    fn condvar_wait_recovers_after_poison() {
        // Poison the mutex first, then make sure a waiter can still ride
        // the condvar: recover the guard, wait, observe the signalled state.
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        {
            let p = pair.clone();
            let _ = std::thread::spawn(move || {
                let _g = p.0.lock().unwrap();
                panic!("poison");
            })
            .join();
        }
        let p = pair.clone();
        let waiter = std::thread::spawn(move || {
            let mut g = lock_recover(&p.0);
            while !*g {
                g = wait_recover(&p.1, g);
            }
        });
        std::thread::sleep(std::time::Duration::from_millis(10));
        *lock_recover(&pair.0) = true;
        pair.1.notify_all();
        let joined = std::panic::catch_unwind(AssertUnwindSafe(|| waiter.join().unwrap()));
        assert!(joined.is_ok(), "waiter must survive the poisoned pair");
    }
}
