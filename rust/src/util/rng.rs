//! Seedable PRNG: xoshiro256++ with splitmix64 seeding (rand isn't in the
//! offline registry). Deterministic across platforms — request seeds map to
//! reproducible sample batches, which the parity fixtures and the
//! coordinator's per-request slicing rely on.

/// splitmix64 — used to expand a u64 seed into xoshiro state and to derive
/// independent stream seeds (`Rng::fork`).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Not cryptographic; plenty for sampling workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller variate
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = splitmix64(&mut sm);
        }
        // all-zero state is invalid; splitmix of any seed never yields it, but
        // guard anyway.
        if s == [0; 4] {
            s[0] = 1;
        }
        Rng { s, spare: None }
    }

    /// Derive an independent stream (e.g. one per request in a merged batch).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        Rng::new(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1) with 53-bit precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        // Rejection-free for our use (n << 2^64): multiply-shift.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (spare cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Fill `out` with iid standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        let mut v = vec![0.0; n];
        self.fill_normal(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let (mut m1, mut m2, mut m4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m1 += x;
            m2 += x * x;
            m4 += x * x * x * x;
        }
        let nf = n as f64;
        assert!((m1 / nf).abs() < 0.02, "mean {}", m1 / nf);
        assert!((m2 / nf - 1.0).abs() < 0.03, "var {}", m2 / nf);
        assert!((m4 / nf - 3.0).abs() < 0.15, "kurt {}", m4 / nf);
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(5);
        let mut a = r.fork(0);
        let mut b = r.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }
}
