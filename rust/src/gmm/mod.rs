//! Gaussian-mixture math: the exact-score substrate.
//!
//! A GMM pushed through a scalar diffusion stays a GMM, so the score, the
//! eps-parameterization, log p_t, and the score divergence all have closed
//! forms. This is what lets us measure *pure discretization error* (paper
//! Figs 3–4) and exact NLL — the paper only had neural approximations.

use crate::diffusion::Sde;
use crate::util::rng::Rng;

/// Isotropic mixture: uniform weights, shared std.
#[derive(Clone, Debug)]
pub struct Gmm {
    pub means: Vec<Vec<f64>>, // [M][D]
    pub std: f64,
}

impl Gmm {
    pub fn new(means: Vec<Vec<f64>>, std: f64) -> Gmm {
        assert!(!means.is_empty() && std > 0.0);
        let d = means[0].len();
        assert!(means.iter().all(|m| m.len() == d), "ragged means");
        Gmm { means, std }
    }

    /// Ring of `n` components at `radius` (the gmm2d dataset).
    pub fn ring2d(radius: f64, n: usize, std: f64) -> Gmm {
        let means = (0..n)
            .map(|i| {
                let a = 2.0 * std::f64::consts::PI * i as f64 / n as f64;
                vec![radius * a.cos(), radius * a.sin()]
            })
            .collect();
        Gmm::new(means, std)
    }

    pub fn dim(&self) -> usize {
        self.means[0].len()
    }

    /// Draw n exact data samples into a row-major [n*D] buffer.
    pub fn sample(&self, rng: &mut Rng, n: usize) -> Vec<f64> {
        let d = self.dim();
        let mut out = vec![0.0; n * d];
        for i in 0..n {
            let m = &self.means[rng.below(self.means.len())];
            for j in 0..d {
                out[i * d + j] = m[j] + self.std * rng.normal();
            }
        }
        out
    }

    /// Marginal parameters at time t: (sqrt_abar, component variance).
    fn marginal(&self, sde: &Sde, t: f64) -> (f64, f64) {
        let sq = sde.sqrt_abar(t);
        let sig = sde.sigma(t);
        (sq, (sq * self.std) * (sq * self.std) + sig * sig)
    }

    /// Posterior component weights γ_m(x, t), the common inner loop.
    fn posteriors(&self, sq: f64, var: f64, x: &[f64], gamma: &mut [f64]) {
        let d = self.dim();
        let mut max = f64::NEG_INFINITY;
        for (m, mean) in self.means.iter().enumerate() {
            let mut sq_dist = 0.0;
            for j in 0..d {
                let diff = x[j] - sq * mean[j];
                sq_dist += diff * diff;
            }
            gamma[m] = -0.5 * sq_dist / var;
            max = max.max(gamma[m]);
        }
        let mut z = 0.0;
        for g in gamma.iter_mut() {
            *g = (*g - max).exp();
            z += *g;
        }
        for g in gamma.iter_mut() {
            *g /= z;
        }
    }

    /// Exact eps*(x, t) = -sigma_t * grad log p_t(x) for a batch (row-major).
    pub fn eps(&self, sde: &Sde, x: &[f64], t: &[f64], b: usize, out: &mut [f64]) {
        let d = self.dim();
        assert_eq!(x.len(), b * d);
        assert_eq!(out.len(), b * d);
        let mut gamma = vec![0.0; self.means.len()];
        for i in 0..b {
            let (sq, var) = self.marginal(sde, t[i]);
            let sig = sde.sigma(t[i]);
            let xi = &x[i * d..(i + 1) * d];
            self.posteriors(sq, var, xi, &mut gamma);
            let oi = &mut out[i * d..(i + 1) * d];
            for j in 0..d {
                // score_j = sum_m gamma_m (sq*mu - x)_j / var; eps = -sig*score
                let mut s = 0.0;
                for (m, mean) in self.means.iter().enumerate() {
                    s += gamma[m] * (sq * mean[j] - xi[j]);
                }
                oi[j] = -sig * s / var;
            }
        }
    }

    /// Exact log p_t(x) per row.
    pub fn logp(&self, sde: &Sde, x: &[f64], t: f64, b: usize) -> Vec<f64> {
        let d = self.dim();
        let (sq, var) = self.marginal(sde, t);
        let log_norm = -0.5 * d as f64 * (2.0 * std::f64::consts::PI * var).ln();
        let mut out = vec![0.0; b];
        for i in 0..b {
            let xi = &x[i * d..(i + 1) * d];
            let mut max = f64::NEG_INFINITY;
            let mut terms = Vec::with_capacity(self.means.len());
            for mean in &self.means {
                let mut sq_dist = 0.0;
                for j in 0..d {
                    let diff = xi[j] - sq * mean[j];
                    sq_dist += diff * diff;
                }
                let l = -0.5 * sq_dist / var;
                max = max.max(l);
                terms.push(l);
            }
            let sum: f64 = terms.iter().map(|l| (l - max).exp()).sum();
            out[i] = max + sum.ln() + log_norm - (self.means.len() as f64).ln();
        }
        out
    }

    /// Exact divergence of eps w.r.t. x, tr(∂ε/∂x), per row — needed for the
    /// probability-flow NLL (App. B.1).
    ///
    ///   ∇·score = Σ_m γ_m [ −D/var + ‖u_m‖² ] − ‖Σ_m γ_m u_m‖²,
    ///   u_m = (√ᾱ μ_m − x)/var;  ∇·ε = −σ ∇·score.
    pub fn eps_div(&self, sde: &Sde, x: &[f64], t: &[f64], b: usize) -> Vec<f64> {
        let d = self.dim();
        let mut gamma = vec![0.0; self.means.len()];
        let mut out = vec![0.0; b];
        let mut mean_u = vec![0.0; d];
        for i in 0..b {
            let (sq, var) = self.marginal(sde, t[i]);
            let sig = sde.sigma(t[i]);
            let xi = &x[i * d..(i + 1) * d];
            self.posteriors(sq, var, xi, &mut gamma);
            mean_u.iter_mut().for_each(|v| *v = 0.0);
            let mut acc = 0.0;
            for (m, mean) in self.means.iter().enumerate() {
                let mut norm2 = 0.0;
                for j in 0..d {
                    let u = (sq * mean[j] - xi[j]) / var;
                    norm2 += u * u;
                    mean_u[j] += gamma[m] * u;
                }
                acc += gamma[m] * (norm2 - d as f64 / var);
            }
            let mean_norm2: f64 = mean_u.iter().map(|v| v * v).sum();
            out[i] = -sig * (acc - mean_norm2);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;

    fn fd_eps(gmm: &Gmm, sde: &Sde, x: &[f64], t: f64) -> Vec<f64> {
        // eps = -sigma * grad log p via central differences on logp.
        let d = gmm.dim();
        let h = 1e-5;
        let sig = sde.sigma(t);
        (0..d)
            .map(|j| {
                let mut xp = x.to_vec();
                let mut xm = x.to_vec();
                xp[j] += h;
                xm[j] -= h;
                let lp = gmm.logp(sde, &xp, t, 1)[0];
                let lm = gmm.logp(sde, &xm, t, 1)[0];
                -sig * (lp - lm) / (2.0 * h)
            })
            .collect()
    }

    #[test]
    fn eps_matches_finite_difference_of_logp() {
        let gmm = Gmm::ring2d(4.0, 8, 0.25);
        run_prop("gmm eps fd", 5, 40, |rng| {
            let sde = if rng.below(2) == 0 { Sde::vp() } else { Sde::ve() };
            let t = rng.uniform_in(0.05, 1.0);
            let x = vec![rng.uniform_in(-5.0, 5.0), rng.uniform_in(-5.0, 5.0)];
            let mut got = vec![0.0; 2];
            gmm.eps(&sde, &x, &[t], 1, &mut got);
            let want = fd_eps(&gmm, &sde, &x, t);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "{got:?} vs {want:?} t={t}");
            }
        });
    }

    #[test]
    fn eps_div_matches_finite_difference() {
        let gmm = Gmm::ring2d(4.0, 8, 0.25);
        run_prop("gmm div fd", 6, 40, |rng| {
            let sde = Sde::vp();
            let t = rng.uniform_in(0.05, 1.0);
            let x = vec![rng.uniform_in(-5.0, 5.0), rng.uniform_in(-5.0, 5.0)];
            let h = 1e-5;
            let mut div_fd = 0.0;
            for j in 0..2 {
                let mut xp = x.clone();
                let mut xm = x.clone();
                xp[j] += h;
                xm[j] -= h;
                let mut ep = vec![0.0; 2];
                let mut em = vec![0.0; 2];
                gmm.eps(&sde, &xp, &[t], 1, &mut ep);
                gmm.eps(&sde, &xm, &[t], 1, &mut em);
                div_fd += (ep[j] - em[j]) / (2.0 * h);
            }
            let got = gmm.eps_div(&sde, &x, &[t], 1)[0];
            assert!((got - div_fd).abs() < 1e-4, "{got} vs {div_fd} t={t}");
        });
    }

    #[test]
    fn single_gaussian_closed_form() {
        // M=1: eps(x) = sig * (x - sq*mu) / var * sig ... check directly:
        // score = (sq*mu - x)/var, eps = -sig*score.
        let gmm = Gmm::new(vec![vec![2.0]], 0.5);
        let sde = Sde::vp();
        let (t, x) = (0.3, 1.1);
        let sq = sde.sqrt_abar(t);
        let var = (sq * 0.5) * (sq * 0.5) + sde.sigma(t).powi(2);
        let want = sde.sigma(t) * (x - sq * 2.0) / var;
        let mut got = vec![0.0];
        gmm.eps(&sde, &[x], &[t], 1, &mut got);
        assert!((got[0] - want).abs() < 1e-12);
    }

    #[test]
    fn sample_means_cover_modes() {
        let gmm = Gmm::ring2d(4.0, 8, 0.1);
        let mut rng = Rng::new(3);
        let xs = gmm.sample(&mut rng, 4000);
        // every sample within 5 sigma of some mode
        for i in 0..4000 {
            let x = &xs[i * 2..i * 2 + 2];
            let dmin = gmm
                .means
                .iter()
                .map(|m| ((x[0] - m[0]).powi(2) + (x[1] - m[1]).powi(2)).sqrt())
                .fold(f64::INFINITY, f64::min);
            assert!(dmin < 0.5, "sample {x:?} too far ({dmin})");
        }
    }

    #[test]
    fn logp_integrates_to_one_1d() {
        // trapezoid over a wide grid for a 1-D mixture.
        let gmm = Gmm::new(vec![vec![-1.0], vec![1.0]], 0.3);
        let sde = Sde::vp();
        let n = 4000;
        let (lo, hi) = (-10.0, 10.0);
        let h = (hi - lo) / n as f64;
        let xs: Vec<f64> = (0..=n).map(|i| lo + i as f64 * h).collect();
        let lp = gmm.logp(&sde, &xs, 0.5, n + 1);
        let integral: f64 = lp.iter().map(|l| l.exp()).sum::<f64>() * h;
        assert!((integral - 1.0).abs() < 1e-3, "{integral}");
    }
}
