//! Blackbox adaptive RK45 (Dormand–Prince 5(4)) on the probability-flow ODE
//! in t — the `scipy.integrate.solve_ivp` baseline of paper Tab. 11 / Fig. 5.
//! It ignores the provided grid except for its endpoints, adapts its own
//! step, and (like the paper notes) wastes NFE on rejected steps at tight
//! tolerances. NFE is whatever the controller spends; wrap the model in
//! `score::Counting` to measure it.

use crate::diffusion::Sde;
use crate::score::EpsModel;
use crate::solvers::{fill_t, Solver};
use crate::util::rng::Rng;

// Dormand–Prince coefficients.
const C: [f64; 7] = [0.0, 1.0 / 5.0, 3.0 / 10.0, 4.0 / 5.0, 8.0 / 9.0, 1.0, 1.0];
const A: [[f64; 6]; 7] = [
    [0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
    [1.0 / 5.0, 0.0, 0.0, 0.0, 0.0, 0.0],
    [3.0 / 40.0, 9.0 / 40.0, 0.0, 0.0, 0.0, 0.0],
    [44.0 / 45.0, -56.0 / 15.0, 32.0 / 9.0, 0.0, 0.0, 0.0],
    [19372.0 / 6561.0, -25360.0 / 2187.0, 64448.0 / 6561.0, -212.0 / 729.0, 0.0, 0.0],
    [9017.0 / 3168.0, -355.0 / 33.0, 46732.0 / 5247.0, 49.0 / 176.0, -5103.0 / 18656.0, 0.0],
    [35.0 / 384.0, 0.0, 500.0 / 1113.0, 125.0 / 192.0, -2187.0 / 6784.0, 11.0 / 84.0],
];
const B5: [f64; 7] = [35.0 / 384.0, 0.0, 500.0 / 1113.0, 125.0 / 192.0, -2187.0 / 6784.0, 11.0 / 84.0, 0.0];
const B4: [f64; 7] = [
    5179.0 / 57600.0,
    0.0,
    7571.0 / 16695.0,
    393.0 / 640.0,
    -92097.0 / 339200.0,
    187.0 / 2100.0,
    1.0 / 40.0,
];

pub struct Rk45 {
    sde: Sde,
    t0: f64,
    t_max: f64,
    pub rtol: f64,
    pub atol: f64,
}

impl Rk45 {
    pub fn new(sde: &Sde, grid: &[f64], rtol: f64, atol: f64) -> Self {
        Rk45 { sde: *sde, t0: grid[0], t_max: grid[grid.len() - 1], rtol, atol }
    }

    /// dx/dt of the eps-form PF ODE (Eq. 10).
    fn deriv(
        &self,
        model: &dyn EpsModel,
        x: &[f64],
        t: f64,
        b: usize,
        tb: &mut Vec<f64>,
        out: &mut [f64],
    ) {
        model.eval(x, fill_t(tb, t, b), b, out);
        let f = self.sde.f_scalar(t);
        let w = 0.5 * self.sde.g2(t) / self.sde.sigma(t);
        for (o, &xv) in out.iter_mut().zip(x) {
            *o = f * xv + w * *o;
        }
    }
}

impl Solver for Rk45 {
    fn name(&self) -> String {
        format!("rk45[{:.0e}]", self.rtol)
    }

    fn nfe(&self) -> usize {
        0 // adaptive — measured, not declared
    }

    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, _rng: &mut Rng) {
        let d = model.dim();
        let mut tb = Vec::new();
        let mut k: Vec<Vec<f64>> = (0..7).map(|_| vec![0.0; b * d]).collect();
        let mut xs = vec![0.0; b * d];
        let mut x5 = vec![0.0; b * d];

        let mut t = self.t_max;
        let mut h = -(self.t_max - self.t0) * 0.02; // initial step, backward
        let h_min = 1e-10;

        self.deriv(model, x, t, b, &mut tb, &mut k[0]);
        while t > self.t0 + 1e-12 {
            if t + h < self.t0 {
                h = self.t0 - t;
            }
            // Stages 1..6 (k[0] carried over, FSAL).
            for s in 1..7 {
                xs.copy_from_slice(x);
                for (j, kj) in k.iter().enumerate().take(s) {
                    let a = A[s][j];
                    if a != 0.0 {
                        for (xv, kv) in xs.iter_mut().zip(kj) {
                            *xv += h * a * kv;
                        }
                    }
                }
                let (head, tail) = k.split_at_mut(s);
                let _ = head;
                self.deriv(model, &xs, t + C[s] * h, b, &mut tb, &mut tail[0]);
            }
            // 5th-order solution + embedded error estimate.
            x5.copy_from_slice(x);
            let mut err: f64 = 0.0;
            for idx in 0..b * d {
                let mut dx5 = 0.0;
                let mut dx4 = 0.0;
                for s in 0..7 {
                    dx5 += B5[s] * k[s][idx];
                    dx4 += B4[s] * k[s][idx];
                }
                x5[idx] += h * dx5;
                let sc = self.atol + self.rtol * x[idx].abs().max(x5[idx].abs());
                let e = h * (dx5 - dx4) / sc;
                err += e * e;
            }
            err = (err / (b * d) as f64).sqrt();

            if err <= 1.0 {
                t += h;
                x.copy_from_slice(&x5);
                // FSAL: k7 of the accepted step is k1 of the next.
                let last = k[6].clone();
                k[0].copy_from_slice(&last);
            }
            // PI-ish controller.
            let factor = (0.9 * err.powf(-0.2)).clamp(0.2, 5.0);
            h *= factor;
            if h.abs() < h_min {
                h = -h_min;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::score::{Counting, GmmEps};
    use crate::solvers::tab::TabDeis;
    use crate::timegrid::{build, GridKind};

    #[test]
    fn rk45_matches_fine_ddim() {
        let sde = Sde::vp();
        let gmm = Gmm::ring2d(4.0, 8, 0.25);
        let model = GmmEps::new(gmm, sde);
        let b = 6;
        let x0: Vec<f64> = Rng::new(12).normal_vec(b * 2);
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 640);

        let mut x_ref = x0.clone();
        TabDeis::new(&sde, &grid, 3).sample(&model, &mut x_ref, b, &mut Rng::new(0));

        let mut x_rk = x0;
        let counted = Counting::new(&model);
        Rk45::new(&sde, &grid, 1e-6, 1e-6).sample(&counted, &mut x_rk, b, &mut Rng::new(0));
        let err: f64 =
            x_rk.iter().zip(&x_ref).map(|(a, r)| (a - r).abs()).fold(0.0, f64::max);
        assert!(err < 1e-3, "rk45 vs fine tab3: {err}");
        assert!(counted.nfe() > 20, "adaptive solver did work: {}", counted.nfe());
    }

    #[test]
    fn looser_tolerance_spends_fewer_nfe() {
        let sde = Sde::vp();
        let model = GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), sde);
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 10);
        let b = 4;
        let x0: Vec<f64> = Rng::new(3).normal_vec(b * 2);
        let spend = |tol: f64| {
            let counted = Counting::new(&model);
            let mut x = x0.clone();
            Rk45::new(&sde, &grid, tol, tol).sample(&counted, &mut x, b, &mut Rng::new(0));
            counted.nfe()
        };
        assert!(spend(1e-2) < spend(1e-6));
    }
}
