//! Blackbox adaptive RK45 (Dormand–Prince 5(4)) on the probability-flow ODE
//! in t — the `scipy.integrate.solve_ivp` baseline of paper Tab. 11 / Fig. 5.
//! It ignores the provided grid except for its endpoints, adapts its own
//! step, and (like the paper notes) wastes NFE on rejected steps at tight
//! tolerances. NFE is whatever the controller spends; wrap the model in
//! `score::Counting` to measure it.

use crate::diffusion::Sde;
use crate::score::EpsModel;
use crate::solvers::plan::{sample_via_cursor, StepCursor};
use crate::solvers::Solver;
use crate::util::rng::Rng;

// Dormand–Prince coefficients.
const C: [f64; 7] = [0.0, 1.0 / 5.0, 3.0 / 10.0, 4.0 / 5.0, 8.0 / 9.0, 1.0, 1.0];
const A: [[f64; 6]; 7] = [
    [0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
    [1.0 / 5.0, 0.0, 0.0, 0.0, 0.0, 0.0],
    [3.0 / 40.0, 9.0 / 40.0, 0.0, 0.0, 0.0, 0.0],
    [44.0 / 45.0, -56.0 / 15.0, 32.0 / 9.0, 0.0, 0.0, 0.0],
    [19372.0 / 6561.0, -25360.0 / 2187.0, 64448.0 / 6561.0, -212.0 / 729.0, 0.0, 0.0],
    [9017.0 / 3168.0, -355.0 / 33.0, 46732.0 / 5247.0, 49.0 / 176.0, -5103.0 / 18656.0, 0.0],
    [35.0 / 384.0, 0.0, 500.0 / 1113.0, 125.0 / 192.0, -2187.0 / 6784.0, 11.0 / 84.0],
];
const B5: [f64; 7] = [35.0 / 384.0, 0.0, 500.0 / 1113.0, 125.0 / 192.0, -2187.0 / 6784.0, 11.0 / 84.0, 0.0];
const B4: [f64; 7] = [
    5179.0 / 57600.0,
    0.0,
    7571.0 / 16695.0,
    393.0 / 640.0,
    -92097.0 / 339200.0,
    187.0 / 2100.0,
    1.0 / 40.0,
];

pub struct Rk45 {
    sde: Sde,
    t0: f64,
    t_max: f64,
    pub rtol: f64,
    pub atol: f64,
}

impl Rk45 {
    pub fn new(sde: &Sde, grid: &[f64], rtol: f64, atol: f64) -> Self {
        Rk45 { sde: *sde, t0: grid[0], t_max: grid[grid.len() - 1], rtol, atol }
    }
}

/// Smallest |h| the controller may shrink to (guards against stalling).
const H_MIN: f64 = 1e-10;

/// Resumable Dormand–Prince step machine. The cursor yields one raw ε-eval
/// per RK stage; `advance` applies the PF-ODE transform (Eq. 10:
/// k = f(t)·x_stage + w(t)·eps), and after the 7th stage of an attempt it
/// computes the embedded 5(4) error estimate and runs the step-size
/// controller — all between yields, so the adaptive step sequence (accepts,
/// rejects, h trajectory) is exactly the one the former blocking loop took.
pub struct Rk45Cursor {
    sde: Sde,
    t0: f64,
    rtol: f64,
    atol: f64,
    /// Accepted state.
    x: Vec<f64>,
    /// Stage input for the pending eval (stages 1..=6).
    xs: Vec<f64>,
    /// 5th-order candidate of the current attempt.
    x5: Vec<f64>,
    /// Stage derivatives; the pending eval writes raw eps into `k[stage]`.
    k: Vec<Vec<f64>>,
    t: f64,
    h: f64,
    /// Time of the pending eval (cached so `pending_t` stays pure).
    t_eval: f64,
    /// 0 = the initial FSAL eval on `x`; 1..=6 = stage of the current attempt.
    stage: usize,
    done: bool,
    b: usize,
}

impl Rk45Cursor {
    fn new(solver: &Rk45, x: &[f64], b: usize) -> Rk45Cursor {
        Rk45Cursor {
            sde: solver.sde,
            t0: solver.t0,
            rtol: solver.rtol,
            atol: solver.atol,
            x: x.to_vec(),
            xs: vec![0.0; x.len()],
            x5: vec![0.0; x.len()],
            k: (0..7).map(|_| vec![0.0; x.len()]).collect(),
            t: solver.t_max,
            h: -(solver.t_max - solver.t0) * 0.02, // initial step, backward
            t_eval: solver.t_max,
            stage: 0,
            done: false,
            b,
        }
    }

    /// eps -> PF-ODE derivative in place (Eq. 10), using the stage input the
    /// eval was issued on.
    fn to_deriv(&mut self, stage: usize) {
        let f = self.sde.f_scalar(self.t_eval);
        let w = 0.5 * self.sde.g2(self.t_eval) / self.sde.sigma(self.t_eval);
        let x_in = if stage == 0 { &self.x } else { &self.xs };
        for (o, &xv) in self.k[stage].iter_mut().zip(x_in) {
            *o = f * xv + w * *o;
        }
    }

    /// Start the next attempted step, or finish the integration.
    fn begin_attempt(&mut self) {
        if self.t <= self.t0 + 1e-12 {
            self.done = true;
            return;
        }
        if self.t + self.h < self.t0 {
            self.h = self.t0 - self.t;
        }
        self.stage = 1;
        self.prep_stage();
    }

    /// Build the stage input xs = x + h·Σ_j A[s][j]·k_j and the stage time.
    fn prep_stage(&mut self) {
        let s = self.stage;
        self.xs.copy_from_slice(&self.x);
        for (j, kj) in self.k.iter().enumerate().take(s) {
            let a = A[s][j];
            if a != 0.0 {
                let h = self.h;
                for (xv, kv) in self.xs.iter_mut().zip(kj) {
                    *xv += h * a * kv;
                }
            }
        }
        self.t_eval = self.t + C[s] * self.h;
    }

    /// All 7 stage derivatives are in: 5th-order solution + embedded error
    /// estimate, accept/reject, and the step-size controller.
    fn finish_attempt(&mut self) {
        let nd = self.x.len();
        self.x5.copy_from_slice(&self.x);
        let mut err: f64 = 0.0;
        for idx in 0..nd {
            let mut dx5 = 0.0;
            let mut dx4 = 0.0;
            for s in 0..7 {
                dx5 += B5[s] * self.k[s][idx];
                dx4 += B4[s] * self.k[s][idx];
            }
            self.x5[idx] += self.h * dx5;
            let sc = self.atol + self.rtol * self.x[idx].abs().max(self.x5[idx].abs());
            let e = self.h * (dx5 - dx4) / sc;
            err += e * e;
        }
        err = (err / nd as f64).sqrt();

        if err <= 1.0 {
            self.t += self.h;
            self.x.copy_from_slice(&self.x5);
            // FSAL: k7 of the accepted attempt is k1 of the next.
            let (head, tail) = self.k.split_at_mut(6);
            head[0].copy_from_slice(&tail[0]);
        }
        // PI-ish controller.
        let factor = (0.9 * err.powf(-0.2)).clamp(0.2, 5.0);
        self.h *= factor;
        if self.h.abs() < H_MIN {
            self.h = -H_MIN;
        }
        self.begin_attempt();
    }
}

impl StepCursor for Rk45Cursor {
    fn pending_t(&self) -> Option<f64> {
        if self.done {
            None
        } else {
            Some(self.t_eval)
        }
    }

    fn io(&mut self) -> (&[f64], &mut [f64]) {
        let stage = self.stage;
        if stage == 0 {
            (&self.x, &mut self.k[0])
        } else {
            (&self.xs, &mut self.k[stage])
        }
    }

    fn advance(&mut self) {
        let stage = self.stage;
        self.to_deriv(stage);
        if stage == 0 {
            self.begin_attempt();
        } else if stage < 6 {
            self.stage = stage + 1;
            self.prep_stage();
        } else {
            self.finish_attempt();
        }
    }

    fn batch(&self) -> usize {
        self.b
    }

    fn take_samples(&mut self) -> Vec<f64> {
        std::mem::take(&mut self.x)
    }
}

impl Solver for Rk45 {
    fn name(&self) -> String {
        format!("rk45[{:.0e}]", self.rtol)
    }

    fn nfe(&self) -> usize {
        0 // adaptive — measured, not declared
    }

    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, rng: &mut Rng) {
        sample_via_cursor(self, model, x, b, rng);
    }

    fn cursor(&self, x: &[f64], b: usize, _rng: &mut Rng) -> Box<dyn StepCursor> {
        Box::new(Rk45Cursor::new(self, x, b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::score::{Counting, GmmEps};
    use crate::solvers::tab::TabDeis;
    use crate::timegrid::{build, GridKind};

    #[test]
    fn rk45_matches_fine_ddim() {
        let sde = Sde::vp();
        let gmm = Gmm::ring2d(4.0, 8, 0.25);
        let model = GmmEps::new(gmm, sde);
        let b = 6;
        let x0: Vec<f64> = Rng::new(12).normal_vec(b * 2);
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 640);

        let mut x_ref = x0.clone();
        TabDeis::new(&sde, &grid, 3).sample(&model, &mut x_ref, b, &mut Rng::new(0));

        let mut x_rk = x0;
        let counted = Counting::new(&model);
        Rk45::new(&sde, &grid, 1e-6, 1e-6).sample(&counted, &mut x_rk, b, &mut Rng::new(0));
        let err: f64 =
            x_rk.iter().zip(&x_ref).map(|(a, r)| (a - r).abs()).fold(0.0, f64::max);
        assert!(err < 1e-3, "rk45 vs fine tab3: {err}");
        assert!(counted.nfe() > 20, "adaptive solver did work: {}", counted.nfe());
    }

    #[test]
    fn looser_tolerance_spends_fewer_nfe() {
        let sde = Sde::vp();
        let model = GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), sde);
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 10);
        let b = 4;
        let x0: Vec<f64> = Rng::new(3).normal_vec(b * 2);
        let spend = |tol: f64| {
            let counted = Counting::new(&model);
            let mut x = x0.clone();
            Rk45::new(&sde, &grid, tol, tol).sample(&counted, &mut x, b, &mut Rng::new(0));
            counted.nfe()
        };
        assert!(spend(1e-2) < spend(1e-6));
    }
}
