//! Stochastic samplers (paper Eq. 4 with λ > 0 and App. C/G):
//!   * Euler–Maruyama on the reverse SDE (λ = 1) — Fig. 5's "EM" baseline.
//!   * Stochastic DDIM with hyperparameter η (Eq. 34; Prop. 4 shows its
//!     continuous limit is Eq. 4 with λ = η).
//!   * Analytic-DDIM (Bao et al. 2022, Tab. 12 baseline): DDPM-family mean
//!     with the *analytically optimal* reverse variance. The paper's exact
//!     Γ_n uses a precomputed dataset statistic; we estimate E‖ε‖²/d from
//!     the current batch (documented substitution, DESIGN.md §1) and expose
//!     the x̂0-clipping trick the paper says A-DDIM depends on.

use crate::diffusion::Sde;
use crate::score::EpsModel;
use crate::solvers::plan::{sample_via_cursor, StepCursor};
use crate::solvers::Solver;
use crate::util::rng::Rng;

pub struct EulerMaruyama {
    sde: Sde,
    grid: Vec<f64>,
}

impl EulerMaruyama {
    pub fn new(sde: &Sde, grid: &[f64]) -> Self {
        EulerMaruyama { sde: *sde, grid: grid.to_vec() }
    }
}

/// Which per-step update a [`StochCursor`] applies.
#[derive(Clone, Copy)]
enum StochKind {
    Em,
    Sddim { eta: f64 },
    Addim { clip: Option<f64> },
}

/// Resumable step machine shared by all three stochastic samplers — they
/// differ only in the per-step update (`StochKind`), each one eval per grid
/// step on `x`. The cursor owns its `Rng` (cloned from the stream handed to
/// [`Solver::cursor`]) and draws noise only in `advance`, so the noise a
/// trajectory receives does not depend on how its evals were co-batched by
/// the scheduler.
pub struct StochCursor {
    sde: Sde,
    grid: Vec<f64>,
    kind: StochKind,
    x: Vec<f64>,
    eps: Vec<f64>,
    rng: Rng,
    /// Integrating grid[i] -> grid[i-1]; done at i == 0.
    i: usize,
    b: usize,
}

impl StochCursor {
    fn new(sde: &Sde, grid: &[f64], kind: StochKind, x: &[f64], b: usize, rng: &mut Rng) -> Self {
        StochCursor {
            sde: *sde,
            grid: grid.to_vec(),
            kind,
            x: x.to_vec(),
            eps: vec![0.0; x.len()],
            rng: rng.clone(),
            i: grid.len() - 1,
            b,
        }
    }

    /// Euler–Maruyama on the reverse SDE (λ = 1).
    fn advance_em(&mut self) {
        let (t, t_prev) = (self.grid[self.i], self.grid[self.i - 1]);
        let dt = t_prev - t; // negative
        let f = self.sde.f_scalar(t);
        let g2 = self.sde.g2(t);
        let w = g2 / self.sde.sigma(t); // (1+λ²)/2 · g²/σ with λ=1
        let noise_scale = ((-dt).max(0.0)).sqrt() * g2.sqrt();
        for (xv, ev) in self.x.iter_mut().zip(&self.eps) {
            *xv += dt * (f * *xv + w * ev) + noise_scale * self.rng.normal();
        }
    }

    /// Stochastic DDIM step (Eq. 34).
    fn advance_sddim(&mut self, eta: f64) {
        let i = self.i;
        let (t_s, t_e) = (self.grid[i], self.grid[i - 1]);
        let (a_s, a_e) = (self.sde.abar(t_s), self.sde.abar(t_e));
        let (sig_s, sig_e) = (self.sde.sigma(t_s), self.sde.sigma(t_e));
        // Eq. (34): sigma_eta^2 = eta^2 (1-a_e)/(1-a_s) (1 - a_s/a_e)
        let var_eta = eta * eta * (1.0 - a_e) / (1.0 - a_s) * (1.0 - a_s / a_e);
        // No noise into the final state.
        let var_eta = if i == 1 { 0.0 } else { var_eta.max(0.0) };
        let coef_eps = (sig_e * sig_e - var_eta).max(0.0).sqrt();
        let scale = (a_e / a_s).sqrt();
        let sd = var_eta.sqrt();
        for (xv, ev) in self.x.iter_mut().zip(&self.eps) {
            let x0_dir = scale * (*xv - sig_s * ev);
            *xv = x0_dir + coef_eps * ev + sd * self.rng.normal();
        }
    }

    /// Analytic-DDIM step. The Γ estimate (mean ‖ε‖²/d, module doc) is
    /// computed over the cursor's own batch, exactly as the blocking loop
    /// did over its stacked rows.
    fn advance_addim(&mut self, clip: Option<f64>) {
        let i = self.i;
        let d = self.x.len() / self.b;
        let (t_s, t_e) = (self.grid[i], self.grid[i - 1]);
        let (a_s, a_e) = (self.sde.abar(t_s), self.sde.abar(t_e));
        let (bb_s, bb_e) = (1.0 - a_s, 1.0 - a_e); // beta-bar
        let alpha_step = a_s / a_e; // per-step alpha_n
        let beta_step = 1.0 - alpha_step;
        // DDPM "small" posterior variance lambda_n^2.
        let lam2 = bb_e / bb_s * beta_step;
        // Batch MC estimate of Gamma = E[||eps||^2]/d  (dataset statistic
        // in Bao et al.; see module doc for the substitution).
        let mean_eps2: f64 =
            self.eps.iter().map(|e| e * e).sum::<f64>() / (self.b as f64 * d as f64);
        let gap = (bb_s / alpha_step).sqrt() - (bb_e - lam2).max(0.0).sqrt();
        let var_opt = lam2 + gap * gap * (1.0 - mean_eps2).max(0.0);
        let var_opt = if i == 1 { 0.0 } else { var_opt.max(0.0) };
        let sd = var_opt.sqrt();
        // Posterior mean mu(x, x0_hat) with optional clipping of x0_hat.
        let c0 = a_e.sqrt() * beta_step / bb_s;
        let cx = alpha_step.sqrt() * bb_e / bb_s;
        let sig_s = bb_s.sqrt();
        let sqrt_as = a_s.sqrt();
        for (xv, ev) in self.x.iter_mut().zip(&self.eps) {
            let mut x0 = (*xv - sig_s * ev) / sqrt_as;
            if let Some(c) = clip {
                x0 = x0.clamp(-c, c);
            }
            *xv = c0 * x0 + cx * *xv + sd * self.rng.normal();
        }
    }
}

impl StepCursor for StochCursor {
    fn pending_t(&self) -> Option<f64> {
        if self.i >= 1 {
            Some(self.grid[self.i])
        } else {
            None
        }
    }

    fn io(&mut self) -> (&[f64], &mut [f64]) {
        (&self.x, &mut self.eps)
    }

    fn advance(&mut self) {
        match self.kind {
            StochKind::Em => self.advance_em(),
            StochKind::Sddim { eta } => self.advance_sddim(eta),
            StochKind::Addim { clip } => self.advance_addim(clip),
        }
        self.i -= 1;
    }

    fn batch(&self) -> usize {
        self.b
    }

    fn take_samples(&mut self) -> Vec<f64> {
        std::mem::take(&mut self.x)
    }

    fn take_rng(&mut self) -> Option<Rng> {
        Some(std::mem::replace(&mut self.rng, Rng::new(0)))
    }
}

impl Solver for EulerMaruyama {
    fn name(&self) -> String {
        "em".into()
    }

    fn nfe(&self) -> usize {
        self.grid.len() - 1
    }

    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, rng: &mut Rng) {
        sample_via_cursor(self, model, x, b, rng);
    }

    fn cursor(&self, x: &[f64], b: usize, rng: &mut Rng) -> Box<dyn StepCursor> {
        Box::new(StochCursor::new(&self.sde, &self.grid, StochKind::Em, x, b, rng))
    }
}

pub struct StochDdim {
    sde: Sde,
    grid: Vec<f64>,
    pub eta: f64,
}

impl StochDdim {
    pub fn new(sde: &Sde, grid: &[f64], eta: f64) -> Self {
        assert!(matches!(sde, Sde::Vp(_)), "stochastic DDIM is defined for VPSDE");
        StochDdim { sde: *sde, grid: grid.to_vec(), eta }
    }
}

impl Solver for StochDdim {
    fn name(&self) -> String {
        format!("sddim(eta={})", self.eta)
    }

    fn nfe(&self) -> usize {
        self.grid.len() - 1
    }

    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, rng: &mut Rng) {
        sample_via_cursor(self, model, x, b, rng);
    }

    fn cursor(&self, x: &[f64], b: usize, rng: &mut Rng) -> Box<dyn StepCursor> {
        let kind = StochKind::Sddim { eta: self.eta };
        Box::new(StochCursor::new(&self.sde, &self.grid, kind, x, b, rng))
    }
}

pub struct ADdim {
    sde: Sde,
    grid: Vec<f64>,
    /// x̂0-clipping range (Bao et al.'s trick; None disables).
    pub clip: Option<f64>,
}

impl ADdim {
    pub fn new(sde: &Sde, grid: &[f64]) -> Self {
        assert!(matches!(sde, Sde::Vp(_)), "A-DDIM is defined for VPSDE");
        ADdim { sde: *sde, grid: grid.to_vec(), clip: Some(6.0) }
    }
}

impl Solver for ADdim {
    fn name(&self) -> String {
        "addim".into()
    }

    fn nfe(&self) -> usize {
        self.grid.len() - 1
    }

    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, rng: &mut Rng) {
        sample_via_cursor(self, model, x, b, rng);
    }

    fn cursor(&self, x: &[f64], b: usize, rng: &mut Rng) -> Box<dyn StepCursor> {
        let kind = StochKind::Addim { clip: self.clip };
        Box::new(StochCursor::new(&self.sde, &self.grid, kind, x, b, rng))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::score::GmmEps;
    use crate::solvers::tab::TabDeis;
    use crate::timegrid::{build, GridKind};
    use crate::util::prop::assert_close;

    fn model() -> GmmEps {
        GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())
    }

    #[test]
    fn sddim_eta0_is_deterministic_ddim() {
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 10);
        let m = model();
        let b = 8;
        let x0: Vec<f64> = Rng::new(5).normal_vec(b * 2);
        let mut xa = x0.clone();
        let mut xb = x0;
        StochDdim::new(&sde, &grid, 0.0).sample(&m, &mut xa, b, &mut Rng::new(1));
        TabDeis::new(&sde, &grid, 0).sample(&m, &mut xb, b, &mut Rng::new(2));
        assert_close(&xa, &xb, 1e-9, "sddim(0) vs ddim");
    }

    #[test]
    fn stochastic_solvers_land_near_modes_with_many_steps() {
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 200);
        let m = model();
        let gmm = Gmm::ring2d(4.0, 8, 0.25);
        let b = 64;
        for solver in [
            &EulerMaruyama::new(&sde, &grid) as &dyn Solver,
            &StochDdim::new(&sde, &grid, 1.0),
            &ADdim::new(&sde, &grid),
        ] {
            let mut x = Rng::new(11).normal_vec(b * 2);
            solver.sample(&m, &mut x, b, &mut Rng::new(42));
            let mut dists: Vec<f64> = (0..b)
                .map(|i| {
                    gmm.means
                        .iter()
                        .map(|mu| {
                            ((x[2 * i] - mu[0]).powi(2) + (x[2 * i + 1] - mu[1]).powi(2)).sqrt()
                        })
                        .fold(f64::INFINITY, f64::min)
                })
                .collect();
            dists.sort_by(f64::total_cmp);
            assert!(dists[b / 2] < 0.8, "{} median {}", solver.name(), dists[b / 2]);
        }
    }

    #[test]
    fn consecutive_sample_calls_advance_the_shared_rng() {
        // Two sample() calls on one Rng must not replay identical noise:
        // the cursor clones the stream, so sample_via_cursor re-syncs the
        // caller's rng from the cursor afterwards (StepCursor::take_rng).
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 10);
        let m = model();
        let x0: Vec<f64> = Rng::new(5).normal_vec(8);
        let mut rng = Rng::new(1);
        let em = EulerMaruyama::new(&sde, &grid);
        let mut xa = x0.clone();
        em.sample(&m, &mut xa, 4, &mut rng);
        let mut xb = x0;
        em.sample(&m, &mut xb, 4, &mut rng);
        assert!(
            xa.iter().zip(&xb).any(|(a, b)| (a - b).abs() > 1e-9),
            "second sample call replayed the first call's noise stream"
        );
    }

    #[test]
    fn stochastic_paths_depend_on_rng_seed() {
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 20);
        let m = model();
        let x0: Vec<f64> = Rng::new(5).normal_vec(4);
        let mut xa = x0.clone();
        let mut xb = x0;
        EulerMaruyama::new(&sde, &grid).sample(&m, &mut xa, 2, &mut Rng::new(1));
        EulerMaruyama::new(&sde, &grid).sample(&m, &mut xb, 2, &mut Rng::new(2));
        assert!(xa.iter().zip(&xb).any(|(a, b)| (a - b).abs() > 1e-6));
    }
}
