//! DPM-Solver-1/2/3 (Lu et al. 2022) — the concurrent-work baseline of paper
//! Tab. 3 / App. B Q5. Singlestep solvers built on Taylor expansion in
//! λ = log(√ᾱ/σ) (half log-SNR). DPM-Solver-1 is algebraically DDIM.
//!
//! Update formulas (α̂ = √ᾱ, h = λ_e − λ_s > 0 going toward data):
//!   1: x_e = (α̂_e/α̂_s) x − σ_e (e^h − 1) ε(x, s)
//!   2: u   = (α̂_m/α̂_s) x − σ_m (e^{h/2} − 1) ε(x, s)          [λ-midpoint m]
//!      x_e = (α̂_e/α̂_s) x − σ_e (e^h − 1) ε(u, m)
//!   3: r1 = 1/3, r2 = 2/3 stages per Lu et al. Algorithm 2.

use crate::diffusion::Sde;
use crate::score::EpsModel;
use crate::solvers::plan::{sample_via_cursor, StepCursor};
use crate::solvers::Solver;
use crate::util::rng::Rng;

/// λ(t) = log(√ᾱ(t)/σ(t)). For VE this is −log σ.
fn lambda(sde: &Sde, t: f64) -> f64 {
    (0.5 * sde.log_abar(t)) - sde.sigma(t).ln()
}

/// Invert λ via ρ: e^{−λ} = σ/√ᾱ = ρ exactly for both VP and VE.
fn t_of_lambda(sde: &Sde, lam: f64) -> f64 {
    sde.t_of_rho((-lam).exp())
}

/// x <- (α̂_e/α̂_s) x − σ_e (e^{λ_e−λ_s} − 1) eps
fn dpm1_update(sde: &Sde, x: &mut [f64], eps: &[f64], t_s: f64, t_e: f64) {
    let psi = sde.psi(t_e, t_s);
    let h = lambda(sde, t_e) - lambda(sde, t_s);
    let c = -sde.sigma(t_e) * (h.exp() - 1.0);
    for (xv, ev) in x.iter_mut().zip(eps) {
        *xv = psi * *xv + c * ev;
    }
}

pub struct DpmSolver {
    sde: Sde,
    grid: Vec<f64>,
    order: usize,
}

impl DpmSolver {
    pub fn new(sde: &Sde, grid: &[f64], order: usize) -> Self {
        assert!((1..=3).contains(&order), "DPM-Solver order 1..3");
        DpmSolver { sde: *sde, grid: grid.to_vec(), order }
    }

    /// λ(t) for this solver's SDE (tests/diagnostics).
    pub fn lambda(&self, t: f64) -> f64 {
        lambda(&self.sde, t)
    }

    /// Inverse of [`Self::lambda`] (tests/diagnostics).
    pub fn t_of_lambda(&self, lam: f64) -> f64 {
        t_of_lambda(&self.sde, lam)
    }
}

/// Resumable DPM-Solver step machine: each grid step runs `order` stages,
/// each stage one ε-evaluation. State = (grid index i, stage). This is the
/// single copy of the Lu et al. update formulas, driven by both
/// `Solver::sample` and the coordinator's scheduler.
pub struct DpmCursor {
    sde: Sde,
    grid: Vec<f64>,
    order: usize,
    x: Vec<f64>,
    /// Intermediate stage state (orders 2/3 only).
    u: Vec<f64>,
    e0: Vec<f64>,
    e1: Vec<f64>,
    e2: Vec<f64>,
    /// Integrating grid[i] -> grid[i-1]; done at i == 0.
    i: usize,
    /// 0..order-1 within the current step.
    stage: usize,
    b: usize,
}

impl StepCursor for DpmCursor {
    fn pending_t(&self) -> Option<f64> {
        if self.i == 0 {
            return None;
        }
        let t_s = self.grid[self.i];
        Some(match (self.order, self.stage) {
            (_, 0) => t_s,
            (2, 1) => {
                let (ls, le) = (lambda(&self.sde, t_s), lambda(&self.sde, self.grid[self.i - 1]));
                t_of_lambda(&self.sde, 0.5 * (ls + le))
            }
            (3, s) => {
                let (ls, le) = (lambda(&self.sde, t_s), lambda(&self.sde, self.grid[self.i - 1]));
                let h = le - ls;
                let r = if s == 1 { 1.0 / 3.0 } else { 2.0 / 3.0 };
                t_of_lambda(&self.sde, ls + r * h)
            }
            _ => unreachable!("dpm stage out of range"),
        })
    }

    fn io(&mut self) -> (&[f64], &mut [f64]) {
        match self.stage {
            0 => (&self.x, &mut self.e0),
            1 => (&self.u, &mut self.e1),
            _ => (&self.u, &mut self.e2),
        }
    }

    fn advance(&mut self) {
        let (t_s, t_e) = (self.grid[self.i], self.grid[self.i - 1]);
        match (self.order, self.stage) {
            (1, 0) => {
                dpm1_update(&self.sde, &mut self.x, &self.e0, t_s, t_e);
                self.i -= 1;
            }
            (2, 0) => {
                let (ls, le) = (lambda(&self.sde, t_s), lambda(&self.sde, t_e));
                let t_m = t_of_lambda(&self.sde, 0.5 * (ls + le));
                self.u.copy_from_slice(&self.x);
                dpm1_update(&self.sde, &mut self.u, &self.e0, t_s, t_m);
                self.stage = 1;
            }
            (2, 1) => {
                dpm1_update(&self.sde, &mut self.x, &self.e1, t_s, t_e);
                self.stage = 0;
                self.i -= 1;
            }
            (3, 0) => {
                let (ls, le) = (lambda(&self.sde, t_s), lambda(&self.sde, t_e));
                let h = le - ls;
                let r1 = 1.0 / 3.0;
                let t1 = t_of_lambda(&self.sde, ls + r1 * h);
                // u1 = DDIM-in-λ to s1 with e0
                self.u.copy_from_slice(&self.x);
                dpm1_update(&self.sde, &mut self.u, &self.e0, t_s, t1);
                self.stage = 1;
            }
            (3, 1) => {
                let (ls, le) = (lambda(&self.sde, t_s), lambda(&self.sde, t_e));
                let h = le - ls;
                let (r1, r2) = (1.0 / 3.0, 2.0 / 3.0);
                let t2 = t_of_lambda(&self.sde, ls + r2 * h);
                // u2 = (α̂2/α̂s)x − σ2(e^{r2h}−1)e0 − (σ2 r2/r1)((e^{r2h}−1)/(r2h) − 1)(e1−e0)
                let psi2 = self.sde.psi(t2, t_s);
                let s2 = self.sde.sigma(t2);
                let ex = (r2 * h).exp() - 1.0;
                let c0 = -s2 * ex;
                let c1 = -(s2 * r2 / r1) * (ex / (r2 * h) - 1.0);
                for idx in 0..self.x.len() {
                    self.u[idx] = psi2 * self.x[idx] + c0 * self.e0[idx]
                        + c1 * (self.e1[idx] - self.e0[idx]);
                }
                self.stage = 2;
            }
            (3, 2) => {
                let (ls, le) = (lambda(&self.sde, t_s), lambda(&self.sde, t_e));
                let h = le - ls;
                let r2 = 2.0 / 3.0;
                // x_e = (α̂e/α̂s)x − σe(e^h−1)e0 − (σe/r2)((e^h−1)/h − 1)(e2−e0)
                let psie = self.sde.psi(t_e, t_s);
                let se = self.sde.sigma(t_e);
                let exh = h.exp() - 1.0;
                let d0 = -se * exh;
                let d1 = -(se / r2) * (exh / h - 1.0);
                for idx in 0..self.x.len() {
                    self.x[idx] = psie * self.x[idx] + d0 * self.e0[idx]
                        + d1 * (self.e2[idx] - self.e0[idx]);
                }
                self.stage = 0;
                self.i -= 1;
            }
            _ => unreachable!("dpm (order, stage) out of range"),
        }
    }

    fn batch(&self) -> usize {
        self.b
    }

    fn take_samples(&mut self) -> Vec<f64> {
        std::mem::take(&mut self.x)
    }
}

impl Solver for DpmSolver {
    fn name(&self) -> String {
        format!("dpm{}", self.order)
    }

    fn nfe(&self) -> usize {
        (self.grid.len() - 1) * self.order
    }

    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, rng: &mut Rng) {
        sample_via_cursor(self, model, x, b, rng);
    }

    fn cursor(&self, x: &[f64], b: usize, _rng: &mut Rng) -> Box<dyn StepCursor> {
        // Stage buffers only exist for the multi-stage orders.
        let (u, e1, e2) = if self.order >= 2 {
            (vec![0.0; x.len()], vec![0.0; x.len()], vec![0.0; x.len()])
        } else {
            (Vec::new(), Vec::new(), Vec::new())
        };
        Box::new(DpmCursor {
            sde: self.sde,
            grid: self.grid.clone(),
            order: self.order,
            x: x.to_vec(),
            u,
            e0: vec![0.0; x.len()],
            e1,
            e2,
            i: self.grid.len() - 1,
            stage: 0,
            b,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::score::GmmEps;
    use crate::solvers::tab::TabDeis;
    use crate::timegrid::{build, GridKind};
    use crate::util::prop::assert_close;

    fn model() -> GmmEps {
        GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())
    }

    #[test]
    fn dpm1_is_ddim() {
        // Lu et al. Prop 4.1 / our App B discussion: DPM-Solver-1 == DDIM.
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 10);
        let m = model();
        let b = 8;
        let x0: Vec<f64> = Rng::new(6).normal_vec(b * 2);
        let mut xa = x0.clone();
        let mut xb = x0;
        DpmSolver::new(&sde, &grid, 1).sample(&m, &mut xa, b, &mut Rng::new(0));
        TabDeis::new(&sde, &grid, 0).sample(&m, &mut xb, b, &mut Rng::new(0));
        assert_close(&xa, &xb, 1e-9, "dpm1 vs ddim");
    }

    #[test]
    fn lambda_inversion_roundtrip() {
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 4);
        let s = DpmSolver::new(&sde, &grid, 2);
        for i in 1..=20 {
            let t = 0.01 + 0.98 * i as f64 / 20.0;
            let back = s.t_of_lambda(s.lambda(t));
            assert!((back - t).abs() < 1e-8, "t={t} back={back}");
        }
    }

    #[test]
    fn higher_order_closer_to_limit() {
        let sde = Sde::vp();
        let m = model();
        let b = 8;
        let x0: Vec<f64> = Rng::new(7).normal_vec(b * 2);
        let reference = {
            let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 512);
            let mut x = x0.clone();
            TabDeis::new(&sde, &grid, 0).sample(&m, &mut x, b, &mut Rng::new(0));
            x
        };
        let err = |order: usize, steps: usize| -> f64 {
            let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, steps);
            let mut x = x0.clone();
            DpmSolver::new(&sde, &grid, order).sample(&m, &mut x, b, &mut Rng::new(0));
            x.iter().zip(&reference).map(|(a, r)| (a - r).abs()).sum::<f64>() / x.len() as f64
        };
        // Equal NFE=12 budget: dpm1@12, dpm2@6, dpm3@4.
        let (e1, e2) = (err(1, 12), err(2, 6));
        assert!(e2 < e1, "dpm2 ({e2}) should beat dpm1 ({e1}) at equal NFE");
    }
}
