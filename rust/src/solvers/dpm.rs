//! DPM-Solver-1/2/3 (Lu et al. 2022) — the concurrent-work baseline of paper
//! Tab. 3 / App. B Q5. Singlestep solvers built on Taylor expansion in
//! λ = log(√ᾱ/σ) (half log-SNR). DPM-Solver-1 is algebraically DDIM.
//!
//! Update formulas (α̂ = √ᾱ, h = λ_e − λ_s > 0 going toward data):
//!   1: x_e = (α̂_e/α̂_s) x − σ_e (e^h − 1) ε(x, s)
//!   2: u   = (α̂_m/α̂_s) x − σ_m (e^{h/2} − 1) ε(x, s)          [λ-midpoint m]
//!      x_e = (α̂_e/α̂_s) x − σ_e (e^h − 1) ε(u, m)
//!   3: r1 = 1/3, r2 = 2/3 stages per Lu et al. Algorithm 2.

use crate::diffusion::Sde;
use crate::score::EpsModel;
use crate::solvers::{fill_t, Solver};
use crate::util::rng::Rng;

pub struct DpmSolver {
    sde: Sde,
    grid: Vec<f64>,
    order: usize,
}

impl DpmSolver {
    pub fn new(sde: &Sde, grid: &[f64], order: usize) -> Self {
        assert!((1..=3).contains(&order), "DPM-Solver order 1..3");
        DpmSolver { sde: *sde, grid: grid.to_vec(), order }
    }

    /// λ(t) = log(√ᾱ(t)/σ(t)). For VE this is −log σ.
    fn lambda(&self, t: f64) -> f64 {
        (0.5 * self.sde.log_abar(t)) - self.sde.sigma(t).ln()
    }

    /// Invert λ via ρ: e^{−λ} = σ/√ᾱ = ρ exactly for both VP and VE.
    fn t_of_lambda(&self, lam: f64) -> f64 {
        self.sde.t_of_rho((-lam).exp())
    }

    /// x <- (α̂_e/α̂_s) x − σ_e (e^{λ_e−λ_s} − 1) eps
    fn dpm1_update(&self, x: &mut [f64], eps: &[f64], t_s: f64, t_e: f64) {
        let psi = self.sde.psi(t_e, t_s);
        let h = self.lambda(t_e) - self.lambda(t_s);
        let c = -self.sde.sigma(t_e) * (h.exp() - 1.0);
        for (xv, ev) in x.iter_mut().zip(eps) {
            *xv = psi * *xv + c * ev;
        }
    }
}

impl Solver for DpmSolver {
    fn name(&self) -> String {
        format!("dpm{}", self.order)
    }

    fn nfe(&self) -> usize {
        (self.grid.len() - 1) * self.order
    }

    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, _rng: &mut Rng) {
        let d = model.dim();
        let n = self.grid.len() - 1;
        let mut tb = Vec::new();
        let mut e0 = vec![0.0; b * d];
        // Stage buffers, sized once and reused every step (orders 2/3 only).
        let (mut u, mut e1, mut e2) = if self.order >= 2 {
            (vec![0.0; b * d], vec![0.0; b * d], vec![0.0; b * d])
        } else {
            (Vec::new(), Vec::new(), Vec::new())
        };
        for i in (1..=n).rev() {
            let (t_s, t_e) = (self.grid[i], self.grid[i - 1]);
            model.eval(x, fill_t(&mut tb, t_s, b), b, &mut e0);
            match self.order {
                1 => self.dpm1_update(x, &e0, t_s, t_e),
                2 => {
                    let (ls, le) = (self.lambda(t_s), self.lambda(t_e));
                    let t_m = self.t_of_lambda(0.5 * (ls + le));
                    u.copy_from_slice(x);
                    self.dpm1_update(&mut u, &e0, t_s, t_m);
                    model.eval(&u, fill_t(&mut tb, t_m, b), b, &mut e1);
                    self.dpm1_update(x, &e1, t_s, t_e);
                }
                3 => {
                    let (ls, le) = (self.lambda(t_s), self.lambda(t_e));
                    let h = le - ls;
                    let (r1, r2) = (1.0 / 3.0, 2.0 / 3.0);
                    let t1 = self.t_of_lambda(ls + r1 * h);
                    let t2 = self.t_of_lambda(ls + r2 * h);
                    // u1 = DDIM-in-λ to s1 with e0
                    u.copy_from_slice(x);
                    self.dpm1_update(&mut u, &e0, t_s, t1);
                    model.eval(&u, fill_t(&mut tb, t1, b), b, &mut e1);
                    // u2 = (α̂2/α̂s)x − σ2(e^{r2h}−1)e0 − (σ2 r2/r1)((e^{r2h}−1)/(r2h) − 1)(e1−e0)
                    let psi2 = self.sde.psi(t2, t_s);
                    let s2 = self.sde.sigma(t2);
                    let ex = (r2 * h).exp() - 1.0;
                    let c0 = -s2 * ex;
                    let c1 = -(s2 * r2 / r1) * (ex / (r2 * h) - 1.0);
                    for idx in 0..b * d {
                        u[idx] = psi2 * x[idx] + c0 * e0[idx] + c1 * (e1[idx] - e0[idx]);
                    }
                    model.eval(&u, fill_t(&mut tb, t2, b), b, &mut e2);
                    // x_e = (α̂e/α̂s)x − σe(e^h−1)e0 − (σe/r2)((e^h−1)/h − 1)(e2−e0)
                    let psie = self.sde.psi(t_e, t_s);
                    let se = self.sde.sigma(t_e);
                    let exh = h.exp() - 1.0;
                    let d0 = -se * exh;
                    let d1 = -(se / r2) * (exh / h - 1.0);
                    for idx in 0..b * d {
                        x[idx] = psie * x[idx] + d0 * e0[idx] + d1 * (e2[idx] - e0[idx]);
                    }
                }
                _ => unreachable!(),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::score::GmmEps;
    use crate::solvers::tab::TabDeis;
    use crate::timegrid::{build, GridKind};
    use crate::util::prop::assert_close;

    fn model() -> GmmEps {
        GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())
    }

    #[test]
    fn dpm1_is_ddim() {
        // Lu et al. Prop 4.1 / our App B discussion: DPM-Solver-1 == DDIM.
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 10);
        let m = model();
        let b = 8;
        let x0: Vec<f64> = Rng::new(6).normal_vec(b * 2);
        let mut xa = x0.clone();
        let mut xb = x0;
        DpmSolver::new(&sde, &grid, 1).sample(&m, &mut xa, b, &mut Rng::new(0));
        TabDeis::new(&sde, &grid, 0).sample(&m, &mut xb, b, &mut Rng::new(0));
        assert_close(&xa, &xb, 1e-9, "dpm1 vs ddim");
    }

    #[test]
    fn lambda_inversion_roundtrip() {
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 4);
        let s = DpmSolver::new(&sde, &grid, 2);
        for i in 1..=20 {
            let t = 0.01 + 0.98 * i as f64 / 20.0;
            let back = s.t_of_lambda(s.lambda(t));
            assert!((back - t).abs() < 1e-8, "t={t} back={back}");
        }
    }

    #[test]
    fn higher_order_closer_to_limit() {
        let sde = Sde::vp();
        let m = model();
        let b = 8;
        let x0: Vec<f64> = Rng::new(7).normal_vec(b * 2);
        let reference = {
            let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 512);
            let mut x = x0.clone();
            TabDeis::new(&sde, &grid, 0).sample(&m, &mut x, b, &mut Rng::new(0));
            x
        };
        let err = |order: usize, steps: usize| -> f64 {
            let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, steps);
            let mut x = x0.clone();
            DpmSolver::new(&sde, &grid, order).sample(&m, &mut x, b, &mut Rng::new(0));
            x.iter().zip(&reference).map(|(a, r)| (a - r).abs()).sum::<f64>() / x.len() as f64
        };
        // Equal NFE=12 budget: dpm1@12, dpm2@6, dpm3@4.
        let (e1, e2) = (err(1, 12), err(2, 6));
        assert!(e2 < e1, "dpm2 ({e2}) should beat dpm1 ({e1}) at equal NFE");
    }
}
