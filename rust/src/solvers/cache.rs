//! Shared solver-plan cache: the per-(sde, solver, grid, t0, NFE) work that
//! is reusable across requests — the time grid and the solver with all of
//! its precomputed coefficients (tAB/ρAB polynomial integrals, EI
//! quadrature, DPM λ tables) — built once and shared as an
//! [`Arc<SolverPlan>`].
//!
//! Why this layer exists: the coordinator used to rebuild grid +
//! coefficients on every admission, *under the coordinator mutex*. The
//! quadrature behind a tAB-DEIS plan is orders of magnitude more work than
//! the admission bookkeeping around it, so a burst of requests serialized
//! on polynomial integrals before a single ε-eval was dispatched. With the
//! cache, `Coordinator::submit` resolves the plan on the submitting thread
//! — a map lookup in the steady state, with builds for distinct configs
//! running concurrently — and admission under the mutex is reduced to
//! drawing priors and instantiating a cursor.
//!
//! Concurrency contract: the internal map lock is held only for
//! lookup/insert, never during a build. Two threads racing on the same
//! missing key may both build; the first insert wins and the loser's plan
//! is dropped (both count as misses). `plan_cache_hits`/`plan_cache_misses`
//! are surfaced through the coordinator stats.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::diffusion::Sde;
use crate::solvers::{self, Solver, SolverKind};
use crate::timegrid::{self, GridKind};

/// A fully precomputed sampling plan: everything about a configuration that
/// does not depend on the request's batch, seed, or deadline.
pub struct SolverPlan {
    pub kind: SolverKind,
    /// Ascending time grid, grid[0] = t0.
    pub grid: Vec<f64>,
    /// Solver with coefficients precomputed for `grid`.
    pub solver: Box<dyn Solver>,
}

impl SolverPlan {
    /// Build from a request-shaped config. Panics exactly where the grid and
    /// solver constructors assert (bad t0, too few steps for PNDM, ...);
    /// callers serving untrusted configs must catch that (the coordinator
    /// does, outside any lock).
    pub fn build(sde: &Sde, kind: SolverKind, grid: GridKind, t0: f64, nfe: usize) -> SolverPlan {
        let steps = kind.steps_for_nfe(nfe);
        let g = timegrid::build(grid, sde, t0, 1.0, steps);
        let solver = solvers::build(kind, sde, &g);
        SolverPlan { kind, grid: g, solver }
    }
}

/// Cache key: a cheap `Copy` tuple of bit patterns. f64 parameters enter
/// as bits ([`Sde::key_bits`], [`GridKind::key_bits`], `t0.to_bits()`) —
/// no allocation or string hashing on the per-submit lookup path.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    sde: (u8, u64, u64),
    solver: SolverKind,
    grid: (u8, u64),
    t0_bits: u64,
    nfe: usize,
}

impl PlanKey {
    pub fn of(sde: &Sde, solver: SolverKind, grid: GridKind, t0: f64, nfe: usize) -> PlanKey {
        PlanKey {
            sde: sde.key_bits(),
            solver,
            grid: grid.key_bits(),
            t0_bits: t0.to_bits(),
            nfe,
        }
    }
}

/// Hard cap on retained plans. The key embeds client-controlled bit
/// patterns (t0, NFE), so without a bound a client iterating t0 one ULP at
/// a time would grow the map — and coordinator memory — forever. At the
/// cap an arbitrary existing entry is evicted for each new insert, so a
/// transient burst of junk configs cannot permanently pin the cache away
/// from the real serving configs. A serving workload's steady state is a
/// handful of configs, far below the cap.
pub const MAX_PLANS: usize = 256;

/// Process-lifetime map from [`PlanKey`] to its shared [`SolverPlan`],
/// bounded by [`MAX_PLANS`].
#[derive(Default)]
pub struct PlanCache {
    map: Mutex<HashMap<PlanKey, Arc<SolverPlan>>>,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// Resolve the plan for a config, building it outside the map lock on a
    /// miss. Returns (plan, hit).
    pub fn get_or_build(
        &self,
        sde: &Sde,
        solver: SolverKind,
        grid: GridKind,
        t0: f64,
        nfe: usize,
    ) -> (Arc<SolverPlan>, bool) {
        let key = PlanKey::of(sde, solver, grid, t0, nfe);
        if let Some(plan) = self.map.lock().unwrap().get(&key) {
            return (plan.clone(), true);
        }
        // Build WITHOUT the lock: quadrature dominates, and misses on
        // distinct configs must not serialize on each other.
        let plan = Arc::new(SolverPlan::build(sde, solver, grid, t0, nfe));
        let mut map = self.map.lock().unwrap();
        if let Some(existing) = map.get(&key) {
            // A racing build won the insert; share its plan.
            return (existing.clone(), false);
        }
        if map.len() >= MAX_PLANS {
            // Evict an arbitrary entry: bounds memory without letting a
            // one-time flood of configs pin the cache forever.
            if let Some(victim) = map.keys().next().copied() {
                map.remove(&victim);
            }
        }
        map.insert(key, plan.clone());
        (plan, false)
    }

    /// Number of distinct configs cached.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeat_config_hits_and_shares_the_plan() {
        let cache = PlanCache::new();
        let sde = Sde::vp();
        let (a, hit_a) =
            cache.get_or_build(&sde, SolverKind::Tab(3), GridKind::Quadratic, 1e-3, 10);
        assert!(!hit_a, "first resolution must be a miss");
        let (b, hit_b) =
            cache.get_or_build(&sde, SolverKind::Tab(3), GridKind::Quadratic, 1e-3, 10);
        assert!(hit_b, "second resolution of the same config must hit");
        assert!(Arc::ptr_eq(&a, &b), "hit must return the SAME shared plan");
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_configs_do_not_alias() {
        let cache = PlanCache::new();
        let sde = Sde::vp();
        let base = (SolverKind::Tab(2), GridKind::Quadratic, 1e-3, 10);
        let (p0, _) = cache.get_or_build(&sde, base.0, base.1, base.2, base.3);
        // Vary every key dimension; each must be its own cache entry.
        let variants: Vec<(Arc<SolverPlan>, bool)> = vec![
            cache.get_or_build(&sde, SolverKind::Tab(3), base.1, base.2, base.3),
            cache.get_or_build(&sde, base.0, GridKind::Uniform, base.2, base.3),
            cache.get_or_build(&sde, base.0, base.1, 1e-4, base.3),
            cache.get_or_build(&sde, base.0, base.1, base.2, 12),
            cache.get_or_build(&Sde::ve(), base.0, GridKind::LogRho, 1e-3, base.3),
        ];
        for (p, hit) in &variants {
            assert!(!*hit, "distinct config must miss");
            assert!(!Arc::ptr_eq(&p0, p), "distinct configs must not alias");
        }
        assert_eq!(cache.len(), 6);
    }

    #[test]
    fn plan_grid_matches_direct_build() {
        let cache = PlanCache::new();
        let sde = Sde::vp();
        let (plan, _) = cache.get_or_build(&sde, SolverKind::Dpm(2), GridKind::LogRho, 1e-3, 10);
        let steps = SolverKind::Dpm(2).steps_for_nfe(10);
        let grid = timegrid::build(GridKind::LogRho, &sde, 1e-3, 1.0, steps);
        assert_eq!(plan.grid, grid);
        assert_eq!(plan.kind, SolverKind::Dpm(2));
        assert_eq!(plan.solver.nfe(), solvers::build(SolverKind::Dpm(2), &sde, &grid).nfe());
    }

    #[test]
    fn cache_size_is_bounded_and_not_pinned_by_floods() {
        let cache = PlanCache::new();
        let sde = Sde::vp();
        // Euler plans are cheap to build (no quadrature), so flooding the
        // cache with distinct configs is fast.
        for nfe in 1..=MAX_PLANS + 8 {
            let (plan, hit) =
                cache.get_or_build(&sde, SolverKind::Euler, GridKind::Uniform, 1e-3, nfe);
            assert!(!hit);
            assert_eq!(plan.grid.len(), nfe + 1, "over-cap plans must still build correctly");
        }
        assert!(cache.len() <= MAX_PLANS, "cache grew past its bound: {}", cache.len());
        // The flood must not pin the cache: a config arriving after it is
        // still cacheable (evict-on-insert, not insert-refusal).
        let fresh =
            |c: &PlanCache| c.get_or_build(&sde, SolverKind::Euler, GridKind::Quadratic, 1e-3, 7);
        let (_, hit) = fresh(&cache);
        assert!(!hit, "first sighting of the post-flood config is a miss");
        let (_, hit) = fresh(&cache);
        assert!(hit, "post-flood config must be retained on its next resolution");
        assert!(cache.len() <= MAX_PLANS);
    }

    #[test]
    fn key_equality_follows_config_equality() {
        let sde = Sde::vp();
        let k = |t0: f64, nfe: usize| {
            PlanKey::of(&sde, SolverKind::Tab(1), GridKind::LogRho, t0, nfe)
        };
        assert_eq!(k(1e-3, 10), k(1e-3, 10));
        assert_ne!(k(1e-3, 10), k(1e-4, 10));
        assert_ne!(k(1e-3, 10), k(1e-3, 11));
        assert_ne!(
            PlanKey::of(&sde, SolverKind::Tab(1), GridKind::PowerT(2.0), 1e-3, 10),
            PlanKey::of(&sde, SolverKind::Tab(1), GridKind::PowerT(3.0), 1e-3, 10),
        );
        assert_ne!(
            PlanKey::of(&Sde::vp(), SolverKind::Tab(1), GridKind::LogRho, 1e-3, 10),
            PlanKey::of(&Sde::ve(), SolverKind::Tab(1), GridKind::LogRho, 1e-3, 10),
        );
    }
}
