//! The DEIS solver family and every baseline the paper compares against.
//!
//! All solvers integrate the probability-flow ODE (or SDE, for the
//! stochastic ones) from t_N = T down to t_0 = t0 over a fixed `grid`,
//! against an abstract [`EpsModel`]. Coefficients that depend only on
//! (sde, grid, order) are precomputed in the constructor and reused across
//! batches — the paper's point under Eq. (15).
//!
//! Map from paper names:
//!   Euler (Eq. 7)              -> [`euler::EulerEps`] / [`euler::EulerScore`]
//!   EI, s-param (Eq. 8)        -> [`ei::EiScore`]        (the Fig 3a "worse" one)
//!   EI, eps-param (Eq. 11)     -> [`tab::TabDeis`] order 0 == DDIM (Prop 2)
//!   tAB-DEIS (Eq. 14-15)       -> [`tab::TabDeis`] order 1..3
//!   rhoAB-DEIS (Sec. 4)        -> [`rho_ab::RhoAbDeis`]
//!   rhoRK-DEIS (Sec. 4)        -> [`rho_rk::RhoRk`] (midpoint/Heun/Kutta3/RK4)
//!   RK45 blackbox (Tab. 11)    -> [`rk45::Rk45`]
//!   PNDM / iPNDM (App. H.2)    -> [`pndm::Pndm`] / [`pndm::Ipndm`]
//!   DPM-Solver-1/2/3 (App. B)  -> [`dpm::DpmSolver`]
//!   Analytic-DDIM (Tab. 12)    -> [`sde_samplers::ADdim`]
//!   Euler-Maruyama / sDDIM     -> [`sde_samplers::EulerMaruyama`] / [`sde_samplers::StochDdim`]

pub mod cache;
pub mod dpm;
pub mod ei;
pub mod euler;
pub mod plan;
pub mod pndm;
pub mod rho_ab;
pub mod rho_rk;
pub mod rk45;
pub mod sde_samplers;
pub mod tab;

pub use cache::{PlanCache, SolverPlan};
pub use plan::{drive, StepCursor};

use crate::diffusion::Sde;
use crate::score::EpsModel;
use crate::util::rng::Rng;

/// A configured sampler over a fixed time grid.
pub trait Solver: Send + Sync {
    fn name(&self) -> String;

    /// Integrate the batch `x` ([b * dim], row-major) from t = grid[N] down
    /// to grid[0] in place. `rng` is consumed only by stochastic solvers.
    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, rng: &mut Rng);

    /// Model evaluations per trajectory for this configuration.
    fn nfe(&self) -> usize;

    /// Begin a resumable integration from the prior draw `x` ([b * dim]).
    /// Every solver is a step machine — there is no blocking whole-trajectory
    /// path. Stochastic solvers clone `rng` into the cursor so scheduled and
    /// solo runs consume an identical noise stream; deterministic solvers
    /// ignore it.
    fn cursor(&self, x: &[f64], b: usize, rng: &mut Rng) -> Box<dyn StepCursor>;
}

/// Solver selector (string names are the CLI / wire format).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SolverKind {
    Euler,
    EulerScore,
    EiScore,
    Tab(usize),    // 0 == DDIM
    RhoAb(usize),  // 0 == DDIM
    RhoMidpoint,
    RhoHeun,
    RhoKutta3,
    RhoRk4,
    Rk45,
    Pndm,
    Ipndm(usize),
    Dpm(usize), // 1..3
    EulerMaruyama,
    StochDdim, // eta = 1
    ADdim,
}

impl SolverKind {
    pub fn parse(s: &str) -> Option<SolverKind> {
        use SolverKind::*;
        Some(match s {
            "euler" => Euler,
            "euler-score" => EulerScore,
            "ei-score" => EiScore,
            "ddim" | "tab0" => Tab(0),
            "tab1" => Tab(1),
            "tab2" => Tab(2),
            "tab3" => Tab(3),
            "rho-ab0" => RhoAb(0),
            "rho-ab1" => RhoAb(1),
            "rho-ab2" => RhoAb(2),
            "rho-ab3" => RhoAb(3),
            "rho-midpoint" => RhoMidpoint,
            "rho-heun" => RhoHeun,
            "rho-kutta3" => RhoKutta3,
            "rho-rk4" => RhoRk4,
            "rk45" => Rk45,
            "pndm" => Pndm,
            "ipndm1" => Ipndm(1),
            "ipndm2" => Ipndm(2),
            "ipndm3" | "ipndm" => Ipndm(3),
            "dpm1" => Dpm(1),
            "dpm2" => Dpm(2),
            "dpm3" => Dpm(3),
            "em" | "euler-maruyama" => EulerMaruyama,
            "sddim" => StochDdim,
            "addim" => ADdim,
            _ => return None,
        })
    }

    pub fn name(&self) -> String {
        use SolverKind::*;
        match self {
            Euler => "euler".into(),
            EulerScore => "euler-score".into(),
            EiScore => "ei-score".into(),
            Tab(0) => "ddim".into(),
            Tab(r) => format!("tab{r}"),
            RhoAb(r) => format!("rho-ab{r}"),
            RhoMidpoint => "rho-midpoint".into(),
            RhoHeun => "rho-heun".into(),
            RhoKutta3 => "rho-kutta3".into(),
            RhoRk4 => "rho-rk4".into(),
            Rk45 => "rk45".into(),
            Pndm => "pndm".into(),
            Ipndm(r) => format!("ipndm{r}"),
            Dpm(k) => format!("dpm{k}"),
            EulerMaruyama => "em".into(),
            StochDdim => "sddim".into(),
            ADdim => "addim".into(),
        }
    }

    /// NFE cost of one grid step (RK45 is adaptive: None).
    pub fn nfe_per_step(&self) -> Option<usize> {
        use SolverKind::*;
        Some(match self {
            Rk45 => return None,
            RhoMidpoint | RhoHeun | Dpm(2) => 2,
            RhoKutta3 | Dpm(3) => 3,
            RhoRk4 => 4,
            _ => 1,
        })
    }

    /// Grid steps to spend for a target NFE budget (PNDM's pseudo-RK warmup
    /// burns 3 extra evals on each of its first 3 steps).
    pub fn steps_for_nfe(&self, nfe: usize) -> usize {
        match self {
            SolverKind::Pndm => nfe.saturating_sub(9).max(1),
            _ => (nfe / self.nfe_per_step().unwrap_or(1)).max(1),
        }
    }
}

/// Instantiate a solver for (sde, grid). `grid` ascending, grid[0] = t0.
pub fn build(kind: SolverKind, sde: &Sde, grid: &[f64]) -> Box<dyn Solver> {
    use SolverKind::*;
    match kind {
        Euler => Box::new(euler::EulerEps::new(sde, grid)),
        EulerScore => Box::new(euler::EulerScore::new(sde, grid)),
        EiScore => Box::new(ei::EiScore::new(sde, grid)),
        Tab(r) => Box::new(tab::TabDeis::new(sde, grid, r)),
        RhoAb(r) => Box::new(rho_ab::RhoAbDeis::new(sde, grid, r)),
        RhoMidpoint => Box::new(rho_rk::RhoRk::new(sde, grid, rho_rk::Scheme::Midpoint)),
        RhoHeun => Box::new(rho_rk::RhoRk::new(sde, grid, rho_rk::Scheme::Heun)),
        RhoKutta3 => Box::new(rho_rk::RhoRk::new(sde, grid, rho_rk::Scheme::Kutta3)),
        RhoRk4 => Box::new(rho_rk::RhoRk::new(sde, grid, rho_rk::Scheme::Rk4)),
        Rk45 => Box::new(rk45::Rk45::new(sde, grid, 1e-3, 1e-3)),
        Pndm => Box::new(pndm::Pndm::new(sde, grid)),
        Ipndm(r) => Box::new(pndm::Ipndm::new(sde, grid, r)),
        Dpm(k) => Box::new(dpm::DpmSolver::new(sde, grid, k)),
        EulerMaruyama => Box::new(sde_samplers::EulerMaruyama::new(sde, grid)),
        StochDdim => Box::new(sde_samplers::StochDdim::new(sde, grid, 1.0)),
        ADdim => Box::new(sde_samplers::ADdim::new(sde, grid)),
    }
}

/// All deterministic DEIS variants of paper Table 2, in column order.
pub fn table2_kinds() -> Vec<SolverKind> {
    use SolverKind::*;
    vec![
        Tab(0),
        RhoHeun,
        RhoKutta3,
        RhoRk4,
        RhoAb(1),
        RhoAb(2),
        RhoAb(3),
        Tab(1),
        Tab(2),
        Tab(3),
    ]
}

// --------------------------------------------------------------------------
// Shared step helpers
// --------------------------------------------------------------------------

/// Broadcast a scalar time into a reusable buffer.
pub(crate) fn fill_t(buf: &mut Vec<f64>, t: f64, b: usize) -> &[f64] {
    buf.clear();
    buf.resize(b, t);
    buf
}

/// x = psi * x + sum_j c_j * eps_j — the fused DEIS combine (Eq. 14). This is
/// the rust twin of the L1 `deis_combine` Pallas kernel.
///
/// Up to four histories (the tAB-DEIS maximum, order 3) are combined in a
/// single pass over `x` — one load/store per element instead of one per
/// history — with the multiply-adds laid out back-to-back so the compiler
/// can contract them into FMAs where the target supports it.
pub fn deis_combine(x: &mut [f64], psi: f64, coefs: &[f64], eps: &[&[f64]]) {
    assert_eq!(coefs.len(), eps.len());
    for e in eps {
        assert_eq!(e.len(), x.len());
    }
    match eps.len() {
        0 => {
            for v in x.iter_mut() {
                *v *= psi;
            }
        }
        1 => {
            let (c0, e0) = (coefs[0], eps[0]);
            for (i, v) in x.iter_mut().enumerate() {
                *v = psi * *v + c0 * e0[i];
            }
        }
        2 => {
            let (c0, c1) = (coefs[0], coefs[1]);
            let (e0, e1) = (eps[0], eps[1]);
            for (i, v) in x.iter_mut().enumerate() {
                *v = psi * *v + c0 * e0[i] + c1 * e1[i];
            }
        }
        3 => {
            let (c0, c1, c2) = (coefs[0], coefs[1], coefs[2]);
            let (e0, e1, e2) = (eps[0], eps[1], eps[2]);
            for (i, v) in x.iter_mut().enumerate() {
                *v = psi * *v + c0 * e0[i] + c1 * e1[i] + c2 * e2[i];
            }
        }
        4 => {
            let (c0, c1, c2, c3) = (coefs[0], coefs[1], coefs[2], coefs[3]);
            let (e0, e1, e2, e3) = (eps[0], eps[1], eps[2], eps[3]);
            for (i, v) in x.iter_mut().enumerate() {
                *v = psi * *v + c0 * e0[i] + c1 * e1[i] + c2 * e2[i] + c3 * e3[i];
            }
        }
        _ => {
            for v in x.iter_mut() {
                *v *= psi;
            }
            for (c, e) in coefs.iter().zip(eps) {
                for (v, ev) in x.iter_mut().zip(e.iter()) {
                    *v += c * ev;
                }
            }
        }
    }
}

/// Ring buffer of the last `cap` eps evaluations (newest first) used by the
/// multistep solvers. Evicted vectors are recycled through [`Self::checkout`]
/// so the per-step `vec![0.0; b*d]` disappears after warmup: in the steady
/// state `cap + 1` buffers circulate with zero heap traffic
/// (`rust/tests/zero_alloc.rs` pins this).
pub(crate) struct EpsBuffer {
    cap: usize,
    entries: std::collections::VecDeque<(f64, Vec<f64>)>, // (t_node, eps)
    free: Vec<Vec<f64>>,
}

impl EpsBuffer {
    pub fn new(cap: usize) -> Self {
        EpsBuffer { cap, entries: Default::default(), free: Vec::new() }
    }

    /// A zeroed length-`len` vector, reusing an evicted buffer when one is
    /// available. Intended pattern: checkout -> model.eval into it -> push.
    pub fn checkout(&mut self, len: usize) -> Vec<f64> {
        let mut v = self.free.pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0.0);
        v
    }

    pub fn push(&mut self, t: f64, eps: Vec<f64>) {
        self.entries.push_front((t, eps));
        while self.entries.len() > self.cap {
            if let Some((_, v)) = self.entries.pop_back() {
                self.free.push(v);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    #[allow(dead_code)] // used by tests and kept for diagnostics
    pub fn node(&self, j: usize) -> f64 {
        self.entries[j].0
    }

    pub fn eps(&self, j: usize) -> &[f64] {
        &self.entries[j].1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_name_roundtrip() {
        let all = [
            "euler", "euler-score", "ei-score", "ddim", "tab1", "tab2", "tab3", "rho-ab1",
            "rho-ab2", "rho-ab3", "rho-midpoint", "rho-heun", "rho-kutta3", "rho-rk4", "rk45",
            "pndm", "ipndm3", "dpm1", "dpm2", "dpm3", "em", "sddim", "addim",
        ];
        for s in all {
            let k = SolverKind::parse(s).unwrap_or_else(|| panic!("parse {s}"));
            assert_eq!(k.name(), s, "roundtrip {s}");
            assert_eq!(SolverKind::parse(&k.name()), Some(k));
        }
        assert!(SolverKind::parse("bogus").is_none());
    }

    #[test]
    fn steps_for_nfe_accounting() {
        assert_eq!(SolverKind::Tab(3).steps_for_nfe(10), 10);
        assert_eq!(SolverKind::RhoHeun.steps_for_nfe(10), 5);
        assert_eq!(SolverKind::RhoKutta3.steps_for_nfe(10), 3);
        assert_eq!(SolverKind::RhoRk4.steps_for_nfe(10), 2);
        assert_eq!(SolverKind::Pndm.steps_for_nfe(20), 11); // 3 warm steps cost 4 each
        assert_eq!(SolverKind::Dpm(2).steps_for_nfe(10), 5);
    }

    #[test]
    fn deis_combine_basic() {
        let mut x = vec![1.0, 2.0];
        let e1 = vec![10.0, 20.0];
        let e2 = vec![1.0, 1.0];
        deis_combine(&mut x, 2.0, &[0.5, -1.0], &[&e1, &e2]);
        assert_eq!(x, vec![2.0 + 5.0 - 1.0, 4.0 + 10.0 - 1.0]);
    }

    #[test]
    fn eps_buffer_evicts_oldest() {
        let mut b = EpsBuffer::new(2);
        b.push(3.0, vec![3.0]);
        b.push(2.0, vec![2.0]);
        b.push(1.0, vec![1.0]);
        assert_eq!(b.len(), 2);
        assert_eq!(b.node(0), 1.0);
        assert_eq!(b.node(1), 2.0);
    }

    #[test]
    fn eps_buffer_recycles_evicted_storage() {
        let mut b = EpsBuffer::new(1);
        // Seed a large buffer, evict it, and check the next checkout reuses
        // its storage (capacity survives even at a smaller length).
        b.push(2.0, Vec::with_capacity(64));
        b.push(1.0, vec![0.0; 4]); // evicts the 64-cap vec into the free list
        let v = b.checkout(8);
        assert_eq!(v.len(), 8);
        assert!(v.iter().all(|&x| x == 0.0), "checkout must hand back zeroed data");
        assert!(v.capacity() >= 64, "evicted storage was not recycled");
    }

    #[test]
    fn deis_combine_unrolled_matches_reference() {
        use crate::util::prop::run_prop;
        use crate::util::rng::Rng;
        let reference = |x: &mut [f64], psi: f64, coefs: &[f64], eps: &[&[f64]]| {
            for v in x.iter_mut() {
                *v *= psi;
            }
            for (c, e) in coefs.iter().zip(eps) {
                for (v, ev) in x.iter_mut().zip(e.iter()) {
                    *v += c * ev;
                }
            }
        };
        run_prop("deis_combine unroll", 31, 40, |rng: &mut Rng| {
            let n = 1 + rng.below(40);
            let r = rng.below(7); // 0..6 covers every specialization + fallback
            let x0 = rng.normal_vec(n);
            let psi = rng.normal();
            let coefs = rng.normal_vec(r);
            let eps: Vec<Vec<f64>> = (0..r).map(|_| rng.normal_vec(n)).collect();
            let eps_refs: Vec<&[f64]> = eps.iter().map(|e| e.as_slice()).collect();
            let mut got = x0.clone();
            deis_combine(&mut got, psi, &coefs, &eps_refs);
            let mut want = x0;
            reference(&mut want, psi, &coefs, &eps_refs);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-12, "{g} vs {w}");
            }
        });
    }
}
