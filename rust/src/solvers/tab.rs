//! tAB-DEIS (paper Eq. 14–15): Exponential Integrator + Adams–Bashforth
//! polynomial extrapolation of ε_θ in t. Order 0 is exactly deterministic
//! DDIM (Prop. 2 — a property test pins the quadrature against the closed
//! form). The C_ij are integrated once per (sde, grid, order) with panelled
//! Gauss–Legendre and reused across batches.

use crate::diffusion::Sde;
use crate::quad::{lagrange_basis, Quadrature};
use crate::score::EpsModel;
use crate::solvers::plan::{sample_via_cursor, StepCursor};
use crate::solvers::{deis_combine, EpsBuffer, Solver};
use crate::util::rng::Rng;

pub struct TabDeis {
    grid: Vec<f64>,
    order: usize,
    /// Per step (index 0 = the i=N step): (psi, C_ij for j=0..r_eff).
    /// Arc-shared with cursors so starting a trajectory costs O(1)
    /// allocations regardless of step count (rust/tests/zero_alloc.rs).
    plan: std::sync::Arc<Vec<(f64, Vec<f64>)>>,
}

impl TabDeis {
    pub fn new(sde: &Sde, grid: &[f64], order: usize) -> Self {
        assert!(order <= 3, "tAB order up to 3 (paper evaluates 0..3)");
        let n = grid.len() - 1;
        let q = Quadrature::gauss(32);
        let mut plan = Vec::with_capacity(n);
        for i in (1..=n).rev() {
            let (t, t_prev) = (grid[i], grid[i - 1]);
            // Warmup: only N-i previous evals exist at step i (paper: lower
            // order for the first steps; App. B Q3).
            let r_eff = order.min(n - i);
            let nodes: Vec<f64> = (0..=r_eff).map(|j| grid[i + j]).collect();
            let coefs: Vec<f64> = (0..=r_eff)
                .map(|j| {
                    q.integrate_panels(
                        |tau| sde.eps_integrand(t_prev, tau) * lagrange_basis(&nodes, j, tau),
                        t,
                        t_prev,
                        8,
                    )
                })
                .collect();
            plan.push((sde.psi(t_prev, t), coefs));
        }
        TabDeis { grid: grid.to_vec(), order, plan: std::sync::Arc::new(plan) }
    }

    /// Closed-form DDIM coefficient for a VP step (Prop. 2) — test oracle.
    pub fn ddim_coef_vp(sde: &Sde, t_from: f64, t_to: f64) -> f64 {
        sde.sigma(t_to) - sde.psi(t_to, t_from) * sde.sigma(t_from)
    }

    /// Expose a step's coefficients (tests/diagnostics).
    pub fn step_coef(&self, step: usize) -> &[f64] {
        &self.plan[step].1
    }
}

/// Resumable tAB-DEIS step machine — the single copy of the Eq. 14–15
/// update, driven both by `Solver::sample` and the coordinator's scheduler.
pub struct TabCursor {
    grid: Vec<f64>,
    /// Per step: (psi, C_ij) — shared with the precomputed solver plan.
    plan: std::sync::Arc<Vec<(f64, Vec<f64>)>>,
    x: Vec<f64>,
    /// Destination of the pending eval, checked out of `buf`'s recycler.
    pending: Vec<f64>,
    buf: EpsBuffer,
    step: usize,
    n: usize,
    b: usize,
}

impl StepCursor for TabCursor {
    fn pending_t(&self) -> Option<f64> {
        if self.step < self.n {
            Some(self.grid[self.n - self.step])
        } else {
            None
        }
    }

    fn io(&mut self) -> (&[f64], &mut [f64]) {
        (&self.x, &mut self.pending)
    }

    fn advance(&mut self) {
        let t = self.grid[self.n - self.step];
        let eps = std::mem::take(&mut self.pending);
        self.buf.push(t, eps);
        let (psi, coefs) = &self.plan[self.step];
        // Fixed-size ref array: order <= 3 means at most 4 histories.
        let mut eps_refs: [&[f64]; 4] = [&[]; 4];
        for (j, r) in eps_refs.iter_mut().enumerate().take(coefs.len()) {
            *r = self.buf.eps(j);
        }
        deis_combine(&mut self.x, *psi, coefs, &eps_refs[..coefs.len()]);
        self.step += 1;
        if self.step < self.n {
            self.pending = self.buf.checkout(self.x.len());
        }
    }

    fn batch(&self) -> usize {
        self.b
    }

    fn take_samples(&mut self) -> Vec<f64> {
        std::mem::take(&mut self.x)
    }
}

impl Solver for TabDeis {
    fn name(&self) -> String {
        if self.order == 0 {
            "ddim".into()
        } else {
            format!("tab{}", self.order)
        }
    }

    fn nfe(&self) -> usize {
        self.grid.len() - 1
    }

    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, rng: &mut Rng) {
        sample_via_cursor(self, model, x, b, rng);
    }

    fn cursor(&self, x: &[f64], b: usize, _rng: &mut Rng) -> Box<dyn StepCursor> {
        let n = self.grid.len() - 1;
        let mut buf = EpsBuffer::new(self.order + 1);
        let pending = buf.checkout(x.len());
        Box::new(TabCursor {
            grid: self.grid.clone(),
            plan: self.plan.clone(),
            x: x.to_vec(),
            pending,
            buf,
            step: 0,
            n,
            b,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::score::GmmEps;
    use crate::timegrid::{build, GridKind};
    use crate::util::prop::{assert_close, run_prop};

    #[test]
    fn tab0_coef_matches_ddim_closed_form_vp() {
        // Prop 2: quadrature C_i0 == closed form, to 1e-9, on random grids.
        run_prop("tab0 == ddim", 21, 30, |rng| {
            let sde = Sde::vp();
            let n = 2 + rng.below(20);
            let kind = match rng.below(3) {
                0 => GridKind::Uniform,
                1 => GridKind::Quadratic,
                _ => GridKind::LogRho,
            };
            let grid = build(kind, &sde, 1e-3, 1.0, n);
            let tab = TabDeis::new(&sde, &grid, 0);
            for (step, i) in (1..=n).rev().enumerate() {
                let want = TabDeis::ddim_coef_vp(&sde, grid[i], grid[i - 1]);
                let got = tab.step_coef(step)[0];
                assert!((got - want).abs() < 1e-9, "step {step}: {got} vs {want}");
            }
        });
    }

    #[test]
    fn tab0_coef_matches_ddim_closed_form_ve() {
        let sde = Sde::ve();
        let grid = build(GridKind::LogRho, &sde, 1e-5, 1.0, 12);
        let tab = TabDeis::new(&sde, &grid, 0);
        for (step, i) in (1..=12).rev().enumerate() {
            let want = sde.sigma(grid[i - 1]) - sde.sigma(grid[i]);
            let got = tab.step_coef(step)[0];
            assert!((got - want).abs() < 1e-9, "step {step}: {got} vs {want}");
        }
    }

    #[test]
    fn warmup_orders_ramp() {
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 10);
        let tab = TabDeis::new(&sde, &grid, 3);
        assert_eq!(tab.step_coef(0).len(), 1); // first step: zero order
        assert_eq!(tab.step_coef(1).len(), 2);
        assert_eq!(tab.step_coef(2).len(), 3);
        assert_eq!(tab.step_coef(3).len(), 4);
        assert_eq!(tab.step_coef(9).len(), 4);
    }

    #[test]
    fn coefs_sum_to_ddim_coef() {
        // sum_j C_ij == ∫ w(τ)·1 dτ == C^{DDIM}_i (partition of unity).
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 10);
        let tab3 = TabDeis::new(&sde, &grid, 3);
        let tab0 = TabDeis::new(&sde, &grid, 0);
        for step in 0..10 {
            let sum: f64 = tab3.step_coef(step).iter().sum();
            let want = tab0.step_coef(step)[0];
            assert!((sum - want).abs() < 1e-9, "step {step}: {sum} vs {want}");
        }
    }

    #[test]
    fn high_order_beats_ddim_at_n10() {
        // Fig 4c shape: on the exact-score oracle, tab3 at N=10 is closer to
        // the N=640 reference than ddim at N=10.
        let sde = Sde::vp();
        let model = GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), sde);
        let b = 16;
        let x0: Vec<f64> = Rng::new(5).normal_vec(b * 2);
        let run = |order: usize, n: usize| {
            let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, n);
            let mut x = x0.clone();
            TabDeis::new(&sde, &grid, order).sample(&model, &mut x, b, &mut Rng::new(0));
            x
        };
        let reference = run(0, 640);
        let err = |x: &[f64]| -> f64 {
            x.iter().zip(&reference).map(|(a, b)| (a - b).abs()).sum::<f64>() / x.len() as f64
        };
        let e0 = err(&run(0, 10));
        let e3 = err(&run(3, 10));
        assert!(e3 < e0, "tab3 ({e3}) should beat ddim ({e0}) at N=10");
    }

    #[test]
    fn ddim_closed_form_trajectory_matches_plan() {
        // Integrating with the plan == integrating with the textbook DDIM
        // update (Eq. 12) step by step.
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 8);
        let model = GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), sde);
        let b = 4;
        let x0: Vec<f64> = Rng::new(9).normal_vec(b * 2);

        let mut xa = x0.clone();
        TabDeis::new(&sde, &grid, 0).sample(&model, &mut xa, b, &mut Rng::new(0));

        let mut xb = x0;
        let mut eps = vec![0.0; b * 2];
        for i in (1..=8).rev() {
            let (t, tp) = (grid[i], grid[i - 1]);
            model.eval(&xb, &vec![t; b], b, &mut eps);
            let psi = sde.psi(tp, t);
            let c = TabDeis::ddim_coef_vp(&sde, t, tp);
            for (xv, ev) in xb.iter_mut().zip(&eps) {
                *xv = psi * *xv + c * ev;
            }
        }
        assert_close(&xa, &xb, 1e-8, "plan vs closed-form DDIM");
    }
}
