//! ρRK-DEIS (paper Sec. 4): classical Runge–Kutta on the transformed ODE
//! dŷ/dρ = ε̂(ŷ, ρ). ρ2Heun is the Karras et al. (2022) sampler (paper
//! App. B Q4 proves the equivalence); Kutta3 and RK4 are the other variants
//! of Table 2. Each stage costs one NFE.

use crate::diffusion::Sde;
use crate::score::EpsModel;
use crate::solvers::{fill_t, Solver};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    Midpoint,
    Heun,
    Kutta3,
    Rk4,
}

impl Scheme {
    pub fn stages(&self) -> usize {
        match self {
            Scheme::Midpoint | Scheme::Heun => 2,
            Scheme::Kutta3 => 3,
            Scheme::Rk4 => 4,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Midpoint => "rho-midpoint",
            Scheme::Heun => "rho-heun",
            Scheme::Kutta3 => "rho-kutta3",
            Scheme::Rk4 => "rho-rk4",
        }
    }

    /// Butcher tableau (c offsets, per-stage a-rows, b weights).
    fn tableau(&self) -> (Vec<f64>, Vec<Vec<f64>>, Vec<f64>) {
        match self {
            Scheme::Midpoint => (
                vec![0.0, 0.5],
                vec![vec![], vec![0.5]],
                vec![0.0, 1.0],
            ),
            Scheme::Heun => (
                vec![0.0, 1.0],
                vec![vec![], vec![1.0]],
                vec![0.5, 0.5],
            ),
            Scheme::Kutta3 => (
                vec![0.0, 0.5, 1.0],
                vec![vec![], vec![0.5], vec![-1.0, 2.0]],
                vec![1.0 / 6.0, 2.0 / 3.0, 1.0 / 6.0],
            ),
            Scheme::Rk4 => (
                vec![0.0, 0.5, 0.5, 1.0],
                vec![vec![], vec![0.5], vec![0.0, 0.5], vec![0.0, 0.0, 1.0]],
                vec![1.0 / 6.0, 1.0 / 3.0, 1.0 / 3.0, 1.0 / 6.0],
            ),
        }
    }
}

pub struct RhoRk {
    sde: Sde,
    grid: Vec<f64>,
    rho: Vec<f64>,
    scheme: Scheme,
}

impl RhoRk {
    pub fn new(sde: &Sde, grid: &[f64], scheme: Scheme) -> Self {
        let rho = grid.iter().map(|&t| sde.rho(t)).collect();
        RhoRk { sde: *sde, grid: grid.to_vec(), rho, scheme }
    }

    /// Evaluate ε̂(y, ρ) = ε_θ(√ᾱ(t(ρ)) y, t(ρ)).
    fn eval_hat(
        &self,
        model: &dyn EpsModel,
        y: &[f64],
        rho: f64,
        b: usize,
        tb: &mut Vec<f64>,
        xbuf: &mut [f64],
        out: &mut [f64],
    ) {
        let t = self.sde.t_of_rho(rho).clamp(self.grid[0], self.grid[self.grid.len() - 1]);
        let s = self.sde.sqrt_abar(t);
        for (xv, &yv) in xbuf.iter_mut().zip(y) {
            *xv = s * yv;
        }
        model.eval(xbuf, fill_t(tb, t, b), b, out);
    }
}

impl Solver for RhoRk {
    fn name(&self) -> String {
        self.scheme.name().into()
    }

    fn nfe(&self) -> usize {
        (self.grid.len() - 1) * self.scheme.stages()
    }

    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, _rng: &mut Rng) {
        let n = self.grid.len() - 1;
        let d = model.dim();
        let (c, a, w) = self.scheme.tableau();
        let stages = self.scheme.stages();
        let mut tb = Vec::new();
        let mut xbuf = vec![0.0; b * d];
        let mut ybuf = vec![0.0; b * d];
        let mut ks: Vec<Vec<f64>> = (0..stages).map(|_| vec![0.0; b * d]).collect();

        let s_start = self.sde.sqrt_abar(self.grid[n]);
        let mut y: Vec<f64> = x.iter().map(|&v| v / s_start).collect();

        for i in (1..=n).rev() {
            let h = self.rho[i - 1] - self.rho[i]; // negative (rho shrinks)
            for s_idx in 0..stages {
                // y_stage = y + h * sum_j a[s][j] k_j
                ybuf.copy_from_slice(&y);
                for (j, &aj) in a[s_idx].iter().enumerate() {
                    if aj != 0.0 {
                        for (yv, kv) in ybuf.iter_mut().zip(&ks[j]) {
                            *yv += h * aj * kv;
                        }
                    }
                }
                let rho_s = self.rho[i] + c[s_idx] * h;
                let (head, tail) = ks.split_at_mut(s_idx);
                let _ = head;
                self.eval_hat(model, &ybuf, rho_s, b, &mut tb, &mut xbuf, &mut tail[0]);
            }
            for (s_idx, ws) in w.iter().enumerate() {
                if *ws != 0.0 {
                    for (yv, kv) in y.iter_mut().zip(&ks[s_idx]) {
                        *yv += h * ws * kv;
                    }
                }
            }
        }
        let s0 = self.sde.sqrt_abar(self.grid[0]);
        for (xv, &yv) in x.iter_mut().zip(&y) {
            *xv = s0 * yv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::score::GmmEps;
    use crate::timegrid::{build, GridKind};

    fn model() -> GmmEps {
        GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())
    }

    fn run(scheme: Scheme, n: usize, x0: &[f64], b: usize) -> Vec<f64> {
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, n);
        let mut x = x0.to_vec();
        RhoRk::new(&sde, &grid, scheme).sample(&model(), &mut x, b, &mut Rng::new(0));
        x
    }

    #[test]
    fn nfe_accounting() {
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 5);
        assert_eq!(RhoRk::new(&sde, &grid, Scheme::Heun).nfe(), 10);
        assert_eq!(RhoRk::new(&sde, &grid, Scheme::Rk4).nfe(), 20);
    }

    #[test]
    fn schemes_converge_to_common_limit() {
        let b = 4;
        let x0: Vec<f64> = Rng::new(8).normal_vec(b * 2);
        let reference = run(Scheme::Rk4, 256, &x0, b);
        for scheme in [Scheme::Midpoint, Scheme::Heun, Scheme::Kutta3] {
            let got = run(scheme, 128, &x0, b);
            let err: f64 =
                got.iter().zip(&reference).map(|(a, r)| (a - r).abs()).fold(0.0, f64::max);
            assert!(err < 1e-3, "{:?} err {err}", scheme);
        }
    }

    #[test]
    fn heun_order_two() {
        let b = 4;
        let x0: Vec<f64> = Rng::new(8).normal_vec(b * 2);
        let reference = run(Scheme::Rk4, 512, &x0, b);
        let err = |x: &[f64]| -> f64 {
            x.iter().zip(&reference).map(|(a, r)| (a - r).abs()).fold(0.0, f64::max)
        };
        let e16 = err(&run(Scheme::Heun, 16, &x0, b));
        let e32 = err(&run(Scheme::Heun, 32, &x0, b));
        let rate = (e16 / e32).log2();
        assert!(rate > 1.5, "heun rate {rate} (e16={e16} e32={e32})");
    }

    #[test]
    fn tableaus_are_consistent() {
        // b-weights sum to 1, a-rows sum to c (standard RK consistency).
        for scheme in [Scheme::Midpoint, Scheme::Heun, Scheme::Kutta3, Scheme::Rk4] {
            let (c, a, w) = scheme.tableau();
            assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12, "{scheme:?}");
            for (s, row) in a.iter().enumerate() {
                let sum: f64 = row.iter().sum();
                assert!((sum - c[s]).abs() < 1e-12, "{scheme:?} stage {s}");
            }
        }
    }
}
