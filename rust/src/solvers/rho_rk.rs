//! ρRK-DEIS (paper Sec. 4): classical Runge–Kutta on the transformed ODE
//! dŷ/dρ = ε̂(ŷ, ρ). ρ2Heun is the Karras et al. (2022) sampler (paper
//! App. B Q4 proves the equivalence); Kutta3 and RK4 are the other variants
//! of Table 2. Each stage costs one NFE.

use crate::diffusion::Sde;
use crate::score::EpsModel;
use crate::solvers::plan::{sample_via_cursor, StepCursor};
use crate::solvers::Solver;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    Midpoint,
    Heun,
    Kutta3,
    Rk4,
}

impl Scheme {
    pub fn stages(&self) -> usize {
        match self {
            Scheme::Midpoint | Scheme::Heun => 2,
            Scheme::Kutta3 => 3,
            Scheme::Rk4 => 4,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Midpoint => "rho-midpoint",
            Scheme::Heun => "rho-heun",
            Scheme::Kutta3 => "rho-kutta3",
            Scheme::Rk4 => "rho-rk4",
        }
    }

    /// Butcher tableau (c offsets, per-stage a-rows, b weights).
    fn tableau(&self) -> (Vec<f64>, Vec<Vec<f64>>, Vec<f64>) {
        match self {
            Scheme::Midpoint => (
                vec![0.0, 0.5],
                vec![vec![], vec![0.5]],
                vec![0.0, 1.0],
            ),
            Scheme::Heun => (
                vec![0.0, 1.0],
                vec![vec![], vec![1.0]],
                vec![0.5, 0.5],
            ),
            Scheme::Kutta3 => (
                vec![0.0, 0.5, 1.0],
                vec![vec![], vec![0.5], vec![-1.0, 2.0]],
                vec![1.0 / 6.0, 2.0 / 3.0, 1.0 / 6.0],
            ),
            Scheme::Rk4 => (
                vec![0.0, 0.5, 0.5, 1.0],
                vec![vec![], vec![0.5], vec![0.0, 0.5], vec![0.0, 0.0, 1.0]],
                vec![1.0 / 6.0, 1.0 / 3.0, 1.0 / 3.0, 1.0 / 6.0],
            ),
        }
    }
}

pub struct RhoRk {
    sde: Sde,
    grid: Vec<f64>,
    rho: Vec<f64>,
    scheme: Scheme,
}

impl RhoRk {
    pub fn new(sde: &Sde, grid: &[f64], scheme: Scheme) -> Self {
        let rho = grid.iter().map(|&t| sde.rho(t)).collect();
        RhoRk { sde: *sde, grid: grid.to_vec(), rho, scheme }
    }
}

/// Resumable ρRK step machine: integrates dŷ/dρ = ε̂(ŷ, ρ) stage by stage.
/// Each yield is the eval for one RK stage at x̂ = √ᾱ(t(ρ_s))·ŷ_stage; the
/// stage combination y += h·Σ b_s k_s runs in `advance` after the last
/// stage, so solo and scheduled runs share one copy of the tableau math.
pub struct RhoRkCursor {
    sde: Sde,
    grid: Vec<f64>,
    rho: Vec<f64>,
    c: Vec<f64>,
    a: Vec<Vec<f64>>,
    w: Vec<f64>,
    stages: usize,
    /// Transformed state ŷ = x / √ᾱ.
    y: Vec<f64>,
    /// Stage state ŷ + h·Σ_j a[s][j]·k_j.
    ybuf: Vec<f64>,
    /// Eval input x̂ = √ᾱ(t_eval)·ybuf for the pending stage.
    xbuf: Vec<f64>,
    /// Stage derivatives; the pending eval writes into `ks[stage]`.
    ks: Vec<Vec<f64>>,
    /// Integrating grid[i] -> grid[i-1]; done at i == 0.
    i: usize,
    stage: usize,
    /// Time of the pending eval (cached so `pending_t` stays pure).
    t_eval: f64,
    b: usize,
}

impl RhoRkCursor {
    fn new(solver: &RhoRk, x: &[f64], b: usize) -> RhoRkCursor {
        let n = solver.grid.len() - 1;
        let (c, a, w) = solver.scheme.tableau();
        let stages = solver.scheme.stages();
        let s_start = solver.sde.sqrt_abar(solver.grid[n]);
        let y: Vec<f64> = x.iter().map(|&v| v / s_start).collect();
        let mut cur = RhoRkCursor {
            sde: solver.sde,
            grid: solver.grid.clone(),
            rho: solver.rho.clone(),
            c,
            a,
            w,
            stages,
            y,
            ybuf: vec![0.0; x.len()],
            xbuf: vec![0.0; x.len()],
            ks: (0..stages).map(|_| vec![0.0; x.len()]).collect(),
            i: n,
            stage: 0,
            t_eval: 0.0,
            b,
        };
        cur.prep_stage();
        cur
    }

    /// ρ-step of the current grid interval (negative: rho shrinks).
    fn h(&self) -> f64 {
        self.rho[self.i - 1] - self.rho[self.i]
    }

    /// Build the pending stage's input: ybuf = y + h·Σ_j a[s][j]·k_j, then
    /// x̂ = √ᾱ(t(ρ_s))·ybuf at the stage node ρ_s = ρ_i + c[s]·h.
    fn prep_stage(&mut self) {
        let h = self.h();
        let s_idx = self.stage;
        self.ybuf.copy_from_slice(&self.y);
        for (j, &aj) in self.a[s_idx].iter().enumerate() {
            if aj != 0.0 {
                for (yv, kv) in self.ybuf.iter_mut().zip(&self.ks[j]) {
                    *yv += h * aj * kv;
                }
            }
        }
        let rho_s = self.rho[self.i] + self.c[s_idx] * h;
        let t = self.sde.t_of_rho(rho_s).clamp(self.grid[0], self.grid[self.grid.len() - 1]);
        self.t_eval = t;
        let sc = self.sde.sqrt_abar(t);
        for (xv, &yv) in self.xbuf.iter_mut().zip(&self.ybuf) {
            *xv = sc * yv;
        }
    }
}

impl StepCursor for RhoRkCursor {
    fn pending_t(&self) -> Option<f64> {
        if self.i >= 1 {
            Some(self.t_eval)
        } else {
            None
        }
    }

    fn io(&mut self) -> (&[f64], &mut [f64]) {
        let stage = self.stage;
        (&self.xbuf, &mut self.ks[stage])
    }

    fn advance(&mut self) {
        self.stage += 1;
        if self.stage < self.stages {
            self.prep_stage();
            return;
        }
        let h = self.h();
        for (s_idx, ws) in self.w.iter().enumerate() {
            if *ws != 0.0 {
                for (yv, kv) in self.y.iter_mut().zip(&self.ks[s_idx]) {
                    *yv += h * ws * kv;
                }
            }
        }
        self.i -= 1;
        self.stage = 0;
        if self.i >= 1 {
            self.prep_stage();
        }
    }

    fn batch(&self) -> usize {
        self.b
    }

    fn take_samples(&mut self) -> Vec<f64> {
        let s0 = self.sde.sqrt_abar(self.grid[0]);
        let mut x = std::mem::take(&mut self.y);
        for v in x.iter_mut() {
            *v *= s0;
        }
        x
    }
}

impl Solver for RhoRk {
    fn name(&self) -> String {
        self.scheme.name().into()
    }

    fn nfe(&self) -> usize {
        (self.grid.len() - 1) * self.scheme.stages()
    }

    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, rng: &mut Rng) {
        sample_via_cursor(self, model, x, b, rng);
    }

    fn cursor(&self, x: &[f64], b: usize, _rng: &mut Rng) -> Box<dyn StepCursor> {
        Box::new(RhoRkCursor::new(self, x, b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::score::GmmEps;
    use crate::timegrid::{build, GridKind};

    fn model() -> GmmEps {
        GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())
    }

    fn run(scheme: Scheme, n: usize, x0: &[f64], b: usize) -> Vec<f64> {
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, n);
        let mut x = x0.to_vec();
        RhoRk::new(&sde, &grid, scheme).sample(&model(), &mut x, b, &mut Rng::new(0));
        x
    }

    #[test]
    fn nfe_accounting() {
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 5);
        assert_eq!(RhoRk::new(&sde, &grid, Scheme::Heun).nfe(), 10);
        assert_eq!(RhoRk::new(&sde, &grid, Scheme::Rk4).nfe(), 20);
    }

    #[test]
    fn schemes_converge_to_common_limit() {
        let b = 4;
        let x0: Vec<f64> = Rng::new(8).normal_vec(b * 2);
        let reference = run(Scheme::Rk4, 256, &x0, b);
        for scheme in [Scheme::Midpoint, Scheme::Heun, Scheme::Kutta3] {
            let got = run(scheme, 128, &x0, b);
            let err: f64 =
                got.iter().zip(&reference).map(|(a, r)| (a - r).abs()).fold(0.0, f64::max);
            assert!(err < 1e-3, "{:?} err {err}", scheme);
        }
    }

    #[test]
    fn heun_order_two() {
        let b = 4;
        let x0: Vec<f64> = Rng::new(8).normal_vec(b * 2);
        let reference = run(Scheme::Rk4, 512, &x0, b);
        let err = |x: &[f64]| -> f64 {
            x.iter().zip(&reference).map(|(a, r)| (a - r).abs()).fold(0.0, f64::max)
        };
        let e16 = err(&run(Scheme::Heun, 16, &x0, b));
        let e32 = err(&run(Scheme::Heun, 32, &x0, b));
        let rate = (e16 / e32).log2();
        assert!(rate > 1.5, "heun rate {rate} (e16={e16} e32={e32})");
    }

    #[test]
    fn tableaus_are_consistent() {
        // b-weights sum to 1, a-rows sum to c (standard RK consistency).
        for scheme in [Scheme::Midpoint, Scheme::Heun, Scheme::Kutta3, Scheme::Rk4] {
            let (c, a, w) = scheme.tableau();
            assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12, "{scheme:?}");
            for (s, row) in a.iter().enumerate() {
                let sum: f64 = row.iter().sum();
                assert!((sum - c[s]).abs() < 1e-12, "{scheme:?} stage {s}");
            }
        }
    }
}
