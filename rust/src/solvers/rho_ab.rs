//! ρAB-DEIS (paper Sec. 4): Adams–Bashforth on the transformed, non-stiff
//! ODE dŷ/dρ = ε̂(ŷ, ρ) of Prop. 3. The Lagrange-basis integrals are exactly
//! polynomial in ρ, so coefficients are computed in closed form. Differs
//! from tAB-DEIS in fitting polynomials in ρ rather than t (paper Sec. 4).

use crate::diffusion::Sde;
use crate::quad::lagrange_basis_integral;
use crate::score::EpsModel;
use crate::solvers::plan::{sample_via_cursor, StepCursor};
use crate::solvers::{EpsBuffer, Solver};
use crate::util::rng::Rng;

pub struct RhoAbDeis {
    sde: Sde,
    grid: Vec<f64>,
    rho: Vec<f64>,
    order: usize,
    /// Per step (index 0 = the i=N step): AB coefficients for the warmup-
    /// ramped effective order. Precomputed once per (sde, grid, order) so
    /// the sampling loop does no coefficient work (paper Eq. 15 remark);
    /// Arc-shared with cursors so starting a trajectory costs O(1)
    /// allocations regardless of step count (rust/tests/zero_alloc.rs).
    plan: std::sync::Arc<Vec<Vec<f64>>>,
}

impl RhoAbDeis {
    pub fn new(sde: &Sde, grid: &[f64], order: usize) -> Self {
        assert!(order <= 3);
        let rho: Vec<f64> = grid.iter().map(|&t| sde.rho(t)).collect();
        let n = grid.len() - 1;
        let plan: Vec<Vec<f64>> = (1..=n)
            .rev()
            .enumerate()
            .map(|(step, i)| {
                // Warmup: only `step` previous evals exist at step `step`.
                let r_eff = order.min(step);
                let nodes: Vec<f64> = (0..=r_eff).map(|j| rho[i + j]).collect();
                (0..=r_eff)
                    .map(|j| lagrange_basis_integral(&nodes, j, rho[i], rho[i - 1]))
                    .collect()
            })
            .collect();
        RhoAbDeis {
            sde: *sde,
            grid: grid.to_vec(),
            rho,
            order,
            plan: std::sync::Arc::new(plan),
        }
    }
}

/// Resumable ρAB-DEIS step machine: integrates the transformed ODE in
/// y = x/√ᾱ, yielding evals at x̂(t) = √ᾱ(t)·y. Single copy of the update
/// math for both the solo and scheduled paths.
pub struct RhoAbCursor {
    sde: Sde,
    grid: Vec<f64>,
    rho: Vec<f64>,
    plan: std::sync::Arc<Vec<Vec<f64>>>,
    /// Transformed state y = x / sqrt(abar).
    y: Vec<f64>,
    /// Eval input x̂ = sqrt(abar(t)) * y at the pending node.
    xcur: Vec<f64>,
    pending: Vec<f64>,
    buf: EpsBuffer,
    step: usize,
    n: usize,
    b: usize,
}

impl RhoAbCursor {
    /// Rebuild the eval input for the current pending node.
    fn refresh_xcur(&mut self) {
        let s = self.sde.sqrt_abar(self.grid[self.n - self.step]);
        for (xc, &yv) in self.xcur.iter_mut().zip(&self.y) {
            *xc = s * yv;
        }
    }
}

impl StepCursor for RhoAbCursor {
    fn pending_t(&self) -> Option<f64> {
        if self.step < self.n {
            Some(self.grid[self.n - self.step])
        } else {
            None
        }
    }

    fn io(&mut self) -> (&[f64], &mut [f64]) {
        (&self.xcur, &mut self.pending)
    }

    fn advance(&mut self) {
        let i = self.n - self.step;
        let eps = std::mem::take(&mut self.pending);
        self.buf.push(self.rho[i], eps);
        let coefs = &self.plan[self.step];
        for (j, c) in coefs.iter().enumerate() {
            let e = self.buf.eps(j);
            for (yv, ev) in self.y.iter_mut().zip(e) {
                *yv += c * ev;
            }
        }
        self.step += 1;
        if self.step < self.n {
            self.refresh_xcur();
            self.pending = self.buf.checkout(self.xcur.len());
        }
    }

    fn batch(&self) -> usize {
        self.b
    }

    fn take_samples(&mut self) -> Vec<f64> {
        let s0 = self.sde.sqrt_abar(self.grid[0]);
        let mut x = std::mem::take(&mut self.y);
        for v in x.iter_mut() {
            *v *= s0;
        }
        x
    }
}

impl Solver for RhoAbDeis {
    fn name(&self) -> String {
        format!("rho-ab{}", self.order)
    }

    fn nfe(&self) -> usize {
        self.grid.len() - 1
    }

    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, rng: &mut Rng) {
        sample_via_cursor(self, model, x, b, rng);
    }

    fn cursor(&self, x: &[f64], b: usize, _rng: &mut Rng) -> Box<dyn StepCursor> {
        let n = self.grid.len() - 1;
        let s = self.sde.sqrt_abar(self.grid[n]);
        let y: Vec<f64> = x.iter().map(|&v| v / s).collect();
        let mut buf = EpsBuffer::new(self.order + 1);
        let pending = buf.checkout(x.len());
        let mut cur = RhoAbCursor {
            sde: self.sde,
            grid: self.grid.clone(),
            rho: self.rho.clone(),
            plan: self.plan.clone(),
            y,
            xcur: vec![0.0; x.len()],
            pending,
            buf,
            step: 0,
            n,
            b,
        };
        cur.refresh_xcur();
        Box::new(cur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::score::GmmEps;
    use crate::solvers::tab::TabDeis;
    use crate::timegrid::{build, GridKind};
    use crate::util::prop::assert_close;

    fn model() -> GmmEps {
        GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())
    }

    #[test]
    fn rho_ab0_equals_ddim() {
        // Prop 2 again: r=0 in rho-space is DDIM, since sqrt(abar)*drho
        // integrates to the DDIM coefficient.
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 10);
        let m = model();
        let b = 8;
        let x0: Vec<f64> = Rng::new(2).normal_vec(b * 2);
        let mut xa = x0.clone();
        let mut xb = x0;
        RhoAbDeis::new(&sde, &grid, 0).sample(&m, &mut xa, b, &mut Rng::new(0));
        TabDeis::new(&sde, &grid, 0).sample(&m, &mut xb, b, &mut Rng::new(0));
        assert_close(&xa, &xb, 1e-7, "rho-ab0 vs ddim");
    }

    #[test]
    fn rho_ab0_equals_ddim_ve() {
        let sde = Sde::ve();
        let grid = build(GridKind::LogRho, &sde, 1e-5, 1.0, 10);
        let m = GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), sde);
        let b = 8;
        let x0: Vec<f64> = Rng::new(2).normal_vec(b * 2).iter().map(|v| v * 50.0).collect();
        let mut xa = x0.clone();
        let mut xb = x0;
        RhoAbDeis::new(&sde, &grid, 0).sample(&m, &mut xa, b, &mut Rng::new(0));
        TabDeis::new(&sde, &grid, 0).sample(&m, &mut xb, b, &mut Rng::new(0));
        assert_close(&xa, &xb, 1e-7, "rho-ab0 vs ddim (ve)");
    }

    #[test]
    fn rho_ab2_converges_third_order_ish() {
        // Self-convergence rate: halving steps shrinks error by ~2^(r+1).
        let sde = Sde::vp();
        let m = model();
        let b = 8;
        let x0: Vec<f64> = Rng::new(4).normal_vec(b * 2);
        let run = |n: usize| {
            let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, n);
            let mut x = x0.clone();
            RhoAbDeis::new(&sde, &grid, 2).sample(&m, &mut x, b, &mut Rng::new(0));
            x
        };
        let reference = run(512);
        let err = |x: &[f64]| {
            x.iter().zip(&reference).map(|(a, r)| (a - r).abs()).fold(0.0_f64, f64::max)
        };
        let (e1, e2) = (err(&run(16)), err(&run(32)));
        let rate = (e1 / e2).log2();
        assert!(rate > 2.0, "rho-ab2 convergence rate {rate} (e16={e1}, e32={e2})");
    }
}
