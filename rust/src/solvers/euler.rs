//! Euler discretizations of the probability-flow ODE (paper Eq. 7).
//!
//! `EulerEps` steps dx/dt = f(t)x + ½g²(t)/σ(t) · ε_θ(x,t) (Eq. 10);
//! `EulerScore` steps dx/dt = f(t)x − ½g²(t) · s_θ(x,t) (Eq. 5) with
//! s = −ε/σ — pointwise the two are identical vector fields, so the solvers
//! agree to rounding (a unit test pins this); both are kept because the
//! paper's ablation ladder starts from "Euler" regardless of param.

use crate::diffusion::Sde;
use crate::score::EpsModel;
use crate::solvers::plan::{sample_via_cursor, StepCursor};
use crate::solvers::Solver;
use crate::util::rng::Rng;

/// Resumable Euler step machine; `score_param` selects Eq. 5 vs Eq. 10.
/// This is the single copy of the Euler step math — both `Solver::sample`
/// paths drive it (see `solvers::plan`).
pub struct EulerCursor {
    sde: Sde,
    grid: Vec<f64>,
    score_param: bool,
    x: Vec<f64>,
    eps: Vec<f64>,
    b: usize,
    /// Current grid index: the pending eval is at grid[i]; done at i == 0.
    i: usize,
}

impl EulerCursor {
    fn new(sde: &Sde, grid: &[f64], score_param: bool, x: &[f64], b: usize) -> EulerCursor {
        EulerCursor {
            sde: *sde,
            grid: grid.to_vec(),
            score_param,
            x: x.to_vec(),
            eps: vec![0.0; x.len()],
            b,
            i: grid.len() - 1,
        }
    }
}

impl StepCursor for EulerCursor {
    fn pending_t(&self) -> Option<f64> {
        if self.i >= 1 {
            Some(self.grid[self.i])
        } else {
            None
        }
    }

    fn io(&mut self) -> (&[f64], &mut [f64]) {
        (&self.x, &mut self.eps)
    }

    fn advance(&mut self) {
        let (t, t_prev) = (self.grid[self.i], self.grid[self.i - 1]);
        let dt = t_prev - t; // negative
        let f = self.sde.f_scalar(t);
        if self.score_param {
            let g2 = self.sde.g2(t);
            let sig = self.sde.sigma(t);
            for (xv, ev) in self.x.iter_mut().zip(&self.eps) {
                let s = -ev / sig; // score from eps
                *xv += dt * (f * *xv - 0.5 * g2 * s);
            }
        } else {
            let w = 0.5 * self.sde.g2(t) / self.sde.sigma(t);
            for (xv, ev) in self.x.iter_mut().zip(&self.eps) {
                *xv += dt * (f * *xv + w * ev);
            }
        }
        self.i -= 1;
    }

    fn batch(&self) -> usize {
        self.b
    }

    fn take_samples(&mut self) -> Vec<f64> {
        std::mem::take(&mut self.x)
    }
}

pub struct EulerEps {
    sde: Sde,
    grid: Vec<f64>,
}

impl EulerEps {
    pub fn new(sde: &Sde, grid: &[f64]) -> Self {
        EulerEps { sde: *sde, grid: grid.to_vec() }
    }
}

impl Solver for EulerEps {
    fn name(&self) -> String {
        "euler".into()
    }

    fn nfe(&self) -> usize {
        self.grid.len() - 1
    }

    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, rng: &mut Rng) {
        sample_via_cursor(self, model, x, b, rng);
    }

    fn cursor(&self, x: &[f64], b: usize, _rng: &mut Rng) -> Box<dyn StepCursor> {
        Box::new(EulerCursor::new(&self.sde, &self.grid, false, x, b))
    }
}

pub struct EulerScore {
    sde: Sde,
    grid: Vec<f64>,
}

impl EulerScore {
    pub fn new(sde: &Sde, grid: &[f64]) -> Self {
        EulerScore { sde: *sde, grid: grid.to_vec() }
    }
}

impl Solver for EulerScore {
    fn name(&self) -> String {
        "euler-score".into()
    }

    fn nfe(&self) -> usize {
        self.grid.len() - 1
    }

    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, rng: &mut Rng) {
        sample_via_cursor(self, model, x, b, rng);
    }

    fn cursor(&self, x: &[f64], b: usize, _rng: &mut Rng) -> Box<dyn StepCursor> {
        Box::new(EulerCursor::new(&self.sde, &self.grid, true, x, b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::score::GmmEps;
    use crate::timegrid::{build, GridKind};
    use crate::util::prop::assert_close;

    #[test]
    fn eps_and_score_params_agree_for_euler() {
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 20);
        let model = GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), sde);
        let mut rng = Rng::new(1);
        let x0: Vec<f64> = rng.normal_vec(12);
        let mut xa = x0.clone();
        let mut xb = x0;
        EulerEps::new(&sde, &grid).sample(&model, &mut xa, 6, &mut Rng::new(0));
        EulerScore::new(&sde, &grid).sample(&model, &mut xb, 6, &mut Rng::new(0));
        assert_close(&xa, &xb, 1e-10, "euler param equivalence");
    }

    #[test]
    fn euler_converges_on_gaussian() {
        // Single Gaussian: exact ODE solution is affine in x; Euler with many
        // steps must land near the exact map x0 = sqrt(abar_t0)*... Here we
        // just check self-convergence: N=400 vs N=800 differ by O(1/N).
        let sde = Sde::vp();
        let model = GmmEps::new(Gmm::new(vec![vec![1.5, -0.5]], 0.4), sde);
        let mut rng = Rng::new(3);
        let x0: Vec<f64> = rng.normal_vec(8);
        let run = |n: usize| {
            let grid = build(GridKind::Uniform, &sde, 1e-3, 1.0, n);
            let mut x = x0.clone();
            EulerEps::new(&sde, &grid).sample(&model, &mut x, 4, &mut Rng::new(0));
            x
        };
        let a = run(400);
        let b = run(800);
        let err: f64 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(err < 5e-3, "euler self-convergence err {err}");
    }
}
