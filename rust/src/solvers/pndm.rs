//! PNDM (Liu et al. 2022) and the paper's improved iPNDM (App. H.2).
//!
//! Both combine a DDIM "transfer" step with classical Adams–Bashforth
//! weights on the buffered eps evaluations (Eqs. 36–40). PNDM warms up with
//! a pseudo-Runge–Kutta phase costing 4 NFE for each of its first 3 steps;
//! iPNDM replaces that with lower-order multistep formulas (Eq. 38–40) so it
//! works below 12 NFE — the paper's proposed tweak.
//!
//! Implemented for any scalar SDE through the generic DDIM transfer
//! φ(x, e, s→t) = Ψ(t,s)·x + (σ_t − Ψσ_s)·e.

use crate::diffusion::Sde;
use crate::score::EpsModel;
use crate::solvers::plan::{sample_via_cursor, StepCursor};
use crate::solvers::{EpsBuffer, Solver};
use crate::util::rng::Rng;

/// Classical AB weights for uniform steps, newest first (Eqs. 36, 38–40).
pub fn ab_weights(order: usize) -> &'static [f64] {
    match order {
        0 => &[1.0],
        1 => &[3.0 / 2.0, -1.0 / 2.0],
        2 => &[23.0 / 12.0, -16.0 / 12.0, 5.0 / 12.0],
        3 => &[55.0 / 24.0, -59.0 / 24.0, 37.0 / 24.0, -9.0 / 24.0],
        _ => panic!("AB order up to 3"),
    }
}

/// DDIM transfer from time s to time t using eps estimate `e`.
fn transfer(sde: &Sde, x: &mut [f64], e: &[f64], s: f64, t: f64) {
    let psi = sde.psi(t, s);
    let c = sde.sigma(t) - psi * sde.sigma(s);
    for (xv, ev) in x.iter_mut().zip(e) {
        *xv = psi * *xv + c * ev;
    }
}

/// out = sum_j weights[j] * buf.eps(j), into a caller-reused buffer.
fn combine_into(out: &mut [f64], weights: &[f64], buf: &EpsBuffer) {
    out.fill(0.0);
    for (j, w) in weights.iter().enumerate() {
        for (o, &e) in out.iter_mut().zip(buf.eps(j)) {
            *o += w * e;
        }
    }
}

pub struct Ipndm {
    sde: Sde,
    grid: Vec<f64>,
    order: usize,
}

impl Ipndm {
    pub fn new(sde: &Sde, grid: &[f64], order: usize) -> Self {
        assert!((1..=3).contains(&order));
        Ipndm { sde: *sde, grid: grid.to_vec(), order }
    }
}

/// Resumable iPNDM step machine: one eval per step, AB-weighted transfer.
pub struct IpndmCursor {
    sde: Sde,
    grid: Vec<f64>,
    order: usize,
    x: Vec<f64>,
    e_hat: Vec<f64>,
    pending: Vec<f64>,
    buf: EpsBuffer,
    step: usize,
    n: usize,
    b: usize,
}

impl StepCursor for IpndmCursor {
    fn pending_t(&self) -> Option<f64> {
        if self.step < self.n {
            Some(self.grid[self.n - self.step])
        } else {
            None
        }
    }

    fn io(&mut self) -> (&[f64], &mut [f64]) {
        (&self.x, &mut self.pending)
    }

    fn advance(&mut self) {
        let i = self.n - self.step;
        let t = self.grid[i];
        let eps = std::mem::take(&mut self.pending);
        self.buf.push(t, eps);
        let ord = self.order.min(self.buf.len() - 1); // warmup ramps 0,1,..,order
        combine_into(&mut self.e_hat, ab_weights(ord), &self.buf);
        transfer(&self.sde, &mut self.x, &self.e_hat, t, self.grid[i - 1]);
        self.step += 1;
        if self.step < self.n {
            self.pending = self.buf.checkout(self.x.len());
        }
    }

    fn batch(&self) -> usize {
        self.b
    }

    fn take_samples(&mut self) -> Vec<f64> {
        std::mem::take(&mut self.x)
    }
}

impl Solver for Ipndm {
    fn name(&self) -> String {
        format!("ipndm{}", self.order)
    }

    fn nfe(&self) -> usize {
        self.grid.len() - 1
    }

    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, rng: &mut Rng) {
        sample_via_cursor(self, model, x, b, rng);
    }

    fn cursor(&self, x: &[f64], b: usize, _rng: &mut Rng) -> Box<dyn StepCursor> {
        let mut buf = EpsBuffer::new(self.order + 1);
        let pending = buf.checkout(x.len());
        Box::new(IpndmCursor {
            sde: self.sde,
            grid: self.grid.clone(),
            order: self.order,
            x: x.to_vec(),
            e_hat: vec![0.0; x.len()],
            pending,
            buf,
            step: 0,
            n: self.grid.len() - 1,
            b,
        })
    }
}

pub struct Pndm {
    sde: Sde,
    grid: Vec<f64>,
}

impl Pndm {
    pub fn new(sde: &Sde, grid: &[f64]) -> Self {
        assert!(grid.len() - 1 >= 4, "PNDM needs >= 4 grid steps");
        Pndm { sde: *sde, grid: grid.to_vec() }
    }
}

/// Resumable PNDM step machine. The first 3 steps are the pseudo-RK warmup
/// (Liu et al. 2022): 4 evals per step — stage 0 at t on x (into `pending`,
/// which later seeds the multistep buffer), stages 1/2 at the midpoint and
/// stage 3 at t_prev, each on a transfer-rebuilt `xtmp`, accumulating the
/// RK-weighted eps (e1 + 2e2 + 2e3 + e4)/6 into `acc`. Once 3 evals are
/// buffered, each step is a single eval + AB(3) transfer.
pub struct PndmCursor {
    sde: Sde,
    grid: Vec<f64>,
    x: Vec<f64>,
    e_hat: Vec<f64>,
    /// Eval destination for stage 0 (the t-node eps that seeds `buf`).
    pending: Vec<f64>,
    buf: EpsBuffer,
    /// Warmup scratch: stage input, stage eps, RK accumulator.
    xtmp: Vec<f64>,
    etmp: Vec<f64>,
    acc: Vec<f64>,
    /// Integrating grid[i] -> grid[i-1]; done at i == 0.
    i: usize,
    /// Stage within a warmup step (0..=3); multistep steps use stage 0 only.
    stage: usize,
    /// Whether the current step is a pseudo-RK warmup step (buf.len() < 3
    /// when the step began).
    warm: bool,
    b: usize,
}

impl StepCursor for PndmCursor {
    fn pending_t(&self) -> Option<f64> {
        if self.i == 0 {
            return None;
        }
        let (t, t_prev) = (self.grid[self.i], self.grid[self.i - 1]);
        let mid = 0.5 * (t + t_prev);
        Some(match self.stage {
            0 => t,
            1 | 2 => mid,
            3 => t_prev,
            _ => unreachable!("pndm stage out of range"),
        })
    }

    fn io(&mut self) -> (&[f64], &mut [f64]) {
        match self.stage {
            0 => (&self.x, &mut self.pending),
            _ => (&self.xtmp, &mut self.etmp),
        }
    }

    fn advance(&mut self) {
        let (t, t_prev) = (self.grid[self.i], self.grid[self.i - 1]);
        let mid = 0.5 * (t + t_prev);
        match (self.warm, self.stage) {
            (false, 0) => {
                let eps = std::mem::take(&mut self.pending);
                self.buf.push(t, eps);
                combine_into(&mut self.e_hat, ab_weights(3), &self.buf);
                transfer(&self.sde, &mut self.x, &self.e_hat, t, t_prev);
                self.finish_step();
            }
            (true, 0) => {
                // e1 sits in `pending`; build stage-2's input from it.
                self.xtmp.copy_from_slice(&self.x);
                transfer(&self.sde, &mut self.xtmp, &self.pending, t, mid);
                self.stage = 1;
            }
            (true, 1) => {
                // acc = (e1 + 2 e2) / 6; rebuild input with e2 for stage 3.
                for (a, (&e1v, &e2v)) in
                    self.acc.iter_mut().zip(self.pending.iter().zip(&self.etmp))
                {
                    *a = (e1v + 2.0 * e2v) / 6.0;
                }
                self.xtmp.copy_from_slice(&self.x);
                transfer(&self.sde, &mut self.xtmp, &self.etmp, t, mid);
                self.stage = 2;
            }
            (true, 2) => {
                for (a, &e3v) in self.acc.iter_mut().zip(&self.etmp) {
                    *a += 2.0 * e3v / 6.0;
                }
                self.xtmp.copy_from_slice(&self.x);
                transfer(&self.sde, &mut self.xtmp, &self.etmp, t, t_prev);
                self.stage = 3;
            }
            (true, 3) => {
                for (a, &e4v) in self.acc.iter_mut().zip(&self.etmp) {
                    *a += e4v / 6.0;
                }
                transfer(&self.sde, &mut self.x, &self.acc, t, t_prev);
                let e1 = std::mem::take(&mut self.pending);
                self.buf.push(t, e1);
                self.finish_step();
            }
            _ => unreachable!("pndm (warm, stage) out of range"),
        }
    }

    fn batch(&self) -> usize {
        self.b
    }

    fn take_samples(&mut self) -> Vec<f64> {
        std::mem::take(&mut self.x)
    }
}

impl PndmCursor {
    fn finish_step(&mut self) {
        self.i -= 1;
        self.stage = 0;
        self.warm = self.buf.len() < 3;
        if self.i >= 1 {
            self.pending = self.buf.checkout(self.x.len());
        }
    }
}

impl Solver for Pndm {
    fn name(&self) -> String {
        "pndm".into()
    }

    fn nfe(&self) -> usize {
        // 3 warmup steps x 4 evals + 1 eval per remaining step.
        let n = self.grid.len() - 1;
        3 * 4 + (n - 3)
    }

    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, rng: &mut Rng) {
        sample_via_cursor(self, model, x, b, rng);
    }

    fn cursor(&self, x: &[f64], b: usize, _rng: &mut Rng) -> Box<dyn StepCursor> {
        let mut buf = EpsBuffer::new(4);
        let pending = buf.checkout(x.len());
        Box::new(PndmCursor {
            sde: self.sde,
            grid: self.grid.clone(),
            x: x.to_vec(),
            e_hat: vec![0.0; x.len()],
            pending,
            buf,
            xtmp: vec![0.0; x.len()],
            etmp: vec![0.0; x.len()],
            acc: vec![0.0; x.len()],
            i: self.grid.len() - 1,
            stage: 0,
            warm: true,
            b,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::score::{Counting, GmmEps};
    use crate::solvers::tab::TabDeis;
    use crate::timegrid::{build, GridKind};
    use crate::util::prop::assert_close;

    fn model() -> GmmEps {
        GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())
    }

    #[test]
    fn ab_weights_sum_to_one() {
        for r in 0..=3 {
            let s: f64 = ab_weights(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "order {r}");
        }
    }

    #[test]
    fn ipndm1_warmup_first_step_is_ddim() {
        // With a single eval buffered, iPNDM's first step == DDIM's.
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 1);
        let m = model();
        let b = 4;
        let x0: Vec<f64> = Rng::new(1).normal_vec(b * 2);
        let mut xa = x0.clone();
        let mut xb = x0;
        Ipndm::new(&sde, &grid, 3).sample(&m, &mut xa, b, &mut Rng::new(0));
        TabDeis::new(&sde, &grid, 0).sample(&m, &mut xb, b, &mut Rng::new(0));
        // tab0 integrates the single giant [t0, T] step by quadrature while
        // the transfer uses the closed form; ~1e-7 apart on this worst case.
        assert_close(&xa, &xb, 1e-5, "ipndm first step vs ddim");
    }

    #[test]
    fn pndm_nfe_accounting() {
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 11);
        let m = model();
        let counted = Counting::new(&m);
        let p = Pndm::new(&sde, &grid);
        let mut x = Rng::new(2).normal_vec(8);
        p.sample(&counted, &mut x, 4, &mut Rng::new(0));
        assert_eq!(counted.nfe(), p.nfe());
        assert_eq!(p.nfe(), 20);
    }

    #[test]
    fn both_land_near_modes_at_n50() {
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 50);
        let m = model();
        let gmm = Gmm::ring2d(4.0, 8, 0.25);
        for solver in [&Ipndm::new(&sde, &grid, 3) as &dyn Solver, &Pndm::new(&sde, &grid)] {
            let b = 64;
            let mut x = Rng::new(4).normal_vec(b * 2);
            solver.sample(&m, &mut x, b, &mut Rng::new(0));
            let mut med: Vec<f64> = (0..b)
                .map(|i| {
                    gmm.means
                        .iter()
                        .map(|mu| {
                            ((x[i * 2] - mu[0]).powi(2) + (x[i * 2 + 1] - mu[1]).powi(2)).sqrt()
                        })
                        .fold(f64::INFINITY, f64::min)
                })
                .collect();
            med.sort_by(f64::total_cmp);
            assert!(med[b / 2] < 0.75, "{} median mode dist {}", solver.name(), med[b / 2]);
        }
    }
}
