//! PNDM (Liu et al. 2022) and the paper's improved iPNDM (App. H.2).
//!
//! Both combine a DDIM "transfer" step with classical Adams–Bashforth
//! weights on the buffered eps evaluations (Eqs. 36–40). PNDM warms up with
//! a pseudo-Runge–Kutta phase costing 4 NFE for each of its first 3 steps;
//! iPNDM replaces that with lower-order multistep formulas (Eq. 38–40) so it
//! works below 12 NFE — the paper's proposed tweak.
//!
//! Implemented for any scalar SDE through the generic DDIM transfer
//! φ(x, e, s→t) = Ψ(t,s)·x + (σ_t − Ψσ_s)·e.

use crate::diffusion::Sde;
use crate::score::EpsModel;
use crate::solvers::{fill_t, EpsBuffer, Solver};
use crate::util::rng::Rng;

/// Classical AB weights for uniform steps, newest first (Eqs. 36, 38–40).
pub fn ab_weights(order: usize) -> &'static [f64] {
    match order {
        0 => &[1.0],
        1 => &[3.0 / 2.0, -1.0 / 2.0],
        2 => &[23.0 / 12.0, -16.0 / 12.0, 5.0 / 12.0],
        3 => &[55.0 / 24.0, -59.0 / 24.0, 37.0 / 24.0, -9.0 / 24.0],
        _ => panic!("AB order up to 3"),
    }
}

/// DDIM transfer from time s to time t using eps estimate `e`.
fn transfer(sde: &Sde, x: &mut [f64], e: &[f64], s: f64, t: f64) {
    let psi = sde.psi(t, s);
    let c = sde.sigma(t) - psi * sde.sigma(s);
    for (xv, ev) in x.iter_mut().zip(e) {
        *xv = psi * *xv + c * ev;
    }
}

/// out = sum_j weights[j] * buf.eps(j), into a caller-reused buffer.
fn combine_into(out: &mut [f64], weights: &[f64], buf: &EpsBuffer) {
    out.fill(0.0);
    for (j, w) in weights.iter().enumerate() {
        for (o, &e) in out.iter_mut().zip(buf.eps(j)) {
            *o += w * e;
        }
    }
}

pub struct Ipndm {
    sde: Sde,
    grid: Vec<f64>,
    order: usize,
}

impl Ipndm {
    pub fn new(sde: &Sde, grid: &[f64], order: usize) -> Self {
        assert!((1..=3).contains(&order));
        Ipndm { sde: *sde, grid: grid.to_vec(), order }
    }
}

impl Solver for Ipndm {
    fn name(&self) -> String {
        format!("ipndm{}", self.order)
    }

    fn nfe(&self) -> usize {
        self.grid.len() - 1
    }

    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, _rng: &mut Rng) {
        let d = model.dim();
        let n = self.grid.len() - 1;
        let mut tb = Vec::new();
        let mut buf = EpsBuffer::new(self.order + 1);
        let mut e_hat = vec![0.0; b * d];
        for i in (1..=n).rev() {
            let t = self.grid[i];
            let mut eps = buf.checkout(b * d);
            model.eval(x, fill_t(&mut tb, t, b), b, &mut eps);
            buf.push(t, eps);
            let ord = self.order.min(buf.len() - 1); // warmup ramps 0,1,..,order
            combine_into(&mut e_hat, ab_weights(ord), &buf);
            transfer(&self.sde, x, &e_hat, t, self.grid[i - 1]);
        }
    }
}

pub struct Pndm {
    sde: Sde,
    grid: Vec<f64>,
}

impl Pndm {
    pub fn new(sde: &Sde, grid: &[f64]) -> Self {
        assert!(grid.len() - 1 >= 4, "PNDM needs >= 4 grid steps");
        Pndm { sde: *sde, grid: grid.to_vec() }
    }

    /// Pseudo-RK warmup step (Liu et al. 2022): 4 evals, Runge–Kutta-weighted
    /// eps fed through the DDIM transfer. `ws` buffers are reused across the
    /// three warmup steps; the returned eps at t (checked out of `buf`'s
    /// recycler by the caller) seeds the multistep buffer.
    #[allow(clippy::too_many_arguments)]
    fn prk_step(
        &self,
        model: &dyn EpsModel,
        x: &mut [f64],
        e1: &mut [f64],
        b: usize,
        t: f64,
        t_prev: f64,
        tb: &mut Vec<f64>,
        ws: &mut PrkScratch,
    ) {
        let mid = 0.5 * (t + t_prev);
        model.eval(x, fill_t(tb, t, b), b, e1);
        // xtmp is reused for all three stage states: each stage's input is
        // rebuilt from x before its transfer.
        ws.xtmp.copy_from_slice(x);
        transfer(&self.sde, &mut ws.xtmp, e1, t, mid);
        model.eval(&ws.xtmp, fill_t(tb, mid, b), b, &mut ws.etmp);
        // acc accumulates the RK-weighted eps: (e1 + 2 e2 + 2 e3 + e4) / 6.
        for (a, (&e1v, &e2v)) in ws.acc.iter_mut().zip(e1.iter().zip(&ws.etmp)) {
            *a = (e1v + 2.0 * e2v) / 6.0;
        }
        ws.xtmp.copy_from_slice(x);
        transfer(&self.sde, &mut ws.xtmp, &ws.etmp, t, mid);
        model.eval(&ws.xtmp, fill_t(tb, mid, b), b, &mut ws.etmp);
        for (a, &e3v) in ws.acc.iter_mut().zip(&ws.etmp) {
            *a += 2.0 * e3v / 6.0;
        }
        ws.xtmp.copy_from_slice(x);
        transfer(&self.sde, &mut ws.xtmp, &ws.etmp, t, t_prev);
        model.eval(&ws.xtmp, fill_t(tb, t_prev, b), b, &mut ws.etmp);
        for (a, &e4v) in ws.acc.iter_mut().zip(&ws.etmp) {
            *a += e4v / 6.0;
        }
        transfer(&self.sde, x, &ws.acc, t, t_prev);
    }
}

/// Reused stage buffers for the pseudo-RK warmup.
struct PrkScratch {
    xtmp: Vec<f64>,
    etmp: Vec<f64>,
    acc: Vec<f64>,
}

impl Solver for Pndm {
    fn name(&self) -> String {
        "pndm".into()
    }

    fn nfe(&self) -> usize {
        // 3 warmup steps x 4 evals + 1 eval per remaining step.
        let n = self.grid.len() - 1;
        3 * 4 + (n - 3)
    }

    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, _rng: &mut Rng) {
        let d = model.dim();
        let n = self.grid.len() - 1;
        let mut tb = Vec::new();
        let mut buf = EpsBuffer::new(4);
        let mut e_hat = vec![0.0; b * d];
        let mut ws = PrkScratch {
            xtmp: vec![0.0; b * d],
            etmp: vec![0.0; b * d],
            acc: vec![0.0; b * d],
        };
        for i in (1..=n).rev() {
            let (t, t_prev) = (self.grid[i], self.grid[i - 1]);
            if buf.len() < 3 {
                let mut e1 = buf.checkout(b * d);
                self.prk_step(model, x, &mut e1, b, t, t_prev, &mut tb, &mut ws);
                buf.push(t, e1);
            } else {
                let mut eps = buf.checkout(b * d);
                model.eval(x, fill_t(&mut tb, t, b), b, &mut eps);
                buf.push(t, eps);
                combine_into(&mut e_hat, ab_weights(3), &buf);
                transfer(&self.sde, x, &e_hat, t, t_prev);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::score::{Counting, GmmEps};
    use crate::solvers::tab::TabDeis;
    use crate::timegrid::{build, GridKind};
    use crate::util::prop::assert_close;

    fn model() -> GmmEps {
        GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())
    }

    #[test]
    fn ab_weights_sum_to_one() {
        for r in 0..=3 {
            let s: f64 = ab_weights(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "order {r}");
        }
    }

    #[test]
    fn ipndm1_warmup_first_step_is_ddim() {
        // With a single eval buffered, iPNDM's first step == DDIM's.
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 1);
        let m = model();
        let b = 4;
        let x0: Vec<f64> = Rng::new(1).normal_vec(b * 2);
        let mut xa = x0.clone();
        let mut xb = x0;
        Ipndm::new(&sde, &grid, 3).sample(&m, &mut xa, b, &mut Rng::new(0));
        TabDeis::new(&sde, &grid, 0).sample(&m, &mut xb, b, &mut Rng::new(0));
        // tab0 integrates the single giant [t0, T] step by quadrature while
        // the transfer uses the closed form; ~1e-7 apart on this worst case.
        assert_close(&xa, &xb, 1e-5, "ipndm first step vs ddim");
    }

    #[test]
    fn pndm_nfe_accounting() {
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 11);
        let m = model();
        let counted = Counting::new(&m);
        let p = Pndm::new(&sde, &grid);
        let mut x = Rng::new(2).normal_vec(8);
        p.sample(&counted, &mut x, 4, &mut Rng::new(0));
        assert_eq!(counted.nfe(), p.nfe());
        assert_eq!(p.nfe(), 20);
    }

    #[test]
    fn both_land_near_modes_at_n50() {
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 50);
        let m = model();
        let gmm = Gmm::ring2d(4.0, 8, 0.25);
        for solver in [&Ipndm::new(&sde, &grid, 3) as &dyn Solver, &Pndm::new(&sde, &grid)] {
            let b = 64;
            let mut x = Rng::new(4).normal_vec(b * 2);
            solver.sample(&m, &mut x, b, &mut Rng::new(0));
            let mut med: Vec<f64> = (0..b)
                .map(|i| {
                    gmm.means
                        .iter()
                        .map(|mu| {
                            ((x[i * 2] - mu[0]).powi(2) + (x[i * 2 + 1] - mu[1]).powi(2)).sqrt()
                        })
                        .fold(f64::INFINITY, f64::min)
                })
                .collect();
            med.sort_by(f64::total_cmp);
            assert!(med[b / 2] < 0.75, "{} median mode dist {}", solver.name(), med[b / 2]);
        }
    }
}
