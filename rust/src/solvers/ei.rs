//! Exponential Integrator with the *score* parameterization (paper Eq. 8) —
//! the method Fig. 3a shows is WORSE than Euler: it freezes
//! s_θ(x_t, t) = −ε_θ(x_t,t)/σ(t) over the whole step, so the rapidly
//! changing 1/σ(τ) factor is mis-approximated near t → 0. Kept as a
//! first-class solver because the ablation ladder (Fig. 5 / Tab. 9) needs it.
//!
//! Step: x_{i-1} = Ψ x_i + [∫ ½Ψ(t_{i-1},τ) g²(τ) dτ] · ε_i/σ(t_i).

use crate::diffusion::Sde;
use crate::quad::Quadrature;
use crate::score::EpsModel;
use crate::solvers::{deis_combine, fill_t, Solver};
use crate::util::rng::Rng;

pub struct EiScore {
    grid: Vec<f64>,
    /// Per step (i = N..1): (psi, coef) with coef already divided by σ(t_i).
    plan: Vec<(f64, f64)>,
}

impl EiScore {
    pub fn new(sde: &Sde, grid: &[f64]) -> Self {
        let q = Quadrature::gauss(32);
        let n = grid.len() - 1;
        let mut plan = Vec::with_capacity(n);
        for i in (1..=n).rev() {
            let (t, t_prev) = (grid[i], grid[i - 1]);
            let psi = sde.psi(t_prev, t);
            // ∫_t^{t_prev} ½ Ψ(t_prev, τ) g²(τ) dτ — note σ frozen OUTSIDE.
            let integral =
                q.integrate_panels(|tau| 0.5 * sde.psi(t_prev, tau) * sde.g2(tau), t, t_prev, 8);
            plan.push((psi, integral / sde.sigma(t)));
        }
        EiScore { grid: grid.to_vec(), plan }
    }
}

impl Solver for EiScore {
    fn name(&self) -> String {
        "ei-score".into()
    }

    fn nfe(&self) -> usize {
        self.grid.len() - 1
    }

    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, _rng: &mut Rng) {
        let d = model.dim();
        let mut tb = Vec::new();
        let mut eps = vec![0.0; b * d];
        let n = self.grid.len() - 1;
        for (step, i) in (1..=n).rev().enumerate() {
            model.eval(x, fill_t(&mut tb, self.grid[i], b), b, &mut eps);
            let (psi, c) = self.plan[step];
            deis_combine(x, psi, &[c], &[&eps]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timegrid::{build, GridKind};

    #[test]
    fn coefficient_sign_removes_noise() {
        // The EI-score coefficient must be negative-ish relative to DDIM's:
        // both scale eps to REDUCE noise; check sign matches DDIM's C < 0
        // when sigma shrinks.
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 10);
        let ei = EiScore::new(&sde, &grid);
        for &(psi, c) in &ei.plan {
            assert!(psi >= 1.0, "vp psi toward t=0 grows: {psi}");
            assert!(c < 0.0, "coef should remove noise: {c}");
        }
    }

    #[test]
    fn differs_from_ddim_at_coarse_grid() {
        // The whole point of Ingredient 2: frozen sigma != integrated sigma.
        use crate::solvers::tab::TabDeis;
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 5);
        let ei = EiScore::new(&sde, &grid);
        let ddim = TabDeis::new(&sde, &grid, 0);
        let c_ei = ei.plan[4].1; // final step, t -> t0, where sigma changes fast
        let c_ddim = ddim.step_coef(4)[0];
        assert!(
            (c_ei - c_ddim).abs() > 0.01 * c_ddim.abs(),
            "EI-score should misweight the last step: {c_ei} vs {c_ddim}"
        );
    }
}
