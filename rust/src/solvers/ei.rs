//! Exponential Integrator with the *score* parameterization (paper Eq. 8) —
//! the method Fig. 3a shows is WORSE than Euler: it freezes
//! s_θ(x_t, t) = −ε_θ(x_t,t)/σ(t) over the whole step, so the rapidly
//! changing 1/σ(τ) factor is mis-approximated near t → 0. Kept as a
//! first-class solver because the ablation ladder (Fig. 5 / Tab. 9) needs it.
//!
//! Step: x_{i-1} = Ψ x_i + [∫ ½Ψ(t_{i-1},τ) g²(τ) dτ] · ε_i/σ(t_i).

use crate::diffusion::Sde;
use crate::quad::Quadrature;
use crate::score::EpsModel;
use crate::solvers::plan::{sample_via_cursor, StepCursor};
use crate::solvers::{deis_combine, Solver};
use crate::util::rng::Rng;

pub struct EiScore {
    grid: Vec<f64>,
    /// Per step (i = N..1): (psi, coef) with coef already divided by σ(t_i).
    /// Arc-shared with cursors so starting a trajectory costs O(1)
    /// allocations regardless of step count (same discipline as TabDeis).
    plan: std::sync::Arc<Vec<(f64, f64)>>,
}

impl EiScore {
    pub fn new(sde: &Sde, grid: &[f64]) -> Self {
        let q = Quadrature::gauss(32);
        let n = grid.len() - 1;
        let mut plan = Vec::with_capacity(n);
        for i in (1..=n).rev() {
            let (t, t_prev) = (grid[i], grid[i - 1]);
            let psi = sde.psi(t_prev, t);
            // ∫_t^{t_prev} ½ Ψ(t_prev, τ) g²(τ) dτ — note σ frozen OUTSIDE.
            let integral =
                q.integrate_panels(|tau| 0.5 * sde.psi(t_prev, tau) * sde.g2(tau), t, t_prev, 8);
            plan.push((psi, integral / sde.sigma(t)));
        }
        EiScore { grid: grid.to_vec(), plan: std::sync::Arc::new(plan) }
    }
}

/// Resumable EI-score step machine — one eval per step, precomputed
/// (psi, coef) combine. Single copy of the Eq. 8 update for both the solo
/// and scheduled paths.
pub struct EiCursor {
    grid: Vec<f64>,
    plan: std::sync::Arc<Vec<(f64, f64)>>,
    x: Vec<f64>,
    eps: Vec<f64>,
    step: usize,
    n: usize,
    b: usize,
}

impl StepCursor for EiCursor {
    fn pending_t(&self) -> Option<f64> {
        if self.step < self.n {
            Some(self.grid[self.n - self.step])
        } else {
            None
        }
    }

    fn io(&mut self) -> (&[f64], &mut [f64]) {
        (&self.x, &mut self.eps)
    }

    fn advance(&mut self) {
        let (psi, c) = self.plan[self.step];
        deis_combine(&mut self.x, psi, &[c], &[&self.eps]);
        self.step += 1;
    }

    fn batch(&self) -> usize {
        self.b
    }

    fn take_samples(&mut self) -> Vec<f64> {
        std::mem::take(&mut self.x)
    }
}

impl Solver for EiScore {
    fn name(&self) -> String {
        "ei-score".into()
    }

    fn nfe(&self) -> usize {
        self.grid.len() - 1
    }

    fn sample(&self, model: &dyn EpsModel, x: &mut [f64], b: usize, rng: &mut Rng) {
        sample_via_cursor(self, model, x, b, rng);
    }

    fn cursor(&self, x: &[f64], b: usize, _rng: &mut Rng) -> Box<dyn StepCursor> {
        Box::new(EiCursor {
            grid: self.grid.clone(),
            plan: self.plan.clone(),
            x: x.to_vec(),
            eps: vec![0.0; x.len()],
            step: 0,
            n: self.grid.len() - 1,
            b,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timegrid::{build, GridKind};

    #[test]
    fn coefficient_sign_removes_noise() {
        // The EI-score coefficient must be negative-ish relative to DDIM's:
        // both scale eps to REDUCE noise; check sign matches DDIM's C < 0
        // when sigma shrinks.
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 10);
        let ei = EiScore::new(&sde, &grid);
        for &(psi, c) in ei.plan.iter() {
            assert!(psi >= 1.0, "vp psi toward t=0 grows: {psi}");
            assert!(c < 0.0, "coef should remove noise: {c}");
        }
    }

    #[test]
    fn differs_from_ddim_at_coarse_grid() {
        // The whole point of Ingredient 2: frozen sigma != integrated sigma.
        use crate::solvers::tab::TabDeis;
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 5);
        let ei = EiScore::new(&sde, &grid);
        let ddim = TabDeis::new(&sde, &grid, 0);
        let c_ei = ei.plan[4].1; // final step, t -> t0, where sigma changes fast
        let c_ddim = ddim.step_coef(4)[0];
        assert!(
            (c_ei - c_ddim).abs() > 0.01 * c_ddim.abs(),
            "EI-score should misweight the last step: {c_ei} vs {c_ddim}"
        );
    }
}
