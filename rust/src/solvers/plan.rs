//! Resumable step machines: the `EvalPlan`/`StepCursor` layer.
//!
//! A [`StepCursor`] is a solver trajectory turned inside out: instead of the
//! solver calling `EpsModel::eval` itself, the cursor *yields* one pending
//! ε-evaluation at a time — (scalar t, input states, eps destination) — and
//! advances its internal state machine when the caller reports the eval
//! done. That inversion is what lets the coordinator's scheduler collect
//! pending evals from every in-flight trajectory, group them by (model, t),
//! and dispatch one merged network call per group: the per-step score
//! evaluation is the dominant cost at low NFE (paper §3), so amortizing it
//! across concurrent clients is the whole serving win.
//!
//! Two invariants make scheduled integration *bit-identical* to solo
//! integration:
//!
//! 1. `Solver::sample` for every cursor-capable solver is implemented by
//!    driving its own cursor ([`drive`]) — there is exactly one copy of the
//!    step math, so the two paths cannot drift.
//! 2. Every eval a cursor yields broadcasts a single scalar t over its rows
//!    (this is what `fill_t` always did), so a merged batch is uniform-t and
//!    takes the native engine's shared-embedding fast path; and every model
//!    backend computes rows independently, so a row's eps does not depend on
//!    which other rows share the batch (`rust/tests/scheduler.rs` pins the
//!    resulting sample-level parity).
//!
//! Cursor-capable solvers: tAB-DEIS (incl. DDIM), ρAB-DEIS, DPM-Solver-1/2/3,
//! PNDM/iPNDM, Euler (both params). The adaptive RK45, the fixed-stage ρRK
//! schemes, the s-param EI baseline, and the stochastic samplers keep their
//! blocking `sample` only (`Solver::cursor` returns `None`) and are run
//! whole-trajectory by the scheduler's fallback path.

use crate::score::EpsModel;
use crate::solvers::{fill_t, Solver};

/// A solver trajectory paused at an ε-evaluation boundary.
///
/// Protocol: while [`pending_t`](Self::pending_t) is `Some(t)`, evaluate the
/// model at scalar time `t` on [`io`](Self::io)'s input rows, write eps into
/// `io`'s destination, then call [`advance`](Self::advance). When it turns
/// `None`, the integration is complete and [`take_samples`](Self::take_samples)
/// yields the final states.
pub trait StepCursor: Send {
    /// Scalar time of the pending ε-evaluation (solver steps always
    /// broadcast one t over the whole batch), or `None` when the trajectory
    /// has reached t_0.
    fn pending_t(&self) -> Option<f64>;

    /// (input states, eps destination) for the pending eval, both
    /// `[batch * dim]`. Only valid while `pending_t()` is `Some`.
    fn io(&mut self) -> (&[f64], &mut [f64]);

    /// Consume the eps written into `io().1` and step the state machine to
    /// the next pending eval (or to completion).
    fn advance(&mut self);

    /// Rows in this trajectory's batch.
    fn batch(&self) -> usize;

    /// Final samples `[batch * dim]`; valid once `pending_t()` is `None`.
    /// Leaves the cursor drained.
    fn take_samples(&mut self) -> Vec<f64>;
}

/// Drive a cursor to completion against one model — the solo (unscheduled)
/// path. `Solver::sample` of every cursor-capable solver routes through
/// here, so solo and scheduled integration share the same step math.
pub fn drive(cursor: &mut dyn StepCursor, model: &dyn EpsModel) {
    let b = cursor.batch();
    let mut tb = Vec::new();
    while let Some(t) = cursor.pending_t() {
        fill_t(&mut tb, t, b);
        let (x, out) = cursor.io();
        model.eval(x, &tb, b, out);
        cursor.advance();
    }
}

/// Shared `Solver::sample` implementation for cursor-capable solvers.
pub(crate) fn sample_via_cursor(
    solver: &dyn Solver,
    model: &dyn EpsModel,
    x: &mut [f64],
    b: usize,
) {
    let mut cursor = solver.cursor(x, b).expect("solver advertises cursor support");
    drive(cursor.as_mut(), model);
    x.copy_from_slice(&cursor.take_samples());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diffusion::Sde;
    use crate::gmm::Gmm;
    use crate::score::{Counting, GmmEps};
    use crate::solvers::{self, SolverKind};
    use crate::timegrid::{build, GridKind};
    use crate::util::rng::Rng;

    fn model() -> GmmEps {
        GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())
    }

    /// Manually driving a cursor must reproduce `Solver::sample` exactly,
    /// for every cursor-capable solver kind.
    #[test]
    fn cursor_drive_matches_sample_bit_exact() {
        let sde = Sde::vp();
        let m = model();
        let b = 6;
        let kinds = [
            SolverKind::Euler,
            SolverKind::EulerScore,
            SolverKind::Tab(0),
            SolverKind::Tab(3),
            SolverKind::RhoAb(2),
            SolverKind::Dpm(1),
            SolverKind::Dpm(2),
            SolverKind::Dpm(3),
            SolverKind::Ipndm(3),
            SolverKind::Pndm,
        ];
        for kind in kinds {
            let steps = kind.steps_for_nfe(16).max(5);
            let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, steps);
            let solver = solvers::build(kind, &sde, &grid);
            let x0: Vec<f64> = Rng::new(17).normal_vec(b * 2);

            let mut xa = x0.clone();
            solver.sample(&m, &mut xa, b, &mut Rng::new(0));

            let mut cursor = solver.cursor(&x0, b).expect("cursor-capable");
            drive(cursor.as_mut(), &m);
            let xb = cursor.take_samples();
            assert_eq!(xa, xb, "{} cursor vs sample", solver.name());
        }
    }

    /// The cursor spends exactly the solver's advertised NFE.
    #[test]
    fn cursor_nfe_matches_solver_nfe() {
        let sde = Sde::vp();
        let m = model();
        let counted = Counting::new(&m);
        for kind in [SolverKind::Tab(3), SolverKind::Dpm(3), SolverKind::Pndm] {
            let steps = kind.steps_for_nfe(20).max(5);
            let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, steps);
            let solver = solvers::build(kind, &sde, &grid);
            let x0: Vec<f64> = Rng::new(3).normal_vec(8);
            counted.reset();
            let mut cursor = solver.cursor(&x0, 4).expect("cursor-capable");
            drive(cursor.as_mut(), &counted);
            assert_eq!(counted.nfe(), solver.nfe(), "{}", solver.name());
        }
    }

    /// Non-resumable solvers advertise it by returning None.
    #[test]
    fn blocking_solvers_have_no_cursor() {
        let sde = Sde::vp();
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 8);
        for kind in [
            SolverKind::EiScore,
            SolverKind::RhoHeun,
            SolverKind::Rk45,
            SolverKind::EulerMaruyama,
            SolverKind::ADdim,
        ] {
            let solver = solvers::build(kind, &sde, &grid);
            let x0 = vec![0.0; 8];
            assert!(solver.cursor(&x0, 4).is_none(), "{}", solver.name());
        }
    }
}
