//! Resumable step machines: the `EvalPlan`/`StepCursor` layer.
//!
//! A [`StepCursor`] is a solver trajectory turned inside out: instead of the
//! solver calling `EpsModel::eval` itself, the cursor *yields* one pending
//! ε-evaluation at a time — (scalar t, input states, eps destination) — and
//! advances its internal state machine when the caller reports the eval
//! done. That inversion is what lets the coordinator's scheduler collect
//! pending evals from every in-flight trajectory, group them by (model, t),
//! and dispatch one merged network call per group: the per-step score
//! evaluation is the dominant cost at low NFE (paper §3), so amortizing it
//! across concurrent clients is the whole serving win.
//!
//! Three invariants make scheduled integration *bit-identical* to solo
//! integration:
//!
//! 1. `Solver::sample` for every solver is implemented by driving its own
//!    cursor ([`drive`]) — there is exactly one copy of the step math, so
//!    the two paths cannot drift.
//! 2. Every eval a cursor yields broadcasts a single scalar t over its rows
//!    (this is what `fill_t` always did), so a merged batch is uniform-t and
//!    takes the native engine's shared-embedding fast path; and every model
//!    backend computes rows independently, so a row's eps does not depend on
//!    which other rows share the batch (`rust/tests/scheduler.rs` pins the
//!    resulting sample-level parity).
//! 3. Stochastic cursors own their `Rng` (cloned from the stream handed to
//!    [`Solver::cursor`]) and draw noise only inside `advance`, so the noise
//!    a trajectory receives is independent of how its evals were co-batched.
//!
//! Cursorization is universal: tAB-DEIS (incl. DDIM), ρAB-DEIS,
//! DPM-Solver-1/2/3, PNDM/iPNDM, Euler (both params), the s-param EI
//! baseline, the fixed-stage ρRK schemes, the adaptive RK45 (its embedded
//! error estimate and step-size controller run between yields), and the
//! stochastic samplers (Euler–Maruyama, sDDIM, A-DDIM). There is no
//! blocking whole-trajectory fallback anywhere in the serving stack.
//!
//! The heavy per-(sde, grid, solver) coefficient precomputation these
//! cursors consume is shared across requests through
//! [`solvers::cache::PlanCache`](crate::solvers::cache::PlanCache).
//!
//! # Cursor invariants the scheduler's off-lock checkout relies on
//!
//! The coordinator's workers take a flight's cursor *out* of the shared
//! scheduler state and run scatter + [`advance`](StepCursor::advance)
//! without any lock held. That is sound because of three contractual
//! properties every cursor implementation upholds:
//!
//! 1. **Self-containment.** A cursor owns every piece of per-trajectory
//!    state — the state matrix, eps history, adaptive-controller state,
//!    and (for stochastic solvers) the noise `Rng`. The shared plan behind
//!    it (`Arc<SolverPlan>`: grid + coefficients) is immutable. Advancing a
//!    cursor therefore needs no synchronization with anything else.
//! 2. **`pending_t` is stable between advances.** Only
//!    [`advance`](StepCursor::advance) may change the pending eval; while a
//!    flight sits in a scheduler slot its `(model, pending_t)` is frozen,
//!    which is what lets the scheduler index flights by that key and trust
//!    the index until the flight is checked out.
//! 3. **`io` is valid exactly while pending.** The (input, eps
//!    destination) buffers stay put between `pending_t()` turning `Some`
//!    and the matching `advance`, so a worker may gather inputs, run the
//!    merged eval, and scatter results with no cursor interaction in
//!    between.

use crate::score::EpsModel;
use crate::solvers::{fill_t, Solver};
use crate::util::rng::Rng;

/// A solver trajectory paused at an ε-evaluation boundary.
///
/// Protocol: while [`pending_t`](Self::pending_t) is `Some(t)`, evaluate the
/// model at scalar time `t` on [`io`](Self::io)'s input rows, write eps into
/// `io`'s destination, then call [`advance`](Self::advance). When it turns
/// `None`, the integration is complete and [`take_samples`](Self::take_samples)
/// yields the final states.
pub trait StepCursor: Send {
    /// Scalar time of the pending ε-evaluation (solver steps always
    /// broadcast one t over the whole batch), or `None` when the trajectory
    /// has reached t_0.
    fn pending_t(&self) -> Option<f64>;

    /// (input states, eps destination) for the pending eval, both
    /// `[batch * dim]`. Only valid while `pending_t()` is `Some`.
    fn io(&mut self) -> (&[f64], &mut [f64]);

    /// Consume the eps written into `io().1` and step the state machine to
    /// the next pending eval (or to completion).
    fn advance(&mut self);

    /// Rows in this trajectory's batch.
    fn batch(&self) -> usize;

    /// Final samples `[batch * dim]`; valid once `pending_t()` is `None`.
    /// Leaves the cursor drained.
    fn take_samples(&mut self) -> Vec<f64>;

    /// Hand back the cursor's owned noise stream, if it has one (stochastic
    /// cursors only), leaving the cursor drained. [`sample_via_cursor`] uses
    /// this to re-sync the caller's `&mut Rng` after a solo run, preserving
    /// the pre-cursor contract that consecutive `sample` calls sharing one
    /// `Rng` draw fresh noise each time.
    fn take_rng(&mut self) -> Option<Rng> {
        None
    }
}

/// Drive a cursor to completion against one model — the solo (unscheduled)
/// path. `Solver::sample` of every cursor-capable solver routes through
/// here, so solo and scheduled integration share the same step math.
pub fn drive(cursor: &mut dyn StepCursor, model: &dyn EpsModel) {
    let b = cursor.batch();
    let mut tb = Vec::new();
    while let Some(t) = cursor.pending_t() {
        fill_t(&mut tb, t, b);
        let (x, out) = cursor.io();
        model.eval(x, &tb, b, out);
        cursor.advance();
    }
}

/// Shared `Solver::sample` implementation: every solver routes through its
/// cursor. `rng` feeds the cursor's noise stream (stochastic solvers clone
/// it; deterministic solvers ignore it); after the run the caller's `rng`
/// is re-synced from the cursor, so stochastic `sample` consumes the stream
/// exactly as the pre-cursor blocking loops did.
pub(crate) fn sample_via_cursor(
    solver: &dyn Solver,
    model: &dyn EpsModel,
    x: &mut [f64],
    b: usize,
    rng: &mut Rng,
) {
    let mut cursor = solver.cursor(x, b, rng);
    drive(cursor.as_mut(), model);
    if let Some(consumed) = cursor.take_rng() {
        *rng = consumed;
    }
    x.copy_from_slice(&cursor.take_samples());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diffusion::Sde;
    use crate::gmm::Gmm;
    use crate::score::{Counting, GmmEps};
    use crate::solvers::{self, SolverKind};
    use crate::timegrid::{build, GridKind};
    use crate::util::rng::Rng;

    fn model() -> GmmEps {
        GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())
    }

    /// Every solver kind, deterministic and stochastic alike.
    fn all_kinds() -> Vec<SolverKind> {
        use SolverKind::*;
        vec![
            Euler,
            EulerScore,
            EiScore,
            Tab(0),
            Tab(3),
            RhoAb(2),
            RhoMidpoint,
            RhoHeun,
            RhoKutta3,
            RhoRk4,
            Rk45,
            Pndm,
            Ipndm(3),
            Dpm(1),
            Dpm(2),
            Dpm(3),
            EulerMaruyama,
            StochDdim,
            ADdim,
        ]
    }

    /// Manually driving a cursor must reproduce `Solver::sample` exactly,
    /// for EVERY solver kind — including the stochastic samplers, whose
    /// cursors clone the seeded `Rng` and must replay the same noise stream.
    #[test]
    fn cursor_drive_matches_sample_bit_exact() {
        let sde = Sde::vp();
        let m = model();
        let b = 6;
        for kind in all_kinds() {
            let steps = kind.steps_for_nfe(16).max(5);
            let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, steps);
            let solver = solvers::build(kind, &sde, &grid);
            let x0: Vec<f64> = Rng::new(17).normal_vec(b * 2);

            let mut xa = x0.clone();
            solver.sample(&m, &mut xa, b, &mut Rng::new(9));

            let mut cursor = solver.cursor(&x0, b, &mut Rng::new(9));
            drive(cursor.as_mut(), &m);
            let xb = cursor.take_samples();
            assert_eq!(xa, xb, "{} cursor vs sample", solver.name());
        }
    }

    /// Cursorization is universal: every kind yields a live cursor that
    /// integrates to finite samples of the right shape.
    #[test]
    fn every_solver_kind_yields_a_cursor() {
        let sde = Sde::vp();
        let m = model();
        let b = 4;
        for kind in all_kinds() {
            let steps = kind.steps_for_nfe(12).max(5);
            let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, steps);
            let solver = solvers::build(kind, &sde, &grid);
            let x0: Vec<f64> = Rng::new(23).normal_vec(b * 2);
            let mut cursor = solver.cursor(&x0, b, &mut Rng::new(1));
            assert!(cursor.pending_t().is_some(), "{} starts pending", solver.name());
            assert_eq!(cursor.batch(), b);
            drive(cursor.as_mut(), &m);
            let out = cursor.take_samples();
            assert_eq!(out.len(), x0.len(), "{}", solver.name());
            assert!(out.iter().all(|v| v.is_finite()), "{} diverged", solver.name());
        }
    }

    /// The cursor spends exactly the solver's advertised NFE.
    #[test]
    fn cursor_nfe_matches_solver_nfe() {
        let sde = Sde::vp();
        let m = model();
        let counted = Counting::new(&m);
        for kind in [
            SolverKind::Tab(3),
            SolverKind::Dpm(3),
            SolverKind::Pndm,
            SolverKind::RhoHeun,
            SolverKind::EiScore,
            SolverKind::EulerMaruyama,
            SolverKind::ADdim,
        ] {
            let steps = kind.steps_for_nfe(20).max(5);
            let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, steps);
            let solver = solvers::build(kind, &sde, &grid);
            let x0: Vec<f64> = Rng::new(3).normal_vec(8);
            counted.reset();
            let mut cursor = solver.cursor(&x0, 4, &mut Rng::new(5));
            drive(cursor.as_mut(), &counted);
            assert_eq!(counted.nfe(), solver.nfe(), "{}", solver.name());
        }
    }
}
