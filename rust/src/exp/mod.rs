//! Experiment harness shared by examples/ and rust/benches/: dataset ground
//! truth, model loading by name, solver-at-NFE runs, and quality rows.
//! Every table/figure regenerator is a thin wrapper over this module
//! (DESIGN.md §4 maps experiment ids to bench binaries).

pub mod datasets;

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{ModelRegistry, SampleRequest};
use crate::diffusion::Sde;
use crate::gmm::Gmm;
use crate::metrics;
use crate::runtime::Runtime;
use crate::score::{pjrt::PjrtEps, Counting, EpsModel, GmmEps, NativeMlp, Precision};
use crate::solvers::{self, SolverKind};
use crate::timegrid::{self, GridKind};
use crate::util::rng::Rng;

/// Build the standard serving registry. Backend per name:
///   <ds>            PJRT artifact (the serving path)
///   <ds>_native     rust-native MLP from weights json
///   gmm2d_oracle    analytic GMM in rust (exact score)
///   gmm2d_exact     analytic GMM via PJRT artifact
pub fn default_registry(names: &[String]) -> Result<ModelRegistry> {
    default_registry_with(names, Precision::F64)
}

/// [`default_registry`] plus precision: with `Precision::F32`, every
/// `*_native` model additionally gets an f32 engine registered under
/// `<name>@f32` (the submit-time dtype routing target — see
/// [`crate::coordinator::F32_SUFFIX`]). Only the native MLP has an f32
/// engine; analytic oracles are exact-math reference models and PJRT
/// executables have their precision baked in at compile time, so their f32
/// requests are refused at submit with a clear error instead of silently
/// serving a different numeric class.
pub fn default_registry_with(names: &[String], precision: Precision) -> Result<ModelRegistry> {
    let mut reg = ModelRegistry::new();
    for name in names {
        match name.as_str() {
            "gmm2d_oracle" => {
                reg.insert(name, Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())));
            }
            n if n.ends_with("_native") => {
                let base = n.trim_end_matches("_native");
                let rt = Runtime::global();
                let path = rt.artifacts_dir().join(format!("weights_{base}.json"));
                let path = path.to_string_lossy();
                reg.insert(n, Arc::new(NativeMlp::load(&path)?));
                if precision == Precision::F32 {
                    let f32_name = format!("{n}{}", crate::coordinator::F32_SUFFIX);
                    reg.insert(&f32_name, Arc::new(NativeMlp::load_with(&path, Precision::F32)?));
                }
            }
            "gmm2d_exact" => {
                let rt = Runtime::global();
                reg.insert(name, Arc::new(PjrtEps::load(rt, "gmm2d_exact", &[16, 256, 1024])?));
            }
            n => {
                let rt = Runtime::global();
                let batches: &[usize] =
                    if n.starts_with("gmm2d") { &[16, 64, 256, 1024] } else { &[16, 256] };
                reg.insert(n, Arc::new(PjrtEps::load(rt, n, batches)
                    .with_context(|| format!("loading model '{n}'"))?));
            }
        }
    }
    Ok(reg)
}

/// Resolve a model backend by name for offline sweeps:
///   "<ds>"         rust-native MLP (fast; used for the big tables)
///   "gmm2d_oracle" exact analytic score
/// PJRT variants are loaded by the serving paths (main.rs / serve_bench).
pub fn sweep_model(name: &str) -> Box<dyn EpsModel> {
    match name {
        "gmm2d_oracle" | "toy1d_oracle" | "gmm2d_sharp_oracle" => {
            let gmm = match name {
                "toy1d_oracle" => Gmm::new(vec![vec![0.0]], 0.05),
                "gmm2d_sharp_oracle" => Gmm::ring2d(4.0, 8, 0.02),
                _ => Gmm::ring2d(4.0, 8, 0.25),
            };
            Box::new(GmmEps::new(gmm, Sde::vp()))
        }
        "gmm2d_oracle_ve" => Box::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::ve())),
        ds => Box::new(
            NativeMlp::load(&format!("artifacts/weights_{ds}.json")).unwrap_or_else(|e| {
                panic!("weights for '{ds}' missing — run `make artifacts` ({e:#})")
            }),
        ),
    }
}

/// One sampling run: prior draw -> solver at the given NFE budget -> samples.
/// Returns (samples, actual NFE spent).
#[allow(clippy::too_many_arguments)]
pub fn run_solver(
    model: &dyn EpsModel,
    sde: &Sde,
    kind: SolverKind,
    grid_kind: GridKind,
    t0: f64,
    nfe: usize,
    n: usize,
    seed: u64,
) -> (Vec<f64>, usize) {
    let steps = kind.steps_for_nfe(nfe);
    let grid = timegrid::build(grid_kind, sde, t0, 1.0, steps);
    let solver = solvers::build(kind, sde, &grid);
    let counted = Counting::new(model);
    let d = model.dim();
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0; n * d];
    let prior = sde.prior_std(1.0);
    for v in x.iter_mut() {
        *v = prior * rng.normal();
    }
    let mut srng = Rng::new(seed ^ 0xD1F_F051);
    solver.sample(&counted, &mut x, n, &mut srng);
    (x, counted.nfe())
}

/// Quality of a sample set vs dataset ground truth.
#[derive(Clone, Copy, Debug)]
pub struct Quality {
    /// Sliced Wasserstein x1000 — the primary FID-substitute.
    pub swd1000: f64,
    pub mmd1000: f64,
    pub energy: f64,
}

pub struct QualityEval {
    truth: Vec<f64>,
    /// Disjoint second truth draw for the finite-sample SWD baseline.
    truth_b: Vec<f64>,
    dim: usize,
    /// Cache of same-distribution SWD^2 floor per generated-sample count.
    floor: std::sync::Mutex<std::collections::HashMap<usize, f64>>,
}

impl QualityEval {
    /// Ground truth for a dataset name ("gmm2d", "spiral2d", "img8", "toy1d").
    pub fn new(dataset: &str, n_truth: usize) -> QualityEval {
        let mut rng = Rng::new(0xDA7A);
        let (truth, dim) = datasets::sample(dataset, n_truth, &mut rng);
        let (truth_b, _) = datasets::sample(dataset, n_truth, &mut rng);
        QualityEval { truth, truth_b, dim, floor: Default::default() }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Finite-sample SWD between n exact samples and the reference — the
    /// same-distribution floor that would otherwise dominate high-NFE cells.
    fn swd_floor(&self, n: usize) -> f64 {
        let key = n.min(self.truth_b.len() / self.dim);
        if let Some(&f) = self.floor.lock().unwrap().get(&key) {
            return f;
        }
        let mut rng = Rng::new(0xF100);
        let probe = &self.truth_b[..key * self.dim];
        let f = metrics::sliced_wasserstein(probe, &self.truth, self.dim, 96, &mut rng);
        self.floor.lock().unwrap().insert(key, f);
        f
    }

    pub fn score(&self, samples: &[f64]) -> Quality {
        let mut rng = Rng::new(0x5EED);
        let raw = metrics::sliced_wasserstein(samples, &self.truth, self.dim, 96, &mut rng);
        let floor = self.swd_floor(samples.len() / self.dim);
        // Debias in squared space (independent error contributions add).
        let swd = (raw * raw - floor * floor).max(0.0).sqrt();
        Quality {
            swd1000: 1000.0 * swd,
            mmd1000: 1000.0 * metrics::mmd2_rbf(samples, &self.truth, self.dim, 384, &mut rng),
            energy: metrics::energy_distance(samples, &self.truth, self.dim, 384, &mut rng),
        }
    }
}

/// Convenience: SampleRequest matching a sweep row (used by serving examples).
pub fn request_for(model: &str, kind: SolverKind, nfe: usize, n: usize, seed: u64)
    -> SampleRequest {
    let mut req = SampleRequest::new(model, kind, nfe, n);
    req.seed = seed;
    req
}

/// Fixed-width table printing in the paper's layout.
pub fn print_table(title: &str, header: &[String], rows: &[(String, Vec<f64>)]) {
    println!("\n=== {title} ===");
    print!("{:<12}", "");
    for h in header {
        print!("{h:>12}");
    }
    println!();
    for (name, vals) in rows {
        print!("{name:<12}");
        for v in vals {
            if v.is_nan() {
                print!("{:>12}", "-");
            } else {
                print!("{v:>12.2}");
            }
        }
        println!();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_solver_respects_nfe_budget() {
        let model = sweep_model("gmm2d_oracle");
        let sde = Sde::vp();
        for kind in [SolverKind::Tab(3), SolverKind::RhoHeun, SolverKind::RhoRk4] {
            let (x, nfe) = run_solver(&*model, &sde, kind, GridKind::Quadratic, 1e-3, 12, 8, 1);
            assert_eq!(x.len(), 16);
            assert!(nfe <= 12, "{:?} spent {nfe} > 12", kind);
            assert!(nfe >= 12 - 3, "{:?} spent only {nfe}", kind);
        }
    }

    #[test]
    fn quality_improves_with_nfe() {
        let model = sweep_model("gmm2d_oracle");
        let sde = Sde::vp();
        let eval = QualityEval::new("gmm2d", 4000);
        // Energy distance: unbiased, so it discriminates even below the
        // (debiased-to-zero) SWD floor.
        let q = |nfe: usize| {
            let (x, _) =
                run_solver(&*model, &sde, SolverKind::Tab(3), GridKind::Quadratic, 1e-3, nfe,
                    1500, 3);
            eval.score(&x).energy
        };
        let (coarse, fine) = (q(3), q(40));
        assert!(fine < coarse, "energy at nfe40 ({fine}) should beat nfe3 ({coarse})");
    }
}
