//! Rust ports of the synthetic datasets (python/compile/datasets.py) for
//! ground-truth metric evaluation. Same *distributions*, independent RNG —
//! metrics only compare distributions, so stream identity is not required
//! (the per-sample parity path goes through the GMM, which IS identical).

use crate::gmm::Gmm;
use crate::util::rng::Rng;

/// Draw n samples of the named dataset; returns (row-major data, dim).
pub fn sample(name: &str, n: usize, rng: &mut Rng) -> (Vec<f64>, usize) {
    match name {
        "gmm2d" => (Gmm::ring2d(4.0, 8, 0.25).sample(rng, n), 2),
        // Manifold-like variant: near-point modes make the score stiff as
        // t -> 0 (the regime the paper's image experiments live in).
        "gmm2d_sharp" => (Gmm::ring2d(4.0, 8, 0.02).sample(rng, n), 2),
        "toy1d" => (Gmm::new(vec![vec![0.0]], 0.05).sample(rng, n), 1),
        "spiral2d" => (spiral2d(rng, n), 2),
        "img8" => (img8(rng, n), 64),
        other => panic!("unknown dataset '{other}'"),
    }
}

/// Two-arm Archimedean spiral, radius in [0.5, 4], radial noise 0.15.
fn spiral2d(rng: &mut Rng, n: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(n * 2);
    for _ in 0..n {
        let u = rng.uniform();
        let arm = if rng.uniform() < 0.5 { 0.0 } else { std::f64::consts::PI };
        let theta = 2.0 * 2.0 * std::f64::consts::PI * u.sqrt() + arm;
        let r = 0.5 + 3.5 * u.sqrt();
        out.push(r * theta.cos() + 0.15 * rng.normal());
        out.push(r * theta.sin() + 0.15 * rng.normal());
    }
    out
}

/// 8x8 synthetic "images": gradient background x bright bars + pixel noise.
fn img8(rng: &mut Rng, n: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(n * 64);
    for _ in 0..n {
        let row = rng.below(8);
        let col = rng.below(8);
        let gsign = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
        for r in 0..8 {
            let ramp = -0.5 + r as f64 / 7.0;
            for c in 0..8 {
                let mut v = gsign * ramp;
                if r == row {
                    v += 1.0;
                }
                if c == col {
                    v += 1.0;
                }
                out.push(v + 0.1 * rng.normal());
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let mut rng = Rng::new(1);
        for (name, dim) in [("gmm2d", 2), ("toy1d", 1), ("spiral2d", 2), ("img8", 64)] {
            let (x, d) = sample(name, 100, &mut rng);
            assert_eq!(d, dim);
            assert_eq!(x.len(), 100 * dim);
            assert!(x.iter().all(|v| v.is_finite() && v.abs() < 20.0), "{name}");
        }
    }

    #[test]
    fn spiral_radius_band() {
        let mut rng = Rng::new(2);
        let (x, _) = sample("spiral2d", 2000, &mut rng);
        let mut inside = 0;
        for i in 0..2000 {
            let r = (x[2 * i].powi(2) + x[2 * i + 1].powi(2)).sqrt();
            if (0.1..=4.8).contains(&r) {
                inside += 1;
            }
        }
        assert!(inside > 1900, "{inside}");
    }

    #[test]
    fn img8_bar_structure() {
        // Each image's brightest row/col should exceed the background.
        let mut rng = Rng::new(3);
        let (x, _) = sample("img8", 50, &mut rng);
        for i in 0..50 {
            let img = &x[i * 64..(i + 1) * 64];
            let max = img.iter().cloned().fold(f64::MIN, f64::max);
            assert!(max > 0.8, "image {i} lacks a bright bar (max {max})");
        }
    }
}
