//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the L3
//! hot path. Adapts /opt/xla-example/load_hlo — HLO *text* is the
//! interchange format (xla_extension 0.5.1 rejects jax>=0.5 serialized
//! protos with 64-bit instruction ids; the text parser reassigns ids).
//!
//! Threading: the `xla` crate's client/executable types are `!Send` (Rc +
//! raw pointers), so a dedicated executor thread owns every xla object and
//! the rest of the process talks to it over channels. Execution is thereby
//! serialized at the dispatch level — fine on CPU, where PJRT parallelizes
//! *inside* a single execute call via its own thread pool; the coordinator's
//! dynamic batching keeps that one stream saturated.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{anyhow, bail, Result};

// Without the `pjrt` feature (the default — see Cargo.toml) the `xla` crate
// is replaced by an in-tree stub with the same surface: the runtime
// initializes, but artifact loads return a "backend unavailable" error that
// callers handle by falling back to native/analytic models.
#[cfg(not(feature = "pjrt"))]
#[path = "xla_stub.rs"]
mod xla;

enum Cmd {
    Load { path: PathBuf, reply: Sender<Result<usize>> },
    Run { id: usize, x: Vec<f32>, dims: [usize; 2], t: Vec<f32>, reply: Sender<Result<Vec<Vec<f32>>>> },
    Platform { reply: Sender<String> },
}

/// Process-wide runtime handle (cheap to clone through `Arc`).
pub struct Runtime {
    tx: Mutex<Sender<Cmd>>,
    cache: Mutex<HashMap<(PathBuf, usize), Arc<EpsExecutable>>>,
    artifacts_dir: PathBuf,
}

static GLOBAL: OnceLock<Runtime> = OnceLock::new();

impl Runtime {
    pub fn new(artifacts_dir: &str) -> Result<Runtime> {
        let (tx, rx) = channel();
        let (ready_tx, ready_rx) = channel();
        std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || executor_thread(rx, ready_tx))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("pjrt executor died during init"))?
            .map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Runtime {
            tx: Mutex::new(tx),
            cache: Mutex::new(HashMap::new()),
            artifacts_dir: PathBuf::from(artifacts_dir),
        })
    }

    /// Global runtime rooted at $DEIS_ARTIFACTS (default "artifacts").
    pub fn global() -> &'static Runtime {
        GLOBAL.get_or_init(|| {
            let dir = std::env::var("DEIS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
            Runtime::new(&dir).expect("PJRT CPU client init")
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    pub fn platform(&self) -> String {
        let (reply, rx) = channel();
        self.send(Cmd::Platform { reply });
        rx.recv().unwrap_or_else(|_| "dead".into())
    }

    fn send(&self, cmd: Cmd) {
        self.tx.lock().unwrap().send(cmd).expect("pjrt executor gone");
    }

    /// Load + compile an eps artifact (cached by path). `outputs` is the
    /// tuple arity (1 for eps, 2 for epsdiv).
    pub fn load_eps(&self, file: &str, batch: usize, dim: usize, outputs: usize)
        -> Result<Arc<EpsExecutable>> {
        let path = self.artifacts_dir.join(file);
        let key = (path.clone(), outputs);
        if let Some(hit) = self.cache.lock().unwrap().get(&key) {
            return Ok(hit.clone());
        }
        let (reply, rx) = channel();
        self.send(Cmd::Load { path: path.clone(), reply });
        let id = rx.recv().map_err(|_| anyhow!("pjrt executor gone"))??;
        let wrapped = Arc::new(EpsExecutable {
            rt_tx: Mutex::new(self.tx.lock().unwrap().clone()),
            id,
            batch,
            dim,
            outputs,
            file: file.to_string(),
        });
        self.cache.lock().unwrap().insert(key, wrapped.clone());
        Ok(wrapped)
    }

    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

fn executor_thread(rx: Receiver<Cmd>, ready: Sender<std::result::Result<(), String>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(format!("{e:?}")));
            return;
        }
    };
    let mut exes: Vec<xla::PjRtLoadedExecutable> = Vec::new();
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Platform { reply } => {
                let _ = reply.send(client.platform_name());
            }
            Cmd::Load { path, reply } => {
                let result = (|| -> Result<usize> {
                    let pstr = path.to_string_lossy().to_string();
                    let proto = xla::HloModuleProto::from_text_file(&pstr)
                        .map_err(|e| anyhow!("parsing HLO text {pstr}: {e:?}"))?;
                    let comp = xla::XlaComputation::from_proto(&proto);
                    let exe = client
                        .compile(&comp)
                        .map_err(|e| anyhow!("compiling {pstr}: {e:?}"))?;
                    exes.push(exe);
                    Ok(exes.len() - 1)
                })();
                let _ = reply.send(result);
            }
            Cmd::Run { id, x, dims, t, reply } => {
                let result = (|| -> Result<Vec<Vec<f32>>> {
                    let exe = exes.get(id).ok_or_else(|| anyhow!("bad exe id {id}"))?;
                    let xl = xla::Literal::vec1(&x)
                        .reshape(&[dims[0] as i64, dims[1] as i64])
                        .map_err(|e| anyhow!("reshape x: {e:?}"))?;
                    let tl = xla::Literal::vec1(&t);
                    let out = exe
                        .execute::<xla::Literal>(&[xl, tl])
                        .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
                        .to_literal_sync()
                        .map_err(|e| anyhow!("to_literal: {e:?}"))?;
                    // Lowered with return_tuple=True: unwrap the tuple.
                    let parts = out.to_tuple().map_err(|e| anyhow!("tuple: {e:?}"))?;
                    parts
                        .into_iter()
                        .map(|lit| lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
                        .collect()
                })();
                let _ = reply.send(result);
            }
        }
    }
}

/// A compiled (model, batch-size) entry point: eps = f(x[B,D], t[B]).
pub struct EpsExecutable {
    /// Channel to the executor thread (std Sender is !Sync, hence the mutex).
    rt_tx: Mutex<Sender<Cmd>>,
    id: usize,
    pub batch: usize,
    pub dim: usize,
    pub outputs: usize,
    pub file: String,
}

impl EpsExecutable {
    /// Execute on exactly `self.batch` rows (f32 at the PJRT boundary).
    /// Returns `outputs` flat vectors (eps [B*D]; epsdiv adds div [B]).
    pub fn run(&self, x: &[f32], t: &[f32]) -> Result<Vec<Vec<f32>>> {
        if x.len() != self.batch * self.dim || t.len() != self.batch {
            bail!(
                "artifact {} expects x[{}x{}], t[{}]; got x[{}], t[{}]",
                self.file, self.batch, self.dim, self.batch, x.len(), t.len()
            );
        }
        let (reply, rx) = channel();
        self.rt_tx
            .lock()
            .unwrap()
            .send(Cmd::Run {
                id: self.id,
                x: x.to_vec(),
                dims: [self.batch, self.dim],
                t: t.to_vec(),
                reply,
            })
            .map_err(|_| anyhow!("pjrt executor gone"))?;
        let parts = rx.recv().map_err(|_| anyhow!("pjrt executor gone"))??;
        if parts.len() != self.outputs {
            bail!("artifact {}: expected {} outputs, got {}", self.file, self.outputs,
                parts.len());
        }
        Ok(parts)
    }

    /// f64-boundary convenience used by the solvers (math runs in f64, the
    /// network is f32 — conversion cost is measured in perf_hotpath).
    pub fn run_f64(&self, x: &[f64], t: &[f64]) -> Result<Vec<Vec<f64>>> {
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let tf: Vec<f32> = t.iter().map(|&v| v as f32).collect();
        Ok(self
            .run(&xf, &tf)?
            .into_iter()
            .map(|v| v.into_iter().map(|x| x as f64).collect())
            .collect())
    }
}

/// Resolve the best artifact batch size >= n (or the max available).
pub fn pick_batch(available: &[usize], n: usize) -> usize {
    let mut sorted = available.to_vec();
    sorted.sort_unstable();
    for &b in &sorted {
        if b >= n {
            return b;
        }
    }
    *sorted.last().expect("no batch sizes")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_batch_prefers_smallest_fit() {
        let avail = [16, 64, 256, 1024];
        assert_eq!(pick_batch(&avail, 1), 16);
        assert_eq!(pick_batch(&avail, 16), 16);
        assert_eq!(pick_batch(&avail, 17), 64);
        assert_eq!(pick_batch(&avail, 1000), 1024);
        assert_eq!(pick_batch(&avail, 5000), 1024);
    }

    // PJRT-touching tests live in rust/tests/pjrt_integration.rs (they need
    // artifacts/ built).
}
