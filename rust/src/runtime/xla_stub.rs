//! API-compatible stand-in for the `xla` crate, compiled when the `pjrt`
//! feature is off (the default: the real crate needs a vendored
//! xla_extension C++ toolchain that neither CI nor the offline registry
//! ships). The stub keeps the whole `runtime`/`score::pjrt` layer compiling
//! and lets `Runtime::global()` initialize, but every artifact load fails
//! with a clear error, so callers (benches, `default_registry`) can detect
//! the missing backend at runtime and fall back to the native / analytic
//! models. Only the surface `runtime/mod.rs` actually touches is mirrored.

pub struct Error(pub String);

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

fn unavailable() -> Error {
    Error(
        "PJRT backend unavailable: built without the `pjrt` feature (the \
         `xla` crate is not vendored in this environment); use a *_native or \
         *_oracle model instead"
            .to_string(),
    )
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub (pjrt feature disabled)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}
