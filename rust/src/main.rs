//! `deis` — CLI for the DEIS sampling service.
//!
//! Subcommands:
//!   serve   --addr 127.0.0.1:7878 --workers 4 --models gmm2d,gmm2d_exact
//!           [--precision f64|f32] [--max-batch 1024] [--max-inflight 4096]
//!           [--max-inflight-per-model 4096]
//!           [--breaker-threshold 5] [--breaker-cooldown-ms 1000]
//!           [--sched-policy oldest|edf] [--edf-age-guard-ms 250]
//!           [--max-conns 1024] [--read-timeout-ms 30000]
//!           [--write-timeout-ms 30000] [--max-line-bytes 262144]
//!           [--io-threads N]   (readiness-driven I/O threads; default
//!                               min(4, cores))
//!   router  --addr 127.0.0.1:7800 (--upstream host:port,... | --spawn-workers N)
//!           [--pool-per-worker 8] [--connect-timeout-ms 250]
//!           [--cooldown-ms 1000] [--max-conns 1024]
//!           [--read-timeout-ms 30000] [--write-timeout-ms 30000]
//!           [--max-line-bytes 262144]
//!           (--spawn-workers forks N `deis serve` children of this same
//!            binary on ephemeral ports and forwards the serve flags —
//!            --models/--workers/--precision/... — to each of them)
//!   sample  --model gmm2d_exact --solver tab3 --nfe 10 --n 1000 [--metric]
//!           [--precision f64|f32]
//!
//! `--precision f32` additionally registers an f32 engine per native model
//! (served to requests carrying "dtype":"f32"); f64 remains the default
//! numeric class for every request that does not opt in.
//!   info    (artifact + platform inventory)

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use deis::coordinator::{Coordinator, CoordinatorConfig, SampleRequest, SchedPolicy};
use deis::exp::default_registry_with;
use deis::gmm::Gmm;
use deis::metrics;
use deis::runtime::Runtime;
use deis::score::Precision;
use deis::server;
use deis::solvers::SolverKind;
use deis::timegrid::GridKind;
use deis::util::cli::Args;
use deis::util::rng::Rng;

fn main() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("usage: deis <serve|sample|info> [flags]");
        return Ok(());
    }
    let cmd = argv.remove(0);
    let args = Args::parse(argv);
    match cmd.as_str() {
        "serve" => cmd_serve(&args),
        "router" => cmd_router(&args),
        "sample" => cmd_sample(&args),
        "info" => cmd_info(),
        other => bail!("unknown command '{other}'"),
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let models = args.list_or("models", "gmm2d,gmm2d_exact,gmm2d_oracle");
    let precision = parse_precision(args)?;
    let reg = default_registry_with(&models, precision)?;
    let max_inflight = args.usize_or("max-inflight", 4096);
    let cfg = CoordinatorConfig {
        workers: args.usize_or("workers", 4),
        max_batch_samples: args.usize_or("max-batch", 1024),
        max_inflight_requests: max_inflight,
        // One model may not hog the whole global budget; defaults to the
        // global bound (i.e. no extra cap) unless narrowed explicitly.
        max_inflight_per_model: args.usize_or("max-inflight-per-model", max_inflight),
        // Per-model circuit breaker: consecutive eval failures before the
        // model's traffic is refused outright, and how long the refusal
        // lasts before a retry is admitted. 0 disables the breaker.
        breaker_threshold: args.u64_or("breaker-threshold", 5) as u32,
        breaker_cooldown_ms: args.u64_or("breaker-cooldown-ms", 1000),
        sched_policy: parse_sched_policy(args)?,
    };
    let opts = server::ServeOptions {
        max_conns: args.usize_or("max-conns", 1024),
        read_timeout: std::time::Duration::from_millis(args.u64_or("read-timeout-ms", 30_000)),
        write_timeout: std::time::Duration::from_millis(
            args.u64_or("write-timeout-ms", 30_000),
        ),
        max_line_bytes: args.usize_or("max-line-bytes", 256 * 1024),
        io_threads: args
            .usize_or("io-threads", server::ServeOptions::default().io_threads),
    };
    let coord = Arc::new(Coordinator::new(cfg, reg));
    let addr = server::serve_with(coord, &args.str_or("addr", "127.0.0.1:7878"), opts)?;
    println!("deis serving on {addr} (models: {})", models.join(","));
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Serve flags forwarded verbatim to each `--spawn-workers` child, so a
/// spawned fleet behaves exactly like hand-started `deis serve` processes.
const FORWARDED_SERVE_FLAGS: &[&str] = &[
    "models",
    "workers",
    "precision",
    "max-batch",
    "max-inflight",
    "max-inflight-per-model",
    "breaker-threshold",
    "breaker-cooldown-ms",
    "sched-policy",
    "edf-age-guard-ms",
    "io-threads",
];

fn cmd_router(args: &Args) -> Result<()> {
    let mut upstreams = args.list_or("upstream", "");
    // Keep the Child handles alive for the process lifetime; the router
    // process IS the fleet supervisor in spawn mode.
    let mut children: Vec<std::process::Child> = Vec::new();
    let spawn_n = args.usize_or("spawn-workers", 0);
    if spawn_n > 0 && !upstreams.is_empty() {
        bail!("--spawn-workers and --upstream are mutually exclusive");
    }
    if spawn_n > 0 {
        let exe = std::env::current_exe().context("locating own binary")?;
        for i in 0..spawn_n {
            let mut cmd = std::process::Command::new(&exe);
            cmd.arg("serve").arg("--addr").arg("127.0.0.1:0");
            for flag in FORWARDED_SERVE_FLAGS {
                if let Some(v) = args.get(flag) {
                    cmd.arg(format!("--{flag}")).arg(v);
                }
            }
            cmd.stdout(std::process::Stdio::piped());
            let mut child = cmd.spawn().with_context(|| format!("spawning worker {i}"))?;
            let stdout = child.stdout.take().expect("stdout piped");
            let mut reader = std::io::BufReader::new(stdout);
            let mut banner = String::new();
            std::io::BufRead::read_line(&mut reader, &mut banner)
                .with_context(|| format!("reading worker {i} banner"))?;
            let addr = deis::router::parse_serve_banner(&banner).ok_or_else(|| {
                anyhow::anyhow!("worker {i} printed no serve banner (got {banner:?})")
            })?;
            // Drain the rest of the child's stdout so it never blocks on a
            // full pipe.
            std::thread::spawn(move || {
                std::io::copy(&mut reader, &mut std::io::sink()).ok();
            });
            upstreams.push(addr.to_string());
            children.push(child);
        }
    }
    if upstreams.is_empty() {
        bail!("router needs --upstream host:port,... or --spawn-workers N");
    }
    let opts = deis::router::RouterOptions {
        max_conns: args.usize_or("max-conns", 1024),
        read_timeout: std::time::Duration::from_millis(args.u64_or("read-timeout-ms", 30_000)),
        write_timeout: std::time::Duration::from_millis(
            args.u64_or("write-timeout-ms", 30_000),
        ),
        max_line_bytes: args.usize_or("max-line-bytes", 256 * 1024),
        pool_per_worker: args.usize_or("pool-per-worker", 8),
        connect_timeout: std::time::Duration::from_millis(
            args.u64_or("connect-timeout-ms", 250),
        ),
        cooldown: std::time::Duration::from_millis(args.u64_or("cooldown-ms", 1000)),
    };
    let addr = deis::router::serve_with(
        upstreams.clone(),
        &args.str_or("addr", "127.0.0.1:7800"),
        opts,
    )?;
    println!("deis router on {addr} (workers: {})", upstreams.join(","));
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_sample(args: &Args) -> Result<()> {
    let model = args.str_or("model", "gmm2d_oracle");
    let solver = SolverKind::parse(&args.str_or("solver", "tab3"))
        .context("unknown solver")?;
    let precision = parse_precision(args)?;
    let reg = default_registry_with(&[model.clone()], precision)?;
    let coord = Coordinator::new(CoordinatorConfig::default(), reg);
    let mut req = SampleRequest::new(&model, solver, args.usize_or("nfe", 10),
        args.usize_or("n", 1000));
    req.seed = args.u64_or("seed", 0);
    req.dtype = precision;
    if let Some(g) = args.get("grid") {
        req.grid = GridKind::parse(g).context("unknown grid")?;
    }
    let t = std::time::Instant::now();
    let res = coord.sample_blocking(req)?;
    let elapsed = t.elapsed();
    println!(
        "sampled {} x {}d in {:.1} ms ({} NFE, solver {})",
        res.samples.len() / res.dim, res.dim,
        elapsed.as_secs_f64() * 1e3, res.nfe, solver.name()
    );
    if args.bool("metric") && res.dim == 2 {
        let gmm = Gmm::ring2d(4.0, 8, 0.25);
        let mut rng = Rng::new(999);
        let truth = gmm.sample(&mut rng, 20_000);
        let swd = metrics::sliced_wasserstein(&res.samples, &truth, 2, 128, &mut rng);
        println!("SWD x1000 vs exact data: {:.2}", swd * 1000.0);
    }
    coord.shutdown();
    Ok(())
}

fn parse_sched_policy(args: &Args) -> Result<SchedPolicy> {
    let policy = SchedPolicy::parse(&args.str_or("sched-policy", "oldest"))?;
    Ok(match policy {
        SchedPolicy::Edf { .. } if args.get("edf-age-guard-ms").is_some() => {
            SchedPolicy::Edf {
                age_guard: std::time::Duration::from_millis(
                    args.u64_or("edf-age-guard-ms", 250),
                ),
            }
        }
        p => p,
    })
}

fn parse_precision(args: &Args) -> Result<Precision> {
    let s = args.str_or("precision", "f64");
    Precision::parse(&s)
        .with_context(|| format!("unknown --precision '{s}' (expected f32 or f64)"))
}

fn cmd_info() -> Result<()> {
    let rt = Runtime::global();
    println!("platform: {}", rt.platform());
    println!("artifacts: {}", rt.artifacts_dir().display());
    let meta = deis::util::json::Json::from_file(
        &rt.artifacts_dir().join("meta.json").to_string_lossy(),
    )?;
    if let Ok(models) = meta.get("models") {
        if let deis::util::json::Json::Obj(m) = models {
            for (name, info) in m {
                println!(
                    "  model {name}: dim={} hidden={} blocks={}",
                    info.get("dim")?.as_f64()?,
                    info.get("hidden")?.as_f64()?,
                    info.get("n_blocks")?.as_f64()?
                );
            }
        }
    }
    Ok(())
}
