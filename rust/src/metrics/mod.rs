//! Sample-quality metrics — the FID substitutes (DESIGN.md §1).
//!
//! The paper ranks samplers by FID against dataset statistics; offline we
//! rank by divergences against *exact* data samples: sliced Wasserstein
//! (primary, reported ×1000 like FID tables), RBF-kernel MMD, and energy
//! distance. All are zero iff the distributions match (in the limit), and
//! preserve the orderings/crossovers the paper's tables establish.

use crate::util::rng::Rng;

/// Sliced Wasserstein-2 distance between row-major point sets a, b (same d).
/// Projects onto `n_proj` random unit directions and averages 1-D W2^2,
/// then takes sqrt. a and b may have different sizes (quantile matching).
pub fn sliced_wasserstein(a: &[f64], b: &[f64], d: usize, n_proj: usize, rng: &mut Rng) -> f64 {
    let na = a.len() / d;
    let nb = b.len() / d;
    assert!(na > 0 && nb > 0);
    let mut total = 0.0;
    let mut pa = vec![0.0; na];
    let mut pb = vec![0.0; nb];
    for _ in 0..n_proj {
        let dir = random_unit(rng, d);
        project(a, d, &dir, &mut pa);
        project(b, d, &dir, &mut pb);
        pa.sort_by(f64::total_cmp);
        pb.sort_by(f64::total_cmp);
        total += w2_sorted_1d(&pa, &pb);
    }
    (total / n_proj as f64).sqrt()
}

fn random_unit(rng: &mut Rng, d: usize) -> Vec<f64> {
    loop {
        let v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let n: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        if n > 1e-12 {
            return v.into_iter().map(|x| x / n).collect();
        }
    }
}

fn project(x: &[f64], d: usize, dir: &[f64], out: &mut [f64]) {
    for (i, o) in out.iter_mut().enumerate() {
        let row = &x[i * d..(i + 1) * d];
        *o = row.iter().zip(dir).map(|(a, b)| a * b).sum();
    }
}

/// W2^2 between two sorted 1-D samples of possibly different sizes, by
/// integrating the squared quantile difference on the union grid.
fn w2_sorted_1d(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len().max(b.len()).max(64);
    let mut acc = 0.0;
    for i in 0..n {
        let q = (i as f64 + 0.5) / n as f64;
        let qa = quantile_sorted(a, q);
        let qb = quantile_sorted(b, q);
        acc += (qa - qb) * (qa - qb);
    }
    acc / n as f64
}

fn quantile_sorted(x: &[f64], q: f64) -> f64 {
    let pos = q * (x.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    x[lo] * (1.0 - frac) + x[hi] * frac
}

/// Unbiased RBF-kernel MMD^2 with median-heuristic bandwidth. Subsamples to
/// at most `cap` points per set (quadratic cost).
pub fn mmd2_rbf(a: &[f64], b: &[f64], d: usize, cap: usize, rng: &mut Rng) -> f64 {
    let a = subsample(a, d, cap, rng);
    let b = subsample(b, d, cap, rng);
    let na = a.len() / d;
    let nb = b.len() / d;
    // median of pairwise distances on the pooled set (on a further subsample)
    let mut dists = Vec::new();
    let pool_n = (na + nb).min(256);
    for i in 0..pool_n {
        for j in (i + 1)..pool_n {
            let (xi, xj) = (pooled(&a, &b, d, i), pooled(&a, &b, d, j));
            dists.push(sq_dist(xi, xj));
        }
    }
    dists.sort_by(f64::total_cmp);
    let med = dists[dists.len() / 2].max(1e-12);
    let gamma = 1.0 / med;
    let k = |x: &[f64], y: &[f64]| (-gamma * sq_dist(x, y)).exp();

    let mut kaa = 0.0;
    for i in 0..na {
        for j in 0..na {
            if i != j {
                kaa += k(&a[i * d..(i + 1) * d], &a[j * d..(j + 1) * d]);
            }
        }
    }
    let mut kbb = 0.0;
    for i in 0..nb {
        for j in 0..nb {
            if i != j {
                kbb += k(&b[i * d..(i + 1) * d], &b[j * d..(j + 1) * d]);
            }
        }
    }
    let mut kab = 0.0;
    for i in 0..na {
        for j in 0..nb {
            kab += k(&a[i * d..(i + 1) * d], &b[j * d..(j + 1) * d]);
        }
    }
    kaa / (na * (na - 1)) as f64 + kbb / (nb * (nb - 1)) as f64
        - 2.0 * kab / (na * nb) as f64
}

/// Energy distance: 2 E|X−Y| − E|X−X'| − E|Y−Y'| (subsampled).
pub fn energy_distance(a: &[f64], b: &[f64], d: usize, cap: usize, rng: &mut Rng) -> f64 {
    let a = subsample(a, d, cap, rng);
    let b = subsample(b, d, cap, rng);
    let na = a.len() / d;
    let nb = b.len() / d;
    let mean_cross = {
        let mut s = 0.0;
        for i in 0..na {
            for j in 0..nb {
                s += sq_dist(&a[i * d..(i + 1) * d], &b[j * d..(j + 1) * d]).sqrt();
            }
        }
        s / (na * nb) as f64
    };
    let mean_self = |x: &[f64], n: usize| {
        if n < 2 {
            return 0.0;
        }
        let mut s = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                s += sq_dist(&x[i * d..(i + 1) * d], &x[j * d..(j + 1) * d]).sqrt();
            }
        }
        2.0 * s / (n * (n - 1)) as f64
    };
    2.0 * mean_cross - mean_self(&a, na) - mean_self(&b, nb)
}

fn pooled<'a>(a: &'a [f64], b: &'a [f64], d: usize, i: usize) -> &'a [f64] {
    let na = a.len() / d;
    if i < na {
        &a[i * d..(i + 1) * d]
    } else {
        let j = i - na;
        &b[j * d..(j + 1) * d]
    }
}

fn sq_dist(x: &[f64], y: &[f64]) -> f64 {
    x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum()
}

fn subsample(x: &[f64], d: usize, cap: usize, rng: &mut Rng) -> Vec<f64> {
    let n = x.len() / d;
    if n <= cap {
        return x.to_vec();
    }
    let mut out = Vec::with_capacity(cap * d);
    for _ in 0..cap {
        let i = rng.below(n);
        out.extend_from_slice(&x[i * d..(i + 1) * d]);
    }
    out
}

/// Mean absolute per-coordinate difference — the paper's Δ_p (Fig. 3).
pub fn mean_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f64>() / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gaussian_cloud(rng: &mut Rng, n: usize, d: usize, shift: f64) -> Vec<f64> {
        let mut v = rng.normal_vec(n * d);
        for x in v.iter_mut() {
            *x += shift;
        }
        v
    }

    #[test]
    fn swd_zero_for_same_distribution() {
        let mut rng = Rng::new(1);
        let a = gaussian_cloud(&mut rng, 2000, 2, 0.0);
        let b = gaussian_cloud(&mut rng, 2000, 2, 0.0);
        let d0 = sliced_wasserstein(&a, &b, 2, 64, &mut Rng::new(7));
        assert!(d0 < 0.1, "same-dist swd {d0}");
    }

    #[test]
    fn swd_detects_shift_monotonically() {
        let mut rng = Rng::new(2);
        let a = gaussian_cloud(&mut rng, 1500, 2, 0.0);
        let mut last = 0.0;
        for shift in [0.5, 1.0, 2.0] {
            let b = gaussian_cloud(&mut rng, 1500, 2, shift);
            let dist = sliced_wasserstein(&a, &b, 2, 64, &mut Rng::new(7));
            assert!(dist > last, "shift {shift}: {dist} <= {last}");
            last = dist;
        }
        // 1-D shift of mean by s gives SW ~ s/sqrt(2) in 2-D; sanity check scale.
        assert!(last > 1.0 && last < 2.2, "{last}");
    }

    #[test]
    fn mmd_separates() {
        let mut rng = Rng::new(3);
        let a = gaussian_cloud(&mut rng, 600, 2, 0.0);
        let b = gaussian_cloud(&mut rng, 600, 2, 0.0);
        let c = gaussian_cloud(&mut rng, 600, 2, 3.0);
        let same = mmd2_rbf(&a, &b, 2, 256, &mut Rng::new(9));
        let diff = mmd2_rbf(&a, &c, 2, 256, &mut Rng::new(9));
        assert!(same < 0.01, "{same}");
        assert!(diff > 10.0 * same.max(1e-6), "same {same} diff {diff}");
    }

    #[test]
    fn energy_separates() {
        let mut rng = Rng::new(4);
        let a = gaussian_cloud(&mut rng, 500, 2, 0.0);
        let b = gaussian_cloud(&mut rng, 500, 2, 0.0);
        let c = gaussian_cloud(&mut rng, 500, 2, 2.0);
        let same = energy_distance(&a, &b, 2, 256, &mut Rng::new(9));
        let diff = energy_distance(&a, &c, 2, 256, &mut Rng::new(9));
        assert!(same.abs() < 0.05, "{same}");
        assert!(diff > 0.5, "{diff}");
    }

    #[test]
    fn mean_abs_diff_basic() {
        assert_eq!(mean_abs_diff(&[1.0, 2.0], &[0.0, 4.0]), 1.5);
    }
}
