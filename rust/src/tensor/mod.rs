//! Precision-generic dense row-major matrix ops for the rust-native eps
//! backend.
//!
//! The native backend exists to (a) cross-check PJRT numerics against an
//! independent implementation and (b) run the huge table sweeps and the
//! serving hot path without per-call PJRT overhead. DEIS makes the per-step
//! eps eval the entire serving cost, so the matmul kernel here is *the*
//! hot loop of the whole service.
//!
//! ## API
//!
//! One descriptor type, [`Kernel`], replaces the old
//! `matmul_rows::<ACC, GELU>` const-generic surface:
//!
//! ```text
//! Kernel { acc, epilogue } . run(x, kdim, &w, bias, &mut out)
//! ```
//!
//! computes `out[b, n] = x[b, k] @ w[k, n] (+ bias / epilogue variants)`
//! with every epilogue fused into the store so the engine never takes a
//! second pass over its activations:
//!
//!   * `acc = false`, [`Epilogue::None`]: `out  = bias + x @ w`
//!   * `acc = true`,  [`Epilogue::None`]: `out += bias + x @ w`
//!     (residual update `h += z @ w2 + b2`)
//!   * [`Epilogue::Gelu`]: tanh-GELU applied to each finished value
//!     (`z = gelu(h @ w1 + bias)`; with `acc` the GELU wraps the
//!     accumulated value, fusing the old separate `gelu_slice` pass)
//!   * [`Epilogue::GeluResidual`]: `out += gelu(bias + x @ w)` — the
//!     residual-around-activation form, `acc` implied
//!
//! All kernels take raw slices, not `Mat`, so callers can feed workspace
//! arenas and batch sub-ranges without copying; [`Mat`] remains for
//! coefficient storage and tests.
//!
//! ## Element types
//!
//! Everything is generic over [`Element`] — `f64` (default, bit-compatible
//! with the python oracles) or `f32` (opt-in inference precision, ~2x SIMD
//! width; see EXPERIMENTS.md §Kernels for the tolerance story).
//!
//! ## Kernel paths
//!
//! Three interchangeable implementations, selectable per call with
//! [`Kernel::run_with`] or process-wide with [`force_kernel_path`]:
//!
//!   * [`KernelPath::Reference`] — the original 2-row × 4-k scalar kernel,
//!     kept verbatim as the numeric baseline.
//!   * [`KernelPath::Tiled`] — register-tiled, cache-blocked microkernel
//!     (4 rows × 8 columns of accumulators held across the whole k loop).
//!     **Bit-identical to `Reference`** for every element type: each output
//!     element sees exactly the same operation chain (seed, 4-k product
//!     quads in k order, singles tail, epilogue), only the iteration order
//!     *across* elements differs. Pinned by tests here and in
//!     `tests/kernel_paths.rs`.
//!   * [`KernelPath::Fma`] — `std::arch` x86-64 AVX2+FMA microkernel behind
//!     runtime feature detection (scalar `Tiled` fallback elsewhere). Fused
//!     multiply-add skips intermediate roundings, so this path is its own
//!     numeric class: *not* bit-identical, but within a few ulps of the
//!     scalar paths (property-tested).
//!
//! The auto-dispatched path ([`active_kernel_path`]) is `Fma` where the CPU
//! supports it, else `Tiled`. Single-threaded by design: batch-level
//! parallelism lives one level up (`score::NativeMlp` fans row chunks
//! across the persistent `score::pool::WorkerPool` once per forward —
//! §Perf in EXPERIMENTS.md showed per-matmul threading eats its own gains).

use std::sync::atomic::{AtomicU8, Ordering};

/// Scalar type the tensor kernels are generic over. Implemented for `f64`
/// and `f32`; the ops bounds cover exactly what the kernels use, so the
/// generic code monomorphizes to the same loops the old f64-only code had.
pub trait Element:
    Copy
    + Send
    + Sync
    + Default
    + PartialEq
    + std::fmt::Debug
    + std::fmt::Display
    + std::ops::Add<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::AddAssign
    + 'static
{
    const ZERO: Self;
    /// Wire/CLI name of the dtype ("f64" / "f32").
    const NAME: &'static str;
    fn from_f64(v: f64) -> Self;
    fn to_f64(self) -> f64;
    /// tanh-approximate GELU in this type's native arithmetic.
    fn gelu(self) -> Self;
    /// Implementation hook, not part of the caller-facing API: run the
    /// arch-specific FMA microkernel for this type if the CPU supports it.
    /// Returns false when the caller must fall back to the tiled kernel.
    fn fma_run(k: Kernel, x: &[Self], kdim: usize, w: &Mat<Self>, bias: &[Self], out: &mut [Self])
        -> bool;
}

impl Element for f64 {
    const ZERO: f64 = 0.0;
    const NAME: &'static str = "f64";

    #[inline(always)]
    fn from_f64(v: f64) -> f64 {
        v
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }

    #[inline(always)]
    fn gelu(self) -> f64 {
        gelu(self)
    }

    #[cfg(target_arch = "x86_64")]
    fn fma_run(k: Kernel, x: &[f64], kdim: usize, w: &Mat<f64>, bias: &[f64], out: &mut [f64])
        -> bool {
        if !fma::available() {
            return false;
        }
        // Safety: feature availability checked above; shapes validated by
        // the `run_with` caller.
        unsafe { fma::run_f64(k, x, kdim, w, bias, out) };
        true
    }

    #[cfg(not(target_arch = "x86_64"))]
    fn fma_run(_: Kernel, _: &[f64], _: usize, _: &Mat<f64>, _: &[f64], _: &mut [f64]) -> bool {
        false
    }
}

impl Element for f32 {
    const ZERO: f32 = 0.0;
    const NAME: &'static str = "f32";

    #[inline(always)]
    fn from_f64(v: f64) -> f32 {
        v as f32
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline(always)]
    fn gelu(self) -> f32 {
        gelu_f32(self)
    }

    #[cfg(target_arch = "x86_64")]
    fn fma_run(k: Kernel, x: &[f32], kdim: usize, w: &Mat<f32>, bias: &[f32], out: &mut [f32])
        -> bool {
        if !fma::available() {
            return false;
        }
        // Safety: feature availability checked above; shapes validated by
        // the `run_with` caller.
        unsafe { fma::run_f32(k, x, kdim, w, bias, out) };
        true
    }

    #[cfg(not(target_arch = "x86_64"))]
    fn fma_run(_: Kernel, _: &[f32], _: usize, _: &Mat<f32>, _: &[f32], _: &mut [f32]) -> bool {
        false
    }
}

/// Row-major matrix over an [`Element`] type (defaults to f64, so existing
/// `Mat` spellings keep meaning the double-precision matrix).
#[derive(Clone, Debug, PartialEq)]
pub struct Mat<E: Element = f64> {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<E>,
}

impl<E: Element> Mat<E> {
    pub fn zeros(rows: usize, cols: usize) -> Mat<E> {
        Mat { rows, cols, data: vec![E::ZERO; rows * cols] }
    }

    pub fn from_rows(rows: usize, cols: usize, data: Vec<E>) -> Mat<E> {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// Narrow (or pass through) f64 coefficient data into this precision —
    /// the weight-loading conversion point.
    pub fn from_f64_rows(rows: usize, cols: usize, data: &[f64]) -> Mat<E> {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data: data.iter().map(|&v| E::from_f64(v)).collect() }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[E] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [E] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }
}

/// Fused store transform applied to each finished output element.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Epilogue {
    /// Plain store.
    None,
    /// `out = gelu(value)`.
    Gelu,
    /// `out += gelu(value)` — residual-around-activation; reads `out`
    /// regardless of `acc` (which is implied and ignored for seeding).
    GeluResidual,
}

/// Matmul kernel descriptor: `value_j = seed_j + x_row @ w[:, j]` where the
/// seed is `bias_j` (or `out_j + bias_j` when `acc`), then the [`Epilogue`]
/// decides how `value` lands in `out`. One call-site shape for every fused
/// variant the eps-net forward needs.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Kernel {
    pub acc: bool,
    pub epilogue: Epilogue,
}

impl Kernel {
    /// `out = bias + x @ w`
    pub const fn overwrite() -> Kernel {
        Kernel { acc: false, epilogue: Epilogue::None }
    }

    /// `out = gelu(bias + x @ w)`
    pub const fn overwrite_gelu() -> Kernel {
        Kernel { acc: false, epilogue: Epilogue::Gelu }
    }

    /// `out += bias + x @ w`
    pub const fn accumulate() -> Kernel {
        Kernel { acc: true, epilogue: Epilogue::None }
    }

    /// `out = gelu(out + bias + x @ w)` — accumulate, then GELU the total.
    pub const fn accumulate_gelu() -> Kernel {
        Kernel { acc: true, epilogue: Epilogue::Gelu }
    }

    /// `out += gelu(bias + x @ w)`
    pub const fn gelu_residual() -> Kernel {
        Kernel { acc: true, epilogue: Epilogue::GeluResidual }
    }

    /// Run on the auto-dispatched path (see [`active_kernel_path`]).
    #[inline]
    pub fn run<E: Element>(self, x: &[E], kdim: usize, w: &Mat<E>, bias: &[E], out: &mut [E]) {
        self.run_with(active_kernel_path(), x, kdim, w, bias, out);
    }

    /// Run on an explicit path — deterministic regardless of the process-
    /// wide force, which is what correctness tests and benches use.
    /// `x[rows, kdim] @ w[kdim, n] -> out[rows, n]`, rows inferred from
    /// `out`. `Fma` silently falls back to `Tiled` on unsupported CPUs.
    pub fn run_with<E: Element>(
        self,
        path: KernelPath,
        x: &[E],
        kdim: usize,
        w: &Mat<E>,
        bias: &[E],
        out: &mut [E],
    ) {
        let n = w.cols;
        assert_eq!(w.rows, kdim);
        assert_eq!(bias.len(), n);
        assert!(kdim > 0 && n > 0, "degenerate matmul shape");
        let rows = out.len() / n;
        assert_eq!(out.len(), rows * n);
        assert_eq!(x.len(), rows * kdim);
        match path {
            // The pre-PR kernel never had a GeluResidual epilogue; the tiled
            // kernel (bit-identical operation chain) covers it on every path.
            KernelPath::Reference if self.epilogue != Epilogue::GeluResidual => {
                reference::run(self, x, kdim, w, bias, out);
            }
            KernelPath::Reference | KernelPath::Tiled => {
                tiled::run(self, x, kdim, w, bias, out);
            }
            KernelPath::Fma => {
                if !E::fma_run(self, x, kdim, w, bias, out) {
                    tiled::run(self, x, kdim, w, bias, out);
                }
            }
        }
    }
}

/// Which matmul implementation executes (see the module doc for the
/// numeric contract of each).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum KernelPath {
    Reference,
    Tiled,
    Fma,
}

/// Process-wide kernel-path override for [`Kernel::run`] callers.
/// 0 = auto, 1 = Reference, 2 = Tiled, 3 = Fma-if-available.
static FORCED_PATH: AtomicU8 = AtomicU8::new(0);

/// Force every auto-dispatched kernel call onto one path (`None` restores
/// auto). Process-global and racy across threads by nature — intended for
/// single-test binaries and benches, not for concurrent unit tests (those
/// should pass an explicit path to [`Kernel::run_with`]).
pub fn force_kernel_path(path: Option<KernelPath>) {
    let v = match path {
        None => 0,
        Some(KernelPath::Reference) => 1,
        Some(KernelPath::Tiled) => 2,
        Some(KernelPath::Fma) => 3,
    };
    FORCED_PATH.store(v, Ordering::Relaxed);
}

/// True when the CPU has the AVX2+FMA features the [`KernelPath::Fma`]
/// microkernels need (always false off x86-64).
#[cfg(target_arch = "x86_64")]
pub fn fma_supported() -> bool {
    fma::available()
}

/// True when the CPU has the AVX2+FMA features the [`KernelPath::Fma`]
/// microkernels need (always false off x86-64).
#[cfg(not(target_arch = "x86_64"))]
pub fn fma_supported() -> bool {
    false
}

/// The path [`Kernel::run`] dispatches to right now: the forced path if one
/// is set, else `Fma` where supported, else `Tiled`.
pub fn active_kernel_path() -> KernelPath {
    match FORCED_PATH.load(Ordering::Relaxed) {
        1 => KernelPath::Reference,
        2 => KernelPath::Tiled,
        _ => {
            if fma_supported() {
                KernelPath::Fma
            } else {
                KernelPath::Tiled
            }
        }
    }
}

/// out[b, n] = x[b, k] @ w[k, n] + bias[n]; `out` is fully overwritten.
/// Thin `Mat` wrapper over [`Kernel::overwrite`].
pub fn matmul_bias_into<E: Element>(x: &Mat<E>, w: &Mat<E>, bias: &[E], out: &mut Mat<E>) {
    assert_eq!((out.rows, out.cols), (x.rows, w.cols));
    Kernel::overwrite().run(&x.data, x.cols, w, bias, &mut out.data);
}

/// tanh-approximate GELU — must match jax.nn.gelu(approximate=True) used by
/// both L1 kernels and the jnp oracle.
#[inline]
pub fn gelu(x: f64) -> f64 {
    const C: f64 = 0.797_884_560_802_865_4; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// [`gelu`] computed in f32 arithmetic (the f32 inference mode's
/// activation; its error is covered by the documented f32 tolerance).
#[inline]
pub fn gelu_f32(x: f32) -> f32 {
    const C: f32 = 0.797_884_560_802_865_4_f64 as f32; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// The original scalar kernel, generified over [`Element`] but otherwise
/// kept verbatim: 2-row × 4-k register blocking, accumulating directly into
/// `out`. This is the numeric baseline the tiled kernel must match bit for
/// bit, and the scalar fallback pinned by `tests/kernel_paths.rs`.
mod reference {
    use super::{Element, Epilogue, Kernel, Mat};

    pub(super) fn run<E: Element>(
        k: Kernel,
        x: &[E],
        kdim: usize,
        w: &Mat<E>,
        bias: &[E],
        out: &mut [E],
    ) {
        let n = w.cols;
        let rows = out.len() / n;
        let acc = k.acc;
        let gelu_ep = k.epilogue == Epilogue::Gelu;

        let mut r = 0;
        while r + 2 <= rows {
            let (o_lo, o_hi) = out[r * n..(r + 2) * n].split_at_mut(n);
            if acc {
                for (o, &bv) in o_lo.iter_mut().zip(bias) {
                    *o += bv;
                }
                for (o, &bv) in o_hi.iter_mut().zip(bias) {
                    *o += bv;
                }
            } else {
                o_lo.copy_from_slice(bias);
                o_hi.copy_from_slice(bias);
            }
            let xa = &x[r * kdim..(r + 1) * kdim];
            let xb = &x[(r + 1) * kdim..(r + 2) * kdim];
            let mut k_ = 0;
            while k_ + 4 <= kdim {
                let (a0, a1, a2, a3) = (xa[k_], xa[k_ + 1], xa[k_ + 2], xa[k_ + 3]);
                let (b0, b1, b2, b3) = (xb[k_], xb[k_ + 1], xb[k_ + 2], xb[k_ + 3]);
                let w0 = &w.data[k_ * n..][..n];
                let w1 = &w.data[(k_ + 1) * n..][..n];
                let w2 = &w.data[(k_ + 2) * n..][..n];
                let w3 = &w.data[(k_ + 3) * n..][..n];
                for j in 0..n {
                    let (v0, v1, v2, v3) = (w0[j], w1[j], w2[j], w3[j]);
                    o_lo[j] += a0 * v0 + a1 * v1 + a2 * v2 + a3 * v3;
                    o_hi[j] += b0 * v0 + b1 * v1 + b2 * v2 + b3 * v3;
                }
                k_ += 4;
            }
            while k_ < kdim {
                let (av, bv) = (xa[k_], xb[k_]);
                let wrow = &w.data[k_ * n..][..n];
                for j in 0..n {
                    o_lo[j] += av * wrow[j];
                    o_hi[j] += bv * wrow[j];
                }
                k_ += 1;
            }
            if gelu_ep {
                for v in o_lo.iter_mut() {
                    *v = v.gelu();
                }
                for v in o_hi.iter_mut() {
                    *v = v.gelu();
                }
            }
            r += 2;
        }
        // Tail row (odd batch): plain 4-k unroll.
        if r < rows {
            let orow = &mut out[r * n..(r + 1) * n];
            if acc {
                for (o, &bv) in orow.iter_mut().zip(bias) {
                    *o += bv;
                }
            } else {
                orow.copy_from_slice(bias);
            }
            let xrow = &x[r * kdim..(r + 1) * kdim];
            let mut k_ = 0;
            while k_ + 4 <= kdim {
                let (x0, x1, x2, x3) = (xrow[k_], xrow[k_ + 1], xrow[k_ + 2], xrow[k_ + 3]);
                let w0 = &w.data[k_ * n..][..n];
                let w1 = &w.data[(k_ + 1) * n..][..n];
                let w2 = &w.data[(k_ + 2) * n..][..n];
                let w3 = &w.data[(k_ + 3) * n..][..n];
                for j in 0..n {
                    orow[j] += x0 * w0[j] + x1 * w1[j] + x2 * w2[j] + x3 * w3[j];
                }
                k_ += 4;
            }
            while k_ < kdim {
                let xv = xrow[k_];
                let wrow = &w.data[k_ * n..][..n];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
                k_ += 1;
            }
            if gelu_ep {
                for v in orow.iter_mut() {
                    *v = v.gelu();
                }
            }
        }
    }
}

/// Register-tiled, cache-blocked kernel. MR × NR output accumulators live
/// in locals across the entire k loop, so each output element is stored
/// exactly once (the reference kernel re-loads and re-stores `out` on every
/// k quad). Each loaded weight tile row is reused for MR output rows,
/// halving weight-stream bandwidth again versus the reference's 2-row
/// blocking.
///
/// Bit-identity with `reference`: for every output element the operation
/// chain is *identical* — seed (`bias` or `out + bias`), then one
/// `acc += a0*v0 + a1*v1 + a2*v2 + a3*v3` per k quad in k order, then
/// `acc += a*v` singles, then the epilogue. Only the iteration order across
/// elements changes, which cannot change any individual result.
mod tiled {
    use super::{Element, Epilogue, Kernel, Mat};

    /// Tile height (output rows per register block).
    pub(super) const MR: usize = 4;
    /// Tile width (output columns per register block). 8 f64 accumulator
    /// columns = two 512-bit or four 256-bit lanes per row — wide enough to
    /// saturate autovectorization, small enough that MR×NR accumulators
    /// plus a weight-tile row stay in registers.
    pub(super) const NR: usize = 8;

    pub(super) fn run<E: Element>(
        k: Kernel,
        x: &[E],
        kdim: usize,
        w: &Mat<E>,
        bias: &[E],
        out: &mut [E],
    ) {
        let rows = out.len() / w.cols;
        run_range(k, x, kdim, w, bias, out, 0, rows, 0, w.cols);
    }

    /// Tiled kernel over output rows [r0, r1) and columns [c0, c1). The FMA
    /// path reuses this for its row/column tail regions.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn run_range<E: Element>(
        k: Kernel,
        x: &[E],
        kdim: usize,
        w: &Mat<E>,
        bias: &[E],
        out: &mut [E],
        r0: usize,
        r1: usize,
        c0: usize,
        c1: usize,
    ) {
        let mut r = r0;
        while r + MR <= r1 {
            tile_cols::<E, MR>(k, x, kdim, w, bias, out, r, c0, c1);
            r += MR;
        }
        while r < r1 {
            tile_cols::<E, 1>(k, x, kdim, w, bias, out, r, c0, c1);
            r += 1;
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn tile_cols<E: Element, const M: usize>(
        k: Kernel,
        x: &[E],
        kdim: usize,
        w: &Mat<E>,
        bias: &[E],
        out: &mut [E],
        r: usize,
        c0: usize,
        c1: usize,
    ) {
        let mut c = c0;
        while c + NR <= c1 {
            tile::<E, M>(k, x, kdim, w, bias, out, r, c, NR);
            c += NR;
        }
        if c < c1 {
            tile::<E, M>(k, x, kdim, w, bias, out, r, c, c1 - c);
        }
    }

    /// One register tile: M output rows × wd (≤ NR) output columns.
    #[inline(always)]
    #[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
    fn tile<E: Element, const M: usize>(
        k: Kernel,
        x: &[E],
        kdim: usize,
        w: &Mat<E>,
        bias: &[E],
        out: &mut [E],
        r: usize,
        c: usize,
        wd: usize,
    ) {
        let n = w.cols;
        let mut acc = [[E::ZERO; NR]; M];
        // Seed: bias, plus the prior output for accumulating kernels —
        // out + bias FIRST, matching the reference order bit for bit.
        let seed_out = k.acc && k.epilogue != Epilogue::GeluResidual;
        for (mi, am) in acc.iter_mut().enumerate() {
            let orow = &out[(r + mi) * n + c..(r + mi) * n + c + wd];
            for ji in 0..wd {
                am[ji] = if seed_out { orow[ji] + bias[c + ji] } else { bias[c + ji] };
            }
        }
        let mut kk = 0;
        while kk + 4 <= kdim {
            let w0 = &w.data[kk * n + c..][..wd];
            let w1 = &w.data[(kk + 1) * n + c..][..wd];
            let w2 = &w.data[(kk + 2) * n + c..][..wd];
            let w3 = &w.data[(kk + 3) * n + c..][..wd];
            for (mi, am) in acc.iter_mut().enumerate() {
                let xr = &x[(r + mi) * kdim + kk..];
                let (a0, a1, a2, a3) = (xr[0], xr[1], xr[2], xr[3]);
                for ji in 0..wd {
                    am[ji] += a0 * w0[ji] + a1 * w1[ji] + a2 * w2[ji] + a3 * w3[ji];
                }
            }
            kk += 4;
        }
        while kk < kdim {
            let wrow = &w.data[kk * n + c..][..wd];
            for (mi, am) in acc.iter_mut().enumerate() {
                let a = x[(r + mi) * kdim + kk];
                for ji in 0..wd {
                    am[ji] += a * wrow[ji];
                }
            }
            kk += 1;
        }
        for (mi, am) in acc.iter().enumerate() {
            let orow = &mut out[(r + mi) * n + c..(r + mi) * n + c + wd];
            match k.epilogue {
                Epilogue::None => orow.copy_from_slice(&am[..wd]),
                Epilogue::Gelu => {
                    for (o, &v) in orow.iter_mut().zip(&am[..wd]) {
                        *o = v.gelu();
                    }
                }
                Epilogue::GeluResidual => {
                    for (o, &v) in orow.iter_mut().zip(&am[..wd]) {
                        *o += v.gelu();
                    }
                }
            }
        }
    }
}

/// x86-64 AVX2+FMA microkernels. Callers gate on [`available`]; the
/// vectorized body covers full 4-row × NR-column tiles and hands row/column
/// tails to the (bit-identical-to-reference) tiled kernel — tails are
/// O(edge) work, and mixing scalar tails with FMA interiors is fine because
/// the whole FMA path is already its own numeric class.
#[cfg(target_arch = "x86_64")]
mod fma {
    use std::arch::x86_64::*;
    use std::sync::OnceLock;

    use super::{tiled, Epilogue, Kernel, Mat};

    pub(super) fn available() -> bool {
        static AVAIL: OnceLock<bool> = OnceLock::new();
        *AVAIL.get_or_init(|| {
            std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
        })
    }

    /// f64: 4 output rows × 8 columns (two 256-bit lanes per row).
    ///
    /// # Safety
    /// Requires AVX2+FMA (checked via [`available`]) and shape-validated
    /// slices (done by `Kernel::run_with`).
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn run_f64(
        k: Kernel,
        x: &[f64],
        kdim: usize,
        w: &Mat<f64>,
        bias: &[f64],
        out: &mut [f64],
    ) {
        const MR: usize = 4;
        const NR: usize = 8;
        let n = w.cols;
        let rows = out.len() / n;
        let seed_out = k.acc && k.epilogue != Epilogue::GeluResidual;
        let mut r = 0;
        while r + MR <= rows {
            let mut c = 0;
            while c + NR <= n {
                let b0 = _mm256_loadu_pd(bias.as_ptr().add(c));
                let b1 = _mm256_loadu_pd(bias.as_ptr().add(c + 4));
                let mut acc = [[b0, b1]; MR];
                if seed_out {
                    for (mi, am) in acc.iter_mut().enumerate() {
                        let op = out.as_ptr().add((r + mi) * n + c);
                        am[0] = _mm256_add_pd(_mm256_loadu_pd(op), b0);
                        am[1] = _mm256_add_pd(_mm256_loadu_pd(op.add(4)), b1);
                    }
                }
                for kk in 0..kdim {
                    let wp = w.data.as_ptr().add(kk * n + c);
                    let w0 = _mm256_loadu_pd(wp);
                    let w1 = _mm256_loadu_pd(wp.add(4));
                    for (mi, am) in acc.iter_mut().enumerate() {
                        let a = _mm256_set1_pd(*x.get_unchecked((r + mi) * kdim + kk));
                        am[0] = _mm256_fmadd_pd(a, w0, am[0]);
                        am[1] = _mm256_fmadd_pd(a, w1, am[1]);
                    }
                }
                for (mi, am) in acc.iter().enumerate() {
                    let op = out.as_mut_ptr().add((r + mi) * n + c);
                    match k.epilogue {
                        Epilogue::None => {
                            _mm256_storeu_pd(op, am[0]);
                            _mm256_storeu_pd(op.add(4), am[1]);
                        }
                        Epilogue::Gelu | Epilogue::GeluResidual => {
                            let mut tmp = [0.0f64; NR];
                            _mm256_storeu_pd(tmp.as_mut_ptr(), am[0]);
                            _mm256_storeu_pd(tmp.as_mut_ptr().add(4), am[1]);
                            if k.epilogue == Epilogue::Gelu {
                                for (i, &v) in tmp.iter().enumerate() {
                                    *op.add(i) = super::gelu(v);
                                }
                            } else {
                                for (i, &v) in tmp.iter().enumerate() {
                                    *op.add(i) += super::gelu(v);
                                }
                            }
                        }
                    }
                }
                c += NR;
            }
            r += MR;
        }
        let r_main = rows - rows % MR;
        let c_main = n - n % NR;
        if c_main < n {
            tiled::run_range(k, x, kdim, w, bias, out, 0, r_main, c_main, n);
        }
        if r_main < rows {
            tiled::run_range(k, x, kdim, w, bias, out, r_main, rows, 0, n);
        }
    }

    /// f32: 4 output rows × 16 columns (two 256-bit lanes per row, 8 f32
    /// each) — the ~2x-width payoff of the f32 inference mode.
    ///
    /// # Safety
    /// Requires AVX2+FMA (checked via [`available`]) and shape-validated
    /// slices (done by `Kernel::run_with`).
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn run_f32(
        k: Kernel,
        x: &[f32],
        kdim: usize,
        w: &Mat<f32>,
        bias: &[f32],
        out: &mut [f32],
    ) {
        const MR: usize = 4;
        const NR: usize = 16;
        let n = w.cols;
        let rows = out.len() / n;
        let seed_out = k.acc && k.epilogue != Epilogue::GeluResidual;
        let mut r = 0;
        while r + MR <= rows {
            let mut c = 0;
            while c + NR <= n {
                let b0 = _mm256_loadu_ps(bias.as_ptr().add(c));
                let b1 = _mm256_loadu_ps(bias.as_ptr().add(c + 8));
                let mut acc = [[b0, b1]; MR];
                if seed_out {
                    for (mi, am) in acc.iter_mut().enumerate() {
                        let op = out.as_ptr().add((r + mi) * n + c);
                        am[0] = _mm256_add_ps(_mm256_loadu_ps(op), b0);
                        am[1] = _mm256_add_ps(_mm256_loadu_ps(op.add(8)), b1);
                    }
                }
                for kk in 0..kdim {
                    let wp = w.data.as_ptr().add(kk * n + c);
                    let w0 = _mm256_loadu_ps(wp);
                    let w1 = _mm256_loadu_ps(wp.add(8));
                    for (mi, am) in acc.iter_mut().enumerate() {
                        let a = _mm256_set1_ps(*x.get_unchecked((r + mi) * kdim + kk));
                        am[0] = _mm256_fmadd_ps(a, w0, am[0]);
                        am[1] = _mm256_fmadd_ps(a, w1, am[1]);
                    }
                }
                for (mi, am) in acc.iter().enumerate() {
                    let op = out.as_mut_ptr().add((r + mi) * n + c);
                    match k.epilogue {
                        Epilogue::None => {
                            _mm256_storeu_ps(op, am[0]);
                            _mm256_storeu_ps(op.add(8), am[1]);
                        }
                        Epilogue::Gelu | Epilogue::GeluResidual => {
                            let mut tmp = [0.0f32; NR];
                            _mm256_storeu_ps(tmp.as_mut_ptr(), am[0]);
                            _mm256_storeu_ps(tmp.as_mut_ptr().add(8), am[1]);
                            if k.epilogue == Epilogue::Gelu {
                                for (i, &v) in tmp.iter().enumerate() {
                                    *op.add(i) = super::gelu_f32(v);
                                }
                            } else {
                                for (i, &v) in tmp.iter().enumerate() {
                                    *op.add(i) += super::gelu_f32(v);
                                }
                            }
                        }
                    }
                }
                c += NR;
            }
            r += MR;
        }
        let r_main = rows - rows % MR;
        let c_main = n - n % NR;
        if c_main < n {
            tiled::run_range(k, x, kdim, w, bias, out, 0, r_main, c_main, n);
        }
        if r_main < rows {
            tiled::run_range(k, x, kdim, w, bias, out, r_main, rows, 0, n);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop::run_prop, rng::Rng};

    /// Every kernel variant the forward pass (or API) can issue.
    const KERNELS: [Kernel; 5] = [
        Kernel::overwrite(),
        Kernel::overwrite_gelu(),
        Kernel::accumulate(),
        Kernel::accumulate_gelu(),
        Kernel::gelu_residual(),
    ];

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_rows(r, c, rng.normal_vec(r * c))
    }

    /// Naive triple loop as the oracle.
    fn matmul_naive(x: &Mat, w: &Mat, bias: &[f64]) -> Mat {
        let mut out = Mat::zeros(x.rows, w.cols);
        for r in 0..x.rows {
            for c in 0..w.cols {
                let mut acc = bias[c];
                for k in 0..x.cols {
                    acc += x.data[r * x.cols + k] * w.data[k * w.cols + c];
                }
                out.data[r * w.cols + c] = acc;
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        run_prop("matmul", 17, 30, |rng| {
            let (b, k, n) = (1 + rng.below(9), 1 + rng.below(9), 1 + rng.below(9));
            let x = rand_mat(rng, b, k);
            let w = rand_mat(rng, k, n);
            let bias = rng.normal_vec(n);
            let mut got = Mat::zeros(b, n);
            matmul_bias_into(&x, &w, &bias, &mut got);
            let want = matmul_naive(&x, &w, &bias);
            for (g, w_) in got.data.iter().zip(&want.data) {
                assert!((g - w_).abs() < 1e-10);
            }
        });
    }

    #[test]
    fn blocked_kernel_matches_naive_on_larger_shapes() {
        let mut rng = Rng::new(42);
        let (b, k, n) = (512, 64, 64);
        let x = rand_mat(&mut rng, b, k);
        let w = rand_mat(&mut rng, k, n);
        let bias = rng.normal_vec(n);
        for path in [KernelPath::Reference, KernelPath::Tiled, KernelPath::Fma] {
            let mut got = Mat::zeros(b, n);
            Kernel::overwrite().run_with(path, &x.data, k, &w, &bias, &mut got.data);
            let want = matmul_naive(&x, &w, &bias);
            for (g, w_) in got.data.iter().zip(&want.data) {
                assert!((g - w_).abs() < 1e-9, "path {path:?}: {g} vs {w_}");
            }
        }
    }

    #[test]
    fn gelu_epilogue_matches_two_pass() {
        run_prop("matmul gelu epilogue", 19, 30, |rng| {
            let (b, k, n) = (1 + rng.below(7), 1 + rng.below(7), 1 + rng.below(7));
            let x = rand_mat(rng, b, k);
            let w = rand_mat(rng, k, n);
            let bias = rng.normal_vec(n);
            let mut fused = Mat::zeros(b, n);
            Kernel::overwrite_gelu().run(&x.data, k, &w, &bias, &mut fused.data);
            let mut two_pass = Mat::zeros(b, n);
            matmul_bias_into(&x, &w, &bias, &mut two_pass);
            for v in two_pass.data.iter_mut() {
                *v = gelu(*v);
            }
            for (f, t) in fused.data.iter().zip(&two_pass.data) {
                assert!((f - t).abs() < 1e-14, "{f} vs {t}");
            }
        });
    }

    #[test]
    fn acc_epilogue_matches_matmul_plus_add() {
        run_prop("matmul acc epilogue", 23, 30, |rng| {
            let (b, k, n) = (1 + rng.below(7), 1 + rng.below(7), 1 + rng.below(7));
            let x = rand_mat(rng, b, k);
            let w = rand_mat(rng, k, n);
            let bias = rng.normal_vec(n);
            let base = rand_mat(rng, b, n);
            // Fused: out starts at `base`, accumulates bias + x@w.
            let mut fused = base.clone();
            Kernel::accumulate().run(&x.data, k, &w, &bias, &mut fused.data);
            // Reference: separate matmul then add.
            let mut tmp = Mat::zeros(b, n);
            matmul_bias_into(&x, &w, &bias, &mut tmp);
            let mut want = base;
            for (o, &v) in want.data.iter_mut().zip(&tmp.data) {
                *o += v;
            }
            for (f, t) in fused.data.iter().zip(&want.data) {
                assert!((f - t).abs() < 1e-12, "{f} vs {t}");
            }
        });
    }

    #[test]
    fn accumulate_gelu_matches_add_then_gelu() {
        run_prop("matmul acc+gelu epilogue", 31, 30, |rng| {
            let (b, k, n) = (1 + rng.below(7), 1 + rng.below(7), 1 + rng.below(7));
            let x = rand_mat(rng, b, k);
            let w = rand_mat(rng, k, n);
            let bias = rng.normal_vec(n);
            let base = rand_mat(rng, b, n);
            let mut fused = base.clone();
            Kernel::accumulate_gelu().run(&x.data, k, &w, &bias, &mut fused.data);
            let mut want = base;
            Kernel::accumulate().run(&x.data, k, &w, &bias, &mut want.data);
            for v in want.data.iter_mut() {
                *v = gelu(*v);
            }
            for (f, t) in fused.data.iter().zip(&want.data) {
                assert!((f - t).abs() < 1e-12, "{f} vs {t}");
            }
        });
    }

    #[test]
    fn gelu_residual_epilogue_matches_two_pass() {
        run_prop("matmul gelu-residual epilogue", 37, 30, |rng| {
            let (b, k, n) = (1 + rng.below(7), 1 + rng.below(7), 1 + rng.below(7));
            let x = rand_mat(rng, b, k);
            let w = rand_mat(rng, k, n);
            let bias = rng.normal_vec(n);
            let base = rand_mat(rng, b, n);
            let mut fused = base.clone();
            Kernel::gelu_residual().run(&x.data, k, &w, &bias, &mut fused.data);
            // Reference: out += gelu(bias + x@w) in two passes.
            let mut tmp = Mat::zeros(b, n);
            matmul_bias_into(&x, &w, &bias, &mut tmp);
            let mut want = base;
            for (o, &v) in want.data.iter_mut().zip(&tmp.data) {
                *o += gelu(v);
            }
            for (f, t) in fused.data.iter().zip(&want.data) {
                assert!((f - t).abs() < 1e-12, "{f} vs {t}");
            }
        });
    }

    /// Tiled must equal Reference BIT FOR BIT (the acceptance-criteria
    /// pin), and FMA must stay within a few ulps — for f64.
    #[test]
    fn kernel_paths_agree_f64() {
        run_prop("kernel paths f64", 41, 40, |rng| {
            // Shapes straddle every tile boundary: MR=4 rows, NR=8 cols.
            let (b, k, n) = (1 + rng.below(13), 1 + rng.below(10), 1 + rng.below(19));
            let x = rng.normal_vec(b * k);
            let w = rand_mat(rng, k, n);
            let bias = rng.normal_vec(n);
            let base = rng.normal_vec(b * n);
            for kern in KERNELS {
                let mut o_ref = base.clone();
                kern.run_with(KernelPath::Reference, &x, k, &w, &bias, &mut o_ref);
                let mut o_tiled = base.clone();
                kern.run_with(KernelPath::Tiled, &x, k, &w, &bias, &mut o_tiled);
                for (a, t) in o_ref.iter().zip(&o_tiled) {
                    assert_eq!(a.to_bits(), t.to_bits(), "{kern:?}: {a} vs {t} (tiled)");
                }
                if fma_supported() {
                    let mut o_fma = base.clone();
                    kern.run_with(KernelPath::Fma, &x, k, &w, &bias, &mut o_fma);
                    for (a, f) in o_ref.iter().zip(&o_fma) {
                        let tol = 1e-11 * (1.0 + a.abs());
                        assert!((a - f).abs() < tol, "{kern:?}: {a} vs {f} (fma)");
                    }
                }
            }
        });
    }

    /// Same three-way agreement for f32 (bitwise Reference == Tiled; FMA
    /// within f32 ulp noise).
    #[test]
    fn kernel_paths_agree_f32() {
        run_prop("kernel paths f32", 43, 40, |rng| {
            // f32 FMA tiles are 16 columns wide; straddle that too.
            let (b, k, n) = (1 + rng.below(13), 1 + rng.below(10), 1 + rng.below(37));
            let x: Vec<f32> = rng.normal_vec(b * k).iter().map(|&v| v as f32).collect();
            let w = Mat::<f32>::from_f64_rows(k, n, &rng.normal_vec(k * n));
            let bias: Vec<f32> = rng.normal_vec(n).iter().map(|&v| v as f32).collect();
            let base: Vec<f32> = rng.normal_vec(b * n).iter().map(|&v| v as f32).collect();
            for kern in KERNELS {
                let mut o_ref = base.clone();
                kern.run_with(KernelPath::Reference, &x, k, &w, &bias, &mut o_ref);
                let mut o_tiled = base.clone();
                kern.run_with(KernelPath::Tiled, &x, k, &w, &bias, &mut o_tiled);
                for (a, t) in o_ref.iter().zip(&o_tiled) {
                    assert_eq!(a.to_bits(), t.to_bits(), "{kern:?}: {a} vs {t} (tiled)");
                }
                if fma_supported() {
                    let mut o_fma = base.clone();
                    kern.run_with(KernelPath::Fma, &x, k, &w, &bias, &mut o_fma);
                    for (a, f) in o_ref.iter().zip(&o_fma) {
                        let tol = 1e-4 * (1.0 + a.abs());
                        assert!((a - f).abs() < tol, "{kern:?}: {a} vs {f} (fma)");
                    }
                }
            }
        });
    }

    /// f32 kernels track the f64 result within single-precision tolerance
    /// (the unit-level half of the precision-parity story; the end-to-end
    /// half lives in tests/precision_parity.rs).
    #[test]
    fn f32_tracks_f64_within_tolerance() {
        run_prop("f32 vs f64 matmul", 47, 30, |rng| {
            let (b, k, n) = (1 + rng.below(9), 1 + rng.below(33), 1 + rng.below(17));
            let x64 = rng.normal_vec(b * k);
            let wdata = rng.normal_vec(k * n);
            let bias64 = rng.normal_vec(n);
            let w64 = Mat::from_rows(k, n, wdata.clone());
            let w32 = Mat::<f32>::from_f64_rows(k, n, &wdata);
            let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
            let bias32: Vec<f32> = bias64.iter().map(|&v| v as f32).collect();
            for kern in [Kernel::overwrite(), Kernel::overwrite_gelu()] {
                let mut o64 = vec![0.0f64; b * n];
                kern.run(&x64, k, &w64, &bias64, &mut o64);
                let mut o32 = vec![0.0f32; b * n];
                kern.run(&x32, k, &w32, &bias32, &mut o32);
                for (a, f) in o64.iter().zip(&o32) {
                    // f32 eps ~1.2e-7 per op; k ≤ 32 terms of O(1) values
                    // keeps the accumulated error well under 1e-4 relative.
                    let tol = 1e-4 * (1.0 + a.abs());
                    assert!((a - f.to_f64()).abs() < tol, "{kern:?}: {a} vs {f}");
                }
            }
        });
    }

    #[test]
    fn gelu_reference_values() {
        // Spot values from jax.nn.gelu(approximate=True).
        assert!((gelu(0.0)).abs() < 1e-15);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-5);
        assert!((gelu(-2.0) + 0.045402).abs() < 1e-5);
        assert!((gelu(10.0) - 10.0).abs() < 1e-6);
        // f32 flavor tracks the f64 one at f32 precision.
        for v in [-3.0, -0.7, 0.0, 0.9, 2.5] {
            assert!((gelu_f32(v as f32).to_f64() - gelu(v)).abs() < 1e-6);
        }
    }

    #[test]
    fn active_path_defaults_to_best_supported() {
        // No force set by this test binary's other tests (they all use
        // run_with), so auto must pick FMA exactly when the CPU has it.
        let p = active_kernel_path();
        if fma_supported() {
            assert_eq!(p, KernelPath::Fma);
        } else {
            assert_eq!(p, KernelPath::Tiled);
        }
    }
}
