//! Minimal dense row-major matrix ops for the rust-native eps backend.
//!
//! The native backend exists to (a) cross-check PJRT numerics against an
//! independent implementation and (b) run the huge table sweeps without
//! per-call PJRT overhead. Hot path: `matmul_rows` — a blocked ikj kernel
//! the compiler auto-vectorizes (see EXPERIMENTS.md §Perf), parameterized
//! by two compile-time epilogues so the engine never takes a second pass
//! over its activations:
//!
//!   * `ACC`  — accumulate into `out` instead of overwriting it, fusing the
//!     residual `h += gelu(z) @ w2 + b2` update (was matmul + add_inplace).
//!   * `GELU` — apply tanh-GELU to each finished output row while it is
//!     still hot in cache (was matmul + a second full sweep).
//!
//! The kernel takes raw slices, not `Mat`, so callers can feed workspace
//! arenas and batch sub-ranges without copying; `Mat` wrappers remain for
//! coefficient storage and tests.

/// Row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }
}

/// out[b, n] = x[b, k] @ w[k, n] + bias[n]; `out` is fully overwritten.
/// Thin `Mat` wrapper over [`matmul_rows`].
pub fn matmul_bias_into(x: &Mat, w: &Mat, bias: &[f64], out: &mut Mat) {
    assert_eq!((out.rows, out.cols), (x.rows, w.cols));
    matmul_rows::<false, false>(&x.data, x.cols, w, bias, &mut out.data);
}

/// x[rows, kdim] @ w + bias into `out[rows, w.cols]` (rows inferred from
/// `out`). Compile-time epilogues:
///   ACC  = false: out_row  = bias + x_row @ w
///   ACC  = true:  out_row += bias + x_row @ w
///   GELU = true:  out_row  = gelu(out_row)   (applied per finished row)
///
/// ikj order with 2-row x 4-k register blocking: each loaded w row is used
/// for two output rows, halving weight-stream bandwidth (the bottleneck on
/// narrow boxes). Single-threaded by design: batch-level parallelism lives
/// one level up (`score::NativeMlp` fans row chunks across the persistent
/// `score::pool::WorkerPool` once per forward — §Perf in EXPERIMENTS.md
/// showed per-matmul threading eats its own gains).
pub fn matmul_rows<const ACC: bool, const GELU: bool>(
    x: &[f64],
    kdim: usize,
    w: &Mat,
    bias: &[f64],
    out: &mut [f64],
) {
    let n = w.cols;
    assert_eq!(w.rows, kdim);
    assert_eq!(bias.len(), n);
    assert!(kdim > 0 && n > 0, "degenerate matmul shape");
    let rows = out.len() / n;
    assert_eq!(out.len(), rows * n);
    assert_eq!(x.len(), rows * kdim);

    let mut r = 0;
    while r + 2 <= rows {
        let (o_lo, o_hi) = out[r * n..(r + 2) * n].split_at_mut(n);
        if ACC {
            for (o, &bv) in o_lo.iter_mut().zip(bias) {
                *o += bv;
            }
            for (o, &bv) in o_hi.iter_mut().zip(bias) {
                *o += bv;
            }
        } else {
            o_lo.copy_from_slice(bias);
            o_hi.copy_from_slice(bias);
        }
        let xa = &x[r * kdim..(r + 1) * kdim];
        let xb = &x[(r + 1) * kdim..(r + 2) * kdim];
        let mut k = 0;
        while k + 4 <= kdim {
            let (a0, a1, a2, a3) = (xa[k], xa[k + 1], xa[k + 2], xa[k + 3]);
            let (b0, b1, b2, b3) = (xb[k], xb[k + 1], xb[k + 2], xb[k + 3]);
            let w0 = &w.data[k * n..][..n];
            let w1 = &w.data[(k + 1) * n..][..n];
            let w2 = &w.data[(k + 2) * n..][..n];
            let w3 = &w.data[(k + 3) * n..][..n];
            for j in 0..n {
                let (v0, v1, v2, v3) = (w0[j], w1[j], w2[j], w3[j]);
                o_lo[j] += a0 * v0 + a1 * v1 + a2 * v2 + a3 * v3;
                o_hi[j] += b0 * v0 + b1 * v1 + b2 * v2 + b3 * v3;
            }
            k += 4;
        }
        while k < kdim {
            let (av, bv) = (xa[k], xb[k]);
            let wrow = &w.data[k * n..][..n];
            for j in 0..n {
                o_lo[j] += av * wrow[j];
                o_hi[j] += bv * wrow[j];
            }
            k += 1;
        }
        if GELU {
            for v in o_lo.iter_mut() {
                *v = gelu(*v);
            }
            for v in o_hi.iter_mut() {
                *v = gelu(*v);
            }
        }
        r += 2;
    }
    // Tail row (odd batch): plain 4-k unroll.
    if r < rows {
        let orow = &mut out[r * n..(r + 1) * n];
        if ACC {
            for (o, &bv) in orow.iter_mut().zip(bias) {
                *o += bv;
            }
        } else {
            orow.copy_from_slice(bias);
        }
        let xrow = &x[r * kdim..(r + 1) * kdim];
        let mut k = 0;
        while k + 4 <= kdim {
            let (x0, x1, x2, x3) = (xrow[k], xrow[k + 1], xrow[k + 2], xrow[k + 3]);
            let w0 = &w.data[k * n..][..n];
            let w1 = &w.data[(k + 1) * n..][..n];
            let w2 = &w.data[(k + 2) * n..][..n];
            let w3 = &w.data[(k + 3) * n..][..n];
            for j in 0..n {
                orow[j] += x0 * w0[j] + x1 * w1[j] + x2 * w2[j] + x3 * w3[j];
            }
            k += 4;
        }
        while k < kdim {
            let xv = xrow[k];
            let wrow = &w.data[k * n..][..n];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
            k += 1;
        }
        if GELU {
            for v in orow.iter_mut() {
                *v = gelu(*v);
            }
        }
    }
}

/// tanh-approximate GELU — must match jax.nn.gelu(approximate=True) used by
/// both L1 kernels and the jnp oracle.
#[inline]
pub fn gelu(x: f64) -> f64 {
    const C: f64 = 0.797_884_560_802_865_4; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

pub fn gelu_inplace(m: &mut Mat) {
    gelu_slice(&mut m.data);
}

/// GELU over a raw slice (workspace form of [`gelu_inplace`]).
pub fn gelu_slice(xs: &mut [f64]) {
    for v in xs.iter_mut() {
        *v = gelu(*v);
    }
}

/// out += a (elementwise).
pub fn add_inplace(out: &mut Mat, a: &Mat) {
    assert_eq!(out.data.len(), a.data.len());
    for (o, &v) in out.data.iter_mut().zip(&a.data) {
        *o += v;
    }
}

/// out[r, :] += bias
pub fn add_bias_inplace(out: &mut Mat, bias: &[f64]) {
    for r in 0..out.rows {
        for (o, &b) in out.row_mut(r).iter_mut().zip(bias) {
            *o += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop::run_prop, rng::Rng};

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_rows(r, c, rng.normal_vec(r * c))
    }

    /// Naive triple loop as the oracle.
    fn matmul_naive(x: &Mat, w: &Mat, bias: &[f64]) -> Mat {
        let mut out = Mat::zeros(x.rows, w.cols);
        for r in 0..x.rows {
            for c in 0..w.cols {
                let mut acc = bias[c];
                for k in 0..x.cols {
                    acc += x.data[r * x.cols + k] * w.data[k * w.cols + c];
                }
                out.data[r * w.cols + c] = acc;
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        run_prop("matmul", 17, 30, |rng| {
            let (b, k, n) = (1 + rng.below(9), 1 + rng.below(9), 1 + rng.below(9));
            let x = rand_mat(rng, b, k);
            let w = rand_mat(rng, k, n);
            let bias = rng.normal_vec(n);
            let mut got = Mat::zeros(b, n);
            matmul_bias_into(&x, &w, &bias, &mut got);
            let want = matmul_naive(&x, &w, &bias);
            for (g, w_) in got.data.iter().zip(&want.data) {
                assert!((g - w_).abs() < 1e-10);
            }
        });
    }

    #[test]
    fn blocked_kernel_matches_naive_on_larger_shapes() {
        let mut rng = Rng::new(42);
        let (b, k, n) = (512, 64, 64);
        let x = rand_mat(&mut rng, b, k);
        let w = rand_mat(&mut rng, k, n);
        let bias = rng.normal_vec(n);
        let mut got = Mat::zeros(b, n);
        matmul_bias_into(&x, &w, &bias, &mut got);
        let want = matmul_naive(&x, &w, &bias);
        for (g, w_) in got.data.iter().zip(&want.data) {
            assert!((g - w_).abs() < 1e-9);
        }
    }

    #[test]
    fn gelu_epilogue_matches_two_pass() {
        run_prop("matmul gelu epilogue", 19, 30, |rng| {
            let (b, k, n) = (1 + rng.below(7), 1 + rng.below(7), 1 + rng.below(7));
            let x = rand_mat(rng, b, k);
            let w = rand_mat(rng, k, n);
            let bias = rng.normal_vec(n);
            let mut fused = Mat::zeros(b, n);
            matmul_rows::<false, true>(&x.data, k, &w, &bias, &mut fused.data);
            let mut two_pass = Mat::zeros(b, n);
            matmul_bias_into(&x, &w, &bias, &mut two_pass);
            gelu_inplace(&mut two_pass);
            for (f, t) in fused.data.iter().zip(&two_pass.data) {
                assert!((f - t).abs() < 1e-14, "{f} vs {t}");
            }
        });
    }

    #[test]
    fn acc_epilogue_matches_matmul_plus_add() {
        run_prop("matmul acc epilogue", 23, 30, |rng| {
            let (b, k, n) = (1 + rng.below(7), 1 + rng.below(7), 1 + rng.below(7));
            let x = rand_mat(rng, b, k);
            let w = rand_mat(rng, k, n);
            let bias = rng.normal_vec(n);
            let base = rand_mat(rng, b, n);
            // Fused: out starts at `base`, accumulates bias + x@w.
            let mut fused = base.clone();
            matmul_rows::<true, false>(&x.data, k, &w, &bias, &mut fused.data);
            // Reference: separate matmul then add.
            let mut tmp = Mat::zeros(b, n);
            matmul_bias_into(&x, &w, &bias, &mut tmp);
            let mut want = base;
            add_inplace(&mut want, &tmp);
            for (f, t) in fused.data.iter().zip(&want.data) {
                assert!((f - t).abs() < 1e-12, "{f} vs {t}");
            }
        });
    }

    #[test]
    fn gelu_reference_values() {
        // Spot values from jax.nn.gelu(approximate=True).
        assert!((gelu(0.0)).abs() < 1e-15);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-5);
        assert!((gelu(-2.0) + 0.045402).abs() < 1e-5);
        assert!((gelu(10.0) - 10.0).abs() < 1e-6);
    }

    #[test]
    fn add_ops() {
        let mut a = Mat::from_rows(2, 2, vec![1., 2., 3., 4.]);
        add_inplace(&mut a, &Mat::from_rows(2, 2, vec![10., 10., 10., 10.]));
        add_bias_inplace(&mut a, &[1., -1.]);
        assert_eq!(a.data, vec![12., 11., 14., 13.]);
    }
}
