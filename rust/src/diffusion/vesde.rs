//! Variance-exploding SDE (Song et al. 2020b) with geometric σ schedule:
//! σ(t) = σ_min (σ_max/σ_min)^t, g²(t) = dσ²/dt.

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VeSde {
    pub sigma_min: f64,
    pub sigma_max: f64,
}

impl Default for VeSde {
    fn default() -> Self {
        VeSde { sigma_min: 0.01, sigma_max: 50.0 }
    }
}

impl VeSde {
    pub fn sigma(&self, t: f64) -> f64 {
        self.sigma_min * (self.sigma_max / self.sigma_min).powf(t)
    }

    pub fn g2(&self, t: f64) -> f64 {
        let s = self.sigma(t);
        2.0 * (self.sigma_max / self.sigma_min).ln() * s * s
    }

    pub fn t_of_sigma(&self, sigma: f64) -> f64 {
        (sigma / self.sigma_min).ln() / (self.sigma_max / self.sigma_min).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints() {
        let s = VeSde::default();
        assert!((s.sigma(0.0) - 0.01).abs() < 1e-12);
        assert!((s.sigma(1.0) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn g2_is_dsigma2_dt() {
        let s = VeSde::default();
        let (t, h) = (0.6, 1e-7);
        let fd = (s.sigma(t + h).powi(2) - s.sigma(t - h).powi(2)) / (2.0 * h);
        assert!((fd / s.g2(t) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn t_of_sigma_inverts() {
        let s = VeSde::default();
        for i in 0..=10 {
            let t = i as f64 / 10.0;
            assert!((s.t_of_sigma(s.sigma(t)) - t).abs() < 1e-12);
        }
    }
}
