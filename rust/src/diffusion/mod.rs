//! Scalar diffusion SDEs (paper §2, Tab. 1) and the maps DEIS needs:
//! ᾱ(t), marginal σ(t), transition Ψ(t,s), the ρ rescaling of Prop. 3 and
//! its inverse, and the ε-form ODE integrand of Eq. (11)/(15).
//!
//! Mirrors python/compile/sde.py exactly; the cross-language parity fixtures
//! (rust/tests/parity.rs) fail if the two drift apart.

mod vesde;
mod vpsde;

pub use vesde::VeSde;
pub use vpsde::VpSde;

/// Default sampling end time: the score blows up at t = 0 (paper App. H.1),
/// so trajectories stop at a small t0 > 0.
pub const T0_VP: f64 = 1e-3;
pub const T0_VE: f64 = 1e-5;
pub const T_MAX: f64 = 1.0;

/// A scalar (isotropic) diffusion SDE dx = f(t) x dt + g(t) dw.
///
/// Everything DEIS needs reduces to scalar functions of t for VP/VE; the
/// matrix notation of the paper collapses to these maps.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sde {
    Vp(VpSde),
    Ve(VeSde),
}

impl Sde {
    pub fn vp() -> Sde {
        Sde::Vp(VpSde::default())
    }

    pub fn ve() -> Sde {
        Sde::Ve(VeSde::default())
    }

    pub fn name(&self) -> &'static str {
        match self {
            Sde::Vp(_) => "vp",
            Sde::Ve(_) => "ve",
        }
    }

    /// Stable identity for hashable cache keys (`solvers::cache::PlanKey`):
    /// (variant discriminant, parameter bit patterns). `Sde` itself cannot
    /// be `Eq`/`Hash` because of the f64 parameters.
    pub fn key_bits(&self) -> (u8, u64, u64) {
        match self {
            Sde::Vp(s) => (0, s.beta0.to_bits(), s.beta1.to_bits()),
            Sde::Ve(s) => (1, s.sigma_min.to_bits(), s.sigma_max.to_bits()),
        }
    }

    /// log ᾱ(t) (0 for VE).
    pub fn log_abar(&self, t: f64) -> f64 {
        match self {
            Sde::Vp(s) => s.log_abar(t),
            Sde::Ve(_) => 0.0,
        }
    }

    pub fn abar(&self, t: f64) -> f64 {
        self.log_abar(t).exp()
    }

    pub fn sqrt_abar(&self, t: f64) -> f64 {
        (0.5 * self.log_abar(t)).exp()
    }

    /// Marginal std of x_t | x_0 — the scalar L_t of the paper.
    pub fn sigma(&self, t: f64) -> f64 {
        match self {
            Sde::Vp(s) => s.sigma(t),
            Sde::Ve(s) => s.sigma(t),
        }
    }

    /// Drift coefficient f(t) (x-multiplier).
    pub fn f_scalar(&self, t: f64) -> f64 {
        match self {
            Sde::Vp(s) => -0.5 * s.beta(t),
            Sde::Ve(_) => 0.0,
        }
    }

    /// Squared diffusion coefficient g(t)^2.
    pub fn g2(&self, t: f64) -> f64 {
        match self {
            Sde::Vp(s) => s.beta(t),
            Sde::Ve(s) => s.g2(t),
        }
    }

    /// Transition scalar Ψ(t, s) = exp(∫_s^t f). VP: √(ᾱ_t/ᾱ_s); VE: 1.
    pub fn psi(&self, t_to: f64, t_from: f64) -> f64 {
        match self {
            Sde::Vp(s) => (0.5 * (s.log_abar(t_to) - s.log_abar(t_from))).exp(),
            Sde::Ve(_) => 1.0,
        }
    }

    /// DEIS time rescaling (Prop. 3): ρ = √((1−ᾱ)/ᾱ) for VP, σ for VE.
    /// Monotone increasing in t; the transformed ODE is dŷ/dρ = ε̂(ŷ, ρ).
    pub fn rho(&self, t: f64) -> f64 {
        match self {
            Sde::Vp(s) => s.rho(t),
            Sde::Ve(s) => s.sigma(t),
        }
    }

    /// Inverse of `rho` (closed form for both schedules).
    pub fn t_of_rho(&self, rho: f64) -> f64 {
        match self {
            Sde::Vp(s) => s.t_of_rho(rho),
            Sde::Ve(s) => s.t_of_sigma(rho),
        }
    }

    /// The ε-form ODE weight of Eq. (11)/(15): ½ Ψ(t_target, τ) g²(τ)/σ(τ).
    /// Integrating this (× a Lagrange basis) over [t_i, t_{i−1}] gives C_ij.
    pub fn eps_integrand(&self, t_target: f64, tau: f64) -> f64 {
        0.5 * self.psi(t_target, tau) * self.g2(tau) / self.sigma(tau)
    }

    /// Scale mapping state x to the ρ-ODE variable ŷ = x/√ᾱ (identity for VE).
    pub fn y_of_x(&self, x: f64, t: f64) -> f64 {
        x / self.sqrt_abar(t)
    }

    pub fn x_of_y(&self, y: f64, t: f64) -> f64 {
        y * self.sqrt_abar(t)
    }

    /// Std of the prior π(x_T) the sampler starts from.
    pub fn prior_std(&self, t_max: f64) -> f64 {
        match self {
            Sde::Vp(_) => 1.0,
            Sde::Ve(s) => s.sigma(t_max),
        }
    }

    pub fn t0_default(&self) -> f64 {
        match self {
            Sde::Vp(_) => T0_VP,
            Sde::Ve(_) => T0_VE,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vp_boundaries() {
        let sde = Sde::vp();
        assert!((sde.abar(0.0) - 1.0).abs() < 1e-12);
        assert!(sde.abar(1.0) < 1e-4, "abar(T) = {}", sde.abar(1.0));
        assert!((sde.sigma(1.0) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn rho_identity_vp() {
        // ρ √ᾱ == √(1−ᾱ): the Prop 3 rescaling identity.
        let sde = Sde::vp();
        for i in 1..50 {
            let t = i as f64 / 50.0;
            let lhs = sde.rho(t) * sde.sqrt_abar(t);
            assert!((lhs - sde.sigma(t)).abs() < 1e-10, "t={t}");
        }
    }

    #[test]
    fn t_of_rho_roundtrip_both() {
        for sde in [Sde::vp(), Sde::ve()] {
            for i in 1..40 {
                let t = 0.001 + 0.999 * i as f64 / 40.0;
                let back = sde.t_of_rho(sde.rho(t));
                assert!((back - t).abs() < 1e-9, "{} t={t} back={back}", sde.name());
            }
        }
    }

    #[test]
    fn psi_cocycle() {
        let sde = Sde::vp();
        let (a, b, c) = (0.9, 0.5, 0.2);
        let direct = sde.psi(c, a);
        let chained = sde.psi(c, b) * sde.psi(b, a);
        assert!((direct - chained).abs() < 1e-12);
        assert!((sde.psi(a, a) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn rho_monotone() {
        for sde in [Sde::vp(), Sde::ve()] {
            let mut last = sde.rho(1e-4);
            for i in 1..100 {
                let t = 1e-4 + i as f64 / 100.0 * (1.0 - 1e-4);
                let r = sde.rho(t);
                assert!(r > last, "{} rho not monotone at t={t}", sde.name());
                last = r;
            }
        }
    }

    #[test]
    fn f_g_consistent_with_abar_vp() {
        // d log ᾱ/dt == -g²(t) == 2 f(t) (finite-difference check).
        let sde = Sde::vp();
        let (t, h) = (0.37, 1e-6);
        let d = (sde.log_abar(t + h) - sde.log_abar(t - h)) / (2.0 * h);
        assert!((d + sde.g2(t)).abs() < 1e-6);
        assert!((d - 2.0 * sde.f_scalar(t)).abs() < 1e-6);
    }
}
