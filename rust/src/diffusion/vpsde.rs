//! Variance-preserving SDE (Ho et al. 2020) with the linear-β schedule of
//! Song et al. 2020b: β(t) = β₀ + t(β₁−β₀), log ᾱ(t) = −(β₀t + ½t²(β₁−β₀)).

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VpSde {
    pub beta0: f64,
    pub beta1: f64,
}

impl Default for VpSde {
    fn default() -> Self {
        VpSde { beta0: 0.1, beta1: 20.0 }
    }
}

impl VpSde {
    pub fn beta(&self, t: f64) -> f64 {
        self.beta0 + t * (self.beta1 - self.beta0)
    }

    pub fn log_abar(&self, t: f64) -> f64 {
        -0.5 * t * t * (self.beta1 - self.beta0) - t * self.beta0
    }

    pub fn abar(&self, t: f64) -> f64 {
        self.log_abar(t).exp()
    }

    /// Marginal std √(1−ᾱ(t)).
    pub fn sigma(&self, t: f64) -> f64 {
        // Stable for small t: 1−exp(x) = −expm1(x).
        (-self.log_abar(t).exp_m1()).max(0.0).sqrt()
    }

    pub fn rho(&self, t: f64) -> f64 {
        let a = self.abar(t);
        ((1.0 - a) / a).max(0.0).sqrt()
    }

    /// Closed-form inverse of ρ(t): ᾱ = 1/(1+ρ²) then solve the quadratic
    /// ½(β₁−β₀)t² + β₀ t + log ᾱ = 0 for its positive root.
    pub fn t_of_rho(&self, rho: f64) -> f64 {
        let log_abar = -(rho * rho).ln_1p();
        let a = 0.5 * (self.beta1 - self.beta0);
        let b = self.beta0;
        ((b * b - 4.0 * a * log_abar).sqrt() - b) / (2.0 * a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn beta_endpoints() {
        let s = VpSde::default();
        assert_eq!(s.beta(0.0), 0.1);
        assert_eq!(s.beta(1.0), 20.0);
    }

    #[test]
    fn sigma_small_t_stable() {
        let s = VpSde::default();
        let t = 1e-8;
        // σ² ≈ β₀ t for tiny t.
        let sig = s.sigma(t);
        assert!((sig * sig / (0.1 * t) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn t_of_rho_inverts() {
        let s = VpSde::default();
        for i in 1..=20 {
            let t = i as f64 / 20.0;
            assert!((s.t_of_rho(s.rho(t)) - t).abs() < 1e-10);
        }
    }
}
