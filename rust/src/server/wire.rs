//! Wire-cost layer for the serving front end: a zero-copy submit parse and
//! a direct reply writer with an opt-in binary sample frame.
//!
//! ## Fast parse ([`parse_submit_fast`])
//!
//! The submit line is by far the most common thing a connection sends, and
//! parsing it through [`Json::parse`] allocates an owned tree (a `BTreeMap`
//! plus one `String` per key and string value) that is thrown away
//! immediately after field extraction. The fast path scans the line once
//! with [`Scanner`], borrowing every string straight from the line buffer,
//! and builds the [`SampleRequest`] directly — the only allocations are the
//! ones the request itself owns.
//!
//! Parity contract: the fast path succeeds **only** when it would produce
//! exactly what the tree path produces. Anything else — a `"cmd"` key
//! (introspection), an escape in a wanted string, a wrong-typed value,
//! malformed JSON — returns `Ok(None)`/`Err`, and the caller re-parses
//! through the owned tree, which remains the single source of truth for
//! every error text a client sees. Duplicate keys resolve last-wins on both
//! paths (the tree's `BTreeMap::insert` semantics).
//!
//! ## Reply writer ([`write_reply`])
//!
//! Replies are serialized straight into the connection's outbound byte
//! buffer with no [`Json`] tree. The JSON form is byte-identical to the
//! tree writer's (same alphabetical key order as `BTreeMap` iteration, same
//! number formatting via [`write_f64`]) — pinned by a unit test, so
//! existing clients cannot tell the difference.
//!
//! ## Binary sample frame (`"frame":"bin"`)
//!
//! Sample rows dominate response bytes (a shortest-roundtrip f64 averages
//! ~21 JSON characters vs 8 raw bytes). A submit carrying `"frame":"bin"`
//! together with `"return_samples":true` gets its samples as a
//! length-prefixed binary frame instead of a JSON array:
//!
//! ```text
//!   {"bin_bytes":4096,...,"frame":"bin",...,"ok":true,...,"rows":256,...}\n
//!   <bin_bytes raw bytes: rows x dim little-endian f64, row-major>
//! ```
//!
//! The header is a normal JSON reply line (all the usual keys except
//! `samples`, plus `frame`, `rows` and `bin_bytes`); exactly `bin_bytes`
//! payload bytes follow the newline, with **no** trailing newline — the
//! next reply starts right after the payload. Error replies and
//! `"return_samples":false` replies are always plain JSON lines, whatever
//! frame was requested. Clients must bound `bin_bytes` before trusting it;
//! [`MAX_BIN_REPLY_BYTES`] is the cap the built-in client enforces.

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::{SampleRequest, SampleResult};
use crate::diffusion::Sde;
use crate::score::Precision;
use crate::solvers::SolverKind;
use crate::timegrid::GridKind;
use crate::util::json::{write_escaped, write_f64, Json, NumTok, Scanner};

use super::parse_request;

/// How sample payloads ride the reply: a JSON array (the default) or the
/// length-prefixed binary frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Frame {
    Json,
    Bin,
}

/// A fully parsed submit line: the request plus the reply-shaping options
/// that are wire concerns, not coordinator concerns.
#[derive(Clone, Debug)]
pub struct SubmitArgs {
    pub req: SampleRequest,
    pub return_samples: bool,
    pub frame: Frame,
}

/// What the reply writer needs to know about the request after the
/// coordinator has taken ownership of it.
#[derive(Clone, Copy, Debug)]
pub struct ReplyMeta {
    /// Requested sample count (echoed as `n`).
    pub n: usize,
    /// Requested precision (echoed as `dtype`).
    pub dtype: Precision,
    pub return_samples: bool,
    pub frame: Frame,
}

impl SubmitArgs {
    pub fn meta(&self) -> ReplyMeta {
        ReplyMeta {
            n: self.req.n_samples,
            dtype: self.req.dtype,
            return_samples: self.return_samples,
            frame: self.frame,
        }
    }
}

/// Hard cap a client puts on `bin_bytes` before allocating the payload
/// buffer (1 GiB — far above any real reply, far below an allocation bomb).
pub const MAX_BIN_REPLY_BYTES: u64 = 1 << 30;

/// Zero-copy parse of one submit line. `Ok(None)` means the line carries a
/// `"cmd"` key and belongs to the introspection path; `Err` means the fast
/// path cannot represent the line faithfully (escapes, type surprises,
/// malformed JSON, or a genuinely invalid request) and the caller must
/// re-parse through the owned tree — which then owns the error text.
pub fn parse_submit_fast(line: &str) -> Result<Option<SubmitArgs>> {
    let mut sc = Scanner::new(line);
    sc.begin_object()?;
    let mut model: Option<&str> = None;
    let mut solver: Option<&str> = None;
    let mut sde: Option<&str> = None;
    let mut grid: Option<&str> = None;
    let mut nfe: Option<NumTok> = None;
    let mut n: Option<NumTok> = None;
    let mut t0: Option<NumTok> = None;
    let mut seed: Option<NumTok> = None;
    let mut deadline_ms: Option<NumTok> = None;
    let mut dtype: Option<&str> = None;
    let mut return_samples: Option<bool> = None;
    let mut frame: Option<&str> = None;
    while let Some(key) = sc.next_key()? {
        match key {
            "cmd" => return Ok(None),
            "model" => model = Some(sc.value_str()?),
            "solver" => solver = Some(sc.value_str()?),
            "sde" => sde = Some(sc.value_str()?),
            "grid" => grid = Some(sc.value_str()?),
            "nfe" => nfe = Some(sc.value_num()?),
            "n" => n = Some(sc.value_num()?),
            "t0" => t0 = Some(sc.value_num()?),
            "seed" => seed = Some(sc.value_num()?),
            "deadline_ms" => deadline_ms = Some(sc.value_num()?),
            "dtype" => dtype = Some(sc.value_str()?),
            "return_samples" => return_samples = Some(sc.value_bool()?),
            "frame" => frame = Some(sc.value_str()?),
            _ => sc.skip_value()?,
        }
    }
    sc.end()?;
    // Conversion, in the exact order the owned path checks things
    // (return_samples -> frame -> parse_request's field order). These error
    // texts match the tree path's, but no client ever sees them: the caller
    // falls back on ANY Err, and the re-parse reproduces the error.
    let return_samples = return_samples.unwrap_or(false);
    let frame = parse_frame(frame)?;
    let model = model.ok_or_else(|| anyhow!("missing key 'model'"))?;
    let solver = SolverKind::parse(solver.ok_or_else(|| anyhow!("missing key 'solver'"))?)
        .with_context(|| "unknown solver")?;
    let sde = match sde.unwrap_or("vp") {
        "vp" => Sde::vp(),
        "ve" => Sde::ve(),
        other => bail!("unknown sde '{other}'"),
    };
    let grid = match grid {
        Some(g) => GridKind::parse(g).with_context(|| "unknown grid")?,
        None => GridKind::Quadratic,
    };
    let mut req = SampleRequest::new(
        model,
        solver,
        nfe.ok_or_else(|| anyhow!("missing key 'nfe'"))?.as_usize()?,
        n.ok_or_else(|| anyhow!("missing key 'n'"))?.as_usize()?,
    );
    req.sde = sde;
    req.grid = grid;
    req.t0 = t0.map(|x| x.as_f64()).unwrap_or(sde.t0_default());
    req.seed = seed.map(|x| x.as_u64()).transpose()?.unwrap_or(0);
    req.deadline_ms = deadline_ms.map(|x| x.as_usize()).transpose()?.map(|ms| ms as u64);
    if let Some(s) = dtype {
        req.dtype = Precision::parse(s)
            .with_context(|| format!("unknown dtype '{s}' (expected \"f32\" or \"f64\")"))?;
    }
    Ok(Some(SubmitArgs { req, return_samples, frame }))
}

/// Owned-tree submit parse — the fallback and the reference. Shares
/// [`parse_request`] with the tests that call it directly.
pub fn submit_args_from_json(v: &Json) -> Result<SubmitArgs> {
    let return_samples =
        v.opt("return_samples").map(|b| b.as_bool()).transpose()?.unwrap_or(false);
    let frame = parse_frame(v.opt("frame").map(|f| f.as_str()).transpose()?)?;
    let req = parse_request(v)?;
    Ok(SubmitArgs { req, return_samples, frame })
}

fn parse_frame(s: Option<&str>) -> Result<Frame> {
    match s {
        None | Some("json") => Ok(Frame::Json),
        Some("bin") => Ok(Frame::Bin),
        Some(other) => bail!("unknown frame '{other}' (expected \"json\" or \"bin\")"),
    }
}

/// Append one complete reply (newline-terminated line, plus the binary
/// payload when the request asked for it) to the connection's outbound
/// buffer. The JSON form is byte-identical to the old tree-built reply.
pub fn write_reply(out: &mut Vec<u8>, meta: &ReplyMeta, res: &Result<SampleResult>) {
    match res {
        Err(e) => error_reply(out, &format!("{e:#}")),
        Ok(r) if meta.return_samples && meta.frame == Frame::Bin => {
            let payload = samples_to_le_bytes(&r.samples);
            let rows = r.samples.len() / r.dim.max(1);
            let mut s = String::new();
            s.push_str("{\"bin_bytes\":");
            write_f64(&mut s, payload.len() as f64);
            push_common_fields(&mut s, meta, r, true);
            s.push_str(",\"rows\":");
            write_f64(&mut s, rows as f64);
            s.push_str(",\"solve_us\":");
            write_f64(&mut s, r.solve_us as f64);
            s.push_str("}\n");
            out.extend_from_slice(s.as_bytes());
            out.extend_from_slice(&payload);
        }
        Ok(r) => {
            let mut s = String::new();
            s.push_str("{\"co_batched\":");
            write_f64(&mut s, r.co_batched as f64);
            s.push_str(",\"dim\":");
            write_f64(&mut s, r.dim as f64);
            s.push_str(",\"dtype\":");
            write_escaped(&mut s, meta.dtype.name());
            push_tail_fields(&mut s, meta, r);
            if meta.return_samples {
                s.push_str(",\"samples\":[");
                for (i, &x) in r.samples.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    write_f64(&mut s, x);
                }
                s.push(']');
            }
            s.push_str(",\"solve_us\":");
            write_f64(&mut s, r.solve_us as f64);
            s.push_str("}\n");
            out.extend_from_slice(s.as_bytes());
        }
    }
}

/// `co_batched` .. `queue_us` for the bin header (which interleaves its own
/// keys to keep the alphabetical order the tree writer would have used).
fn push_common_fields(s: &mut String, meta: &ReplyMeta, r: &SampleResult, bin: bool) {
    s.push_str(",\"co_batched\":");
    write_f64(s, r.co_batched as f64);
    s.push_str(",\"dim\":");
    write_f64(s, r.dim as f64);
    s.push_str(",\"dtype\":");
    write_escaped(s, meta.dtype.name());
    if bin {
        s.push_str(",\"frame\":\"bin\"");
    }
    push_tail_fields(s, meta, r);
}

/// `merged_with` .. `queue_us` — identical between the JSON and bin shapes.
fn push_tail_fields(s: &mut String, meta: &ReplyMeta, r: &SampleResult) {
    s.push_str(",\"merged_with\":");
    write_f64(s, r.merged_with as f64);
    s.push_str(",\"n\":");
    write_f64(s, meta.n as f64);
    s.push_str(",\"nfe\":");
    write_f64(s, r.nfe as f64);
    s.push_str(",\"ok\":true,\"queue_us\":");
    write_f64(s, r.queue_us as f64);
}

/// Append the standard error reply line ({"error":...,"ok":false}\n —
/// byte-identical to the tree-built form).
pub fn error_reply(out: &mut Vec<u8>, msg: &str) {
    let mut s = String::new();
    s.push_str("{\"error\":");
    write_escaped(&mut s, msg);
    s.push_str(",\"ok\":false}\n");
    out.extend_from_slice(s.as_bytes());
}

/// Zero-copy scan of one reply line for the binary-frame marker: returns
/// `Ok(Some(bin_bytes))` for a `"frame":"bin"` header line, `Ok(None)` for
/// a plain JSON reply. The router's passthrough calls this per relayed
/// reply line to learn how many raw payload bytes follow — and because
/// [`write_reply`] emits `bin_bytes` as the alphabetically FIRST key, a
/// bin header resolves after scanning exactly one key. Errs on malformed
/// JSON (the caller treats that as upstream protocol corruption).
pub fn reply_bin_bytes(line: &str) -> Result<Option<u64>> {
    let mut sc = Scanner::new(line);
    sc.begin_object()?;
    while let Some(key) = sc.next_key()? {
        if key == "bin_bytes" {
            return Ok(Some(sc.value_num()?.as_u64()?));
        }
        sc.skip_value()?;
    }
    Ok(None)
}

/// Row-major f64 samples -> little-endian payload bytes.
pub fn samples_to_le_bytes(samples: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(samples.len() * 8);
    for &x in samples {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Payload bytes -> f64 samples (bit-exact; errs on a ragged byte count).
pub fn samples_from_le_bytes(bytes: &[u8]) -> Result<Vec<f64>> {
    if bytes.len() % 8 != 0 {
        bail!("binary frame length {} is not a multiple of 8", bytes.len());
    }
    Ok(bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().expect("chunks_exact(8)")))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast(line: &str) -> SubmitArgs {
        parse_submit_fast(line).unwrap().expect("not a cmd line")
    }

    fn owned(line: &str) -> SubmitArgs {
        submit_args_from_json(&Json::parse(line).unwrap()).unwrap()
    }

    fn assert_same(line: &str) {
        let (a, b) = (fast(line), owned(line));
        assert_eq!(a.req.model, b.req.model, "{line}");
        assert_eq!(a.req.solver, b.req.solver, "{line}");
        assert_eq!(a.req.sde.key_bits(), b.req.sde.key_bits(), "{line}");
        assert_eq!(a.req.grid, b.req.grid, "{line}");
        assert_eq!(a.req.t0.to_bits(), b.req.t0.to_bits(), "{line}");
        assert_eq!(a.req.nfe, b.req.nfe, "{line}");
        assert_eq!(a.req.n_samples, b.req.n_samples, "{line}");
        assert_eq!(a.req.seed, b.req.seed, "{line}");
        assert_eq!(a.req.deadline_ms, b.req.deadline_ms, "{line}");
        assert_eq!(a.req.dtype, b.req.dtype, "{line}");
        assert_eq!(a.return_samples, b.return_samples, "{line}");
        assert_eq!(a.frame, b.frame, "{line}");
    }

    #[test]
    fn fast_parse_matches_the_tree_parse() {
        for line in [
            r#"{"model":"gmm2d","solver":"tab3","nfe":10,"n":4}"#,
            r#"{"model":"gmm2d","solver":"ddim","nfe":5,"n":4,"return_samples":true}"#,
            // every optional key at once, plus whitespace tolerance
            r#" {"model": "gmm2d", "solver": "rho-ab2", "sde": "ve", "grid": "uniform",
                "nfe": 12, "n": 7, "t0": 1e-4, "seed": 42, "deadline_ms": 250,
                "dtype": "f64", "return_samples": true, "frame": "bin"} "#,
            // seed above 2^53 must stay exact on both paths
            r#"{"model":"m","solver":"tab3","nfe":10,"n":4,"seed":1152921504606846977}"#,
            // unknown keys are skipped, however deep
            r#"{"model":"m","solver":"tab3","nfe":10,"n":4,"extra":{"deep":[1,"a\"b",{}]}}"#,
            // duplicate keys resolve last-wins (the tree's BTreeMap::insert)
            r#"{"model":"a","solver":"tab3","nfe":10,"n":4,"model":"b","nfe":3}"#,
            r#"{"model":"m","solver":"tab3","nfe":10,"n":4,"frame":"json"}"#,
        ] {
            assert_same(line);
        }
    }

    #[test]
    fn fast_parse_defers_cmds_and_anything_it_cannot_borrow() {
        // cmd lines route to the introspection path, wherever the key sits.
        assert!(parse_submit_fast(r#"{"cmd":"stats"}"#).unwrap().is_none());
        assert!(parse_submit_fast(r#"{"model":"m","cmd":"stats"}"#).unwrap().is_none());
        // Everything else unrepresentable errs into the tree fallback.
        for line in [
            r#"{"model":"a\nb","solver":"tab3","nfe":10,"n":4}"#, // escape in wanted string
            r#"{"model":"m","solver":"tab3","nfe":"ten","n":4}"#, // wrong-typed number
            r#"{"model":"m","solver":"tab3","nfe":10,"n":4} x"#,  // trailing data
            r#"not json"#,
            r#"{"model":"m","solver":"tab3","nfe":10,"n":4"#, // truncated
        ] {
            assert!(parse_submit_fast(line).is_err(), "{line}");
        }
        // Semantically invalid requests err too (the fallback then owns the
        // error text a client sees).
        for line in [
            r#"{"solver":"tab3","nfe":10,"n":4}"#,                    // missing model
            r#"{"model":"m","solver":"bogus","nfe":10,"n":4}"#,       // unknown solver
            r#"{"model":"m","solver":"tab3","nfe":10,"n":4,"frame":"hex"}"#,
            r#"{"model":"m","solver":"tab3","nfe":10,"n":4,"seed":1.5}"#,
        ] {
            assert!(parse_submit_fast(line).is_err(), "{line}");
            assert!(submit_args_from_json(&Json::parse(line).unwrap()).is_err(), "{line}");
        }
    }

    fn sample_result() -> SampleResult {
        SampleResult {
            samples: vec![0.25, -1.5, 1e-3, 0.123456789012345678, -0.0, 3.0],
            dim: 2,
            nfe: 10,
            merged_with: 2,
            co_batched: 3,
            queue_us: 120,
            solve_us: 5300,
        }
    }

    #[test]
    fn json_reply_is_byte_identical_to_the_tree_writer() {
        let r = sample_result();
        for return_samples in [false, true] {
            let meta = ReplyMeta {
                n: 3,
                dtype: Precision::F64,
                return_samples,
                frame: Frame::Json,
            };
            let mut out = Vec::new();
            write_reply(&mut out, &meta, &Ok(r.clone()));
            // The reference: the reply as the old tree path built it.
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("n", Json::num(meta.n as f64)),
                ("dim", Json::num(r.dim as f64)),
                ("nfe", Json::num(r.nfe as f64)),
                ("merged_with", Json::num(r.merged_with as f64)),
                ("co_batched", Json::num(r.co_batched as f64)),
                ("queue_us", Json::num(r.queue_us as f64)),
                ("solve_us", Json::num(r.solve_us as f64)),
                ("dtype", Json::str(meta.dtype.name())),
            ];
            if return_samples {
                fields.push(("samples", Json::arr_f64(&r.samples)));
            }
            let mut want = Json::obj(fields).to_string();
            want.push('\n');
            assert_eq!(String::from_utf8(out).unwrap(), want);
        }
        let mut out = Vec::new();
        error_reply(&mut out, "boom \"quoted\"");
        let mut want = Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::str("boom \"quoted\"")),
        ])
        .to_string();
        want.push('\n');
        assert_eq!(String::from_utf8(out).unwrap(), want);
    }

    #[test]
    fn bin_frame_roundtrips_bit_exactly() {
        let r = sample_result();
        let meta =
            ReplyMeta { n: 3, dtype: Precision::F64, return_samples: true, frame: Frame::Bin };
        let mut out = Vec::new();
        write_reply(&mut out, &meta, &Ok(r.clone()));
        let nl = out.iter().position(|&b| b == b'\n').unwrap();
        let header = Json::parse(std::str::from_utf8(&out[..nl]).unwrap()).unwrap();
        assert!(header.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(header.get("frame").unwrap().as_str().unwrap(), "bin");
        assert_eq!(header.get("rows").unwrap().as_usize().unwrap(), 3);
        assert_eq!(header.get("dim").unwrap().as_usize().unwrap(), 2);
        let bin_bytes = header.get("bin_bytes").unwrap().as_usize().unwrap();
        assert_eq!(bin_bytes, r.samples.len() * 8);
        assert!(header.opt("samples").is_none());
        let payload = &out[nl + 1..];
        assert_eq!(payload.len(), bin_bytes, "no trailing bytes after the payload");
        let back = samples_from_le_bytes(payload).unwrap();
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back), bits(&r.samples), "bit-exact, -0.0 included");
        // Ragged payloads are refused.
        assert!(samples_from_le_bytes(&payload[..9]).is_err());
        // A bin request without return_samples degrades to the plain JSON
        // reply — no frame key, no payload.
        let meta = ReplyMeta { return_samples: false, ..meta };
        let mut out = Vec::new();
        write_reply(&mut out, &meta, &Ok(r));
        assert_eq!(*out.last().unwrap(), b'\n');
        let j = Json::parse(std::str::from_utf8(&out[..out.len() - 1]).unwrap()).unwrap();
        assert!(j.opt("frame").is_none() && j.opt("bin_bytes").is_none());
    }

    #[test]
    fn reply_bin_bytes_classifies_reply_lines() {
        // A real bin header from the writer resolves to its payload size.
        let r = sample_result();
        let meta =
            ReplyMeta { n: 3, dtype: Precision::F64, return_samples: true, frame: Frame::Bin };
        let mut out = Vec::new();
        write_reply(&mut out, &meta, &Ok(r.clone()));
        let nl = out.iter().position(|&b| b == b'\n').unwrap();
        let header = std::str::from_utf8(&out[..nl]).unwrap();
        assert_eq!(reply_bin_bytes(header).unwrap(), Some(r.samples.len() as u64 * 8));
        // Plain JSON replies and error lines carry no payload.
        let mut out = Vec::new();
        write_reply(
            &mut out,
            &ReplyMeta { frame: Frame::Json, ..meta },
            &Ok(sample_result()),
        );
        let line = std::str::from_utf8(&out[..out.len() - 1]).unwrap();
        assert_eq!(reply_bin_bytes(line).unwrap(), None);
        assert_eq!(reply_bin_bytes(r#"{"error":"boom","ok":false}"#).unwrap(), None);
        // Malformed lines are protocol corruption, not "no payload".
        assert!(reply_bin_bytes(r#"{"ok":true"#).is_err());
        assert!(reply_bin_bytes("not json").is_err());
    }
}
