//! Line-JSON TCP front end for the coordinator (std::net; tokio is not in
//! the offline registry — one thread per connection, which is plenty for a
//! sampling service whose unit of work is a whole diffusion trajectory).
//!
//! Wire protocol, one JSON object per line.
//!
//! Sampling request:
//!   -> {"model":"gmm2d","solver":"tab3","grid":"quadratic","nfe":10,
//!       "n":256,"seed":1,"t0":1e-3,"sde":"vp","return_samples":false,
//!       "deadline_ms":500,"dtype":"f64"}
//!   <- {"ok":true,"n":256,"dim":2,"nfe":10,"merged_with":3,"co_batched":5,
//!       "queue_us":120,"solve_us":5300,"dtype":"f64","samples":[...]?}
//!
//! `dtype` (optional, default "f64") selects the inference precision of
//! the model eval. "f32" routes the request to the model's f32 engine —
//! registered as `<model>@f32` when the server runs with `--precision
//! f32`; if no f32 engine exists for the model, the reply is {"ok":false,
//! "error":"model ... has no f32 engine registered ..."}. Any value other
//! than "f32"/"f64" is rejected with {"ok":false,"error":"unknown dtype
//! ..."}. The reply echoes the `dtype` that served the request. Samples
//! are always f64 JSON numbers on the wire regardless of dtype (the f32
//! engine widens its output at the model boundary); f32 results track f64
//! within the documented tolerance (EXPERIMENTS.md §Kernels). f32 and f64
//! requests are never merged or co-batched together — the rewritten model
//! name keys the batch, so the precision class of a reply is exact. In the
//! stats reply, f32 traffic appears under the "<model>@f32" per-model key.
//!
//! `deadline_ms` (optional) is a relative per-request deadline: if the
//! request is still queued or still integrating when it fires, the reply is
//! {"ok":false,"error":"deadline exceeded ..."} instead of samples, and the
//! trajectory is aborted when no other request shares it. Overload
//! (backpressure: more than the coordinator's max in-flight requests) is
//! likewise reported immediately as {"ok":false,"error":"coordinator
//! overloaded ..."} — clients should back off and retry. `nfe` is capped
//! at `coordinator::MAX_REQUEST_NFE` (it sizes the solver-plan build);
//! larger values are rejected with {"ok":false,"error":"nfe ... out of
//! range ..."}.
//!
//! In the reply, `merged_with` counts requests stacked into the same
//! trajectory group at admission, and `co_batched` is the peak number of
//! requests whose ε-evaluations the step-level scheduler dispatched in a
//! single model call with this one. Every solver — deterministic,
//! adaptive (rk45) and stochastic (em/sddim/addim) alike — runs through
//! the scheduler, so `co_batched` is always reported and always
//! >= `merged_with`; there is no blocking fallback path.
//!
//! Introspection:
//!   -> {"cmd":"stats"}            <- {"ok":true,"requests":...}
//!   -> {"cmd":"models"}           <- {"ok":true,"models":[...]}
//!   -> {"cmd":"health"}           <- {"ok":true,"draining":false,
//!                                     "worker_panics":0,
//!                                     "models":{"gmm2d":true,...}}
//!
//! `health` reports graceful-degradation state: `draining` is true once a
//! graceful shutdown began (new requests are refused), `worker_panics`
//! counts scheduler worker threads the supervisor has restarted, and
//! `models` maps each model that has seen traffic to its circuit-breaker
//! state (`true` = healthy/closed, `false` = open: that model's requests
//! are being refused with {"ok":false,"error":"model ... unhealthy ..."}
//! until the breaker's cooldown half-opens it).
//!
//! Stats keys: request lifecycle (`requests`, `completed`, `rejected`,
//! `expired`, `failed`, `samples`), admission merging (`batches`,
//! `merged_requests`), scheduler effectiveness (`model_evals`,
//! `sched_evals`, `sched_eval_requests`, `eval_occupancy`, `max_occupancy`
//! — occupancy k means each scheduled network call served k requests on
//! average), fault containment (`eval_panics` — merged ε-evals that
//! panicked and were contained; `unhealthy` — refusals due to an open
//! circuit breaker, a subset of `rejected`), the shared solver-plan cache
//! (`plan_cache_hits`, `plan_cache_misses` — a hit means admission reused
//! a cached (grid, coefficients) plan instead of rebuilding it), and
//! latency (`p50_us`, `p99_us`, `mean_us`). `rejected` covers every
//! refusal at submit: global overload, per-model overload, out-of-range
//! `nfe`, unknown model names, invalid sampling configs, open circuit
//! breakers and draining shutdowns; `failed` counts requests whose
//! admitted work was lost to a contained fault (eval panic, non-finite
//! model output, panicking solver advance, or work stranded past the drain
//! window) — so `requests == completed + rejected + expired + failed`
//! always balances.
//!
//! The coordinator is sharded by model (one scheduler shard per registered
//! model; see `coordinator/scheduler.rs`), and the stats reply additionally
//! carries an ADDITIVE `per_model` object — one entry per shard (models
//! that have received traffic), keyed by model name:
//!
//!   "per_model": {"gmm2d": {"requests":N,"completed":N,"rejected":N,
//!                           "expired":N,"failed":N,"eval_panics":N,
//!                           "unhealthy":N,"samples":N,"batches":N,
//!                           "merged_requests":N,"model_evals":N,
//!                           "sched_evals":N,"sched_eval_requests":N,
//!                           "eval_occupancy":X,"max_occupancy":N}, ...}
//!
//! Per-model `rejected` counts only refusals attributable to that shard
//! (per-model overload, open breaker, invalid configs); global-overload,
//! unknown-model, draining and nfe-cap refusals appear only in the
//! top-level `rejected`. Each model's lifecycle balances on its own:
//! `requests == completed + rejected + expired + failed` per entry.
//! Existing clients that ignore unknown keys need no migration.
//!
//! Connection hygiene (see [`ServeOptions`]): at most `max_conns`
//! concurrent connections (excess connections get one {"ok":false,
//! "error":"server at connection capacity ..."} line and are closed),
//! request lines are capped at `max_line_bytes` (an over-long line gets an
//! error reply and the connection is closed — the reader never buffers
//! unbounded input), and a connection that goes silent MID-line for longer
//! than `read_timeout` is dropped (slowloris). Idle connections *between*
//! requests are not timed out; they hold a connection slot, which
//! `max_conns` bounds. Replies are written under `write_timeout`.
//!
//! Graceful shutdown is coordinator-level: once `Coordinator::begin_drain`
//! runs (or a drain-based shutdown starts), every new submission — from
//! any connection — is refused with {"ok":false,"error":"coordinator
//! shutting down ..."} while already-admitted work finishes; work still
//! stranded when the drain window closes is answered with the same error
//! rather than left hanging. Introspection (`stats`/`models`/`health`)
//! keeps working throughout, so clients can watch the drain.
//!
//! Latency semantics: latencies are recorded into a lock-free log-bucketed
//! histogram (`coordinator::stats::LatencyHistogram`), not a raw list.
//! `p50_us`/`p99_us` are therefore *bucketed* percentiles — the midpoint of
//! the bucket containing the exact order statistic, within a relative
//! quantization error of at most 2^-5 ≈ 3.1% (exact below 64µs, where
//! buckets have width 1). `mean_us` stays exact (sum and count are tracked
//! directly). The keys, types and meaning are otherwise unchanged from the
//! previous sorted-list implementation; clients need no migration.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::{Coordinator, SampleRequest};
use crate::diffusion::Sde;
use crate::score::Precision;
use crate::solvers::SolverKind;
use crate::timegrid::GridKind;
use crate::util::json::Json;

/// Parse a request line into a SampleRequest.
pub fn parse_request(v: &Json) -> Result<SampleRequest> {
    let model = v.get("model")?.as_str()?.to_string();
    let solver = SolverKind::parse(v.get("solver")?.as_str()?)
        .with_context(|| "unknown solver")?;
    let sde = match v.opt("sde").map(|s| s.as_str()).transpose()?.unwrap_or("vp") {
        "vp" => Sde::vp(),
        "ve" => Sde::ve(),
        other => bail!("unknown sde '{other}'"),
    };
    let grid = match v.opt("grid") {
        Some(g) => GridKind::parse(g.as_str()?).with_context(|| "unknown grid")?,
        None => GridKind::Quadratic,
    };
    let mut req = SampleRequest::new(&model, solver, v.get("nfe")?.as_usize()?,
        v.get("n")?.as_usize()?);
    req.sde = sde;
    req.grid = grid;
    req.t0 = v.opt("t0").map(|x| x.as_f64()).transpose()?.unwrap_or(sde.t0_default());
    // Seeds are u64 and must stay lossless: routing them through f64 would
    // silently collapse every seed above 2^53 (and truncate fractions).
    req.seed = v.opt("seed").map(|x| x.as_u64()).transpose()?.unwrap_or(0);
    req.deadline_ms = v.opt("deadline_ms").map(|x| x.as_usize()).transpose()?.map(|ms| ms as u64);
    if let Some(s) = v.opt("dtype").map(|s| s.as_str()).transpose()? {
        req.dtype = Precision::parse(s)
            .with_context(|| format!("unknown dtype '{s}' (expected \"f32\" or \"f64\")"))?;
    }
    Ok(req)
}

fn handle_line(coord: &Coordinator, line: &str) -> String {
    let reply = (|| -> Result<Json> {
        let v = Json::parse(line)?;
        if let Some(cmd) = v.opt("cmd") {
            return match cmd.as_str()? {
                "stats" => {
                    let s = coord.stats();
                    let per_model: std::collections::BTreeMap<String, Json> = s
                        .per_model
                        .iter()
                        .map(|(name, m)| {
                            (
                                name.clone(),
                                Json::obj(vec![
                                    ("requests", Json::num(m.requests as f64)),
                                    ("completed", Json::num(m.completed as f64)),
                                    ("rejected", Json::num(m.rejected as f64)),
                                    ("expired", Json::num(m.expired as f64)),
                                    ("failed", Json::num(m.failed as f64)),
                                    ("eval_panics", Json::num(m.eval_panics as f64)),
                                    ("unhealthy", Json::num(m.unhealthy as f64)),
                                    ("samples", Json::num(m.samples as f64)),
                                    ("batches", Json::num(m.batches as f64)),
                                    ("merged_requests", Json::num(m.merged_requests as f64)),
                                    ("model_evals", Json::num(m.model_evals as f64)),
                                    ("sched_evals", Json::num(m.sched_evals as f64)),
                                    (
                                        "sched_eval_requests",
                                        Json::num(m.sched_eval_requests as f64),
                                    ),
                                    ("eval_occupancy", Json::num(m.eval_occupancy)),
                                    ("max_occupancy", Json::num(m.max_occupancy as f64)),
                                ]),
                            )
                        })
                        .collect();
                    Ok(Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("requests", Json::num(s.requests as f64)),
                        ("completed", Json::num(s.completed as f64)),
                        ("rejected", Json::num(s.rejected as f64)),
                        ("expired", Json::num(s.expired as f64)),
                        ("failed", Json::num(s.failed as f64)),
                        ("eval_panics", Json::num(s.eval_panics as f64)),
                        ("unhealthy", Json::num(s.unhealthy as f64)),
                        ("samples", Json::num(s.samples as f64)),
                        ("batches", Json::num(s.batches as f64)),
                        ("merged_requests", Json::num(s.merged_requests as f64)),
                        ("model_evals", Json::num(s.model_evals as f64)),
                        ("sched_evals", Json::num(s.sched_evals as f64)),
                        ("sched_eval_requests", Json::num(s.sched_eval_requests as f64)),
                        ("eval_occupancy", Json::num(s.eval_occupancy)),
                        ("max_occupancy", Json::num(s.max_occupancy as f64)),
                        ("plan_cache_hits", Json::num(s.plan_cache_hits as f64)),
                        ("plan_cache_misses", Json::num(s.plan_cache_misses as f64)),
                        ("p50_us", Json::num(s.p50_us as f64)),
                        ("p99_us", Json::num(s.p99_us as f64)),
                        ("mean_us", Json::num(s.mean_us)),
                        ("per_model", Json::Obj(per_model)),
                    ]))
                }
                "models" => Ok(Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    (
                        "models",
                        Json::Arr(coord.models().iter().map(|m| Json::str(m)).collect()),
                    ),
                ])),
                "health" => {
                    let h = coord.health();
                    let models: std::collections::BTreeMap<String, Json> =
                        h.models.into_iter().map(|(n, up)| (n, Json::Bool(up))).collect();
                    Ok(Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("draining", Json::Bool(h.draining)),
                        ("worker_panics", Json::uint(h.worker_panics)),
                        ("models", Json::Obj(models)),
                    ]))
                }
                other => bail!("unknown cmd '{other}'"),
            };
        }
        let return_samples =
            v.opt("return_samples").map(|b| b.as_bool()).transpose()?.unwrap_or(false);
        let req = parse_request(&v)?;
        let n = req.n_samples;
        let dtype = req.dtype;
        let res = coord.sample_blocking(req)?;
        let mut fields = vec![
            ("ok", Json::Bool(true)),
            ("n", Json::num(n as f64)),
            ("dim", Json::num(res.dim as f64)),
            ("nfe", Json::num(res.nfe as f64)),
            ("merged_with", Json::num(res.merged_with as f64)),
            ("co_batched", Json::num(res.co_batched as f64)),
            ("queue_us", Json::num(res.queue_us as f64)),
            ("solve_us", Json::num(res.solve_us as f64)),
            ("dtype", Json::str(dtype.name())),
        ];
        if return_samples {
            fields.push(("samples", Json::arr_f64(&res.samples)));
        }
        Ok(Json::obj(fields))
    })();
    match reply {
        Ok(j) => j.to_string(),
        Err(e) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::str(&format!("{e:#}"))),
        ])
        .to_string(),
    }
}

/// Front-end hardening knobs. The defaults keep a well-behaved client
/// entirely unaffected; they exist to bound what a misbehaving one can
/// cost the process.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Concurrent connections (one thread each). Excess connections get
    /// one "server at connection capacity" error line and are closed.
    pub max_conns: usize,
    /// Longest a connection may sit silent MID-line before it is dropped
    /// (slowloris guard). Idle connections between requests are exempt.
    pub read_timeout: Duration,
    /// Longest a reply write may block on an unread socket.
    pub write_timeout: Duration,
    /// Request-line byte cap: the reader never buffers more than this for
    /// one line. Over-long lines get an error reply and the connection is
    /// closed (the rest of the line is unread, so resync is impossible).
    pub max_line_bytes: usize,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            max_conns: 1024,
            read_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(30),
            max_line_bytes: 256 * 1024,
        }
    }
}

/// Serve until the process dies, with default [`ServeOptions`]. Returns
/// the bound address (port 0 allowed).
pub fn serve(coord: Arc<Coordinator>, addr: &str) -> Result<std::net::SocketAddr> {
    serve_with(coord, addr, ServeOptions::default())
}

/// RAII connection slot: decrements the live-connection count when the
/// connection thread finishes, however it finishes.
struct ConnSlot(Arc<AtomicUsize>);

impl Drop for ConnSlot {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Serve until the process dies, with explicit hardening options.
pub fn serve_with(
    coord: Arc<Coordinator>,
    addr: &str,
    opts: ServeOptions,
) -> Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let conns = Arc::new(AtomicUsize::new(0));
    std::thread::spawn(move || {
        for stream in listener.incoming().flatten() {
            // Admission at the accept loop: a full house sheds the new
            // connection with one error line instead of spawning a thread
            // the box has no budget for.
            if conns.fetch_add(1, Ordering::SeqCst) >= opts.max_conns.max(1) {
                conns.fetch_sub(1, Ordering::SeqCst);
                let mut s = stream;
                let _ = s.set_write_timeout(Some(opts.write_timeout));
                let _ = s.write_all(
                    Json::obj(vec![
                        ("ok", Json::Bool(false)),
                        (
                            "error",
                            Json::str(&format!(
                                "server at connection capacity ({}); retry later",
                                opts.max_conns
                            )),
                        ),
                    ])
                    .to_string()
                    .as_bytes(),
                );
                let _ = s.write_all(b"\n");
                continue;
            }
            let slot = ConnSlot(conns.clone());
            let coord = coord.clone();
            std::thread::spawn(move || {
                let _slot = slot;
                let _ = handle_conn(&coord, stream, opts);
            });
        }
    });
    Ok(local)
}

/// One bounded request line. `Eof` ends the connection; `TooLong` means
/// the cap was hit (the line's remainder is still un-read — the caller
/// must close, since resynchronizing on the next newline could buffer
/// arbitrarily slowly).
enum LineRead {
    Line(Vec<u8>),
    TooLong,
    Eof,
}

/// Read one newline-terminated line without ever buffering more than
/// `max` bytes, tolerating read-timeout wakeups while the line is empty
/// (an idle connection between requests) but not once bytes have arrived
/// (a slowloris trickling a request forever).
fn read_line_bounded(reader: &mut BufReader<TcpStream>, max: usize) -> Result<LineRead> {
    let mut out: Vec<u8> = Vec::new();
    loop {
        let chunk = match reader.fill_buf() {
            Ok(c) => c,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if out.is_empty() {
                    continue; // idle between requests: keep waiting
                }
                bail!("read timed out mid-request-line");
            }
            Err(e) => return Err(e.into()),
        };
        if chunk.is_empty() {
            // EOF. A trailing unterminated line still gets served (same
            // contract as BufRead::lines).
            return Ok(if out.is_empty() { LineRead::Eof } else { LineRead::Line(out) });
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                if out.len() + pos > max {
                    reader.consume(pos + 1);
                    return Ok(LineRead::TooLong);
                }
                out.extend_from_slice(&chunk[..pos]);
                reader.consume(pos + 1);
                return Ok(LineRead::Line(out));
            }
            None => {
                let n = chunk.len();
                if out.len() + n > max {
                    reader.consume(n);
                    return Ok(LineRead::TooLong);
                }
                out.extend_from_slice(chunk);
                reader.consume(n);
            }
        }
    }
}

fn handle_conn(coord: &Coordinator, stream: TcpStream, opts: ServeOptions) -> Result<()> {
    stream.set_read_timeout(Some(opts.read_timeout))?;
    stream.set_write_timeout(Some(opts.write_timeout))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        match read_line_bounded(&mut reader, opts.max_line_bytes)? {
            LineRead::Eof => return Ok(()),
            LineRead::TooLong => {
                let reply = Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    (
                        "error",
                        Json::str(&format!(
                            "request line too long (max {} bytes)",
                            opts.max_line_bytes
                        )),
                    ),
                ]);
                writer.write_all(reply.to_string().as_bytes())?;
                writer.write_all(b"\n")?;
                return Ok(()); // cannot resync past an unread tail: close
            }
            LineRead::Line(bytes) => {
                let line = String::from_utf8_lossy(&bytes);
                if line.trim().is_empty() {
                    continue;
                }
                let reply = handle_line(coord, &line);
                writer.write_all(reply.as_bytes())?;
                writer.write_all(b"\n")?;
            }
        }
    }
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { writer: stream.try_clone()?, reader: BufReader::new(stream) })
    }

    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CoordinatorConfig, ModelRegistry};
    use crate::gmm::Gmm;
    use crate::score::GmmEps;

    fn coord() -> Arc<Coordinator> {
        let mut reg = ModelRegistry::new();
        reg.insert("gmm2d", Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())));
        Arc::new(Coordinator::new(CoordinatorConfig::default(), reg))
    }

    #[test]
    fn request_parsing_defaults() {
        let v = Json::parse(r#"{"model":"gmm2d","solver":"tab3","nfe":10,"n":4}"#).unwrap();
        let req = parse_request(&v).unwrap();
        assert_eq!(req.model, "gmm2d");
        assert_eq!(req.solver, SolverKind::Tab(3));
        assert_eq!(req.t0, 1e-3);
        assert_eq!(req.grid, GridKind::Quadratic);
    }

    #[test]
    fn tcp_roundtrip() {
        let c = coord();
        let addr = serve(c, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(addr).unwrap();
        let resp = client
            .call(&Json::parse(
                r#"{"model":"gmm2d","solver":"ddim","nfe":5,"n":4,"return_samples":true}"#,
            ).unwrap())
            .unwrap();
        assert!(resp.get("ok").unwrap().as_bool().unwrap(), "{resp:?}");
        assert_eq!(resp.get("samples").unwrap().as_arr().unwrap().len(), 8);

        let stats = client.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
        assert_eq!(stats.get("completed").unwrap().as_f64().unwrap(), 1.0);
        // The additive per-model breakdown mirrors the single-model traffic.
        let pm = stats.get("per_model").unwrap().get("gmm2d").unwrap();
        assert_eq!(pm.get("requests").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(pm.get("completed").unwrap().as_f64().unwrap(), 1.0);

        let models = client.call(&Json::parse(r#"{"cmd":"models"}"#).unwrap()).unwrap();
        assert_eq!(models.get("models").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn bad_requests_report_errors() {
        let c = coord();
        let addr = serve(c, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(addr).unwrap();
        for bad in [
            r#"{"model":"gmm2d","solver":"bogus","nfe":5,"n":4}"#,
            r#"{"model":"gmm2d","solver":"ddim","n":4}"#,
            r#"not json"#,
        ] {
            let resp = client.call(&Json::parse(&format!("{:?}", bad)).unwrap_or(Json::str(bad)))
                .unwrap_or_else(|_| {
                    // raw invalid line path
                    let mut cl = Client::connect(addr).unwrap();
                    cl.writer.write_all(bad.as_bytes()).unwrap();
                    cl.writer.write_all(b"\n").unwrap();
                    let mut line = String::new();
                    cl.reader.read_line(&mut line).unwrap();
                    Json::parse(&line).unwrap()
                });
            assert!(!resp.get("ok").unwrap().as_bool().unwrap(), "{bad}");
        }
    }

    /// Seeds are u64 end to end: a seed above 2^53 must parse losslessly
    /// (the old path went through f64, which silently collapses adjacent
    /// seeds), and a lossy/fractional seed is a parse error, not a guess.
    #[test]
    fn seed_above_2_53_parses_exactly() {
        let seed = (1u64 << 60) + 1;
        let line =
            format!(r#"{{"model":"gmm2d","solver":"tab3","nfe":10,"n":4,"seed":{seed}}}"#);
        let req = parse_request(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(req.seed, seed, "seed must not round-trip through f64");
        let bad = r#"{"model":"gmm2d","solver":"tab3","nfe":10,"n":4,"seed":1.5}"#;
        assert!(parse_request(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn health_reports_draining_and_model_state() {
        let c = coord();
        let addr = serve(c.clone(), "127.0.0.1:0").unwrap();
        let mut cl = Client::connect(addr).unwrap();
        let sample = Json::parse(r#"{"model":"gmm2d","solver":"ddim","nfe":5,"n":2}"#).unwrap();
        assert!(cl.call(&sample).unwrap().get("ok").unwrap().as_bool().unwrap());
        let h = cl.call(&Json::parse(r#"{"cmd":"health"}"#).unwrap()).unwrap();
        assert!(h.get("ok").unwrap().as_bool().unwrap());
        assert!(!h.get("draining").unwrap().as_bool().unwrap());
        assert!(h.get("models").unwrap().get("gmm2d").unwrap().as_bool().unwrap());
        // Draining: sampling is refused, introspection keeps working.
        c.begin_drain();
        let h = cl.call(&Json::parse(r#"{"cmd":"health"}"#).unwrap()).unwrap();
        assert!(h.get("draining").unwrap().as_bool().unwrap());
        let r = cl.call(&sample).unwrap();
        assert!(!r.get("ok").unwrap().as_bool().unwrap());
        assert!(r.get("error").unwrap().as_str().unwrap().contains("shutting down"));
    }

    #[test]
    fn over_long_request_lines_error_and_close() {
        let c = coord();
        let addr = serve_with(
            c,
            "127.0.0.1:0",
            ServeOptions { max_line_bytes: 128, ..Default::default() },
        )
        .unwrap();
        let mut cl = Client::connect(addr).unwrap();
        let huge = "x".repeat(4096);
        cl.writer.write_all(huge.as_bytes()).unwrap();
        cl.writer.write_all(b"\n").unwrap();
        let mut line = String::new();
        cl.reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert!(!resp.get("ok").unwrap().as_bool().unwrap());
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("too long"));
        let mut l2 = String::new();
        assert_eq!(
            cl.reader.read_line(&mut l2).unwrap(),
            0,
            "server must close the connection after an over-long line"
        );
    }

    #[test]
    fn connection_cap_sheds_excess_connections_with_an_error() {
        let c = coord();
        let addr = serve_with(
            c,
            "127.0.0.1:0",
            ServeOptions { max_conns: 1, ..Default::default() },
        )
        .unwrap();
        let mut keep = Client::connect(addr).unwrap();
        let models = Json::parse(r#"{"cmd":"models"}"#).unwrap();
        // A served call proves the first connection is accepted + counted.
        assert!(keep.call(&models).unwrap().get("ok").unwrap().as_bool().unwrap());
        let mut shed = Client::connect(addr).unwrap();
        let mut line = String::new();
        shed.reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert!(!resp.get("ok").unwrap().as_bool().unwrap());
        assert!(
            resp.get("error").unwrap().as_str().unwrap().contains("connection capacity"),
            "{resp:?}"
        );
        // The surviving connection is unaffected by the shed one.
        assert!(keep.call(&models).unwrap().get("ok").unwrap().as_bool().unwrap());
    }
}
