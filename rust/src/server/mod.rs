//! Line-JSON TCP front end for the coordinator (std::net; tokio is not in
//! the offline registry — one thread per connection, which is plenty for a
//! sampling service whose unit of work is a whole diffusion trajectory).
//!
//! Wire protocol, one JSON object per line.
//!
//! Sampling request:
//!   -> {"model":"gmm2d","solver":"tab3","grid":"quadratic","nfe":10,
//!       "n":256,"seed":1,"t0":1e-3,"sde":"vp","return_samples":false,
//!       "deadline_ms":500}
//!   <- {"ok":true,"n":256,"dim":2,"nfe":10,"merged_with":3,"co_batched":5,
//!       "queue_us":120,"solve_us":5300,"samples":[...]?}
//!
//! `deadline_ms` (optional) is a relative per-request deadline: if the
//! request is still queued or still integrating when it fires, the reply is
//! {"ok":false,"error":"deadline exceeded ..."} instead of samples, and the
//! trajectory is aborted when no other request shares it. Overload
//! (backpressure: more than the coordinator's max in-flight requests) is
//! likewise reported immediately as {"ok":false,"error":"coordinator
//! overloaded ..."} — clients should back off and retry. `nfe` is capped
//! at `coordinator::MAX_REQUEST_NFE` (it sizes the solver-plan build);
//! larger values are rejected with {"ok":false,"error":"nfe ... out of
//! range ..."}.
//!
//! In the reply, `merged_with` counts requests stacked into the same
//! trajectory group at admission, and `co_batched` is the peak number of
//! requests whose ε-evaluations the step-level scheduler dispatched in a
//! single model call with this one. Every solver — deterministic,
//! adaptive (rk45) and stochastic (em/sddim/addim) alike — runs through
//! the scheduler, so `co_batched` is always reported and always
//! >= `merged_with`; there is no blocking fallback path.
//!
//! Introspection:
//!   -> {"cmd":"stats"}            <- {"ok":true,"requests":...}
//!   -> {"cmd":"models"}           <- {"ok":true,"models":[...]}
//!
//! Stats keys: request lifecycle (`requests`, `completed`, `rejected`,
//! `expired`, `samples`), admission merging (`batches`, `merged_requests`),
//! scheduler effectiveness (`model_evals`, `sched_evals`,
//! `sched_eval_requests`, `eval_occupancy`, `max_occupancy` — occupancy k
//! means each scheduled network call served k requests on average), the
//! shared solver-plan cache (`plan_cache_hits`, `plan_cache_misses` — a hit
//! means admission reused a cached (grid, coefficients) plan instead of
//! rebuilding it), and latency (`p50_us`, `p99_us`, `mean_us`). `rejected`
//! covers every refusal at submit: global overload, per-model overload,
//! out-of-range `nfe`, unknown model names and invalid sampling configs —
//! so `requests == completed + rejected + expired` always balances.
//!
//! The coordinator is sharded by model (one scheduler shard per registered
//! model; see `coordinator/scheduler.rs`), and the stats reply additionally
//! carries an ADDITIVE `per_model` object — one entry per shard (models
//! that have received traffic), keyed by model name:
//!
//!   "per_model": {"gmm2d": {"requests":N,"completed":N,"rejected":N,
//!                           "expired":N,"samples":N,"batches":N,
//!                           "merged_requests":N,"model_evals":N,
//!                           "sched_evals":N,"sched_eval_requests":N,
//!                           "eval_occupancy":X,"max_occupancy":N}, ...}
//!
//! Per-model `rejected` counts only refusals attributable to that shard
//! (per-model overload, invalid configs); global-overload, unknown-model
//! and nfe-cap refusals appear only in the top-level `rejected`. Each
//! model's lifecycle balances on its own: `requests == completed +
//! rejected + expired` per entry. Existing clients that ignore unknown
//! keys need no migration.
//!
//! Latency semantics: latencies are recorded into a lock-free log-bucketed
//! histogram (`coordinator::stats::LatencyHistogram`), not a raw list.
//! `p50_us`/`p99_us` are therefore *bucketed* percentiles — the midpoint of
//! the bucket containing the exact order statistic, within a relative
//! quantization error of at most 2^-5 ≈ 3.1% (exact below 64µs, where
//! buckets have width 1). `mean_us` stays exact (sum and count are tracked
//! directly). The keys, types and meaning are otherwise unchanged from the
//! previous sorted-list implementation; clients need no migration.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::{Coordinator, SampleRequest};
use crate::diffusion::Sde;
use crate::solvers::SolverKind;
use crate::timegrid::GridKind;
use crate::util::json::Json;

/// Parse a request line into a SampleRequest.
pub fn parse_request(v: &Json) -> Result<SampleRequest> {
    let model = v.get("model")?.as_str()?.to_string();
    let solver = SolverKind::parse(v.get("solver")?.as_str()?)
        .with_context(|| "unknown solver")?;
    let sde = match v.opt("sde").map(|s| s.as_str()).transpose()?.unwrap_or("vp") {
        "vp" => Sde::vp(),
        "ve" => Sde::ve(),
        other => bail!("unknown sde '{other}'"),
    };
    let grid = match v.opt("grid") {
        Some(g) => GridKind::parse(g.as_str()?).with_context(|| "unknown grid")?,
        None => GridKind::Quadratic,
    };
    let mut req = SampleRequest::new(&model, solver, v.get("nfe")?.as_usize()?,
        v.get("n")?.as_usize()?);
    req.sde = sde;
    req.grid = grid;
    req.t0 = v.opt("t0").map(|x| x.as_f64()).transpose()?.unwrap_or(sde.t0_default());
    req.seed = v.opt("seed").map(|x| x.as_f64()).transpose()?.unwrap_or(0.0) as u64;
    req.deadline_ms = v.opt("deadline_ms").map(|x| x.as_usize()).transpose()?.map(|ms| ms as u64);
    Ok(req)
}

fn handle_line(coord: &Coordinator, line: &str) -> String {
    let reply = (|| -> Result<Json> {
        let v = Json::parse(line)?;
        if let Some(cmd) = v.opt("cmd") {
            return match cmd.as_str()? {
                "stats" => {
                    let s = coord.stats();
                    let per_model: std::collections::BTreeMap<String, Json> = s
                        .per_model
                        .iter()
                        .map(|(name, m)| {
                            (
                                name.clone(),
                                Json::obj(vec![
                                    ("requests", Json::num(m.requests as f64)),
                                    ("completed", Json::num(m.completed as f64)),
                                    ("rejected", Json::num(m.rejected as f64)),
                                    ("expired", Json::num(m.expired as f64)),
                                    ("samples", Json::num(m.samples as f64)),
                                    ("batches", Json::num(m.batches as f64)),
                                    ("merged_requests", Json::num(m.merged_requests as f64)),
                                    ("model_evals", Json::num(m.model_evals as f64)),
                                    ("sched_evals", Json::num(m.sched_evals as f64)),
                                    (
                                        "sched_eval_requests",
                                        Json::num(m.sched_eval_requests as f64),
                                    ),
                                    ("eval_occupancy", Json::num(m.eval_occupancy)),
                                    ("max_occupancy", Json::num(m.max_occupancy as f64)),
                                ]),
                            )
                        })
                        .collect();
                    Ok(Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("requests", Json::num(s.requests as f64)),
                        ("completed", Json::num(s.completed as f64)),
                        ("rejected", Json::num(s.rejected as f64)),
                        ("expired", Json::num(s.expired as f64)),
                        ("samples", Json::num(s.samples as f64)),
                        ("batches", Json::num(s.batches as f64)),
                        ("merged_requests", Json::num(s.merged_requests as f64)),
                        ("model_evals", Json::num(s.model_evals as f64)),
                        ("sched_evals", Json::num(s.sched_evals as f64)),
                        ("sched_eval_requests", Json::num(s.sched_eval_requests as f64)),
                        ("eval_occupancy", Json::num(s.eval_occupancy)),
                        ("max_occupancy", Json::num(s.max_occupancy as f64)),
                        ("plan_cache_hits", Json::num(s.plan_cache_hits as f64)),
                        ("plan_cache_misses", Json::num(s.plan_cache_misses as f64)),
                        ("p50_us", Json::num(s.p50_us as f64)),
                        ("p99_us", Json::num(s.p99_us as f64)),
                        ("mean_us", Json::num(s.mean_us)),
                        ("per_model", Json::Obj(per_model)),
                    ]))
                }
                "models" => Ok(Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    (
                        "models",
                        Json::Arr(coord.models().iter().map(|m| Json::str(m)).collect()),
                    ),
                ])),
                other => bail!("unknown cmd '{other}'"),
            };
        }
        let return_samples =
            v.opt("return_samples").map(|b| b.as_bool()).transpose()?.unwrap_or(false);
        let req = parse_request(&v)?;
        let n = req.n_samples;
        let res = coord.sample_blocking(req)?;
        let mut fields = vec![
            ("ok", Json::Bool(true)),
            ("n", Json::num(n as f64)),
            ("dim", Json::num(res.dim as f64)),
            ("nfe", Json::num(res.nfe as f64)),
            ("merged_with", Json::num(res.merged_with as f64)),
            ("co_batched", Json::num(res.co_batched as f64)),
            ("queue_us", Json::num(res.queue_us as f64)),
            ("solve_us", Json::num(res.solve_us as f64)),
        ];
        if return_samples {
            fields.push(("samples", Json::arr_f64(&res.samples)));
        }
        Ok(Json::obj(fields))
    })();
    match reply {
        Ok(j) => j.to_string(),
        Err(e) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::str(&format!("{e:#}"))),
        ])
        .to_string(),
    }
}

/// Serve until the process dies. Returns the bound address (port 0 allowed).
pub fn serve(coord: Arc<Coordinator>, addr: &str) -> Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    std::thread::spawn(move || {
        for stream in listener.incoming().flatten() {
            let coord = coord.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(&coord, stream);
            });
        }
    });
    Ok(local)
}

fn handle_conn(coord: &Coordinator, stream: TcpStream) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = handle_line(coord, &line);
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { writer: stream.try_clone()?, reader: BufReader::new(stream) })
    }

    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CoordinatorConfig, ModelRegistry};
    use crate::gmm::Gmm;
    use crate::score::GmmEps;

    fn coord() -> Arc<Coordinator> {
        let mut reg = ModelRegistry::new();
        reg.insert("gmm2d", Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())));
        Arc::new(Coordinator::new(CoordinatorConfig::default(), reg))
    }

    #[test]
    fn request_parsing_defaults() {
        let v = Json::parse(r#"{"model":"gmm2d","solver":"tab3","nfe":10,"n":4}"#).unwrap();
        let req = parse_request(&v).unwrap();
        assert_eq!(req.model, "gmm2d");
        assert_eq!(req.solver, SolverKind::Tab(3));
        assert_eq!(req.t0, 1e-3);
        assert_eq!(req.grid, GridKind::Quadratic);
    }

    #[test]
    fn tcp_roundtrip() {
        let c = coord();
        let addr = serve(c, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(addr).unwrap();
        let resp = client
            .call(&Json::parse(
                r#"{"model":"gmm2d","solver":"ddim","nfe":5,"n":4,"return_samples":true}"#,
            ).unwrap())
            .unwrap();
        assert!(resp.get("ok").unwrap().as_bool().unwrap(), "{resp:?}");
        assert_eq!(resp.get("samples").unwrap().as_arr().unwrap().len(), 8);

        let stats = client.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
        assert_eq!(stats.get("completed").unwrap().as_f64().unwrap(), 1.0);
        // The additive per-model breakdown mirrors the single-model traffic.
        let pm = stats.get("per_model").unwrap().get("gmm2d").unwrap();
        assert_eq!(pm.get("requests").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(pm.get("completed").unwrap().as_f64().unwrap(), 1.0);

        let models = client.call(&Json::parse(r#"{"cmd":"models"}"#).unwrap()).unwrap();
        assert_eq!(models.get("models").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn bad_requests_report_errors() {
        let c = coord();
        let addr = serve(c, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(addr).unwrap();
        for bad in [
            r#"{"model":"gmm2d","solver":"bogus","nfe":5,"n":4}"#,
            r#"{"model":"gmm2d","solver":"ddim","n":4}"#,
            r#"not json"#,
        ] {
            let resp = client.call(&Json::parse(&format!("{:?}", bad)).unwrap_or(Json::str(bad)))
                .unwrap_or_else(|_| {
                    // raw invalid line path
                    let mut cl = Client::connect(addr).unwrap();
                    cl.writer.write_all(bad.as_bytes()).unwrap();
                    cl.writer.write_all(b"\n").unwrap();
                    let mut line = String::new();
                    cl.reader.read_line(&mut line).unwrap();
                    Json::parse(&line).unwrap()
                });
            assert!(!resp.get("ok").unwrap().as_bool().unwrap(), "{bad}");
        }
    }
}
