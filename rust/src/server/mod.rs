//! Line-JSON TCP front end for the coordinator — a readiness-driven event
//! loop (tokio is not in the offline registry; `server/poll.rs` wraps raw
//! epoll instead). A fixed pool of I/O threads ([`ServeOptions::io_threads`],
//! default `min(4, cores)`) owns all sockets in non-blocking mode; each
//! connection is a small state machine (read-accumulate -> parse -> submit
//! -> pending-reply -> write-drain), so thousands of mostly-idle
//! connections cost buffers, not threads. The accept loop deals new
//! connections round-robin across the pool; coordinator completions come
//! back through a per-thread completion queue and a pipe-based waker.
//!
//! Wire protocol, one JSON object per line.
//!
//! Sampling request:
//!   -> {"model":"gmm2d","solver":"tab3","grid":"quadratic","nfe":10,
//!       "n":256,"seed":1,"t0":1e-3,"sde":"vp","return_samples":false,
//!       "deadline_ms":500,"dtype":"f64"}
//!   <- {"ok":true,"n":256,"dim":2,"nfe":10,"merged_with":3,"co_batched":5,
//!       "queue_us":120,"solve_us":5300,"dtype":"f64","samples":[...]?}
//!
//! Submit lines are parsed zero-copy when possible (`server/wire.rs`
//! borrows string slices straight out of the request line; no JSON tree is
//! built); anything the borrowing parser cannot represent faithfully falls
//! back to the owned tree parser, which keeps the error texts — so client
//! visible behaviour is identical on both paths. Introspection commands
//! and error replies always go through the tree.
//!
//! `dtype` (optional, default "f64") selects the inference precision of
//! the model eval. "f32" routes the request to the model's f32 engine —
//! registered as `<model>@f32` when the server runs with `--precision
//! f32`; if no f32 engine exists for the model, the reply is {"ok":false,
//! "error":"model ... has no f32 engine registered ..."}. Any value other
//! than "f32"/"f64" is rejected with {"ok":false,"error":"unknown dtype
//! ..."}. The reply echoes the `dtype` that served the request. Samples
//! are always f64 JSON numbers on the wire regardless of dtype (the f32
//! engine widens its output at the model boundary); f32 results track f64
//! within the documented tolerance (EXPERIMENTS.md §Kernels). f32 and f64
//! requests are never merged or co-batched together — the rewritten model
//! name keys the batch, so the precision class of a reply is exact. In the
//! stats reply, f32 traffic appears under the "<model>@f32" per-model key.
//!
//! Binary sample frames: a submit carrying `"return_samples":true` may add
//! `"frame":"bin"`. The reply is then a JSON header line whose `bin_bytes`
//! key gives the exact byte length of the raw payload that follows the
//! newline: `rows`×`dim` f64 values, row-major, little-endian, with no
//! terminator of its own (the header's byte count delimits it). The values
//! are bit-identical to what the JSON `samples` array would have carried —
//! only the encoding changes, cutting the payload roughly 2.5× for typical
//! samples. The header carries the same fields as the JSON success reply
//! (minus `samples`) plus `frame`, `rows` and `bin_bytes`; `"frame":"bin"`
//! without `"return_samples":true` degrades to the plain JSON reply, since
//! there is no payload to frame. `"frame":"json"` is accepted and is the
//! default. See [`Client::call_bin`] for the client side.
//!
//! `deadline_ms` (optional) is a relative per-request deadline: if the
//! request is still queued or still integrating when it fires, the reply is
//! {"ok":false,"error":"deadline exceeded ..."} instead of samples, and the
//! trajectory is aborted when no other request shares it. Overload
//! (backpressure: more than the coordinator's max in-flight requests) is
//! likewise reported immediately as {"ok":false,"error":"coordinator
//! overloaded ..."} — clients should back off and retry. `nfe` is capped
//! at `coordinator::MAX_REQUEST_NFE` (it sizes the solver-plan build);
//! larger values are rejected with {"ok":false,"error":"nfe ... out of
//! range ..."}.
//!
//! In the reply, `merged_with` counts requests stacked into the same
//! trajectory group at admission, and `co_batched` is the peak number of
//! requests whose ε-evaluations the step-level scheduler dispatched in a
//! single model call with this one. Every solver — deterministic,
//! adaptive (rk45) and stochastic (em/sddim/addim) alike — runs through
//! the scheduler, so `co_batched` is always reported and always
//! >= `merged_with`; there is no blocking fallback path.
//!
//! Introspection:
//!   -> {"cmd":"stats"}            <- {"ok":true,"requests":...}
//!   -> {"cmd":"models"}           <- {"ok":true,"models":[...]}
//!   -> {"cmd":"health"}           <- {"ok":true,"draining":false,
//!                                     "worker_panics":0,
//!                                     "models":{"gmm2d":true,...}}
//!
//! `health` reports graceful-degradation state: `draining` is true once a
//! graceful shutdown began (new requests are refused), `worker_panics`
//! counts scheduler worker threads the supervisor has restarted, and
//! `models` maps each model that has seen traffic to its circuit-breaker
//! state (`true` = healthy/closed, `false` = open: that model's requests
//! are being refused with {"ok":false,"error":"model ... unhealthy ..."}
//! until the breaker's cooldown half-opens it).
//!
//! Stats keys: request lifecycle (`requests`, `completed`, `rejected`,
//! `expired`, `failed`, `samples`), admission merging (`batches`,
//! `merged_requests`), scheduler effectiveness (`model_evals`,
//! `sched_evals`, `sched_eval_requests`, `eval_occupancy`, `max_occupancy`
//! — occupancy k means each scheduled network call served k requests on
//! average), fault containment (`eval_panics` — merged ε-evals that
//! panicked and were contained; `unhealthy` — refusals due to an open
//! circuit breaker, a subset of `rejected`), the shared solver-plan cache
//! (`plan_cache_hits`, `plan_cache_misses` — a hit means admission reused
//! a cached (grid, coefficients) plan instead of rebuilding it), deadline
//! outcomes (`deadline_hit` — delivered requests that carried a
//! `deadline_ms`; `deadline_missed` — requests dropped because their
//! deadline fired, always equal to `expired`; hit rate is
//! `deadline_hit / (deadline_hit + deadline_missed)`, and deadline-carrying
//! requests that were rejected or failed before the deadline fired count in
//! neither), and latency (`p50_us`, `p99_us`, `mean_us`). The scheduler's
//! anchor-selection policy is a serve-time knob (`--sched-policy
//! oldest|edf`, default `oldest`; see `coordinator/scheduler.rs`) — `edf`
//! orders ready work by tightest surviving deadline with an age-based
//! starvation guard for deadline-less requests, which is what moves the
//! `deadline_hit`/`deadline_missed` split under contention. `rejected`
//! covers every
//! refusal at submit: global overload, per-model overload, out-of-range
//! `nfe`, unknown model names, invalid sampling configs, open circuit
//! breakers and draining shutdowns; `failed` counts requests whose
//! admitted work was lost to a contained fault (eval panic, non-finite
//! model output, panicking solver advance, or work stranded past the drain
//! window) — so `requests == completed + rejected + expired + failed`
//! always balances.
//!
//! The coordinator is sharded by model (one scheduler shard per registered
//! model; see `coordinator/scheduler.rs`), and the stats reply additionally
//! carries an ADDITIVE `per_model` object — one entry per shard (models
//! that have received traffic), keyed by model name:
//!
//!   "per_model": {"gmm2d": {"requests":N,"completed":N,"rejected":N,
//!                           "expired":N,"failed":N,
//!                           "deadline_hit":N,"deadline_missed":N,
//!                           "eval_panics":N,
//!                           "unhealthy":N,"samples":N,"batches":N,
//!                           "merged_requests":N,"model_evals":N,
//!                           "sched_evals":N,"sched_eval_requests":N,
//!                           "eval_occupancy":X,"max_occupancy":N}, ...}
//!
//! Per-model `rejected` counts only refusals attributable to that shard
//! (per-model overload, open breaker, invalid configs); global-overload,
//! unknown-model, draining and nfe-cap refusals appear only in the
//! top-level `rejected`. Each model's lifecycle balances on its own:
//! `requests == completed + rejected + expired + failed` per entry.
//! Existing clients that ignore unknown keys need no migration.
//!
//! Connection hygiene (see [`ServeOptions`]): at most `max_conns`
//! concurrent connections (excess connections get one {"ok":false,
//! "error":"server at connection capacity ..."} line and are closed),
//! request lines are capped at `max_line_bytes` — the per-connection read
//! buffer never accumulates more than that for one line, and an over-long
//! line gets an error reply and the connection is closed. A connection
//! that goes silent MID-line for longer than `read_timeout` is dropped
//! (slowloris; enforced by a periodic sweep of the event loop, so the
//! bound is `read_timeout` plus at most one sweep tick). Idle connections
//! *between* requests are not timed out; they hold a connection slot,
//! which `max_conns` bounds. A reply that makes no write progress for
//! longer than `write_timeout` drops the connection the same way, and a
//! connection whose outbound backlog passes a high-water mark stops being
//! read until the backlog drains (per-connection backpressure). One
//! request is in flight per connection at a time: pipelined lines queue in
//! the read buffer and are answered in order.
//!
//! Graceful shutdown is coordinator-level: once `Coordinator::begin_drain`
//! runs (or a drain-based shutdown starts), every new submission — from
//! any connection — is refused with {"ok":false,"error":"coordinator
//! shutting down ..."} while already-admitted work finishes; work still
//! stranded when the drain window closes is answered with the same error
//! rather than left hanging — completions flow back through the event loop
//! and pending replies are written out normally. Introspection
//! (`stats`/`models`/`health`) keeps working throughout, so clients can
//! watch the drain.
//!
//! Latency semantics: latencies are recorded into a lock-free log-bucketed
//! histogram (`coordinator::stats::LatencyHistogram`), not a raw list.
//! `p50_us`/`p99_us` are therefore *bucketed* percentiles — the midpoint of
//! the bucket containing the exact order statistic, within a relative
//! quantization error of at most 2^-5 ≈ 3.1% (exact below 64µs, where
//! buckets have width 1). `mean_us` stays exact (sum and count are tracked
//! directly). The keys, types and meaning are otherwise unchanged from the
//! previous sorted-list implementation; clients need no migration.
//!
//! ## Router tier (multi-process sharding)
//!
//! `deis router` (see [`crate::router`]) puts this exact wire protocol in
//! front of N independent worker processes. Clients need no migration:
//! submit lines, binary frames, pipelining-in-order, and the hygiene
//! contract above behave identically through the router, and proxied
//! replies are byte-identical to direct ones (binary payloads are relayed
//! as raw bytes, never re-encoded).
//!
//! *Routing key*: the submit line's `model`, with a `@f32` suffix
//! stripped — so a model and its f32 sibling land on the SAME worker and
//! their co-batching opportunity concentrates instead of fragmenting.
//! Placement is rendezvous (HRW) hashing over the configured upstream
//! address strings: deterministic, stateless, and minimally disruptive
//! when the worker set changes (only the models owned by a dead worker
//! move).
//!
//! *Aggregated introspection*: `stats`/`health`/`models` fan out to every
//! reachable worker and come back as ONE object in the worker schema —
//! lifecycle and volume counters summed, `eval_occupancy` recomputed from
//! the summed terms, `mean_us` request-weighted, `p50_us`/`p99_us` the
//! per-worker max (the wire carries quantiles, not histograms), and
//! `per_model` unioned. The stats reply additionally carries a `"router"`
//! object with the router's own accounting: `requests`, `forwarded`,
//! `upstream_errors`, `in_flight`, `cmds`, `bad_lines`, a `per_worker`
//! breakdown keyed by upstream address, and `per_model_errors`; its own
//! balance is `requests == forwarded + upstream_errors + in_flight`.
//! Merged `health` ANDs per-model breaker states, sums `worker_panics`,
//! reports `draining` only when every reachable worker is draining, and
//! breaks all of it out per upstream under `"workers"`.
//!
//! *Failure semantics*: a worker connect failure, connection death or
//! protocol violation fails that worker as a unit — every in-flight
//! request routed to it is answered immediately with {"ok":false,
//! "error":"upstream unavailable: ..."} (counted in the router's
//! `upstream_errors`, never a hang), a threshold-1 breaker opens for the
//! router's cooldown, and subsequent submits re-home down the rendezvous
//! rank to the next live worker. Replies the dying worker already
//! delivered are relayed before the teardown; a request whose binary
//! payload was only part-delivered tears the client connection down
//! instead (a late error line would corrupt the byte stream).

pub mod loadgen;
pub mod poll;
pub mod wire;

use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::{Coordinator, Responder, SampleRequest, SampleResult};
use crate::diffusion::Sde;
use crate::score::Precision;
use crate::solvers::SolverKind;
use crate::timegrid::GridKind;
use crate::util::json::Json;
use crate::util::sync::lock_recover;

use poll::{Event, Interest, Poller, Waker};

/// Parse a request line into a SampleRequest.
pub fn parse_request(v: &Json) -> Result<SampleRequest> {
    let model = v.get("model")?.as_str()?.to_string();
    let solver = SolverKind::parse(v.get("solver")?.as_str()?)
        .with_context(|| "unknown solver")?;
    let sde = match v.opt("sde").map(|s| s.as_str()).transpose()?.unwrap_or("vp") {
        "vp" => Sde::vp(),
        "ve" => Sde::ve(),
        other => bail!("unknown sde '{other}'"),
    };
    let grid = match v.opt("grid") {
        Some(g) => GridKind::parse(g.as_str()?).with_context(|| "unknown grid")?,
        None => GridKind::Quadratic,
    };
    let mut req = SampleRequest::new(&model, solver, v.get("nfe")?.as_usize()?,
        v.get("n")?.as_usize()?);
    req.sde = sde;
    req.grid = grid;
    req.t0 = v.opt("t0").map(|x| x.as_f64()).transpose()?.unwrap_or(sde.t0_default());
    // Seeds are u64 and must stay lossless: routing them through f64 would
    // silently collapse every seed above 2^53 (and truncate fractions).
    req.seed = v.opt("seed").map(|x| x.as_u64()).transpose()?.unwrap_or(0);
    req.deadline_ms = v.opt("deadline_ms").map(|x| x.as_usize()).transpose()?.map(|ms| ms as u64);
    if let Some(s) = v.opt("dtype").map(|s| s.as_str()).transpose()? {
        req.dtype = Precision::parse(s)
            .with_context(|| format!("unknown dtype '{s}' (expected \"f32\" or \"f64\")"))?;
    }
    Ok(req)
}

/// Serve one introspection command (`stats`/`models`/`health`). Submits do
/// not come through here — they ride the asynchronous completion path.
fn handle_cmd(coord: &Coordinator, v: &Json) -> Result<Json> {
    match v.get("cmd")?.as_str()? {
        "stats" => {
            let s = coord.stats();
            let per_model: std::collections::BTreeMap<String, Json> = s
                .per_model
                .iter()
                .map(|(name, m)| {
                    (
                        name.clone(),
                        Json::obj(vec![
                            ("requests", Json::num(m.requests as f64)),
                            ("completed", Json::num(m.completed as f64)),
                            ("rejected", Json::num(m.rejected as f64)),
                            ("expired", Json::num(m.expired as f64)),
                            ("failed", Json::num(m.failed as f64)),
                            ("deadline_hit", Json::num(m.deadline_hit as f64)),
                            (
                                "deadline_missed",
                                Json::num(m.deadline_missed as f64),
                            ),
                            ("eval_panics", Json::num(m.eval_panics as f64)),
                            ("unhealthy", Json::num(m.unhealthy as f64)),
                            ("samples", Json::num(m.samples as f64)),
                            ("batches", Json::num(m.batches as f64)),
                            ("merged_requests", Json::num(m.merged_requests as f64)),
                            ("model_evals", Json::num(m.model_evals as f64)),
                            ("sched_evals", Json::num(m.sched_evals as f64)),
                            (
                                "sched_eval_requests",
                                Json::num(m.sched_eval_requests as f64),
                            ),
                            ("eval_occupancy", Json::num(m.eval_occupancy)),
                            ("max_occupancy", Json::num(m.max_occupancy as f64)),
                        ]),
                    )
                })
                .collect();
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("requests", Json::num(s.requests as f64)),
                ("completed", Json::num(s.completed as f64)),
                ("rejected", Json::num(s.rejected as f64)),
                ("expired", Json::num(s.expired as f64)),
                ("failed", Json::num(s.failed as f64)),
                ("deadline_hit", Json::num(s.deadline_hit as f64)),
                ("deadline_missed", Json::num(s.deadline_missed as f64)),
                ("eval_panics", Json::num(s.eval_panics as f64)),
                ("unhealthy", Json::num(s.unhealthy as f64)),
                ("samples", Json::num(s.samples as f64)),
                ("batches", Json::num(s.batches as f64)),
                ("merged_requests", Json::num(s.merged_requests as f64)),
                ("model_evals", Json::num(s.model_evals as f64)),
                ("sched_evals", Json::num(s.sched_evals as f64)),
                ("sched_eval_requests", Json::num(s.sched_eval_requests as f64)),
                ("eval_occupancy", Json::num(s.eval_occupancy)),
                ("max_occupancy", Json::num(s.max_occupancy as f64)),
                ("plan_cache_hits", Json::num(s.plan_cache_hits as f64)),
                ("plan_cache_misses", Json::num(s.plan_cache_misses as f64)),
                ("p50_us", Json::num(s.p50_us as f64)),
                ("p99_us", Json::num(s.p99_us as f64)),
                ("mean_us", Json::num(s.mean_us)),
                ("per_model", Json::Obj(per_model)),
            ]))
        }
        "models" => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            (
                "models",
                Json::Arr(coord.models().iter().map(|m| Json::str(m)).collect()),
            ),
        ])),
        "health" => {
            let h = coord.health();
            let models: std::collections::BTreeMap<String, Json> =
                h.models.into_iter().map(|(n, up)| (n, Json::Bool(up))).collect();
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("draining", Json::Bool(h.draining)),
                ("worker_panics", Json::uint(h.worker_panics)),
                ("models", Json::Obj(models)),
            ]))
        }
        other => bail!("unknown cmd '{other}'"),
    }
}

/// Front-end hardening knobs. The defaults keep a well-behaved client
/// entirely unaffected; they exist to bound what a misbehaving one can
/// cost the process.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Concurrent connections (each a slot in an I/O thread's table).
    /// Excess connections get one "server at connection capacity" error
    /// line and are closed.
    pub max_conns: usize,
    /// Longest a connection may sit silent MID-line before it is dropped
    /// (slowloris guard). Idle connections between requests are exempt.
    /// Enforced by a periodic sweep: the effective bound is this plus at
    /// most one sweep tick (a quarter of the smaller timeout, clamped to
    /// [10ms, 1s]).
    pub read_timeout: Duration,
    /// Longest a reply may go without any write progress on an unread
    /// socket before the connection is dropped (same sweep).
    pub write_timeout: Duration,
    /// Request-line byte cap: the connection buffer never accumulates more
    /// than this for one line. Over-long lines get an error reply and the
    /// connection is closed (the rest of the line is unread, so resync is
    /// impossible).
    pub max_line_bytes: usize,
    /// Readiness-driven I/O threads sharing the connection load. Each owns
    /// its own epoll set; accepted connections are dealt round-robin.
    pub io_threads: usize,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            max_conns: 1024,
            read_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(30),
            max_line_bytes: 256 * 1024,
            io_threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(4),
        }
    }
}

/// Serve until the process dies, with default [`ServeOptions`]. Returns
/// the bound address (port 0 allowed).
pub fn serve(coord: Arc<Coordinator>, addr: &str) -> Result<std::net::SocketAddr> {
    serve_with(coord, addr, ServeOptions::default())
}

/// RAII connection slot: decrements the live-connection count when the
/// connection is dropped, however it is dropped.
struct ConnSlot(Arc<AtomicUsize>);

impl Drop for ConnSlot {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Event-loop token for the wake pipe.
const WAKER_TOKEN: u64 = u64::MAX;
/// Event-loop token for the listener (thread 0 only).
const LISTENER_TOKEN: u64 = u64::MAX - 1;
/// Outbound-backlog high-water mark: a connection with this much unwritten
/// reply data stops having new lines parsed (and stops being read) until
/// the backlog drains below it — per-connection backpressure against a
/// client that pipelines requests faster than it reads replies.
const OUT_HIGH_WATER: usize = 256 * 1024;

/// Slot index + generation packed into an epoll token. The generation
/// guards against a stale kernel event (or a late coordinator completion)
/// touching a slot that has since been recycled for a new connection.
fn token(idx: u32, gen: u32) -> u64 {
    ((gen as u64) << 32) | idx as u64
}

fn sweep_tick(opts: &ServeOptions) -> Duration {
    (opts.read_timeout.min(opts.write_timeout) / 4)
        .clamp(Duration::from_millis(10), Duration::from_secs(1))
}

/// A finished coordinator request routed back to its connection.
type Completion = (u32, u32, anyhow::Result<SampleResult>);

/// The cross-thread mailbox of one I/O thread: connections dealt to it by
/// the accepting thread, completions pushed by coordinator workers, and
/// the waker that gets its epoll loop to look.
struct IoShared {
    inbox: Mutex<Vec<(TcpStream, ConnSlot)>>,
    completions: Mutex<Vec<Completion>>,
    waker: Waker,
}

/// Per-connection state machine.
struct Conn {
    stream: TcpStream,
    _slot: ConnSlot,
    /// Generation of this occupancy of the slot (see [`token`]).
    gen: u32,
    /// Inbound bytes not yet consumed as request lines.
    buf: Vec<u8>,
    /// Prefix of `buf` already known to contain no newline (scan resume).
    scanned: usize,
    /// Outbound bytes; `written` of them are already on the socket.
    out: Vec<u8>,
    written: usize,
    /// The in-flight request's reply shape, if one is at the coordinator.
    /// While set, no further lines are parsed and the socket is not read:
    /// one request per connection at a time, replies strictly in order.
    pending: Option<wire::ReplyMeta>,
    eof: bool,
    /// Close once `out` drains (over-long line, fatal protocol state).
    close_after_write: bool,
    interest: Interest,
    last_read_progress: Instant,
    last_write_progress: Instant,
}

/// Stamp the write-progress clock when `out` is about to go from drained
/// to non-empty, so `write_timeout` measures from when there was first
/// something to write — not from the last reply's final byte.
fn note_outbound(conn: &mut Conn) {
    if conn.out.len() == conn.written {
        conn.last_write_progress = Instant::now();
    }
}

/// Drain as much of `out` as the socket accepts. Returns true if the
/// connection is dead.
fn write_some(conn: &mut Conn) -> bool {
    while conn.written < conn.out.len() {
        match (&conn.stream).write(&conn.out[conn.written..]) {
            Ok(0) => return true,
            Ok(n) => {
                conn.written += n;
                conn.last_write_progress = Instant::now();
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return true,
        }
    }
    if conn.written > 0 && conn.written == conn.out.len() {
        conn.out.clear();
        conn.written = 0;
    }
    false
}

/// Read what the socket has, bounded per pass so one firehose connection
/// cannot starve the loop (level-triggered epoll re-reports the rest).
/// Returns true if the connection is dead.
fn read_some(conn: &mut Conn) -> bool {
    let mut tmp = [0u8; 16 * 1024];
    let mut budget: usize = 16;
    loop {
        match (&conn.stream).read(&mut tmp) {
            Ok(0) => {
                conn.eof = true;
                return false;
            }
            Ok(n) => {
                conn.buf.extend_from_slice(&tmp[..n]);
                conn.last_read_progress = Instant::now();
                if n < tmp.len() {
                    return false;
                }
                budget -= 1;
                if budget == 0 {
                    return false;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return false,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return true,
        }
    }
}

/// Queue the over-long-line error and doom the connection (the tail of the
/// line is unread, so resynchronizing on a later newline is impossible).
fn too_long(conn: &mut Conn, opts: &ServeOptions) {
    note_outbound(conn);
    wire::error_reply(
        &mut conn.out,
        &format!("request line too long (max {} bytes)", opts.max_line_bytes),
    );
    conn.buf.clear();
    conn.scanned = 0;
    conn.close_after_write = true;
}

/// Shed a connection refused at the accept gate: one error line, close.
/// (Accepted sockets start in blocking mode — the listener's non-blocking
/// flag is not inherited — so the write is bounded by a socket timeout.)
fn shed(mut stream: TcpStream, opts: &ServeOptions) {
    let _ = stream.set_write_timeout(Some(opts.write_timeout));
    let mut out = Vec::new();
    wire::error_reply(
        &mut out,
        &format!("server at connection capacity ({}); retry later", opts.max_conns),
    );
    let _ = stream.write_all(&out);
}

/// One I/O thread: an epoll set over its waker, its share of the
/// connections, and (thread 0 only) the listener.
struct IoThread {
    poller: Poller,
    waker_rx: UnixStream,
    listener: Option<TcpListener>,
    conns: Vec<Option<Conn>>,
    free: Vec<u32>,
    next_gen: u32,
    /// Round-robin deal cursor (offset by thread index so a single-connection
    /// workload does not pile onto thread 0).
    rr: usize,
    shared: Arc<IoShared>,
    peers: Vec<Arc<IoShared>>,
    coord: Arc<Coordinator>,
    opts: ServeOptions,
    conn_count: Arc<AtomicUsize>,
}

impl IoThread {
    fn run(mut self) {
        let tick = sweep_tick(&self.opts);
        let mut events: Vec<Event> = Vec::new();
        let mut ready: Vec<(u32, u32, bool)> = Vec::new();
        let mut last_sweep = Instant::now();
        loop {
            events.clear();
            ready.clear();
            if self.poller.wait(&mut events, Some(tick)).is_err() {
                return;
            }
            let mut woke = false;
            let mut accept = false;
            for ev in &events {
                match ev.token {
                    WAKER_TOKEN => woke = true,
                    LISTENER_TOKEN => accept = true,
                    t => ready.push(((t & 0xFFFF_FFFF) as u32, (t >> 32) as u32, ev.hangup)),
                }
            }
            if woke {
                poll::drain_waker(&self.waker_rx);
            }
            // Adopt connections dealt over by the accepting thread.
            let inbox = std::mem::take(&mut *lock_recover(&self.shared.inbox));
            for (stream, slot) in inbox {
                self.add_conn(stream, slot);
            }
            // Finished coordinator work: write the reply, drive the socket.
            let done = std::mem::take(&mut *lock_recover(&self.shared.completions));
            for (idx, gen, res) in done {
                self.complete(idx, gen, res);
            }
            if accept {
                self.accept_burst();
            }
            for &(idx, gen, hangup) in &ready {
                self.drive(idx, Some(gen), true, hangup);
            }
            if last_sweep.elapsed() >= tick {
                self.sweep();
                last_sweep = Instant::now();
            }
        }
    }

    fn accept_burst(&mut self) {
        loop {
            let res = match &self.listener {
                Some(l) => l.accept(),
                None => return,
            };
            match res {
                Ok((stream, _addr)) => self.admit(stream),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => return,
            }
        }
    }

    /// Admission at the accept gate: a full house sheds the new connection
    /// with one error line instead of registering a socket the box has no
    /// budget for. Admitted connections are dealt round-robin.
    fn admit(&mut self, stream: TcpStream) {
        if self.conn_count.fetch_add(1, Ordering::SeqCst) >= self.opts.max_conns.max(1) {
            self.conn_count.fetch_sub(1, Ordering::SeqCst);
            shed(stream, &self.opts);
            return;
        }
        let slot = ConnSlot(self.conn_count.clone());
        let t = self.rr % self.peers.len();
        self.rr = self.rr.wrapping_add(1);
        if Arc::ptr_eq(&self.peers[t], &self.shared) {
            self.add_conn(stream, slot);
        } else {
            lock_recover(&self.peers[t].inbox).push((stream, slot));
            self.peers[t].waker.wake();
        }
    }

    fn add_conn(&mut self, stream: TcpStream, slot: ConnSlot) {
        if stream.set_nonblocking(true).is_err() {
            return; // slot drops -> count released
        }
        let idx = match self.free.pop() {
            Some(i) => i,
            None => {
                self.conns.push(None);
                (self.conns.len() - 1) as u32
            }
        };
        self.next_gen = self.next_gen.wrapping_add(1);
        let gen = self.next_gen;
        let now = Instant::now();
        let fd = stream.as_raw_fd();
        if self.poller.register(fd, token(idx, gen), Interest::READ).is_err() {
            self.free.push(idx);
            return;
        }
        self.conns[idx as usize] = Some(Conn {
            stream,
            _slot: slot,
            gen,
            buf: Vec::new(),
            scanned: 0,
            out: Vec::new(),
            written: 0,
            pending: None,
            eof: false,
            close_after_write: false,
            interest: Interest::READ,
            last_read_progress: now,
            last_write_progress: now,
        });
    }

    /// Advance one connection's state machine: drain writes, read if the
    /// FSM wants input, consume buffered lines, then settle the epoll
    /// interest set — or tear the connection down if it is done or dead.
    fn drive(&mut self, idx: u32, gen: Option<u32>, do_read: bool, hangup: bool) {
        let Some(slot) = self.conns.get_mut(idx as usize) else { return };
        let Some(mut conn) = slot.take() else { return };
        if let Some(g) = gen {
            if conn.gen != g {
                self.conns[idx as usize] = Some(conn); // stale event
                return;
            }
        }
        if hangup && conn.pending.is_some() {
            // The peer is gone (HUP/ERR is level-triggered and reported
            // regardless of interest, so keeping the registration would
            // spin the loop until the coordinator finishes). Tear down
            // now; the late completion is dropped by the generation check.
            let _ = self.poller.deregister(conn.stream.as_raw_fd());
            self.free.push(idx);
            return;
        }
        let mut dead = write_some(&mut conn);
        if !dead
            && do_read
            && conn.pending.is_none()
            && !conn.eof
            && !conn.close_after_write
        {
            dead |= read_some(&mut conn);
        }
        if !dead {
            self.process_buffer(&mut conn, idx);
            dead |= write_some(&mut conn);
        }
        let backlog = conn.out.len() - conn.written;
        let finished = backlog == 0
            && (conn.close_after_write
                || (conn.eof && conn.pending.is_none() && conn.buf.is_empty()));
        if dead || finished {
            let _ = self.poller.deregister(conn.stream.as_raw_fd());
            self.free.push(idx);
            return; // conn drops; its ConnSlot releases the count
        }
        let want = Interest {
            read: conn.pending.is_none()
                && !conn.close_after_write
                && !conn.eof
                && backlog < OUT_HIGH_WATER,
            write: backlog > 0,
        };
        if want != conn.interest {
            if self
                .poller
                .modify(conn.stream.as_raw_fd(), token(idx, conn.gen), want)
                .is_err()
            {
                let _ = self.poller.deregister(conn.stream.as_raw_fd());
                self.free.push(idx);
                return;
            }
            conn.interest = want;
        }
        self.conns[idx as usize] = Some(conn);
    }

    /// Consume complete request lines from the inbound buffer. Stops at a
    /// pending request (one in flight per connection), a doomed
    /// connection, or an outbound backlog past the high-water mark.
    /// Invariant: `buf` always starts at a line boundary, and
    /// `buf[..scanned]` is known to contain no newline.
    fn process_buffer(&mut self, conn: &mut Conn, idx: u32) {
        loop {
            if conn.pending.is_some() || conn.close_after_write {
                return;
            }
            if conn.out.len() - conn.written >= OUT_HIGH_WATER {
                return;
            }
            match conn.buf[conn.scanned..].iter().position(|&b| b == b'\n') {
                Some(rel) => {
                    let pos = conn.scanned + rel;
                    if pos > self.opts.max_line_bytes {
                        too_long(conn, &self.opts);
                        return;
                    }
                    let buf_taken = std::mem::take(&mut conn.buf);
                    self.dispatch(conn, idx, &buf_taken[..pos]);
                    conn.buf = buf_taken;
                    conn.buf.drain(..=pos);
                    conn.scanned = 0;
                }
                None => {
                    conn.scanned = conn.buf.len();
                    if conn.buf.len() > self.opts.max_line_bytes {
                        too_long(conn, &self.opts);
                    } else if conn.eof && !conn.buf.is_empty() {
                        // A trailing unterminated line at EOF still gets
                        // served (same contract as BufRead::lines).
                        let taken = std::mem::take(&mut conn.buf);
                        conn.scanned = 0;
                        self.dispatch(conn, idx, &taken);
                    }
                    return;
                }
            }
        }
    }

    /// Serve one request line: zero-copy submit parse first, then the
    /// owned tree for commands, fallbacks and error texts.
    fn dispatch(&mut self, conn: &mut Conn, idx: u32, bytes: &[u8]) {
        let owned;
        let line = match std::str::from_utf8(bytes) {
            Ok(s) => s,
            Err(_) => {
                owned = String::from_utf8_lossy(bytes).into_owned();
                owned.as_str()
            }
        };
        if line.trim().is_empty() {
            return;
        }
        if let Ok(Some(args)) = wire::parse_submit_fast(line) {
            self.submit(conn, idx, args);
            return;
        }
        let v = match Json::parse(line) {
            Ok(v) => v,
            Err(e) => {
                note_outbound(conn);
                wire::error_reply(&mut conn.out, &format!("{e:#}"));
                return;
            }
        };
        if v.opt("cmd").is_some() {
            note_outbound(conn);
            match handle_cmd(&self.coord, &v) {
                Ok(j) => {
                    conn.out.extend_from_slice(j.to_string().as_bytes());
                    conn.out.push(b'\n');
                }
                Err(e) => wire::error_reply(&mut conn.out, &format!("{e:#}")),
            }
            return;
        }
        match wire::submit_args_from_json(&v) {
            Ok(args) => self.submit(conn, idx, args),
            Err(e) => {
                note_outbound(conn);
                wire::error_reply(&mut conn.out, &format!("{e:#}"));
            }
        }
    }

    /// Hand a parsed request to the coordinator. The responder hook pushes
    /// the result onto this thread's completion queue and wakes the loop —
    /// including for synchronous refusals (overload, drain, unknown
    /// model), which are answered on the next loop pass.
    fn submit(&mut self, conn: &mut Conn, idx: u32, args: wire::SubmitArgs) {
        conn.pending = Some(args.meta());
        let shared = self.shared.clone();
        let gen = conn.gen;
        let responder = Responder::hook(move |res| {
            lock_recover(&shared.completions).push((idx, gen, res));
            shared.waker.wake();
        });
        self.coord.submit_with(args.req, responder);
    }

    /// Route one finished request back to its connection (if it is still
    /// the same connection) and drive the reply out.
    fn complete(&mut self, idx: u32, gen: u32, res: anyhow::Result<SampleResult>) {
        {
            let Some(Some(conn)) = self.conns.get_mut(idx as usize) else { return };
            if conn.gen != gen {
                return; // slot was recycled; the requester is long gone
            }
            let Some(meta) = conn.pending.take() else { return };
            note_outbound(conn);
            wire::write_reply(&mut conn.out, &meta, &res);
            // The read clock was parked while the request was in flight;
            // restart it so a buffered partial next line is not instantly
            // judged stalled.
            conn.last_read_progress = Instant::now();
        }
        self.drive(idx, Some(gen), false, false);
    }

    /// Periodic hygiene: drop connections stalled mid-request-line past
    /// `read_timeout` (slowloris) and connections whose reply has made no
    /// write progress past `write_timeout`. Idle connections between
    /// requests and connections waiting on the coordinator are exempt.
    fn sweep(&mut self) {
        let now = Instant::now();
        let mut doomed: Vec<u32> = Vec::new();
        for (i, slot) in self.conns.iter().enumerate() {
            let Some(conn) = slot else { continue };
            let backlog = conn.out.len() - conn.written;
            let write_stalled = backlog > 0
                && now.duration_since(conn.last_write_progress) > self.opts.write_timeout;
            let mid_line = conn.pending.is_none()
                && !conn.eof
                && backlog == 0
                && !conn.buf.is_empty()
                && !conn.buf.contains(&b'\n');
            let read_stalled = mid_line
                && now.duration_since(conn.last_read_progress) > self.opts.read_timeout;
            if write_stalled || read_stalled {
                doomed.push(i as u32);
            }
        }
        for idx in doomed {
            if let Some(conn) = self.conns[idx as usize].take() {
                let _ = self.poller.deregister(conn.stream.as_raw_fd());
                self.free.push(idx);
                // Silent close, matching the old thread-per-conn bail.
            }
        }
    }
}

/// Serve until the process dies, with explicit hardening options.
pub fn serve_with(
    coord: Arc<Coordinator>,
    addr: &str,
    opts: ServeOptions,
) -> Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let nthreads = opts.io_threads.max(1);
    let conn_count = Arc::new(AtomicUsize::new(0));
    let mut shareds: Vec<Arc<IoShared>> = Vec::with_capacity(nthreads);
    let mut rxs: Vec<UnixStream> = Vec::with_capacity(nthreads);
    for _ in 0..nthreads {
        let (waker, rx) = poll::waker_pair()?;
        shareds.push(Arc::new(IoShared {
            inbox: Mutex::new(Vec::new()),
            completions: Mutex::new(Vec::new()),
            waker,
        }));
        rxs.push(rx);
    }
    let mut listener = Some(listener);
    for (me, waker_rx) in rxs.into_iter().enumerate() {
        let poller = Poller::new()?;
        poller.register(waker_rx.as_raw_fd(), WAKER_TOKEN, Interest::READ)?;
        let own_listener = listener.take(); // thread 0 (first pass) accepts
        if let Some(l) = &own_listener {
            poller.register(l.as_raw_fd(), LISTENER_TOKEN, Interest::READ)?;
        }
        let io = IoThread {
            poller,
            waker_rx,
            listener: own_listener,
            conns: Vec::new(),
            free: Vec::new(),
            next_gen: 0,
            rr: me,
            shared: shareds[me].clone(),
            peers: shareds.clone(),
            coord: coord.clone(),
            opts,
            conn_count: conn_count.clone(),
        };
        std::thread::spawn(move || io.run());
    }
    Ok(local)
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { writer: stream.try_clone()?, reader: BufReader::new(stream) })
    }

    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line)
    }

    /// Call expecting a binary-framed reply: returns the header object and
    /// the decoded sample payload. A reply without `bin_bytes` (an error,
    /// or a request that degraded to plain JSON) comes back with an empty
    /// payload — check `header.opt("ok")` / `header.opt("samples")`.
    pub fn call_bin(&mut self, req: &Json) -> Result<(Json, Vec<f64>)> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let header = Json::parse(&line)?;
        let nbytes = header.opt("bin_bytes").map(|b| b.as_u64()).transpose()?.unwrap_or(0);
        if nbytes > wire::MAX_BIN_REPLY_BYTES {
            bail!(
                "binary frame too large: {nbytes} bytes (max {})",
                wire::MAX_BIN_REPLY_BYTES
            );
        }
        let mut payload = vec![0u8; nbytes as usize];
        self.reader.read_exact(&mut payload)?;
        Ok((header, wire::samples_from_le_bytes(&payload)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CoordinatorConfig, ModelRegistry};
    use crate::gmm::Gmm;
    use crate::score::GmmEps;

    fn coord() -> Arc<Coordinator> {
        let mut reg = ModelRegistry::new();
        reg.insert("gmm2d", Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())));
        Arc::new(Coordinator::new(CoordinatorConfig::default(), reg))
    }

    #[test]
    fn request_parsing_defaults() {
        let v = Json::parse(r#"{"model":"gmm2d","solver":"tab3","nfe":10,"n":4}"#).unwrap();
        let req = parse_request(&v).unwrap();
        assert_eq!(req.model, "gmm2d");
        assert_eq!(req.solver, SolverKind::Tab(3));
        assert_eq!(req.t0, 1e-3);
        assert_eq!(req.grid, GridKind::Quadratic);
    }

    #[test]
    fn tcp_roundtrip() {
        let c = coord();
        let addr = serve(c, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(addr).unwrap();
        let resp = client
            .call(&Json::parse(
                r#"{"model":"gmm2d","solver":"ddim","nfe":5,"n":4,"return_samples":true}"#,
            ).unwrap())
            .unwrap();
        assert!(resp.get("ok").unwrap().as_bool().unwrap(), "{resp:?}");
        assert_eq!(resp.get("samples").unwrap().as_arr().unwrap().len(), 8);

        let stats = client.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
        assert_eq!(stats.get("completed").unwrap().as_f64().unwrap(), 1.0);
        // The additive per-model breakdown mirrors the single-model traffic.
        let pm = stats.get("per_model").unwrap().get("gmm2d").unwrap();
        assert_eq!(pm.get("requests").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(pm.get("completed").unwrap().as_f64().unwrap(), 1.0);

        let models = client.call(&Json::parse(r#"{"cmd":"models"}"#).unwrap()).unwrap();
        assert_eq!(models.get("models").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn bad_requests_report_errors() {
        let c = coord();
        let addr = serve(c, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(addr).unwrap();
        for bad in [
            r#"{"model":"gmm2d","solver":"bogus","nfe":5,"n":4}"#,
            r#"{"model":"gmm2d","solver":"ddim","n":4}"#,
            r#"not json"#,
        ] {
            let resp = client.call(&Json::parse(&format!("{:?}", bad)).unwrap_or(Json::str(bad)))
                .unwrap_or_else(|_| {
                    // raw invalid line path
                    let mut cl = Client::connect(addr).unwrap();
                    cl.writer.write_all(bad.as_bytes()).unwrap();
                    cl.writer.write_all(b"\n").unwrap();
                    let mut line = String::new();
                    cl.reader.read_line(&mut line).unwrap();
                    Json::parse(&line).unwrap()
                });
            assert!(!resp.get("ok").unwrap().as_bool().unwrap(), "{bad}");
        }
    }

    /// Seeds are u64 end to end: a seed above 2^53 must parse losslessly
    /// (the old path went through f64, which silently collapses adjacent
    /// seeds), and a lossy/fractional seed is a parse error, not a guess.
    #[test]
    fn seed_above_2_53_parses_exactly() {
        let seed = (1u64 << 60) + 1;
        let line =
            format!(r#"{{"model":"gmm2d","solver":"tab3","nfe":10,"n":4,"seed":{seed}}}"#);
        let req = parse_request(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(req.seed, seed, "seed must not round-trip through f64");
        let bad = r#"{"model":"gmm2d","solver":"tab3","nfe":10,"n":4,"seed":1.5}"#;
        assert!(parse_request(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn health_reports_draining_and_model_state() {
        let c = coord();
        let addr = serve(c.clone(), "127.0.0.1:0").unwrap();
        let mut cl = Client::connect(addr).unwrap();
        let sample = Json::parse(r#"{"model":"gmm2d","solver":"ddim","nfe":5,"n":2}"#).unwrap();
        assert!(cl.call(&sample).unwrap().get("ok").unwrap().as_bool().unwrap());
        let h = cl.call(&Json::parse(r#"{"cmd":"health"}"#).unwrap()).unwrap();
        assert!(h.get("ok").unwrap().as_bool().unwrap());
        assert!(!h.get("draining").unwrap().as_bool().unwrap());
        assert!(h.get("models").unwrap().get("gmm2d").unwrap().as_bool().unwrap());
        // Draining: sampling is refused, introspection keeps working.
        c.begin_drain();
        let h = cl.call(&Json::parse(r#"{"cmd":"health"}"#).unwrap()).unwrap();
        assert!(h.get("draining").unwrap().as_bool().unwrap());
        let r = cl.call(&sample).unwrap();
        assert!(!r.get("ok").unwrap().as_bool().unwrap());
        assert!(r.get("error").unwrap().as_str().unwrap().contains("shutting down"));
    }

    #[test]
    fn over_long_request_lines_error_and_close() {
        let c = coord();
        let addr = serve_with(
            c,
            "127.0.0.1:0",
            ServeOptions { max_line_bytes: 128, ..Default::default() },
        )
        .unwrap();
        let mut cl = Client::connect(addr).unwrap();
        let huge = "x".repeat(4096);
        cl.writer.write_all(huge.as_bytes()).unwrap();
        cl.writer.write_all(b"\n").unwrap();
        let mut line = String::new();
        cl.reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert!(!resp.get("ok").unwrap().as_bool().unwrap());
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("too long"));
        let mut l2 = String::new();
        assert_eq!(
            cl.reader.read_line(&mut l2).unwrap(),
            0,
            "server must close the connection after an over-long line"
        );
    }

    #[test]
    fn connection_cap_sheds_excess_connections_with_an_error() {
        let c = coord();
        let addr = serve_with(
            c,
            "127.0.0.1:0",
            ServeOptions { max_conns: 1, ..Default::default() },
        )
        .unwrap();
        let mut keep = Client::connect(addr).unwrap();
        let models = Json::parse(r#"{"cmd":"models"}"#).unwrap();
        // A served call proves the first connection is accepted + counted.
        assert!(keep.call(&models).unwrap().get("ok").unwrap().as_bool().unwrap());
        let mut shed = Client::connect(addr).unwrap();
        let mut line = String::new();
        shed.reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert!(!resp.get("ok").unwrap().as_bool().unwrap());
        assert!(
            resp.get("error").unwrap().as_str().unwrap().contains("connection capacity"),
            "{resp:?}"
        );
        // The surviving connection is unaffected by the shed one.
        assert!(keep.call(&models).unwrap().get("ok").unwrap().as_bool().unwrap());
    }
}
